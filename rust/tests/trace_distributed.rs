//! Integration tests for the distributed EEG (§9.2): local chrome-trace
//! export with intra-op parallelism on, the two-replica acceptance path
//! (replica + parameter-server spans merged onto one clock-aligned
//! timeline with consistent step ids), and hostile wire frames on the
//! `MSG_TRACE_*` path erroring instead of panicking.

use rustflow::distributed::proto;
use rustflow::distributed::ps::{ParamServer, PsClient, PsOptions};
use rustflow::distributed::train::{DistTrainer, DistTrainerOptions};
use rustflow::graph::Endpoint;
use rustflow::optim::Optimizer;
use rustflow::tensor::Tensor;
use rustflow::util::json::Json;
use rustflow::{wire, GraphBuilder, Session, SessionOptions};

#[test]
fn local_chrome_trace_parses_and_orders_kernels() {
    // A dependent chain m → r → f with intra-op lanes on: the chrome
    // trace must be valid JSON (our own parser), every span must carry
    // this run's step id, and data dependencies must show up as ordered
    // spans even with multiple lanes running.
    let mut b = GraphBuilder::new();
    let x = b.constant(
        Tensor::from_f32(vec![64, 64], (0..4096).map(|i| (i % 13) as f32 * 0.25).collect())
            .unwrap(),
    );
    let m = b.matmul(x, x);
    let r = b.relu(m);
    let f = b.matmul(r, r);
    let m_name = b.graph.node(m.node).name.clone();
    let r_name = b.graph.node(r.node).name.clone();
    let fetch = format!("{}:0", b.graph.node(f.node).name);
    let sess = Session::new(
        b.into_graph(),
        SessionOptions {
            trace: true,
            intra_op_threads: 4,
            // Keep the const-rooted chain executing as kernels.
            enable_constant_folding: false,
            ..Default::default()
        },
    );
    sess.run(&[], &[&fetch], &[]).unwrap();

    let trace = sess.last_trace().expect("tracing enabled");
    let events = trace.events();
    let stats = sess.last_step_stats().expect("step stats produced");
    assert!(events.iter().all(|e| e.step == stats.step_id), "one step id per run");
    let ev = |name: &str| {
        events.iter().find(|e| e.name == *name).unwrap_or_else(|| panic!("no span for {name}"))
    };
    let (em, er) = (ev(&m_name), ev(&r_name));
    // relu consumes the matmul: its span cannot begin before the matmul
    // span ends (±2µs timestamp truncation slack).
    assert!(
        er.start_us + 2 >= em.start_us + em.dur_us,
        "relu at {} before matmul [{}, +{}] ended",
        er.start_us,
        em.start_us,
        em.dur_us
    );

    let json = trace.to_chrome_trace();
    let parsed = Json::parse(&json).unwrap();
    let arr = parsed.as_array().unwrap();
    assert_eq!(arr.len(), events.len());
    for e in arr {
        assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"));
        assert!(e.get("ts").and_then(Json::as_i64).unwrap() >= 0);
        assert!(e.get("dur").and_then(Json::as_i64).unwrap() >= 1);
        let step = e.get("args").unwrap().get("step").and_then(Json::as_i64).unwrap();
        assert_eq!(step as u64, stats.step_id);
    }
}

/// The tower from the training tests: loss = (w0*x + w1 - y)^2.
fn tower(b: &mut GraphBuilder) -> (Endpoint, Endpoint, Endpoint) {
    let w0 = b.variable("w0", Tensor::scalar_f32(0.25)).unwrap();
    let w1 = b.variable("w1", Tensor::scalar_f32(-0.5)).unwrap();
    let x = b.placeholder("x", rustflow::DType::F32).unwrap();
    let y = b.placeholder("y", rustflow::DType::F32).unwrap();
    let wx = b.mul(w0, x);
    let pred = b.add(wx, w1);
    let d = b.sub(pred, y);
    (b.square(d), w0, w1)
}

#[test]
fn two_replica_sync_step_merges_into_one_timeline() {
    // The acceptance path: two synchronous replicas train against a
    // tracing parameter server; replica 1 hands its fragment to replica
    // 0, whose `merged_trace` pulls the shard's spans (clock-aligned via
    // the HELLO offsets) and renders one chrome://tracing JSON with
    // worker AND ps lanes carrying consistent step ids.
    const STEPS: u64 = 2;
    let ps = ParamServer::new(PsOptions {
        opt: Optimizer::sgd(0.25),
        sync_replicas: Some(2),
        trace: true,
        ..Default::default()
    });
    let addr = ps.serve("127.0.0.1:0").unwrap().to_string();

    let trainers: Vec<DistTrainer> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..2u32)
            .map(|r| {
                let addr = addr.clone();
                scope.spawn(move || {
                    let mut b = GraphBuilder::new();
                    let (loss, w0, w1) = tower(&mut b);
                    let mut t = DistTrainer::new(
                        b,
                        loss,
                        &[w0, w1],
                        r,
                        &[addr],
                        DistTrainerOptions { compress: false, ..Default::default() },
                        SessionOptions { trace: true, ..Default::default() },
                    )
                    .unwrap();
                    t.init_params().unwrap();
                    for s in 0..STEPS {
                        let x = 1.0 + 0.5 * r as f32 + 0.25 * s as f32;
                        let feeds =
                            [("x", Tensor::scalar_f32(x)), ("y", Tensor::scalar_f32(2.0 * x))];
                        t.step(&feeds).unwrap();
                    }
                    t
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    // Give the applier a beat to finish recording the final apply span
    // (pushes unblock on the version bump, a hair before the span ends).
    std::thread::sleep(std::time::Duration::from_millis(100));

    let mut it = trainers.into_iter();
    let t0 = it.next().unwrap();
    let t1 = it.next().unwrap();
    let frag1 = t1.take_trace().expect("replica 1 traced");
    let json = t0.merged_trace(vec![frag1]).unwrap();

    let parsed = Json::parse(&json).unwrap();
    let arr = parsed.as_array().unwrap();
    // (pid, name, ts, step) per event.
    let rows: Vec<(String, String, i64, u64)> = arr
        .iter()
        .map(|e| {
            (
                e.get("pid").and_then(Json::as_str).unwrap().to_string(),
                e.get("name").and_then(Json::as_str).unwrap().to_string(),
                e.get("ts").and_then(Json::as_i64).unwrap(),
                e.get("args").unwrap().get("step").and_then(Json::as_i64).unwrap() as u64,
            )
        })
        .collect();

    // All three lanes present.
    for pid in ["replica:0", "replica:1", "ps"] {
        assert!(rows.iter().any(|(p, ..)| p == pid), "no {pid} lane in {json}");
    }
    // Each replica lane has the three phase spans for every step, plus
    // at least one session kernel span re-tagged with the step number.
    for pid in ["replica:0", "replica:1"] {
        for step in 0..STEPS {
            for phase in ["replica/pull", "replica/compute", "replica/push"] {
                assert!(
                    rows.iter().any(|(p, n, _, s)| p == pid && n == phase && *s == step),
                    "{pid} missing {phase} at step {step}"
                );
            }
            assert!(
                rows.iter().any(|(p, n, _, s)| p == pid
                    && *s == step
                    && !n.starts_with("replica/")),
                "{pid} has no kernel spans at step {step}"
            );
        }
    }
    // The ps lane shows the sync protocol: recv + barrier-wait for both
    // steps, and an apply for step 0 at minimum (step 1's span recording
    // can race the final unblock). Every ps step id is a real step.
    for step in 0..STEPS {
        for phase in ["ps/recv", "ps/barrier_wait"] {
            assert!(
                rows.iter().any(|(p, n, _, s)| p == "ps" && n == phase && *s == step),
                "ps missing {phase} at step {step}"
            );
        }
    }
    assert!(rows.iter().any(|(p, n, _, s)| p == "ps" && n == "ps/apply" && *s == 0));
    assert!(rows.iter().all(|(p, _, _, s)| p != "ps" || *s < STEPS));

    // One aligned timeline: normalized to 0, everything within a sane
    // window, and causality holds across processes — step 0's apply
    // cannot precede the first replica/push of step 0 (5ms slack for the
    // loopback clock-offset estimate).
    assert_eq!(rows.iter().map(|(_, _, ts, _)| *ts).min(), Some(0));
    assert!(rows.iter().all(|(_, _, ts, _)| *ts < 120_000_000), "wild timestamp in {json}");
    let first_push = rows
        .iter()
        .filter(|(_, n, _, s)| n == "replica/push" && *s == 0)
        .map(|(_, _, ts, _)| *ts)
        .min()
        .unwrap();
    let apply = rows
        .iter()
        .filter(|(p, n, _, s)| p == "ps" && n == "ps/apply" && *s == 0)
        .map(|(_, _, ts, _)| *ts)
        .min()
        .unwrap();
    assert!(apply + 5_000 >= first_push, "apply at {apply} before any push at {first_push}");

    // Everything was drained: a second merge has no replica-0/ps events.
    let again = t0.merged_trace(vec![]).unwrap();
    assert_eq!(Json::parse(&again).unwrap().as_array().unwrap().len(), 0);
    ps.shutdown();
}

#[test]
fn hostile_trace_wire_frames_error_not_panic() {
    // Server side: a garbage frame (truncated header) drops that
    // connection only — the server keeps serving trace pulls.
    let ps = ParamServer::new(PsOptions { trace: true, ..Default::default() });
    let addr = ps.serve("127.0.0.1:0").unwrap().to_string();
    {
        use std::io::Write;
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        s.write_all(&[0xFF, 0xFF, 0x03]).unwrap(); // 3 of 5 header bytes
    } // dropped mid-frame
    let c = PsClient::connect(&addr, false).unwrap();
    let frag = c.trace_pull().unwrap();
    assert_eq!(frag.process, "ps");
    ps.shutdown();

    // Client side: a server replying MSG_TRACE_REPLY with a truncated
    // payload must surface as a decode error from `trace_pull`.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let fake_addr = listener.local_addr().unwrap().to_string();
    let fake = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let (t, _) = wire::read_frame(&mut s).unwrap();
        assert_eq!(t, proto::MSG_PS_HELLO);
        let hello = proto::PsHelloReply { status: Ok(()), flags: 0, time_us: 0 };
        wire::write_frame(&mut s, proto::MSG_PS_HELLO_REPLY, &hello.encode()).unwrap();
        let (t, _) = wire::read_frame(&mut s).unwrap();
        assert_eq!(t, proto::MSG_TRACE_PULL);
        // A fragment with a claimed event count but no event bytes.
        let mut garbage = Vec::new();
        garbage.push(255u8); // status: Ok
        wire::put_str(&mut garbage, "ps");
        wire::put_u64(&mut garbage, 0); // dropped
        wire::put_u32(&mut garbage, 1000); // 1000 events follow... or not
        wire::write_frame(&mut s, proto::MSG_TRACE_REPLY, &garbage).unwrap();
    });
    let c = PsClient::connect(&fake_addr, false).unwrap();
    assert!(c.trace_pull().is_err(), "truncated fragment must fail to decode");
    fake.join().unwrap();
}
