//! Cross-module integration tests: input pipelines with queues (§4.5/4.6),
//! summaries (§9.1), tracing (§9.2), optimization ablations (§5), and
//! randomized property checks over the coordinator invariants.

use rustflow::graph::AttrValue;
use rustflow::optim::Optimizer;
use rustflow::util::rng::Pcg32;
use rustflow::{data, models, DType, GraphBuilder, Session, SessionOptions, Tensor};

fn init_and_session(b: GraphBuilder, devices: usize) -> (Session, Vec<String>) {
    let inits: Vec<String> = b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
    let sess =
        Session::new(b.into_graph(), SessionOptions { devices, ..Default::default() });
    (sess, inits)
}

#[test]
fn input_pipeline_queue_prefetch() {
    // Producer subgraph enqueues batches; consumer dequeues and computes —
    // "input data to be prefetched … while a previous batch of data is
    // still being processed" (§4.6).
    let mut b = GraphBuilder::new();
    let q = b
        .op1(
            "FIFOQueue",
            "q",
            vec![],
            vec![
                ("capacity", AttrValue::I64(4)),
                ("component_types", AttrValue::ListType(vec![DType::F32])),
            ],
        )
        .unwrap();
    let batch = b.constant(Tensor::fill_f32(vec![4, 8], 0.5));
    let enq = b.op("Enqueue", "enq", vec![q, batch], vec![]).unwrap();
    let deq = b
        .op(
            "Dequeue",
            "deq",
            vec![q],
            vec![("component_types", AttrValue::ListType(vec![DType::F32]))],
        )
        .unwrap();
    let x = rustflow::Endpoint::new(deq, 0);
    let s = b.reduce_sum(x, None);
    let sname = format!("{}:0", b.graph.node(s.node).name);
    let ename = b.graph.node(enq).name.clone();
    let (sess, _) = init_and_session(b, 1);
    // Prefetch 3 batches, then consume them.
    for _ in 0..3 {
        sess.run_targets(&[&ename]).unwrap();
    }
    for _ in 0..3 {
        let out = sess.run(&[], &[&sname], &[]).unwrap();
        assert_eq!(out[0].scalar_value_f32().unwrap(), 16.0);
    }
}

#[test]
fn summaries_flow_to_writer() {
    let mut b = GraphBuilder::new();
    let loss = b.scalar(0.25);
    let s1 = b
        .op1("ScalarSummary", "loss_summary", vec![loss], vec![("tag", AttrValue::Str("loss".into()))])
        .unwrap();
    let w = b.constant(Tensor::from_f32(vec![4], vec![0.1, 0.2, 0.3, 0.4]).unwrap());
    let s2 = b
        .op1("HistogramSummary", "w_hist", vec![w], vec![("tag", AttrValue::Str("w".into()))])
        .unwrap();
    let merged = b.op1("MergeSummary", "merged", vec![s1, s2], vec![]).unwrap();
    let mname = format!("{}:0", b.graph.node(merged.node).name);
    let (sess, _) = init_and_session(b, 1);
    let out = sess.run(&[], &[&mname], &[]).unwrap();
    let records = out[0].as_str_slice().unwrap();
    assert_eq!(records.len(), 2);
    assert!(records[0].contains("\"tag\":\"loss\""));
    assert!(records[1].contains("histogram"));
    // Write to an events file + render.
    let path = std::env::temp_dir().join(format!("rf-int-events-{}.log", std::process::id()));
    let mut writer = rustflow::summary::SummaryWriter::create(&path).unwrap();
    writer.add_summary(7, &out[0]).unwrap();
    writer.flush().unwrap();
    let rendered = rustflow::summary::summarize(&path).unwrap();
    assert!(rendered.contains("loss"));
}

#[test]
fn trace_covers_multi_device_step() {
    let mut b = GraphBuilder::new();
    let x = b.constant(Tensor::fill_f32(vec![16, 16], 0.1));
    let mut l = x;
    let mut r = x;
    for _ in 0..3 {
        l = b.matmul(l, l);
        r = b.matmul(r, x);
    }
    let out = b.add(l, r);
    let name = format!("{}:0", b.graph.node(out.node).name);
    // Constant folding off: this graph is const-rooted, and the test wants
    // the *kernels* to run across devices, not a folded literal.
    let sess = Session::new(
        b.into_graph(),
        SessionOptions {
            devices: 2,
            trace: true,
            enable_constant_folding: false,
            ..Default::default()
        },
    );
    sess.run(&[], &[&name], &[]).unwrap();
    let trace = sess.last_trace().unwrap();
    assert!(trace.len() >= 7, "expected kernel spans, got {}", trace.len());
    let json = trace.to_chrome_trace();
    assert!(json.contains("MatMul"));
    // Multi-device: events on at least 2 distinct pids (devices).
    let devices: std::collections::HashSet<String> =
        trace.events().into_iter().map(|e| e.device).collect();
    assert!(devices.len() >= 2, "trace shows {devices:?}");
}

#[test]
fn cse_ablation_reduces_execution() {
    // The same redundant graph with and without §5.1 CSE: fewer kernel
    // executions with the pass on.
    let build = || {
        let mut b = GraphBuilder::new();
        let x = b.constant(Tensor::fill_f32(vec![32, 32], 0.01));
        // Four copies of the same tower.
        let mut outs = Vec::new();
        for _ in 0..4 {
            let mut h = x;
            for _ in 0..3 {
                h = b.matmul(h, x);
            }
            outs.push(h);
        }
        let sum = b.add_n(outs);
        let name = format!("{}:0", b.graph.node(sum.node).name);
        (b, name)
    };
    let run = |enable_cse: bool| -> usize {
        let (b, name) = build();
        // Folding off: the towers are const-rooted and would otherwise
        // collapse identically with or without CSE.
        let sess = Session::new(
            b.into_graph(),
            SessionOptions {
                enable_cse,
                trace: true,
                enable_constant_folding: false,
                ..Default::default()
            },
        );
        let r = sess.run(&[], &[&name], &[]).unwrap();
        assert!(r[0].as_f32().unwrap()[0].is_finite());
        sess.last_trace().unwrap().len()
    };
    let with_cse = run(true);
    let without = run(false);
    assert!(
        with_cse < without,
        "CSE should reduce executed kernels: {with_cse} vs {without}"
    );
}

#[test]
fn compression_ablation_preserves_training() {
    // §5.5: train the same model with wire compression forced on for every
    // cross-device edge; convergence must be preserved.
    let run = |compress_all: bool| -> f32 {
        let mut b = GraphBuilder::new();
        let examples = data::synthetic_classification(64, 16, 4, 0.2, 9);
        let (f, l) = data::batch_tensors(&examples).unwrap();
        let x = b.with_device("/device:cpu:0", |b| b.constant(f.clone()));
        let labels = b.with_device("/device:cpu:1", |b| b.constant(data::one_hot(l.as_i32().unwrap(), 4)));
        let (logits, vars) = b.with_device("/device:cpu:0", |b| models::mlp(b, x, &[16, 32, 4], 3)).unwrap();
        let loss = b.with_device("/device:cpu:1", |b| models::xent_loss(b, logits, labels)).unwrap();
        let train = Optimizer::sgd(0.5).minimize(&mut b, loss, &vars).unwrap();
        let tname = b.graph.node(train).name.clone();
        let lname = format!("{}:0", b.graph.node(loss.node).name);
        let mut opts = SessionOptions { devices: 2, ..Default::default() };
        opts.partition.compress_all = compress_all;
        let inits: Vec<String> =
            b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
        let sess = Session::new(b.into_graph(), opts);
        sess.run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();
        let mut loss_v = f32::NAN;
        for _ in 0..60 {
            loss_v = sess.run(&[], &[&lname], &[&tname]).unwrap()[0]
                .scalar_value_f32()
                .unwrap();
        }
        loss_v
    };
    let exact = run(false);
    let lossy = run(true);
    assert!(exact < 0.5, "baseline failed to converge: {exact}");
    assert!(lossy < 0.7, "compressed training diverged: {lossy}");
    assert!((exact - lossy).abs() < 0.4, "compression changed convergence too much: {exact} vs {lossy}");
}

#[test]
fn checkpoint_training_roundtrip() {
    // Train → Save → perturb → Restore → verify variables back.
    let dir = std::env::temp_dir().join(format!("rf-int-ckpt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("m.ckpt").to_string_lossy().to_string();
    let mut b = GraphBuilder::new();
    let w = b.variable("w", Tensor::from_f32(vec![3], vec![1., 2., 3.]).unwrap()).unwrap();
    let two = b.scalar(2.0);
    let double = b.mul(w, two);
    let upd = b.assign(w, double).unwrap();
    let save = b
        .op(
            "Save",
            "save",
            vec![w],
            vec![
                ("tensor_names", AttrValue::ListStr(vec!["w".into()])),
                ("path", AttrValue::Str(ckpt.clone())),
            ],
        )
        .unwrap();
    let restore = b
        .op1(
            "Restore",
            "restore",
            vec![],
            vec![
                ("tensor_names", AttrValue::ListStr(vec!["w".into()])),
                ("out_types", AttrValue::ListType(vec![DType::F32])),
                ("path", AttrValue::Str(ckpt)),
            ],
        )
        .unwrap();
    let restore_op = b.assign(w, restore).unwrap();
    let names: Vec<String> = [upd, save, restore_op]
        .iter()
        .map(|&n| b.graph.node(n).name.clone())
        .collect();
    let (sess, inits) = init_and_session(b, 1);
    sess.run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();
    sess.run_targets(&[&names[0]]).unwrap(); // w = [2,4,6]
    sess.run_targets(&[&names[1]]).unwrap(); // save
    sess.run_targets(&[&names[0]]).unwrap(); // w = [4,8,12]
    sess.run_targets(&[&names[2]]).unwrap(); // restore
    let out = sess.run(&[], &["w"], &[]).unwrap();
    assert_eq!(out[0].as_f32().unwrap(), &[2., 4., 6.]);
}

/// Property test (hand-rolled; no proptest in the image): random DAGs run
/// on 1 vs N devices must produce identical fetch values — the §3.2
/// partitioning correctness invariant.
#[test]
fn property_random_graphs_device_count_invariant() {
    for seed in 0..12u64 {
        let mut rng = Pcg32::new(seed * 7 + 1);
        let build = |_rng: &mut Pcg32| {
            let mut rng = Pcg32::new(seed * 7 + 1);
            let mut b = GraphBuilder::new();
            let mut pool: Vec<rustflow::Endpoint> = (0..3)
                .map(|i| {
                    let n = 4usize;
                    b.constant(
                        Tensor::from_f32(
                            vec![n, n],
                            (0..n * n).map(|_| rng.uniform(-1.0, 1.0)).collect(),
                        )
                        .unwrap(),
                    )
                })
                .collect();
            for _ in 0..10 {
                let a = pool[rng.index(pool.len())];
                let c = pool[rng.index(pool.len())];
                let v = match rng.next_below(4) {
                    0 => b.add(a, c),
                    1 => b.mul(a, c),
                    2 => b.matmul(a, c),
                    _ => b.tanh(a),
                };
                pool.push(v);
            }
            let out = *pool.last().unwrap();
            let name = format!("{}:0", b.graph.node(out.node).name);
            (b, name)
        };
        // Folding off: the graphs are const-rooted, and the invariant under
        // test is that *partitioned execution* agrees across device counts.
        let no_fold =
            || SessionOptions { enable_constant_folding: false, ..Default::default() };
        let (b1, n1) = build(&mut rng);
        let r1 = Session::new(b1.into_graph(), no_fold())
            .run(&[], &[&n1], &[])
            .unwrap();
        let (b3, n3) = build(&mut rng);
        let r3 = Session::new(
            b3.into_graph(),
            SessionOptions { devices: 3, ..no_fold() },
        )
        .run(&[], &[&n3], &[])
        .unwrap();
        assert!(
            r1[0].allclose(&r3[0], 1e-4, 1e-4),
            "seed {seed}: single vs multi device mismatch"
        );
    }
}

/// Property: CSE never changes results (random redundant graphs).
#[test]
fn property_cse_preserves_semantics() {
    for seed in 0..8u64 {
        let build = || {
            let mut rng = Pcg32::new(seed + 100);
            let mut b = GraphBuilder::new();
            let x = b.constant(
                Tensor::from_f32(vec![4, 4], (0..16).map(|_| rng.uniform(-1.0, 1.0)).collect())
                    .unwrap(),
            );
            let mut pool = vec![x];
            for _ in 0..8 {
                let a = pool[rng.index(pool.len())];
                let v = match rng.next_below(3) {
                    0 => b.mul(a, x),
                    1 => b.add(a, a),
                    _ => b.tanh(a),
                };
                pool.push(v);
            }
            let sum = b.add_n(pool[1..].to_vec());
            let name = format!("{}:0", b.graph.node(sum.node).name);
            (b, name)
        };
        let run = |enable_cse: bool| {
            let (b, name) = build();
            // Folding off so the CSE ablation is not vacuous on these
            // const-rooted graphs.
            Session::new(
                b.into_graph(),
                SessionOptions {
                    enable_cse,
                    enable_constant_folding: false,
                    ..Default::default()
                },
            )
            .run(&[], &[&name], &[])
            .unwrap()
            .remove(0)
        };
        let with = run(true);
        let without = run(false);
        assert!(with.allclose(&without, 1e-5, 1e-5), "seed {seed}: CSE changed results");
    }
}

#[test]
fn mnist_style_training_converges_multi_device() {
    let mut b = GraphBuilder::new();
    let examples = data::synthetic_classification(128, 16, 4, 0.25, 13);
    let (f, l) = data::batch_tensors(&examples).unwrap();
    let x = b.constant(f);
    let y = b.constant(data::one_hot(l.as_i32().unwrap(), 4));
    let (logits, vars) = models::mlp(&mut b, x, &[16, 32, 4], 7).unwrap();
    let loss = models::xent_loss(&mut b, logits, y).unwrap();
    let train = Optimizer::momentum(0.1, 0.9).minimize(&mut b, loss, &vars).unwrap();
    let tname = b.graph.node(train).name.clone();
    let lname = format!("{}:0", b.graph.node(loss.node).name);
    let (sess, inits) = init_and_session(b, 2);
    sess.run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();
    let first = sess.run(&[], &[&lname], &[&tname]).unwrap()[0].scalar_value_f32().unwrap();
    let mut last = first;
    for _ in 0..80 {
        last = sess.run(&[], &[&lname], &[&tname]).unwrap()[0].scalar_value_f32().unwrap();
    }
    assert!(last < first * 0.5, "training failed to converge: {first} -> {last}");
}
