//! Intra-op parallelism contract tests: kernel results must be
//! *bit-identical* for every `SessionOptions::intra_op_threads` setting
//! (the `ComputePool` determinism contract — deterministic contiguous
//! chunks, each output element computed by exactly one chunk with a
//! fixed operation order), and a panic in an intra-op worker must fail
//! the step with a `Status` instead of hanging the executor or aborting
//! the process.

use rustflow::graph::Node;
use rustflow::kernels::{register_kernel, Kernel, KernelContext};
use rustflow::ops::{register_op, Arity, Category, OpDef};
use rustflow::{GraphBuilder, Session, SessionOptions, Tensor};
use std::sync::Arc;

/// Deterministic pseudo-random fill (no RNG dependency; same bytes on
/// every run and platform).
fn fill(n: usize, seed: u32) -> Vec<f32> {
    (0..n)
        .map(|i| {
            let h = (i as u32).wrapping_mul(2654435761).wrapping_add(seed.wrapping_mul(97));
            ((h % 2000) as f32) * 0.013 - 13.0
        })
        .collect()
}

/// Build + run the same graph at the given intra-op width, returning the
/// fetched tensors' raw f32 data.
fn run_with_intra(
    intra: usize,
    build: impl FnOnce(&mut GraphBuilder) -> Vec<String>,
    feeds: &[(&str, Tensor)],
) -> Vec<Vec<f32>> {
    let mut b = GraphBuilder::new();
    let fetches = build(&mut b);
    let sess = Session::new(
        b.into_graph(),
        SessionOptions { intra_op_threads: intra, ..Default::default() },
    );
    let fetch_refs: Vec<&str> = fetches.iter().map(|s| s.as_str()).collect();
    let out = sess.run(feeds, &fetch_refs, &[]).unwrap();
    out.iter().map(|t| t.as_f32().unwrap().to_vec()).collect()
}

/// Assert the graph fetches identical bytes at 1/2/4/8 intra-op threads.
fn assert_bit_identical(
    build: impl Fn(&mut GraphBuilder) -> Vec<String>,
    feeds: &[(&str, Tensor)],
    what: &str,
) {
    let base = run_with_intra(1, &build, feeds);
    for threads in [2usize, 4, 8] {
        let got = run_with_intra(threads, &build, feeds);
        assert_eq!(got.len(), base.len());
        for (i, (g, b)) in got.iter().zip(&base).enumerate() {
            assert_eq!(g, b, "{what}: fetch {i} differs at intra_op_threads={threads}");
        }
    }
}

#[test]
fn matmul_bit_identical_all_transposes_odd_dims() {
    // Non-multiple-of-tile dims (KC=128/NC=512 tiles never divide these)
    // and every transpose-flag combination, fed so nothing folds away.
    let (m, k, n) = (97usize, 131usize, 43usize);
    for (ta, tb) in [(false, false), (false, true), (true, false), (true, true)] {
        let a_dims = if ta { vec![k, m] } else { vec![m, k] };
        let b_dims = if tb { vec![n, k] } else { vec![k, n] };
        let a = Tensor::from_f32(a_dims, fill(m * k, 1)).unwrap();
        let feeds = [("a", a)];
        let build = |b: &mut GraphBuilder| {
            let x = b.placeholder("a", rustflow::DType::F32).unwrap();
            let w = b.constant(Tensor::from_f32(b_dims.clone(), fill(k * n, 2)).unwrap());
            let mm = b.matmul_t(x, w, ta, tb);
            vec![format!("{}:0", b.graph.node(mm.node).name)]
        };
        assert_bit_identical(build, &feeds, &format!("matmul ta={ta} tb={tb}"));
    }
}

#[test]
fn matmul_m1_row_vector_bit_identical() {
    // m == 1 skips the panel loop for the GEMV paths of the packed GEMM
    // (per-column dot when B is transposed, ascending-k axpy otherwise);
    // both must keep the same every-thread-count byte contract.
    let (k, n) = (219usize, 87usize);
    for tb in [false, true] {
        let b_dims = if tb { vec![n, k] } else { vec![k, n] };
        let a = Tensor::from_f32(vec![1, k], fill(k, 9)).unwrap();
        let feeds = [("a", a)];
        let build = |b: &mut GraphBuilder| {
            let x = b.placeholder("a", rustflow::DType::F32).unwrap();
            let w = b.constant(Tensor::from_f32(b_dims.clone(), fill(k * n, 10)).unwrap());
            let mm = b.matmul_t(x, w, false, tb);
            vec![format!("{}:0", b.graph.node(mm.node).name)]
        };
        assert_bit_identical(build, &feeds, &format!("matmul m=1 tb={tb}"));
    }
}

#[test]
fn conv_relu_maxpool_net_and_gradients_bit_identical() {
    // A conv stack through autodiff: Convolution2D → BiasAdd → ReLU →
    // MaxPool, a scalar loss, and gradients w.r.t. input, filter and
    // bias — covering the im2col forward, Conv2DBackpropInput/Filter,
    // the MaxPoolGrad gather, ReluGrad and BiasAddGrad parallel paths.
    let (n, h, w, ic, kh, oc) = (2usize, 9, 8, 3, 3, 8);
    let x = Tensor::from_f32(vec![n, h, w, ic], fill(n * h * w * ic, 11)).unwrap();
    let feeds = [("x", x)];
    let build = |b: &mut GraphBuilder| {
        let x = b.placeholder("x", rustflow::DType::F32).unwrap();
        let f = b.constant(
            Tensor::from_f32(vec![kh, kh, ic, oc], fill(kh * kh * ic * oc, 12)).unwrap(),
        );
        let bias = b.constant(Tensor::from_f32(vec![oc], fill(oc, 13)).unwrap());
        let conv = b
            .op1(
                "Convolution2D",
                "conv",
                vec![x, f],
                vec![("stride", 1i64.into()), ("padding", "SAME".into())],
            )
            .unwrap();
        let ba = b.bias_add(conv, bias);
        let r = b.relu(ba);
        let mp = b
            .op1(
                "MaxPool",
                "mp",
                vec![r],
                vec![("ksize", 2i64.into()), ("stride", 2i64.into()), ("padding", "VALID".into())],
            )
            .unwrap();
        let loss = b.reduce_sum(mp, None);
        let grads = rustflow::autodiff::gradients(b, loss, &[x, f, bias]).unwrap();
        let mut fetches = vec![
            format!("{}:0", b.graph.node(mp.node).name),
            format!("{}:0", b.graph.node(loss.node).name),
        ];
        for g in grads {
            let g = g.expect("conv-net gradient exists");
            fetches.push(format!("{}:{}", b.graph.node(g.node).name, g.port));
        }
        fetches
    };
    assert_bit_identical(build, &feeds, "conv/relu/maxpool net + gradients");
}

#[test]
fn softmax_xent_fused_bit_identical() {
    // The fused loss+backprop xent kernel: both outputs, plus the
    // gradient of the summed loss w.r.t. the logits.
    let (rows, cols) = (53usize, 31usize);
    let x = Tensor::from_f32(vec![rows, cols], fill(rows * cols, 14)).unwrap();
    // Rows of positive weights summing to 1, so labels are
    // distribution-shaped (values don't matter for the byte contract).
    let raw = fill(rows * cols, 15);
    let mut lab = vec![0f32; rows * cols];
    for r in 0..rows {
        let row = &raw[r * cols..(r + 1) * cols];
        let sum: f32 = row.iter().map(|v| v.abs() + 0.01).sum();
        for c in 0..cols {
            lab[r * cols + c] = (row[c].abs() + 0.01) / sum;
        }
    }
    let feeds = [("x", x)];
    let build = |b: &mut GraphBuilder| {
        let x = b.placeholder("x", rustflow::DType::F32).unwrap();
        let labels = b.constant(Tensor::from_f32(vec![rows, cols], lab.clone()).unwrap());
        let (loss, backprop) = b.softmax_xent(x, labels).unwrap();
        let total = b.reduce_sum(loss, None);
        let grads = rustflow::autodiff::gradients(b, total, &[x]).unwrap();
        let g = grads[0].expect("dloss/dlogits exists");
        vec![
            format!("{}:0", b.graph.node(loss.node).name),
            format!("{}:{}", b.graph.node(backprop.node).name, backprop.port),
            format!("{}:{}", b.graph.node(g.node).name, g.port),
        ]
    };
    assert_bit_identical(build, &feeds, "fused softmax xent");
}

#[test]
fn shared_session_concurrent_steps_bit_identical() {
    // Many threads drive the SAME session — one intra-op pool, so chunks
    // from concurrent steps mix in the worker deques and get stolen
    // across jobs (the serving fan-in shape). Every step must still
    // produce its serial bytes.
    let dim = 96usize;
    let build = |b: &mut GraphBuilder| -> Vec<String> {
        let x = b.placeholder("x", rustflow::DType::F32).unwrap();
        let w = b.constant(Tensor::from_f32(vec![dim, dim], fill(dim * dim, 90)).unwrap());
        let mm = b.matmul(x, w);
        let t = b.tanh(mm);
        let sm = b.softmax(t);
        vec![format!("{}:0", b.graph.node(sm.node).name)]
    };
    let make = |intra: usize| {
        let mut b = GraphBuilder::new();
        let fetches = build(&mut b);
        let sess = Session::new(
            b.into_graph(),
            SessionOptions { intra_op_threads: intra, ..Default::default() },
        );
        (sess, fetches)
    };
    let (serial, fetches) = make(1);
    let expected: Vec<Vec<f32>> = (0..8u32)
        .map(|t| {
            let x = Tensor::from_f32(vec![dim, dim], fill(dim * dim, 100 + t)).unwrap();
            let fr: Vec<&str> = fetches.iter().map(|s| s.as_str()).collect();
            serial.run(&[("x", x)], &fr, &[]).unwrap()[0].as_f32().unwrap().to_vec()
        })
        .collect();
    let (shared, fetches) = make(4);
    let shared = Arc::new(shared);
    std::thread::scope(|s| {
        for (t, want) in expected.iter().enumerate() {
            let shared = Arc::clone(&shared);
            let fetches = &fetches;
            s.spawn(move || {
                let fr: Vec<&str> = fetches.iter().map(|s| s.as_str()).collect();
                for round in 0..10 {
                    let x =
                        Tensor::from_f32(vec![dim, dim], fill(dim * dim, 100 + t as u32)).unwrap();
                    let got = shared.run(&[("x", x)], &fr, &[]).unwrap();
                    assert_eq!(
                        got[0].as_f32().unwrap(),
                        &want[..],
                        "thread {t} round {round} diverged from serial bytes"
                    );
                }
            });
        }
    });
}

#[test]
fn fused_broadcast_chain_bit_identical() {
    // tanh(x * scale + row_bias): fuses into one FusedElementwise with a
    // scalar extra and a row-broadcast ([cols] vs [rows, cols]) extra —
    // the strided fast path, chunked mid-tensor by the pool.
    let (rows, cols) = (150usize, 271usize);
    let x = Tensor::from_f32(vec![rows, cols], fill(rows * cols, 3)).unwrap();
    let feeds = [("x", x)];
    let build = |b: &mut GraphBuilder| {
        let x = b.placeholder("x", rustflow::DType::F32).unwrap();
        let scale = b.scalar(1.7);
        let bias = b.constant(Tensor::from_f32(vec![cols], fill(cols, 4)).unwrap());
        let m = b.mul(x, scale);
        let s = b.add(m, bias);
        let t = b.tanh(s);
        vec![format!("{}:0", b.graph.node(t.node).name)]
    };
    assert_bit_identical(build, &feeds, "fused broadcast chain");
}

#[test]
fn softmax_and_reductions_bit_identical() {
    let (rows, cols) = (307usize, 157usize);
    let x = Tensor::from_f32(vec![rows, cols], fill(rows * cols, 5)).unwrap();
    let feeds = [("x", x)];
    let build = |b: &mut GraphBuilder| {
        let x = b.placeholder("x", rustflow::DType::F32).unwrap();
        let sm = b.softmax(x);
        let row_sum = b.reduce_sum(x, Some(vec![1])); // trailing axis
        let col_mean = b.reduce_mean(x, Some(vec![0])); // leading (strided) axis
        let total = b.reduce_sum(x, None); // full reduce (scalar)
        [sm, row_sum, col_mean, total]
            .iter()
            .map(|e| format!("{}:0", b.graph.node(e.node).name))
            .collect()
    };
    assert_bit_identical(build, &feeds, "softmax + reductions");
}

#[test]
fn general_broadcast_binary_bit_identical() {
    // [rows,1] * [1,cols]: neither the same-shape nor the scalar fast
    // path — the pooled general-broadcast index map, run in parallel.
    let (rows, cols) = (211usize, 173usize);
    let col = Tensor::from_f32(vec![rows, 1], fill(rows, 6)).unwrap();
    let feeds = [("c", col)];
    let build = |b: &mut GraphBuilder| {
        let c = b.placeholder("c", rustflow::DType::F32).unwrap();
        let row = b.constant(Tensor::from_f32(vec![1, cols], fill(cols, 7)).unwrap());
        let m = b.mul(c, row);
        vec![format!("{}:0", b.graph.node(m.node).name)]
    };
    assert_bit_identical(build, &feeds, "general broadcast binary");
}

#[test]
fn deep_mlp_step_bit_identical() {
    // A whole model step (matmul → bias-add → tanh stack, then softmax
    // and a mean loss): the composition must stay deterministic too.
    let dim = 96usize;
    let x = Tensor::from_f32(vec![dim, dim], fill(dim * dim, 8)).unwrap();
    let feeds = [("x", x)];
    let build = |b: &mut GraphBuilder| {
        let x = b.placeholder("x", rustflow::DType::F32).unwrap();
        let mut h = x;
        for l in 0..4 {
            let w = b.constant(Tensor::from_f32(vec![dim, dim], fill(dim * dim, 20 + l)).unwrap());
            let bias = b.constant(Tensor::from_f32(vec![dim], fill(dim, 40 + l)).unwrap());
            let mm = b.matmul(h, w);
            let s = b.add(mm, bias);
            h = b.tanh(s);
        }
        let sm = b.softmax(h);
        let loss = b.reduce_mean(sm, None);
        vec![
            format!("{}:0", b.graph.node(sm.node).name),
            format!("{}:0", b.graph.node(loss.node).name),
        ]
    };
    assert_bit_identical(build, &feeds, "deep mlp step");
}

fn one_output(_: &Node) -> rustflow::Result<usize> {
    Ok(1)
}

/// Register the panicking test op (op def + CPU kernel) once.
fn install_panic_op() {
    // Ignore AlreadyExists when several tests in this binary race here.
    let _ = register_op(OpDef {
        name: "TestPanicOp",
        category: Category::ElementWise,
        arity: Arity::Exact(1),
        num_outputs: one_output,
        stateful: false,
        is_async: false,
    });
    register_kernel(
        "TestPanicOp",
        "cpu",
        Arc::new(|_node: &rustflow::kernels::NodeInfo| {
            Ok(Kernel::Sync(Box::new(|ctx: &mut KernelContext| {
                // Large enough to clear the inline threshold so the panic
                // really fires inside pool workers when intra > 1 (and on
                // the calling thread when intra == 1 — both must become a
                // Status, not a hang or abort).
                ctx.parallel_for(1 << 16, 64, |_r| panic!("boom in intra-op worker"));
                Ok(vec![ctx.input(0)?.clone()])
            })))
        }),
    );
}

#[test]
fn panic_in_worker_fails_step_with_status() {
    install_panic_op();
    for intra in [1usize, 4] {
        let mut b = GraphBuilder::new();
        let x = b.constant(Tensor::fill_f32(vec![8], 1.0));
        let p = b.op1("TestPanicOp", "panic_node", vec![x], vec![]).unwrap();
        let fetch = format!("{}:0", b.graph.node(p.node).name);
        let sess = Session::new(
            b.into_graph(),
            SessionOptions {
                intra_op_threads: intra,
                // Keep the panicking op out of build-time constant
                // folding: the step, not the optimizer, must hit it.
                enable_constant_folding: false,
                ..Default::default()
            },
        );
        let err = sess.run(&[], &[&fetch], &[]).unwrap_err();
        assert_eq!(err.code, rustflow::error::Code::Internal, "intra={intra}: {err:?}");
        assert!(err.message.contains("panicked"), "intra={intra}: {}", err.message);
        assert!(err.message.contains("boom in intra-op worker"), "intra={intra}");
        // The session (and process) stay healthy: a fresh run of an
        // unrelated graph still works.
        let mut b2 = GraphBuilder::new();
        let y = b2.scalar(2.0);
        let z = b2.square(y);
        let zname = b2.graph.node(z.node).name.clone();
        let s2 = Session::new(
            b2.into_graph(),
            SessionOptions { intra_op_threads: intra, ..Default::default() },
        );
        assert_eq!(s2.run(&[], &[&zname], &[]).unwrap()[0].scalar_value_f32().unwrap(), 4.0);
    }
}
