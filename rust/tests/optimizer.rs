//! Optimizer-subsystem integration tests (§5): every pass and every
//! combination of passes must preserve `Session::run` results — exactly
//! for folding/simplification/CSE, to 1e-6 for fusion — including graphs
//! with control flow and dead Switch branches the passes must not rewrite
//! across. Running all 2³ ablation combinations also proves the per-pass
//! flags independent.

use rustflow::graph::AttrValue;
use rustflow::util::rng::Pcg32;
use rustflow::{DType, Endpoint, GraphBuilder, Session, SessionOptions, Tensor};

fn opts(fold: bool, simplify: bool, fuse: bool) -> SessionOptions {
    SessionOptions {
        enable_constant_folding: fold,
        enable_arithmetic_simplification: simplify,
        enable_elementwise_fusion: fuse,
        // CSE predates this subsystem and has its own ablation tests; off
        // here so node-count assertions see only the new passes.
        enable_cse: false,
        ..Default::default()
    }
}

/// A randomized graph mixing everything the passes care about: a fed
/// placeholder, const subtrees (folding), scalar identities (simplify),
/// elementwise chains (fusion), and shared fan-out.
fn random_model(seed: u64) -> (GraphBuilder, String) {
    let mut rng = Pcg32::new(seed * 31 + 7);
    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", DType::F32).unwrap();
    let one = b.scalar(1.0);
    let zero = b.scalar(0.0);
    let c0 = b.scalar(rng.uniform(0.5, 1.5));
    let cc = b.mul(c0, c0); // const subtree for folding
    let mut pool: Vec<Endpoint> = vec![x, cc];
    for _ in 0..14 {
        let a = pool[rng.index(pool.len())];
        let v = match rng.next_below(8) {
            0 => b.add(a, zero),
            1 => b.mul(a, one),
            2 => b.neg(a),
            3 => {
                let n = b.neg(a);
                b.neg(n)
            }
            4 => b.tanh(a),
            5 => b.identity(a),
            6 => {
                let d = pool[rng.index(pool.len())];
                b.add(a, d)
            }
            _ => {
                let s = b.scalar(rng.uniform(-1.0, 1.0));
                b.mul(a, s)
            }
        };
        pool.push(v);
    }
    let out = b.add_n(pool[2..].to_vec());
    let name = format!("{}:0", b.graph.node(out.node).name);
    (b, name)
}

fn run_model(seed: u64, options: SessionOptions) -> Tensor {
    let (b, name) = random_model(seed);
    let mut rng = Pcg32::with_stream(seed, 999);
    let feed = Tensor::from_f32(vec![8], (0..8).map(|_| rng.uniform(-2.0, 2.0)).collect())
        .unwrap();
    Session::new(b.into_graph(), options)
        .run(&[("x", feed)], &[&name], &[])
        .unwrap()
        .remove(0)
}

#[test]
fn randomized_equivalence_across_all_flag_combinations() {
    for seed in 0..6u64 {
        let baseline = run_model(seed, opts(false, false, false));
        for fold in [false, true] {
            for simplify in [false, true] {
                for fuse in [false, true] {
                    let out = run_model(seed, opts(fold, simplify, fuse));
                    if fuse {
                        assert!(
                            baseline.allclose(&out, 1e-6, 1e-6),
                            "seed {seed} fold={fold} simplify={simplify} fuse={fuse}: diverged"
                        );
                    } else {
                        // Folding evaluates with the same kernels and
                        // simplification only removes exact identities:
                        // results must agree exactly.
                        assert_eq!(
                            baseline.as_f32().unwrap(),
                            out.as_f32().unwrap(),
                            "seed {seed} fold={fold} simplify={simplify}: not bit-exact"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn each_pass_actually_fires_on_its_pattern() {
    // One graph carrying all three patterns, so the per-pass reports prove
    // each flag drives exactly its own pass.
    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", DType::F32).unwrap();
    let c2 = b.scalar(2.0);
    let c3 = b.scalar(3.0);
    let cc = b.mul(c2, c3); // folding: const subtree
    let one = b.scalar(1.0);
    let m = b.mul(x, one); // simplification: x*1
    let a = b.add(m, cc);
    let t = b.tanh(a);
    let n = b.neg(t); // fusion: Add→Tanh→Neg chain once simplified
    let name = format!("{}:0", b.graph.node(n.node).name);
    let sess = Session::new(b.into_graph(), opts(true, true, true));
    let out = sess.run(&[("x", Tensor::scalar_f32(0.5))], &[&name], &[]).unwrap();
    assert!((out[0].scalar_value_f32().unwrap() - (-(6.5f32.tanh()))).abs() < 1e-6);
    let stats = sess.optimizer_stats(&["x"], &[&name], &[]).unwrap();
    assert!(stats.report("constant_folding").unwrap().rewrites >= 1, "{stats:?}");
    assert!(stats.report("arithmetic_simplification").unwrap().rewrites >= 1, "{stats:?}");
    assert!(stats.report("elementwise_fusion").unwrap().rewrites >= 1, "{stats:?}");
    assert!(stats.report("cse").is_none(), "cse disabled but reported");
}

#[test]
fn dead_switch_branch_not_rewritten_or_evaluated() {
    // if pred: x*10 else x+1 — with pred=false the true branch is dead.
    // The optimizer must neither evaluate it at build time nor change
    // which branch executes.
    for (pred, expect) in [(true, 50.0f32), (false, 6.0)] {
        let build = || {
            let mut b = GraphBuilder::new();
            let x = b.scalar(5.0);
            let p = b.constant(Tensor::scalar_bool(pred));
            let (f_side, t_side) = b.switch(x, p).unwrap();
            let ten = b.scalar(10.0);
            let one = b.scalar(1.0);
            let t_out = b.mul(t_side, ten);
            let f_out = b.add(f_side, one);
            let (merged, _) = b.merge(vec![f_out, t_out]).unwrap();
            let name = format!("{}:0", b.graph.node(merged.node).name);
            (b, name)
        };
        for options in [opts(true, true, true), opts(false, false, false)] {
            let (b, name) = build();
            let sess = Session::new(b.into_graph(), options);
            let out = sess.run(&[], &[&name], &[]).unwrap();
            assert_eq!(out[0].scalar_value_f32().unwrap(), expect, "pred={pred}");
        }
    }
}

#[test]
fn while_loop_agrees_under_optimization() {
    // while (i < 10) i = (i + 1) * 1 — the body carries a simplifiable
    // multiply and a fusable chain; loop structure must survive.
    let build = || {
        let mut b = GraphBuilder::new();
        let zero = b.scalar(0.0);
        let exits = b
            .while_loop(
                "loop",
                vec![zero],
                |b, v| {
                    let lim = b.scalar(10.0);
                    Ok(b.less(v[0], lim))
                },
                |b, v| {
                    let one = b.scalar(1.0);
                    let inc = b.add(v[0], one);
                    Ok(vec![b.mul(inc, one)])
                },
            )
            .unwrap();
        let name = format!("{}:0", b.graph.node(exits[0].node).name);
        (b, name)
    };
    for options in [opts(true, true, true), opts(false, false, false)] {
        let (b, name) = build();
        let out = Session::new(b.into_graph(), options).run(&[], &[&name], &[]).unwrap();
        assert_eq!(out[0].scalar_value_f32().unwrap(), 10.0);
    }
}

#[test]
fn fusion_handles_broadcast_extras_via_fallback() {
    // A chain whose binary extra is a row vector against a matrix primary:
    // the fused kernel's fast path does not apply, the fallback must.
    let build = || {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let row = b.constant(Tensor::from_f32(vec![3], vec![1.0, 2.0, 3.0]).unwrap());
        let a = b.add(x, row);
        let t = b.tanh(a);
        let n = b.neg(t);
        let name = format!("{}:0", b.graph.node(n.node).name);
        (b, name)
    };
    let feed = Tensor::from_f32(vec![2, 3], vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6]).unwrap();
    let run = |options: SessionOptions| {
        let (b, name) = build();
        Session::new(b.into_graph(), options)
            .run(&[("x", feed.clone())], &[&name], &[])
            .unwrap()
            .remove(0)
    };
    let fused = run(opts(false, false, true));
    let plain = run(opts(false, false, false));
    assert_eq!(fused.shape(), plain.shape());
    assert!(fused.allclose(&plain, 1e-6, 1e-6));
}

#[test]
fn folding_shrinks_step_graph_and_caches_once() {
    // A deep const tower folds to one Const; the optimizer stats record it
    // and the cached step keeps serving the folded value.
    let mut b = GraphBuilder::new();
    let mut c = b.scalar(1.0);
    for _ in 0..20 {
        let h = b.scalar(0.5);
        c = b.add(c, h);
    }
    let name = format!("{}:0", b.graph.node(c.node).name);
    let sess = Session::new(b.into_graph(), opts(true, false, false));
    for _ in 0..3 {
        let out = sess.run(&[], &[&name], &[]).unwrap();
        assert_eq!(out[0].scalar_value_f32().unwrap(), 11.0);
    }
    let stats = sess.optimizer_stats(&[], &[&name], &[]).unwrap();
    let fold = stats.report("constant_folding").unwrap();
    assert_eq!(fold.rewrites, 1, "one frontier endpoint (the tower root)");
    assert!(fold.nodes_after < fold.nodes_before, "{fold:?}");
}

#[test]
fn feeds_are_never_folded() {
    // A fed tensor flows through _Feed (stateful); folding must not bake
    // the first fed value into the cached step.
    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", DType::F32).unwrap();
    let two = b.scalar(2.0);
    let y = b.mul(x, two);
    let name = format!("{}:0", b.graph.node(y.node).name);
    let sess = Session::new(b.into_graph(), opts(true, true, true));
    let r1 = sess.run(&[("x", Tensor::scalar_f32(3.0))], &[&name], &[]).unwrap();
    assert_eq!(r1[0].scalar_value_f32().unwrap(), 6.0);
    let r2 = sess.run(&[("x", Tensor::scalar_f32(5.0))], &[&name], &[]).unwrap();
    assert_eq!(r2[0].scalar_value_f32().unwrap(), 10.0);
}

#[test]
fn mistyped_feed_fails_identically_with_and_without_passes() {
    // x is declared F32; feeding F64 must error whether the optimizer
    // bypassed x's consumers (the _Feed dtype check) or the Mul kernel
    // rejects the mismatch itself.
    let build = || {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let one = b.scalar(1.0);
        let m = b.mul(x, one);
        let n = b.neg(m);
        let name = format!("{}:0", b.graph.node(n.node).name);
        (b, name)
    };
    let feed = Tensor::from_f64(vec![2], vec![1.0, 2.0]).unwrap();
    for options in [opts(true, true, true), opts(false, false, false)] {
        let (b, name) = build();
        let err = Session::new(b.into_graph(), options)
            .run(&[("x", feed.clone())], &[&name], &[])
            .unwrap_err();
        assert_eq!(err.code, rustflow::error::Code::InvalidArgument);
    }
}

#[test]
fn fused_graph_roundtrips_through_wire_format() {
    // Optimize → serialize → deserialize → run: what a master would ship.
    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", DType::F32).unwrap();
    let c = b.scalar(0.5);
    let m = b.mul(x, c);
    let t = b.tanh(m);
    let n = b.neg(t);
    let name = format!("{}:0", b.graph.node(n.node).name);
    let (pruned, _, _) =
        rustflow::session::prune_for_run(&b.graph, &[], &[&name], &[]).unwrap();
    let (fused, stats) = rustflow::passes::fuse_elementwise_chains(&pruned).unwrap();
    assert_eq!(stats.chains_fused, 1);
    let wire = rustflow::graph::serde::encode_graph(&fused);
    let decoded = rustflow::graph::serde::decode_graph(&wire).unwrap();
    let fused_node = decoded.nodes.iter().find(|n| n.op == "FusedElementwise").unwrap();
    assert_eq!(
        fused_node.attrs["ops"],
        AttrValue::ListStr(vec!["Mul,r,1".into(), "Tanh".into(), "Neg".into()])
    );
}
