//! Integration tests for the sparse embedding subsystem: `Gather`
//! gradients are `IndexedSlices` whose host densification matches the
//! lazy `SparseToDense` handle bitwise, mod-sharded lookup is
//! bit-identical to an unsharded table, two synchronous replicas shipping
//! `GradEntry::Sparse` natively match the single-process densified
//! reference bitwise (and spend fewer bytes on the wire than the dense
//! path), and sampled softmax trains deterministically from a fixed seed.

use rustflow::autodiff::gradients;
use rustflow::distributed::ps::{ParamServer, PsOptions};
use rustflow::distributed::train::{DistTrainer, DistTrainerOptions};
use rustflow::graph::Endpoint;
use rustflow::optim::Optimizer;
use rustflow::replicate;
use rustflow::sparse::{self, ShardedTable};
use rustflow::tensor::Tensor;
use rustflow::util::rng::Pcg32;
use rustflow::{DType, GraphBuilder, Session, SessionOptions};

/// Fusion off on both sides of every equivalence: the fusion pass carries
/// a 1e-6 contract, and these tests demand bitwise equality.
fn exact_session_options() -> SessionOptions {
    SessionOptions { enable_elementwise_fusion: false, ..Default::default() }
}

fn random_table(vocab: usize, dim: usize, seed: u64) -> Tensor {
    let mut rng = Pcg32::new(seed);
    let v: Vec<f32> = (0..vocab * dim).map(|_| rng.normal()).collect();
    Tensor::from_f32(vec![vocab, dim], v).unwrap()
}

fn fetch_name(b: &GraphBuilder, e: Endpoint) -> String {
    format!("{}:{}", b.graph.node(e.node).name, e.port)
}

/// Build a session, run the graph's initializers, return it.
fn init_session(b: GraphBuilder) -> Session {
    let inits: Vec<String> = b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
    let sess = Session::new(b.into_graph(), exact_session_options());
    sess.run_targets(&inits.iter().map(String::as_str).collect::<Vec<_>>()).unwrap();
    sess
}

#[test]
fn gather_grad_twins_densify_to_the_handle_bitwise() {
    // loss = Σ gather(table, ids)²; the gradient w.r.t. the table is an
    // IndexedSlices (duplicate id included — duplicates mean "sum").
    // Fetching the (indices, values) twins and densifying on the host in
    // occurrence order must be bit-identical to fetching the lazy
    // SparseToDense handle, which accumulates in the same order.
    let (vocab, dim) = (8, 3);
    let ids = vec![5i64, 2, 2, 7];
    let mut b = GraphBuilder::new();
    let table = b.variable("table", random_table(vocab, dim, 11)).unwrap();
    let idc = sparse::ids_const(&mut b, ids.clone());
    let rows = b.op1("Gather", "lookup", vec![table, idc], vec![]).unwrap();
    let sq = b.square(rows);
    let loss = b.reduce_sum(sq, None);

    let g = gradients(&mut b, loss, &[table]).unwrap()[0].expect("table gets a gradient");
    let s = sparse::as_sparse(&b, g).expect("Gather gradient must be IndexedSlices");
    assert_eq!(b.graph.node(g.node).op, "SparseToDense", "handle is the lazy densify node");

    let fetches = [fetch_name(&b, g), fetch_name(&b, s.indices), fetch_name(&b, s.values)];
    let sess = init_session(b);
    let out = sess
        .run(&[], &fetches.iter().map(String::as_str).collect::<Vec<_>>(), &[])
        .unwrap();
    let dense = out[0].as_f32().unwrap();
    let idx = out[1].as_i64().unwrap();
    let vals = out[2].as_f32().unwrap();

    assert_eq!(out[0].shape().dims(), &[vocab, dim], "handle has the table's shape");
    assert_eq!(idx, ids.as_slice(), "indices are the lookup's ids");
    assert_eq!(out[2].shape().dims(), &[ids.len(), dim], "one value row per id");

    let mut host = vec![0.0f32; vocab * dim];
    for (k, &i) in idx.iter().enumerate() {
        for j in 0..dim {
            host[i as usize * dim + j] += vals[k * dim + j];
        }
    }
    let dense_bits: Vec<u32> = dense.iter().map(|v| v.to_bits()).collect();
    let host_bits: Vec<u32> = host.iter().map(|v| v.to_bits()).collect();
    assert_eq!(dense_bits, host_bits, "host densify == SparseToDense handle, bitwise");
    // Rows never gathered stay exactly +0.0 — the handle is sparse-backed,
    // not a dense zeros-like with arithmetic residue.
    for r in [0usize, 1, 3, 4, 6] {
        assert!(dense[r * dim..(r + 1) * dim].iter().all(|v| v.to_bits() == 0));
    }
}

#[test]
fn sharded_lookup_is_bit_identical_to_unsharded() {
    let (vocab, dim) = (16, 5);
    let table = random_table(vocab, dim, 77);
    let ids = vec![0i64, 15, 7, 7, 3, 12, 8, 1];

    let mut b = GraphBuilder::new();
    let var = b.variable("table", table.clone()).unwrap();
    let idc = sparse::ids_const(&mut b, ids.clone());
    let dense = b.op1("Gather", "lookup", vec![var, idc], vec![]).unwrap();
    let name = fetch_name(&b, dense);
    let want = init_session(b).run(&[], &[&name], &[]).unwrap().remove(0);

    for shards in [1usize, 2, 3, 4] {
        let mut b = GraphBuilder::new();
        let t = ShardedTable::new(&mut b, "emb", table.clone(), shards).unwrap();
        let idc = sparse::ids_const(&mut b, ids.clone());
        let out = t.lookup(&mut b, idc).unwrap();
        let name = fetch_name(&b, out);
        let got = init_session(b).run(&[], &[&name], &[]).unwrap().remove(0);
        assert_eq!(got.shape().dims(), want.shape().dims(), "{shards} shards");
        let got_bits: Vec<u32> = got.as_f32().unwrap().iter().map(|v| v.to_bits()).collect();
        let want_bits: Vec<u32> = want.as_f32().unwrap().iter().map(|v| v.to_bits()).collect();
        assert_eq!(got_bits, want_bits, "{shards} shards must match bitwise");
    }
}

// ---- 2-replica synchronous training over the native sparse wire ----

const LR: f32 = 0.25;
const STEPS: usize = 6;
const REPLICAS: usize = 2;
const VOCAB: usize = 32;
const DIM: usize = 4;

/// Replica `r` touches only rows `r*16..r*16+16` — disjoint across
/// replicas and unique within a step, which is exactly the regime where
/// scatter-apply is bitwise-equal to densify-then-apply.
fn step_ids(step: usize, replica: usize) -> Vec<i64> {
    let base = (replica * 16) as i64;
    vec![base + (step % 16) as i64, base + ((step + 5) % 16) as i64]
}

/// One tower: `loss = Σ gather(emb, ids)²` over an i64 `ids` placeholder
/// under the caller's scope.
fn embedding_tower(b: &mut GraphBuilder, emb: Endpoint) -> Endpoint {
    let ids = b.placeholder("ids", DType::I64).unwrap();
    let rows = b.op1("Gather", "lookup", vec![emb, ids], vec![]).unwrap();
    let sq = b.square(rows);
    b.reduce_sum(sq, None)
}

/// Single-process densified reference: both towers in ONE graph; each
/// tower's gradient is an IndexedSlices handle, and
/// `sync_data_parallel`'s `add_n` + in-graph apply *densifies* them —
/// the Fig 7 (top) baseline the sparse wire path must reproduce.
/// Returns (per-step tower-0 loss bits, final emb bits).
fn reference_trajectory() -> (Vec<u32>, Vec<u32>) {
    let mut b = GraphBuilder::new();
    let emb = b.variable("emb", random_table(VOCAB, DIM, 42)).unwrap();
    let losses: Vec<Endpoint> = (0..REPLICAS)
        .map(|r| b.with_scope(&format!("rep{r}"), |b| embedding_tower(b, emb)))
        .collect();
    let train =
        replicate::sync_data_parallel(&mut b, &[emb], &losses, &Optimizer::sgd(LR)).unwrap();
    let tname = b.graph.node(train).name.clone();
    let loss0 = fetch_name(&b, losses[0]);
    let sess = init_session(b);
    let mut loss_bits = Vec::with_capacity(STEPS);
    for s in 0..STEPS {
        let feeds: Vec<(String, Tensor)> = (0..REPLICAS)
            .map(|r| {
                let ids = step_ids(s, r);
                let n = ids.len();
                (format!("rep{r}/ids"), Tensor::from_i64(vec![n], ids).unwrap())
            })
            .collect();
        let refs: Vec<(&str, Tensor)> =
            feeds.iter().map(|(k, t)| (k.as_str(), t.clone())).collect();
        let out = sess.run(&refs, &[&loss0], &[&tname]).unwrap();
        loss_bits.push(out[0].scalar_value_f32().unwrap().to_bits());
    }
    let emb = sess.run(&[], &["emb"], &[]).unwrap().remove(0);
    (loss_bits, emb.as_f32().unwrap().iter().map(|v| v.to_bits()).collect())
}

/// Run the 2-replica synchronous PS training and return (replica-0 loss
/// bits, final emb bits on the server, total wire bytes).
fn distributed_run(native_sparse: bool) -> (Vec<u32>, Vec<u32>, u64) {
    let ps = ParamServer::new(PsOptions {
        opt: Optimizer::sgd(LR),
        sync_replicas: Some(REPLICAS),
        ..Default::default()
    });
    let addr = ps.serve("127.0.0.1:0").unwrap().to_string();

    let losses: Vec<Vec<u32>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..REPLICAS)
            .map(|r| {
                let addr = addr.clone();
                scope.spawn(move || {
                    let mut b = GraphBuilder::new();
                    let emb = b.variable("emb", random_table(VOCAB, DIM, 42)).unwrap();
                    let loss = embedding_tower(&mut b, emb);
                    let mut t = DistTrainer::new(
                        b,
                        loss,
                        &[emb],
                        r as u32,
                        &[addr],
                        DistTrainerOptions {
                            compress: false,
                            native_sparse,
                            ..Default::default()
                        },
                        exact_session_options(),
                    )
                    .unwrap();
                    assert_eq!(
                        t.native_sparse(),
                        &[native_sparse],
                        "embedding gradient rides the IndexedSlices wire path iff enabled"
                    );
                    t.init_params().unwrap();
                    (0..STEPS)
                        .map(|s| {
                            let ids = step_ids(s, r);
                            let n = ids.len();
                            let feeds = [("ids", Tensor::from_i64(vec![n], ids).unwrap())];
                            t.step(&feeds).unwrap().to_bits()
                        })
                        .collect::<Vec<u32>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    assert_eq!(ps.version(), STEPS as u64, "one version bump per synchronous step");
    let emb = ps.param("emb").unwrap();
    let emb_bits: Vec<u32> = emb.as_f32().unwrap().iter().map(|v| v.to_bits()).collect();
    let wire = ps.wire_bytes();
    ps.shutdown();
    (losses.into_iter().next().unwrap(), emb_bits, wire)
}

#[test]
fn sync_sparse_replicas_bitwise_match_densified_reference() {
    let (ref_losses, ref_emb) = reference_trajectory();
    let (losses, emb, sparse_wire) = distributed_run(true);
    assert_eq!(losses, ref_losses, "replica-0 loss trajectory must be bit-identical");
    assert_eq!(emb, ref_emb, "final embedding must be bit-identical to the dense reference");

    // The dense wire path (same model, native sparse off) reaches the same
    // parameters — and pays full [VOCAB, DIM] pushes for 2-row updates.
    let (dense_losses, dense_emb, dense_wire) = distributed_run(false);
    assert_eq!(dense_losses, ref_losses);
    assert_eq!(dense_emb, ref_emb, "dense and sparse wire paths agree bitwise");
    assert!(
        sparse_wire < dense_wire,
        "GradEntry::Sparse must spend fewer wire bytes ({sparse_wire}) than dense ({dense_wire})"
    );
}

#[test]
fn sampled_softmax_converges_deterministically() {
    // Synthetic skip-gram on a 12-token ring (context = center + 1): train
    // input embeddings + output weights under sampled softmax. Fixed graph
    // seed + per-run step ids make the whole trajectory a pure function of
    // the build, so two runs agree bitwise.
    let (vocab, dim, num_sampled, seed, steps) = (12usize, 4usize, 4i64, 7i64, 120usize);
    let run = || -> Vec<f32> {
        let mut b = GraphBuilder::new();
        let scale = |t: Tensor| {
            let v: Vec<f32> = t.as_f32().unwrap().iter().map(|x| 0.1 * x).collect();
            Tensor::from_f32(t.shape().dims().to_vec(), v).unwrap()
        };
        let emb = b.variable("emb", scale(random_table(vocab, dim, 5))).unwrap();
        let w = b.variable("w", scale(random_table(vocab, dim, 6))).unwrap();
        let centers = sparse::ids_const(&mut b, (0..vocab as i64).collect());
        let labels = sparse::ids_const(&mut b, (0..vocab as i64).map(|i| (i + 1) % 12).collect());
        let rows = b.op1("Gather", "center_emb", vec![emb, centers], vec![]).unwrap();
        let loss_vec = sparse::sampled_softmax(&mut b, rows, w, labels, num_sampled, seed).unwrap();
        let mean_loss = b.reduce_mean(loss_vec, None);
        let total = b.reduce_sum(loss_vec, None);
        let train = Optimizer::sgd(0.2).minimize(&mut b, total, &[emb, w]).unwrap();
        let tname = b.graph.node(train).name.clone();
        let lname = fetch_name(&b, mean_loss);
        let sess = init_session(b);
        (0..steps)
            .map(|_| {
                // Loss and gradient fetched in one run: the kernels re-draw
                // the same negatives only within a step.
                let out = sess.run(&[], &[&lname], &[&tname]).unwrap();
                out[0].scalar_value_f32().unwrap()
            })
            .collect()
    };

    let a = run();
    assert!(a.iter().all(|l| l.is_finite()), "losses stay finite");
    let head: f32 = a[..10].iter().sum::<f32>() / 10.0;
    let tail: f32 = a[steps - 10..].iter().sum::<f32>() / 10.0;
    assert!(
        tail < 0.9 * head,
        "sampled softmax must train: first-10 mean {head}, last-10 mean {tail}"
    );

    let b = run();
    let a_bits: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
    let b_bits: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
    assert_eq!(a_bits, b_bits, "fixed seed + step ids make the trajectory deterministic");
}
