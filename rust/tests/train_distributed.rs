//! Integration tests for §4.4 data-parallel training against parameter
//! servers: synchronous SGD is **bit-identical** to single-process
//! training (compression off), asynchronous SGD converges, stale pushes
//! never corrupt server state, and remote partitions run identically with
//! planned step memory on or off.

use rustflow::distributed::ps::{ParamServer, PsClient, PsOptions};
use rustflow::distributed::train::{DistTrainer, DistTrainerOptions};
use rustflow::distributed::proto::GradEntry;
use rustflow::error::Code;
use rustflow::graph::Endpoint;
use rustflow::optim::Optimizer;
use rustflow::replicate;
use rustflow::tensor::Tensor;
use rustflow::util::rng::Pcg32;
use rustflow::{GraphBuilder, Session, SessionOptions};

const LR: f32 = 0.25;
const STEPS: usize = 6;
const REPLICAS: usize = 2;

/// Deterministic per-(step, replica) training data for the linear model
/// `pred = w0*x + w1`. Dyadic values so every intermediate is exact-ish;
/// bitwise equality holds regardless because both sides run the same ops
/// in the same order.
fn data(step: usize, replica: usize) -> (f32, f32) {
    let x = 1.0 + 0.5 * replica as f32 + 0.25 * (step % 8) as f32;
    let y = 0.5 - 0.25 * replica as f32 + 0.125 * (step % 5) as f32;
    (x, y)
}

/// One tower of the model: `loss = (w0*x + w1 - y)^2` over scalar
/// placeholders named `x`/`y` under the caller's scope.
fn tower(b: &mut GraphBuilder, w0: Endpoint, w1: Endpoint) -> Endpoint {
    let x = b.placeholder("x", rustflow::DType::F32).unwrap();
    let y = b.placeholder("y", rustflow::DType::F32).unwrap();
    let wx = b.mul(w0, x);
    let pred = b.add(wx, w1);
    let d = b.sub(pred, y);
    b.square(d)
}

fn vars(b: &mut GraphBuilder) -> (Endpoint, Endpoint) {
    let w0 = b.variable("w0", Tensor::scalar_f32(0.25)).unwrap();
    let w1 = b.variable("w1", Tensor::scalar_f32(-0.5)).unwrap();
    (w0, w1)
}

/// Fusion stays off on both sides of the equivalence: the elementwise
/// fusion pass carries a 1e-6 contract, everything else in the pipeline
/// is exact, and this test demands bitwise equality.
fn exact_session_options() -> SessionOptions {
    SessionOptions { enable_elementwise_fusion: false, ..Default::default() }
}

/// Reference trajectory: both towers in ONE graph, averaged and applied by
/// `replicate::sync_data_parallel` — the paper's in-graph Fig 7 (top).
/// Returns (per-step tower-0 losses, final w0, final w1) as raw bits.
fn reference_trajectory() -> (Vec<u32>, u32, u32) {
    let mut b = GraphBuilder::new();
    let (w0, w1) = vars(&mut b);
    let losses: Vec<Endpoint> = (0..REPLICAS)
        .map(|r| b.with_scope(&format!("rep{r}"), |b| tower(b, w0, w1)))
        .collect();
    let train =
        replicate::sync_data_parallel(&mut b, &[w0, w1], &losses, &Optimizer::sgd(LR)).unwrap();
    let tname = b.graph.node(train).name.clone();
    let loss0 = format!("{}:{}", b.graph.node(losses[0].node).name, losses[0].port);
    let inits: Vec<String> =
        b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
    let sess = Session::new(b.into_graph(), exact_session_options());
    sess.run_targets(&inits.iter().map(String::as_str).collect::<Vec<_>>()).unwrap();
    let mut loss_bits = Vec::with_capacity(STEPS);
    for s in 0..STEPS {
        let mut feeds = Vec::new();
        for r in 0..REPLICAS {
            let (x, y) = data(s, r);
            feeds.push((format!("rep{r}/x"), Tensor::scalar_f32(x)));
            feeds.push((format!("rep{r}/y"), Tensor::scalar_f32(y)));
        }
        let refs: Vec<(&str, Tensor)> =
            feeds.iter().map(|(k, t)| (k.as_str(), t.clone())).collect();
        let out = sess.run(&refs, &[&loss0], &[&tname]).unwrap();
        loss_bits.push(out[0].scalar_value_f32().unwrap().to_bits());
    }
    let w = sess.run(&[], &["w0", "w1"], &[]).unwrap();
    (
        loss_bits,
        w[0].scalar_value_f32().unwrap().to_bits(),
        w[1].scalar_value_f32().unwrap().to_bits(),
    )
}

#[test]
fn sync_two_replicas_bitwise_match_single_process() {
    let (ref_losses, ref_w0, ref_w1) = reference_trajectory();

    let ps = ParamServer::new(PsOptions {
        opt: Optimizer::sgd(LR),
        sync_replicas: Some(REPLICAS),
        ..Default::default()
    });
    let addr = ps.serve("127.0.0.1:0").unwrap().to_string();

    // One replica per thread: each owns a single-tower graph + DistTrainer
    // with compression off (the bitwise contract; bf16 is lossy by design).
    let losses: Vec<Vec<u32>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..REPLICAS)
            .map(|r| {
                let addr = addr.clone();
                scope.spawn(move || {
                    let mut b = GraphBuilder::new();
                    let (w0, w1) = vars(&mut b);
                    let loss = tower(&mut b, w0, w1);
                    let mut t = DistTrainer::new(
                        b,
                        loss,
                        &[w0, w1],
                        r as u32,
                        &[addr],
                        DistTrainerOptions { compress: false, ..Default::default() },
                        exact_session_options(),
                    )
                    .unwrap();
                    t.init_params().unwrap();
                    (0..STEPS)
                        .map(|s| {
                            let (x, y) = data(s, r);
                            let feeds =
                                [("x", Tensor::scalar_f32(x)), ("y", Tensor::scalar_f32(y))];
                            t.step(&feeds).unwrap().to_bits()
                        })
                        .collect::<Vec<u32>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    assert_eq!(losses[0], ref_losses, "replica-0 loss trajectory must be bit-identical");
    let w0 = ps.param("w0").unwrap().scalar_value_f32().unwrap().to_bits();
    let w1 = ps.param("w1").unwrap().scalar_value_f32().unwrap().to_bits();
    assert_eq!((w0, w1), (ref_w0, ref_w1), "final parameters must be bit-identical");
    assert_eq!(ps.version(), STEPS as u64, "one version bump per synchronous step");
    ps.shutdown();
}

#[test]
fn async_converges_on_convex_problem_from_fixed_seed() {
    // Downpour SGD on y = 3x data: each replica draws its own x stream
    // from a fixed seed; w must land near 3 despite staleness. Replica 0
    // pushes bf16-compressed, replica 1 uncompressed — interop on one
    // server.
    let ps = ParamServer::new(PsOptions { opt: Optimizer::sgd(0.05), ..Default::default() });
    let addr = ps.serve("127.0.0.1:0").unwrap().to_string();

    std::thread::scope(|scope| {
        for r in 0..2u32 {
            let addr = addr.clone();
            scope.spawn(move || {
                let mut b = GraphBuilder::new();
                let w = b.variable("w", Tensor::scalar_f32(0.0)).unwrap();
                let x = b.placeholder("x", rustflow::DType::F32).unwrap();
                let y = b.placeholder("y", rustflow::DType::F32).unwrap();
                let wx = b.mul(w, x);
                let d = b.sub(wx, y);
                let loss = b.square(d);
                let mut t = DistTrainer::new(
                    b,
                    loss,
                    &[w],
                    r,
                    &[addr],
                    DistTrainerOptions { compress: r == 0, ..Default::default() },
                    SessionOptions::default(),
                )
                .unwrap();
                assert_eq!(t.compressed(), r == 0, "per-channel negotiation");
                t.init_params().unwrap();
                let mut rng = Pcg32::new(1000 + r as u64);
                for _ in 0..80 {
                    let x = rng.uniform(0.5, 1.5);
                    let feeds =
                        [("x", Tensor::scalar_f32(x)), ("y", Tensor::scalar_f32(3.0 * x))];
                    t.step(&feeds).unwrap();
                }
            });
        }
    });

    let w = ps.param("w").unwrap().scalar_value_f32().unwrap();
    assert!((w - 3.0).abs() < 0.1, "async training ended at w={w}, want ≈3");
    assert_eq!(ps.version(), 160, "one version bump per push in async mode");
    ps.shutdown();
}

/// Raw-bytes snapshot of every parameter on the shard.
fn param_bits(ps: &ParamServer, names: &[&str]) -> Vec<Vec<u32>> {
    names
        .iter()
        .map(|n| {
            ps.param(n).unwrap().as_f32().unwrap().iter().map(|v| v.to_bits()).collect()
        })
        .collect()
}

#[test]
fn stale_sync_push_never_corrupts_server_state() {
    // A single-replica synchronous group: pushes must carry the exact
    // version they pulled. A worker joining mid-run with stale parameters
    // gets refused — bitwise-untouched state — then catches up by pulling.
    let ps = ParamServer::new(PsOptions {
        opt: Optimizer::sgd(0.5),
        sync_replicas: Some(1),
        ..Default::default()
    });
    let addr = ps.serve("127.0.0.1:0").unwrap().to_string();

    let a = PsClient::connect(&addr, false).unwrap();
    a.init(&[("w".to_string(), Tensor::from_f32(vec![2], vec![1.0, 2.0]).unwrap())]).unwrap();
    let (v0, _) = a.pull().unwrap();
    assert_eq!(v0, 0);
    let grad = || {
        vec![(
            "w".to_string(),
            GradEntry::Dense(Tensor::from_f32(vec![2], vec![1.0, -1.0]).unwrap()),
        )]
    };
    // Step 0 applies: w = [1,2] - 0.5*[1,-1] = [0.5, 2.5].
    assert_eq!(a.push(0, 0, grad()).unwrap(), 1);

    // The late joiner still believes version 0: stale → refused, state
    // bitwise untouched.
    let before = param_bits(&ps, &["w"]);
    let b = PsClient::connect(&addr, false).unwrap();
    let e = b.push(0, 0, grad()).unwrap_err();
    assert_eq!(e.code, Code::FailedPrecondition);
    assert_eq!(param_bits(&ps, &["w"]), before, "stale push must not touch parameters");

    // A push from the future is a protocol bug, also refused untouched.
    let e = b.push(7, 0, grad()).unwrap_err();
    assert_eq!(e.code, Code::InvalidArgument);
    assert_eq!(param_bits(&ps, &["w"]), before);

    // Catch-up: pull the real version, then the push lands.
    let (v1, params) = b.pull().unwrap();
    assert_eq!(v1, 1);
    assert_eq!(params[0].1.as_f32().unwrap(), &[0.5, 2.5]);
    assert_eq!(b.push(1, 0, grad()).unwrap(), 2);
    assert_eq!(ps.param("w").unwrap().as_f32().unwrap(), &[0.0, 3.0]);
    ps.shutdown();
}

#[test]
fn async_late_joiner_adopts_seeded_params() {
    // First replica seeds the shard; a replica joining later (different
    // local init!) loses the race and trains against the seeded values.
    let ps = ParamServer::new(PsOptions { opt: Optimizer::sgd(0.1), ..Default::default() });
    let addr = ps.serve("127.0.0.1:0").unwrap().to_string();

    let build = |init: f32| {
        let mut b = GraphBuilder::new();
        let w = b.variable("w", Tensor::scalar_f32(init)).unwrap();
        let x = b.placeholder("x", rustflow::DType::F32).unwrap();
        let wx = b.mul(w, x);
        let c = b.scalar(2.0);
        let d = b.sub(wx, c);
        let loss = b.square(d);
        (b, loss, w)
    };

    // Compression off: this test asserts exact f32 equality between the
    // server's parameters and what the replicas see.
    let (b1, loss1, w1) = build(5.0);
    let mut early = DistTrainer::new(
        b1,
        loss1,
        &[w1],
        0,
        &[addr.clone()],
        DistTrainerOptions { compress: false, ..Default::default() },
        SessionOptions::default(),
    )
    .unwrap();
    assert!(early.init_params().unwrap(), "first replica seeds the shard");
    for _ in 0..3 {
        early.step(&[("x", Tensor::scalar_f32(1.0))]).unwrap();
    }
    let server_w = ps.param("w").unwrap().scalar_value_f32().unwrap();

    let (b2, loss2, w2) = build(-9.0); // a would-be-corrupting local init
    let mut late = DistTrainer::new(
        b2,
        loss2,
        &[w2],
        1,
        &[addr],
        DistTrainerOptions { compress: false, ..Default::default() },
        SessionOptions::default(),
    )
    .unwrap();
    assert!(!late.init_params().unwrap(), "late joiner must lose the seeding race");
    assert_eq!(
        ps.param("w").unwrap().scalar_value_f32().unwrap(),
        server_w,
        "late init must not overwrite trained parameters"
    );
    late.pull().unwrap();
    let local = late.session().run(&[], &["w"], &[]).unwrap()[0].scalar_value_f32().unwrap();
    assert_eq!(local, server_w, "pull adopts the server's parameters");
    late.step(&[("x", Tensor::scalar_f32(1.0))]).unwrap();
    assert_eq!(ps.version(), 4, "late replica's push applies");
    ps.shutdown();
}

#[test]
fn worker_planned_memory_is_result_identical() {
    // Satellite: remote partitions now compile with the PR-3 step-memory
    // planner by default. Planning must be invisible in the results.
    use rustflow::distributed::{ClusterSpec, DistMaster, DistMasterOptions, Worker, WorkerOptions};

    let run_with = |enable_memory_planning: bool| -> Vec<f32> {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addrs = vec![l.local_addr().unwrap().to_string()];
        drop(l);
        let cluster = ClusterSpec::new(addrs.clone(), 1);
        let w = Worker::with_options(
            0,
            cluster.clone(),
            WorkerOptions { enable_memory_planning, ..Default::default() },
        );
        w.serve(&addrs[0]).unwrap();

        let mut b = GraphBuilder::new();
        let x = b.constant(
            Tensor::from_f32(vec![32, 32], (0..1024).map(|i| (i % 7) as f32 * 0.5).collect())
                .unwrap(),
        );
        let y = b.with_device("/job:worker/task:0", |b| {
            let m = b.matmul(x, x);
            let r = b.relu(m);
            let s = b.add(r, m);
            b.matmul(s, s)
        });
        let yname = format!("{}:0", b.graph.node(y.node).name);
        // Const-rooted transfer-intent idiom: folding off so the chain
        // really executes on the worker, through its (planned) arenas.
        let opts =
            DistMasterOptions { enable_constant_folding: false, ..DistMasterOptions::default() };
        let master = DistMaster::new(cluster, b.into_graph(), opts);
        let out = master.run(&[], &[&yname], &[]).unwrap();
        out[0].as_f32().unwrap().to_vec()
    };

    let planned = run_with(true);
    let unplanned = run_with(false);
    assert_eq!(planned, unplanned, "planned step memory must not change results");
}
