//! Integration tests for the §3.3 distributed runtime: master + in-process
//! workers over real TCP (Fig 3's distributed structure), cross-worker
//! Send/Recv, variables on workers, fault tolerance via health checks and
//! checkpoint recovery (E3/E17 support).

use rustflow::distributed::{ClusterSpec, DistMaster, DistMasterOptions, Worker};
use rustflow::optim::Optimizer;
use rustflow::tensor::Tensor;
use rustflow::GraphBuilder;

/// Spin up `n` in-process workers on ephemeral ports; returns the cluster
/// spec and worker handles.
fn spawn_cluster(
    n: usize,
    devices_per_worker: usize,
) -> (ClusterSpec, Vec<std::sync::Arc<Worker>>) {
    // Bind ephemeral listeners first to learn the addresses.
    let mut addrs = Vec::new();
    let mut listeners = Vec::new();
    for _ in 0..n {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        addrs.push(l.local_addr().unwrap().to_string());
        listeners.push(l);
    }
    drop(listeners); // free the ports; tiny race acceptable in tests
    let cluster = ClusterSpec::new(addrs.clone(), devices_per_worker);
    let workers: Vec<_> = (0..n)
        .map(|t| {
            let w = Worker::new(t, cluster.clone(), 2);
            w.serve(&addrs[t]).unwrap();
            w
        })
        .collect();
    (cluster, workers)
}

#[test]
fn distributed_constant_math() {
    let (cluster, _workers) = spawn_cluster(2, 1);
    let mut b = GraphBuilder::new();
    let x = b.with_device("/job:worker/task:0", |b| b.scalar(6.0));
    let y = b.with_device("/job:worker/task:1", |b| b.scalar(7.0));
    // The multiply forces a cross-worker tensor transfer.
    let z = b.with_device("/job:worker/task:1", |b| b.mul(x, y));
    let zname = format!("{}:0", b.graph.node(z.node).name);
    // Const-rooted on purpose: pin folding off so the multiply really runs
    // on worker 1 and the Send/Recv + %STEP% paths are exercised (the
    // established idiom for const-rooted graphs whose intent is transfer).
    let mut opts = DistMasterOptions::default();
    opts.enable_constant_folding = false;
    let master = DistMaster::new(cluster, b.into_graph(), opts);
    master.health_check().unwrap();
    let out = master.run(&[], &[&zname], &[]).unwrap();
    assert_eq!(out[0].scalar_value_f32().unwrap(), 42.0);
    // Second step exercises the %STEP% key namespacing.
    let out2 = master.run(&[], &[&zname], &[]).unwrap();
    assert_eq!(out2[0].scalar_value_f32().unwrap(), 42.0);
}

#[test]
fn distributed_matches_local() {
    // §6 lesson 4: "make a single machine implementation match before
    // debugging a distributed implementation" — we assert they match.
    let build = |b: &mut GraphBuilder| {
        let x = b.constant(
            Tensor::from_f32(vec![4, 4], (0..16).map(|i| 0.1 * i as f32).collect()).unwrap(),
        );
        let mut l = x;
        for _ in 0..3 {
            l = b.matmul(l, l);
        }
        let r = b.with_device("/job:worker/task:1", |b| b.relu(l));
        format!("{}:0", b.graph.node(r.node).name)
    };
    // Local.
    let mut bl = GraphBuilder::new();
    let mut name = build(&mut bl);
    // Local session can't satisfy /job:worker constraints; strip them.
    for n in &mut bl.graph.nodes {
        n.requested_device.clear();
    }
    let sess = rustflow::Session::new(bl.into_graph(), rustflow::SessionOptions::default());
    let local = sess.run(&[], &[&name], &[]).unwrap();
    // Distributed.
    let (cluster, _workers) = spawn_cluster(2, 1);
    let mut bd = GraphBuilder::new();
    name = build(&mut bd);
    // Disable §5.5 lossy wire compression for the exact comparison (its
    // accuracy impact is measured separately in E13), and pin folding off:
    // the chain is const-rooted, and the point is to run it *on workers*.
    let mut opts = DistMasterOptions::default();
    opts.partition.compress_cross_task = false;
    opts.enable_constant_folding = false;
    let master = DistMaster::new(cluster, bd.into_graph(), opts);
    let dist = master.run(&[], &[&name], &[]).unwrap();
    assert!(local[0].allclose(&dist[0], 1e-4, 1e-4), "local vs distributed numerics differ");
}

#[test]
fn distributed_feeds_and_fetches() {
    let (cluster, _workers) = spawn_cluster(2, 1);
    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", rustflow::DType::F32).unwrap();
    let c = b.with_device("/job:worker/task:1", |b| b.scalar(10.0));
    let y = b.mul(x, c);
    let yname = format!("{}:0", b.graph.node(y.node).name);
    let master = DistMaster::new(cluster, b.into_graph(), DistMasterOptions::default());
    for v in [1.0f32, 2.5, -3.0] {
        let out = master.run(&[("x", Tensor::scalar_f32(v))], &[&yname], &[]).unwrap();
        assert_eq!(out[0].scalar_value_f32().unwrap(), v * 10.0);
    }
}

#[test]
fn distributed_training_with_variables() {
    // Variables live on worker 0; gradient compute pulled across workers.
    let (cluster, _workers) = spawn_cluster(2, 1);
    let mut b = GraphBuilder::new();
    let w = b.with_device("/job:worker/task:0", |b| {
        b.variable("w", Tensor::scalar_f32(0.0)).unwrap()
    });
    let target = b.with_device("/job:worker/task:1", |b| b.scalar(5.0));
    let diff = b.sub(w, target);
    let loss = b.square(diff);
    let train = Optimizer::sgd(0.2).minimize(&mut b, loss, &[w]).unwrap();
    let train_name = b.graph.node(train).name.clone();
    let loss_name = format!("{}:0", b.graph.node(loss.node).name);
    let init: Vec<String> = b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
    let master = DistMaster::new(cluster, b.into_graph(), DistMasterOptions::default());
    master.run_targets(&init.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();
    let mut last = f32::INFINITY;
    for _ in 0..30 {
        let out = master.run(&[], &[&loss_name], &[&train_name]).unwrap();
        last = out[0].scalar_value_f32().unwrap();
    }
    assert!(last < 1e-3, "distributed training failed to converge: loss {last}");
    let w_final = master.run(&[], &["w"], &[]).unwrap();
    assert!((w_final[0].scalar_value_f32().unwrap() - 5.0).abs() < 0.05);
}

#[test]
fn health_check_detects_dead_worker() {
    let (cluster, workers) = spawn_cluster(2, 1);
    let master = {
        let mut b = GraphBuilder::new();
        b.scalar(1.0);
        DistMaster::new(cluster.clone(), b.into_graph(), DistMasterOptions::default())
    };
    master.health_check().unwrap();
    // "Kill" worker 1 by shutting it down.
    let (t, _) = rustflow::distributed::proto::rpc(
        cluster.addr_of(1),
        rustflow::distributed::proto::MSG_SHUTDOWN,
        b"",
    )
    .unwrap();
    assert_eq!(t, rustflow::distributed::proto::MSG_HEALTH_OK);
    drop(workers);
    // Now the health check must fail with Unavailable.
    std::thread::sleep(std::time::Duration::from_millis(50));
    let e = master.health_check().unwrap_err();
    assert_eq!(e.code, rustflow::error::Code::Unavailable);
}

#[test]
fn checkpoint_recovery_after_worker_restart() {
    // E17 core: train, checkpoint, "lose" the worker state (reset), restore,
    // verify the step counter continues — §3.3's recovery loop.
    let dir = std::env::temp_dir().join(format!("rustflow-dist-ckpt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("model.ckpt").to_string_lossy().to_string();

    let (cluster, workers) = spawn_cluster(1, 1);
    let mut b = GraphBuilder::new();
    let w = b.variable("w", Tensor::scalar_f32(0.0)).unwrap();
    let one = b.scalar(1.0);
    let inc = b.assign_add(w, one).unwrap();
    // Save node wired to the variable (§3.3: "each Variable node is
    // connected to a Save node").
    let save = b
        .op(
            "Save",
            "save",
            vec![w],
            vec![
                ("tensor_names", rustflow::graph::AttrValue::ListStr(vec!["w".into()])),
                ("path", rustflow::graph::AttrValue::Str(ckpt.clone())),
            ],
        )
        .unwrap();
    // Restore node + assign, "only enabled in the first iteration after a
    // restart" — here: run explicitly on recovery.
    let restore = b
        .op1(
            "Restore",
            "restore",
            vec![],
            vec![
                ("tensor_names", rustflow::graph::AttrValue::ListStr(vec!["w".into()])),
                ("out_types", rustflow::graph::AttrValue::ListType(vec![rustflow::DType::F32])),
                ("path", rustflow::graph::AttrValue::Str(ckpt.clone())),
            ],
        )
        .unwrap();
    let restore_assign = b.assign(w, restore).unwrap();

    let names: Vec<String> = [b.init_ops[0], inc, save, restore_assign]
        .iter()
        .map(|&i| b.graph.node(i).name.clone())
        .collect();
    let (init, inc, save, restore) = (&names[0], &names[1], &names[2], &names[3]);

    let master = DistMaster::new(cluster, b.into_graph(), DistMasterOptions::default());
    master.run_targets(&[init]).unwrap();
    for _ in 0..5 {
        master.run_targets(&[inc]).unwrap();
    }
    master.run_targets(&[save]).unwrap(); // checkpoint at w=5
    for _ in 0..3 {
        master.run_targets(&[inc]).unwrap();
    }
    // Simulate worker loss: wipe its variable container.
    workers[0].resources().reset_container("");
    let e = master.run(&[], &["w"], &[]).unwrap_err();
    assert_eq!(e.code, rustflow::error::Code::FailedPrecondition);
    // Recovery: restore from the checkpoint, then continue.
    master.run_targets(&[restore]).unwrap();
    let out = master.run(&[], &["w"], &[]).unwrap();
    assert_eq!(out[0].scalar_value_f32().unwrap(), 5.0, "restored to checkpointed value");
    master.run_targets(&[inc]).unwrap();
    let out = master.run(&[], &["w"], &[]).unwrap();
    assert_eq!(out[0].scalar_value_f32().unwrap(), 6.0, "training continues after recovery");
}

#[test]
fn worker_intra_op_pools_sized_and_results_identical() {
    use rustflow::distributed::WorkerOptions;
    // Two clusters running the same remote matmul: serial kernels vs
    // intra-op pools of 4. The pool's determinism contract promises
    // bit-identical results; the worker config must actually size the
    // per-device pools.
    let run_with = |intra_op_threads: usize| -> (Vec<f32>, usize) {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addrs = vec![l.local_addr().unwrap().to_string()];
        drop(l);
        let cluster = ClusterSpec::new(addrs.clone(), 1);
        let w = Worker::with_options(
            0,
            cluster.clone(),
            WorkerOptions { threads_per_device: 2, intra_op_threads, ..Default::default() },
        );
        w.serve(&addrs[0]).unwrap();
        let pool_threads = w.devices().get(0).compute.threads();

        let mut b = GraphBuilder::new();
        let x = b.constant(
            Tensor::from_f32(vec![96, 96], (0..96 * 96).map(|i| (i % 13) as f32 * 0.1).collect())
                .unwrap(),
        );
        let y = b.with_device("/job:worker/task:0", |b| b.matmul(x, x));
        let yname = format!("{}:0", b.graph.node(y.node).name);
        // Const-rooted on purpose (transfer-intent idiom): keep the matmul
        // on the worker so the remote kernel actually uses the pool.
        let opts =
            DistMasterOptions { enable_constant_folding: false, ..DistMasterOptions::default() };
        let master = DistMaster::new(cluster, b.into_graph(), opts);
        let out = master.run(&[], &[&yname], &[]).unwrap();
        (out[0].as_f32().unwrap().to_vec(), pool_threads)
    };
    let (serial, serial_threads) = run_with(1);
    let (pooled, pooled_threads) = run_with(4);
    assert_eq!(serial_threads, 1);
    assert_eq!(pooled_threads, 4, "WorkerOptions::intra_op_threads must size the device pools");
    assert_eq!(serial, pooled, "intra-op parallelism must be bit-identical on remote partitions");
}
