//! Step-memory-planner integration tests (`rustflow::memory`): planning
//! on and off must be *result-identical* across the same graph families
//! the optimizer equivalence suite uses — randomized elementwise/fan-out
//! graphs, dead Switch branches, while loops, and feed/fetch aliasing
//! hazards — because the planner only changes where bytes live, never
//! what kernels compute. Exact equality is asserted on unfused paths and
//! 1e-6 closeness where fusion is enabled, and the plan/runtime stats are
//! checked to prove the arena actually engaged (reuse hits, in-place
//! forwards, packed footprint below the naive sum).

use rustflow::util::rng::Pcg32;
use rustflow::{DType, Endpoint, GraphBuilder, Session, SessionOptions, Tensor};

fn opts(planning: bool, fuse: bool) -> SessionOptions {
    SessionOptions {
        enable_memory_planning: planning,
        enable_elementwise_fusion: fuse,
        ..Default::default()
    }
}

/// A randomized graph mixing what the planner cares about: a fed
/// placeholder (dynamic shapes), const subtrees, elementwise chains
/// (forwarding fodder), shared fan-out (refcount > 1), and Identity
/// pass-throughs (storage aliasing).
fn random_model(seed: u64) -> (GraphBuilder, String) {
    let mut rng = Pcg32::new(seed * 77 + 13);
    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", DType::F32).unwrap();
    let c0 = b.scalar(rng.uniform(0.5, 1.5));
    let mut pool: Vec<Endpoint> = vec![x, c0];
    for _ in 0..16 {
        let a = pool[rng.index(pool.len())];
        let v = match rng.next_below(7) {
            0 => b.neg(a),
            1 => b.tanh(a),
            2 => b.relu(a),
            3 => b.identity(a),
            4 => {
                let d = pool[rng.index(pool.len())];
                b.add(a, d)
            }
            5 => {
                let d = pool[rng.index(pool.len())];
                b.mul(a, d)
            }
            _ => {
                let s = b.scalar(rng.uniform(-1.0, 1.0));
                b.sub(a, s)
            }
        };
        pool.push(v);
    }
    let out = b.add_n(pool[2..].to_vec());
    let name = format!("{}:0", b.graph.node(out.node).name);
    (b, name)
}

fn run_model(seed: u64, options: SessionOptions, steps: usize) -> Vec<Tensor> {
    let (b, name) = random_model(seed);
    let sess = Session::new(b.into_graph(), options);
    let mut rng = Pcg32::with_stream(seed, 4242);
    (0..steps)
        .map(|_| {
            let feed =
                Tensor::from_f32(vec![6], (0..6).map(|_| rng.uniform(-2.0, 2.0)).collect())
                    .unwrap();
            sess.run(&[("x", feed)], &[&name], &[]).unwrap().remove(0)
        })
        .collect()
}

#[test]
fn randomized_equivalence_planning_on_vs_off() {
    for seed in 0..6u64 {
        for fuse in [false, true] {
            // Several steps per session so arena reuse (not just the cold
            // first step) is covered by the comparison.
            let off = run_model(seed, opts(false, fuse), 4);
            let on = run_model(seed, opts(true, fuse), 4);
            for (i, (a, b)) in off.iter().zip(&on).enumerate() {
                if fuse {
                    assert!(
                        a.allclose(b, 1e-6, 1e-6),
                        "seed {seed} fuse={fuse} step {i}: diverged"
                    );
                } else {
                    assert_eq!(
                        a.as_f32().unwrap(),
                        b.as_f32().unwrap(),
                        "seed {seed} step {i}: planning changed unfused results"
                    );
                }
            }
        }
    }
}

#[test]
fn dead_switch_branch_unaffected_by_planning() {
    for (pred, expect) in [(true, 50.0f32), (false, 6.0)] {
        for planning in [false, true] {
            let mut b = GraphBuilder::new();
            let x = b.scalar(5.0);
            let p = b.constant(Tensor::scalar_bool(pred));
            let (f_side, t_side) = b.switch(x, p).unwrap();
            let ten = b.scalar(10.0);
            let one = b.scalar(1.0);
            let t_out = b.mul(t_side, ten);
            let f_out = b.add(f_side, one);
            let (merged, _) = b.merge(vec![f_out, t_out]).unwrap();
            let name = format!("{}:0", b.graph.node(merged.node).name);
            let sess = Session::new(b.into_graph(), opts(planning, true));
            for _ in 0..3 {
                let out = sess.run(&[], &[&name], &[]).unwrap();
                assert_eq!(
                    out[0].scalar_value_f32().unwrap(),
                    expect,
                    "pred={pred} planning={planning}"
                );
            }
        }
    }
}

#[test]
fn while_loop_unaffected_by_planning() {
    for planning in [false, true] {
        let mut b = GraphBuilder::new();
        let zero = b.scalar(0.0);
        let exits = b
            .while_loop(
                "loop",
                vec![zero],
                |b, v| {
                    let lim = b.scalar(10.0);
                    Ok(b.less(v[0], lim))
                },
                |b, v| {
                    let one = b.scalar(1.0);
                    let inc = b.add(v[0], one);
                    Ok(vec![b.mul(inc, one)])
                },
            )
            .unwrap();
        let name = format!("{}:0", b.graph.node(exits[0].node).name);
        let sess = Session::new(b.into_graph(), opts(planning, true));
        for _ in 0..2 {
            let out = sess.run(&[], &[&name], &[]).unwrap();
            assert_eq!(out[0].scalar_value_f32().unwrap(), 10.0, "planning={planning}");
        }
    }
}

#[test]
fn feed_and_fetch_aliasing_hazards() {
    // Fetch a fed tensor, fetch an intermediate that is also consumed
    // downstream, and fetch the final value — all in one signature. The
    // fetched intermediate must keep its value even though its consumer
    // (a forwarding-safe op) runs after the fetch is recorded.
    for planning in [false, true] {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let two = b.scalar(2.0);
        let m = b.mul(x, two);
        let t = b.tanh(m);
        let mname = format!("{}:0", b.graph.node(m.node).name);
        let tname = format!("{}:0", b.graph.node(t.node).name);
        let sess = Session::new(b.into_graph(), opts(planning, false));
        for step in 0..3 {
            let feed = Tensor::from_f32(vec![4], vec![0.5 + step as f32, -1.0, 2.0, 0.0]).unwrap();
            let out = sess.run(&[("x", feed.clone())], &["x", &mname, &tname], &[]).unwrap();
            assert_eq!(out[0].as_f32().unwrap(), feed.as_f32().unwrap(), "fed fetch");
            let m_expect: Vec<f32> = feed.as_f32().unwrap().iter().map(|v| v * 2.0).collect();
            assert_eq!(out[1].as_f32().unwrap(), m_expect, "intermediate fetch, planning={planning}");
            let t_expect: Vec<f32> = m_expect.iter().map(|v| v.tanh()).collect();
            assert_eq!(out[2].as_f32().unwrap(), t_expect, "final fetch");
        }
    }
}

#[test]
fn fan_out_values_survive_in_place_forwarding() {
    // `a` feeds two forwarding-safe consumers: neither may mutate it in
    // place (refcount > 1 at run time; consumer count > 1 in the plan).
    for planning in [false, true] {
        let mut b = GraphBuilder::new();
        let x = b.constant(Tensor::from_f32(vec![8], (0..8).map(|i| i as f32 - 3.5).collect()).unwrap());
        let c = b.scalar(1.5);
        let a = b.mul(x, c);
        let n1 = b.neg(a);
        let n2 = b.tanh(a);
        let s = b.add(n1, n2);
        let name = format!("{}:0", b.graph.node(s.node).name);
        let sess = Session::new(
            b.into_graph(),
            SessionOptions {
                enable_memory_planning: planning,
                enable_constant_folding: false, // keep the graph live at run time
                ..Default::default()
            },
        );
        let first = sess.run(&[], &[&name], &[]).unwrap();
        for _ in 0..3 {
            let again = sess.run(&[], &[&name], &[]).unwrap();
            assert_eq!(
                first[0].as_f32().unwrap(),
                again[0].as_f32().unwrap(),
                "planning={planning}: repeated runs diverged (buffer corruption)"
            );
        }
    }
}

#[test]
fn const_storage_never_mutated() {
    // Neg is forwarding-safe, but its Const input is pinned (and shared
    // with the node's attr): ten runs must all see the same constant.
    let mut b = GraphBuilder::new();
    let c = b.constant(Tensor::from_f32(vec![4], vec![1.0, -2.0, 3.0, -4.0]).unwrap());
    let y = b.neg(c);
    let name = format!("{}:0", b.graph.node(y.node).name);
    let sess = Session::new(
        b.into_graph(),
        SessionOptions { enable_constant_folding: false, ..Default::default() },
    );
    for _ in 0..10 {
        let out = sess.run(&[], &[&name], &[]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[-1.0, 2.0, -3.0, 4.0]);
    }
}

/// A deep const-rooted elementwise chain (static shapes throughout, so
/// the planner's byte-exact static slots and forwarding all engage).
fn static_chain(depth: usize, elements: usize) -> (GraphBuilder, String) {
    let mut b = GraphBuilder::new();
    let x = b.constant(Tensor::fill_f32(vec![elements], 0.25));
    let c = b.scalar(1.01);
    let mut h = x;
    for i in 0..depth {
        h = match i % 3 {
            0 => b.mul(h, c),
            1 => b.tanh(h),
            _ => b.relu(h),
        };
    }
    let name = format!("{}:0", b.graph.node(h.node).name);
    (b, name)
}

#[test]
fn plan_stats_show_packing_and_runtime_reuse() {
    let (b, name) = static_chain(12, 1024);
    let sess = Session::new(
        b.into_graph(),
        SessionOptions {
            enable_memory_planning: true,
            // Keep the chain alive at run time and as separate nodes.
            enable_constant_folding: false,
            enable_elementwise_fusion: false,
            ..Default::default()
        },
    );
    let first = sess.run(&[], &[&name], &[]).unwrap();
    for _ in 0..3 {
        let out = sess.run(&[], &[&name], &[]).unwrap();
        assert_eq!(first[0].as_f32().unwrap(), out[0].as_f32().unwrap());
    }
    let reports = sess.memory_stats(&[], &[&name], &[]).expect("cached step");
    assert_eq!(reports.len(), 1);
    let r = &reports[0];
    assert!(r.plan.planned_static >= 8, "chain endpoints should be planned: {:?}", r.plan);
    assert!(
        r.plan.arena_bytes < r.plan.naive_bytes,
        "interval packing must beat one-buffer-per-endpoint: {:?}",
        r.plan
    );
    assert!(r.plan.forward_candidates >= 1, "chain should forward in place: {:?}", r.plan);
    assert!(
        r.runtime.forwards_taken + r.runtime.reuse_hits > 0,
        "warm steps should reuse arena storage or forward: {:?}",
        r.runtime
    );
    assert_eq!(r.runtime.checkouts, 4, "one arena checkout per run");
}

#[test]
fn dynamic_slots_pool_fed_graphs() {
    // Everything downstream of a feed has unknown static shape: those
    // endpoints get dynamic slots whose buffers still pool across steps.
    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", DType::F32).unwrap();
    let c = b.scalar(0.5);
    let mut h = x;
    for _ in 0..6 {
        let m = b.mul(h, c);
        h = b.tanh(m);
    }
    let name = format!("{}:0", b.graph.node(h.node).name);
    let sess = Session::new(
        b.into_graph(),
        SessionOptions { enable_elementwise_fusion: false, ..Default::default() },
    );
    let feed = Tensor::fill_f32(vec![256], 1.0);
    for _ in 0..4 {
        sess.run(&[("x", feed.clone())], &[&name], &[]).unwrap();
    }
    let reports = sess.memory_stats(&["x"], &[&name], &[]).expect("cached step");
    let r = &reports[0];
    assert!(r.plan.planned_dynamic >= 6, "fed chain should use dynamic slots: {:?}", r.plan);
    assert!(
        r.runtime.forwards_taken + r.runtime.reuse_hits > 0,
        "dynamic slots should still reuse storage across steps: {:?}",
        r.runtime
    );
}

#[test]
fn multi_device_planning_matches_single_device() {
    let build = || {
        let mut b = GraphBuilder::new();
        let x = b.constant(
            Tensor::from_f32(vec![4, 4], (0..16).map(|i| i as f32 * 0.1).collect()).unwrap(),
        );
        let mut l = x;
        let mut r = x;
        for _ in 0..3 {
            l = b.matmul(l, l);
            r = b.matmul(r, x);
        }
        let out = b.add(l, r);
        let name = format!("{}:0", b.graph.node(out.node).name);
        (b, name)
    };
    let run = |devices: usize, planning: bool| {
        let (b, name) = build();
        let sess = Session::new(
            b.into_graph(),
            SessionOptions {
                devices,
                enable_memory_planning: planning,
                enable_constant_folding: false,
                ..Default::default()
            },
        );
        sess.run(&[], &[&name], &[]).unwrap().remove(0)
    };
    let base = run(1, false);
    for (devices, planning) in [(1, true), (3, true), (3, false)] {
        let out = run(devices, planning);
        assert!(
            base.allclose(&out, 1e-4, 1e-4),
            "devices={devices} planning={planning} diverged"
        );
    }
}

#[test]
fn planning_off_reports_empty_plan() {
    let (b, name) = static_chain(4, 16);
    let sess = Session::new(
        b.into_graph(),
        SessionOptions { enable_memory_planning: false, ..Default::default() },
    );
    sess.run(&[], &[&name], &[]).unwrap();
    let reports = sess.memory_stats(&[], &[&name], &[]).expect("cached step");
    assert_eq!(reports[0].plan.planned_static, 0);
    assert_eq!(reports[0].plan.num_slots, 0);
    assert_eq!(reports[0].runtime.checkouts, 0);
}
