//! The three-layer stack end to end: rust coordinator (L3) executing
//! AOT-compiled JAX (L2) containing Pallas kernels (L1) via PJRT.
//! Requires `make artifacts`.

use rustflow::graph::AttrValue;
use rustflow::runtime::{artifact_dir, load_artifact};
use rustflow::xla_model::{TransformerConfig, XlaTrainer};
use rustflow::{DType, GraphBuilder, Session, SessionOptions, Tensor};

fn relu_artifact() -> std::path::PathBuf {
    artifact_dir().join("relu_layer.hlo.txt")
}

/// The XLA stack needs two opt-ins: `make artifacts` (produces the HLO
/// files) and `--features xla` (the PJRT bridge; the default build uses a
/// stub that cannot execute). Tests skip rather than fail when either is
/// missing, and assert fully when both are present.
/// Only genuine absence skips — `NotFound` (no artifacts) or
/// `Unavailable` (stub build). Any other error in an xla-enabled build
/// (HLO parse failure, compile failure, …) is a real regression and
/// must fail the test.
macro_rules! require_xla {
    ($e:expr) => {
        match $e {
            Ok(v) => v,
            Err(e)
                if e.code == rustflow::error::Code::NotFound
                    || e.code == rustflow::error::Code::Unavailable =>
            {
                eprintln!("skipping (XLA stack unavailable: {e})");
                return;
            }
            Err(e) => panic!("XLA stack present but broken: {e}"),
        }
    };
}

#[test]
fn relu_layer_artifact_matches_cpu_kernels() {
    // The same relu(x·w + b) computed by (a) the Pallas-kernel XLA
    // artifact and (b) rustflow's own CPU kernels must agree.
    let exe = require_xla!(load_artifact(&relu_artifact()));
    let (m, k, n) = (32usize, 64usize, 128usize);
    let mut rng = rustflow::util::rng::Pcg32::new(5);
    let x = Tensor::from_f32(vec![m, k], (0..m * k).map(|_| rng.normal()).collect()).unwrap();
    let w = Tensor::from_f32(vec![k, n], (0..k * n).map(|_| rng.normal() * 0.1).collect()).unwrap();
    let b = Tensor::from_f32(vec![n], (0..n).map(|_| rng.normal() * 0.1).collect()).unwrap();
    let xla_out = exe.run(&[x.clone(), w.clone(), b.clone()]).unwrap().remove(0);

    let mm = rustflow::kernels::matrix::matmul(&x, &w, false, false).unwrap();
    let pre = rustflow::kernels::nn::bias_add(&mm, &b).unwrap();
    let cpu_out = rustflow::kernels::nn::relu(&pre).unwrap();
    assert!(
        xla_out.allclose(&cpu_out, 1e-4, 1e-4),
        "XLA artifact and native kernels disagree"
    );
}

#[test]
fn xla_call_op_inside_a_graph() {
    // §5.4 as a graph node: XlaCall participates in a dataflow graph like
    // any other op.
    let exe_path = relu_artifact();
    require_xla!(load_artifact(&exe_path));
    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", DType::F32).unwrap();
    let w = b.constant(Tensor::fill_f32(vec![64, 128], 0.01));
    let bias = b.constant(Tensor::fill_f32(vec![128], -0.5));
    let call = b
        .op(
            "XlaCall",
            "relu_layer",
            vec![x, w, bias],
            vec![
                ("path", AttrValue::Str(exe_path.to_string_lossy().into())),
                ("out_types", AttrValue::ListType(vec![DType::F32])),
            ],
        )
        .unwrap();
    let out = rustflow::Endpoint::new(call, 0);
    let s = b.reduce_sum(out, None);
    let sname = format!("{}:0", b.graph.node(s.node).name);
    let sess = Session::new(b.into_graph(), SessionOptions::default());
    let x_val = Tensor::fill_f32(vec![32, 64], 1.0);
    let got = sess.run(&[("x", x_val)], &[&sname], &[]).unwrap();
    // relu(1·0.01·64 - 0.5) = relu(0.14) = 0.14 per element, 32*128 elements.
    let expect = 0.14f32 * 32.0 * 128.0;
    let v = got[0].scalar_value_f32().unwrap();
    assert!((v - expect).abs() / expect < 1e-3, "got {v}, want {expect}");
}

#[test]
fn transformer_trainer_loss_decreases() {
    let cfg = require_xla!(TransformerConfig::preset("tiny"));
    assert!(cfg.num_params() > 50_000);
    let mut trainer = require_xla!(XlaTrainer::new(&artifact_dir(), &cfg, 7));
    let mut losses = Vec::new();
    for _ in 0..30 {
        losses.push(trainer.train_step().unwrap());
    }
    let first = losses[0];
    let last = *losses.last().unwrap();
    // Initial loss ≈ ln(vocab) for random init.
    assert!((first - (cfg.vocab as f32).ln()).abs() < 1.0, "initial loss {first}");
    assert!(last < first * 0.9, "loss did not decrease: {first} -> {last}");
}

#[test]
fn transformer_checkpoint_roundtrip() {
    let cfg = require_xla!(TransformerConfig::preset("tiny"));
    let mut trainer = require_xla!(XlaTrainer::new(&artifact_dir(), &cfg, 11));
    for _ in 0..3 {
        trainer.train_step().unwrap();
    }
    let snapshot: Vec<Tensor> = trainer.params.clone();
    let path = std::env::temp_dir().join(format!("rf-xla-ckpt-{}.ckpt", std::process::id()));
    trainer.save(&path).unwrap();
    for _ in 0..3 {
        trainer.train_step().unwrap();
    }
    assert!(!trainer.params[0].allclose(&snapshot[0], 1e-7, 1e-7), "params should have moved");
    trainer.restore(&path).unwrap();
    for (a, b) in trainer.params.iter().zip(&snapshot) {
        assert!(a.allclose(b, 0.0, 0.0), "restore must be exact");
    }
}

#[test]
fn trainer_deterministic_given_seed() {
    let cfg = require_xla!(TransformerConfig::preset("tiny"));
    let mut a = require_xla!(XlaTrainer::new(&artifact_dir(), &cfg, 3));
    let mut b = require_xla!(XlaTrainer::new(&artifact_dir(), &cfg, 3));
    for _ in 0..3 {
        let la = a.train_step().unwrap();
        let lb = b.train_step().unwrap();
        assert_eq!(la, lb, "same seed must reproduce the loss trajectory");
    }
}
