//! Integration tests for the observability surface: the httpz debug
//! endpoints mounted on the serving front end and the parameter server,
//! the continuous profiler feeding `/statusz`, Prometheus exposition on
//! `/varz`, and straggler identification from barrier-arrival-lag
//! histograms alone.

use rustflow::distributed::ps::{ParamServer, PsOptions};
use rustflow::distributed::train::{DistTrainer, DistTrainerOptions};
use rustflow::obs::httpz;
use rustflow::obs::profiler::straggler_report;
use rustflow::optim::Optimizer;
use rustflow::serving::{ManagerOptions, ModelManager, ModelSpec, NetServer, WarmupRequest};
use rustflow::{models, DType, GraphBuilder, Session, SessionOptions, Tensor};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("rustflow-statusz-{tag}-{}", std::process::id()));
    let _ = std::fs::create_dir_all(&d);
    d
}

/// Export one MLP version to disk (GraphDef + checkpoint) and return the
/// spec plus its logits fetch name.
fn export_mlp(dir: &Path, tag: &str, seed: u64) -> (ModelSpec, String) {
    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", DType::F32).unwrap();
    let (logits, vars) = models::mlp(&mut b, x, &[8, 16, 4], seed).unwrap();
    let fetch = format!("{}:0", b.graph.node(logits.node).name);
    let inits: Vec<String> = b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
    let var_names: Vec<String> = vars.iter().map(|v| b.graph.node(v.node).name.clone()).collect();
    let graph = b.graph.clone();

    let sess = Session::new(b.into_graph(), SessionOptions::default());
    sess.run_targets(&inits.iter().map(String::as_str).collect::<Vec<_>>()).unwrap();
    let values =
        sess.run(&[], &var_names.iter().map(String::as_str).collect::<Vec<_>>(), &[]).unwrap();
    let pairs: Vec<(String, Tensor)> = var_names.into_iter().zip(values).collect();
    let ckpt = dir.join(format!("{tag}.ckpt"));
    rustflow::checkpoint::save_bundle(&ckpt, &pairs).unwrap();
    let gdf = dir.join(format!("{tag}.graphdef"));
    rustflow::graph::serde::write_graphdef(&gdf, &graph).unwrap();

    let spec = ModelSpec {
        graph_path: gdf,
        checkpoint_path: Some(ckpt),
        init_targets: vec![],
        warmup: vec![WarmupRequest {
            feeds: vec![("x".to_string(), Tensor::fill_f32(vec![1, 8], 0.1))],
            fetches: vec![fetch.clone()],
        }],
    };
    (spec, fetch)
}

/// The serving front end's debug surface end to end: health, Prometheus
/// metrics, a profiler report naming real graph nodes with nonzero
/// self-times and memory watermarks, a chrome trace — and the health
/// flip once the manager begins shutting down.
#[test]
fn serving_debug_surface_round_trips() {
    let dir = tmpdir("serving");
    let manager = Arc::new(ModelManager::new(ManagerOptions::default()));
    let server = NetServer::serve(Arc::clone(&manager), "127.0.0.1:0").unwrap();
    let dbg = NetServer::serve_debug(&manager, "127.0.0.1:0").unwrap();
    let dbg_addr = dbg.addr();

    // Healthy before any model exists; statusz says so too.
    let (code, body) = httpz::get(dbg_addr, "/healthz").unwrap();
    assert_eq!((code, body.as_str()), (200, "ok\n"));
    let (code, body) = httpz::get(dbg_addr, "/statusz").unwrap();
    assert_eq!(code, 200);
    assert!(body.contains("no live model versions"), "{body}");

    // Deploy and serve a few predictions so the session profiler has a
    // window of steps to roll up.
    let (spec, fetch) = export_mlp(&dir, "v1", 7);
    manager.deploy("mlp", 1, &spec).unwrap();
    for i in 0..4 {
        let probe = Tensor::fill_f32(vec![2, 8], 0.1 * (i + 1) as f32);
        manager.run("mlp", None, &[("x", probe)], &[&fetch]).unwrap();
    }

    let (code, body) = httpz::get(dbg_addr, "/statusz").unwrap();
    assert_eq!(code, 200);
    assert!(body.contains("== model \"mlp\" v1 =="), "{body}");
    assert!(!body.contains("of 0 observed"), "profiler must have observed steps: {body}");
    // Real graph nodes with self-time shares, and the arena watermarks.
    assert!(body.contains("MatMul"), "top-k must name real nodes: {body}");
    assert!(body.contains("share="), "{body}");
    assert!(body.contains("memory (per executor"), "memory attribution missing: {body}");

    let (code, body) = httpz::get(dbg_addr, "/varz").unwrap();
    assert_eq!(code, 200);
    assert!(body.contains("# TYPE"), "Prometheus exposition expected: {body}");

    let (code, body) = httpz::get(dbg_addr, "/tracez").unwrap();
    assert_eq!(code, 200);
    assert!(body.trim_start().starts_with('['), "chrome trace array: {body}");
    assert!(body.contains("MatMul"), "trace must hold kernel spans: {body}");

    // Unknown path: 404 listing the mounted routes, server stays up.
    let (code, body) = httpz::get(dbg_addr, "/nope").unwrap();
    assert_eq!(code, 404);
    assert!(body.contains("/statusz"), "404 should list routes: {body}");

    // Shutdown flips health while the surface itself keeps serving.
    manager.shutdown();
    let (code, _) = httpz::get(dbg_addr, "/healthz").unwrap();
    assert_eq!(code, 503);

    server.shutdown();
    dbg.shutdown();
}

/// Hostile bytes at the debug port get clean HTTP errors, never a hang
/// or a panic, and the listener keeps serving afterwards.
#[test]
fn hostile_requests_answered_with_errors() {
    let manager = Arc::new(ModelManager::new(ManagerOptions::default()));
    let dbg = NetServer::serve_debug(&manager, "127.0.0.1:0").unwrap();
    let addr = dbg.addr();

    let raw = |req: &[u8]| -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(req).unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut out = String::new();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.read_to_string(&mut out).unwrap();
        out
    };

    assert!(raw(b"POST /healthz HTTP/1.0\r\n\r\n").starts_with("HTTP/1.0 405"));
    assert!(raw(b"complete garbage\r\n\r\n").starts_with("HTTP/1.0 400"));
    assert!(raw(b"\r\n\r\n").starts_with("HTTP/1.0 400"));

    // Still healthy after the abuse.
    let (code, _) = httpz::get(addr, "/healthz").unwrap();
    assert_eq!(code, 200);
    dbg.shutdown();
}

/// The acceptance scenario: two synchronous replicas train against one
/// shard; replica 1 sleeps before every step. The parameter server's
/// per-replica barrier-arrival-lag histograms — with no trace, no shared
/// clocks, nothing but metric names — must identify it, and the lag must
/// show up in Prometheus form on the shard's `/varz`.
#[test]
fn straggler_identified_from_barrier_wait_histograms_alone() {
    const STEPS: usize = 4;
    const SLEEP: Duration = Duration::from_millis(25);

    let ps = ParamServer::new(PsOptions {
        opt: Optimizer::sgd(0.1),
        sync_replicas: Some(2),
        ..Default::default()
    });
    let addr = ps.serve("127.0.0.1:0").unwrap().to_string();
    let dbg = ps.serve_httpz("127.0.0.1:0").unwrap();

    std::thread::scope(|scope| {
        for r in 0..2u32 {
            let addr = addr.clone();
            scope.spawn(move || {
                let mut b = GraphBuilder::new();
                let w = b.variable("w", Tensor::scalar_f32(0.5)).unwrap();
                let x = b.placeholder("x", DType::F32).unwrap();
                let d = b.sub(w, x);
                let loss = b.square(d);
                let mut t = DistTrainer::new(
                    b,
                    loss,
                    &[w],
                    r,
                    &[addr],
                    DistTrainerOptions { compress: false, ..Default::default() },
                    SessionOptions::default(),
                )
                .unwrap();
                t.init_params().unwrap();
                for s in 0..STEPS {
                    if r == 1 {
                        std::thread::sleep(SLEEP);
                    }
                    let feeds = [("x", Tensor::scalar_f32(0.25 * s as f32))];
                    t.step(&feeds).unwrap();
                }
            });
        }
    });

    let report = straggler_report(ps.metrics()).expect("lag histograms after sync training");
    assert_eq!(report.replicas.len(), 2);
    assert_eq!(report.slowest, 1, "injected sleep must name replica 1: {report:?}");
    let slow = report.slowest_wait().unwrap();
    assert_eq!(slow.count as usize, STEPS);
    assert!(slow.p95_us >= 20_000, "25ms sleep must dominate the lag: {} us", slow.p95_us);
    let fast = report.replicas.iter().find(|w| w.replica == 0).unwrap();
    assert!(
        fast.p95_us < slow.p95_us / 2,
        "fast p95 {} us vs slow {} us",
        fast.p95_us,
        slow.p95_us
    );
    assert!(report.render_text().contains("<-- straggler"));

    // The same histograms ride `/varz` in Prometheus exposition, and
    // `/statusz` renders the report for humans.
    let (code, body) = httpz::get(dbg.addr(), "/varz").unwrap();
    assert_eq!(code, 200);
    assert!(body.contains("ps_replica1_barrier_wait_us_bucket"), "{body}");
    let (code, body) = httpz::get(dbg.addr(), "/statusz").unwrap();
    assert_eq!(code, 200);
    assert!(body.contains("straggler"), "{body}");

    ps.shutdown();
    dbg.shutdown();
}
