//! Integration tests for the model lifecycle manager and the TCP predict
//! front end: artifact round-trips (checkpoint save → GraphDef serialize
//! → `ModelManager` load → identical outputs), zero-loss hot-swap under
//! concurrent client load, version-pinning semantics, and the wire path.

use rustflow::serving::{
    ManagerOptions, ModelManager, ModelSpec, NetClient, NetServer, VersionState, WarmupRequest,
};
use rustflow::util::json::Json;
use rustflow::{models, DType, GraphBuilder, Session, SessionOptions, Tensor};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("rustflow-lifecycle-{tag}-{}", std::process::id()));
    let _ = std::fs::create_dir_all(&d);
    d
}

fn col(vals: &[f32]) -> Tensor {
    Tensor::from_f32(vec![vals.len(), 1], vals.to_vec()).unwrap()
}

/// Build an MLP classifier graph; returns (builder, fetch name, init
/// targets, variable names).
fn mlp_graph(seed: u64) -> (GraphBuilder, String, Vec<String>, Vec<String>) {
    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", DType::F32).unwrap();
    let (logits, vars) = models::mlp(&mut b, x, &[8, 16, 4], seed).unwrap();
    let fetch = format!("{}:0", b.graph.node(logits.node).name);
    let inits: Vec<String> = b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
    let var_names: Vec<String> = vars.iter().map(|v| b.graph.node(v.node).name.clone()).collect();
    (b, fetch, inits, var_names)
}

/// Export one trained model version to disk: GraphDef + checkpoint of
/// the variables' current values. Returns the spec (no warmup).
fn export_version(dir: &Path, tag: &str, seed: u64) -> (ModelSpec, String, Vec<Tensor>) {
    let (b, fetch, inits, var_names) = mlp_graph(seed);
    let graph = b.graph.clone();
    let sess = Session::new(b.into_graph(), SessionOptions::default());
    sess.run_targets(&inits.iter().map(String::as_str).collect::<Vec<_>>()).unwrap();
    // Fetch the initialized variables and bundle them — the serving-side
    // checkpoint path (`Save` nodes produce the identical bundle format).
    let fetch_names: Vec<&str> = var_names.iter().map(String::as_str).collect();
    let values = sess.run(&[], &fetch_names, &[]).unwrap();
    let pairs: Vec<(String, Tensor)> = var_names.iter().cloned().zip(values).collect();
    let ckpt = dir.join(format!("{tag}.ckpt"));
    rustflow::checkpoint::save_bundle(&ckpt, &pairs).unwrap();
    let gdf = dir.join(format!("{tag}.graphdef"));
    rustflow::graph::serde::write_graphdef(&gdf, &graph).unwrap();

    // Reference outputs computed directly, for round-trip comparison.
    let probe = Tensor::from_f32(vec![2, 8], (0..16).map(|i| i as f32 * 0.1).collect()).unwrap();
    let direct = sess.run(&[("x", probe.clone())], &[&fetch], &[]).unwrap();
    let spec = ModelSpec {
        graph_path: gdf,
        checkpoint_path: Some(ckpt),
        init_targets: vec![],
        warmup: vec![WarmupRequest {
            feeds: vec![("x".to_string(), probe)],
            fetches: vec![fetch.clone()],
        }],
    };
    (spec, fetch, direct)
}

#[test]
fn checkpoint_graphdef_manager_roundtrip_is_exact() {
    let dir = tmpdir("roundtrip");
    let (spec, fetch, direct) = export_version(&dir, "v1", 42);
    let mgr = ModelManager::new(ManagerOptions::default());
    mgr.deploy("mlp", 1, &spec).unwrap();
    assert_eq!(mgr.live_version("mlp"), Some(1));

    // Same probe input the direct session answered: byte-identical f32s
    // (same kernels, same deterministic execution).
    let probe = Tensor::from_f32(vec![2, 8], (0..16).map(|i| i as f32 * 0.1).collect()).unwrap();
    let served = mgr.run("mlp", None, &[("x", probe)], &[&fetch]).unwrap();
    assert_eq!(served[0].shape().dims(), &[2, 4]);
    assert_eq!(served[0].as_f32().unwrap(), direct[0].as_f32().unwrap());

    // The warmup request already exercised the lane: stats show it.
    let stats = mgr.model_stats("mlp");
    assert_eq!(stats.len(), 1);
    assert_eq!(stats[0].state, VersionState::Live);
    assert!(stats[0].batch.batches >= 2, "warmup + request should have run");
}

#[test]
fn missing_artifacts_fail_cleanly() {
    let dir = tmpdir("missing");
    let mgr = ModelManager::new(ManagerOptions::default());
    let spec = ModelSpec { graph_path: dir.join("nope.graphdef"), ..Default::default() };
    let e = mgr.deploy("m", 1, &spec).unwrap_err();
    assert_eq!(e.code, rustflow::error::Code::NotFound);
    assert!(e.message.contains("graphdef load failed"), "{}", e.message);
    // A checkpoint naming a variable the graph lacks also fails the deploy.
    let (mut spec2, _, _) = export_version(&dir, "v1", 1);
    let bad_ckpt = dir.join("bad.ckpt");
    rustflow::checkpoint::save_bundle(
        &bad_ckpt,
        &[("ghost_var".to_string(), Tensor::scalar_f32(1.0))],
    )
    .unwrap();
    spec2.checkpoint_path = Some(bad_ckpt);
    let e = mgr.deploy("m", 1, &spec2).unwrap_err();
    assert!(e.message.contains("checkpoint restore failed"), "{}", e.message);
    assert_eq!(mgr.live_version("m"), None);
}

/// The headline guarantee: a hot-swap under concurrent client load loses
/// zero in-flight requests, and every request submitted after the deploy
/// returns is answered by the new version.
#[test]
fn hot_swap_under_load_loses_nothing() {
    // v1: y = x * 1; v2: y = x * 2 — responses identify their version.
    let scale_session = |k: f32| -> (Arc<Session>, String) {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let c = b.scalar(k);
        let y = b.mul(x, c);
        let fetch = format!("{}:0", b.graph.node(y.node).name);
        (Arc::new(Session::new(b.into_graph(), SessionOptions::default())), fetch)
    };
    let (s1, fetch) = scale_session(1.0);
    let (s2, fetch2) = scale_session(2.0);
    assert_eq!(fetch, fetch2);

    let mgr = Arc::new(ModelManager::new(ManagerOptions::default()));
    mgr.deploy_session("m", 1, s1, &[]).unwrap();

    let swapped = Arc::new(AtomicBool::new(false));
    let stop = Arc::new(AtomicBool::new(false));
    let mut clients = Vec::new();
    for c in 0..4u32 {
        let mgr = Arc::clone(&mgr);
        let swapped = Arc::clone(&swapped);
        let stop = Arc::clone(&stop);
        let fetch = fetch.clone();
        clients.push(std::thread::spawn(move || -> (u64, u64, u64) {
            let (mut v1_answers, mut v2_answers, mut total) = (0u64, 0u64, 0u64);
            let mut i = 0f32;
            while !stop.load(Ordering::SeqCst) {
                i += 1.0;
                let input = i * (c + 1) as f32;
                // Sampled *before* submit: if the swap had completed by
                // then, the answer must come from v2.
                let after_swap = swapped.load(Ordering::SeqCst);
                let out = mgr
                    .run("m", None, &[("x", col(&[input]))], &[&fetch])
                    .expect("no request may fail across a hot-swap");
                let y = out[0].as_f32().unwrap()[0];
                total += 1;
                if y == input {
                    v1_answers += 1;
                } else if y == input * 2.0 {
                    v2_answers += 1;
                } else {
                    panic!("answer {y} for input {input} came from neither version");
                }
                if after_swap {
                    assert_eq!(y, input * 2.0, "post-swap request answered by the old version");
                }
            }
            (v1_answers, v2_answers, total)
        }));
    }

    std::thread::sleep(Duration::from_millis(100));
    mgr.deploy_session("m", 2, s2, &[]).unwrap(); // blocks until v1 drained
    swapped.store(true, Ordering::SeqCst);
    std::thread::sleep(Duration::from_millis(100));
    stop.store(true, Ordering::SeqCst);

    let (mut v1_total, mut v2_total, mut total) = (0u64, 0u64, 0u64);
    for t in clients {
        let (v1, v2, n) = t.join().expect("client thread panicked");
        v1_total += v1;
        v2_total += v2;
        total += n;
    }
    assert!(v1_total > 0, "expected some pre-swap traffic");
    assert!(v2_total > 0, "expected some post-swap traffic");
    // Zero lost requests: the managers' per-version counters account for
    // every client request, all of them OK.
    let stats = mgr.model_stats("m");
    let sum_requests: u64 = stats.iter().map(|s| s.requests).sum();
    let sum_ok: u64 = stats.iter().map(|s| s.ok).sum();
    let sum_errors: u64 = stats.iter().map(|s| s.errors).sum();
    assert_eq!(sum_requests, total);
    assert_eq!(sum_ok, total);
    assert_eq!(sum_errors, 0);
    assert_eq!(stats.iter().find(|s| s.version == 1).unwrap().state, VersionState::Retired);
    assert_eq!(stats.iter().find(|s| s.version == 2).unwrap().state, VersionState::Live);

    // Version-pinned requests to the retired version: NotFound, fast.
    let e = mgr.run("m", Some(1), &[("x", col(&[1.0]))], &[&fetch]).unwrap_err();
    assert_eq!(e.code, rustflow::error::Code::NotFound);
}

#[test]
fn tcp_front_end_serves_and_hot_swaps() {
    let dir = tmpdir("tcp");
    let (spec1, fetch, _) = export_version(&dir, "v1", 7);
    let (spec2, fetch2, _) = export_version(&dir, "v2", 13);
    assert_eq!(fetch, fetch2);
    let mgr = Arc::new(ModelManager::new(ManagerOptions::default()));
    mgr.deploy("mlp", 1, &spec1).unwrap();
    let server = NetServer::serve(Arc::clone(&mgr), "127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();

    let mut client = NetClient::connect(&addr).unwrap();
    client.ping().unwrap();

    // Round trip over the wire matches the in-process answer.
    let probe = Tensor::from_f32(vec![1, 8], vec![0.5; 8]).unwrap();
    let wire_out = client.predict("mlp", None, &[("x", probe.clone())], &[&fetch]).unwrap();
    let local_out = mgr.run("mlp", None, &[("x", probe.clone())], &[&fetch]).unwrap();
    assert_eq!(wire_out[0].as_f32().unwrap(), local_out[0].as_f32().unwrap());

    // Unknown model / retired version / malformed feeds keep their codes
    // across the wire.
    let e = client.predict("ghost", None, &[("x", probe.clone())], &[&fetch]).unwrap_err();
    assert_eq!(e.code, rustflow::error::Code::NotFound);
    let e = client
        .predict("mlp", None, &[("x", Tensor::scalar_f32(1.0))], &[&fetch])
        .unwrap_err();
    assert_eq!(e.code, rustflow::error::Code::InvalidArgument);

    // Hot-swap while clients hammer over TCP: zero failures.
    let stop = Arc::new(AtomicBool::new(false));
    let mut clients = Vec::new();
    for _ in 0..3 {
        let addr = addr.clone();
        let stop = Arc::clone(&stop);
        let fetch = fetch.clone();
        clients.push(std::thread::spawn(move || -> u64 {
            let mut c = NetClient::connect(&addr).unwrap();
            let probe = Tensor::from_f32(vec![1, 8], vec![0.25; 8]).unwrap();
            let mut n = 0u64;
            while !stop.load(Ordering::SeqCst) {
                c.predict("mlp", None, &[("x", probe.clone())], &[&fetch])
                    .expect("wire predict failed during hot-swap");
                n += 1;
            }
            n
        }));
    }
    std::thread::sleep(Duration::from_millis(50));
    mgr.deploy("mlp", 2, &spec2).unwrap();
    std::thread::sleep(Duration::from_millis(50));
    stop.store(true, Ordering::SeqCst);
    let total: u64 = clients.into_iter().map(|t| t.join().unwrap()).sum();
    assert!(total > 0);

    // v2 answers differ from v1 (different seed) — "latest" now routes to it.
    let out_v2 = client.predict("mlp", None, &[("x", probe.clone())], &[&fetch]).unwrap();
    let out_pin1 = client.predict("mlp", Some(1), &[("x", probe)], &[&fetch]);
    assert_eq!(out_pin1.unwrap_err().code, rustflow::error::Code::NotFound);
    assert_ne!(out_v2[0].as_f32().unwrap(), wire_out[0].as_f32().unwrap());

    // Stats travel the wire as JSON, and the unified registry dump rides
    // along: per-version serving counters plus the front end's own
    // per-message-type wire counters.
    let json = client.stats_json().unwrap();
    assert!(json.contains("\"model\":\"mlp\""), "{json}");
    assert!(json.contains("\"state\":\"live\""), "{json}");
    let parsed = Json::parse(&json).unwrap();
    assert_eq!(parsed.get("shutting_down").and_then(Json::as_bool), Some(false));
    let metrics = parsed.get("metrics").expect("stats dump carries the registry");
    let frames_in = metrics.get("wire/PREDICT/frames_in").and_then(Json::as_i64).unwrap();
    assert!(frames_in > 0, "{json}");
    assert!(metrics.get("wire/bytes_out_total").and_then(Json::as_i64).unwrap() > 0, "{json}");
    assert!(metrics.get("serving/mlp/v2/requests").and_then(Json::as_i64).unwrap() > 0, "{json}");

    server.shutdown();
    // A connection established before shutdown still gets real stats —
    // flagged as shutting down — not an empty placeholder.
    let json = client.stats_json().unwrap();
    let parsed = Json::parse(&json).unwrap();
    assert_eq!(parsed.get("shutting_down").and_then(Json::as_bool), Some(true));
    assert!(parsed.get("metrics").is_some(), "{json}");
    // After shutdown, new connections are refused or die on first read.
    if let Ok(mut c) = NetClient::connect(&addr) {
        assert!(c.ping().is_err());
    }
    mgr.shutdown();
}

#[test]
fn warming_version_never_steals_latest_traffic() {
    // A deploy whose warmup takes a while must leave "latest" routed to
    // the old version for its whole duration: run a slow-warmup deploy
    // from a second thread and assert every concurrent "latest" answer
    // still comes from v1 until the deploy returns.
    let scale_session = |k: f32| -> (Arc<Session>, String) {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let c = b.scalar(k);
        let y = b.mul(x, c);
        let fetch = format!("{}:0", b.graph.node(y.node).name);
        (Arc::new(Session::new(b.into_graph(), SessionOptions::default())), fetch)
    };
    let (s1, fetch) = scale_session(1.0);
    let (s2, _) = scale_session(3.0);
    let mgr = Arc::new(ModelManager::new(ManagerOptions::default()));
    mgr.deploy_session("m", 1, s1, &[]).unwrap();

    // 64 warmup requests keep v2 in `warming` for a measurable window.
    let warmup: Vec<WarmupRequest> = (0..64)
        .map(|i| WarmupRequest {
            feeds: vec![("x".to_string(), col(&[i as f32]))],
            fetches: vec![fetch.clone()],
        })
        .collect();
    let deploy_done = Arc::new(AtomicBool::new(false));
    let deployer = {
        let mgr = Arc::clone(&mgr);
        let done = Arc::clone(&deploy_done);
        std::thread::spawn(move || {
            mgr.deploy_session("m", 2, s2, &warmup).unwrap();
            done.store(true, Ordering::SeqCst);
        })
    };
    let mut saw_v1_during_warmup = false;
    loop {
        let before = deploy_done.load(Ordering::SeqCst);
        let out = mgr.run("m", None, &[("x", col(&[5.0]))], &[&fetch]).unwrap();
        let y = out[0].as_f32().unwrap()[0];
        if before {
            assert_eq!(y, 15.0, "after deploy returned, latest must be v2");
            break;
        }
        assert!(y == 5.0 || y == 15.0, "unexpected answer {y}");
        if y == 5.0 {
            saw_v1_during_warmup = true;
        }
    }
    deployer.join().unwrap();
    assert!(saw_v1_during_warmup, "v1 should have answered while v2 warmed");
}
