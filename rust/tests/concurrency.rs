//! Concurrent-execution integration tests: many client threads driving
//! one Session (§7 Fig 9's concurrent-steps idiom) and the serving layer
//! built on top of it. The invariant under test is per-step isolation —
//! every Run gets its own step state and per-step rendezvous, so feeds
//! and fetches never leak between concurrent steps sharing one cached
//! executable.

use rustflow::serving::{BatchConfig, ModelServer};
use rustflow::{models, DType, GraphBuilder, Session, SessionOptions, Tensor};
use std::sync::Arc;
use std::time::Duration;

#[test]
fn concurrent_runs_share_one_cached_step_without_cross_talk() {
    // y = x * 3, one signature, hammered from 8 threads.
    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", DType::F32).unwrap();
    let three = b.scalar(3.0);
    let y = b.mul(x, three);
    let yname = format!("{}:0", b.graph.node(y.node).name);
    let sess = Arc::new(Session::new(b.into_graph(), SessionOptions::default()));

    // Warm the cache so every thread hits the same compiled step.
    sess.run(&[("x", Tensor::scalar_f32(1.0))], &[&yname], &[]).unwrap();

    let mut handles = Vec::new();
    for t in 0..8u32 {
        let sess = Arc::clone(&sess);
        let yname = yname.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..100u32 {
                let v = (t * 1000 + i) as f32;
                let out = sess.run(&[("x", Tensor::scalar_f32(v))], &[&yname], &[]).unwrap();
                let got = out[0].scalar_value_f32().unwrap();
                assert_eq!(got, 3.0 * v, "thread {t} iteration {i}: fed {v}, got {got}");
            }
        }));
    }
    for h in handles {
        h.join().expect("worker thread panicked");
    }
    // The signature was compiled once and reused (cache hit path).
    assert!(sess.step_stats(&["x"], &[&yname], &[]).is_some());
}

#[test]
fn concurrent_runs_with_shared_variable_state() {
    // Concurrent increments of one variable: per-step isolation must not
    // extend to *resources* — all steps see the same counter, and every
    // increment lands (AssignAdd holds the variable lock per apply).
    let mut b = GraphBuilder::new();
    let v = b.variable("counter", Tensor::scalar_f32(0.0)).unwrap();
    let one = b.scalar(1.0);
    let inc = b.assign_add(v, one).unwrap();
    let init_name = b.graph.node(b.init_ops[0]).name.clone();
    let inc_name = b.graph.node(inc).name.clone();
    let sess = Arc::new(Session::new(b.into_graph(), SessionOptions::default()));
    sess.run_targets(&[&init_name]).unwrap();

    let mut handles = Vec::new();
    for _ in 0..4 {
        let sess = Arc::clone(&sess);
        let inc_name = inc_name.clone();
        handles.push(std::thread::spawn(move || {
            for _ in 0..25 {
                sess.run_targets(&[&inc_name]).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().expect("worker thread panicked");
    }
    let out = sess.run(&[], &["counter"], &[]).unwrap();
    assert_eq!(out[0].scalar_value_f32().unwrap(), 100.0);
}

#[test]
fn served_batched_results_match_direct_session_runs() {
    // An MLP served with aggressive batching must return, per request,
    // exactly what a direct unbatched Session::run returns.
    let (dim, hidden, classes) = (16usize, 32usize, 4usize);
    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", DType::F32).unwrap();
    let (logits, _vars) = models::mlp(&mut b, x, &[dim, hidden, classes], 11).unwrap();
    let fetch = format!("{}:0", b.graph.node(logits.node).name);
    let inits: Vec<String> =
        b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
    let session = Arc::new(Session::new(b.into_graph(), SessionOptions::default()));
    session.run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();

    let server = Arc::new(ModelServer::with_session(
        Arc::clone(&session),
        BatchConfig {
            max_batch_size: 16,
            max_batch_delay: Duration::from_millis(5),
            queue_capacity: 256,
            ..BatchConfig::default()
        },
    ));

    // Deterministic per-request inputs with varying row counts 1..=3.
    let make_input = move |c: usize, i: usize| -> Tensor {
        let rows = 1 + (c + i) % 3;
        let data: Vec<f32> =
            (0..rows * dim).map(|k| ((c * 31 + i * 7 + k) % 23) as f32 * 0.05).collect();
        Tensor::from_f32(vec![rows, dim], data).unwrap()
    };

    let mut handles = Vec::new();
    for c in 0..6usize {
        let server = Arc::clone(&server);
        let session = Arc::clone(&session);
        let fetch = fetch.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..20usize {
                let input = make_input(c, i);
                let served =
                    server.run(&[("x", input.clone())], &[&fetch]).unwrap();
                let direct = session.run(&[("x", input)], &[&fetch]).unwrap();
                assert_eq!(served.len(), 1);
                assert_eq!(served[0].shape(), direct[0].shape(), "client {c} request {i}");
                assert!(
                    served[0].allclose(&direct[0], 1e-5, 1e-5),
                    "client {c} request {i}: served result diverged from direct run"
                );
            }
        }));
    }
    for h in handles {
        h.join().expect("client thread panicked");
    }
    let stats = server.stats();
    assert_eq!(stats.requests, 120);
    assert!(stats.batches >= 1);
    server.shutdown();
}

#[test]
fn concurrent_planned_steps_never_share_an_arena() {
    // Memory planning on (the default), many concurrent steps of one
    // cached signature. The arena pool asserts at checkout that no arena
    // serves two in-flight steps at once — a violation panics the step
    // and fails this test — and every result must match the sequential
    // expectation (shared arenas would corrupt intermediates).
    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", DType::F32).unwrap();
    let c = b.scalar(0.5);
    let mut h = x;
    for _ in 0..8 {
        let m = b.mul(h, c);
        h = b.tanh(m);
    }
    let name = format!("{}:0", b.graph.node(h.node).name);
    let sess = Arc::new(Session::new(
        b.into_graph(),
        SessionOptions { enable_elementwise_fusion: false, ..Default::default() },
    ));
    let expect_of = |v: f32| -> f32 {
        let mut h = v;
        for _ in 0..8 {
            h = (h * 0.5).tanh();
        }
        h
    };
    sess.run(&[("x", Tensor::fill_f32(vec![64], 1.0))], &[&name], &[]).unwrap();

    let mut handles = Vec::new();
    for t in 0..8u32 {
        let sess = Arc::clone(&sess);
        let name = name.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..50u32 {
                let v = ((t * 50 + i) % 17) as f32 * 0.1;
                let out = sess
                    .run(&[("x", Tensor::fill_f32(vec![64], v))], &[&name], &[])
                    .unwrap();
                let got = out[0].as_f32().unwrap();
                let want = expect_of(v);
                assert!(
                    got.iter().all(|&g| g == want),
                    "thread {t} iteration {i}: corrupted step output"
                );
            }
        }));
    }
    for h in handles {
        h.join().expect("worker thread panicked");
    }
    let reports = sess.memory_stats(&["x"], &[&name], &[]).expect("cached step");
    let r = &reports[0];
    assert_eq!(r.runtime.checkouts, 401, "one arena checkout per run");
    assert!(
        r.runtime.arenas_created >= 1,
        "pool must have built at least one arena: {:?}",
        r.runtime
    );
    // Concurrency bursts are served by distinct arenas, never by handing
    // one arena to two steps (that would have panicked above); the pool
    // grows only as far as the burst needed.
    assert!(r.runtime.arenas_created <= r.runtime.checkouts);
}
