//! Error type shared by every RustFlow subsystem.
//!
//! Mirrors TensorFlow's `tensorflow::Status`: a small closed set of codes
//! plus a human-readable message. The distributed runtime ships these codes
//! over the wire, so they must stay stable (see `distributed::proto`).

/// Status codes, a subset of TF's `error::Code` that this implementation
/// actually produces.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Code {
    InvalidArgument,
    NotFound,
    AlreadyExists,
    FailedPrecondition,
    OutOfRange,
    Unimplemented,
    Internal,
    Unavailable,
    Aborted,
    Cancelled,
    DeadlineExceeded,
    ResourceExhausted,
}

impl Code {
    pub fn as_u8(self) -> u8 {
        match self {
            Code::InvalidArgument => 0,
            Code::NotFound => 1,
            Code::AlreadyExists => 2,
            Code::FailedPrecondition => 3,
            Code::OutOfRange => 4,
            Code::Unimplemented => 5,
            Code::Internal => 6,
            Code::Unavailable => 7,
            Code::Aborted => 8,
            Code::Cancelled => 9,
            Code::DeadlineExceeded => 10,
            Code::ResourceExhausted => 11,
        }
    }

    pub fn from_u8(v: u8) -> Code {
        match v {
            0 => Code::InvalidArgument,
            1 => Code::NotFound,
            2 => Code::AlreadyExists,
            3 => Code::FailedPrecondition,
            4 => Code::OutOfRange,
            5 => Code::Unimplemented,
            7 => Code::Unavailable,
            8 => Code::Aborted,
            9 => Code::Cancelled,
            10 => Code::DeadlineExceeded,
            11 => Code::ResourceExhausted,
            _ => Code::Internal,
        }
    }
}

/// The error type used throughout RustFlow.
#[derive(Debug, Clone)]
pub struct Status {
    pub code: Code,
    pub message: String,
}

impl std::fmt::Display for Status {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:?}: {}", self.code, self.message)
    }
}

impl std::error::Error for Status {}

impl Status {
    pub fn new(code: Code, message: impl Into<String>) -> Self {
        Status { code, message: message.into() }
    }
    pub fn invalid_argument(m: impl Into<String>) -> Self {
        Status::new(Code::InvalidArgument, m)
    }
    pub fn not_found(m: impl Into<String>) -> Self {
        Status::new(Code::NotFound, m)
    }
    pub fn already_exists(m: impl Into<String>) -> Self {
        Status::new(Code::AlreadyExists, m)
    }
    pub fn failed_precondition(m: impl Into<String>) -> Self {
        Status::new(Code::FailedPrecondition, m)
    }
    pub fn out_of_range(m: impl Into<String>) -> Self {
        Status::new(Code::OutOfRange, m)
    }
    pub fn unimplemented(m: impl Into<String>) -> Self {
        Status::new(Code::Unimplemented, m)
    }
    pub fn internal(m: impl Into<String>) -> Self {
        Status::new(Code::Internal, m)
    }
    pub fn unavailable(m: impl Into<String>) -> Self {
        Status::new(Code::Unavailable, m)
    }
    pub fn aborted(m: impl Into<String>) -> Self {
        Status::new(Code::Aborted, m)
    }
    pub fn cancelled(m: impl Into<String>) -> Self {
        Status::new(Code::Cancelled, m)
    }
    pub fn resource_exhausted(m: impl Into<String>) -> Self {
        Status::new(Code::ResourceExhausted, m)
    }
}

impl From<std::io::Error> for Status {
    fn from(e: std::io::Error) -> Self {
        Status::unavailable(format!("io error: {e}"))
    }
}

pub type Result<T> = std::result::Result<T, Status>;

/// `bail!`-style helper macros.
#[macro_export]
macro_rules! rf_bail {
    ($code:ident, $($arg:tt)*) => {
        return Err($crate::error::Status::new(
            $crate::error::Code::$code,
            format!($($arg)*),
        ))
    };
}

#[macro_export]
macro_rules! rf_ensure {
    ($cond:expr, $code:ident, $($arg:tt)*) => {
        if !($cond) {
            $crate::rf_bail!($code, $($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn code_roundtrip() {
        for c in [
            Code::InvalidArgument,
            Code::NotFound,
            Code::AlreadyExists,
            Code::FailedPrecondition,
            Code::OutOfRange,
            Code::Unimplemented,
            Code::Internal,
            Code::Unavailable,
            Code::Aborted,
            Code::Cancelled,
            Code::DeadlineExceeded,
            Code::ResourceExhausted,
        ] {
            assert_eq!(Code::from_u8(c.as_u8()), c);
        }
    }

    #[test]
    fn display_contains_code_and_message() {
        let s = Status::invalid_argument("bad shape");
        let d = format!("{s}");
        assert!(d.contains("InvalidArgument"));
        assert!(d.contains("bad shape"));
    }

    fn ensure_helper(x: i32) -> Result<i32> {
        rf_ensure!(x > 0, InvalidArgument, "x must be positive, got {}", x);
        Ok(x)
    }

    #[test]
    fn ensure_macro() {
        assert!(ensure_helper(3).is_ok());
        let e = ensure_helper(-1).unwrap_err();
        assert_eq!(e.code, Code::InvalidArgument);
        assert!(e.message.contains("-1"));
    }
}
