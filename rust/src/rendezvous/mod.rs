//! The Rendezvous: the key-indexed tensor mailbox Send/Recv pairs use to
//! transfer data across devices (§3.2.2) and, in the distributed runtime,
//! across machines (§3.3). Feeds are also delivered through a
//! specially-initialized rendezvous (§4.2: "a Rendezvous object used for
//! the Run call").
//!
//! Keys name a logical tensor transfer once per step:
//! `src_device;dst_device;tensor_name;frame_iter` — producing the §3.2.2
//! guarantee that a tensor crosses a device pair once per (step,
//! frame-iteration).

use crate::error::{Result, Status};
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

pub type RecvDone = Box<dyn FnOnce(Result<Tensor>) + Send>;

/// Compose the canonical rendezvous key.
pub fn make_key(src_device: &str, dst_device: &str, tensor_name: &str, frame_iter: &str) -> String {
    format!("{src_device};{dst_device};{tensor_name};{frame_iter}")
}

/// Abstract rendezvous: local for intra-process transfers, remote-backed
/// in the distributed worker.
pub trait Rendezvous: Send + Sync {
    /// Deposit a tensor. Each key may be sent at most once per step.
    fn send(&self, key: &str, value: Tensor) -> Result<()>;
    /// Asynchronously receive; `done` fires when the tensor arrives (§5.3
    /// Receive is the canonical asynchronous kernel).
    fn recv_async(&self, key: &str, done: RecvDone);
    /// Abort every pending and future operation with `status` — the §3.3
    /// failure path ("an error in a communication between a Send and
    /// Receive node pair" cancels the step).
    fn abort(&self, status: Status);
    /// Synchronous probe (used for pre-populated feeds).
    fn try_recv(&self, key: &str) -> Option<Tensor>;
}

enum Slot {
    /// Value arrived, no receiver yet.
    Value(Tensor),
    /// Receivers arrived, no value yet.
    Waiters(Vec<RecvDone>),
}

#[derive(Default)]
struct LocalState {
    slots: HashMap<String, Slot>,
    aborted: Option<Status>,
}

/// In-process rendezvous; one per step (plus one long-lived instance per
/// worker for cross-step distributed traffic).
#[derive(Default)]
pub struct LocalRendezvous {
    state: Mutex<LocalState>,
}

impl LocalRendezvous {
    pub fn new() -> Arc<LocalRendezvous> {
        Arc::new(LocalRendezvous::default())
    }

    /// Number of undelivered tensors parked in the table (test/debug).
    pub fn pending_values(&self) -> usize {
        self.state
            .lock()
            .unwrap()
            .slots
            .values()
            .filter(|s| matches!(s, Slot::Value(_)))
            .count()
    }
}

impl Rendezvous for LocalRendezvous {
    fn send(&self, key: &str, value: Tensor) -> Result<()> {
        let waiters = {
            let mut st = self.state.lock().unwrap();
            if let Some(status) = &st.aborted {
                return Err(status.clone());
            }
            match st.slots.remove(key) {
                None => {
                    st.slots.insert(key.to_string(), Slot::Value(value));
                    return Ok(());
                }
                Some(Slot::Value(_)) => {
                    // Restore and fail: duplicate send is a graph bug.
                    return Err(Status::internal(format!("duplicate send for key {key:?}")));
                }
                Some(Slot::Waiters(w)) => (w, value),
            }
        };
        let (waiters, value) = waiters;
        let mut it = waiters.into_iter();
        if let Some(first) = it.next() {
            for w in it {
                w(Ok(value.clone()));
            }
            first(Ok(value));
        }
        Ok(())
    }

    fn recv_async(&self, key: &str, done: RecvDone) {
        let value = {
            let mut st = self.state.lock().unwrap();
            if let Some(status) = &st.aborted {
                let status = status.clone();
                drop(st);
                done(Err(status));
                return;
            }
            match st.slots.remove(key) {
                Some(Slot::Value(v)) => v,
                Some(Slot::Waiters(mut w)) => {
                    w.push(done);
                    st.slots.insert(key.to_string(), Slot::Waiters(w));
                    return;
                }
                None => {
                    st.slots.insert(key.to_string(), Slot::Waiters(vec![done]));
                    return;
                }
            }
        };
        done(Ok(value));
    }

    fn abort(&self, status: Status) {
        let waiters: Vec<RecvDone> = {
            let mut st = self.state.lock().unwrap();
            st.aborted = Some(status.clone());
            st.slots
                .drain()
                .filter_map(|(_, slot)| match slot {
                    Slot::Waiters(w) => Some(w),
                    Slot::Value(_) => None,
                })
                .flatten()
                .collect()
        };
        for w in waiters {
            w(Err(status.clone()));
        }
    }

    fn try_recv(&self, key: &str) -> Option<Tensor> {
        let mut st = self.state.lock().unwrap();
        match st.slots.remove(key) {
            Some(Slot::Value(v)) => Some(v),
            Some(other) => {
                st.slots.insert(key.to_string(), other);
                None
            }
            None => None,
        }
    }
}

/// Blocking receive helper for host-side code and tests.
pub fn recv_blocking(r: &dyn Rendezvous, key: &str) -> Result<Tensor> {
    let (tx, rx) = std::sync::mpsc::channel();
    r.recv_async(key, Box::new(move |res| {
        let _ = tx.send(res);
    }));
    rx.recv().map_err(|_| Status::internal("rendezvous dropped callback"))?
}

/// Blocking receive with a deadline. `DeadlineExceeded` when nothing
/// arrives within `timeout`; the registered waiter stays parked in the
/// rendezvous, so a later `send` (or `abort`) still consumes the key —
/// callers that give up should abort the rendezvous if the key must not
/// outlive them (the parameter-server sync barrier does exactly that).
pub fn recv_blocking_timeout(
    r: &dyn Rendezvous,
    key: &str,
    timeout: std::time::Duration,
) -> Result<Tensor> {
    let (tx, rx) = std::sync::mpsc::channel();
    r.recv_async(key, Box::new(move |res| {
        let _ = tx.send(res);
    }));
    match rx.recv_timeout(timeout) {
        Ok(res) => res,
        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => Err(Status::new(
            crate::error::Code::DeadlineExceeded,
            format!("rendezvous recv {key:?} timed out after {timeout:?}"),
        )),
        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
            Err(Status::internal("rendezvous dropped callback"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn send_then_recv() {
        let r = LocalRendezvous::new();
        r.send("k", Tensor::scalar_f32(5.0)).unwrap();
        let t = recv_blocking(&*r, "k").unwrap();
        assert_eq!(t.scalar_value_f32().unwrap(), 5.0);
    }

    #[test]
    fn recv_then_send() {
        let r = LocalRendezvous::new();
        let fired = Arc::new(AtomicUsize::new(0));
        let f = Arc::clone(&fired);
        r.recv_async(
            "k",
            Box::new(move |res| {
                assert_eq!(res.unwrap().scalar_value_f32().unwrap(), 9.0);
                f.fetch_add(1, Ordering::SeqCst);
            }),
        );
        assert_eq!(fired.load(Ordering::SeqCst), 0);
        r.send("k", Tensor::scalar_f32(9.0)).unwrap();
        assert_eq!(fired.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn duplicate_send_rejected() {
        let r = LocalRendezvous::new();
        r.send("k", Tensor::scalar_f32(1.0)).unwrap();
        assert!(r.send("k", Tensor::scalar_f32(2.0)).is_err());
    }

    #[test]
    fn keys_are_independent() {
        let r = LocalRendezvous::new();
        r.send("a", Tensor::scalar_f32(1.0)).unwrap();
        r.send("b", Tensor::scalar_f32(2.0)).unwrap();
        assert_eq!(recv_blocking(&*r, "b").unwrap().scalar_value_f32().unwrap(), 2.0);
        assert_eq!(recv_blocking(&*r, "a").unwrap().scalar_value_f32().unwrap(), 1.0);
    }

    #[test]
    fn abort_fails_pending_and_future() {
        let r = LocalRendezvous::new();
        let fired = Arc::new(AtomicUsize::new(0));
        let f = Arc::clone(&fired);
        r.recv_async(
            "k",
            Box::new(move |res| {
                assert_eq!(res.unwrap_err().code, crate::error::Code::Aborted);
                f.fetch_add(1, Ordering::SeqCst);
            }),
        );
        r.abort(Status::aborted("worker died"));
        assert_eq!(fired.load(Ordering::SeqCst), 1);
        // Future ops also fail.
        assert!(r.send("x", Tensor::scalar_f32(0.0)).is_err());
        assert!(recv_blocking(&*r, "y").is_err());
    }

    #[test]
    fn try_recv_nonblocking() {
        let r = LocalRendezvous::new();
        assert!(r.try_recv("k").is_none());
        r.send("k", Tensor::scalar_f32(3.0)).unwrap();
        assert!(r.try_recv("k").is_some());
        assert!(r.try_recv("k").is_none()); // consumed
    }

    #[test]
    fn cross_thread_handoff() {
        let r = LocalRendezvous::new();
        let r2 = Arc::clone(&r);
        let h = std::thread::spawn(move || {
            for i in 0..100 {
                r2.send(&format!("k{i}"), Tensor::scalar_f32(i as f32)).unwrap();
            }
        });
        for i in 0..100 {
            let t = recv_blocking(&*r, &format!("k{i}")).unwrap();
            assert_eq!(t.scalar_value_f32().unwrap(), i as f32);
        }
        h.join().unwrap();
    }

    #[test]
    fn recv_timeout_then_late_send_still_delivers() {
        let r = LocalRendezvous::new();
        let e = recv_blocking_timeout(&*r, "k", std::time::Duration::from_millis(10)).unwrap_err();
        assert_eq!(e.code, crate::error::Code::DeadlineExceeded);
        // The waiter stayed parked: the late send is consumed by it, so a
        // fresh recv on the same key blocks again (times out) rather than
        // seeing the value twice.
        r.send("k", Tensor::scalar_f32(1.0)).unwrap();
        let e2 = recv_blocking_timeout(&*r, "k", std::time::Duration::from_millis(10)).unwrap_err();
        assert_eq!(e2.code, crate::error::Code::DeadlineExceeded);
    }

    #[test]
    fn recv_timeout_immediate_value() {
        let r = LocalRendezvous::new();
        r.send("k", Tensor::scalar_f32(4.0)).unwrap();
        let t = recv_blocking_timeout(&*r, "k", std::time::Duration::from_secs(5)).unwrap();
        assert_eq!(t.scalar_value_f32().unwrap(), 4.0);
    }

    #[test]
    fn key_format() {
        assert_eq!(
            make_key("/job:a/task:0/device:cpu:0", "/job:a/task:0/device:cpu:1", "x:0", "0:0"),
            "/job:a/task:0/device:cpu:0;/job:a/task:0/device:cpu:1;x:0;0:0"
        );
    }
}
