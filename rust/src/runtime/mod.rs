//! The PJRT runtime bridge: loads AOT artifacts (`artifacts/*.hlo.txt`,
//! produced once by `python/compile/aot.py`) and executes them from the L3
//! hot path. Exposed to graphs as the `XlaCall` op — the §5.4 pattern
//! ("many of our kernel implementations are relatively thin wrappers
//! around … optimized libraries"; here the optimized library is an XLA
//! executable compiled from the JAX/Pallas L2+L1 program).
//!
//! The bridge is gated behind the off-by-default `xla` cargo feature so
//! the crate builds in offline environments with no PJRT shared library:
//! without the feature, [`load_artifact`] and the `XlaCall` kernel
//! compile against a stub that fails at *run* time with `Unavailable`
//! (graphs still build, place, and partition; only execution of XlaCall
//! nodes needs the real bridge). Enabling `xla` requires the vendored
//! `xla` (xla_extension) crate — see the comment in rust/Cargo.toml.
//!
//! Interchange format is HLO *text* (not serialized protos) — jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids. See /opt/xla-example/README.md.

use std::path::PathBuf;

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::{literal_to_tensor, load_artifact, tensor_to_literal, XlaExecutable};
#[cfg(feature = "xla")]
pub(crate) use pjrt::register_kernels;

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::{load_artifact, XlaExecutable};
#[cfg(not(feature = "xla"))]
pub(crate) use stub::register_kernels;

/// Where `make artifacts` puts the compiled programs.
pub fn artifact_dir() -> PathBuf {
    if let Ok(d) = std::env::var("RUSTFLOW_ARTIFACTS") {
        return PathBuf::from(d);
    }
    // repo root/artifacts, resolved relative to the executable's cwd.
    PathBuf::from("artifacts")
}
