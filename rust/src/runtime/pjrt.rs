//! The real PJRT bridge (`--features xla`): compiles HLO-text artifacts
//! on a PJRT CPU client and executes them with rustflow tensors in/out.

use crate::error::{Result, Status};
use crate::kernels::{Kernel, KernelRegistry};
use crate::tensor::{Shape, Tensor, TensorData};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::LazyLock as Lazy;
use std::sync::{Arc, Mutex};

/// A compiled XLA executable plus conversion helpers.
pub struct XlaExecutable {
    exe: xla::PjRtLoadedExecutable,
    pub path: PathBuf,
}

// xla_extension's PJRT CPU client is thread-safe; the crate just doesn't
// mark the wrappers Send/Sync.
unsafe impl Send for XlaExecutable {}
unsafe impl Sync for XlaExecutable {}

struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, Arc<XlaExecutable>>>,
}

unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

static RUNTIME: Lazy<std::result::Result<Runtime, String>> = Lazy::new(|| {
    let client = xla::PjRtClient::cpu().map_err(|e| format!("PJRT cpu client: {e}"))?;
    Ok(Runtime { client, cache: Mutex::new(HashMap::new()) })
});

fn runtime() -> Result<&'static Runtime> {
    RUNTIME.as_ref().map_err(|e| Status::unavailable(e.clone()))
}

/// Load (or fetch from cache) an HLO-text artifact and compile it on the
/// PJRT CPU client. Compilation happens once per path per process.
pub fn load_artifact(path: &Path) -> Result<Arc<XlaExecutable>> {
    let rt = runtime()?;
    if let Some(exe) = rt.cache.lock().unwrap().get(path) {
        return Ok(Arc::clone(exe));
    }
    if !path.exists() {
        return Err(Status::not_found(format!(
            "artifact {path:?} not found — run `make artifacts` first"
        )));
    }
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| Status::invalid_argument("non-utf8 path"))?,
    )
    .map_err(|e| Status::invalid_argument(format!("parse {path:?}: {e}")))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = rt
        .client
        .compile(&comp)
        .map_err(|e| Status::internal(format!("compile {path:?}: {e}")))?;
    let wrapped = Arc::new(XlaExecutable { exe, path: path.to_path_buf() });
    rt.cache.lock().unwrap().insert(path.to_path_buf(), Arc::clone(&wrapped));
    Ok(wrapped)
}

impl XlaExecutable {
    /// Execute with rustflow tensors in/out. The artifact must be lowered
    /// with `return_tuple=True` (aot.py does), so outputs decompose.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(tensor_to_literal).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Status::internal(format!("execute {:?}: {e}", self.path)))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| Status::internal(format!("readback: {e}")))?;
        let parts = out
            .to_tuple()
            .map_err(|e| Status::internal(format!("untuple: {e}")))?;
        parts.iter().map(literal_to_tensor).collect()
    }
}

pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().dims().iter().map(|&d| d as i64).collect();
    let lit = match t.data() {
        TensorData::F32(v) => xla::Literal::vec1(v),
        TensorData::F64(v) => xla::Literal::vec1(v),
        TensorData::I32(v) => xla::Literal::vec1(v),
        TensorData::I64(v) => xla::Literal::vec1(v),
        other => {
            return Err(Status::unimplemented(format!(
                "XlaCall input dtype {}",
                other.dtype()
            )))
        }
    };
    lit.reshape(&dims).map_err(|e| Status::internal(format!("literal reshape: {e}")))
}

pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l
        .array_shape()
        .map_err(|e| Status::internal(format!("literal shape: {e}")))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let ty = l.ty().map_err(|e| Status::internal(format!("literal type: {e}")))?;
    let data = match ty {
        xla::ElementType::F32 => TensorData::F32(
            l.to_vec::<f32>().map_err(|e| Status::internal(format!("to_vec: {e}")))?,
        ),
        xla::ElementType::F64 => TensorData::F64(
            l.to_vec::<f64>().map_err(|e| Status::internal(format!("to_vec: {e}")))?,
        ),
        xla::ElementType::S32 => TensorData::I32(
            l.to_vec::<i32>().map_err(|e| Status::internal(format!("to_vec: {e}")))?,
        ),
        xla::ElementType::S64 => TensorData::I64(
            l.to_vec::<i64>().map_err(|e| Status::internal(format!("to_vec: {e}")))?,
        ),
        xla::ElementType::Pred => {
            let v = l.to_vec::<u8>().map_err(|e| Status::internal(format!("to_vec: {e}")))?;
            TensorData::Bool(v.into_iter().map(|b| b != 0).collect())
        }
        other => {
            return Err(Status::unimplemented(format!("XlaCall output type {other:?}")))
        }
    };
    Tensor::new(Shape(dims), data)
}

/// Register the XlaCall kernel: attrs `path` (artifact file) and
/// `out_types` (output dtypes, for graph metadata).
pub(crate) fn register_kernels(r: &mut KernelRegistry) {
    r.add("XlaCall", |node| {
        let path = PathBuf::from(node.attr("path")?.as_str()?);
        // Compile lazily on first execution (kernel instantiation happens
        // at graph-compile time, possibly before artifacts are built).
        let exe: Mutex<Option<Arc<XlaExecutable>>> = Mutex::new(None);
        Ok(Kernel::Sync(Box::new(move |ctx| {
            let exe = {
                let mut guard = exe.lock().unwrap();
                if guard.is_none() {
                    *guard = Some(load_artifact(&path)?);
                }
                Arc::clone(guard.as_ref().unwrap())
            };
            exe.run(&ctx.inputs)
        })))
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_literal_roundtrip() {
        let t = Tensor::from_f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let l = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&l).unwrap();
        assert_eq!(back, t);
        let ti = Tensor::from_i32(vec![2], vec![7, -1]).unwrap();
        let back = literal_to_tensor(&tensor_to_literal(&ti).unwrap()).unwrap();
        assert_eq!(back, ti);
    }
}
