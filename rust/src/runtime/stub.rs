//! The no-PJRT stub (default build, no `xla` feature): the same API
//! surface as the real bridge, failing with `Unavailable` at execution
//! time. Graph construction, placement, and partitioning of `XlaCall`
//! nodes all work; only running one needs the real runtime.

use crate::error::{Result, Status};
use crate::kernels::{Kernel, KernelRegistry};
use crate::tensor::Tensor;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn unavailable(what: &str) -> Status {
    Status::unavailable(format!(
        "{what} requires the PJRT bridge: rebuild with `--features xla` \
         (needs the vendored xla_extension crate)"
    ))
}

/// Stub executable: holds the artifact path, cannot run.
pub struct XlaExecutable {
    pub path: PathBuf,
}

impl XlaExecutable {
    pub fn run(&self, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        Err(unavailable(&format!("executing artifact {:?}", self.path)))
    }
}

/// Mirrors the real loader's error contract: a missing file is still
/// `NotFound` (so "run `make artifacts`" diagnostics stay accurate);
/// an existing artifact fails with `Unavailable` because nothing here
/// can compile it.
pub fn load_artifact(path: &Path) -> Result<Arc<XlaExecutable>> {
    if !path.exists() {
        return Err(Status::not_found(format!(
            "artifact {path:?} not found — run `make artifacts` first"
        )));
    }
    Err(unavailable(&format!("compiling artifact {path:?}")))
}

/// XlaCall still registers so graphs containing it build and place; the
/// kernel fails at execution time.
pub(crate) fn register_kernels(r: &mut KernelRegistry) {
    r.add("XlaCall", |node| {
        let path = PathBuf::from(node.attr("path")?.as_str()?);
        Ok(Kernel::Sync(Box::new(move |_ctx| {
            Err(unavailable(&format!("XlaCall({path:?})")))
        })))
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifact_is_not_found() {
        let e = match load_artifact(Path::new("/nonexistent/foo.hlo.txt")) {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert_eq!(e.code, crate::error::Code::NotFound);
    }

    #[test]
    fn existing_file_is_unavailable_without_pjrt() {
        let p = std::env::temp_dir().join(format!("rf-stub-{}.hlo.txt", std::process::id()));
        std::fs::write(&p, "HloModule m").unwrap();
        let e = load_artifact(&p).unwrap_err();
        std::fs::remove_file(&p).ok();
        assert_eq!(e.code, crate::error::Code::Unavailable);
        assert!(e.message.contains("--features xla"));
    }
}
