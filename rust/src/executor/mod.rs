//! The dataflow executor.
//!
//! §3.1 single-device execution: "we keep track of a count per node of the
//! number of dependencies of that node that have not yet been executed.
//! Once this count drops to zero, the node is eligible for execution and
//! is added to a ready queue … delegating execution of the kernel for a
//! node to the device object."
//!
//! §4.4 control flow: "the TensorFlow runtime implements a notion of tags
//! and frames conceptually similar to the MIT Tagged-Token machine. Each
//! iteration of a loop is uniquely identified by a tag, and its execution
//! state is represented by a frame. An input can enter an iteration
//! whenever it becomes available; thus, multiple iterations can be
//! executed concurrently." Executions are tagged with the full frame path
//! `[(frame, iter), …]`; Switch routes live/dead tokens, Merge fires on
//! its first live input, Enter/Exit/NextIteration retag deliveries into
//! child/parent/next-iteration state, and values captured from ancestor
//! frames are delivered as loop invariants.
//!
//! §5.3 asynchronous kernels (Recv, Enqueue, Dequeue, MutexAcquire)
//! complete via continuation so blocked I/O never parks a pool thread.

pub mod compile;

pub use compile::{CompiledGraph, CompiledNode, FrameDef, NodeKind};

use crate::error::{Result, Status};
use crate::graph::NodeId;
use crate::kernels::{DoneFn, Kernel, KernelContext, StepState};
use crate::rendezvous::Rendezvous;
use crate::resources::ResourceMgr;
use crate::tensor::Tensor;
use crate::tracing_tools::TraceCollector;
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

/// A live-or-dead token (§4.4: untaken Switch branches propagate dead
/// tokens so downstream subgraphs are skipped).
#[derive(Debug, Clone)]
pub enum Entry {
    Live(Tensor),
    Dead,
}

impl Entry {
    pub fn is_dead(&self) -> bool {
        matches!(self, Entry::Dead)
    }
}

/// Execution tag: the frame path, one (frame def, iteration) per nesting
/// level. Root graph = empty path.
pub type Tag = Vec<(u32, u64)>;

/// Everything a single `run` needs besides the compiled graph.
pub struct RunContext {
    pub resources: Arc<ResourceMgr>,
    pub rendezvous: Arc<dyn Rendezvous>,
    pub step: Arc<StepState>,
    pub trace: Option<Arc<TraceCollector>>,
}

#[derive(Default, Clone)]
struct MergeState {
    fired: bool,
    arrived: u32,
    live: Option<(usize, Tensor)>,
    control_remaining: u32,
    initialized: bool,
}

/// State of one (frame instance, iteration).
struct IterState {
    pending: Vec<u32>,
    any_dead: Vec<bool>,
    inputs: Vec<Option<Tensor>>,
    merge: HashMap<usize, MergeState>,
    scheduled: Vec<bool>,
}

struct RunState {
    iters: HashMap<Tag, IterState>,
    /// Loop-invariant captures: (producer, port, producer tag) → entry.
    /// Port `usize::MAX` encodes the control-edge liveness of the producer.
    invariants: HashMap<(NodeId, usize, Tag), Entry>,
    outstanding: u64,
    first_error: Option<Status>,
}

struct ScheduledNode {
    node: NodeId,
    tag: Tag,
    inputs: Vec<Tensor>,
}

enum Delivery {
    Data { consumer: NodeId, slot: usize, tag: Tag, entry: Entry },
    Control { consumer: NodeId, tag: Tag, dead: bool },
}

struct Inner {
    graph: Arc<CompiledGraph>,
    ctx: RunContext,
    state: Mutex<RunState>,
    done_cond: Condvar,
    /// This step's arena (checked out of `graph.arena_pool` for the
    /// duration of the run; concurrent steps get distinct arenas).
    arena: Option<Arc<crate::memory::StepArena>>,
}

/// Executes a compiled per-device subgraph.
pub struct Executor {
    graph: Arc<CompiledGraph>,
}

impl Executor {
    pub fn new(graph: Arc<CompiledGraph>) -> Executor {
        Executor { graph }
    }

    pub fn graph(&self) -> &Arc<CompiledGraph> {
        &self.graph
    }

    /// Run the subgraph to completion (§3.1). Returns the first error;
    /// fetched tensors land in `ctx.step`.
    pub fn run(&self, ctx: RunContext) -> Result<()> {
        // One arena per step: buffers released during this run pool in its
        // slots, and the arena itself returns to the compiled graph's pool
        // at the end so the *next* step reuses the same storage.
        let arena = self.graph.arena_pool.as_ref().map(|p| p.checkout());
        let inner = Arc::new(Inner {
            graph: Arc::clone(&self.graph),
            ctx,
            state: Mutex::new(RunState {
                iters: HashMap::new(),
                invariants: HashMap::new(),
                outstanding: 0,
                first_error: None,
            }),
            done_cond: Condvar::new(),
            arena: arena.clone(),
        });

        let result = Inner::run_to_completion(&inner);

        if let (Some(pool), Some(arena)) = (self.graph.arena_pool.as_ref(), arena) {
            pool.checkin(arena);
        }
        result
    }
}

enum Action {
    None,
    Schedule(Vec<Tensor>),
    DeadPropagate,
    MergeFire(Vec<Entry>),
}

/// Best-effort text of a caught panic payload (`panic!("…")` carries a
/// `&str` or `String`; anything else is opaque).
fn panic_message(p: &(dyn std::any::Any + Send)) -> &str {
    p.downcast_ref::<&'static str>()
        .copied()
        .or_else(|| p.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

impl Inner {
    /// The seed-dispatch-wait loop (body of [`Executor::run`], split out
    /// so the arena check-in runs on every exit path).
    fn run_to_completion(inner: &Arc<Inner>) -> Result<()> {
        // Seed: every zero-dependency (root-frame) node.
        let ready = {
            let mut st = inner.state.lock().unwrap();
            inner.ensure_iter(&mut st, &Tag::new(), &mut Vec::new());
            let ready: Vec<ScheduledNode> = inner
                .graph
                .nodes
                .iter()
                .enumerate()
                .filter(|(_, n)| n.num_deps == 0 && !matches!(n.kind, NodeKind::Merge))
                .map(|(i, _)| ScheduledNode { node: NodeId(i), tag: Tag::new(), inputs: vec![] })
                .collect();
            st.outstanding += ready.len() as u64;
            ready
        };
        if ready.is_empty() {
            return Ok(()); // empty graph
        }
        for s in ready {
            Inner::dispatch(inner, s);
        }

        let mut st = inner.state.lock().unwrap();
        while st.outstanding > 0 {
            st = inner.done_cond.wait(st).unwrap();
        }
        match st.first_error.take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Create the iteration state for `tag` if absent, queueing deliveries
    /// of any already-known invariants into it.
    fn ensure_iter(&self, st: &mut RunState, tag: &Tag, queue: &mut Vec<Delivery>) {
        if st.iters.contains_key(tag) {
            return;
        }
        let frame_idx = self.graph.frame_of_tag(tag);
        let f = &self.graph.frames[frame_idx as usize];
        st.iters.insert(
            tag.clone(),
            IterState {
                pending: f.node_deps.clone(),
                any_dead: vec![false; f.nodes.len()],
                inputs: vec![None; f.num_input_slots],
                merge: HashMap::new(),
                scheduled: vec![false; f.nodes.len()],
            },
        );
        for &(producer, port, consumer, slot) in &f.invariant_in_edges {
            let p_depth = self.graph.nodes[producer.0].frame_depth;
            let p_tag: Tag = tag[..p_depth].to_vec();
            if let Some(entry) = st.invariants.get(&(producer, port, p_tag)) {
                queue.push(Delivery::Data { consumer, slot, tag: tag.clone(), entry: entry.clone() });
            }
        }
        for &(producer, consumer) in &f.invariant_control_edges {
            let p_depth = self.graph.nodes[producer.0].frame_depth;
            let p_tag: Tag = tag[..p_depth].to_vec();
            if let Some(entry) = st.invariants.get(&(producer, usize::MAX, p_tag)) {
                queue.push(Delivery::Control { consumer, tag: tag.clone(), dead: entry.is_dead() });
            }
        }
    }

    /// Apply one delivery to the target iteration state; decide follow-up.
    fn apply_delivery(&self, st: &mut RunState, d: &Delivery) -> (NodeId, Tag, Action) {
        let (consumer, tag) = match d {
            Delivery::Data { consumer, tag, .. } => (*consumer, tag.clone()),
            Delivery::Control { consumer, tag, .. } => (*consumer, tag.clone()),
        };
        let node = &self.graph.nodes[consumer.0];
        let frame = &self.graph.frames[node.frame as usize];
        let local = frame.local_index[&consumer];
        let iter = st.iters.get_mut(&tag).expect("iter state exists");

        if matches!(node.kind, NodeKind::Merge) {
            let ms = iter.merge.entry(local).or_default();
            if !ms.initialized {
                ms.control_remaining = node.control_inputs.len() as u32;
                ms.initialized = true;
            }
            match d {
                Delivery::Data { entry, slot, .. } => {
                    ms.arrived += 1;
                    if let Entry::Live(t) = entry {
                        if ms.live.is_none() {
                            ms.live = Some((*slot, t.clone()));
                        }
                    }
                }
                Delivery::Control { .. } => {
                    ms.control_remaining = ms.control_remaining.saturating_sub(1);
                }
            }
            if !ms.fired && ms.control_remaining == 0 {
                if let Some((slot, value)) = ms.live.clone() {
                    ms.fired = true;
                    return (
                        consumer,
                        tag,
                        Action::MergeFire(vec![
                            Entry::Live(value),
                            Entry::Live(Tensor::scalar_i32(slot as i32)),
                        ]),
                    );
                } else if ms.arrived >= node.merge_non_backedge {
                    // All non-back-edge inputs arrived dead: the merge is
                    // dead (back-edges can never deliver live tokens into a
                    // dead loop).
                    ms.fired = true;
                    return (consumer, tag, Action::DeadPropagate);
                }
            }
            return (consumer, tag, Action::None);
        }

        match d {
            Delivery::Data { entry, slot, .. } => {
                let off = frame.input_slot_offset[&consumer] + slot;
                match entry {
                    Entry::Live(t) => iter.inputs[off] = Some(t.clone()),
                    Entry::Dead => iter.any_dead[local] = true,
                }
            }
            Delivery::Control { dead, .. } => {
                if *dead {
                    iter.any_dead[local] = true;
                }
            }
        }
        iter.pending[local] -= 1;
        if iter.pending[local] == 0 && !iter.scheduled[local] {
            iter.scheduled[local] = true;
            if iter.any_dead[local] {
                return (consumer, tag, Action::DeadPropagate);
            }
            let off = frame.input_slot_offset[&consumer];
            let inputs: Vec<Tensor> = (0..node.inputs.len())
                .map(|s| iter.inputs[off + s].take().expect("live input present"))
                .collect();
            return (consumer, tag, Action::Schedule(inputs));
        }
        (consumer, tag, Action::None)
    }

    /// Propagate a node's completion (live outputs, or deadness) into new
    /// deliveries, honoring retagging and loop-invariant capture.
    fn propagate(
        &self,
        st: &mut RunState,
        node_id: NodeId,
        tag: &Tag,
        outputs: Option<Vec<Entry>>, // None = all-dead
        queue: &mut Vec<Delivery>,
    ) {
        let node = &self.graph.nodes[node_id.0];
        let is_dead = outputs.is_none();
        // Dead tokens flowing out of a loop (Exit) or around its back edge
        // (NextIteration) are dropped: exactly one live Exit fires per loop
        // variable, and dead back-edges would cycle forever. (TF equivalent:
        // dead exits are held in the frame and the iteration stops.)
        if is_dead && matches!(node.kind, NodeKind::Exit | NodeKind::NextIteration) {
            return;
        }
        let entries: Vec<Entry> = match outputs {
            Some(e) => e,
            None => vec![Entry::Dead; node.num_outputs.max(1)],
        };
        let retagging =
            matches!(node.kind, NodeKind::Enter { .. } | NodeKind::Exit | NodeKind::NextIteration);
        let out_tag = || -> Tag {
            match node.kind {
                NodeKind::Enter { frame } => {
                    let mut t = tag.clone();
                    t.push((frame, 0));
                    t
                }
                NodeKind::Exit => tag[..tag.len() - 1].to_vec(),
                NodeKind::NextIteration => {
                    let mut t = tag.clone();
                    t.last_mut().unwrap().1 += 1;
                    t
                }
                _ => tag.clone(),
            }
        };

        if node.has_invariant_consumers {
            // Record for future iteration states…
            for (port, entry) in entries.iter().enumerate() {
                st.invariants.insert((node_id, port, tag.clone()), entry.clone());
            }
            st.invariants.insert(
                (node_id, usize::MAX, tag.clone()),
                if is_dead { Entry::Dead } else { Entry::Live(Tensor::scalar_bool(true)) },
            );
        }

        for (port, edges) in node.out_edges.iter().enumerate() {
            let entry = entries.get(port).cloned().unwrap_or(Entry::Dead);
            for &(consumer, slot) in edges {
                let cframe = self.graph.nodes[consumer.0].frame;
                if cframe == node.frame || retagging {
                    queue.push(Delivery::Data { consumer, slot, tag: out_tag(), entry: entry.clone() });
                } else {
                    // Invariant: deliver to every existing deeper iteration
                    // of the consumer's frame under this producer tag.
                    let cdepth = self.graph.nodes[consumer.0].frame_depth;
                    let targets: Vec<Tag> = st
                        .iters
                        .keys()
                        .filter(|t| {
                            t.len() == cdepth
                                && t.starts_with(tag)
                                && self.graph.frame_of_tag(t) == cframe
                        })
                        .cloned()
                        .collect();
                    for t in targets {
                        queue.push(Delivery::Data {
                            consumer,
                            slot,
                            tag: t,
                            entry: entry.clone(),
                        });
                    }
                }
            }
        }
        for &consumer in &node.control_out {
            let cframe = self.graph.nodes[consumer.0].frame;
            if cframe == node.frame || retagging {
                queue.push(Delivery::Control { consumer, tag: out_tag(), dead: is_dead });
            } else {
                let cdepth = self.graph.nodes[consumer.0].frame_depth;
                let targets: Vec<Tag> = st
                    .iters
                    .keys()
                    .filter(|t| {
                        t.len() == cdepth && t.starts_with(tag) && self.graph.frame_of_tag(t) == cframe
                    })
                    .cloned()
                    .collect();
                for t in targets {
                    queue.push(Delivery::Control { consumer, tag: t, dead: is_dead });
                }
            }
        }
    }

    /// Drain the delivery queue to quiescence; returns newly-ready nodes.
    fn drain(&self, st: &mut RunState, mut queue: Vec<Delivery>) -> Vec<ScheduledNode> {
        let mut ready = Vec::new();
        while let Some(d) = queue.pop() {
            let tag = match &d {
                Delivery::Data { tag, .. } | Delivery::Control { tag, .. } => tag.clone(),
            };
            self.ensure_iter(st, &tag, &mut queue);
            let (node, tag, action) = self.apply_delivery(st, &d);
            match action {
                Action::None => {}
                Action::Schedule(inputs) => ready.push(ScheduledNode { node, tag, inputs }),
                Action::DeadPropagate => self.propagate(st, node, &tag, None, &mut queue),
                Action::MergeFire(entries) => self.propagate(st, node, &tag, Some(entries), &mut queue),
            }
        }
        st.outstanding += ready.len() as u64;
        ready
    }

    fn dispatch(self: &Arc<Self>, s: ScheduledNode) {
        let inner = Arc::clone(self);
        self.graph.device.pool.execute(move || {
            inner.execute_chain(s);
        });
    }

    /// Perf (§Perf L3 iteration 2): run follow-up work inline instead of
    /// round-tripping every ready node through the pool queue — a serial
    /// chain executes on one thread; only genuine fan-out is dispatched.
    fn execute_chain(self: &Arc<Self>, first: ScheduledNode) {
        let mut cur = Some(first);
        while let Some(s) = cur.take() {
            let mut followups = self.execute_node(s).into_iter();
            cur = followups.next();
            for rest in followups {
                self.dispatch(rest);
            }
        }
    }

    /// Execute one node; returns ready follow-ups for sync completions
    /// (async kernels dispatch their follow-ups from the continuation).
    fn execute_node(self: &Arc<Self>, s: ScheduledNode) -> Vec<ScheduledNode> {
        let graph = Arc::clone(&self.graph);
        let node = &graph.nodes[s.node.0];

        if self.ctx.step.is_cancelled() {
            return self.finish(s.node, s.tag, Err(self
                .ctx
                .step
                .cancel_status()
                .unwrap_or_else(|| Status::cancelled("step cancelled"))), true);
        }

        let trace_span =
            self.ctx.trace.as_ref().map(|t| t.begin(&node.info.name, &node.info.op, &graph.device.name()));

        match &node.kind {
            NodeKind::Switch => {
                let result = (|| -> Result<Vec<Entry>> {
                    let data = s.inputs[0].clone();
                    let pred = s.inputs[1].scalar_value_bool()?;
                    Ok(if pred {
                        vec![Entry::Dead, Entry::Live(data)] // port 1 = true
                    } else {
                        vec![Entry::Live(data), Entry::Dead] // port 0 = false
                    })
                })();
                if let Some(sp) = trace_span {
                    sp.end();
                }
                match result {
                    Ok(entries) => self.finish_entries(s.node, s.tag, entries),
                    Err(e) => self.finish(s.node, s.tag, Err(e), false),
                }
            }
            NodeKind::Enter { .. } | NodeKind::Exit | NodeKind::NextIteration => {
                if let Some(sp) = trace_span {
                    sp.end();
                }
                self.finish_entries(s.node, s.tag, vec![Entry::Live(s.inputs[0].clone())])
            }
            NodeKind::Merge => unreachable!("merge fires inside drain()"),
            NodeKind::Normal => {
                let kernel = node.kernel.as_ref().expect("normal node has kernel");
                // Bind the step memory plan (arena slots + forwarding
                // marks) for this node, when planning is on.
                let mem = match (&self.arena, &graph.plan) {
                    (Some(arena), Some(plan)) => Some(crate::kernels::NodeMemory {
                        arena: Arc::clone(arena),
                        plan: Arc::clone(plan),
                        node: s.node.0,
                    }),
                    _ => None,
                };
                let mut kctx = KernelContext {
                    inputs: s.inputs,
                    node: Arc::clone(&node.info),
                    device: Arc::clone(&graph.device),
                    resources: Arc::clone(&self.ctx.resources),
                    rendezvous: Arc::clone(&self.ctx.rendezvous),
                    step: Arc::clone(&self.ctx.step),
                    mem,
                };
                match kernel {
                    Kernel::Sync(f) => {
                        // A panicking kernel (including a panic raised in
                        // an intra-op `parallel_for` worker, which the
                        // compute pool re-raises here) must fail the step
                        // with a Status — not strand `outstanding` and
                        // hang the run, nor abort the process.
                        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || f(&mut kctx),
                        ))
                        .unwrap_or_else(|p| {
                            Err(Status::internal(format!(
                                "kernel {} ({}) panicked: {}",
                                node.info.name,
                                node.info.op,
                                panic_message(p.as_ref())
                            )))
                        });
                        if let Some(sp) = trace_span {
                            // Attribute output bytes to the span so
                            // StepStats can report per-node peak memory.
                            let bytes = result.as_ref().map_or(0, |outs| {
                                outs.iter().map(|t| t.size_bytes() as u64).sum()
                            });
                            sp.end_with_bytes(bytes);
                        }
                        if let Ok(outs) = &result {
                            for t in outs {
                                graph.device.stats.alloc(t.size_bytes());
                            }
                        }
                        self.finish(s.node, s.tag, result, false)
                    }
                    Kernel::Async(f) => {
                        let inner = Arc::clone(self);
                        let node_id = s.node;
                        let tag = s.tag;
                        let done: DoneFn = Box::new(move |result| {
                            if let Some(sp) = trace_span {
                                let bytes = result.as_ref().map_or(0, |outs| {
                                    outs.iter().map(|t| t.size_bytes() as u64).sum()
                                });
                                sp.end_with_bytes(bytes);
                            }
                            if let Ok(outs) = &result {
                                for t in outs {
                                    inner.graph.device.stats.alloc(t.size_bytes());
                                }
                            }
                            // Continuations run on arbitrary threads
                            // (rendezvous/queue callbacks): dispatch all.
                            for next in inner.finish(node_id, tag, result, false) {
                                inner.dispatch(next);
                            }
                        });
                        f(kctx, done);
                        Vec::new()
                    }
                }
            }
        }
    }

    fn finish(
        self: &Arc<Self>,
        node: NodeId,
        tag: Tag,
        result: Result<Vec<Tensor>>,
        was_cancelled: bool,
    ) -> Vec<ScheduledNode> {
        match result {
            Ok(outs) => self.finish_entries(node, tag, outs.into_iter().map(Entry::Live).collect()),
            Err(e) => {
                if !was_cancelled {
                    self.ctx.step.cancel(e.clone());
                    self.ctx.rendezvous.abort(Status::aborted(format!(
                        "step aborted: {}",
                        e.message
                    )));
                }
                let mut st = self.state.lock().unwrap();
                if st.first_error.is_none() && !was_cancelled {
                    st.first_error = Some(e);
                }
                st.outstanding -= 1;
                if st.outstanding == 0 {
                    self.done_cond.notify_all();
                }
                Vec::new()
            }
        }
    }

    fn finish_entries(self: &Arc<Self>, node_id: NodeId, tag: Tag, entries: Vec<Entry>) -> Vec<ScheduledNode> {
        let mut st = self.state.lock().unwrap();
        let mut queue = Vec::new();
        self.propagate(&mut st, node_id, &tag, Some(entries), &mut queue);
        let ready = self.drain(&mut st, queue);
        st.outstanding -= 1;
        if st.outstanding == 0 {
            self.done_cond.notify_all();
        }
        ready
    }
}
