//! Graph → executable form: kernel instantiation, fanout lists, pending
//! counts, frame assignment (§4.4), and resource-ref resolution.

use crate::device::Device;
use crate::error::{Result, Status};
use crate::graph::{Endpoint, Graph, NodeId};
use crate::kernels::{create_kernel, Kernel, NodeInfo};
use crate::memory::{ArenaPool, MemoryPlan};
use crate::ops;
use std::collections::HashMap;
use std::sync::Arc;

/// Executor-special node kinds (§4.4 primitives execute inside the
/// executor's tag machinery, not as kernels).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum NodeKind {
    Normal,
    Switch,
    Merge,
    /// Carries the target (child) frame index.
    Enter { frame: u32 },
    Exit,
    NextIteration,
}

/// One frame definition (§4.4: "execution state is represented by a
/// frame"). Frame 0 is the root graph.
pub struct FrameDef {
    pub name: String,
    pub parent: u32,
    /// Nesting depth == tag length for nodes of this frame (root: 0).
    pub depth: usize,
    pub nodes: Vec<NodeId>,
    pub local_index: HashMap<NodeId, usize>,
    /// Initial pending count per frame-local node.
    pub node_deps: Vec<u32>,
    pub num_input_slots: usize,
    pub input_slot_offset: HashMap<NodeId, usize>,
    /// Loop-invariant data edges entering this frame from ancestor frames:
    /// (producer, port, consumer, slot).
    pub invariant_in_edges: Vec<(NodeId, usize, NodeId, usize)>,
    /// Same for control edges: (producer, consumer).
    pub invariant_control_edges: Vec<(NodeId, NodeId)>,
}

pub struct CompiledNode {
    pub info: Arc<NodeInfo>,
    pub kernel: Option<Kernel>,
    pub inputs: Vec<Endpoint>,
    pub control_inputs: Vec<NodeId>,
    /// out_edges[port] = [(consumer, consumer input slot)].
    pub out_edges: Vec<Vec<(NodeId, usize)>>,
    pub control_out: Vec<NodeId>,
    pub num_outputs: usize,
    pub kind: NodeKind,
    pub frame: u32,
    pub frame_depth: usize,
    pub num_deps: u32,
    /// Any consumer living in a deeper frame (loop-invariant capture)?
    pub has_invariant_consumers: bool,
    /// For Merge: number of inputs that are NOT NextIteration back-edges
    /// (the dead-fire threshold — a Merge is dead once all non-back-edge
    /// inputs arrived dead).
    pub merge_non_backedge: u32,
}

pub struct CompiledGraph {
    pub nodes: Vec<CompiledNode>,
    pub frames: Vec<FrameDef>,
    pub device: Arc<Device>,
    /// Step memory plan (`crate::memory`), when planning was requested.
    pub plan: Option<Arc<MemoryPlan>>,
    /// Arena pool backing the plan: one arena per in-flight step of this
    /// compiled graph, pooled across steps so buffers survive between runs
    /// of the same cached signature.
    pub arena_pool: Option<Arc<ArenaPool>>,
}

impl CompiledGraph {
    pub fn frame_of_tag(&self, tag: &super::Tag) -> u32 {
        tag.last().map(|&(f, _)| f).unwrap_or(0)
    }

    /// Compile a (single-device) graph for execution on `device`, without
    /// a memory plan (build-time evaluation, distributed workers, tests).
    pub fn compile(graph: &Graph, device: Arc<Device>) -> Result<Arc<CompiledGraph>> {
        CompiledGraph::compile_planned(graph, device, false)
    }

    /// Compile with an optional step memory plan (`Session::build_step`
    /// passes `SessionOptions::enable_memory_planning` here).
    pub fn compile_planned(
        graph: &Graph,
        device: Arc<Device>,
        enable_memory_planning: bool,
    ) -> Result<Arc<CompiledGraph>> {
        graph.topo_order()?; // validates acyclicity (mod NextIteration)

        // ---- frame assignment -------------------------------------------
        // frame[node]: Enter's consumers live in the child frame; Exit's
        // consumers in the parent; everything else inherits the deepest
        // input frame. Source nodes live in the root frame.
        let mut frames: Vec<FrameDef> = vec![FrameDef {
            name: "<root>".into(),
            parent: 0,
            depth: 0,
            nodes: vec![],
            local_index: HashMap::new(),
            node_deps: vec![],
            num_input_slots: 0,
            input_slot_offset: HashMap::new(),
            invariant_in_edges: vec![],
            invariant_control_edges: vec![],
        }];
        let mut frame_by_key: HashMap<(u32, String), u32> = HashMap::new();
        let mut node_frame: Vec<u32> = vec![0; graph.len()];

        // Iterate until stable (graphs are shallow; Enter/Exit chains make
        // one or two passes enough, but loop to fixpoint for safety).
        for _ in 0..graph.len().max(2) {
            let mut changed = false;
            for id in graph.ids() {
                let n = graph.node(id);
                // Producer-side view: output frame of a producer p.
                let mut deepest: u32 = 0;
                for e in n
                    .inputs
                    .iter()
                    .map(|e| e.node)
                    .chain(n.control_inputs.iter().copied())
                {
                    let p = graph.node(e);
                    let pf = node_frame[e.0];
                    let out_frame = match p.op.as_str() {
                        "Enter" => {
                            let fname = p.attr("frame_name")?.as_str()?.to_string();
                            *frame_by_key.entry((pf, fname.clone())).or_insert_with(|| {
                                let idx = frames.len() as u32;
                                frames.push(FrameDef {
                                    name: fname,
                                    parent: pf,
                                    depth: frames[pf as usize].depth + 1,
                                    nodes: vec![],
                                    local_index: HashMap::new(),
                                    node_deps: vec![],
                                    num_input_slots: 0,
                                    input_slot_offset: HashMap::new(),
                                    invariant_in_edges: vec![],
                                    invariant_control_edges: vec![],
                                });
                                idx
                            })
                        }
                        "Exit" => frames[pf as usize].parent,
                        _ => pf,
                    };
                    if frames[out_frame as usize].depth > frames[deepest as usize].depth {
                        deepest = out_frame;
                    }
                }
                if node_frame[id.0] != deepest {
                    node_frame[id.0] = deepest;
                    changed = true;
                }
            }
            if !changed {
                break;
            }
        }

        // ---- per-node compilation ----------------------------------------
        let fanout = graph.fanout();
        // Variables read only through ref-edges (Assign/Apply*/…, slot 0)
        // must not dereference their (possibly uninitialized) value: TF's
        // Variable op hands out a ref, and only real reads check
        // initialization. Mark them so the kernel returns a sentinel.
        let mut ref_only: Vec<bool> = vec![false; graph.len()];
        for id in graph.ids() {
            if graph.node(id).op == "Variable" {
                let consumers = &fanout.data[id.0];
                ref_only[id.0] = !consumers.is_empty()
                    && consumers.iter().all(|&(c, slot)| {
                        slot == 0 && ref_input_ops(&graph.node(c).op)
                    });
            }
        }
        let mut nodes: Vec<CompiledNode> = Vec::with_capacity(graph.len());
        for id in graph.ids() {
            let n = graph.node(id);
            let kind = match n.op.as_str() {
                "Switch" => NodeKind::Switch,
                "Merge" => NodeKind::Merge,
                "Enter" => {
                    let fname = n.attr("frame_name")?.as_str()?.to_string();
                    let pf = node_frame[id.0];
                    let child = *frame_by_key.get(&(pf, fname.clone())).ok_or_else(|| {
                        Status::internal(format!("Enter {} frame {fname:?} unresolved", n.name))
                    })?;
                    NodeKind::Enter { frame: child }
                }
                "Exit" => NodeKind::Exit,
                "NextIteration" => NodeKind::NextIteration,
                _ => NodeKind::Normal,
            };

            // Resource-ref resolution: ops whose input 0 must be a direct
            // edge from a Variable / queue node.
            let ref_resource = if ref_input_ops(&n.op) {
                let producer = n.inputs.first().ok_or_else(|| {
                    Status::invalid_argument(format!("{}: ref op missing input 0", n.name))
                })?;
                let p = graph.node(producer.node);
                if !matches!(p.op.as_str(), "Variable" | "FIFOQueue" | "RandomShuffleQueue") {
                    return Err(Status::invalid_argument(format!(
                        "{}: input 0 must come directly from a Variable/queue node, got {} ({})",
                        n.name, p.name, p.op
                    )));
                }
                Some(p.name.clone())
            } else if n.op == "Variable" {
                Some(n.name.clone())
            } else {
                None
            };

            let container = n
                .attrs
                .get("container")
                .and_then(|a| a.as_str().ok().map(String::from))
                .unwrap_or_default();

            let mut attrs = n.attrs.clone();
            if ref_only[id.0] {
                attrs.insert("_ref_only".to_string(), crate::graph::AttrValue::Bool(true));
            }
            let info = Arc::new(NodeInfo {
                name: n.name.clone(),
                op: n.op.clone(),
                attrs,
                ref_resource,
                container,
                device_name: n.assigned_device.clone().unwrap_or_else(|| device.name()),
            });

            let kernel = if kind == NodeKind::Normal {
                Some(create_kernel(&info, device.device_type())?)
            } else {
                None
            };

            let num_outputs = ops::num_outputs(n)?;
            let frame = node_frame[id.0];
            let mut out_edges = vec![Vec::new(); num_outputs.max(1)];
            for &(consumer, slot) in &fanout.data[id.0] {
                let port = graph.node(consumer).inputs[slot].port;
                if port >= out_edges.len() {
                    return Err(Status::invalid_argument(format!(
                        "{}: consumer {} reads port {port}, node has {num_outputs} outputs",
                        n.name,
                        graph.node(consumer).name
                    )));
                }
                out_edges[port].push((consumer, slot));
            }

            let merge_non_backedge = if kind == NodeKind::Merge {
                n.inputs
                    .iter()
                    .filter(|e| graph.node(e.node).op != "NextIteration")
                    .count() as u32
            } else {
                0
            };
            nodes.push(CompiledNode {
                info,
                kernel,
                inputs: n.inputs.clone(),
                control_inputs: n.control_inputs.clone(),
                out_edges,
                control_out: fanout.control[id.0].clone(),
                num_outputs,
                kind,
                frame,
                frame_depth: frames[frame as usize].depth,
                num_deps: (n.inputs.len() + n.control_inputs.len()) as u32,
                has_invariant_consumers: false, // fixed below
                merge_non_backedge,
            });
        }

        // ---- frame membership, slots, invariant edges ----------------------
        for (i, cn) in nodes.iter().enumerate() {
            let f = &mut frames[cn.frame as usize];
            let local = f.nodes.len();
            f.nodes.push(NodeId(i));
            f.local_index.insert(NodeId(i), local);
            f.node_deps.push(cn.num_deps);
            f.input_slot_offset.insert(NodeId(i), f.num_input_slots);
            f.num_input_slots += cn.inputs.len();
        }

        // Classify cross-frame edges.
        let is_ancestor = |anc: u32, mut f: u32, frames: &Vec<FrameDef>| -> bool {
            loop {
                if f == anc {
                    return true;
                }
                if f == 0 {
                    return false;
                }
                f = frames[f as usize].parent;
            }
        };
        let mut invariant_flags = vec![false; nodes.len()];
        for (i, cn) in nodes.iter().enumerate() {
            let pid = NodeId(i);
            let retagging =
                matches!(cn.kind, NodeKind::Enter { .. } | NodeKind::Exit | NodeKind::NextIteration);
            for (port, edges) in cn.out_edges.iter().enumerate() {
                for &(consumer, slot) in edges {
                    let cf = nodes[consumer.0].frame;
                    if cf == cn.frame || retagging {
                        // Retagging consistency checks.
                        if let NodeKind::Enter { frame } = cn.kind {
                            if cf != frame {
                                return Err(Status::invalid_argument(format!(
                                    "Enter {} output consumed outside its frame",
                                    cn.info.name
                                )));
                            }
                        }
                        continue;
                    }
                    if is_ancestor(cn.frame, cf, &frames) {
                        invariant_flags[i] = true;
                        frames[cf as usize]
                            .invariant_in_edges
                            .push((pid, port, consumer, slot));
                    } else {
                        return Err(Status::invalid_argument(format!(
                            "edge {} -> {} crosses frames illegally (use Enter/Exit)",
                            cn.info.name, nodes[consumer.0].info.name
                        )));
                    }
                }
            }
            for &consumer in &cn.control_out {
                let cf = nodes[consumer.0].frame;
                if cf == cn.frame || retagging {
                    continue;
                }
                if is_ancestor(cn.frame, cf, &frames) {
                    invariant_flags[i] = true;
                    frames[cf as usize].invariant_control_edges.push((pid, consumer));
                } else {
                    return Err(Status::invalid_argument(format!(
                        "control edge {} -> {} crosses frames illegally",
                        cn.info.name, nodes[consumer.0].info.name
                    )));
                }
            }
        }
        for (i, flag) in invariant_flags.into_iter().enumerate() {
            nodes[i].has_invariant_consumers = flag;
        }

        // ---- step memory plan (crate::memory) ---------------------------
        let (plan, arena_pool) = if enable_memory_planning {
            let plan = crate::memory::plan_partition(graph, &nodes)?;
            let pool = ArenaPool::new(plan.num_slots());
            (Some(Arc::new(plan)), Some(pool))
        } else {
            (None, None)
        };

        Ok(Arc::new(CompiledGraph { nodes, frames, device, plan, arena_pool }))
    }
}

/// Ops whose input 0 is a resource reference.
fn ref_input_ops(op: &str) -> bool {
    matches!(
        op,
        "Assign"
            | "AssignAdd"
            | "AssignSub"
            | "CountUpTo"
            | "ApplyGradientDescent"
            | "ApplyMomentum"
            | "ApplyAdagrad"
            | "ApplyAdam"
            | "Enqueue"
            | "Dequeue"
            | "QueueClose"
            | "QueueSize"
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::builder::GraphBuilder;
    use crate::tensor::Tensor;

    fn device() -> Arc<Device> {
        Arc::new(Device::new(crate::device::DeviceSpec::local_cpu(0), 2))
    }

    #[test]
    fn compile_simple_graph() {
        let mut b = GraphBuilder::new();
        let x = b.scalar(2.0);
        let y = b.scalar(3.0);
        let _z = b.mul(x, y);
        let cg = CompiledGraph::compile(&b.graph, device()).unwrap();
        assert_eq!(cg.nodes.len(), 3);
        assert_eq!(cg.frames.len(), 1);
        assert_eq!(cg.nodes[2].num_deps, 2);
        assert_eq!(cg.nodes[0].out_edges[0], vec![(NodeId(2), 0)]);
    }

    #[test]
    fn compile_while_loop_frames() {
        let mut b = GraphBuilder::new();
        let zero = b.scalar(0.0);
        b.while_loop(
            "f",
            vec![zero],
            |b, v| {
                let ten = b.scalar(10.0);
                Ok(b.less(v[0], ten))
            },
            |b, v| {
                let one = b.scalar(1.0);
                Ok(vec![b.add(v[0], one)])
            },
        )
        .unwrap();
        let cg = CompiledGraph::compile(&b.graph, device()).unwrap();
        assert_eq!(cg.frames.len(), 2, "root + loop frame");
        // The loop-body consts (10.0, 1.0) live in root but feed the loop:
        // they must be flagged as invariant producers.
        assert!(cg.nodes.iter().any(|n| n.has_invariant_consumers));
        assert!(!cg.frames[1].invariant_in_edges.is_empty());
        // Merge/Switch/Enter/Exit/NextIteration classified.
        assert!(cg.nodes.iter().any(|n| matches!(n.kind, NodeKind::Merge)));
        assert!(cg.nodes.iter().any(|n| matches!(n.kind, NodeKind::Enter { .. })));
    }

    #[test]
    fn memory_plan_packs_chain_into_few_slots() {
        // Const → Neg → Tanh → Square → Abs: disjoint intervals share
        // slots, the Const and the unconsumed tail are pinned/planned per
        // the liveness rules.
        let mut b = GraphBuilder::new();
        let x = b.constant(Tensor::from_f32(vec![16], vec![0.1; 16]).unwrap());
        let a = b.neg(x);
        let t = b.tanh(a);
        let s = b.square(t);
        let _tail = b.op1("Abs", "abs", vec![s], vec![]).unwrap();
        let cg = CompiledGraph::compile_planned(&b.graph, device(), true).unwrap();
        let plan = cg.plan.as_ref().expect("planning on");
        assert!(cg.arena_pool.is_some());
        assert!(plan.stats.planned_static >= 3, "{:?}", plan.stats);
        assert!(
            plan.stats.arena_bytes < plan.stats.naive_bytes,
            "chain must pack: {:?}",
            plan.stats
        );
        assert!(plan.stats.forward_candidates >= 3, "{:?}", plan.stats);
        // Const output (node 0) is pinned; chain nodes have slots.
        assert_eq!(plan.out_slot(0, 0), None);
        assert!(plan.out_slot(1, 0).is_some());
        // Tanh may overwrite Neg's dying output.
        assert!(plan.input_forwardable(2, 0));
    }

    #[test]
    fn memory_plan_pins_fanout_consumers_from_forwarding() {
        let mut b = GraphBuilder::new();
        let x = b.constant(Tensor::from_f32(vec![4], vec![1.0; 4]).unwrap());
        let a = b.neg(x); // two consumers below
        let _u = b.tanh(a);
        let _v = b.square(a);
        let cg = CompiledGraph::compile_planned(&b.graph, device(), true).unwrap();
        let plan = cg.plan.as_ref().unwrap();
        // a is planned, but neither consumer may forward it (2 reads).
        assert!(plan.out_slot(1, 0).is_some());
        assert!(!plan.input_forwardable(2, 0));
        assert!(!plan.input_forwardable(3, 0));
    }

    #[test]
    fn memory_plan_disabled_yields_none() {
        let mut b = GraphBuilder::new();
        let x = b.scalar(1.0);
        b.neg(x);
        let cg = CompiledGraph::compile_planned(&b.graph, device(), false).unwrap();
        assert!(cg.plan.is_none());
        assert!(cg.arena_pool.is_none());
    }

    #[test]
    fn ref_resolution_requires_direct_edge() {
        let mut b = GraphBuilder::new();
        let v = b.variable("v", Tensor::scalar_f32(0.0)).unwrap();
        let ident = b.identity(v);
        let one = b.scalar(1.0);
        // Assign through an Identity: must be rejected at compile.
        b.op("Assign", "bad_assign", vec![ident, one], vec![]).unwrap();
        let err = match CompiledGraph::compile(&b.graph, device()) {
            Err(e) => e,
            Ok(_) => panic!("expected compile error"),
        };
        assert!(err.message.contains("directly"));
    }

    #[test]
    fn unknown_kernel_rejected() {
        use crate::graph::Node;
        let mut g = Graph::new();
        crate::ops::register_op(crate::ops::OpDef {
            name: "OpWithNoKernel",
            category: crate::ops::Category::ElementWise,
            arity: crate::ops::Arity::Exact(0),
            num_outputs: |_| Ok(1),
            stateful: false,
            is_async: false,
        })
        .ok();
        g.add(Node {
            name: "n".into(),
            op: "OpWithNoKernel".into(),
            inputs: vec![],
            control_inputs: vec![],
            attrs: Default::default(),
            requested_device: String::new(),
            assigned_device: None,
        })
        .unwrap();
        assert!(CompiledGraph::compile(&g, device()).is_err());
    }
}
