//! The placement cost model (§3.2.1): "estimates of the sizes (in bytes)
//! of the input and output tensors for each graph node, along with
//! estimates of the computation time required for each node … either
//! statically estimated based on heuristics associated with different
//! operation types, or measured based on an actual set of placement
//! decisions for earlier executions of the graph."
//!
//! Both modes are implemented: `static` heuristics per Table-1 category,
//! and `update_from_trace` which folds real kernel timings from the §9.2
//! tracer back into the model.

use crate::graph::{Graph, Node, NodeId};
use crate::ops::Category;
use crate::tracing_tools::Event;
use std::collections::HashMap;

/// Relative per-device-type speeds and link parameters. On this testbed
/// all devices are CPU threads, so heterogeneity is *configured*: the
/// Fig-8 model-parallel experiment, e.g., gives devices distinct speeds to
/// reproduce a CPU+GPU mix.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Multiplier on compute cost per device name (smaller = faster).
    device_speed: HashMap<String, f64>,
    /// Fallback speed for unlisted devices.
    default_speed: f64,
    /// Per-device-pair (latency µs, µs per KB). Same-device = free.
    link_latency_us: f64,
    link_us_per_kb: f64,
    /// Cross-task links are slower (TCP vs in-memory).
    cross_task_latency_us: f64,
    cross_task_us_per_kb: f64,
    /// Measured execution times, µs, keyed by node name (overrides
    /// heuristics — the paper's "measured" mode).
    measured_us: HashMap<String, f64>,
    /// Estimated output bytes per node name (measured mode).
    measured_bytes: HashMap<String, f64>,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            device_speed: HashMap::new(),
            default_speed: 1.0,
            link_latency_us: 2.0,
            link_us_per_kb: 0.05,
            cross_task_latency_us: 100.0,
            cross_task_us_per_kb: 1.0,
            measured_us: HashMap::new(),
            measured_bytes: HashMap::new(),
        }
    }
}

impl CostModel {
    pub fn new() -> CostModel {
        CostModel::default()
    }

    /// Configure a device's relative speed (0.5 = 2× faster than default).
    pub fn set_device_speed(&mut self, device: &str, speed: f64) {
        self.device_speed.insert(device.to_string(), speed);
    }

    pub fn device_speed(&self, device: &str) -> f64 {
        self.device_speed.get(device).copied().unwrap_or(self.default_speed)
    }

    /// Static heuristic cost in µs for one node (before device speed).
    pub fn static_node_cost_us(&self, node: &Node) -> f64 {
        let category = crate::ops::lookup(&node.op).map(|d| d.category).unwrap_or(Category::Internal);
        match node.op.as_str() {
            "MatMul" | "BatchMatMul" => 200.0,
            // One fused launch doing k elementwise steps in one data pass:
            // cheaper than k separate 10µs launches, pricier than one.
            "FusedElementwise" => {
                let steps =
                    node.attrs.get("ops").and_then(|a| a.as_list_str().ok()).map_or(1, |s| s.len());
                5.0 + 3.0 * steps as f64
            }
            // Conv2D lowers to im2col + the packed GEMM, so its cost tracks
            // MatMul's but with the extra pack/gather pass on top.
            "Convolution2D" | "Conv2DBackpropInput" | "Conv2DBackpropFilter" => 350.0,
            "XlaCall" => 1000.0,
            "MatrixInverse" | "MatrixDeterminant" => 150.0,
            "SoftmaxCrossEntropyWithLogits" | "SoftMax" | "LogSoftmax" => 30.0,
            // Window scans: one read per (window × output) pair — heavier
            // than elementwise, far lighter than a conv's GEMM.
            "MaxPool" | "MaxPoolGrad" => 40.0,
            _ => match category {
                Category::ElementWise | Category::NeuralNet => 10.0,
                Category::Array => 5.0,
                Category::Matrix => 100.0,
                Category::Stateful => 5.0,
                Category::Checkpointing => 1000.0,
                Category::QueueSync => 5.0,
                Category::ControlFlow | Category::Internal => 1.0,
            },
        }
    }

    /// Cost of running `node` on `device`, µs. Measured value wins.
    pub fn node_cost_us(&self, node: &Node, device: &str) -> f64 {
        let base = self
            .measured_us
            .get(&node.name)
            .copied()
            .unwrap_or_else(|| self.static_node_cost_us(node));
        base * self.device_speed(device)
    }

    /// Estimated output bytes of a node (for transfer costs).
    pub fn output_bytes(&self, node: &Node) -> f64 {
        if let Some(&b) = self.measured_bytes.get(&node.name) {
            return b;
        }
        // Const/Variable: the attr tensor/shape tells us exactly.
        if let Some(v) = node.attrs.get("value").and_then(|a| a.as_tensor().ok()) {
            return v.size_bytes() as f64;
        }
        if let Some(s) = node.attrs.get("shape").and_then(|a| a.as_shape().ok()) {
            return (s.num_elements() * 4) as f64;
        }
        4096.0 // order-of-magnitude default
    }

    /// Transfer cost in µs of moving `bytes` from `src` to `dst` device.
    pub fn transfer_cost_us(&self, bytes: f64, src: &str, dst: &str) -> f64 {
        if src == dst {
            return 0.0;
        }
        let cross_task = task_of(src) != task_of(dst);
        let (lat, per_kb) = if cross_task {
            (self.cross_task_latency_us, self.cross_task_us_per_kb)
        } else {
            (self.link_latency_us, self.link_us_per_kb)
        };
        lat + per_kb * bytes / 1024.0
    }

    /// Fold measured kernel timings back in (§3.2.1 "measured based on an
    /// actual set of placement decisions for earlier executions").
    pub fn update_from_trace(&mut self, events: &[Event]) {
        // Average duration per node name.
        let mut sums: HashMap<&str, (f64, f64)> = HashMap::new();
        for ev in events {
            let e = sums.entry(&ev.name).or_default();
            e.0 += ev.dur_us as f64;
            e.1 += 1.0;
        }
        for (name, (total, n)) in sums {
            self.measured_us.insert(name.to_string(), total / n);
        }
    }

    /// Fold a step profile in: each node's mean traced duration becomes
    /// its measured cost. The [`crate::tracing_tools::StepStats`] may come
    /// from this process's last traced run or from a persisted
    /// `StepStats::to_json` file (profile-guided placement across runs —
    /// ROADMAP direction 5).
    pub fn update_from_step_stats(&mut self, stats: &crate::tracing_tools::StepStats) {
        for n in &stats.nodes {
            self.measured_us.insert(n.name.clone(), n.mean_us() as f64);
        }
    }

    /// Record a measured output size.
    pub fn record_output_bytes(&mut self, node_name: &str, bytes: f64) {
        self.measured_bytes.insert(node_name.to_string(), bytes);
    }

    pub fn has_measurements(&self) -> bool {
        !self.measured_us.is_empty()
    }

    /// Estimated serial cost of a whole graph on one device (bench helper).
    pub fn graph_cost_us(&self, graph: &Graph, device: &str) -> f64 {
        graph.ids().map(|id: NodeId| self.node_cost_us(graph.node(id), device)).sum()
    }
}

/// "/job:w/task:3/device:cpu:0" -> "/job:w/task:3"
fn task_of(device: &str) -> &str {
    match device.find("/device:") {
        Some(i) => &device[..i],
        None => device,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::builder::GraphBuilder;

    #[test]
    fn static_costs_ordered_sensibly() {
        let mut b = GraphBuilder::new();
        let x = b.scalar(1.0);
        let mm = b.matmul(x, x);
        let add = b.add(x, x);
        let cm = CostModel::new();
        let g = &b.graph;
        assert!(
            cm.static_node_cost_us(g.node(mm.node)) > cm.static_node_cost_us(g.node(add.node))
        );
    }

    #[test]
    fn nn_kernel_costs_ordered_sensibly() {
        let mut b = GraphBuilder::new();
        let x = b.scalar(1.0);
        let add = b.add(x, x);
        let mm = b.matmul(x, x);
        let mp = b.op("MaxPool", "mp", vec![x], vec![]).unwrap();
        let cv = b.op("Convolution2D", "cv", vec![x, x], vec![]).unwrap();
        let cm = CostModel::new();
        let g = &b.graph;
        let cost = |n: NodeId| cm.static_node_cost_us(g.node(n));
        // elementwise < window scan < GEMM < im2col conv.
        assert!(cost(add.node) < cost(mp));
        assert!(cost(mp) < cost(mm.node));
        assert!(cost(mm.node) < cost(cv));
    }

    #[test]
    fn device_speed_scales_cost() {
        let mut b = GraphBuilder::new();
        let x = b.scalar(1.0);
        let mm = b.matmul(x, x);
        let mut cm = CostModel::new();
        cm.set_device_speed("/fast", 0.25);
        let n = b.graph.node(mm.node);
        assert!(cm.node_cost_us(n, "/fast") < cm.node_cost_us(n, "/other"));
    }

    #[test]
    fn transfer_costs() {
        let cm = CostModel::new();
        let same = cm.transfer_cost_us(1e6, "/job:a/task:0/device:cpu:0", "/job:a/task:0/device:cpu:0");
        assert_eq!(same, 0.0);
        let local = cm.transfer_cost_us(1e6, "/job:a/task:0/device:cpu:0", "/job:a/task:0/device:cpu:1");
        let remote = cm.transfer_cost_us(1e6, "/job:a/task:0/device:cpu:0", "/job:a/task:1/device:cpu:0");
        assert!(local > 0.0);
        assert!(remote > local, "cross-task must cost more: {remote} vs {local}");
    }

    #[test]
    fn measured_overrides_static() {
        let mut b = GraphBuilder::new();
        let x = b.scalar(1.0);
        let mm = b.matmul(x, x);
        let name = b.graph.node(mm.node).name.clone();
        let mut cm = CostModel::new();
        cm.update_from_trace(&[Event {
            name: name.clone(),
            op: "MatMul".into(),
            device: "d".into(),
            thread: 0,
            start_us: 0,
            dur_us: 12345,
            step: 0,
            out_bytes: 0,
        }]);
        assert!(cm.has_measurements());
        assert_eq!(cm.node_cost_us(b.graph.node(mm.node), "/d"), 12345.0);
    }

    #[test]
    fn step_stats_feed_measured_mode() {
        use crate::tracing_tools::{Event, StepStats};
        let mut b = GraphBuilder::new();
        let x = b.scalar(1.0);
        let mm = b.matmul(x, x);
        let name = b.graph.node(mm.node).name.clone();
        let ev = |dur: u64| Event {
            name: name.clone(),
            op: "MatMul".into(),
            device: "d".into(),
            thread: 0,
            start_us: 0,
            dur_us: dur,
            step: 1,
            out_bytes: 0,
        };
        // Two executions of the node in one step: the model takes the mean.
        let ss = StepStats::from_events(1, &[ev(100), ev(300)], Vec::new());
        let mut cm = CostModel::new();
        cm.update_from_step_stats(&ss);
        assert_eq!(cm.node_cost_us(b.graph.node(mm.node), "/d"), 200.0);
    }

    #[test]
    fn const_output_bytes_exact() {
        let mut b = GraphBuilder::new();
        let c = b.constant(crate::tensor::Tensor::from_f32(vec![10, 10], vec![0.0; 100]).unwrap());
        let cm = CostModel::new();
        assert_eq!(cm.output_bytes(b.graph.node(c.node)), 400.0);
    }
}
