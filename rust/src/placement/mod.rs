//! Node placement (§3.2.1 + §4.3).
//!
//! "The placement algorithm first runs a simulated execution of the graph
//! … For each node that is reached in this traversal, the set of feasible
//! devices is considered … For nodes with multiple feasible devices, the
//! placement algorithm uses a greedy heuristic that examines the effects
//! on the completion time of the node of placing the node on each possible
//! device. … The device where the node's operation would finish the
//! soonest is selected."
//!
//! §4.3 constraints: partial device specs per node, plus colocation via
//! union-find ("we first compute the feasible set of devices for each
//! node, and then use union-find on the graph of colocation constraints to
//! compute the graph components that must be placed together").

pub mod cost_model;

pub use cost_model::CostModel;

use crate::device::{DeviceSet, PartialDeviceSpec};
use crate::error::{Result, Status};
use crate::graph::Graph;
#[allow(unused_imports)]
use crate::graph::NodeId;
use crate::kernels::has_kernel;
use std::collections::HashMap;

/// Union-find over node indices.
pub struct UnionFind {
    parent: Vec<usize>,
}

impl UnionFind {
    pub fn new(n: usize) -> UnionFind {
        UnionFind { parent: (0..n).collect() }
    }

    pub fn find(&mut self, x: usize) -> usize {
        if self.parent[x] != x {
            let root = self.find(self.parent[x]);
            self.parent[x] = root;
        }
        self.parent[x]
    }

    pub fn union(&mut self, a: usize, b: usize) {
        let ra = self.find(a);
        let rb = self.find(b);
        if ra != rb {
            self.parent[rb] = ra;
        }
    }
}

/// Ops that must be colocated with the resource produced by their input 0
/// (variable/queue refs cannot cross device boundaries).
fn ref_colocated(op: &str) -> bool {
    matches!(
        op,
        "Assign"
            | "AssignAdd"
            | "AssignSub"
            | "CountUpTo"
            | "ApplyGradientDescent"
            | "ApplyMomentum"
            | "ApplyAdagrad"
            | "ApplyAdam"
            | "Enqueue"
            | "Dequeue"
            | "QueueClose"
            | "QueueSize"
    )
}

/// Compute colocation groups (§4.3): explicit `_class=loc:@x` constraints,
/// ref edges, and whole loop frames (this implementation colocates each
/// control-flow frame on one device; see DESIGN.md §limitations — the
/// paper's distributed-loop control nodes are not reproduced).
pub fn colocation_groups(graph: &Graph) -> Result<UnionFind> {
    let mut uf = UnionFind::new(graph.len());
    // loc:@ constraints.
    for id in graph.ids() {
        let n = graph.node(id);
        if let Some(classes) = n.attrs.get("_class").and_then(|a| a.as_list_str().ok()) {
            for c in classes {
                if let Some(target) = c.strip_prefix("loc:@") {
                    let t = graph.must_find(target)?;
                    uf.union(id.0, t.0);
                }
            }
        }
        // Ref edges.
        if ref_colocated(&n.op) {
            if let Some(first) = n.inputs.first() {
                uf.union(id.0, first.node.0);
            }
        }
    }
    // Loop frames: every node reachable inside an Enter..Exit region is
    // glued to its Enter. Frame membership ~ the executor's assignment;
    // here the cheap approximation: union across every edge that does NOT
    // cross a frame boundary op — equivalently, union each Enter with its
    // consumers transitively until Exit.
    for id in graph.ids() {
        let n = graph.node(id);
        if n.op == "Enter" {
            // BFS forward until Exit nodes.
            let mut stack = vec![id];
            let fanout = graph.fanout();
            let mut seen = std::collections::HashSet::new();
            while let Some(cur) = stack.pop() {
                if !seen.insert(cur) {
                    continue;
                }
                uf.union(id.0, cur.0);
                if graph.node(cur).op == "Exit" {
                    continue;
                }
                for &(consumer, _) in &fanout.data[cur.0] {
                    stack.push(consumer);
                }
                for &consumer in &fanout.control[cur.0] {
                    stack.push(consumer);
                }
            }
        }
    }
    Ok(uf)
}

/// Statistics returned by the placer (consumed by benches/experiments).
#[derive(Debug, Default, Clone)]
pub struct PlacementStats {
    pub groups: usize,
    pub per_device: HashMap<String, usize>,
    pub estimated_makespan_us: f64,
}

/// Run placement: writes `assigned_device` into every node of `graph`.
pub fn place(graph: &mut Graph, devices: &DeviceSet, cost: &CostModel) -> Result<PlacementStats> {
    if devices.is_empty() {
        return Err(Status::invalid_argument("placement with empty device set"));
    }
    let mut uf = colocation_groups(graph)?;

    // Per-group merged constraint + feasible devices.
    let mut group_constraint: HashMap<usize, PartialDeviceSpec> = HashMap::new();
    for id in graph.ids() {
        let n = graph.node(id);
        let root = uf.find(id.0);
        let spec = PartialDeviceSpec::parse(&n.requested_device)?;
        let entry = group_constraint.entry(root).or_insert_with(PartialDeviceSpec::any);
        *entry = entry.merge(&spec).map_err(|e| {
            Status::invalid_argument(format!(
                "conflicting device constraints in colocation group of {:?}: {}",
                n.name, e.message
            ))
        })?;
    }

    let mut group_feasible: HashMap<usize, Vec<usize>> = HashMap::new();
    for id in graph.ids() {
        let root = uf.find(id.0);
        group_feasible.entry(root).or_insert_with(|| {
            let spec = &group_constraint[&root];
            (0..devices.len())
                .filter(|&d| spec.matches(&devices.get(d).spec))
                .collect()
        });
    }
    // Kernel feasibility per member (§3.2.1 "a device may not be feasible
    // if the device does not provide a kernel").
    for id in graph.ids() {
        let n = graph.node(id);
        let root = uf.find(id.0);
        let feas = group_feasible.get_mut(&root).unwrap();
        feas.retain(|&d| has_kernel(&n.op, devices.get(d).device_type()));
        if feas.is_empty() {
            return Err(Status::invalid_argument(format!(
                "no feasible device for node {:?} (op {}, constraint {})",
                n.name, n.op, group_constraint[&root]
            )));
        }
    }

    // ---- greedy simulated execution -----------------------------------
    let order = graph.topo_order()?;
    let mut device_free = vec![0f64; devices.len()];
    let mut finish: Vec<f64> = vec![0.0; graph.len()];
    let mut group_device: HashMap<usize, usize> = HashMap::new();
    let mut assigned: Vec<usize> = vec![usize::MAX; graph.len()];

    for id in order {
        let root = uf.find(id.0);
        let candidates: Vec<usize> = match group_device.get(&root) {
            Some(&d) => vec![d], // group already pinned
            None => group_feasible[&root].clone(),
        };
        let node = graph.node(id);
        let mut best = (f64::INFINITY, candidates[0]);
        for &d in &candidates {
            let dname = devices.get(d).name();
            // Inputs-ready time including §3.2.1 communication costs.
            let mut ready = 0f64;
            for e in node.inputs.iter().map(|e| e.node).chain(node.control_inputs.iter().copied())
            {
                let src = assigned[e.0];
                if src == usize::MAX {
                    continue; // NextIteration back-edge
                }
                let t = finish[e.0]
                    + cost.transfer_cost_us(
                        cost.output_bytes(graph.node(e)),
                        &devices.get(src).name(),
                        &dname,
                    );
                ready = ready.max(t);
            }
            let completion =
                device_free[d].max(ready) + cost.node_cost_us(node, &dname);
            if completion < best.0 {
                best = (completion, d);
            }
        }
        let (completion, d) = best;
        assigned[id.0] = d;
        group_device.insert(root, d);
        device_free[d] = completion;
        finish[id.0] = completion;
    }

    // Write back and collect stats.
    let mut stats = PlacementStats {
        groups: group_feasible.len(),
        per_device: HashMap::new(),
        estimated_makespan_us: device_free.iter().cloned().fold(0.0, f64::max),
    };
    for id in graph.ids() {
        let name = devices.get(assigned[id.0]).name();
        *stats.per_device.entry(name.clone()).or_default() += 1;
        graph.node_mut(id).assigned_device = Some(name);
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::builder::GraphBuilder;
    use crate::tensor::Tensor;

    #[test]
    fn union_find_groups() {
        let mut uf = UnionFind::new(5);
        uf.union(0, 1);
        uf.union(3, 4);
        assert_eq!(uf.find(0), uf.find(1));
        assert_ne!(uf.find(1), uf.find(3));
        uf.union(1, 3);
        assert_eq!(uf.find(0), uf.find(4));
    }

    #[test]
    fn places_all_nodes() {
        let mut b = GraphBuilder::new();
        let x = b.scalar(1.0);
        let y = b.scalar(2.0);
        let _ = b.add(x, y);
        let devices = DeviceSet::local(2, 1);
        let stats = place(&mut b.graph, &devices, &CostModel::new()).unwrap();
        assert!(b.graph.nodes.iter().all(|n| n.assigned_device.is_some()));
        assert_eq!(stats.per_device.values().sum::<usize>(), 3);
    }

    #[test]
    fn respects_device_constraint() {
        let mut b = GraphBuilder::new();
        let x = b.with_device("/device:cpu:1", |b| b.scalar(1.0));
        let devices = DeviceSet::local(3, 1);
        place(&mut b.graph, &devices, &CostModel::new()).unwrap();
        assert_eq!(
            b.graph.node(x.node).assigned_device.as_deref().unwrap(),
            "/job:localhost/task:0/device:cpu:1"
        );
    }

    #[test]
    fn variable_and_assign_colocated() {
        let mut b = GraphBuilder::new();
        let v = b.with_device("/device:cpu:1", |b| {
            b.variable("v", Tensor::scalar_f32(0.0)).unwrap()
        });
        let one = b.scalar(1.0);
        let asn = b.assign_add(v, one).unwrap();
        let devices = DeviceSet::local(4, 1);
        place(&mut b.graph, &devices, &CostModel::new()).unwrap();
        let vd = b.graph.node(v.node).assigned_device.clone().unwrap();
        let ad = b.graph.node(asn).assigned_device.clone().unwrap();
        assert_eq!(vd, ad);
        assert!(vd.ends_with("cpu:1"));
    }

    #[test]
    fn colocate_attr_respected() {
        let mut b = GraphBuilder::new();
        let anchor = b.with_device("/device:cpu:2", |b| b.scalar(1.0));
        let other = b.scalar(2.0);
        b.colocate(other.node, anchor.node);
        let devices = DeviceSet::local(3, 1);
        place(&mut b.graph, &devices, &CostModel::new()).unwrap();
        assert_eq!(
            b.graph.node(other.node).assigned_device,
            b.graph.node(anchor.node).assigned_device
        );
    }

    #[test]
    fn conflicting_constraints_rejected() {
        let mut b = GraphBuilder::new();
        let a = b.with_device("/device:cpu:0", |b| b.scalar(1.0));
        let c = b.with_device("/device:cpu:1", |b| b.scalar(2.0));
        b.colocate(a.node, c.node);
        let devices = DeviceSet::local(2, 1);
        assert!(place(&mut b.graph, &devices, &CostModel::new()).is_err());
    }

    #[test]
    fn infeasible_constraint_rejected() {
        let mut b = GraphBuilder::new();
        b.with_device("/device:gpu:0", |b| b.scalar(1.0));
        let devices = DeviceSet::local(2, 1); // cpu only
        assert!(place(&mut b.graph, &devices, &CostModel::new()).is_err());
    }

    #[test]
    fn parallel_branches_spread_across_devices() {
        // Two expensive independent chains + cheap merge: the greedy
        // simulation should use both devices.
        let mut b = GraphBuilder::new();
        let x = b.constant(Tensor::from_f32(vec![64, 64], vec![0.1; 4096]).unwrap());
        let mut l = x;
        let mut r = x;
        for _ in 0..4 {
            l = b.matmul(l, l);
            r = b.matmul(r, r);
        }
        let _out = b.add(l, r);
        let devices = DeviceSet::local(2, 1);
        let stats = place(&mut b.graph, &devices, &CostModel::new()).unwrap();
        assert_eq!(stats.per_device.len(), 2, "both devices should be used: {stats:?}");
    }

    #[test]
    fn loop_frame_is_colocated() {
        let mut b = GraphBuilder::new();
        let zero = b.scalar(0.0);
        b.while_loop(
            "w",
            vec![zero],
            |b, v| {
                let lim = b.scalar(5.0);
                Ok(b.less(v[0], lim))
            },
            |b, v| {
                let one = b.scalar(1.0);
                Ok(vec![b.add(v[0], one)])
            },
        )
        .unwrap();
        let devices = DeviceSet::local(4, 1);
        place(&mut b.graph, &devices, &CostModel::new()).unwrap();
        // All control-flow nodes on one device.
        let loop_devices: std::collections::HashSet<String> = b
            .graph
            .nodes
            .iter()
            .filter(|n| matches!(n.op.as_str(), "Merge" | "Switch" | "Exit" | "NextIteration"))
            .map(|n| n.assigned_device.clone().unwrap())
            .collect();
        assert_eq!(loop_devices.len(), 1, "loop must live on one device");
    }
}
