//! L3 driver for the AOT transformer train-step artifact: parses the
//! artifact metadata (the rust/python contract emitted by aot.py),
//! initializes parameters, generates synthetic token streams, and steps
//! the model by executing the XLA program — the E16 end-to-end path.

use crate::error::{Result, Status};
use crate::runtime::{load_artifact, XlaExecutable};
use crate::tensor::{Shape, Tensor, TensorData};
use crate::util::rng::Pcg32;
use std::path::Path;
use std::sync::Arc;

/// Parsed transformer artifact metadata.
#[derive(Debug, Clone)]
pub struct TransformerConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub lr: f32,
    pub params: Vec<(String, Shape, String)>, // (name, shape, init)
}

impl TransformerConfig {
    /// Load the `.meta.txt` written next to the artifact.
    pub fn load(meta_path: &Path) -> Result<TransformerConfig> {
        let text = std::fs::read_to_string(meta_path)
            .map_err(|e| Status::not_found(format!("{meta_path:?}: {e}")))?;
        let mut cfg = TransformerConfig {
            name: String::new(),
            vocab: 0,
            d_model: 0,
            n_layers: 0,
            n_heads: 0,
            d_ff: 0,
            seq_len: 0,
            batch: 0,
            lr: 0.0,
            params: Vec::new(),
        };
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("param ") {
                let mut it = rest.split_whitespace();
                let name = it.next().ok_or_else(|| Status::invalid_argument("bad param line"))?;
                let dims = it.next().ok_or_else(|| Status::invalid_argument("bad param dims"))?;
                let init = it.next().unwrap_or("normal");
                let shape = Shape(
                    dims.split(',')
                        .filter(|s| !s.is_empty())
                        .map(|s| s.parse::<usize>())
                        .collect::<std::result::Result<Vec<_>, _>>()
                        .map_err(|_| Status::invalid_argument(format!("bad dims {dims:?}")))?,
                );
                cfg.params.push((name.to_string(), shape, init.to_string()));
            } else if let Some((k, v)) = line.split_once('=') {
                match k {
                    "name" => cfg.name = v.to_string(),
                    "vocab" => cfg.vocab = v.parse().unwrap_or(0),
                    "d_model" => cfg.d_model = v.parse().unwrap_or(0),
                    "n_layers" => cfg.n_layers = v.parse().unwrap_or(0),
                    "n_heads" => cfg.n_heads = v.parse().unwrap_or(0),
                    "d_ff" => cfg.d_ff = v.parse().unwrap_or(0),
                    "seq_len" => cfg.seq_len = v.parse().unwrap_or(0),
                    "batch" => cfg.batch = v.parse().unwrap_or(0),
                    "lr" => cfg.lr = v.parse().unwrap_or(0.0),
                    _ => {}
                }
            }
        }
        if cfg.vocab == 0 || cfg.params.is_empty() {
            return Err(Status::invalid_argument(format!("incomplete meta {meta_path:?}")));
        }
        Ok(cfg)
    }

    /// Load a preset's metadata from the artifact directory.
    pub fn preset(name: &str) -> Result<TransformerConfig> {
        let dir = crate::runtime::artifact_dir();
        TransformerConfig::load(&dir.join(format!("transformer_{name}.meta.txt")))
    }

    pub fn num_params(&self) -> usize {
        self.params.iter().map(|(_, s, _)| s.num_elements()).sum()
    }

    pub fn hlo_path(&self, dir: &Path) -> std::path::PathBuf {
        dir.join(format!("transformer_{}.hlo.txt", self.name))
    }
}

/// Synthetic token stream with learnable structure: a noisy deterministic
/// successor map (90% `next = succ[cur]`, 10% uniform noise). A capable LM
/// approaches H = 0.1·ln(V) + H(0.9) ≈ low loss quickly — enough signal
/// for the loss-decreases validation (real corpora are a data gate; see
/// DESIGN.md substitutions).
pub struct TokenGen {
    succ: Vec<u32>,
    rng: Pcg32,
    vocab: usize,
}

impl TokenGen {
    pub fn new(vocab: usize, seed: u64) -> TokenGen {
        let mut rng = Pcg32::new(seed);
        let mut succ: Vec<u32> = (0..vocab as u32).collect();
        rng.shuffle(&mut succ);
        TokenGen { succ, rng: Pcg32::new(seed ^ 0xDEAD), vocab }
    }

    /// Sample a [batch, seq+1] i32 token tensor.
    pub fn batch(&mut self, batch: usize, seq_plus_one: usize) -> Tensor {
        let mut out = Vec::with_capacity(batch * seq_plus_one);
        for _ in 0..batch {
            let mut cur = self.rng.next_below(self.vocab as u32);
            out.push(cur as i32);
            for _ in 1..seq_plus_one {
                cur = if self.rng.next_f32() < 0.9 {
                    self.succ[cur as usize]
                } else {
                    self.rng.next_below(self.vocab as u32)
                };
                out.push(cur as i32);
            }
        }
        Tensor::new(Shape(vec![batch, seq_plus_one]), TensorData::I32(out)).unwrap()
    }
}

/// Owns the executable + parameter state; one `train_step` = one XLA
/// execution of the fused fwd/bwd/update program.
pub struct XlaTrainer {
    pub cfg: TransformerConfig,
    exe: Arc<XlaExecutable>,
    pub params: Vec<Tensor>,
    gen: TokenGen,
}

impl XlaTrainer {
    pub fn new(artifact_dir: &Path, cfg: &TransformerConfig, seed: u64) -> Result<XlaTrainer> {
        let exe = load_artifact(&cfg.hlo_path(artifact_dir))?;
        let mut rng = Pcg32::new(seed);
        let params = cfg
            .params
            .iter()
            .map(|(_, shape, init)| {
                let n = shape.num_elements();
                let data = match init.as_str() {
                    "ones" => vec![1.0f32; n],
                    "zeros" => vec![0.0; n],
                    _ => (0..n).map(|_| rng.normal() * 0.02).collect(),
                };
                Tensor::from_f32(shape.clone(), data)
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(XlaTrainer {
            cfg: cfg.clone(),
            exe,
            params,
            gen: TokenGen::new(cfg.vocab, seed ^ 0xBEEF),
        })
    }

    /// Run one fused train step; returns the loss.
    pub fn train_step(&mut self) -> Result<f32> {
        let tokens = self.gen.batch(self.cfg.batch, self.cfg.seq_len + 1);
        self.train_step_on(tokens)
    }

    /// Step on a caller-provided token batch (the distributed/data-parallel
    /// drivers shard data themselves).
    pub fn train_step_on(&mut self, tokens: Tensor) -> Result<f32> {
        let mut inputs = Vec::with_capacity(1 + self.params.len());
        inputs.push(tokens);
        inputs.extend(self.params.iter().cloned());
        let mut outputs = self.exe.run(&inputs)?;
        if outputs.len() != 1 + self.params.len() {
            return Err(Status::internal(format!(
                "train step returned {} outputs, expected {}",
                outputs.len(),
                1 + self.params.len()
            )));
        }
        let loss = outputs.remove(0).scalar_value_f32()?;
        self.params = outputs;
        Ok(loss)
    }

    /// Checkpoint the parameters (reuses the §3.3 bundle format).
    pub fn save(&self, path: &Path) -> Result<()> {
        let named: Vec<(String, Tensor)> = self
            .cfg
            .params
            .iter()
            .zip(&self.params)
            .map(|((n, _, _), t)| (n.clone(), t.clone()))
            .collect();
        crate::checkpoint::save_bundle(path, &named)
    }

    pub fn restore(&mut self, path: &Path) -> Result<()> {
        let bundle = crate::checkpoint::load_bundle(path)?;
        for ((name, _, _), slot) in self.cfg.params.iter().zip(self.params.iter_mut()) {
            *slot = bundle
                .get(name)
                .cloned()
                .ok_or_else(|| Status::not_found(format!("param {name:?} not in checkpoint")))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_gen_learnable_structure() {
        let mut g = TokenGen::new(64, 1);
        let t = g.batch(4, 33);
        assert_eq!(t.shape().dims(), &[4, 33]);
        let v = t.as_i32().unwrap();
        assert!(v.iter().all(|&x| (0..64).contains(&x)));
        // Successor structure: most transitions follow the map.
        let g2 = TokenGen::new(64, 1);
        let mut follows = 0;
        let mut total = 0;
        for row in 0..4 {
            for i in 0..32 {
                let cur = v[row * 33 + i] as usize;
                let next = v[row * 33 + i + 1] as u32;
                total += 1;
                if g2.succ[cur] == next {
                    follows += 1;
                }
            }
        }
        assert!(follows * 10 >= total * 7, "{follows}/{total} transitions follow the map");
    }

    #[test]
    fn meta_parse_roundtrip() {
        let dir = std::env::temp_dir();
        let p = dir.join(format!("rustflow-meta-{}.txt", std::process::id()));
        std::fs::write(
            &p,
            "name=tiny\nvocab=128\nd_model=64\nn_layers=2\nn_heads=2\nd_ff=256\nseq_len=32\nbatch=8\nlr=0.1\nparam tok_emb 128,64 normal\nparam b1 256 zeros\n",
        )
        .unwrap();
        let cfg = TransformerConfig::load(&p).unwrap();
        assert_eq!(cfg.vocab, 128);
        assert_eq!(cfg.params.len(), 2);
        assert_eq!(cfg.params[0].1.dims(), &[128, 64]);
        assert_eq!(cfg.params[1].2, "zeros");
        assert_eq!(cfg.num_params(), 128 * 64 + 256);
    }
}
