//! Input operations (§4.5): "special input operation nodes in the graph,
//! which are typically configured with a set of filenames, and which yield
//! a tensor containing one or more examples from the data stored in that
//! set of files each time they are executed."
//!
//! The record file format is a simple length-prefixed example container
//! (features f32 vector + i32 label). `RecordInput` reads round-robin over
//! its file list and emits `(features[batch,dim], labels[batch])`.
//! `synthetic` generates MNIST-like datasets for the examples and benches
//! (the image has no real datasets; see DESIGN.md substitutions).

use crate::error::{Result, Status};
use crate::kernels::{Kernel, KernelContext, KernelRegistry};
use crate::tensor::{Shape, Tensor, TensorData};
use crate::util::rng::Pcg32;
use crate::util::byteorder::LittleEndian;
use std::io::{Read, Write};
use std::path::Path;
use std::sync::Mutex;

const MAGIC: &[u8; 8] = b"RFLOWREC";

/// One labelled example.
#[derive(Debug, Clone, PartialEq)]
pub struct Example {
    pub features: Vec<f32>,
    pub label: i32,
}

/// Write examples to a record file.
pub fn write_records(path: &Path, examples: &[Example]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    let mut cnt = [0u8; 4];
    LittleEndian::write_u32(&mut cnt, examples.len() as u32);
    buf.extend_from_slice(&cnt);
    for ex in examples {
        let mut dim = [0u8; 4];
        LittleEndian::write_u32(&mut dim, ex.features.len() as u32);
        buf.extend_from_slice(&dim);
        for &f in &ex.features {
            let mut b = [0u8; 4];
            LittleEndian::write_f32(&mut b, f);
            buf.extend_from_slice(&b);
        }
        let mut lab = [0u8; 4];
        LittleEndian::write_i32(&mut lab, ex.label);
        buf.extend_from_slice(&lab);
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(&buf)?;
    Ok(())
}

/// Read every example in a record file.
pub fn read_records(path: &Path) -> Result<Vec<Example>> {
    let mut buf = Vec::new();
    std::fs::File::open(path)
        .map_err(|e| Status::not_found(format!("record file {path:?}: {e}")))?
        .read_to_end(&mut buf)?;
    if buf.len() < 12 || &buf[..8] != MAGIC {
        return Err(Status::invalid_argument(format!("{path:?} is not a rustflow record file")));
    }
    let count = LittleEndian::read_u32(&buf[8..12]) as usize;
    let mut pos = 12;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        if buf.len() < pos + 4 {
            return Err(Status::invalid_argument("truncated record file"));
        }
        let dim = LittleEndian::read_u32(&buf[pos..pos + 4]) as usize;
        pos += 4;
        if buf.len() < pos + dim * 4 + 4 {
            return Err(Status::invalid_argument("truncated record file"));
        }
        let mut features = Vec::with_capacity(dim);
        for i in 0..dim {
            features.push(LittleEndian::read_f32(&buf[pos + 4 * i..]));
        }
        pos += dim * 4;
        let label = LittleEndian::read_i32(&buf[pos..pos + 4]);
        pos += 4;
        out.push(Example { features, label });
    }
    Ok(out)
}

/// Synthetic MNIST-like dataset: class-conditional Gaussian blobs in
/// `dim`-dimensional space. Learnable but not trivially separable (blob
/// centers drawn at unit norm, per-pixel noise sigma configurable).
pub fn synthetic_classification(
    n: usize,
    dim: usize,
    classes: usize,
    noise: f32,
    seed: u64,
) -> Vec<Example> {
    let mut rng = Pcg32::new(seed);
    // Class centers.
    let centers: Vec<Vec<f32>> = (0..classes)
        .map(|_| {
            let v: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
            let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
            v.into_iter().map(|x| x / norm).collect()
        })
        .collect();
    (0..n)
        .map(|i| {
            let label = (i % classes) as i32;
            let c = &centers[label as usize];
            let features: Vec<f32> = c.iter().map(|&m| m + noise * rng.normal()).collect();
            Example { features, label }
        })
        .collect()
}

/// One-hot encode labels into [batch, classes] f32.
pub fn one_hot(labels: &[i32], classes: usize) -> Tensor {
    let mut out = vec![0f32; labels.len() * classes];
    for (i, &l) in labels.iter().enumerate() {
        out[i * classes + l as usize] = 1.0;
    }
    Tensor::new(Shape(vec![labels.len(), classes]), TensorData::F32(out)).unwrap()
}

/// Batch a slice of examples into (features, labels) tensors.
pub fn batch_tensors(examples: &[Example]) -> Result<(Tensor, Tensor)> {
    if examples.is_empty() {
        return Err(Status::invalid_argument("empty batch"));
    }
    let dim = examples[0].features.len();
    let mut feats = Vec::with_capacity(examples.len() * dim);
    let mut labels = Vec::with_capacity(examples.len());
    for ex in examples {
        if ex.features.len() != dim {
            return Err(Status::invalid_argument("ragged example dimensions"));
        }
        feats.extend_from_slice(&ex.features);
        labels.push(ex.label);
    }
    Ok((
        Tensor::new(Shape(vec![examples.len(), dim]), TensorData::F32(feats))?,
        Tensor::new(Shape(vec![examples.len()]), TensorData::I32(labels))?,
    ))
}

/// RecordInput kernel: round-robin batches over a file list, wrapping at
/// EOF (stateful op; §4.5 — "data read directly from the underlying
/// storage system into the memory of the machine that will perform
/// subsequent processing").
pub(crate) fn register_kernels(r: &mut KernelRegistry) {
    r.add("RecordInput", |node| {
        let files: Vec<String> = node.attr("files")?.as_list_str()?.to_vec();
        let batch = node.attr_opt("batch_size").and_then(|a| a.as_i64().ok()).unwrap_or(32) as usize;
        // Lazy-load on first execution; cursor is kernel state.
        struct State {
            examples: Vec<Example>,
            cursor: usize,
        }
        let state: Mutex<Option<State>> = Mutex::new(None);
        Ok(Kernel::Sync(Box::new(move |_ctx: &mut KernelContext| {
            let mut guard = state.lock().unwrap();
            if guard.is_none() {
                let mut all = Vec::new();
                for f in &files {
                    all.extend(read_records(Path::new(f))?);
                }
                if all.is_empty() {
                    return Err(Status::out_of_range("RecordInput: no examples in files"));
                }
                *guard = Some(State { examples: all, cursor: 0 });
            }
            let st = guard.as_mut().unwrap();
            let mut batch_ex = Vec::with_capacity(batch);
            for _ in 0..batch {
                batch_ex.push(st.examples[st.cursor].clone());
                st.cursor = (st.cursor + 1) % st.examples.len();
            }
            let (f, l) = batch_tensors(&batch_ex)?;
            Ok(vec![f, l])
        })))
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("rustflow-rec-{tag}-{}.rec", std::process::id()))
    }

    #[test]
    fn record_roundtrip() {
        let path = tmp("rt");
        let examples = vec![
            Example { features: vec![1., 2., 3.], label: 0 },
            Example { features: vec![4., 5., 6.], label: 1 },
        ];
        write_records(&path, &examples).unwrap();
        let back = read_records(&path).unwrap();
        assert_eq!(back, examples);
    }

    #[test]
    fn synthetic_is_deterministic_and_labeled() {
        let a = synthetic_classification(100, 8, 10, 0.1, 7);
        let b = synthetic_classification(100, 8, 10, 0.1, 7);
        assert_eq!(a, b);
        assert_eq!(a.len(), 100);
        // Labels round-robin over classes.
        assert!(a.iter().enumerate().all(|(i, e)| e.label == (i % 10) as i32));
    }

    #[test]
    fn synthetic_classes_separated() {
        // With tiny noise, same-class examples are closer than cross-class.
        let ex = synthetic_classification(40, 16, 2, 0.01, 3);
        let d = |a: &Example, b: &Example| -> f32 {
            a.features.iter().zip(&b.features).map(|(x, y)| (x - y) * (x - y)).sum()
        };
        let same = d(&ex[0], &ex[2]); // both class 0
        let cross = d(&ex[0], &ex[1]); // class 0 vs 1
        assert!(same < cross);
    }

    #[test]
    fn one_hot_encoding() {
        let t = one_hot(&[1, 0, 2], 3);
        assert_eq!(t.shape().dims(), &[3, 3]);
        assert_eq!(t.as_f32().unwrap(), &[0., 1., 0., 1., 0., 0., 0., 0., 1.]);
    }

    #[test]
    fn batch_tensors_shapes() {
        let ex = synthetic_classification(6, 4, 3, 0.1, 1);
        let (f, l) = batch_tensors(&ex).unwrap();
        assert_eq!(f.shape().dims(), &[6, 4]);
        assert_eq!(l.shape().dims(), &[6]);
        assert_eq!(l.as_i32().unwrap(), &[0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn ragged_batch_rejected() {
        let ex = vec![
            Example { features: vec![1.], label: 0 },
            Example { features: vec![1., 2.], label: 1 },
        ];
        assert!(batch_tensors(&ex).is_err());
    }
}
