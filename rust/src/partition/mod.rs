//! Graph partitioning + Send/Recv insertion (§3.2.2).
//!
//! "Once the node placement has been computed, the graph is partitioned
//! into a set of subgraphs, one per device. Any cross-device edge from x
//! to y is removed and replaced by an edge from x to a new Send node in
//! x's subgraph and an edge from a corresponding Receive node to y …
//! we canonicalize all users of a particular tensor on a particular device
//! to use a single Receive node … This ensures that the data for the
//! needed tensor is only transmitted once between a source device →
//! destination device pair."
//!
//! Cross-device *control* edges become a dummy-tensor Send/Recv pair whose
//! Recv feeds the consumer as a control input — the "necessary
//! synchronization between different workers and devices" that lets the
//! master issue a single Run per worker (§3.2.2 last paragraph).

use crate::error::{Result, Status};
use crate::graph::{AttrValue, Endpoint, Graph, Node, NodeId};
use crate::rendezvous::make_key;
use crate::tensor::Tensor;
use std::collections::{BTreeMap, HashMap};

/// Partitioning options.
#[derive(Debug, Clone)]
pub struct PartitionOptions {
    /// §3.2.2 canonicalization: one Recv per (tensor, dst device). Exposed
    /// as a switch so experiment E4 can measure its effect.
    pub canonicalize: bool,
    /// §5.5: compress f32 payloads to bf16 on cross-*task* edges.
    pub compress_cross_task: bool,
    /// Compress on every cross-device edge (for the E13 ablation).
    pub compress_all: bool,
}

impl Default for PartitionOptions {
    fn default() -> Self {
        PartitionOptions { canonicalize: true, compress_cross_task: true, compress_all: false }
    }
}

/// Statistics about a partitioning (consumed by E4/E13).
#[derive(Debug, Default, Clone)]
pub struct PartitionStats {
    pub num_partitions: usize,
    pub send_nodes: usize,
    pub recv_nodes: usize,
    /// Logical cross-device tensor transfers (== recv count).
    pub transfers: usize,
    pub compressed_transfers: usize,
}

/// One device's partition.
pub struct Partition {
    pub device: String,
    pub graph: Graph,
}

fn task_of(device: &str) -> &str {
    match device.find("/device:") {
        Some(i) => &device[..i],
        None => device,
    }
}

/// Split a placed graph into per-device partitions with Send/Recv pairs.
/// `step_prefix` namespaces rendezvous keys (distributed runs pass
/// "step:<id>"; local runs use a fresh rendezvous per step and pass "").
pub fn partition(
    graph: &Graph,
    options: &PartitionOptions,
    step_prefix: &str,
) -> Result<(Vec<Partition>, PartitionStats)> {
    // Group nodes by device.
    let mut device_names: Vec<String> = Vec::new();
    let mut node_device: Vec<usize> = Vec::with_capacity(graph.len());
    {
        let mut index: HashMap<&str, usize> = HashMap::new();
        for id in graph.ids() {
            let dev = graph.node(id).assigned_device.as_deref().ok_or_else(|| {
                Status::failed_precondition(format!(
                    "partition: node {:?} has no assigned device (run placement first)",
                    graph.node(id).name
                ))
            })?;
            let di = *index.entry(dev).or_insert_with(|| {
                device_names.push(dev.to_string());
                device_names.len() - 1
            });
            node_device.push(di);
        }
    }

    let mut parts: Vec<Graph> = device_names.iter().map(|_| Graph::new()).collect();
    // old node -> (partition, new id)
    let mut remap: HashMap<NodeId, (usize, NodeId)> = HashMap::new();
    // Canonicalized recv: (src node, port, dst partition) -> recv endpoint.
    let mut recv_cache: HashMap<(NodeId, usize, usize), Endpoint> = HashMap::new();
    // Canonicalized control recv: (src node, dst partition) -> recv node.
    let mut ctrl_recv_cache: HashMap<(NodeId, usize), NodeId> = HashMap::new();
    let mut stats = PartitionStats { num_partitions: parts.len(), ..Default::default() };

    let order = graph.topo_order()?;
    for id in order {
        let node = graph.node(id);
        let dst_part = node_device[id.0];
        let dst_dev = &device_names[dst_part];

        // Resolve inputs, inserting Send/Recv for cross-device edges.
        // Loop back-edges (Merge ← NextIteration) reference nodes not yet
        // remapped; they are truncated here and patched after the main
        // loop (loop frames are colocated, so the patch is device-local).
        let mut new_inputs = Vec::with_capacity(node.inputs.len());
        for e in &node.inputs {
            if !remap.contains_key(&e.node) {
                break;
            }
            let (src_part, src_new) = remap[&e.node];
            if src_part == dst_part {
                new_inputs.push(Endpoint::new(src_new, e.port));
                continue;
            }
            let cache_key = (e.node, e.port, dst_part);
            if options.canonicalize {
                if let Some(&recv) = recv_cache.get(&cache_key) {
                    new_inputs.push(recv);
                    continue;
                }
            }
            let src_dev = &device_names[src_part];
            let compress = options.compress_all
                || (options.compress_cross_task && task_of(src_dev) != task_of(dst_dev));
            let tensor_name = format!("{}:{}", graph.node(e.node).name, e.port);
            // Non-canonical duplicates need distinct keys.
            let dup = if options.canonicalize {
                String::new()
            } else {
                format!("#{}", stats.transfers)
            };
            let key =
                format!("{step_prefix}{}", make_key(src_dev, dst_dev, &format!("{tensor_name}{dup}"), "0:0"));
            // Send on the source partition.
            let send_name = parts[src_part].unique_name(&format!("_send/{tensor_name}{dup}"));
            parts[src_part].add(Node {
                name: send_name,
                op: "_Send".into(),
                inputs: vec![Endpoint::new(src_new, e.port)],
                control_inputs: vec![],
                attrs: send_attrs(&key, compress),
                requested_device: String::new(),
                assigned_device: Some(src_dev.clone()),
            })?;
            stats.send_nodes += 1;
            // Recv on the destination partition.
            let recv_name = parts[dst_part].unique_name(&format!("_recv/{tensor_name}{dup}"));
            let recv_id = parts[dst_part].add(Node {
                name: recv_name,
                op: "_Recv".into(),
                inputs: vec![],
                control_inputs: vec![],
                attrs: recv_attrs(&key),
                requested_device: String::new(),
                assigned_device: Some(dst_dev.clone()),
            })?;
            stats.recv_nodes += 1;
            stats.transfers += 1;
            if compress {
                stats.compressed_transfers += 1;
            }
            let recv_ep = Endpoint::new(recv_id, 0);
            if options.canonicalize {
                recv_cache.insert(cache_key, recv_ep);
            }
            new_inputs.push(recv_ep);
        }

        // Control inputs: same-device stay control edges; cross-device get
        // a dummy-tensor Send/Recv carrying the happens-before.
        let mut new_controls = Vec::new();
        for c in &node.control_inputs {
            let (src_part, src_new) = remap[c];
            if src_part == dst_part {
                new_controls.push(src_new);
                continue;
            }
            let cache_key = (*c, dst_part);
            if options.canonicalize {
                if let Some(&recv) = ctrl_recv_cache.get(&cache_key) {
                    new_controls.push(recv);
                    continue;
                }
            }
            let src_dev = &device_names[src_part];
            let tensor_name = format!("^{}", graph.node(*c).name);
            let key = format!("{step_prefix}{}", make_key(src_dev, dst_dev, &tensor_name, "0:0"));
            // Dummy const on the source, control-gated by the src node.
            let dummy_name = parts[src_part].unique_name(&format!("_ctrl_dummy/{}", graph.node(*c).name));
            let dummy_id = parts[src_part].add(Node {
                name: dummy_name,
                op: "Const".into(),
                inputs: vec![],
                control_inputs: vec![src_new],
                attrs: {
                    let mut a = BTreeMap::new();
                    a.insert("value".to_string(), AttrValue::Tensor(Tensor::scalar_f32(0.0)));
                    a
                },
                requested_device: String::new(),
                assigned_device: Some(src_dev.clone()),
            })?;
            let send_name = parts[src_part].unique_name(&format!("_send{tensor_name}"));
            parts[src_part].add(Node {
                name: send_name,
                op: "_Send".into(),
                inputs: vec![Endpoint::new(dummy_id, 0)],
                control_inputs: vec![],
                attrs: send_attrs(&key, false),
                requested_device: String::new(),
                assigned_device: Some(src_dev.clone()),
            })?;
            stats.send_nodes += 1;
            let recv_name = parts[dst_part].unique_name(&format!("_recv{tensor_name}"));
            let recv_id = parts[dst_part].add(Node {
                name: recv_name,
                op: "_Recv".into(),
                inputs: vec![],
                control_inputs: vec![],
                attrs: recv_attrs(&key),
                requested_device: String::new(),
                assigned_device: Some(dst_dev.clone()),
            })?;
            stats.recv_nodes += 1;
            stats.transfers += 1;
            if options.canonicalize {
                ctrl_recv_cache.insert(cache_key, recv_id);
            }
            new_controls.push(recv_id);
        }

        let new_id = parts[dst_part].add(Node {
            name: node.name.clone(),
            op: node.op.clone(),
            inputs: new_inputs,
            control_inputs: new_controls,
            attrs: node.attrs.clone(),
            requested_device: node.requested_device.clone(),
            assigned_device: node.assigned_device.clone(),
        })?;
        remap.insert(id, (dst_part, new_id));
    }

    // Patch NextIteration back-edges (skipped by topo order): Merge nodes
    // may reference NextIteration inputs that were added later.
    for id in graph.ids() {
        let node = graph.node(id);
        let (part, new_id) = remap[&id];
        if node.inputs.len() != parts[part].node(new_id).inputs.len() {
            // Rebuild the full input list: loop frames are colocated, so
            // all inputs are local now.
            let rebuilt: Vec<Endpoint> = node
                .inputs
                .iter()
                .map(|e| {
                    let (sp, sn) = remap[&e.node];
                    debug_assert_eq!(sp, part, "loop back-edge must be device-local");
                    Endpoint::new(sn, e.port)
                })
                .collect();
            parts[part].node_mut(new_id).inputs = rebuilt;
        }
    }

    Ok((
        device_names
            .into_iter()
            .zip(parts)
            .map(|(device, graph)| Partition { device, graph })
            .collect(),
        stats,
    ))
}

fn send_attrs(key: &str, compress: bool) -> BTreeMap<String, AttrValue> {
    let mut a = BTreeMap::new();
    a.insert("key".to_string(), AttrValue::Str(key.to_string()));
    if compress {
        a.insert("compress".to_string(), AttrValue::Bool(true));
    }
    a
}

fn recv_attrs(key: &str) -> BTreeMap<String, AttrValue> {
    let mut a = BTreeMap::new();
    a.insert("key".to_string(), AttrValue::Str(key.to_string()));
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceSet;
    use crate::ops::builder::GraphBuilder;
    use crate::placement::{place, CostModel};

    fn two_device_graph() -> Graph {
        // Figure 4's shape: x on dev0; consumers b, c on dev1.
        let mut b = GraphBuilder::new();
        let x = b.with_device("/device:cpu:0", |b| b.scalar(1.0));
        let w = b.with_device("/device:cpu:0", |b| b.scalar(2.0));
        let _a = b.with_device("/device:cpu:0", |b| b.mul(w, x));
        let y = b.with_device("/device:cpu:1", |b| b.add(x, x)); // consumer 1
        let _z = b.with_device("/device:cpu:1", |b| b.mul(x, y)); // consumer 2
        let devices = DeviceSet::local(2, 1);
        place(&mut b.graph, &devices, &CostModel::new()).unwrap();
        b.graph
    }

    #[test]
    fn canonicalization_single_transfer_per_pair() {
        // Fig 4: b and c both read x on the other device — with
        // canonicalization, x is transmitted ONCE.
        let g = two_device_graph();
        let (parts, stats) = partition(&g, &PartitionOptions::default(), "").unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(stats.transfers, 1, "canonicalized: one transfer for x");
        assert_eq!(stats.send_nodes, 1);
        assert_eq!(stats.recv_nodes, 1);
    }

    #[test]
    fn naive_mode_duplicates_transfers() {
        let g = two_device_graph();
        let opts = PartitionOptions { canonicalize: false, ..Default::default() };
        let (_, stats) = partition(&g, &opts, "").unwrap();
        assert_eq!(stats.transfers, 3, "naive: one per consumer edge (x→Add twice, x→Mul)");
    }

    #[test]
    fn single_device_graph_unchanged() {
        let mut b = GraphBuilder::new();
        let x = b.scalar(1.0);
        let y = b.scalar(2.0);
        b.add(x, y);
        let devices = DeviceSet::local(1, 1);
        place(&mut b.graph, &devices, &CostModel::new()).unwrap();
        let (parts, stats) = partition(&b.graph, &PartitionOptions::default(), "").unwrap();
        assert_eq!(parts.len(), 1);
        assert_eq!(stats.transfers, 0);
        assert_eq!(parts[0].graph.len(), 3);
    }

    #[test]
    fn cross_device_control_edge_becomes_send_recv() {
        let mut b = GraphBuilder::new();
        let x = b.with_device("/device:cpu:0", |b| b.scalar(1.0));
        let y = b.with_device("/device:cpu:1", |b| b.scalar(2.0));
        b.add_control_input(y.node, x.node);
        let devices = DeviceSet::local(2, 1);
        place(&mut b.graph, &devices, &CostModel::new()).unwrap();
        let (parts, stats) = partition(&b.graph, &PartitionOptions::default(), "").unwrap();
        assert_eq!(stats.transfers, 1);
        // dst partition's y must have a control input on the recv node.
        let dst = parts.iter().find(|p| p.device.ends_with("cpu:1")).unwrap();
        let yn = dst.graph.find("Const_1").or(dst.graph.find("Const")).unwrap();
        assert!(!dst.graph.node(yn).control_inputs.is_empty());
    }

    #[test]
    fn compression_flag_set_on_cross_task_edges() {
        // Build a graph placed across two *tasks*.
        let mut b = GraphBuilder::new();
        let x = b.with_device("/job:worker/task:0", |b| b.scalar(1.0));
        let _y = b.with_device("/job:worker/task:1", |b| b.identity(x));
        use crate::device::{Device, DeviceSpec};
        use std::sync::Arc;
        let devices = DeviceSet::new(vec![
            Arc::new(Device::new(DeviceSpec::worker_cpu(0, 0), 1)),
            Arc::new(Device::new(DeviceSpec::worker_cpu(1, 0), 1)),
        ]);
        place(&mut b.graph, &devices, &CostModel::new()).unwrap();
        let (parts, stats) = partition(&b.graph, &PartitionOptions::default(), "").unwrap();
        assert_eq!(stats.transfers, 1);
        assert_eq!(stats.compressed_transfers, 1);
        // The Send node carries compress=true.
        let src = parts.iter().find(|p| p.device.contains("task:0")).unwrap();
        let send = src.graph.nodes.iter().find(|n| n.op == "_Send").unwrap();
        assert!(send.attrs.get("compress").unwrap().as_bool().unwrap());
    }

    #[test]
    fn same_task_edges_not_compressed_by_default() {
        let g = two_device_graph();
        let (_, stats) = partition(&g, &PartitionOptions::default(), "").unwrap();
        assert_eq!(stats.compressed_transfers, 0);
    }

    #[test]
    fn step_prefix_namespaces_keys() {
        let g = two_device_graph();
        let (parts, _) = partition(&g, &PartitionOptions::default(), "step:7;").unwrap();
        let send = parts
            .iter()
            .flat_map(|p| p.graph.nodes.iter())
            .find(|n| n.op == "_Send")
            .unwrap();
        assert!(send.attrs.get("key").unwrap().as_str().unwrap().starts_with("step:7;"));
    }
}
