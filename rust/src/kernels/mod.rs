//! Kernels: device-specific implementations of operations (§2 "a kernel is
//! a particular implementation of an operation that can be run on a
//! particular type of device"), plus the kernel registration mechanism and
//! the execution context handed to each kernel invocation.
//!
//! Two kernel flavours, exactly §5.3: synchronous kernels return their
//! outputs from `compute`; asynchronous kernels (Receive, Enqueue,
//! Dequeue, MutexAcquire) are "passed a continuation that should be
//! invoked when the kernel's execution is complete", so blocked I/O never
//! ties up an executor thread.

pub mod array;
pub mod comm;
pub mod fused;
pub mod math;
pub mod matrix;
pub mod nn;
pub mod queue_ops;
pub mod state;
pub mod summary;

use crate::device::Device;
#[allow(unused_imports)]
use crate::error::{Result, Status};
use crate::graph::AttrValue;
use crate::rendezvous::Rendezvous;
use crate::resources::ResourceMgr;
use crate::tensor::Tensor;
use std::sync::LazyLock as Lazy;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};

/// Static description of a node, precomputed when an executable graph is
/// built: attrs plus resolved resource references (which Variable/queue
/// node a ref-input points at).
#[derive(Debug, Clone)]
pub struct NodeInfo {
    pub name: String,
    pub op: String,
    pub attrs: BTreeMap<String, AttrValue>,
    /// For ops whose input 0 is a resource ref (Assign, Apply*, Enqueue…):
    /// the name of the producing Variable / queue node — the resource key.
    pub ref_resource: Option<String>,
    /// Container the resource lives in (attr "container", default "").
    pub container: String,
    /// Device this node was placed on (full name).
    pub device_name: String,
}

impl NodeInfo {
    pub fn attr(&self, name: &str) -> Result<&AttrValue> {
        self.attrs
            .get(name)
            .ok_or_else(|| Status::invalid_argument(format!("node {}: missing attr {name}", self.name)))
    }

    pub fn attr_opt(&self, name: &str) -> Option<&AttrValue> {
        self.attrs.get(name)
    }

    pub fn ref_resource(&self) -> Result<&str> {
        self.ref_resource
            .as_deref()
            .ok_or_else(|| Status::internal(format!("node {}: unresolved resource ref", self.name)))
    }
}

/// Per-Run cancellation + fetch collection, shared by all partitions of a
/// step.
#[derive(Default)]
pub struct StepState {
    pub step_id: u64,
    fetches: Mutex<HashMap<String, Tensor>>,
    cancelled: AtomicBool,
    cancel_status: Mutex<Option<Status>>,
    cancel_cond: Condvar,
}

impl StepState {
    pub fn new(step_id: u64) -> Arc<StepState> {
        Arc::new(StepState { step_id, ..Default::default() })
    }

    pub fn put_fetch(&self, name: &str, t: Tensor) {
        self.fetches.lock().unwrap().insert(name.to_string(), t);
    }

    pub fn take_fetches(&self) -> HashMap<String, Tensor> {
        std::mem::take(&mut *self.fetches.lock().unwrap())
    }

    /// First cancellation wins; later calls are ignored.
    pub fn cancel(&self, status: Status) {
        let mut s = self.cancel_status.lock().unwrap();
        if s.is_none() {
            *s = Some(status);
            self.cancelled.store(true, Ordering::SeqCst);
            self.cancel_cond.notify_all();
        }
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::SeqCst)
    }

    pub fn cancel_status(&self) -> Option<Status> {
        self.cancel_status.lock().unwrap().clone()
    }
}

/// Everything a kernel invocation may touch. Owned (Arc-based) so async
/// kernels can carry it into their continuation.
pub struct KernelContext {
    pub inputs: Vec<Tensor>,
    pub node: Arc<NodeInfo>,
    pub device: Arc<Device>,
    pub resources: Arc<ResourceMgr>,
    pub rendezvous: Arc<dyn Rendezvous>,
    pub step: Arc<StepState>,
}

impl KernelContext {
    pub fn input(&self, i: usize) -> Result<&Tensor> {
        self.inputs
            .get(i)
            .ok_or_else(|| Status::internal(format!("node {}: missing input {i}", self.node.name)))
    }

    /// The container holding this node's resources.
    pub fn container(&self) -> Arc<crate::resources::Container> {
        self.resources.container(&self.node.container)
    }
}

pub type DoneFn = Box<dyn FnOnce(Result<Vec<Tensor>>) + Send>;
pub type SyncFn = Box<dyn Fn(&mut KernelContext) -> Result<Vec<Tensor>> + Send + Sync>;
pub type AsyncFn = Box<dyn Fn(KernelContext, DoneFn) + Send + Sync>;

/// An instantiated kernel, bound to one node.
pub enum Kernel {
    Sync(SyncFn),
    Async(AsyncFn),
}

impl Kernel {
    pub fn is_async(&self) -> bool {
        matches!(self, Kernel::Async(_))
    }
}

/// Kernel factory: builds a kernel instance for a node (may precompute
/// from attrs).
pub type KernelFactory = Arc<dyn Fn(&NodeInfo) -> Result<Kernel> + Send + Sync>;

pub(crate) struct KernelRegistry {
    /// (op name, device type) -> factory.
    factories: HashMap<(String, String), KernelFactory>,
}

static REGISTRY: Lazy<RwLock<KernelRegistry>> = Lazy::new(|| {
    let mut r = KernelRegistry { factories: HashMap::new() };
    install_cpu_kernels(&mut r);
    RwLock::new(r)
});

/// Register a kernel for (op, device_type). Later registrations replace
/// earlier ones (lets tests/extensions override built-ins).
pub fn register_kernel(op: &str, device_type: &str, factory: KernelFactory) {
    REGISTRY
        .write()
        .unwrap()
        .factories
        .insert((op.to_string(), device_type.to_lowercase()), factory);
}

/// Instantiate the kernel for `node` on a device of type `device_type`.
pub fn create_kernel(node: &NodeInfo, device_type: &str) -> Result<Kernel> {
    let reg = REGISTRY.read().unwrap();
    let factory = reg
        .factories
        .get(&(node.op.clone(), device_type.to_lowercase()))
        .ok_or_else(|| {
            Status::not_found(format!(
                "no kernel for op {:?} on device type {:?}",
                node.op, device_type
            ))
        })?;
    factory(node)
}

/// Does a kernel exist for (op, device_type)? The §3.2.1 placement
/// feasibility test ("a device may not be feasible if the device does not
/// provide a kernel that implements the particular operation").
pub fn has_kernel(op: &str, device_type: &str) -> bool {
    // Control-flow ops execute inside the executor itself, on any device.
    if matches!(op, "Switch" | "Merge" | "Enter" | "Exit" | "NextIteration") {
        return true;
    }
    REGISTRY
        .read()
        .unwrap()
        .factories
        .contains_key(&(op.to_string(), device_type.to_lowercase()))
}

fn install_cpu_kernels(r: &mut KernelRegistry) {
    math::register(r);
    array::register(r);
    fused::register(r);
    matrix::register(r);
    nn::register(r);
    state::register(r);
    queue_ops::register(r);
    comm::register(r);
    summary::register(r);
    crate::checkpoint::register_kernels(r);
    crate::data::register_kernels(r);
    crate::runtime::register_kernels(r);
}

impl KernelRegistry {
    /// Register a CPU-device kernel factory (module-internal registration
    /// path; external code uses [`register_kernel`]).
    pub(crate) fn add(
        &mut self,
        op: &str,
        factory: impl Fn(&NodeInfo) -> Result<Kernel> + Send + Sync + 'static,
    ) {
        self.factories.insert((op.to_string(), "cpu".to_string()), Arc::new(factory));
    }

    /// Register a *sync* kernel given just the compute fn.
    pub(crate) fn add_sync(
        &mut self,
        op: &str,
        f: impl Fn(&mut KernelContext) -> Result<Vec<Tensor>> + Send + Sync + Clone + 'static,
    ) {
        self.add(op, move |_node| {
            let f = f.clone();
            Ok(Kernel::Sync(Box::new(move |ctx| f(ctx))))
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_core_kernels() {
        for op in ["Add", "MatMul", "Const", "ReLU", "Variable", "Assign", "_Send", "_Recv"] {
            assert!(has_kernel(op, "cpu"), "missing cpu kernel for {op}");
        }
        assert!(!has_kernel("Add", "tpu"));
        assert!(has_kernel("Switch", "anything")); // executor-internal
    }

    #[test]
    fn step_state_cancel_once() {
        let s = StepState::new(1);
        assert!(!s.is_cancelled());
        s.cancel(Status::aborted("first"));
        s.cancel(Status::internal("second"));
        assert!(s.is_cancelled());
        assert_eq!(s.cancel_status().unwrap().message, "first");
    }

    #[test]
    fn step_state_fetches() {
        let s = StepState::new(1);
        s.put_fetch("a:0", Tensor::scalar_f32(1.0));
        s.put_fetch("b:0", Tensor::scalar_f32(2.0));
        let f = s.take_fetches();
        assert_eq!(f.len(), 2);
        assert!(s.take_fetches().is_empty());
    }
}
