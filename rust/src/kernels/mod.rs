//! Kernels: device-specific implementations of operations (§2 "a kernel is
//! a particular implementation of an operation that can be run on a
//! particular type of device"), plus the kernel registration mechanism and
//! the execution context handed to each kernel invocation.
//!
//! Two kernel flavours, exactly §5.3: synchronous kernels return their
//! outputs from `compute`; asynchronous kernels (Receive, Enqueue,
//! Dequeue, MutexAcquire) are "passed a continuation that should be
//! invoked when the kernel's execution is complete", so blocked I/O never
//! ties up an executor thread.

pub mod array;
pub mod comm;
pub mod fused;
pub mod math;
pub mod matrix;
pub mod nn;
pub mod queue_ops;
pub mod sparse;
pub mod state;
pub mod summary;

use crate::device::{ComputePool, Device};
#[allow(unused_imports)]
use crate::error::{Result, Status};
use crate::graph::AttrValue;
use crate::memory::{MemoryPlan, StepArena};
use crate::rendezvous::Rendezvous;
use crate::resources::ResourceMgr;
use crate::tensor::{BufRecycler, DType, Shape, Tensor, TensorBuffer, TensorData};
use std::sync::LazyLock as Lazy;
use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};

/// Static description of a node, precomputed when an executable graph is
/// built: attrs plus resolved resource references (which Variable/queue
/// node a ref-input points at).
#[derive(Debug, Clone)]
pub struct NodeInfo {
    pub name: String,
    pub op: String,
    pub attrs: BTreeMap<String, AttrValue>,
    /// For ops whose input 0 is a resource ref (Assign, Apply*, Enqueue…):
    /// the name of the producing Variable / queue node — the resource key.
    pub ref_resource: Option<String>,
    /// Container the resource lives in (attr "container", default "").
    pub container: String,
    /// Device this node was placed on (full name).
    pub device_name: String,
}

impl NodeInfo {
    pub fn attr(&self, name: &str) -> Result<&AttrValue> {
        self.attrs
            .get(name)
            .ok_or_else(|| Status::invalid_argument(format!("node {}: missing attr {name}", self.name)))
    }

    pub fn attr_opt(&self, name: &str) -> Option<&AttrValue> {
        self.attrs.get(name)
    }

    pub fn ref_resource(&self) -> Result<&str> {
        self.ref_resource
            .as_deref()
            .ok_or_else(|| Status::internal(format!("node {}: unresolved resource ref", self.name)))
    }
}

/// Per-Run cancellation + fetch collection, shared by all partitions of a
/// step.
#[derive(Default)]
pub struct StepState {
    pub step_id: u64,
    fetches: Mutex<HashMap<String, Tensor>>,
    cancelled: AtomicBool,
    cancel_status: Mutex<Option<Status>>,
    cancel_cond: Condvar,
}

impl StepState {
    pub fn new(step_id: u64) -> Arc<StepState> {
        Arc::new(StepState { step_id, ..Default::default() })
    }

    pub fn put_fetch(&self, name: &str, t: Tensor) {
        self.fetches.lock().unwrap().insert(name.to_string(), t);
    }

    pub fn take_fetches(&self) -> HashMap<String, Tensor> {
        std::mem::take(&mut *self.fetches.lock().unwrap())
    }

    /// First cancellation wins; later calls are ignored.
    pub fn cancel(&self, status: Status) {
        let mut s = self.cancel_status.lock().unwrap();
        if s.is_none() {
            *s = Some(status);
            self.cancelled.store(true, Ordering::SeqCst);
            self.cancel_cond.notify_all();
        }
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::SeqCst)
    }

    pub fn cancel_status(&self) -> Option<Status> {
        self.cancel_status.lock().unwrap().clone()
    }
}

/// This node's binding into the step's memory plan: which arena slots its
/// outputs land in and which inputs may be overwritten in place. `None` on
/// `KernelContext::mem` when planning is off or the partition has no plan.
pub struct NodeMemory {
    pub arena: Arc<StepArena>,
    pub plan: Arc<MemoryPlan>,
    /// Compiled-graph index of the node this invocation executes.
    pub node: usize,
}

impl NodeMemory {
    fn out_slot(&self, port: usize) -> Option<u32> {
        self.plan.out_slot(self.node, port)
    }

    fn forwardable(&self, slot: usize) -> bool {
        self.plan.input_forwardable(self.node, slot)
    }
}

/// An input stolen for in-place reuse by [`KernelContext::take_forward_f32`]:
/// unique f32 storage (mutate freely) plus the recycler that keeps it
/// flowing back to its arena slot.
pub struct ForwardedF32 {
    pub shape: Shape,
    pub vec: Vec<f32>,
    recycler: Option<Arc<dyn BufRecycler>>,
}

impl ForwardedF32 {
    /// Rewrap the (now mutated) storage as the kernel's output tensor.
    pub fn into_tensor(self) -> Result<Tensor> {
        Tensor::with_buffer(
            self.shape,
            TensorBuffer::from_parts(TensorData::F32(self.vec), self.recycler),
        )
    }
}

/// Where kernel-internal scratch buffers (GEMM packing panels, im2col
/// patches) come from and return to: the step arena when the kernel runs
/// inside a planned step — so steady-state steps reuse one allocation —
/// or the compute pool's side pool for free-function callers outside a
/// step.
#[derive(Clone, Copy)]
pub enum ScratchSource<'a> {
    Arena(&'a StepArena),
    Pool(&'a ComputePool),
}

impl ScratchSource<'_> {
    /// An empty `Vec<f32>` with capacity ≥ `n`, pooled where possible.
    pub fn take_f32(&self, n: usize) -> Vec<f32> {
        match self {
            ScratchSource::Arena(a) => a.take_scratch_f32(n),
            ScratchSource::Pool(p) => p.take_scratch_f32(n),
        }
    }

    /// Hand a buffer from [`ScratchSource::take_f32`] back to its pool.
    pub fn give_f32(&self, v: Vec<f32>) {
        match self {
            ScratchSource::Arena(a) => a.give_scratch_f32(v),
            ScratchSource::Pool(p) => p.give_scratch_f32(v),
        }
    }
}

/// Stand-in left in `inputs[i]` after a forward steals the real tensor
/// (cloning is just an Arc bump).
static FORWARD_PLACEHOLDER: Lazy<Tensor> = Lazy::new(|| Tensor::scalar_f32(0.0));

/// Everything a kernel invocation may touch. Owned (Arc-based) so async
/// kernels can carry it into their continuation.
pub struct KernelContext {
    pub inputs: Vec<Tensor>,
    pub node: Arc<NodeInfo>,
    pub device: Arc<Device>,
    pub resources: Arc<ResourceMgr>,
    pub rendezvous: Arc<dyn Rendezvous>,
    pub step: Arc<StepState>,
    /// Step-memory-plan binding (None ⇒ every output heap-allocates).
    pub mem: Option<NodeMemory>,
}

impl KernelContext {
    pub fn input(&self, i: usize) -> Result<&Tensor> {
        let t = self
            .inputs
            .get(i)
            .ok_or_else(|| Status::internal(format!("node {}: missing input {i}", self.node.name)))?;
        // A forwarded input's storage now belongs to the output being
        // built; reading the stand-in would silently compute on 0.0, so
        // fail loudly instead (kernel-author bug, not a user error).
        if std::ptr::eq(t.data(), FORWARD_PLACEHOLDER.data()) {
            return Err(Status::internal(format!(
                "node {}: input {i} was forwarded in place and can no longer be read",
                self.node.name
            )));
        }
        Ok(t)
    }

    /// The container holding this node's resources.
    pub fn container(&self) -> Arc<crate::resources::Container> {
        self.resources.container(&self.node.container)
    }

    // ---- intra-op parallelism (the device's compute pool) ---------------

    /// Run `f` over `0..total` in deterministic contiguous chunks on this
    /// device's intra-op compute pool — inline on the calling thread when
    /// `total × cost_per_item` is small, so tiny tensors never pay
    /// synchronization. See [`crate::device::ComputePool::parallel_for`]
    /// for the determinism and panic contract.
    pub fn parallel_for<F>(&self, total: usize, cost_per_item: usize, f: F)
    where
        F: Fn(std::ops::Range<usize>) + Sync,
    {
        self.device.compute.parallel_for(total, cost_per_item, f)
    }

    /// The scratch pool for this invocation's internal buffers: the step
    /// arena when planned, the device compute pool's side pool otherwise.
    pub fn scratch(&self) -> ScratchSource<'_> {
        match &self.mem {
            Some(m) => ScratchSource::Arena(&m.arena),
            None => ScratchSource::Pool(&self.device.compute),
        }
    }

    // ---- step-memory-plan hooks (opt-in per kernel; see crate::memory) --

    /// An output Vec for an f32 result of `n` elements at `port`: checked
    /// out of the step arena when the plan assigned the port a slot, fresh
    /// otherwise. Returned empty with capacity ≥ `n`; push exactly `n`
    /// elements, then wrap with [`KernelContext::make_output`].
    pub fn alloc_f32(&self, port: usize, n: usize) -> Vec<f32> {
        match self.mem.as_ref().and_then(|m| m.out_slot(port).map(|s| (m, s))) {
            Some((m, slot)) => m.arena.checkout_f32(slot as usize, n),
            None => Vec::with_capacity(n),
        }
    }

    /// Like [`KernelContext::alloc_f32`] but zero-filled to `len == n`,
    /// for kernels that write by index (MatMul).
    pub fn alloc_f32_zeroed(&self, port: usize, n: usize) -> Vec<f32> {
        match self.mem.as_ref().and_then(|m| m.out_slot(port).map(|s| (m, s))) {
            Some((m, slot)) => m.arena.checkout_f32_zeroed(slot as usize, n),
            None => vec![0.0; n],
        }
    }

    /// [`KernelContext::alloc_f32`] for i32 outputs (index tensors).
    pub fn alloc_i32(&self, port: usize, n: usize) -> Vec<i32> {
        match self.mem.as_ref().and_then(|m| m.out_slot(port).map(|s| (m, s))) {
            Some((m, slot)) => m.arena.checkout_i32(slot as usize, n),
            None => Vec::with_capacity(n),
        }
    }

    /// [`KernelContext::alloc_f32`] for i64 outputs (index tensors).
    pub fn alloc_i64(&self, port: usize, n: usize) -> Vec<i64> {
        match self.mem.as_ref().and_then(|m| m.out_slot(port).map(|s| (m, s))) {
            Some((m, slot)) => m.arena.checkout_i64(slot as usize, n),
            None => Vec::with_capacity(n),
        }
    }

    /// [`KernelContext::alloc_f32`] for f64 outputs.
    pub fn alloc_f64(&self, port: usize, n: usize) -> Vec<f64> {
        match self.mem.as_ref().and_then(|m| m.out_slot(port).map(|s| (m, s))) {
            Some((m, slot)) => m.arena.checkout_f64(slot as usize, n),
            None => Vec::with_capacity(n),
        }
    }

    /// [`KernelContext::alloc_f32_zeroed`] for f64 outputs.
    pub fn alloc_f64_zeroed(&self, port: usize, n: usize) -> Vec<f64> {
        match self.mem.as_ref().and_then(|m| m.out_slot(port).map(|s| (m, s))) {
            Some((m, slot)) => m.arena.checkout_f64_zeroed(slot as usize, n),
            None => vec![0.0; n],
        }
    }

    /// Wrap `data` as the output tensor for `port`, attaching the arena
    /// slot's recycler when the port is planned so the storage returns to
    /// the pool at last drop. Pass storage from `alloc_f32*` here; heap
    /// data is also fine (it just won't recycle).
    pub fn make_output(
        &self,
        port: usize,
        shape: impl Into<Shape>,
        data: TensorData,
    ) -> Result<Tensor> {
        match self.mem.as_ref().and_then(|m| m.out_slot(port).map(|s| (m, s))) {
            Some((m, slot)) => Tensor::with_buffer(
                shape,
                TensorBuffer::recycled(data, m.arena.recycler(slot as usize)),
            ),
            None => Tensor::new(shape, data),
        }
    }

    /// In-place forwarding: steal input `i`'s f32 storage when the plan
    /// marks this node as the input's last use *and* this invocation holds
    /// the only reference to it. Mutate the returned Vec and return
    /// [`ForwardedF32::into_tensor`] as the output — the output then
    /// aliases the input's slot instead of taking a new one. Returns
    /// `None` (inputs untouched) in every other case.
    pub fn take_forward_f32(&mut self, i: usize) -> Option<ForwardedF32> {
        let m = self.mem.as_ref()?;
        if !m.forwardable(i) {
            return None;
        }
        {
            let t = self.inputs.get(i)?;
            if t.dtype() != DType::F32 || t.ref_count() != 1 {
                return None;
            }
        }
        let t = std::mem::replace(&mut self.inputs[i], FORWARD_PLACEHOLDER.clone());
        match t.try_into_parts() {
            Ok((shape, TensorData::F32(vec), recycler)) => {
                if let Some(m) = &self.mem {
                    m.arena.counters().note_forward(vec.len() * 4);
                }
                Some(ForwardedF32 { shape, vec, recycler })
            }
            Ok((shape, data, recycler)) => {
                // Unreachable (dtype checked above), but restore anyway.
                self.inputs[i] =
                    Tensor::with_buffer(shape, TensorBuffer::from_parts(data, recycler))
                        .expect("restoring stolen input");
                None
            }
            Err(t) => {
                self.inputs[i] = t;
                None
            }
        }
    }
}

pub type DoneFn = Box<dyn FnOnce(Result<Vec<Tensor>>) + Send>;
pub type SyncFn = Box<dyn Fn(&mut KernelContext) -> Result<Vec<Tensor>> + Send + Sync>;
pub type AsyncFn = Box<dyn Fn(KernelContext, DoneFn) + Send + Sync>;

/// An instantiated kernel, bound to one node.
pub enum Kernel {
    Sync(SyncFn),
    Async(AsyncFn),
}

impl Kernel {
    pub fn is_async(&self) -> bool {
        matches!(self, Kernel::Async(_))
    }
}

/// Kernel factory: builds a kernel instance for a node (may precompute
/// from attrs).
pub type KernelFactory = Arc<dyn Fn(&NodeInfo) -> Result<Kernel> + Send + Sync>;

pub(crate) struct KernelRegistry {
    /// (op name, device type) -> factory.
    factories: HashMap<(String, String), KernelFactory>,
}

static REGISTRY: Lazy<RwLock<KernelRegistry>> = Lazy::new(|| {
    let mut r = KernelRegistry { factories: HashMap::new() };
    install_cpu_kernels(&mut r);
    RwLock::new(r)
});

/// Register a kernel for (op, device_type). Later registrations replace
/// earlier ones (lets tests/extensions override built-ins).
pub fn register_kernel(op: &str, device_type: &str, factory: KernelFactory) {
    REGISTRY
        .write()
        .unwrap()
        .factories
        .insert((op.to_string(), device_type.to_lowercase()), factory);
}

/// Instantiate the kernel for `node` on a device of type `device_type`.
pub fn create_kernel(node: &NodeInfo, device_type: &str) -> Result<Kernel> {
    let reg = REGISTRY.read().unwrap();
    let factory = reg
        .factories
        .get(&(node.op.clone(), device_type.to_lowercase()))
        .ok_or_else(|| {
            Status::not_found(format!(
                "no kernel for op {:?} on device type {:?}",
                node.op, device_type
            ))
        })?;
    factory(node)
}

/// Does a kernel exist for (op, device_type)? The §3.2.1 placement
/// feasibility test ("a device may not be feasible if the device does not
/// provide a kernel that implements the particular operation").
pub fn has_kernel(op: &str, device_type: &str) -> bool {
    // Control-flow ops execute inside the executor itself, on any device.
    if matches!(op, "Switch" | "Merge" | "Enter" | "Exit" | "NextIteration") {
        return true;
    }
    REGISTRY
        .read()
        .unwrap()
        .factories
        .contains_key(&(op.to_string(), device_type.to_lowercase()))
}

/// Ops whose kernels may write their result over a dying input's storage
/// (the memory planner's in-place forwarding, layer 3): elementwise math
/// and `FusedElementwise`. The contract for membership: output shape ==
/// the forwarded input's shape, every output element depends only on
/// already-read values, and the kernel actually routes through
/// `KernelContext::take_forward_f32` (which adds the refcount-1 runtime
/// guard). Identity-like pass-throughs (`Identity`, `StopGradient`,
/// `CheckNumerics`) are deliberately *not* members: they return the input
/// tensor by clone, which already shares storage zero-copy — listing them
/// would only inflate `forward_candidates` with forwards no kernel takes.
static FORWARDING_SAFE: Lazy<RwLock<HashSet<&'static str>>> = Lazy::new(|| {
    RwLock::new(HashSet::from([
        // binary elementwise (same-shape / scalar-operand fast paths)
        "Add", "Sub", "Mul", "Div", "Maximum", "Minimum", "Pow",
        // unary elementwise
        "Neg", "Exp", "Log", "Sqrt", "Rsqrt", "Abs", "Sign", "Square", "Tanh", "Reciprocal",
        "ReLU", "Sigmoid",
        // fused chains (primary operand only)
        "FusedElementwise",
    ]))
});

/// Register `op` as forwarding-safe (extensions adding in-place kernels).
pub fn register_forwarding_safe(op: &'static str) {
    FORWARDING_SAFE.write().unwrap().insert(op);
}

/// May the memory plan mark this op's inputs for in-place forwarding?
pub fn is_forwarding_safe(op: &str) -> bool {
    FORWARDING_SAFE.read().unwrap().contains(op)
}

fn install_cpu_kernels(r: &mut KernelRegistry) {
    math::register(r);
    array::register(r);
    fused::register(r);
    matrix::register(r);
    nn::register(r);
    sparse::register(r);
    state::register(r);
    queue_ops::register(r);
    comm::register(r);
    summary::register(r);
    crate::checkpoint::register_kernels(r);
    crate::data::register_kernels(r);
    crate::runtime::register_kernels(r);
}

impl KernelRegistry {
    /// Register a CPU-device kernel factory (module-internal registration
    /// path; external code uses [`register_kernel`]).
    pub(crate) fn add(
        &mut self,
        op: &str,
        factory: impl Fn(&NodeInfo) -> Result<Kernel> + Send + Sync + 'static,
    ) {
        self.factories.insert((op.to_string(), "cpu".to_string()), Arc::new(factory));
    }

    /// Register a *sync* kernel given just the compute fn.
    pub(crate) fn add_sync(
        &mut self,
        op: &str,
        f: impl Fn(&mut KernelContext) -> Result<Vec<Tensor>> + Send + Sync + Clone + 'static,
    ) {
        self.add(op, move |_node| {
            let f = f.clone();
            Ok(Kernel::Sync(Box::new(move |ctx| f(ctx))))
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_core_kernels() {
        for op in ["Add", "MatMul", "Const", "ReLU", "Variable", "Assign", "_Send", "_Recv"] {
            assert!(has_kernel(op, "cpu"), "missing cpu kernel for {op}");
        }
        assert!(!has_kernel("Add", "tpu"));
        assert!(has_kernel("Switch", "anything")); // executor-internal
    }

    #[test]
    fn forwarding_registry_defaults_and_extension() {
        for op in ["Add", "Neg", "Tanh", "FusedElementwise", "ReLU", "Sigmoid"] {
            assert!(is_forwarding_safe(op), "{op} should be forwarding-safe");
        }
        // Shape-changing / stateful ops are not, and neither are the
        // Identity-likes (their clone pass-through is already zero-copy).
        for op in ["MatMul", "Sum", "Concat", "Variable", "Assign", "_Fetch", "Switch", "Identity"]
        {
            assert!(!is_forwarding_safe(op), "{op} must not be forwarding-safe");
        }
        register_forwarding_safe("MyInPlaceOp");
        assert!(is_forwarding_safe("MyInPlaceOp"));
    }

    #[test]
    fn step_state_cancel_once() {
        let s = StepState::new(1);
        assert!(!s.is_cancelled());
        s.cancel(Status::aborted("first"));
        s.cancel(Status::internal("second"));
        assert!(s.is_cancelled());
        assert_eq!(s.cancel_status().unwrap().message, "first");
    }

    #[test]
    fn step_state_fetches() {
        let s = StepState::new(1);
        s.put_fetch("a:0", Tensor::scalar_f32(1.0));
        s.put_fetch("b:0", Tensor::scalar_f32(2.0));
        let f = s.take_fetches();
        assert_eq!(f.len(), 2);
        assert!(s.take_fetches().is_empty());
    }
}
