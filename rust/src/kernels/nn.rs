//! Neural-net building blocks (Table 1 row 5): ReLU, Sigmoid, SoftMax,
//! Convolution2D, MaxPool, BiasAdd, the fused softmax cross-entropy, and
//! their gradient kernels (registered as ops so the §4.1 autodiff can
//! reference them).
//!
//! Every kernel here runs its hot loop through the device's intra-op
//! pool. The serial direct-loop forms are kept verbatim as reference
//! implementations: the parallel paths are constructed to replay the
//! same per-element operation order (im2col column order mirrors the
//! direct loop's `ky→kx→ci` walk, the col2im/pool-grad gathers visit
//! windows in the scatter's `oy→ox` order), so outputs are
//! byte-identical at every thread count and the unit tests assert
//! exact equality against the references.

use super::{KernelContext, KernelRegistry, ScratchSource};
use crate::device::ComputePool;
use crate::error::{Result, Status};
use crate::kernels::math::planned_fill;
use crate::kernels::matrix::gemm_into;
use crate::tensor::{Shape, Tensor, TensorData};

/// Approximate per-element scalar-op cost of a softmax row pass (exp +
/// max + normalize), driving the intra-op inline threshold.
const SOFTMAX_ELEM_COST: usize = 16;

/// Scalar ReLU, shared with the fused-elementwise interpreter
/// (`kernels::fused`) so fused and unfused graphs agree exactly.
pub(crate) fn f32_relu(v: f32) -> f32 {
    v.max(0.0)
}

/// Scalar sigmoid, shared with `kernels::fused` for the same reason.
pub(crate) fn f32_sigmoid(v: f32) -> f32 {
    1.0 / (1.0 + (-v).exp())
}

pub fn relu(x: &Tensor) -> Result<Tensor> {
    let v = x.as_f32()?;
    Tensor::new(x.shape().clone(), TensorData::F32(v.iter().map(|&a| f32_relu(a)).collect()))
}

/// dx = dy * (features > 0)
pub fn relu_grad(dy: &Tensor, features: &Tensor) -> Result<Tensor> {
    let g = dy.as_f32()?;
    let f = features.as_f32()?;
    if g.len() != f.len() {
        return Err(Status::invalid_argument("ReluGrad: size mismatch"));
    }
    Tensor::new(
        dy.shape().clone(),
        TensorData::F32(g.iter().zip(f).map(|(&gi, &fi)| if fi > 0.0 { gi } else { 0.0 }).collect()),
    )
}

pub fn sigmoid(x: &Tensor) -> Result<Tensor> {
    let v = x.as_f32()?;
    Tensor::new(
        x.shape().clone(),
        TensorData::F32(v.iter().map(|&a| f32_sigmoid(a)).collect()),
    )
}

/// The softmax row body: rows are independent and each is computed with
/// a fixed operation order, so distributing rows over `pool` is
/// bit-identical to serial for every thread count.
fn softmax_rows(pool: &ComputePool, v: &[f32], rows: usize, cols: usize, out: &mut [f32]) {
    pool.parallel_for_mut(rows, cols.saturating_mul(SOFTMAX_ELEM_COST).max(1), out, |rr, o| {
        for (ri, r) in rr.enumerate() {
            let row = &v[r * cols..(r + 1) * cols];
            let orow = &mut o[ri * cols..(ri + 1) * cols];
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut sum = 0f32;
            for c in 0..cols {
                let e = (row[c] - m).exp();
                orow[c] = e;
                sum += e;
            }
            for oc in orow.iter_mut() {
                *oc /= sum;
            }
        }
    });
}

/// The log-softmax row body (see [`softmax_rows`] for the parallelism
/// contract).
fn log_softmax_rows(pool: &ComputePool, v: &[f32], rows: usize, cols: usize, out: &mut [f32]) {
    pool.parallel_for_mut(rows, cols.saturating_mul(SOFTMAX_ELEM_COST).max(1), out, |rr, o| {
        for (ri, r) in rr.enumerate() {
            let row = &v[r * cols..(r + 1) * cols];
            let orow = &mut o[ri * cols..(ri + 1) * cols];
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let lse = row.iter().map(|&a| (a - m).exp()).sum::<f32>().ln() + m;
            for (oc, &rc) in orow.iter_mut().zip(row) {
                *oc = rc - lse;
            }
        }
    });
}

/// Row softmax over the last axis of a 2-D tensor (numerically stable).
/// Serial heap convenience; the kernel path is [`softmax_planned`].
pub fn softmax(x: &Tensor) -> Result<Tensor> {
    let (rows, cols) = rank2(x, "SoftMax")?;
    let v = x.as_f32()?;
    let mut out = vec![0f32; v.len()];
    softmax_rows(&ComputePool::serial(), v, rows, cols, &mut out);
    Tensor::new(x.shape().clone(), TensorData::F32(out))
}

/// Memory-planned [`softmax`]: output in the node's arena slot, rows
/// distributed over the device's intra-op pool.
pub(crate) fn softmax_planned(ctx: &KernelContext) -> Result<Tensor> {
    let (rows, cols) = rank2(ctx.input(0)?, "SoftMax")?;
    let shape = ctx.input(0)?.shape().clone();
    let mut out = ctx.alloc_f32_zeroed(0, rows * cols);
    {
        let v = ctx.input(0)?.as_f32()?;
        softmax_rows(&ctx.device.compute, v, rows, cols, &mut out);
    }
    ctx.make_output(0, shape, TensorData::F32(out))
}

pub fn log_softmax(x: &Tensor) -> Result<Tensor> {
    let (rows, cols) = rank2(x, "LogSoftmax")?;
    let v = x.as_f32()?;
    let mut out = vec![0f32; v.len()];
    log_softmax_rows(&ComputePool::serial(), v, rows, cols, &mut out);
    Tensor::new(x.shape().clone(), TensorData::F32(out))
}

/// Memory-planned [`log_softmax`] (see [`softmax_planned`]).
pub(crate) fn log_softmax_planned(ctx: &KernelContext) -> Result<Tensor> {
    let (rows, cols) = rank2(ctx.input(0)?, "LogSoftmax")?;
    let shape = ctx.input(0)?.shape().clone();
    let mut out = ctx.alloc_f32_zeroed(0, rows * cols);
    {
        let v = ctx.input(0)?.as_f32()?;
        log_softmax_rows(&ctx.device.compute, v, rows, cols, &mut out);
    }
    ctx.make_output(0, shape, TensorData::F32(out))
}

/// BiasAdd: add a [C] bias over the last axis. Serial reference; the
/// kernel path is the planned parallel fill in [`register`].
pub fn bias_add(x: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (_, c) = bias_dims(x, b)?;
    let xv = x.as_f32()?;
    let bv = b.as_f32()?;
    let mut out = Vec::with_capacity(xv.len());
    for (i, &v) in xv.iter().enumerate() {
        out.push(v + bv[i % c]);
    }
    Tensor::new(x.shape().clone(), TensorData::F32(out))
}

/// Shared BiasAdd shape validation: bias must be rank 1 and match x's
/// last axis. Returns (rows, channels).
fn bias_dims(x: &Tensor, b: &Tensor) -> Result<(usize, usize)> {
    let bd = b.shape().dims();
    if bd.len() != 1 {
        return Err(Status::invalid_argument("BiasAdd: bias must be rank 1"));
    }
    let c = bd[0];
    let xd = x.shape().dims();
    if xd.last() != Some(&c) {
        return Err(Status::invalid_argument(format!(
            "BiasAdd: last dim {} != bias size {c}",
            xd.last().copied().unwrap_or(0)
        )));
    }
    Ok((if c == 0 { 0 } else { x.num_elements() / c }, c))
}

/// Gradient of BiasAdd wrt bias: sum over all but last axis. Serial
/// reference; the kernel path is [`bias_add_grad_into`].
pub fn bias_add_grad(dy: &Tensor) -> Result<Tensor> {
    let xd = dy.shape().dims();
    let c = *xd.last().ok_or_else(|| Status::invalid_argument("BiasAddGrad: rank 0"))?;
    let v = dy.as_f32()?;
    let mut out = vec![0f32; c];
    for (i, &g) in v.iter().enumerate() {
        out[i % c] += g;
    }
    Tensor::new(Shape(vec![c]), TensorData::F32(out))
}

/// BiasAddGrad with channel blocks distributed over `pool`: each
/// channel sums its column over rows in ascending row order — the same
/// per-channel order the serial `i % c` scatter produces — so chunking
/// over channels never changes a sum and the result is bit-identical
/// to [`bias_add_grad`] at every thread count. A chunk reads a
/// contiguous `rr`-wide segment of every row, so the access pattern
/// stays sequential. `out` must be zeroed (`c` elements).
fn bias_add_grad_into(pool: &ComputePool, gv: &[f32], rows: usize, c: usize, out: &mut [f32]) {
    pool.parallel_for_mut(c, rows.saturating_mul(2).max(1), out, |rr, os| {
        for row in 0..rows {
            let seg = &gv[row * c + rr.start..row * c + rr.end];
            for (o, &gi) in os.iter_mut().zip(seg) {
                *o += gi;
            }
        }
    });
}

/// Fused softmax cross entropy: returns (loss[batch], backprop[batch,classes])
/// where backprop = softmax(logits) - labels (labels are one-hot/probabilities).
/// Serial two-step reference; the kernel path is [`softmax_xent_into`].
pub fn softmax_xent(logits: &Tensor, labels: &Tensor) -> Result<(Tensor, Tensor)> {
    let (rows, cols) = rank2(logits, "SoftmaxCrossEntropyWithLogits")?;
    if logits.shape() != labels.shape() {
        return Err(Status::invalid_argument("xent: logits and labels shapes differ"));
    }
    let lsm = log_softmax(logits)?;
    let lsm_v = lsm.as_f32()?;
    let lab = labels.as_f32()?;
    let mut loss = vec![0f32; rows];
    let mut backprop = vec![0f32; rows * cols];
    for r in 0..rows {
        let mut l = 0f32;
        for c in 0..cols {
            let i = r * cols + c;
            l -= lab[i] * lsm_v[i];
            backprop[i] = lsm_v[i].exp() - lab[i];
        }
        loss[r] = l;
    }
    Ok((
        Tensor::new(Shape(vec![rows]), TensorData::F32(loss))?,
        Tensor::new(Shape(vec![rows, cols]), TensorData::F32(backprop))?,
    ))
}

/// The fused xent row body: per row, the same max / sum-exp / lse
/// sequence as [`log_softmax_rows`], then loss and backprop in one
/// ascending-column pass — exactly the operation order of
/// [`softmax_xent`]'s two-step form, minus its intermediate
/// log-softmax tensor. Rows split over both output planes with
/// `parallel_for_mut2`, so kernel and reference agree bitwise at
/// every thread count.
fn softmax_xent_into(
    pool: &ComputePool,
    xv: &[f32],
    lab: &[f32],
    rows: usize,
    cols: usize,
    loss: &mut [f32],
    bp: &mut [f32],
) {
    pool.parallel_for_mut2(
        rows,
        cols.saturating_mul(SOFTMAX_ELEM_COST).max(1),
        loss,
        bp,
        |rr, ls, bs| {
            for (ri, r) in rr.enumerate() {
                let row = &xv[r * cols..(r + 1) * cols];
                let lrow = &lab[r * cols..(r + 1) * cols];
                let orow = &mut bs[ri * cols..(ri + 1) * cols];
                let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                let lse = row.iter().map(|&a| (a - m).exp()).sum::<f32>().ln() + m;
                let mut l = 0f32;
                for ((o, &rc), &lb) in orow.iter_mut().zip(row).zip(lrow) {
                    let lsm = rc - lse;
                    l -= lb * lsm;
                    *o = lsm.exp() - lb;
                }
                ls[ri] = l;
            }
        },
    );
}

/// Padding mode for conv/pool.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Padding {
    Same,
    Valid,
}

impl Padding {
    pub fn parse(s: &str) -> Result<Padding> {
        match s.to_uppercase().as_str() {
            "SAME" => Ok(Padding::Same),
            "VALID" => Ok(Padding::Valid),
            other => Err(Status::invalid_argument(format!("unknown padding {other:?}"))),
        }
    }

    fn out_dim(&self, input: usize, filter: usize, stride: usize) -> usize {
        match self {
            Padding::Same => input.div_ceil(stride),
            Padding::Valid => (input - filter) / stride + 1,
        }
    }

    /// Total padding before the first window element.
    fn pad_before(&self, input: usize, filter: usize, stride: usize) -> i64 {
        match self {
            Padding::Valid => 0,
            Padding::Same => {
                let out = self.out_dim(input, filter, stride);
                let total = ((out - 1) * stride + filter).saturating_sub(input) as i64;
                total / 2
            }
        }
    }
}

/// Resolved window geometry shared by the im2col convolution paths and
/// the pooling kernels (pooling reuses it with `kh = kw = ksize` and
/// `ic = oc = channels`).
#[derive(Clone, Copy)]
struct ConvGeom {
    n: usize,
    h: usize,
    w: usize,
    ic: usize,
    kh: usize,
    kw: usize,
    oc: usize,
    oh: usize,
    ow: usize,
    stride: usize,
    ph: usize,
    pw: usize,
}

impl ConvGeom {
    /// Output rows of the im2col matrix (= output spatial positions).
    fn rows(&self) -> usize {
        self.n * self.oh * self.ow
    }

    /// Columns of the im2col matrix (= one receptive-field patch).
    fn patch(&self) -> usize {
        self.kh * self.kw * self.ic
    }
}

fn conv_geom(xd: &[usize], fd: &[usize], stride: usize, padding: Padding) -> Result<ConvGeom> {
    if xd.len() != 4 || fd.len() != 4 {
        return Err(Status::invalid_argument("Conv2D: x must be NHWC, filter [kh,kw,ic,oc]"));
    }
    let (n, h, w, ic) = (xd[0], xd[1], xd[2], xd[3]);
    let (kh, kw, fic, oc) = (fd[0], fd[1], fd[2], fd[3]);
    if ic != fic {
        return Err(Status::invalid_argument(format!("Conv2D: channels {ic} != filter {fic}")));
    }
    Ok(ConvGeom {
        n,
        h,
        w,
        ic,
        kh,
        kw,
        oc,
        oh: padding.out_dim(h, kh, stride),
        ow: padding.out_dim(w, kw, stride),
        stride,
        ph: padding.pad_before(h, kh, stride) as usize,
        pw: padding.pad_before(w, kw, stride) as usize,
    })
}

fn pool_geom(xd: &[usize], k: usize, stride: usize, padding: Padding) -> Result<ConvGeom> {
    if xd.len() != 4 {
        return Err(Status::invalid_argument("MaxPool: x must be NHWC"));
    }
    let (n, h, w, c) = (xd[0], xd[1], xd[2], xd[3]);
    Ok(ConvGeom {
        n,
        h,
        w,
        ic: c,
        kh: k,
        kw: k,
        oc: c,
        oh: padding.out_dim(h, k, stride),
        ow: padding.out_dim(w, k, stride),
        stride,
        ph: padding.pad_before(h, k, stride) as usize,
        pw: padding.pad_before(w, k, stride) as usize,
    })
}

/// Lower NHWC activations to the im2col matrix [n·oh·ow, kh·kw·ic] in
/// `col` (which must be zeroed — padding positions are never written).
/// Column index `(ky·kw + kx)·ic + ci` preserves the direct loop's
/// `ky→kx→ci` walk, so a GEMM summing ascending columns accumulates
/// each output in the same order as [`conv2d`]'s serial loops (padding
/// contributes exact `+0.0` terms the direct form skips via its bounds
/// checks). Rows are independent and split over `pool`.
fn im2col(pool: &ComputePool, xv: &[f32], g: &ConvGeom, col: &mut [f32]) {
    let kk = g.patch();
    pool.parallel_for_mut(g.rows(), kk.max(1), col, |rr, cs| {
        for (j, row) in rr.enumerate() {
            let b = row / (g.oh * g.ow);
            let rem = row % (g.oh * g.ow);
            let (oy, ox) = (rem / g.ow, rem % g.ow);
            let dst = &mut cs[j * kk..(j + 1) * kk];
            for ky in 0..g.kh {
                let iy = (oy * g.stride + ky) as i64 - g.ph as i64;
                if iy < 0 || iy >= g.h as i64 {
                    continue;
                }
                for kx in 0..g.kw {
                    let ix = (ox * g.stride + kx) as i64 - g.pw as i64;
                    if ix < 0 || ix >= g.w as i64 {
                        continue;
                    }
                    let src = ((b * g.h + iy as usize) * g.w + ix as usize) * g.ic;
                    let d0 = (ky * g.kw + kx) * g.ic;
                    dst[d0..d0 + g.ic].copy_from_slice(&xv[src..src + g.ic]);
                }
            }
        }
    });
}

/// The packed-GEMM convolution body: im2col into pool/arena scratch,
/// then one [rows × patch]·[patch × oc] multiply through [`gemm_into`]
/// (the filter's natural [kh,kw,ic,oc] layout *is* the [patch, oc]
/// right-hand side). A 1×1 stride-1 convolution skips the lowering
/// entirely — the NHWC activations already are the im2col matrix.
/// `out` must be zeroed (`rows·oc` elements).
fn conv2d_into(
    pool: &ComputePool,
    scratch: ScratchSource<'_>,
    xv: &[f32],
    fv: &[f32],
    g: &ConvGeom,
    out: &mut [f32],
) {
    let rows = g.rows();
    if g.kh == 1 && g.kw == 1 && g.stride == 1 && g.ph == 0 && g.pw == 0 {
        gemm_into(pool, scratch, xv, fv, rows, g.ic, g.oc, false, false, out);
        return;
    }
    let kk = g.patch();
    let mut col = scratch.take_f32(rows * kk);
    col.resize(rows * kk, 0.0);
    im2col(pool, xv, g, &mut col);
    gemm_into(pool, scratch, &col, fv, rows, kk, g.oc, false, false, out);
    scratch.give_f32(col);
}

/// Direct 2-D convolution. x: NHWC, filter: [kh, kw, in_c, out_c].
/// Serial reference implementation (note its zero-input skips); the
/// Convolution2D kernel and [`conv2d_with`] run the im2col +
/// packed-GEMM path, which the unit tests hold to exact agreement.
pub fn conv2d(x: &Tensor, filter: &Tensor, stride: usize, padding: Padding) -> Result<Tensor> {
    let xd = x.shape().dims();
    let fd = filter.shape().dims();
    if xd.len() != 4 || fd.len() != 4 {
        return Err(Status::invalid_argument("Conv2D: x must be NHWC, filter [kh,kw,ic,oc]"));
    }
    let (n, h, w, ic) = (xd[0], xd[1], xd[2], xd[3]);
    let (kh, kw, fic, oc) = (fd[0], fd[1], fd[2], fd[3]);
    if ic != fic {
        return Err(Status::invalid_argument(format!("Conv2D: channels {ic} != filter {fic}")));
    }
    let oh = padding.out_dim(h, kh, stride);
    let ow = padding.out_dim(w, kw, stride);
    let ph = padding.pad_before(h, kh, stride);
    let pw = padding.pad_before(w, kw, stride);
    let xv = x.as_f32()?;
    let fv = filter.as_f32()?;
    let mut out = vec![0f32; n * oh * ow * oc];
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ky in 0..kh {
                    let iy = oy as i64 * stride as i64 + ky as i64 - ph;
                    if iy < 0 || iy >= h as i64 {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = ox as i64 * stride as i64 + kx as i64 - pw;
                        if ix < 0 || ix >= w as i64 {
                            continue;
                        }
                        let x_base = ((b * h + iy as usize) * w + ix as usize) * ic;
                        let f_base = (ky * kw + kx) * ic * oc;
                        let o_base = ((b * oh + oy) * ow + ox) * oc;
                        for ci in 0..ic {
                            let xi = xv[x_base + ci];
                            if xi == 0.0 {
                                continue;
                            }
                            let fo = f_base + ci * oc;
                            for co in 0..oc {
                                out[o_base + co] += xi * fv[fo + co];
                            }
                        }
                    }
                }
            }
        }
    }
    Tensor::new(Shape(vec![n, oh, ow, oc]), TensorData::F32(out))
}

/// [`conv2d`] on the im2col + packed-GEMM path, distributing both the
/// lowering and the multiply over `pool` (scratch comes from the
/// pool's buffer recycler). `benches/parallel.rs` and the parity tests
/// drive this directly; the Convolution2D kernel runs the same body
/// with arena scratch into its planned output slot.
pub fn conv2d_with(
    pool: &ComputePool,
    x: &Tensor,
    filter: &Tensor,
    stride: usize,
    padding: Padding,
) -> Result<Tensor> {
    let g = conv_geom(x.shape().dims(), filter.shape().dims(), stride, padding)?;
    let mut out = vec![0f32; g.rows() * g.oc];
    conv2d_into(pool, ScratchSource::Pool(pool), x.as_f32()?, filter.as_f32()?, &g, &mut out);
    Tensor::new(Shape(vec![g.n, g.oh, g.ow, g.oc]), TensorData::F32(out))
}

/// MaxPool over kxk windows; returns (output, flat argmax indices).
/// Serial reference; the kernel path is [`max_pool_into`].
pub fn max_pool(x: &Tensor, k: usize, stride: usize, padding: Padding) -> Result<(Tensor, Tensor)> {
    let xd = x.shape().dims();
    if xd.len() != 4 {
        return Err(Status::invalid_argument("MaxPool: x must be NHWC"));
    }
    let (n, h, w, c) = (xd[0], xd[1], xd[2], xd[3]);
    let oh = padding.out_dim(h, k, stride);
    let ow = padding.out_dim(w, k, stride);
    let ph = padding.pad_before(h, k, stride);
    let pw = padding.pad_before(w, k, stride);
    let xv = x.as_f32()?;
    let mut out = vec![f32::NEG_INFINITY; n * oh * ow * c];
    let mut arg = vec![0i64; n * oh * ow * c];
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ky in 0..k {
                    let iy = oy as i64 * stride as i64 + ky as i64 - ph;
                    if iy < 0 || iy >= h as i64 {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = ox as i64 * stride as i64 + kx as i64 - pw;
                        if ix < 0 || ix >= w as i64 {
                            continue;
                        }
                        let x_base = ((b * h + iy as usize) * w + ix as usize) * c;
                        let o_base = ((b * oh + oy) * ow + ox) * c;
                        for ci in 0..c {
                            let v = xv[x_base + ci];
                            if v > out[o_base + ci] {
                                out[o_base + ci] = v;
                                arg[o_base + ci] = (x_base + ci) as i64;
                            }
                        }
                    }
                }
            }
        }
    }
    Ok((
        Tensor::new(Shape(vec![n, oh, ow, c]), TensorData::F32(out))?,
        Tensor::new(Shape(vec![n, oh, ow, c]), TensorData::I64(arg))?,
    ))
}

/// The row body of [`max_pool`]: every output position scans its
/// window in the serial loop's `ky→kx→ci` order with the same strict
/// `>` update, so distributing positions over `pool` is bit-identical
/// (value and argmax planes both) for every thread count. `out` must
/// be filled with `NEG_INFINITY` and `arg` with 0 — the serial
/// initial state.
fn max_pool_into(pool: &ComputePool, xv: &[f32], g: &ConvGeom, out: &mut [f32], arg: &mut [i64]) {
    let c = g.ic;
    let cost = g.kh.saturating_mul(g.kw).saturating_mul(c).saturating_mul(2).max(1);
    pool.parallel_for_mut2(g.rows(), cost, out, arg, |rr, os, ags| {
        for (j, pos) in rr.enumerate() {
            let b = pos / (g.oh * g.ow);
            let rem = pos % (g.oh * g.ow);
            let (oy, ox) = (rem / g.ow, rem % g.ow);
            let ob = j * c;
            for ky in 0..g.kh {
                let iy = (oy * g.stride + ky) as i64 - g.ph as i64;
                if iy < 0 || iy >= g.h as i64 {
                    continue;
                }
                for kx in 0..g.kw {
                    let ix = (ox * g.stride + kx) as i64 - g.pw as i64;
                    if ix < 0 || ix >= g.w as i64 {
                        continue;
                    }
                    let x_base = ((b * g.h + iy as usize) * g.w + ix as usize) * c;
                    for ci in 0..c {
                        let v = xv[x_base + ci];
                        if v > os[ob + ci] {
                            os[ob + ci] = v;
                            ags[ob + ci] = (x_base + ci) as i64;
                        }
                    }
                }
            }
        }
    });
}

/// [`max_pool`] with output positions distributed over `pool`.
pub fn max_pool_with(
    pool: &ComputePool,
    x: &Tensor,
    k: usize,
    stride: usize,
    padding: Padding,
) -> Result<(Tensor, Tensor)> {
    let g = pool_geom(x.shape().dims(), k, stride, padding)?;
    let len = g.rows() * g.ic;
    let mut out = vec![f32::NEG_INFINITY; len];
    let mut arg = vec![0i64; len];
    max_pool_into(pool, x.as_f32()?, &g, &mut out, &mut arg);
    Ok((
        Tensor::new(Shape(vec![g.n, g.oh, g.ow, g.ic]), TensorData::F32(out))?,
        Tensor::new(Shape(vec![g.n, g.oh, g.ow, g.ic]), TensorData::I64(arg))?,
    ))
}

/// Scatter pooled gradients back through the argmax indices. Serial
/// reference (and the kernel fallback for grad nodes that don't carry
/// the forward's window attrs); the parallel path is
/// [`max_pool_grad_into`].
pub fn max_pool_grad(dy: &Tensor, argmax: &Tensor, input_shape: &Shape) -> Result<Tensor> {
    let g = dy.as_f32()?;
    let a = argmax.as_i64()?;
    let mut out = vec![0f32; input_shape.num_elements()];
    for (i, &gi) in g.iter().enumerate() {
        let idx = a[i] as usize;
        if idx >= out.len() {
            return Err(Status::invalid_argument("MaxPoolGrad: argmax out of range"));
        }
        out[idx] += gi;
    }
    Tensor::new(input_shape.clone(), TensorData::F32(out))
}

/// [`max_pool_grad`] in gather form: each input element sums, over the
/// pooling windows that cover it — visited in ascending `oy→ox` order,
/// exactly the order the serial scatter walks the dy plane — the dy
/// entries whose argmax selected it. For any argmax plane the MaxPool
/// forward can produce (indices always point inside their own window)
/// this is bit-identical to the scatter at every thread count. The
/// caller pre-validates the argmax range; entries that are in range
/// but point outside every covering window (impossible from the
/// forward) contribute nothing here, where the scatter would have
/// honoured them. `out` must be zeroed.
fn max_pool_grad_into(pool: &ComputePool, gv: &[f32], av: &[i64], g: &ConvGeom, out: &mut [f32]) {
    let c = g.ic;
    let windows = (g.kh / g.stride + 1).saturating_mul(g.kw / g.stride + 1);
    let cost = windows.saturating_mul(c).saturating_mul(2).max(1);
    pool.parallel_for_mut(g.n * g.h * g.w, cost, out, |rr, os| {
        for (j, pos) in rr.enumerate() {
            let b = pos / (g.h * g.w);
            let rem = pos % (g.h * g.w);
            let (iy, ix) = (rem / g.w, rem % g.w);
            let dst = &mut os[j * c..(j + 1) * c];
            let x_base = pos * c;
            let py = iy + g.ph;
            let px = ix + g.pw;
            let oy_lo = py.saturating_sub(g.kh - 1).div_ceil(g.stride);
            let oy_hi = (py / g.stride + 1).min(g.oh);
            let ox_lo = px.saturating_sub(g.kw - 1).div_ceil(g.stride);
            let ox_hi = (px / g.stride + 1).min(g.ow);
            for oy in oy_lo..oy_hi {
                for ox in ox_lo..ox_hi {
                    let o_base = ((b * g.oh + oy) * g.ow + ox) * c;
                    for ci in 0..c {
                        if av[o_base + ci] == (x_base + ci) as i64 {
                            dst[ci] += gv[o_base + ci];
                        }
                    }
                }
            }
        }
    });
}

/// [`max_pool_grad`] on the parallel gather path; needs the forward
/// window geometry (ksize/stride/padding) to enumerate covering
/// windows.
pub fn max_pool_grad_with(
    pool: &ComputePool,
    dy: &Tensor,
    argmax: &Tensor,
    input_shape: &Shape,
    k: usize,
    stride: usize,
    padding: Padding,
) -> Result<Tensor> {
    let g = pool_geom(input_shape.dims(), k, stride, padding)?;
    let gv = dy.as_f32()?;
    let av = argmax.as_i64()?;
    let total = input_shape.num_elements();
    if gv.len() != g.rows() * g.ic || av.len() != gv.len() {
        return Err(Status::invalid_argument("MaxPoolGrad: dy/argmax shape mismatch"));
    }
    if av.iter().any(|&i| i < 0 || i >= total as i64) {
        return Err(Status::invalid_argument("MaxPoolGrad: argmax out of range"));
    }
    let mut out = vec![0f32; total];
    max_pool_grad_into(pool, gv, av, &g, &mut out);
    Tensor::new(input_shape.clone(), TensorData::F32(out))
}

/// Conv2D gradient wrt input (direct, full correlation with flipped filter).
/// Serial reference; the kernel path is [`conv2d_backprop_input_into`].
pub fn conv2d_backprop_input(
    dy: &Tensor,
    filter: &Tensor,
    input_shape: &Shape,
    stride: usize,
    padding: Padding,
) -> Result<Tensor> {
    let id = input_shape.dims();
    let fd = filter.shape().dims();
    let dyd = dy.shape().dims();
    let (n, h, w, ic) = (id[0], id[1], id[2], id[3]);
    let (kh, kw, _fic, oc) = (fd[0], fd[1], fd[2], fd[3]);
    let (oh, ow) = (dyd[1], dyd[2]);
    let ph = padding.pad_before(h, kh, stride);
    let pw = padding.pad_before(w, kw, stride);
    let gv = dy.as_f32()?;
    let fv = filter.as_f32()?;
    let mut out = vec![0f32; input_shape.num_elements()];
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ky in 0..kh {
                    let iy = oy as i64 * stride as i64 + ky as i64 - ph;
                    if iy < 0 || iy >= h as i64 {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = ox as i64 * stride as i64 + kx as i64 - pw;
                        if ix < 0 || ix >= w as i64 {
                            continue;
                        }
                        let x_base = ((b * h + iy as usize) * w + ix as usize) * ic;
                        let f_base = (ky * kw + kx) * ic * oc;
                        let g_base = ((b * oh + oy) * ow + ox) * oc;
                        for ci in 0..ic {
                            let mut s = 0f32;
                            let fo = f_base + ci * oc;
                            for co in 0..oc {
                                s += gv[g_base + co] * fv[fo + co];
                            }
                            out[x_base + ci] += s;
                        }
                    }
                }
            }
        }
    }
    Tensor::new(input_shape.clone(), TensorData::F32(out))
}

/// Deterministic col2im: each input element gathers its contributing
/// `dcol` entries in ascending `oy→ox` window order — exactly the
/// order [`conv2d_backprop_input`]'s serial scatter adds them, with
/// each entry being the same ascending-`co` dot product (now computed
/// by the packed GEMM) — so the result is bit-identical to the direct
/// loop for every thread count. `out` must be zeroed.
fn col2im_gather(pool: &ComputePool, dcol: &[f32], g: &ConvGeom, out: &mut [f32]) {
    let kk = g.patch();
    let windows = (g.kh / g.stride + 1).saturating_mul(g.kw / g.stride + 1);
    let cost = windows.saturating_mul(g.ic).saturating_mul(2).max(1);
    pool.parallel_for_mut(g.n * g.h * g.w, cost, out, |rr, os| {
        for (j, pos) in rr.enumerate() {
            let b = pos / (g.h * g.w);
            let rem = pos % (g.h * g.w);
            let (iy, ix) = (rem / g.w, rem % g.w);
            let dst = &mut os[j * g.ic..(j + 1) * g.ic];
            let py = iy + g.ph;
            let px = ix + g.pw;
            let oy_lo = py.saturating_sub(g.kh - 1).div_ceil(g.stride);
            let oy_hi = (py / g.stride + 1).min(g.oh);
            let ox_lo = px.saturating_sub(g.kw - 1).div_ceil(g.stride);
            let ox_hi = (px / g.stride + 1).min(g.ow);
            for oy in oy_lo..oy_hi {
                let ky = py - oy * g.stride;
                for ox in ox_lo..ox_hi {
                    let kx = px - ox * g.stride;
                    let row = (b * g.oh + oy) * g.ow + ox;
                    let c0 = row * kk + (ky * g.kw + kx) * g.ic;
                    for (d, &s) in dst.iter_mut().zip(&dcol[c0..c0 + g.ic]) {
                        *d += s;
                    }
                }
            }
        }
    });
}

/// The packed-GEMM input-gradient body: dcol = dy · filterᵀ (one
/// [rows × oc]·[oc × patch] multiply on the filter's natural layout),
/// then the deterministic [`col2im_gather`]. A 1×1 stride-1
/// convolution needs no gather — dcol *is* dx. `out` must be zeroed
/// (`n·h·w·ic` elements).
fn conv2d_backprop_input_into(
    pool: &ComputePool,
    scratch: ScratchSource<'_>,
    gv: &[f32],
    fv: &[f32],
    g: &ConvGeom,
    out: &mut [f32],
) {
    let rows = g.rows();
    if g.kh == 1 && g.kw == 1 && g.stride == 1 && g.ph == 0 && g.pw == 0 {
        gemm_into(pool, scratch, gv, fv, rows, g.oc, g.ic, false, true, out);
        return;
    }
    let kk = g.patch();
    let mut dcol = scratch.take_f32(rows * kk);
    dcol.resize(rows * kk, 0.0);
    gemm_into(pool, scratch, gv, fv, rows, g.oc, kk, false, true, &mut dcol);
    col2im_gather(pool, &dcol, g, out);
    scratch.give_f32(dcol);
}

/// [`conv2d_backprop_input`] on the packed-GEMM + col2im path.
pub fn conv2d_backprop_input_with(
    pool: &ComputePool,
    dy: &Tensor,
    filter: &Tensor,
    input_shape: &Shape,
    stride: usize,
    padding: Padding,
) -> Result<Tensor> {
    let g = conv_geom(input_shape.dims(), filter.shape().dims(), stride, padding)?;
    let gv = dy.as_f32()?;
    if gv.len() != g.rows() * g.oc {
        return Err(Status::invalid_argument("Conv2DBackpropInput: dy shape mismatch"));
    }
    let mut out = vec![0f32; input_shape.num_elements()];
    conv2d_backprop_input_into(pool, ScratchSource::Pool(pool), gv, filter.as_f32()?, &g, &mut out);
    Tensor::new(input_shape.clone(), TensorData::F32(out))
}

/// Conv2D gradient wrt filter. Serial reference (note its zero-input
/// skips); the kernel path is [`conv2d_backprop_filter_into`].
pub fn conv2d_backprop_filter(
    x: &Tensor,
    dy: &Tensor,
    filter_shape: &Shape,
    stride: usize,
    padding: Padding,
) -> Result<Tensor> {
    let xd = x.shape().dims();
    let fd = filter_shape.dims();
    let dyd = dy.shape().dims();
    let (n, h, w, ic) = (xd[0], xd[1], xd[2], xd[3]);
    let (kh, kw, _fic, oc) = (fd[0], fd[1], fd[2], fd[3]);
    let (oh, ow) = (dyd[1], dyd[2]);
    let ph = padding.pad_before(h, kh, stride);
    let pw = padding.pad_before(w, kw, stride);
    let xv = x.as_f32()?;
    let gv = dy.as_f32()?;
    let mut out = vec![0f32; filter_shape.num_elements()];
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ky in 0..kh {
                    let iy = oy as i64 * stride as i64 + ky as i64 - ph;
                    if iy < 0 || iy >= h as i64 {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = ox as i64 * stride as i64 + kx as i64 - pw;
                        if ix < 0 || ix >= w as i64 {
                            continue;
                        }
                        let x_base = ((b * h + iy as usize) * w + ix as usize) * ic;
                        let f_base = (ky * kw + kx) * ic * oc;
                        let g_base = ((b * oh + oy) * ow + ox) * oc;
                        for ci in 0..ic {
                            let xi = xv[x_base + ci];
                            if xi == 0.0 {
                                continue;
                            }
                            let fo = f_base + ci * oc;
                            for co in 0..oc {
                                out[fo + co] += xi * gv[g_base + co];
                            }
                        }
                    }
                }
            }
        }
    }
    Tensor::new(filter_shape.clone(), TensorData::F32(out))
}

/// The packed-GEMM filter-gradient body: df = im2colᵀ · dy, one
/// [patch × rows]·[rows × oc] multiply whose ascending-k accumulation
/// runs over rows = `b→oy→ox` — the serial scatter's outer-loop order.
/// The 1×1 stride-1 case again uses the activations directly as the
/// im2col matrix. `out` must be zeroed (`patch·oc` elements).
fn conv2d_backprop_filter_into(
    pool: &ComputePool,
    scratch: ScratchSource<'_>,
    xv: &[f32],
    gv: &[f32],
    g: &ConvGeom,
    out: &mut [f32],
) {
    let rows = g.rows();
    if g.kh == 1 && g.kw == 1 && g.stride == 1 && g.ph == 0 && g.pw == 0 {
        gemm_into(pool, scratch, xv, gv, g.ic, rows, g.oc, true, false, out);
        return;
    }
    let kk = g.patch();
    let mut col = scratch.take_f32(rows * kk);
    col.resize(rows * kk, 0.0);
    im2col(pool, xv, g, &mut col);
    gemm_into(pool, scratch, &col, gv, kk, rows, g.oc, true, false, out);
    scratch.give_f32(col);
}

/// [`conv2d_backprop_filter`] on the im2col + packed-GEMM path.
pub fn conv2d_backprop_filter_with(
    pool: &ComputePool,
    x: &Tensor,
    dy: &Tensor,
    filter_shape: &Shape,
    stride: usize,
    padding: Padding,
) -> Result<Tensor> {
    let g = conv_geom(x.shape().dims(), filter_shape.dims(), stride, padding)?;
    let gv = dy.as_f32()?;
    if gv.len() != g.rows() * g.oc {
        return Err(Status::invalid_argument("Conv2DBackpropFilter: dy shape mismatch"));
    }
    let mut out = vec![0f32; filter_shape.num_elements()];
    conv2d_backprop_filter_into(pool, ScratchSource::Pool(pool), x.as_f32()?, gv, &g, &mut out);
    Tensor::new(filter_shape.clone(), TensorData::F32(out))
}

fn rank2(t: &Tensor, what: &str) -> Result<(usize, usize)> {
    let d = t.shape().dims();
    match d.len() {
        2 => Ok((d[0], d[1])),
        1 => Ok((1, d[0])),
        _ => Err(Status::invalid_argument(format!("{what}: expected rank 1/2, got {}", t.shape()))),
    }
}

fn conv_attrs(ctx: &KernelContext) -> Result<(usize, Padding)> {
    let stride =
        ctx.node.attr_opt("stride").map(|a| a.as_i64()).transpose()?.unwrap_or(1) as usize;
    let padding = Padding::parse(
        ctx.node.attr_opt("padding").map(|a| a.as_str()).transpose()?.unwrap_or("SAME"),
    )?;
    Ok((stride, padding))
}

pub(super) fn register(r: &mut KernelRegistry) {
    // ReLU/Sigmoid go through the shared memory-planned map
    // (`math::planned_unary_map`) with the same scalar functions the
    // fused interpreter uses, so planned/unplanned/fused all agree.
    r.add_sync("ReLU", |ctx| {
        Ok(vec![crate::kernels::math::planned_unary_map(ctx, f32_relu, 1)?])
    });
    r.add_sync("ReluGrad", |ctx| {
        let shape = ctx.input(0)?.shape().clone();
        if ctx.input(0)?.num_elements() != ctx.input(1)?.num_elements() {
            return Err(Status::invalid_argument("ReluGrad: size mismatch"));
        }
        let out = {
            let gv = ctx.input(0)?.as_f32()?;
            let fv = ctx.input(1)?.as_f32()?;
            planned_fill(ctx, 0, gv.len(), 2, |i| if fv[i] > 0.0 { gv[i] } else { 0.0 })
        };
        Ok(vec![ctx.make_output(0, shape, TensorData::F32(out))?])
    });
    r.add_sync("Sigmoid", |ctx| {
        Ok(vec![crate::kernels::math::planned_unary_map(ctx, f32_sigmoid, 12)?])
    });
    r.add_sync("SoftMax", |ctx| Ok(vec![softmax_planned(ctx)?]));
    r.add_sync("LogSoftmax", |ctx| Ok(vec![log_softmax_planned(ctx)?]));
    r.add_sync("BiasAdd", |ctx| {
        let (shape, c) = {
            let x = ctx.input(0)?;
            let (_, c) = bias_dims(x, ctx.input(1)?)?;
            (x.shape().clone(), c)
        };
        let out = {
            let xv = ctx.input(0)?.as_f32()?;
            let bv = ctx.input(1)?.as_f32()?;
            planned_fill(ctx, 0, xv.len(), 2, |i| xv[i] + bv[i % c])
        };
        Ok(vec![ctx.make_output(0, shape, TensorData::F32(out))?])
    });
    r.add_sync("BiasAddGrad", |ctx| {
        let (rows, c) = {
            let dy = ctx.input(0)?;
            let xd = dy.shape().dims();
            let c = *xd.last().ok_or_else(|| Status::invalid_argument("BiasAddGrad: rank 0"))?;
            (if c == 0 { 0 } else { dy.num_elements() / c }, c)
        };
        let mut out = ctx.alloc_f32_zeroed(0, c);
        {
            let gv = ctx.input(0)?.as_f32()?;
            bias_add_grad_into(&ctx.device.compute, gv, rows, c, &mut out);
        }
        Ok(vec![ctx.make_output(0, Shape(vec![c]), TensorData::F32(out))?])
    });
    r.add_sync("SoftmaxCrossEntropyWithLogits", |ctx| {
        let (rows, cols) = rank2(ctx.input(0)?, "SoftmaxCrossEntropyWithLogits")?;
        if ctx.input(0)?.shape() != ctx.input(1)?.shape() {
            return Err(Status::invalid_argument("xent: logits and labels shapes differ"));
        }
        let mut loss = ctx.alloc_f32_zeroed(0, rows);
        let mut bp = ctx.alloc_f32_zeroed(1, rows * cols);
        {
            let xv = ctx.input(0)?.as_f32()?;
            let lab = ctx.input(1)?.as_f32()?;
            softmax_xent_into(&ctx.device.compute, xv, lab, rows, cols, &mut loss, &mut bp);
        }
        Ok(vec![
            ctx.make_output(0, Shape(vec![rows]), TensorData::F32(loss))?,
            ctx.make_output(1, Shape(vec![rows, cols]), TensorData::F32(bp))?,
        ])
    });
    r.add_sync("L2Loss", |ctx| {
        let v = ctx.input(0)?.as_f32()?;
        let s: f64 = v.iter().map(|&x| (x as f64) * (x as f64)).sum();
        Ok(vec![Tensor::scalar_f32((s / 2.0) as f32)])
    });
    r.add_sync("Convolution2D", |ctx| {
        let (stride, padding) = conv_attrs(ctx)?;
        let g = conv_geom(ctx.input(0)?.shape().dims(), ctx.input(1)?.shape().dims(), stride, padding)?;
        let mut out = ctx.alloc_f32_zeroed(0, g.rows() * g.oc);
        {
            let xv = ctx.input(0)?.as_f32()?;
            let fv = ctx.input(1)?.as_f32()?;
            conv2d_into(&ctx.device.compute, ctx.scratch(), xv, fv, &g, &mut out);
        }
        Ok(vec![ctx.make_output(0, Shape(vec![g.n, g.oh, g.ow, g.oc]), TensorData::F32(out))?])
    });
    r.add_sync("Conv2DBackpropInput", |ctx| {
        // inputs: (dy, filter, original-input-for-shape)
        let (stride, padding) = conv_attrs(ctx)?;
        let input_shape = ctx.input(2)?.shape().clone();
        let g = conv_geom(input_shape.dims(), ctx.input(1)?.shape().dims(), stride, padding)?;
        if ctx.input(0)?.num_elements() != g.rows() * g.oc {
            return Err(Status::invalid_argument("Conv2DBackpropInput: dy shape mismatch"));
        }
        let mut out = ctx.alloc_f32_zeroed(0, input_shape.num_elements());
        {
            let gv = ctx.input(0)?.as_f32()?;
            let fv = ctx.input(1)?.as_f32()?;
            conv2d_backprop_input_into(&ctx.device.compute, ctx.scratch(), gv, fv, &g, &mut out);
        }
        Ok(vec![ctx.make_output(0, input_shape, TensorData::F32(out))?])
    });
    r.add_sync("Conv2DBackpropFilter", |ctx| {
        // inputs: (x, dy, original-filter-for-shape)
        let (stride, padding) = conv_attrs(ctx)?;
        let filter_shape = ctx.input(2)?.shape().clone();
        let g = conv_geom(ctx.input(0)?.shape().dims(), filter_shape.dims(), stride, padding)?;
        if ctx.input(1)?.num_elements() != g.rows() * g.oc {
            return Err(Status::invalid_argument("Conv2DBackpropFilter: dy shape mismatch"));
        }
        let mut out = ctx.alloc_f32_zeroed(0, filter_shape.num_elements());
        {
            let xv = ctx.input(0)?.as_f32()?;
            let gv = ctx.input(1)?.as_f32()?;
            conv2d_backprop_filter_into(&ctx.device.compute, ctx.scratch(), xv, gv, &g, &mut out);
        }
        Ok(vec![ctx.make_output(0, filter_shape, TensorData::F32(out))?])
    });
    r.add_sync("MaxPool", |ctx| {
        let k = ctx.node.attr_opt("ksize").map(|a| a.as_i64()).transpose()?.unwrap_or(2) as usize;
        let (stride, padding) = conv_attrs(ctx)?;
        let g = pool_geom(ctx.input(0)?.shape().dims(), k, stride, padding)?;
        let len = g.rows() * g.ic;
        let mut out = ctx.alloc_f32(0, len);
        out.resize(len, f32::NEG_INFINITY);
        let mut arg = ctx.alloc_i64(1, len);
        arg.resize(len, 0);
        {
            let xv = ctx.input(0)?.as_f32()?;
            max_pool_into(&ctx.device.compute, xv, &g, &mut out, &mut arg);
        }
        let shape = Shape(vec![g.n, g.oh, g.ow, g.ic]);
        Ok(vec![
            ctx.make_output(0, shape.clone(), TensorData::F32(out))?,
            ctx.make_output(1, shape, TensorData::I64(arg))?,
        ])
    });
    r.add_sync("MaxPoolGrad", |ctx| {
        // inputs: dy, argmax, original input (for shape). When the grad
        // node carries the forward's ksize/stride/padding attrs (the
        // autodiff copies them), the gather form runs input rows in
        // parallel; attr-less nodes keep the serial scatter.
        let shape = ctx.input(2)?.shape().clone();
        let k = match ctx.node.attr_opt("ksize") {
            None => return Ok(vec![max_pool_grad(ctx.input(0)?, ctx.input(1)?, &shape)?]),
            Some(a) => a.as_i64()? as usize,
        };
        let (stride, padding) = conv_attrs(ctx)?;
        let g = pool_geom(shape.dims(), k, stride, padding)?;
        let total = shape.num_elements();
        {
            let gv = ctx.input(0)?.as_f32()?;
            let av = ctx.input(1)?.as_i64()?;
            if gv.len() != g.rows() * g.ic || av.len() != gv.len() {
                return Err(Status::invalid_argument("MaxPoolGrad: dy/argmax shape mismatch"));
            }
            // Same hostile-index contract as the serial scatter.
            if av.iter().any(|&i| i < 0 || i >= total as i64) {
                return Err(Status::invalid_argument("MaxPoolGrad: argmax out of range"));
            }
        }
        let mut out = ctx.alloc_f32_zeroed(0, total);
        {
            let gv = ctx.input(0)?.as_f32()?;
            let av = ctx.input(1)?.as_i64()?;
            max_pool_grad_into(&ctx.device.compute, gv, av, &g, &mut out);
        }
        Ok(vec![ctx.make_output(0, shape, TensorData::F32(out))?])
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: Vec<usize>, v: Vec<f32>) -> Tensor {
        Tensor::from_f32(shape, v).unwrap()
    }

    /// Strictly positive pseudo-random fill: keeps the serial conv
    /// references' `xi == 0.0` skips from ever firing, so the im2col
    /// paths (which include padding's exact `+0.0` terms) must match
    /// them bit for bit.
    fn fill(n: usize, seed: usize) -> Vec<f32> {
        (0..n)
            .map(|i| ((((i + seed).wrapping_mul(2654435761)) % 1000) as f32) * 0.013 + 0.05)
            .collect()
    }

    /// Signed pseudo-random fill for gradient planes.
    fn fill_signed(n: usize, seed: usize) -> Vec<f32> {
        fill(n, seed).into_iter().map(|v| v - 6.5).collect()
    }

    fn assert_bits(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: elem {i}: {x} vs {y}");
        }
    }

    const CONV_GEOMS: &[(usize, usize, usize, usize, usize, usize, usize, usize, Padding)] = &[
        // (n, h, w, ic, kh, kw, oc, stride, padding)
        (2, 5, 5, 3, 3, 3, 4, 1, Padding::Same),
        (1, 7, 6, 2, 3, 2, 3, 2, Padding::Valid),
        (2, 4, 4, 3, 1, 1, 5, 1, Padding::Same), // 1x1 direct (no im2col) path
        (1, 9, 9, 1, 4, 4, 2, 3, Padding::Same),
        (1, 3, 3, 2, 3, 3, 2, 1, Padding::Valid), // single output position (m = 1 GEMM)
    ];

    #[test]
    fn relu_and_grad() {
        let x = t(vec![4], vec![-1., 0., 2., -3.]);
        assert_eq!(relu(&x).unwrap().as_f32().unwrap(), &[0., 0., 2., 0.]);
        let dy = t(vec![4], vec![1., 1., 1., 1.]);
        assert_eq!(relu_grad(&dy, &x).unwrap().as_f32().unwrap(), &[0., 0., 1., 0.]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = t(vec![2, 3], vec![1., 2., 3., 1000., 1000., 1000.]);
        let s = softmax(&x).unwrap();
        let v = s.as_f32().unwrap();
        for r in 0..2 {
            let sum: f32 = v[r * 3..(r + 1) * 3].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
        // large-logit row must not produce NaN (stability check)
        assert!(!s.has_non_finite());
        assert!((v[3] - 1.0 / 3.0).abs() < 1e-5);
    }

    #[test]
    fn log_softmax_matches_softmax() {
        let x = t(vec![1, 4], vec![0.5, -1., 2., 0.]);
        let ls = log_softmax(&x).unwrap();
        let s = softmax(&x).unwrap();
        for (a, b) in ls.as_f32().unwrap().iter().zip(s.as_f32().unwrap()) {
            assert!((a.exp() - b).abs() < 1e-5);
        }
    }

    #[test]
    fn bias_add_and_grad() {
        let x = t(vec![2, 3], vec![0., 0., 0., 1., 1., 1.]);
        let b = t(vec![3], vec![1., 2., 3.]);
        let y = bias_add(&x, &b).unwrap();
        assert_eq!(y.as_f32().unwrap(), &[1., 2., 3., 2., 3., 4.]);
        let g = bias_add_grad(&y).unwrap();
        assert_eq!(g.as_f32().unwrap(), &[3., 5., 7.]);
    }

    #[test]
    fn bias_add_grad_parallel_matches_serial_exactly() {
        let pool = ComputePool::new(4, "nn-test");
        let (rows, c) = (37, 19);
        let dy = t(vec![rows, c], fill_signed(rows * c, 3));
        let reference = bias_add_grad(&dy).unwrap();
        let mut out = vec![0f32; c];
        bias_add_grad_into(&pool, dy.as_f32().unwrap(), rows, c, &mut out);
        assert_bits(&out, reference.as_f32().unwrap(), "bias_add_grad");
    }

    #[test]
    fn xent_loss_and_backprop() {
        // Perfect prediction -> loss near 0; backprop = p - y.
        let logits = t(vec![1, 3], vec![10., 0., 0.]);
        let labels = t(vec![1, 3], vec![1., 0., 0.]);
        let (loss, bp) = softmax_xent(&logits, &labels).unwrap();
        assert!(loss.as_f32().unwrap()[0] < 1e-3);
        let p = softmax(&logits).unwrap();
        for (b, (pi, yi)) in bp
            .as_f32()
            .unwrap()
            .iter()
            .zip(p.as_f32().unwrap().iter().zip(labels.as_f32().unwrap()))
        {
            assert!((b - (pi - yi)).abs() < 1e-6);
        }
    }

    #[test]
    fn xent_uniform() {
        let logits = t(vec![1, 4], vec![0., 0., 0., 0.]);
        let labels = t(vec![1, 4], vec![0.25; 4]);
        let (loss, _) = softmax_xent(&logits, &labels).unwrap();
        assert!((loss.as_f32().unwrap()[0] - (4f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn fused_xent_matches_two_step_exactly() {
        let pool = ComputePool::new(4, "nn-test");
        let (rows, cols) = (9, 7);
        let logits = t(vec![rows, cols], fill_signed(rows * cols, 11));
        let labels = t(vec![rows, cols], fill(rows * cols, 5));
        let (l0, b0) = softmax_xent(&logits, &labels).unwrap();
        let mut loss = vec![0f32; rows];
        let mut bp = vec![0f32; rows * cols];
        softmax_xent_into(
            &pool,
            logits.as_f32().unwrap(),
            labels.as_f32().unwrap(),
            rows,
            cols,
            &mut loss,
            &mut bp,
        );
        assert_bits(&loss, l0.as_f32().unwrap(), "xent loss");
        assert_bits(&bp, b0.as_f32().unwrap(), "xent backprop");
    }

    #[test]
    fn conv2d_identity_filter() {
        // 1x1 filter with weight 1 == identity.
        let x = t(vec![1, 2, 2, 1], vec![1., 2., 3., 4.]);
        let f = t(vec![1, 1, 1, 1], vec![1.]);
        let y = conv2d(&x, &f, 1, Padding::Same).unwrap();
        assert_eq!(y.as_f32().unwrap(), x.as_f32().unwrap());
    }

    #[test]
    fn conv2d_valid_sum_filter() {
        // 2x2 all-ones filter, VALID: each output = sum of 2x2 window.
        let x = t(vec![1, 3, 3, 1], (1..=9).map(|i| i as f32).collect());
        let f = t(vec![2, 2, 1, 1], vec![1.; 4]);
        let y = conv2d(&x, &f, 1, Padding::Valid).unwrap();
        assert_eq!(y.shape().dims(), &[1, 2, 2, 1]);
        assert_eq!(y.as_f32().unwrap(), &[12., 16., 24., 28.]);
    }

    #[test]
    fn conv2d_same_pads() {
        let x = t(vec![1, 2, 2, 1], vec![1., 1., 1., 1.]);
        let f = t(vec![3, 3, 1, 1], vec![1.; 9]);
        let y = conv2d(&x, &f, 1, Padding::Same).unwrap();
        assert_eq!(y.shape().dims(), &[1, 2, 2, 1]);
        // Every output sees all four ones.
        assert_eq!(y.as_f32().unwrap(), &[4., 4., 4., 4.]);
    }

    #[test]
    fn conv2d_stride2_shape() {
        let x = t(vec![1, 4, 4, 1], vec![0.; 16]);
        let f = t(vec![2, 2, 1, 1], vec![0.; 4]);
        let y = conv2d(&x, &f, 2, Padding::Valid).unwrap();
        assert_eq!(y.shape().dims(), &[1, 2, 2, 1]);
    }

    #[test]
    fn im2col_conv2d_matches_naive_exactly() {
        for &threads in &[1usize, 4] {
            let pool = ComputePool::new(threads, "nn-test");
            for &(n, h, w, ic, kh, kw, oc, stride, pad) in CONV_GEOMS {
                let x = t(vec![n, h, w, ic], fill(n * h * w * ic, 1));
                let f = t(vec![kh, kw, ic, oc], fill(kh * kw * ic * oc, 2));
                let reference = conv2d(&x, &f, stride, pad).unwrap();
                let packed = conv2d_with(&pool, &x, &f, stride, pad).unwrap();
                assert_eq!(packed.shape(), reference.shape());
                assert_bits(
                    packed.as_f32().unwrap(),
                    reference.as_f32().unwrap(),
                    &format!("conv {n}x{h}x{w}x{ic} k{kh}x{kw} s{stride} t{threads}"),
                );
            }
        }
    }

    #[test]
    fn im2col_conv_backprops_match_naive_exactly() {
        for &threads in &[1usize, 4] {
            let pool = ComputePool::new(threads, "nn-test");
            for &(n, h, w, ic, kh, kw, oc, stride, pad) in CONV_GEOMS {
                let x = t(vec![n, h, w, ic], fill(n * h * w * ic, 1));
                let f = t(vec![kh, kw, ic, oc], fill(kh * kw * ic * oc, 2));
                let y = conv2d(&x, &f, stride, pad).unwrap();
                let dy = t(y.shape().dims().to_vec(), fill_signed(y.num_elements(), 7));
                let what = format!("conv-bp {n}x{h}x{w}x{ic} k{kh}x{kw} s{stride} t{threads}");

                let dx_ref = conv2d_backprop_input(&dy, &f, x.shape(), stride, pad).unwrap();
                let dx = conv2d_backprop_input_with(&pool, &dy, &f, x.shape(), stride, pad).unwrap();
                assert_bits(dx.as_f32().unwrap(), dx_ref.as_f32().unwrap(), &format!("{what} dx"));

                let df_ref = conv2d_backprop_filter(&x, &dy, f.shape(), stride, pad).unwrap();
                let df = conv2d_backprop_filter_with(&pool, &x, &dy, f.shape(), stride, pad).unwrap();
                assert_bits(df.as_f32().unwrap(), df_ref.as_f32().unwrap(), &format!("{what} df"));
            }
        }
    }

    #[test]
    fn maxpool_and_grad() {
        let x = t(vec![1, 2, 2, 1], vec![1., 5., 3., 2.]);
        let (y, arg) = max_pool(&x, 2, 2, Padding::Valid).unwrap();
        assert_eq!(y.as_f32().unwrap(), &[5.]);
        let dy = t(vec![1, 1, 1, 1], vec![10.]);
        let dx = max_pool_grad(&dy, &arg, x.shape()).unwrap();
        assert_eq!(dx.as_f32().unwrap(), &[0., 10., 0., 0.]);
    }

    #[test]
    fn parallel_maxpool_and_grad_match_serial_exactly() {
        let pool = ComputePool::new(4, "nn-test");
        // (k, stride, padding); stride < k exercises overlapping windows.
        for &(k, stride, pad) in
            &[(2usize, 2usize, Padding::Valid), (3, 2, Padding::Same), (2, 1, Padding::Same)]
        {
            let (n, h, w, c) = (2, 6, 5, 3);
            let x = t(vec![n, h, w, c], fill_signed(n * h * w * c, 13));
            let (y0, a0) = max_pool(&x, k, stride, pad).unwrap();
            let (y1, a1) = max_pool_with(&pool, &x, k, stride, pad).unwrap();
            let what = format!("maxpool k{k} s{stride}");
            assert_eq!(y1.shape(), y0.shape());
            assert_bits(y1.as_f32().unwrap(), y0.as_f32().unwrap(), &what);
            assert_eq!(a1.as_i64().unwrap(), a0.as_i64().unwrap(), "{what} argmax");

            let dy = t(y0.shape().dims().to_vec(), fill_signed(y0.num_elements(), 17));
            let dx0 = max_pool_grad(&dy, &a0, x.shape()).unwrap();
            let dx1 = max_pool_grad_with(&pool, &dy, &a1, x.shape(), k, stride, pad).unwrap();
            assert_bits(dx1.as_f32().unwrap(), dx0.as_f32().unwrap(), &format!("{what} grad"));
        }
    }

    #[test]
    fn max_pool_grad_with_rejects_hostile_argmax() {
        let pool = ComputePool::new(2, "nn-test");
        let dy = t(vec![1, 1, 1, 1], vec![1.0]);
        let arg = Tensor::new(Shape(vec![1, 1, 1, 1]), TensorData::I64(vec![99])).unwrap();
        let shape = Shape(vec![1, 2, 2, 1]);
        let err = max_pool_grad_with(&pool, &dy, &arg, &shape, 2, 2, Padding::Valid).unwrap_err();
        assert!(err.to_string().contains("argmax out of range"), "{err}");
    }

    #[test]
    fn conv_grads_match_finite_difference() {
        // Tiny conv; check d(sum(y))/dx and /df via FD.
        let x = t(vec![1, 3, 3, 1], (0..9).map(|i| (i as f32) * 0.1).collect());
        let f = t(vec![2, 2, 1, 1], vec![0.5, -0.2, 0.3, 0.8]);
        let stride = 1;
        let pad = Padding::Valid;
        let y = conv2d(&x, &f, stride, pad).unwrap();
        let dy = Tensor::fill_f32(y.shape().clone(), 1.0);
        let dx = conv2d_backprop_input(&dy, &f, x.shape(), stride, pad).unwrap();
        let df = conv2d_backprop_filter(&x, &dy, f.shape(), stride, pad).unwrap();
        let eps = 1e-3;
        let sum = |t: &Tensor| -> f32 { t.as_f32().unwrap().iter().sum() };
        // FD wrt one x element
        for check_idx in [0, 4, 8] {
            let mut xv = x.as_f32().unwrap().to_vec();
            xv[check_idx] += eps;
            let x2 = t(vec![1, 3, 3, 1], xv);
            let fd = (sum(&conv2d(&x2, &f, stride, pad).unwrap()) - sum(&y)) / eps;
            assert!(
                (fd - dx.as_f32().unwrap()[check_idx]).abs() < 1e-2,
                "dx[{check_idx}]: fd={fd} analytic={}",
                dx.as_f32().unwrap()[check_idx]
            );
        }
        // FD wrt one filter element
        for check_idx in [0, 3] {
            let mut fv = f.as_f32().unwrap().to_vec();
            fv[check_idx] += eps;
            let f2 = t(vec![2, 2, 1, 1], fv);
            let fd = (sum(&conv2d(&x, &f2, stride, pad).unwrap()) - sum(&y)) / eps;
            assert!(
                (fd - df.as_f32().unwrap()[check_idx]).abs() < 1e-2,
                "df[{check_idx}]: fd={fd} analytic={}",
                df.as_f32().unwrap()[check_idx]
            );
        }
    }
}
