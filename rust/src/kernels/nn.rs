//! Neural-net building blocks (Table 1 row 5): ReLU, Sigmoid, SoftMax,
//! Convolution2D, MaxPool, BiasAdd, the fused softmax cross-entropy, and
//! their gradient kernels (registered as ops so the §4.1 autodiff can
//! reference them).

use super::{KernelContext, KernelRegistry};
use crate::device::ComputePool;
use crate::error::{Result, Status};
use crate::tensor::{Shape, Tensor, TensorData};

/// Approximate per-element scalar-op cost of a softmax row pass (exp +
/// max + normalize), driving the intra-op inline threshold.
const SOFTMAX_ELEM_COST: usize = 16;

/// Scalar ReLU, shared with the fused-elementwise interpreter
/// (`kernels::fused`) so fused and unfused graphs agree exactly.
pub(crate) fn f32_relu(v: f32) -> f32 {
    v.max(0.0)
}

/// Scalar sigmoid, shared with `kernels::fused` for the same reason.
pub(crate) fn f32_sigmoid(v: f32) -> f32 {
    1.0 / (1.0 + (-v).exp())
}

pub fn relu(x: &Tensor) -> Result<Tensor> {
    let v = x.as_f32()?;
    Tensor::new(x.shape().clone(), TensorData::F32(v.iter().map(|&a| f32_relu(a)).collect()))
}

/// dx = dy * (features > 0)
pub fn relu_grad(dy: &Tensor, features: &Tensor) -> Result<Tensor> {
    let g = dy.as_f32()?;
    let f = features.as_f32()?;
    if g.len() != f.len() {
        return Err(Status::invalid_argument("ReluGrad: size mismatch"));
    }
    Tensor::new(
        dy.shape().clone(),
        TensorData::F32(g.iter().zip(f).map(|(&gi, &fi)| if fi > 0.0 { gi } else { 0.0 }).collect()),
    )
}

pub fn sigmoid(x: &Tensor) -> Result<Tensor> {
    let v = x.as_f32()?;
    Tensor::new(
        x.shape().clone(),
        TensorData::F32(v.iter().map(|&a| f32_sigmoid(a)).collect()),
    )
}

/// The softmax row body: rows are independent and each is computed with
/// a fixed operation order, so distributing rows over `pool` is
/// bit-identical to serial for every thread count.
fn softmax_rows(pool: &ComputePool, v: &[f32], rows: usize, cols: usize, out: &mut [f32]) {
    pool.parallel_for_mut(rows, cols.saturating_mul(SOFTMAX_ELEM_COST).max(1), out, |rr, o| {
        for (ri, r) in rr.enumerate() {
            let row = &v[r * cols..(r + 1) * cols];
            let orow = &mut o[ri * cols..(ri + 1) * cols];
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut sum = 0f32;
            for c in 0..cols {
                let e = (row[c] - m).exp();
                orow[c] = e;
                sum += e;
            }
            for oc in orow.iter_mut() {
                *oc /= sum;
            }
        }
    });
}

/// The log-softmax row body (see [`softmax_rows`] for the parallelism
/// contract).
fn log_softmax_rows(pool: &ComputePool, v: &[f32], rows: usize, cols: usize, out: &mut [f32]) {
    pool.parallel_for_mut(rows, cols.saturating_mul(SOFTMAX_ELEM_COST).max(1), out, |rr, o| {
        for (ri, r) in rr.enumerate() {
            let row = &v[r * cols..(r + 1) * cols];
            let orow = &mut o[ri * cols..(ri + 1) * cols];
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let lse = row.iter().map(|&a| (a - m).exp()).sum::<f32>().ln() + m;
            for (oc, &rc) in orow.iter_mut().zip(row) {
                *oc = rc - lse;
            }
        }
    });
}

/// Row softmax over the last axis of a 2-D tensor (numerically stable).
/// Serial heap convenience; the kernel path is [`softmax_planned`].
pub fn softmax(x: &Tensor) -> Result<Tensor> {
    let (rows, cols) = rank2(x, "SoftMax")?;
    let v = x.as_f32()?;
    let mut out = vec![0f32; v.len()];
    softmax_rows(&ComputePool::serial(), v, rows, cols, &mut out);
    Tensor::new(x.shape().clone(), TensorData::F32(out))
}

/// Memory-planned [`softmax`]: output in the node's arena slot, rows
/// distributed over the device's intra-op pool.
pub(crate) fn softmax_planned(ctx: &KernelContext) -> Result<Tensor> {
    let (rows, cols) = rank2(ctx.input(0)?, "SoftMax")?;
    let shape = ctx.input(0)?.shape().clone();
    let mut out = ctx.alloc_f32_zeroed(0, rows * cols);
    {
        let v = ctx.input(0)?.as_f32()?;
        softmax_rows(&ctx.device.compute, v, rows, cols, &mut out);
    }
    ctx.make_output(0, shape, TensorData::F32(out))
}

pub fn log_softmax(x: &Tensor) -> Result<Tensor> {
    let (rows, cols) = rank2(x, "LogSoftmax")?;
    let v = x.as_f32()?;
    let mut out = vec![0f32; v.len()];
    log_softmax_rows(&ComputePool::serial(), v, rows, cols, &mut out);
    Tensor::new(x.shape().clone(), TensorData::F32(out))
}

/// Memory-planned [`log_softmax`] (see [`softmax_planned`]).
pub(crate) fn log_softmax_planned(ctx: &KernelContext) -> Result<Tensor> {
    let (rows, cols) = rank2(ctx.input(0)?, "LogSoftmax")?;
    let shape = ctx.input(0)?.shape().clone();
    let mut out = ctx.alloc_f32_zeroed(0, rows * cols);
    {
        let v = ctx.input(0)?.as_f32()?;
        log_softmax_rows(&ctx.device.compute, v, rows, cols, &mut out);
    }
    ctx.make_output(0, shape, TensorData::F32(out))
}

/// BiasAdd: add a [C] bias over the last axis.
pub fn bias_add(x: &Tensor, b: &Tensor) -> Result<Tensor> {
    let bd = b.shape().dims();
    if bd.len() != 1 {
        return Err(Status::invalid_argument("BiasAdd: bias must be rank 1"));
    }
    let c = bd[0];
    let xd = x.shape().dims();
    if xd.last() != Some(&c) {
        return Err(Status::invalid_argument(format!(
            "BiasAdd: last dim {} != bias size {c}",
            xd.last().copied().unwrap_or(0)
        )));
    }
    let xv = x.as_f32()?;
    let bv = b.as_f32()?;
    let mut out = Vec::with_capacity(xv.len());
    for (i, &v) in xv.iter().enumerate() {
        out.push(v + bv[i % c]);
    }
    Tensor::new(x.shape().clone(), TensorData::F32(out))
}

/// Gradient of BiasAdd wrt bias: sum over all but last axis.
pub fn bias_add_grad(dy: &Tensor) -> Result<Tensor> {
    let xd = dy.shape().dims();
    let c = *xd.last().ok_or_else(|| Status::invalid_argument("BiasAddGrad: rank 0"))?;
    let v = dy.as_f32()?;
    let mut out = vec![0f32; c];
    for (i, &g) in v.iter().enumerate() {
        out[i % c] += g;
    }
    Tensor::new(Shape(vec![c]), TensorData::F32(out))
}

/// Fused softmax cross entropy: returns (loss[batch], backprop[batch,classes])
/// where backprop = softmax(logits) - labels (labels are one-hot/probabilities).
pub fn softmax_xent(logits: &Tensor, labels: &Tensor) -> Result<(Tensor, Tensor)> {
    let (rows, cols) = rank2(logits, "SoftmaxCrossEntropyWithLogits")?;
    if logits.shape() != labels.shape() {
        return Err(Status::invalid_argument("xent: logits and labels shapes differ"));
    }
    let lsm = log_softmax(logits)?;
    let lsm_v = lsm.as_f32()?;
    let lab = labels.as_f32()?;
    let mut loss = vec![0f32; rows];
    let mut backprop = vec![0f32; rows * cols];
    for r in 0..rows {
        let mut l = 0f32;
        for c in 0..cols {
            let i = r * cols + c;
            l -= lab[i] * lsm_v[i];
            backprop[i] = lsm_v[i].exp() - lab[i];
        }
        loss[r] = l;
    }
    Ok((
        Tensor::new(Shape(vec![rows]), TensorData::F32(loss))?,
        Tensor::new(Shape(vec![rows, cols]), TensorData::F32(backprop))?,
    ))
}

/// Padding mode for conv/pool.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Padding {
    Same,
    Valid,
}

impl Padding {
    pub fn parse(s: &str) -> Result<Padding> {
        match s.to_uppercase().as_str() {
            "SAME" => Ok(Padding::Same),
            "VALID" => Ok(Padding::Valid),
            other => Err(Status::invalid_argument(format!("unknown padding {other:?}"))),
        }
    }

    fn out_dim(&self, input: usize, filter: usize, stride: usize) -> usize {
        match self {
            Padding::Same => input.div_ceil(stride),
            Padding::Valid => (input - filter) / stride + 1,
        }
    }

    /// Total padding before the first window element.
    fn pad_before(&self, input: usize, filter: usize, stride: usize) -> i64 {
        match self {
            Padding::Valid => 0,
            Padding::Same => {
                let out = self.out_dim(input, filter, stride);
                let total = ((out - 1) * stride + filter).saturating_sub(input) as i64;
                total / 2
            }
        }
    }
}

/// Direct 2-D convolution. x: NHWC, filter: [kh, kw, in_c, out_c].
pub fn conv2d(x: &Tensor, filter: &Tensor, stride: usize, padding: Padding) -> Result<Tensor> {
    let xd = x.shape().dims();
    let fd = filter.shape().dims();
    if xd.len() != 4 || fd.len() != 4 {
        return Err(Status::invalid_argument("Conv2D: x must be NHWC, filter [kh,kw,ic,oc]"));
    }
    let (n, h, w, ic) = (xd[0], xd[1], xd[2], xd[3]);
    let (kh, kw, fic, oc) = (fd[0], fd[1], fd[2], fd[3]);
    if ic != fic {
        return Err(Status::invalid_argument(format!("Conv2D: channels {ic} != filter {fic}")));
    }
    let oh = padding.out_dim(h, kh, stride);
    let ow = padding.out_dim(w, kw, stride);
    let ph = padding.pad_before(h, kh, stride);
    let pw = padding.pad_before(w, kw, stride);
    let xv = x.as_f32()?;
    let fv = filter.as_f32()?;
    let mut out = vec![0f32; n * oh * ow * oc];
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ky in 0..kh {
                    let iy = oy as i64 * stride as i64 + ky as i64 - ph;
                    if iy < 0 || iy >= h as i64 {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = ox as i64 * stride as i64 + kx as i64 - pw;
                        if ix < 0 || ix >= w as i64 {
                            continue;
                        }
                        let x_base = ((b * h + iy as usize) * w + ix as usize) * ic;
                        let f_base = (ky * kw + kx) * ic * oc;
                        let o_base = ((b * oh + oy) * ow + ox) * oc;
                        for ci in 0..ic {
                            let xi = xv[x_base + ci];
                            if xi == 0.0 {
                                continue;
                            }
                            let fo = f_base + ci * oc;
                            for co in 0..oc {
                                out[o_base + co] += xi * fv[fo + co];
                            }
                        }
                    }
                }
            }
        }
    }
    Tensor::new(Shape(vec![n, oh, ow, oc]), TensorData::F32(out))
}

/// MaxPool over kxk windows; returns (output, flat argmax indices).
pub fn max_pool(x: &Tensor, k: usize, stride: usize, padding: Padding) -> Result<(Tensor, Tensor)> {
    let xd = x.shape().dims();
    if xd.len() != 4 {
        return Err(Status::invalid_argument("MaxPool: x must be NHWC"));
    }
    let (n, h, w, c) = (xd[0], xd[1], xd[2], xd[3]);
    let oh = padding.out_dim(h, k, stride);
    let ow = padding.out_dim(w, k, stride);
    let ph = padding.pad_before(h, k, stride);
    let pw = padding.pad_before(w, k, stride);
    let xv = x.as_f32()?;
    let mut out = vec![f32::NEG_INFINITY; n * oh * ow * c];
    let mut arg = vec![0i64; n * oh * ow * c];
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ky in 0..k {
                    let iy = oy as i64 * stride as i64 + ky as i64 - ph;
                    if iy < 0 || iy >= h as i64 {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = ox as i64 * stride as i64 + kx as i64 - pw;
                        if ix < 0 || ix >= w as i64 {
                            continue;
                        }
                        let x_base = ((b * h + iy as usize) * w + ix as usize) * c;
                        let o_base = ((b * oh + oy) * ow + ox) * c;
                        for ci in 0..c {
                            let v = xv[x_base + ci];
                            if v > out[o_base + ci] {
                                out[o_base + ci] = v;
                                arg[o_base + ci] = (x_base + ci) as i64;
                            }
                        }
                    }
                }
            }
        }
    }
    Ok((
        Tensor::new(Shape(vec![n, oh, ow, c]), TensorData::F32(out))?,
        Tensor::new(Shape(vec![n, oh, ow, c]), TensorData::I64(arg))?,
    ))
}

/// Scatter pooled gradients back through the argmax indices.
pub fn max_pool_grad(dy: &Tensor, argmax: &Tensor, input_shape: &Shape) -> Result<Tensor> {
    let g = dy.as_f32()?;
    let a = argmax.as_i64()?;
    let mut out = vec![0f32; input_shape.num_elements()];
    for (i, &gi) in g.iter().enumerate() {
        let idx = a[i] as usize;
        if idx >= out.len() {
            return Err(Status::invalid_argument("MaxPoolGrad: argmax out of range"));
        }
        out[idx] += gi;
    }
    Tensor::new(input_shape.clone(), TensorData::F32(out))
}

/// Conv2D gradient wrt input (direct, full correlation with flipped filter).
pub fn conv2d_backprop_input(
    dy: &Tensor,
    filter: &Tensor,
    input_shape: &Shape,
    stride: usize,
    padding: Padding,
) -> Result<Tensor> {
    let id = input_shape.dims();
    let fd = filter.shape().dims();
    let dyd = dy.shape().dims();
    let (n, h, w, ic) = (id[0], id[1], id[2], id[3]);
    let (kh, kw, _fic, oc) = (fd[0], fd[1], fd[2], fd[3]);
    let (oh, ow) = (dyd[1], dyd[2]);
    let ph = padding.pad_before(h, kh, stride);
    let pw = padding.pad_before(w, kw, stride);
    let gv = dy.as_f32()?;
    let fv = filter.as_f32()?;
    let mut out = vec![0f32; input_shape.num_elements()];
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ky in 0..kh {
                    let iy = oy as i64 * stride as i64 + ky as i64 - ph;
                    if iy < 0 || iy >= h as i64 {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = ox as i64 * stride as i64 + kx as i64 - pw;
                        if ix < 0 || ix >= w as i64 {
                            continue;
                        }
                        let x_base = ((b * h + iy as usize) * w + ix as usize) * ic;
                        let f_base = (ky * kw + kx) * ic * oc;
                        let g_base = ((b * oh + oy) * ow + ox) * oc;
                        for ci in 0..ic {
                            let mut s = 0f32;
                            let fo = f_base + ci * oc;
                            for co in 0..oc {
                                s += gv[g_base + co] * fv[fo + co];
                            }
                            out[x_base + ci] += s;
                        }
                    }
                }
            }
        }
    }
    Tensor::new(input_shape.clone(), TensorData::F32(out))
}

/// Conv2D gradient wrt filter.
pub fn conv2d_backprop_filter(
    x: &Tensor,
    dy: &Tensor,
    filter_shape: &Shape,
    stride: usize,
    padding: Padding,
) -> Result<Tensor> {
    let xd = x.shape().dims();
    let fd = filter_shape.dims();
    let dyd = dy.shape().dims();
    let (n, h, w, ic) = (xd[0], xd[1], xd[2], xd[3]);
    let (kh, kw, _fic, oc) = (fd[0], fd[1], fd[2], fd[3]);
    let (oh, ow) = (dyd[1], dyd[2]);
    let ph = padding.pad_before(h, kh, stride);
    let pw = padding.pad_before(w, kw, stride);
    let xv = x.as_f32()?;
    let gv = dy.as_f32()?;
    let mut out = vec![0f32; filter_shape.num_elements()];
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ky in 0..kh {
                    let iy = oy as i64 * stride as i64 + ky as i64 - ph;
                    if iy < 0 || iy >= h as i64 {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = ox as i64 * stride as i64 + kx as i64 - pw;
                        if ix < 0 || ix >= w as i64 {
                            continue;
                        }
                        let x_base = ((b * h + iy as usize) * w + ix as usize) * ic;
                        let f_base = (ky * kw + kx) * ic * oc;
                        let g_base = ((b * oh + oy) * ow + ox) * oc;
                        for ci in 0..ic {
                            let xi = xv[x_base + ci];
                            if xi == 0.0 {
                                continue;
                            }
                            let fo = f_base + ci * oc;
                            for co in 0..oc {
                                out[fo + co] += xi * gv[g_base + co];
                            }
                        }
                    }
                }
            }
        }
    }
    Tensor::new(filter_shape.clone(), TensorData::F32(out))
}

fn rank2(t: &Tensor, what: &str) -> Result<(usize, usize)> {
    let d = t.shape().dims();
    match d.len() {
        2 => Ok((d[0], d[1])),
        1 => Ok((1, d[0])),
        _ => Err(Status::invalid_argument(format!("{what}: expected rank 1/2, got {}", t.shape()))),
    }
}

fn conv_attrs(ctx: &KernelContext) -> Result<(usize, Padding)> {
    let stride =
        ctx.node.attr_opt("stride").map(|a| a.as_i64()).transpose()?.unwrap_or(1) as usize;
    let padding = Padding::parse(
        ctx.node.attr_opt("padding").map(|a| a.as_str()).transpose()?.unwrap_or("SAME"),
    )?;
    Ok((stride, padding))
}

pub(super) fn register(r: &mut KernelRegistry) {
    // ReLU/Sigmoid go through the shared memory-planned map
    // (`math::planned_unary_map`) with the same scalar functions the
    // fused interpreter uses, so planned/unplanned/fused all agree.
    r.add_sync("ReLU", |ctx| {
        Ok(vec![crate::kernels::math::planned_unary_map(ctx, f32_relu, 1)?])
    });
    r.add_sync("ReluGrad", |ctx| Ok(vec![relu_grad(ctx.input(0)?, ctx.input(1)?)?]));
    r.add_sync("Sigmoid", |ctx| {
        Ok(vec![crate::kernels::math::planned_unary_map(ctx, f32_sigmoid, 12)?])
    });
    r.add_sync("SoftMax", |ctx| Ok(vec![softmax_planned(ctx)?]));
    r.add_sync("LogSoftmax", |ctx| Ok(vec![log_softmax_planned(ctx)?]));
    r.add_sync("BiasAdd", |ctx| Ok(vec![bias_add(ctx.input(0)?, ctx.input(1)?)?]));
    r.add_sync("BiasAddGrad", |ctx| Ok(vec![bias_add_grad(ctx.input(0)?)?]));
    r.add_sync("SoftmaxCrossEntropyWithLogits", |ctx| {
        let (loss, backprop) = softmax_xent(ctx.input(0)?, ctx.input(1)?)?;
        Ok(vec![loss, backprop])
    });
    r.add_sync("L2Loss", |ctx| {
        let v = ctx.input(0)?.as_f32()?;
        let s: f64 = v.iter().map(|&x| (x as f64) * (x as f64)).sum();
        Ok(vec![Tensor::scalar_f32((s / 2.0) as f32)])
    });
    r.add_sync("Convolution2D", |ctx| {
        let (stride, padding) = conv_attrs(ctx)?;
        Ok(vec![conv2d(ctx.input(0)?, ctx.input(1)?, stride, padding)?])
    });
    r.add_sync("Conv2DBackpropInput", |ctx| {
        // inputs: (dy, filter, original-input-for-shape)
        let (stride, padding) = conv_attrs(ctx)?;
        let shape = ctx.input(2)?.shape().clone();
        Ok(vec![conv2d_backprop_input(ctx.input(0)?, ctx.input(1)?, &shape, stride, padding)?])
    });
    r.add_sync("Conv2DBackpropFilter", |ctx| {
        // inputs: (x, dy, original-filter-for-shape)
        let (stride, padding) = conv_attrs(ctx)?;
        let shape = ctx.input(2)?.shape().clone();
        Ok(vec![conv2d_backprop_filter(ctx.input(0)?, ctx.input(1)?, &shape, stride, padding)?])
    });
    r.add_sync("MaxPool", |ctx| {
        let k = ctx.node.attr_opt("ksize").map(|a| a.as_i64()).transpose()?.unwrap_or(2) as usize;
        let (stride, padding) = conv_attrs(ctx)?;
        let (out, arg) = max_pool(ctx.input(0)?, k, stride, padding)?;
        Ok(vec![out, arg])
    });
    r.add_sync("MaxPoolGrad", |ctx| {
        // inputs: dy, argmax, original input (for shape)
        let shape = ctx.input(2)?.shape().clone();
        Ok(vec![max_pool_grad(ctx.input(0)?, ctx.input(1)?, &shape)?])
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: Vec<usize>, v: Vec<f32>) -> Tensor {
        Tensor::from_f32(shape, v).unwrap()
    }

    #[test]
    fn relu_and_grad() {
        let x = t(vec![4], vec![-1., 0., 2., -3.]);
        assert_eq!(relu(&x).unwrap().as_f32().unwrap(), &[0., 0., 2., 0.]);
        let dy = t(vec![4], vec![1., 1., 1., 1.]);
        assert_eq!(relu_grad(&dy, &x).unwrap().as_f32().unwrap(), &[0., 0., 1., 0.]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = t(vec![2, 3], vec![1., 2., 3., 1000., 1000., 1000.]);
        let s = softmax(&x).unwrap();
        let v = s.as_f32().unwrap();
        for r in 0..2 {
            let sum: f32 = v[r * 3..(r + 1) * 3].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
        // large-logit row must not produce NaN (stability check)
        assert!(!s.has_non_finite());
        assert!((v[3] - 1.0 / 3.0).abs() < 1e-5);
    }

    #[test]
    fn log_softmax_matches_softmax() {
        let x = t(vec![1, 4], vec![0.5, -1., 2., 0.]);
        let ls = log_softmax(&x).unwrap();
        let s = softmax(&x).unwrap();
        for (a, b) in ls.as_f32().unwrap().iter().zip(s.as_f32().unwrap()) {
            assert!((a.exp() - b).abs() < 1e-5);
        }
    }

    #[test]
    fn bias_add_and_grad() {
        let x = t(vec![2, 3], vec![0., 0., 0., 1., 1., 1.]);
        let b = t(vec![3], vec![1., 2., 3.]);
        let y = bias_add(&x, &b).unwrap();
        assert_eq!(y.as_f32().unwrap(), &[1., 2., 3., 2., 3., 4.]);
        let g = bias_add_grad(&y).unwrap();
        assert_eq!(g.as_f32().unwrap(), &[3., 5., 7.]);
    }

    #[test]
    fn xent_loss_and_backprop() {
        // Perfect prediction -> loss near 0; backprop = p - y.
        let logits = t(vec![1, 3], vec![10., 0., 0.]);
        let labels = t(vec![1, 3], vec![1., 0., 0.]);
        let (loss, bp) = softmax_xent(&logits, &labels).unwrap();
        assert!(loss.as_f32().unwrap()[0] < 1e-3);
        let p = softmax(&logits).unwrap();
        for (b, (pi, yi)) in bp
            .as_f32()
            .unwrap()
            .iter()
            .zip(p.as_f32().unwrap().iter().zip(labels.as_f32().unwrap()))
        {
            assert!((b - (pi - yi)).abs() < 1e-6);
        }
    }

    #[test]
    fn xent_uniform() {
        let logits = t(vec![1, 4], vec![0., 0., 0., 0.]);
        let labels = t(vec![1, 4], vec![0.25; 4]);
        let (loss, _) = softmax_xent(&logits, &labels).unwrap();
        assert!((loss.as_f32().unwrap()[0] - (4f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn conv2d_identity_filter() {
        // 1x1 filter with weight 1 == identity.
        let x = t(vec![1, 2, 2, 1], vec![1., 2., 3., 4.]);
        let f = t(vec![1, 1, 1, 1], vec![1.]);
        let y = conv2d(&x, &f, 1, Padding::Same).unwrap();
        assert_eq!(y.as_f32().unwrap(), x.as_f32().unwrap());
    }

    #[test]
    fn conv2d_valid_sum_filter() {
        // 2x2 all-ones filter, VALID: each output = sum of 2x2 window.
        let x = t(vec![1, 3, 3, 1], (1..=9).map(|i| i as f32).collect());
        let f = t(vec![2, 2, 1, 1], vec![1.; 4]);
        let y = conv2d(&x, &f, 1, Padding::Valid).unwrap();
        assert_eq!(y.shape().dims(), &[1, 2, 2, 1]);
        assert_eq!(y.as_f32().unwrap(), &[12., 16., 24., 28.]);
    }

    #[test]
    fn conv2d_same_pads() {
        let x = t(vec![1, 2, 2, 1], vec![1., 1., 1., 1.]);
        let f = t(vec![3, 3, 1, 1], vec![1.; 9]);
        let y = conv2d(&x, &f, 1, Padding::Same).unwrap();
        assert_eq!(y.shape().dims(), &[1, 2, 2, 1]);
        // Every output sees all four ones.
        assert_eq!(y.as_f32().unwrap(), &[4., 4., 4., 4.]);
    }

    #[test]
    fn conv2d_stride2_shape() {
        let x = t(vec![1, 4, 4, 1], vec![0.; 16]);
        let f = t(vec![2, 2, 1, 1], vec![0.; 4]);
        let y = conv2d(&x, &f, 2, Padding::Valid).unwrap();
        assert_eq!(y.shape().dims(), &[1, 2, 2, 1]);
    }

    #[test]
    fn maxpool_and_grad() {
        let x = t(vec![1, 2, 2, 1], vec![1., 5., 3., 2.]);
        let (y, arg) = max_pool(&x, 2, 2, Padding::Valid).unwrap();
        assert_eq!(y.as_f32().unwrap(), &[5.]);
        let dy = t(vec![1, 1, 1, 1], vec![10.]);
        let dx = max_pool_grad(&dy, &arg, x.shape()).unwrap();
        assert_eq!(dx.as_f32().unwrap(), &[0., 10., 0., 0.]);
    }

    #[test]
    fn conv_grads_match_finite_difference() {
        // Tiny conv; check d(sum(y))/dx and /df via FD.
        let x = t(vec![1, 3, 3, 1], (0..9).map(|i| (i as f32) * 0.1).collect());
        let f = t(vec![2, 2, 1, 1], vec![0.5, -0.2, 0.3, 0.8]);
        let stride = 1;
        let pad = Padding::Valid;
        let y = conv2d(&x, &f, stride, pad).unwrap();
        let dy = Tensor::fill_f32(y.shape().clone(), 1.0);
        let dx = conv2d_backprop_input(&dy, &f, x.shape(), stride, pad).unwrap();
        let df = conv2d_backprop_filter(&x, &dy, f.shape(), stride, pad).unwrap();
        let eps = 1e-3;
        let sum = |t: &Tensor| -> f32 { t.as_f32().unwrap().iter().sum() };
        // FD wrt one x element
        for check_idx in [0, 4, 8] {
            let mut xv = x.as_f32().unwrap().to_vec();
            xv[check_idx] += eps;
            let x2 = t(vec![1, 3, 3, 1], xv);
            let fd = (sum(&conv2d(&x2, &f, stride, pad).unwrap()) - sum(&y)) / eps;
            assert!(
                (fd - dx.as_f32().unwrap()[check_idx]).abs() < 1e-2,
                "dx[{check_idx}]: fd={fd} analytic={}",
                dx.as_f32().unwrap()[check_idx]
            );
        }
        // FD wrt one filter element
        for check_idx in [0, 3] {
            let mut fv = f.as_f32().unwrap().to_vec();
            fv[check_idx] += eps;
            let f2 = t(vec![2, 2, 1, 1], fv);
            let fd = (sum(&conv2d(&x, &f2, stride, pad).unwrap()) - sum(&y)) / eps;
            assert!(
                (fd - df.as_f32().unwrap()[check_idx]).abs() < 1e-2,
                "df[{check_idx}]: fd={fd} analytic={}",
                df.as_f32().unwrap()[check_idx]
            );
        }
    }
}
