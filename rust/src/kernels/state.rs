//! Stateful operations (Table 1 row 4): Variable / Assign / AssignAdd /
//! AssignSub (§2 "Variables"), CountUpTo, and the optimizer apply ops
//! (ApplyGradientDescent/Momentum/Adagrad/Adam) whose read-modify-write is
//! atomic per variable — §6 lesson 4 is about the races you get otherwise.
//!
//! A Variable node's backing store is resolved through the node's
//! container (§4.7): resource key = the Variable node's name (TF's
//! `shared_name` default).

use super::{KernelContext, KernelRegistry};
use crate::error::{Result, Status};
use crate::kernels::math::binary_elementwise;
use crate::tensor::{Tensor, TensorData};

/// Resolve the variable state for a ref-input op.
fn var_of(ctx: &KernelContext) -> Result<(std::sync::Arc<crate::resources::VariableState>, String)> {
    let name = ctx.node.ref_resource()?.to_string();
    Ok((ctx.container().variable(&name), name))
}

/// elementwise a*s + b*t for f32 (s,t scalars) — optimizer helper.
fn axpby(a: &Tensor, s: f32, b: &Tensor, t: f32) -> Result<Tensor> {
    let av = a.as_f32()?;
    let bv = b.as_f32()?;
    if av.len() != bv.len() {
        return Err(Status::invalid_argument("axpby: length mismatch"));
    }
    Tensor::new(
        a.shape().clone(),
        TensorData::F32(av.iter().zip(bv).map(|(&x, &y)| x * s + y * t).collect()),
    )
}

pub(super) fn register(r: &mut KernelRegistry) {
    // Variable: read the current value ("returns a handle to a persistent
    // mutable tensor"); the "handle" is the value itself plus the executor's
    // ref-resolution of downstream Assign-like consumers.
    r.add("Variable", |node| {
        let name = node.name.clone();
        // Consumers are all ref-ops (Assign etc.): hand out a ref sentinel
        // without dereferencing — TF's Variable op never reads its buffer;
        // only real value-reads check initialization.
        let ref_only = node.attr_opt("_ref_only").and_then(|a| a.as_bool().ok()).unwrap_or(false);
        let dtype = node.attr_opt("T").and_then(|a| a.as_type().ok()).unwrap_or(crate::tensor::DType::F32);
        Ok(super::Kernel::Sync(Box::new(move |ctx: &mut KernelContext| {
            let v = ctx.container().variable(&name);
            if ref_only {
                return Ok(vec![v
                    .read(&name)
                    .unwrap_or(Tensor::zeros(dtype, vec![0])?)]);
            }
            Ok(vec![v.read(&name)?])
        })))
    });

    r.add_sync("Assign", |ctx| {
        let (var, _) = var_of(ctx)?;
        let value = ctx.input(1)?.clone();
        var.assign(value.clone());
        Ok(vec![value])
    });

    r.add_sync("AssignAdd", |ctx| {
        let (var, name) = var_of(ctx)?;
        Ok(vec![var.assign_add(&name, ctx.input(1)?)?])
    });

    r.add_sync("AssignSub", |ctx| {
        let (var, name) = var_of(ctx)?;
        Ok(vec![var.assign_sub(&name, ctx.input(1)?)?])
    });

    // CountUpTo: increment a scalar variable, error at the limit (used by
    // epoch-limited input pipelines).
    r.add_sync("CountUpTo", |ctx| {
        let (var, name) = var_of(ctx)?;
        let limit = ctx.node.attr("limit")?.as_i64()?;
        let old = var.update(&name, |cur| {
            let c = cur.scalar_value_i64()?;
            if c >= limit {
                return Err(Status::out_of_range(format!("CountUpTo: reached limit {limit}")));
            }
            Ok(Tensor::scalar_i64(c + 1))
        })?;
        let prev = old.scalar_value_i64()? - 1;
        Ok(vec![Tensor::scalar_i64(prev)])
    });

    // var -= lr * grad. Inputs: (var_ref, lr, grad).
    r.add_sync("ApplyGradientDescent", |ctx| {
        let (var, name) = var_of(ctx)?;
        let lr = ctx.input(1)?.scalar_value_f32()?;
        let grad = ctx.input(2)?;
        Ok(vec![var.update(&name, |cur| axpby(cur, 1.0, grad, -lr))?])
    });

    // accum = momentum*accum + grad; var -= lr*accum.
    // Inputs: (var_ref, lr, grad, momentum). Slot: "<var>/Momentum".
    r.add_sync("ApplyMomentum", |ctx| {
        let (var, name) = var_of(ctx)?;
        let lr = ctx.input(1)?.scalar_value_f32()?;
        let grad = ctx.input(2)?.clone();
        let momentum = ctx.input(3)?.scalar_value_f32()?;
        let slot = ctx.container().variable(&format!("{name}/Momentum"));
        let accum = slot.update_or_init(
            || Tensor::zeros(grad.dtype(), grad.shape().clone()),
            |acc| axpby(acc, momentum, &grad, 1.0),
        )?;
        Ok(vec![var.update(&name, |cur| axpby(cur, 1.0, &accum, -lr))?])
    });

    // accum += grad^2; var -= lr * grad / sqrt(accum + eps).
    // Inputs: (var_ref, lr, grad). Slot: "<var>/Adagrad".
    r.add_sync("ApplyAdagrad", |ctx| {
        let (var, name) = var_of(ctx)?;
        let lr = ctx.input(1)?.scalar_value_f32()?;
        let grad = ctx.input(2)?.clone();
        let slot = ctx.container().variable(&format!("{name}/Adagrad"));
        let g2 = binary_elementwise(&grad, &grad, "Mul")?;
        let accum = slot.update_or_init(
            || Tensor::zeros(grad.dtype(), grad.shape().clone()),
            |acc| binary_elementwise(acc, &g2, "Add"),
        )?;
        Ok(vec![var.update(&name, |cur| {
            let cv = cur.as_f32()?;
            let gv = grad.as_f32()?;
            let av = accum.as_f32()?;
            let out: Vec<f32> = cv
                .iter()
                .zip(gv.iter().zip(av))
                .map(|(&c, (&g, &a))| c - lr * g / (a + 1e-8).sqrt())
                .collect();
            Tensor::new(cur.shape().clone(), TensorData::F32(out))
        })?])
    });

    // Adam. Inputs: (var_ref, lr, grad, beta_power_t (precomputed scale), step?)…
    // We keep the wire simple: inputs (var_ref, lr, grad, beta1, beta2);
    // slots m and v; the bias-correction step count is a slot scalar.
    r.add_sync("ApplyAdam", |ctx| {
        let (var, name) = var_of(ctx)?;
        let lr = ctx.input(1)?.scalar_value_f32()?;
        let grad = ctx.input(2)?.clone();
        let beta1 = ctx.input(3)?.scalar_value_f32()?;
        let beta2 = ctx.input(4)?.scalar_value_f32()?;
        let eps = 1e-8f32;
        let c = ctx.container();
        let m_slot = c.variable(&format!("{name}/Adam/m"));
        let v_slot = c.variable(&format!("{name}/Adam/v"));
        let t_slot = c.variable(&format!("{name}/Adam/t"));
        let t = t_slot
            .update_or_init(|| Ok(Tensor::scalar_f32(0.0)), |cur| {
                Ok(Tensor::scalar_f32(cur.scalar_value_f32()? + 1.0))
            })?
            .scalar_value_f32()?;
        let m = m_slot.update_or_init(
            || Tensor::zeros(grad.dtype(), grad.shape().clone()),
            |m| axpby(m, beta1, &grad, 1.0 - beta1),
        )?;
        let g2 = binary_elementwise(&grad, &grad, "Mul")?;
        let v = v_slot.update_or_init(
            || Tensor::zeros(grad.dtype(), grad.shape().clone()),
            |v| axpby(v, beta2, &g2, 1.0 - beta2),
        )?;
        let bc1 = 1.0 - beta1.powf(t);
        let bc2 = 1.0 - beta2.powf(t);
        Ok(vec![var.update(&name, |cur| {
            let cv = cur.as_f32()?;
            let mv = m.as_f32()?;
            let vv = v.as_f32()?;
            let out: Vec<f32> = cv
                .iter()
                .zip(mv.iter().zip(vv))
                .map(|(&c, (&mi, &vi))| {
                    let mhat = mi / bc1;
                    let vhat = vi / bc2;
                    c - lr * mhat / (vhat.sqrt() + eps)
                })
                .collect();
            Tensor::new(cur.shape().clone(), TensorData::F32(out))
        })?])
    });

    // Mutex ops (resource key = node name for Acquire; attr "mutex" names a
    // shared mutex across nodes).
    r.add("MutexAcquire", |node| {
        let key = node
            .attr_opt("mutex")
            .and_then(|a| a.as_str().ok().map(String::from))
            .unwrap_or_else(|| node.name.clone());
        Ok(super::Kernel::Async(Box::new(move |ctx: KernelContext, done: super::DoneFn| {
            let m = ctx.container().mutex(&key);
            // Acquire may block: run on a detached waiter thread rather
            // than the device pool (cheap at the rates mutex ops run).
            std::thread::spawn(move || {
                m.acquire();
                done(Ok(vec![]));
            });
        })))
    });
    r.add("MutexRelease", |node| {
        let key = node
            .attr_opt("mutex")
            .and_then(|a| a.as_str().ok().map(String::from))
            .unwrap_or_else(|| node.name.clone());
        Ok(super::Kernel::Sync(Box::new(move |ctx: &mut KernelContext| {
            ctx.container().mutex(&key).release()?;
            Ok(vec![])
        })))
    });
}

#[cfg(test)]
mod tests {
    // Kernel-level behaviour is exercised through the executor integration
    // tests (rust/tests/); the pure helpers are tested here.
    use super::*;

    #[test]
    fn axpby_math() {
        let a = Tensor::from_f32(vec![2], vec![1., 2.]).unwrap();
        let b = Tensor::from_f32(vec![2], vec![10., 20.]).unwrap();
        let r = axpby(&a, 2.0, &b, 0.5).unwrap();
        assert_eq!(r.as_f32().unwrap(), &[7., 14.]);
    }
}
