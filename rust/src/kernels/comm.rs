//! Communication kernels: `_Send`/`_Recv` pairs inserted by the §3.2.2
//! partitioner, plus `_Feed`/`_Fetch` inserted by §4.2 partial execution.
//!
//! `_Recv` is the canonical asynchronous kernel (§5.3): it registers a
//! continuation with the rendezvous and returns immediately.
//!
//! §5.5 lossy compression: when a Send node carries `compress=true` (set
//! by the partitioner for cross-worker edges), the f32 payload is
//! truncated to bf16 before the rendezvous and re-expanded (zero-filled
//! mantissa, exactly the paper's scheme) by the matching Recv.

use super::{DoneFn, Kernel, KernelContext, KernelRegistry};
use crate::compress;
use crate::error::Status;
use crate::tensor::{DType, Tensor};

/// Distributed keys carry a `%STEP%` placeholder (one registered partition
/// serves every step); substitute the live step id.
fn resolve_key(key: &str, step_id: u64) -> String {
    if key.contains("%STEP%") {
        key.replace("%STEP%", &format!("step:{step_id}"))
    } else {
        key.to_string()
    }
}

pub(super) fn register(r: &mut KernelRegistry) {
    // _Send(tensor). Attrs: key (rendezvous key), compress (bool).
    r.add("_Send", |node| {
        let key = node.attr("key")?.as_str()?.to_string();
        let compress_wire =
            node.attr_opt("compress").and_then(|a| a.as_bool().ok()).unwrap_or(false);
        Ok(Kernel::Sync(Box::new(move |ctx: &mut KernelContext| {
            let mut t = ctx.input(0)?.clone();
            if compress_wire && t.dtype() == DType::F32 {
                t = compress::f32_to_bf16(&t)?;
            }
            let key = resolve_key(&key, ctx.step.step_id);
            ctx.rendezvous.send(&key, t)?;
            Ok(vec![])
        })))
    });

    // _Recv() -> tensor. Attrs: key.
    r.add("_Recv", |node| {
        let key = node.attr("key")?.as_str()?.to_string();
        Ok(Kernel::Async(Box::new(move |ctx: KernelContext, done: DoneFn| {
            let key = resolve_key(&key, ctx.step.step_id);
            ctx.rendezvous.recv_async(
                &key,
                Box::new(move |res| {
                    done(res.and_then(|t| {
                        // Transparently decompress bf16 wire tensors.
                        let t = if t.dtype() == DType::BF16 {
                            compress::bf16_to_f32(&t)?
                        } else {
                            t
                        };
                        Ok(vec![t])
                    }))
                }),
            );
        })))
    });

    // _Feed() -> tensor: reads a pre-populated feed from the step
    // rendezvous ("specially-initialized entries in a Rendezvous object
    // used for the Run call", §4.2). When the fed endpoint declared a
    // dtype (attr `T`, copied from the producer by prune_for_run), the fed
    // tensor must match it — the §5 optimizer's dtype reasoning relies on
    // the declaration, so a mis-typed feed has to fail identically whether
    // or not the passes rewrote its consumers away.
    r.add("_Feed", |node| {
        let key = node.attr("key")?.as_str()?.to_string();
        let declared = node.attr_opt("T").and_then(|a| a.as_type().ok());
        Ok(Kernel::Sync(Box::new(move |ctx: &mut KernelContext| {
            let t = ctx
                .rendezvous
                .try_recv(&key)
                .ok_or_else(|| Status::internal(format!("feed {key:?} missing from rendezvous")))?;
            if let Some(want) = declared {
                if t.dtype() != want {
                    return Err(Status::invalid_argument(format!(
                        "feed {key:?}: fed tensor is {}, graph declares {want}",
                        t.dtype()
                    )));
                }
            }
            Ok(vec![t])
        })))
    });

    // _Fetch(tensor): stores into the step's fetch map under attr "name".
    r.add("_Fetch", |node| {
        let name = node.attr("name")?.as_str()?.to_string();
        Ok(Kernel::Sync(Box::new(move |ctx: &mut KernelContext| {
            ctx.step.put_fetch(&name, ctx.input(0)?.clone());
            Ok(vec![])
        })))
    });
}

#[allow(dead_code)]
fn _t(_: &Tensor) {}
