//! Matrix operations (Table 1 row 3): MatMul (with transpose flags),
//! BatchMatMul, MatrixInverse (Gauss–Jordan), MatrixDeterminant (LU).
//!
//! The f32 matmul is a classic panel-packed GEMM: B is repacked into
//! column panels of [`NR`] (k-major, zero-padded at the right edge), A
//! into [`MR`]-row micro-panels (k-major, gathered through either
//! transpose), and an explicit-SIMD microkernel streams both packed
//! operands linearly, accumulating an `MR × NR` register block over the
//! *entire* k extent before storing. Packing buffers come from a
//! [`ScratchSource`]: the step arena inside a planned step (so
//! steady-state steps reuse one allocation), the compute pool's side pool
//! for free-function callers.
//!
//! **Bit-identity contract.** Every output element accumulates its k
//! contributions in ascending-k order as `acc = acc + a·b` — one IEEE
//! mul, one IEEE add per step, no FMA contraction, no horizontal
//! reductions. SIMD lanes are independent output *columns* (never k), so
//! the AVX microkernel performs exactly the per-element operation
//! sequence of [`micro_scalar`], and results are byte-identical across
//! thread counts, chunkings, and the SIMD/scalar dispatch.
//! `tests/parallel.rs` asserts all of this.

use super::{KernelContext, KernelRegistry, ScratchSource};
use crate::device::ComputePool;
use crate::error::{Result, Status};
use crate::tensor::{Shape, Tensor, TensorData};

/// Resolve the (m, k, n) problem dims of `a`·`b` under transposes.
fn matmul_dims(a: &Tensor, b: &Tensor, ta: bool, tb: bool) -> Result<(usize, usize, usize)> {
    let (ar, ac) = dims2(a, "MatMul lhs")?;
    let (br, bc) = dims2(b, "MatMul rhs")?;
    let (m, k) = if ta { (ac, ar) } else { (ar, ac) };
    let (k2, n) = if tb { (bc, br) } else { (br, bc) };
    if k != k2 {
        return Err(Status::invalid_argument(format!(
            "MatMul: inner dims mismatch {k} vs {k2} (a={ar}x{ac} ta={ta}, b={br}x{bc} tb={tb})"
        )));
    }
    Ok((m, k, n))
}

/// C[m,n] = A·B with optional logical transposes. Row-major. Serial
/// convenience over [`matmul_with_pool`] (baselines and tests).
pub fn matmul(a: &Tensor, b: &Tensor, ta: bool, tb: bool) -> Result<Tensor> {
    matmul_with_pool(&ComputePool::serial(), a, b, ta, tb)
}

/// [`matmul`] running the packed GEMM on `pool` (the kernel path uses the
/// device's intra-op pool; `benches/parallel.rs` drives this directly).
/// Results are bit-identical for every pool size.
pub fn matmul_with_pool(
    pool: &ComputePool,
    a: &Tensor,
    b: &Tensor,
    ta: bool,
    tb: bool,
) -> Result<Tensor> {
    let (m, k, n) = matmul_dims(a, b, ta, tb)?;
    let mut out = vec![0f32; m * n];
    gemm_into(pool, ScratchSource::Pool(pool), a.as_f32()?, b.as_f32()?, m, k, n, ta, tb, &mut out);
    Tensor::new(Shape(vec![m, n]), TensorData::F32(out))
}

/// Microkernel row height: one register block covers `MR` C rows.
pub(crate) const MR: usize = 4;
/// Microkernel column width: one 8-lane f32 vector per C row.
pub(crate) const NR: usize = 8;

/// Is the AVX microkernel usable on this machine? Detected once.
#[cfg(target_arch = "x86_64")]
fn use_avx() -> bool {
    static AVX: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *AVX.get_or_init(|| std::arch::is_x86_feature_detected!("avx"))
}

#[cfg(not(target_arch = "x86_64"))]
fn use_avx() -> bool {
    false
}

/// Scalar microkernel: `acc[r][j] += apack[kk·MR+r] · bblock[kk·NR+j]`,
/// kk ascending. The reference operation sequence the AVX kernel must —
/// and does — reproduce exactly, per element.
fn micro_scalar(k: usize, apack: &[f32], bblock: &[f32], acc: &mut [[f32; NR]; MR]) {
    debug_assert!(apack.len() >= k * MR && bblock.len() >= k * NR);
    for kk in 0..k {
        let a = &apack[kk * MR..kk * MR + MR];
        let b = &bblock[kk * NR..kk * NR + NR];
        for r in 0..MR {
            let ar = a[r];
            for j in 0..NR {
                acc[r][j] += ar * b[j];
            }
        }
    }
}

/// AVX microkernel: 4 broadcast-multiply-adds per k step, one 8-lane
/// vector per C row. `_mm256_add_ps(_mm256_mul_ps(…))` — deliberately
/// *not* an FMA intrinsic, so each lane performs the same rounded mul
/// then rounded add as [`micro_scalar`] and the bytes match.
///
/// # Safety
/// Caller must have verified AVX support ([`use_avx`]); slices must hold
/// at least `k*MR` / `k*NR` elements.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn micro_avx(k: usize, apack: &[f32], bblock: &[f32], acc: &mut [[f32; NR]; MR]) {
    use std::arch::x86_64::*;
    debug_assert!(apack.len() >= k * MR && bblock.len() >= k * NR);
    unsafe {
        let a = apack.as_ptr();
        let b = bblock.as_ptr();
        let mut c0 = _mm256_setzero_ps();
        let mut c1 = _mm256_setzero_ps();
        let mut c2 = _mm256_setzero_ps();
        let mut c3 = _mm256_setzero_ps();
        for kk in 0..k {
            let bv = _mm256_loadu_ps(b.add(kk * NR));
            let ap = a.add(kk * MR);
            c0 = _mm256_add_ps(c0, _mm256_mul_ps(_mm256_set1_ps(*ap), bv));
            c1 = _mm256_add_ps(c1, _mm256_mul_ps(_mm256_set1_ps(*ap.add(1)), bv));
            c2 = _mm256_add_ps(c2, _mm256_mul_ps(_mm256_set1_ps(*ap.add(2)), bv));
            c3 = _mm256_add_ps(c3, _mm256_mul_ps(_mm256_set1_ps(*ap.add(3)), bv));
        }
        _mm256_storeu_ps(acc[0].as_mut_ptr(), c0);
        _mm256_storeu_ps(acc[1].as_mut_ptr(), c1);
        _mm256_storeu_ps(acc[2].as_mut_ptr(), c2);
        _mm256_storeu_ps(acc[3].as_mut_ptr(), c3);
    }
}

/// Dispatch one microkernel call: AVX when the CPU has it, the
/// bit-identical scalar loop otherwise.
#[inline]
fn micro(k: usize, apack: &[f32], bblock: &[f32], acc: &mut [[f32; NR]; MR]) {
    #[cfg(target_arch = "x86_64")]
    if use_avx() {
        // Safety: AVX presence checked at runtime; lengths asserted in
        // the kernel.
        unsafe { micro_avx(k, apack, bblock, acc) };
        return;
    }
    micro_scalar(k, apack, bblock, acc)
}

/// Pack B (under `tb`) into column panels of [`NR`]: panel `jp` is
/// `k × NR`, k-major, holding B columns `jp·NR ..` zero-padded at the
/// right edge. After packing, the microkernel's B reads are perfectly
/// sequential regardless of the source layout.
fn pack_b(bv: &[f32], k: usize, n: usize, tb: bool, bpack: &mut Vec<f32>) {
    let npanels = n.div_ceil(NR);
    bpack.clear();
    bpack.resize(npanels * k * NR, 0.0);
    for jp in 0..npanels {
        let j0 = jp * NR;
        let w = NR.min(n - j0);
        let block = &mut bpack[jp * k * NR..(jp + 1) * k * NR];
        if tb {
            // B is [n, k] logically transposed: gather column kk of each
            // of the panel's rows.
            for kk in 0..k {
                for jj in 0..w {
                    block[kk * NR + jj] = bv[(j0 + jj) * k + kk];
                }
            }
        } else {
            for kk in 0..k {
                block[kk * NR..kk * NR + w].copy_from_slice(&bv[kk * n + j0..kk * n + j0 + w]);
            }
        }
    }
}

/// Pack `h ≤ MR` rows of A starting at `i0` into `apack` (k-major,
/// [`MR`]-wide; rows `h..MR` keep whatever padding is already there —
/// their accumulator rows are never stored). Gathers through either
/// transpose, so the microkernel never strides the source.
fn pack_a(av: &[f32], m: usize, k: usize, ta: bool, i0: usize, h: usize, apack: &mut [f32]) {
    debug_assert!(apack.len() >= k * MR);
    if ta {
        // A is [k, m] logically transposed: element (i, kk) at kk·m + i.
        for kk in 0..k {
            for r in 0..h {
                apack[kk * MR + r] = av[kk * m + (i0 + r)];
            }
        }
    } else {
        for kk in 0..k {
            for r in 0..h {
                apack[kk * MR + r] = av[(i0 + r) * k + kk];
            }
        }
    }
}

/// Raw-pointer wrapper for the disjoint output writes of the packed
/// driver (each row micro-panel owns its C rows exclusively; chunk ranges
/// never overlap).
#[derive(Clone, Copy)]
struct OutPtr(*mut f32);
// Safety: only written through disjoint row panels while the caller's
// exclusive borrow of the output is alive (the drivers block until every
// chunk completes).
unsafe impl Send for OutPtr {}
unsafe impl Sync for OutPtr {}

/// Run row micro-panels `panels` against fully-packed `bpack`, storing
/// into `out` (an `m × n` row-major matrix at `outp`). The workhorse
/// shared by the parallel driver (one call per chunk) and the serial
/// batch path.
#[allow(clippy::too_many_arguments)]
fn run_panel_range(
    scratch: ScratchSource<'_>,
    av: &[f32],
    bpack: &[f32],
    m: usize,
    k: usize,
    n: usize,
    ta: bool,
    panels: std::ops::Range<usize>,
    outp: OutPtr,
) {
    let npanels = n.div_ceil(NR);
    let mut apack = scratch.take_f32(k * MR);
    apack.resize(k * MR, 0.0);
    for p in panels {
        let i0 = p * MR;
        let h = MR.min(m - i0);
        pack_a(av, m, k, ta, i0, h, &mut apack);
        for jp in 0..npanels {
            let j0 = jp * NR;
            let w = NR.min(n - j0);
            let mut acc = [[0f32; NR]; MR];
            micro(k, &apack, &bpack[jp * k * NR..(jp + 1) * k * NR], &mut acc);
            for (r, acc_row) in acc.iter().enumerate().take(h) {
                // Safety: rows i0..i0+h belong exclusively to panel p,
                // and panels are disjoint across chunks (see OutPtr).
                let dst = unsafe {
                    std::slice::from_raw_parts_mut(outp.0.add((i0 + r) * n + j0), w)
                };
                dst.copy_from_slice(&acc_row[..w]);
            }
        }
    }
    scratch.give_f32(apack);
}

/// Contiguous-B matvec chunk for m == 1, tb == false (the batch-1
/// serving shape [1,k]·[k,n]): k-outer axpy over the chunk's columns,
/// SIMD across column lanes. Per element this is `c += a[kk]·b[kk,j]`,
/// kk ascending — the scalar tail and the scalar fallback compute the
/// identical sequence, so chunking and lane grouping never change bytes.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn matvec_axpy_avx(av: &[f32], bv: &[f32], k: usize, n: usize, j0: usize, out: &mut [f32]) {
    use std::arch::x86_64::*;
    unsafe {
        let w = out.len();
        let wv = w - w % NR;
        let op = out.as_mut_ptr();
        for kk in 0..k {
            let a = *av.get_unchecked(kk);
            let avk = _mm256_set1_ps(a);
            let base = bv.as_ptr().add(kk * n + j0);
            let mut j = 0;
            while j < wv {
                let c = _mm256_loadu_ps(op.add(j));
                let b = _mm256_loadu_ps(base.add(j));
                _mm256_storeu_ps(op.add(j), _mm256_add_ps(c, _mm256_mul_ps(avk, b)));
                j += NR;
            }
            for j in wv..w {
                *op.add(j) += a * *base.add(j);
            }
        }
    }
}

fn matvec_axpy_scalar(av: &[f32], bv: &[f32], k: usize, n: usize, j0: usize, out: &mut [f32]) {
    for kk in 0..k {
        let a = av[kk];
        let brow = &bv[kk * n + j0..kk * n + j0 + out.len()];
        for (c, &b) in out.iter_mut().zip(brow) {
            *c += a * b;
        }
    }
}

/// The full GEMM dispatch into caller-provided storage (`out.len() ==
/// m*n`; the m>1 packed path overwrites every element, the m==1 paths
/// require it zeroed) — dims come pre-resolved from [`matmul_dims`] so
/// they are validated exactly once per invocation. Used by the MatMul
/// kernel (arena scratch), the free functions (pool scratch), and the
/// im2col convolution kernels in `kernels::nn`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_into(
    pool: &ComputePool,
    scratch: ScratchSource<'_>,
    av: &[f32],
    bv: &[f32],
    m: usize,
    k: usize,
    n: usize,
    ta: bool,
    tb: bool,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        out.fill(0.0);
        return;
    }
    // Matvec row case (batch-1 inference: [1,k]·[k,n]): a single output
    // row gives the panel loop nothing to split, and packing B would
    // cost as much memory traffic as the whole multiply. Distribute the
    // output *columns* instead, on B's natural layout.
    if m == 1 {
        let col_cost = 2usize.saturating_mul(k).max(1);
        if tb {
            // B is [n, k]: out[j] = dot(a, B[j, :]), both contiguous and
            // cache-friendly as-is. A k-lane SIMD reduction would change
            // the summation tree, so this path stays scalar ascending-k.
            pool.parallel_for_mut(n, col_cost, out, |cols, c| {
                for (j_rel, cj) in c.iter_mut().enumerate() {
                    let brow = &bv[(cols.start + j_rel) * k..(cols.start + j_rel + 1) * k];
                    let mut s = 0f32;
                    for kk in 0..k {
                        s += av[kk] * brow[kk];
                    }
                    *cj = s;
                }
            });
        } else {
            // B is [k, n]: SIMD axpy across column lanes, kk ascending.
            pool.parallel_for_mut(n, col_cost, out, |cols, c| {
                #[cfg(target_arch = "x86_64")]
                if use_avx() {
                    // Safety: AVX checked; `c` covers columns
                    // cols.start..cols.end of row kk at kk·n.
                    unsafe { matvec_axpy_avx(av, bv, k, n, cols.start, c) };
                    return;
                }
                matvec_axpy_scalar(av, bv, k, n, cols.start, c);
            });
        }
        return;
    }

    let npanels = n.div_ceil(NR);
    let mut bpack = scratch.take_f32(npanels * k * NR);
    pack_b(bv, k, n, tb, &mut bpack);
    let bpack_ref: &[f32] = &bpack;

    let mpanels = m.div_ceil(MR);
    // One row micro-panel costs ~2·k·n·MR flops; this drives chunking +
    // the small-matrix inline path.
    let panel_cost = 2usize.saturating_mul(k).saturating_mul(n).saturating_mul(MR).max(1);
    let outp = OutPtr(out.as_mut_ptr());
    pool.parallel_for(mpanels, panel_cost, |panels| {
        run_panel_range(scratch, av, bpack_ref, m, k, n, ta, panels, outp);
    });
    scratch.give_f32(bpack);
}

/// Batched matmul over leading dim: [b,m,k] x [b,k,n] -> [b,m,n].
/// Serial convenience over [`batch_matmul_with_pool`].
pub fn batch_matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    batch_matmul_with_pool(&ComputePool::serial(), a, b)
}

/// [`batch_matmul`] distributing the batch entries over `pool` (each
/// batch element is an independent packed multiply writing a disjoint
/// `m×n` slab, so chunking cannot change any result bit). Within a
/// chunk, each element runs the serial packed path — pack B, stream the
/// row micro-panels — reusing one pair of scratch buffers per chunk.
pub fn batch_matmul_with_pool(pool: &ComputePool, a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let ad = a.shape().dims();
    let bd = b.shape().dims();
    if ad.len() != 3 || bd.len() != 3 || ad[0] != bd[0] || ad[2] != bd[1] {
        return Err(Status::invalid_argument(format!(
            "BatchMatMul: incompatible shapes {} x {}",
            a.shape(),
            b.shape()
        )));
    }
    let (bs, m, k, n) = (ad[0], ad[1], ad[2], bd[2]);
    let av = a.as_f32()?;
    let bv = b.as_f32()?;
    let mut out = vec![0f32; bs * m * n];
    let scratch = ScratchSource::Pool(pool);
    let npanels = n.div_ceil(NR);
    let mpanels = m.div_ceil(MR);
    let batch_cost = 2usize.saturating_mul(m).saturating_mul(k).saturating_mul(n).max(1);
    pool.parallel_for_mut(bs, batch_cost, &mut out, |batches, c| {
        if m == 0 || n == 0 {
            return;
        }
        let mut bpack = scratch.take_f32(npanels * k * NR);
        let b0 = batches.start;
        for bi in batches.clone() {
            let ael = &av[bi * m * k..(bi + 1) * m * k];
            let bel = &bv[bi * k * n..(bi + 1) * k * n];
            let cel = &mut c[(bi - b0) * m * n..(bi - b0 + 1) * m * n];
            if k == 0 {
                cel.fill(0.0);
                continue;
            }
            pack_b(bel, k, n, false, &mut bpack);
            run_panel_range(
                scratch,
                ael,
                &bpack,
                m,
                k,
                n,
                false,
                0..mpanels,
                OutPtr(cel.as_mut_ptr()),
            );
        }
        scratch.give_f32(bpack);
    });
    Tensor::new(Shape(vec![bs, m, n]), TensorData::F32(out))
}

/// Gauss–Jordan inverse with partial pivoting.
pub fn matrix_inverse(x: &Tensor) -> Result<Tensor> {
    let (n, n2) = dims2(x, "MatrixInverse")?;
    if n != n2 {
        return Err(Status::invalid_argument("MatrixInverse: matrix must be square"));
    }
    let v = x.as_f32()?;
    let mut a: Vec<f64> = v.iter().map(|&x| x as f64).collect();
    let mut inv: Vec<f64> = vec![0.0; n * n];
    for i in 0..n {
        inv[i * n + i] = 1.0;
    }
    for col in 0..n {
        // pivot
        let mut piv = col;
        for row in col + 1..n {
            if a[row * n + col].abs() > a[piv * n + col].abs() {
                piv = row;
            }
        }
        if a[piv * n + col].abs() < 1e-12 {
            return Err(Status::invalid_argument("MatrixInverse: singular matrix"));
        }
        if piv != col {
            for j in 0..n {
                a.swap(col * n + j, piv * n + j);
                inv.swap(col * n + j, piv * n + j);
            }
        }
        let d = a[col * n + col];
        for j in 0..n {
            a[col * n + j] /= d;
            inv[col * n + j] /= d;
        }
        for row in 0..n {
            if row == col {
                continue;
            }
            let f = a[row * n + col];
            if f == 0.0 {
                continue;
            }
            for j in 0..n {
                a[row * n + j] -= f * a[col * n + j];
                inv[row * n + j] -= f * inv[col * n + j];
            }
        }
    }
    Tensor::new(Shape(vec![n, n]), TensorData::F32(inv.into_iter().map(|x| x as f32).collect()))
}

/// Determinant via LU with partial pivoting.
pub fn matrix_determinant(x: &Tensor) -> Result<Tensor> {
    let (n, n2) = dims2(x, "MatrixDeterminant")?;
    if n != n2 {
        return Err(Status::invalid_argument("MatrixDeterminant: matrix must be square"));
    }
    let v = x.as_f32()?;
    let mut a: Vec<f64> = v.iter().map(|&x| x as f64).collect();
    let mut det = 1.0f64;
    for col in 0..n {
        let mut piv = col;
        for row in col + 1..n {
            if a[row * n + col].abs() > a[piv * n + col].abs() {
                piv = row;
            }
        }
        if a[piv * n + col].abs() < 1e-14 {
            return Ok(Tensor::scalar_f32(0.0));
        }
        if piv != col {
            for j in 0..n {
                a.swap(col * n + j, piv * n + j);
            }
            det = -det;
        }
        det *= a[col * n + col];
        for row in col + 1..n {
            let f = a[row * n + col] / a[col * n + col];
            for j in col..n {
                a[row * n + j] -= f * a[col * n + j];
            }
        }
    }
    Ok(Tensor::scalar_f32(det as f32))
}

fn dims2(t: &Tensor, what: &str) -> Result<(usize, usize)> {
    let d = t.shape().dims();
    if d.len() != 2 {
        return Err(Status::invalid_argument(format!("{what}: expected rank 2, got {}", t.shape())));
    }
    Ok((d[0], d[1]))
}

pub(super) fn register(r: &mut KernelRegistry) {
    r.add_sync("MatMul", |ctx: &mut KernelContext| {
        let ta = ctx.node.attr_opt("transpose_a").and_then(|a| a.as_bool().ok()).unwrap_or(false);
        let tb = ctx.node.attr_opt("transpose_b").and_then(|a| a.as_bool().ok()).unwrap_or(false);
        // Memory-planned output and packing scratch: the result lands in
        // the port's arena slot, packing panels in the arena's scratch
        // pool, row micro-panels distributed over the intra-op pool.
        let (m, k, n) = matmul_dims(ctx.input(0)?, ctx.input(1)?, ta, tb)?;
        let mut out = ctx.alloc_f32_zeroed(0, m * n);
        gemm_into(
            &ctx.device.compute,
            ctx.scratch(),
            ctx.input(0)?.as_f32()?,
            ctx.input(1)?.as_f32()?,
            m,
            k,
            n,
            ta,
            tb,
            &mut out,
        );
        Ok(vec![ctx.make_output(0, Shape(vec![m, n]), TensorData::F32(out))?])
    });
    r.add_sync("BatchMatMul", |ctx| {
        Ok(vec![batch_matmul_with_pool(&ctx.device.compute, ctx.input(0)?, ctx.input(1)?)?])
    });
    r.add_sync("MatrixInverse", |ctx| Ok(vec![matrix_inverse(ctx.input(0)?)?]));
    r.add_sync("MatrixDeterminant", |ctx| Ok(vec![matrix_determinant(ctx.input(0)?)?]));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: Vec<usize>, v: Vec<f32>) -> Tensor {
        Tensor::from_f32(shape, v).unwrap()
    }

    #[test]
    fn matmul_2x2() {
        let a = t(vec![2, 2], vec![1., 2., 3., 4.]);
        let b = t(vec![2, 2], vec![5., 6., 7., 8.]);
        let c = matmul(&a, &b, false, false).unwrap();
        assert_eq!(c.as_f32().unwrap(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn matmul_rect() {
        let a = t(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = t(vec![3, 1], vec![1., 1., 1.]);
        let c = matmul(&a, &b, false, false).unwrap();
        assert_eq!(c.shape().dims(), &[2, 1]);
        assert_eq!(c.as_f32().unwrap(), &[6., 15.]);
    }

    #[test]
    fn matmul_transposes_agree() {
        // Compare every transpose flag combo against explicit transposition.
        let a = t(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = t(vec![3, 4], (0..12).map(|i| i as f32).collect());
        let base = matmul(&a, &b, false, false).unwrap();
        let at = crate::kernels::array::transpose(&a, &[1, 0]).unwrap();
        let bt = crate::kernels::array::transpose(&b, &[1, 0]).unwrap();
        assert!(matmul(&at, &b, true, false).unwrap().allclose(&base, 1e-6, 1e-6));
        assert!(matmul(&a, &bt, false, true).unwrap().allclose(&base, 1e-6, 1e-6));
        assert!(matmul(&at, &bt, true, true).unwrap().allclose(&base, 1e-6, 1e-6));
    }

    #[test]
    fn matmul_shape_mismatch() {
        let a = t(vec![2, 3], vec![0.; 6]);
        let b = t(vec![2, 2], vec![0.; 4]);
        assert!(matmul(&a, &b, false, false).is_err());
    }

    #[test]
    fn batch_matmul_basic() {
        let a = t(vec![2, 1, 2], vec![1., 2., 3., 4.]);
        let b = t(vec![2, 2, 1], vec![1., 1., 2., 2.]);
        let c = batch_matmul(&a, &b).unwrap();
        assert_eq!(c.shape().dims(), &[2, 1, 1]);
        assert_eq!(c.as_f32().unwrap(), &[3., 14.]);
    }

    #[test]
    fn inverse_roundtrip() {
        let a = t(vec![2, 2], vec![4., 7., 2., 6.]);
        let inv = matrix_inverse(&a).unwrap();
        let prod = matmul(&a, &inv, false, false).unwrap();
        let eye = t(vec![2, 2], vec![1., 0., 0., 1.]);
        assert!(prod.allclose(&eye, 1e-4, 1e-4));
    }

    #[test]
    fn inverse_singular_rejected() {
        let a = t(vec![2, 2], vec![1., 2., 2., 4.]);
        assert!(matrix_inverse(&a).is_err());
    }

    #[test]
    fn determinant_values() {
        let a = t(vec![2, 2], vec![4., 7., 2., 6.]);
        let d = matrix_determinant(&a).unwrap().scalar_value_f32().unwrap();
        assert!((d - 10.0).abs() < 1e-4);
        let sing = t(vec![2, 2], vec![1., 2., 2., 4.]);
        assert_eq!(matrix_determinant(&sing).unwrap().scalar_value_f32().unwrap(), 0.0);
        // 3x3 with known det = -306
        let m = t(vec![3, 3], vec![6., 1., 1., 4., -2., 5., 2., 8., 7.]);
        let d3 = matrix_determinant(&m).unwrap().scalar_value_f32().unwrap();
        assert!((d3 + 306.0).abs() < 1e-2, "{d3}");
    }

    fn fill(r: usize, c: usize, seed: u32) -> Tensor {
        let v: Vec<f32> = (0..r * c)
            .map(|i| ((i as u32).wrapping_mul(2654435761).wrapping_add(seed) % 1000) as f32 * 0.013 - 6.5)
            .collect();
        t(vec![r, c], v)
    }

    #[test]
    fn packed_matches_naive_reference_exactly() {
        // The packed microkernel accumulates `acc += a·b` with kk
        // ascending per element — the *same* operation sequence as this
        // naive triple loop, so equality is exact (bytes), not approx.
        for (m, k, n) in [(37, 65, 29), (4, 8, 8), (5, 1, 9), (1, 33, 70)] {
            for (ta, tb) in [(false, false), (false, true), (true, false), (true, true)] {
                let a = if ta { fill(k, m, 1) } else { fill(m, k, 1) };
                let b = if tb { fill(n, k, 2) } else { fill(k, n, 2) };
                let av = a.as_f32().unwrap();
                let bv = b.as_f32().unwrap();
                let mut want = vec![0f32; m * n];
                for i in 0..m {
                    for j in 0..n {
                        let mut s = 0f32;
                        for kk in 0..k {
                            let ax = if ta { av[kk * m + i] } else { av[i * k + kk] };
                            let bx = if tb { bv[j * k + kk] } else { bv[kk * n + j] };
                            s += ax * bx;
                        }
                        want[i * n + j] = s;
                    }
                }
                let got = matmul(&a, &b, ta, tb).unwrap();
                assert_eq!(
                    got.as_f32().unwrap(),
                    &want[..],
                    "m={m} k={k} n={n} ta={ta} tb={tb}"
                );
            }
        }
    }

    #[test]
    fn matmul_bit_identical_across_pool_sizes() {
        // Odd, non-tile-multiple dims; every transpose combo; pools of
        // 1/2/4/8 must agree bit for bit (the determinism contract).
        // (m=1, …) exercises the matvec column-split path.
        for (m, k, n) in [(67, 131, 45), (1, 131, 4096)] {
            for (ta, tb) in [(false, false), (false, true), (true, false), (true, true)] {
                let a = if ta { fill(k, m, 1) } else { fill(m, k, 1) };
                let b = if tb { fill(n, k, 2) } else { fill(k, n, 2) };
                let base = matmul_with_pool(&ComputePool::serial(), &a, &b, ta, tb).unwrap();
                for threads in [2, 4, 8] {
                    let pool = ComputePool::new(threads, "test-mm");
                    let got = matmul_with_pool(&pool, &a, &b, ta, tb).unwrap();
                    assert_eq!(
                        got.as_f32().unwrap(),
                        base.as_f32().unwrap(),
                        "m={m} ta={ta} tb={tb} threads={threads}"
                    );
                }
            }
        }
    }

    #[test]
    fn batch_matmul_bit_identical_across_pool_sizes() {
        let a = t(vec![5, 17, 23], (0..5 * 17 * 23).map(|i| (i % 97) as f32 * 0.07 - 3.0).collect());
        let b = t(vec![5, 23, 11], (0..5 * 23 * 11).map(|i| (i % 89) as f32 * 0.05 - 2.0).collect());
        let base = batch_matmul(&a, &b).unwrap();
        let pool = ComputePool::new(4, "test-bmm");
        let got = batch_matmul_with_pool(&pool, &a, &b).unwrap();
        assert_eq!(got.as_f32().unwrap(), base.as_f32().unwrap());
    }

    #[test]
    fn matmul_identity() {
        let a = t(vec![3, 3], (0..9).map(|i| i as f32).collect());
        let eye = t(vec![3, 3], vec![1., 0., 0., 0., 1., 0., 0., 0., 1.]);
        let c = matmul(&a, &eye, false, false).unwrap();
        assert_eq!(c.as_f32().unwrap(), a.as_f32().unwrap());
    }
}
