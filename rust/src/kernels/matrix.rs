//! Matrix operations (Table 1 row 3): MatMul (with transpose flags),
//! BatchMatMul, MatrixInverse (Gauss–Jordan), MatrixDeterminant (LU).
//!
//! The f32 matmul is the L3 fallback path; the *fast* path for model math
//! is the `XlaCall` op running AOT-compiled XLA (§5.4 "optimized libraries
//! for kernel implementations"). This kernel is still tuned (blocked
//! k-loop, transpose-aware layouts) because baselines and small graphs use
//! it heavily.

use super::{KernelContext, KernelRegistry};
use crate::error::{Result, Status};
use crate::tensor::{Shape, Tensor, TensorData};

/// Resolve the (m, k, n) problem dims of `a`·`b` under transposes.
fn matmul_dims(a: &Tensor, b: &Tensor, ta: bool, tb: bool) -> Result<(usize, usize, usize)> {
    let (ar, ac) = dims2(a, "MatMul lhs")?;
    let (br, bc) = dims2(b, "MatMul rhs")?;
    let (m, k) = if ta { (ac, ar) } else { (ar, ac) };
    let (k2, n) = if tb { (bc, br) } else { (br, bc) };
    if k != k2 {
        return Err(Status::invalid_argument(format!(
            "MatMul: inner dims mismatch {k} vs {k2} (a={ar}x{ac} ta={ta}, b={br}x{bc} tb={tb})"
        )));
    }
    Ok((m, k, n))
}

/// C[m,n] = A·B with optional logical transposes. Row-major.
pub fn matmul(a: &Tensor, b: &Tensor, ta: bool, tb: bool) -> Result<Tensor> {
    let (m, k, n) = matmul_dims(a, b, ta, tb)?;
    let mut out = vec![0f32; m * n];
    matmul_impl(a.as_f32()?, b.as_f32()?, m, k, n, ta, tb, &mut out);
    Tensor::new(Shape(vec![m, n]), TensorData::F32(out))
}

/// The four-layout multiply into caller-provided storage
/// (`out.len() == m*n`, zeroed) — dims come pre-resolved from
/// [`matmul_dims`] so they are validated exactly once per invocation.
#[allow(clippy::too_many_arguments)]
fn matmul_impl(av: &[f32], bv: &[f32], m: usize, k: usize, n: usize, ta: bool, tb: bool, out: &mut [f32]) {
    debug_assert_eq!(out.len(), m * n);
    match (ta, tb) {
        (false, false) => {
            // ikj loop: streams B rows, vectorizes the inner j loop.
            for i in 0..m {
                for kk in 0..k {
                    let aik = av[i * k + kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &bv[kk * n..(kk + 1) * n];
                    let crow = &mut out[i * n..(i + 1) * n];
                    for j in 0..n {
                        crow[j] += aik * brow[j];
                    }
                }
            }
        }
        (false, true) => {
            // B is [n, k] logically transposed: dot products over contiguous rows.
            for i in 0..m {
                let arow = &av[i * k..(i + 1) * k];
                for j in 0..n {
                    let brow = &bv[j * k..(j + 1) * k];
                    let mut s = 0f32;
                    for kk in 0..k {
                        s += arow[kk] * brow[kk];
                    }
                    out[i * n + j] = s;
                }
            }
        }
        (true, false) => {
            // A is [k, m] logically transposed.
            for kk in 0..k {
                let arow = &av[kk * m..(kk + 1) * m];
                let brow = &bv[kk * n..(kk + 1) * n];
                for i in 0..m {
                    let aik = arow[i];
                    if aik == 0.0 {
                        continue;
                    }
                    let crow = &mut out[i * n..(i + 1) * n];
                    for j in 0..n {
                        crow[j] += aik * brow[j];
                    }
                }
            }
        }
        (true, true) => {
            for i in 0..m {
                for j in 0..n {
                    let mut s = 0f32;
                    for kk in 0..k {
                        s += av[kk * m + i] * bv[j * k + kk];
                    }
                    out[i * n + j] = s;
                }
            }
        }
    }
}

/// Batched matmul over leading dim: [b,m,k] x [b,k,n] -> [b,m,n].
pub fn batch_matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let ad = a.shape().dims();
    let bd = b.shape().dims();
    if ad.len() != 3 || bd.len() != 3 || ad[0] != bd[0] || ad[2] != bd[1] {
        return Err(Status::invalid_argument(format!(
            "BatchMatMul: incompatible shapes {} x {}",
            a.shape(),
            b.shape()
        )));
    }
    let (bs, m, k, n) = (ad[0], ad[1], ad[2], bd[2]);
    let av = a.as_f32()?;
    let bv = b.as_f32()?;
    let mut out = vec![0f32; bs * m * n];
    for bi in 0..bs {
        let ao = bi * m * k;
        let bo = bi * k * n;
        let co = bi * m * n;
        for i in 0..m {
            for kk in 0..k {
                let aik = av[ao + i * k + kk];
                if aik == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out[co + i * n + j] += aik * bv[bo + kk * n + j];
                }
            }
        }
    }
    Tensor::new(Shape(vec![bs, m, n]), TensorData::F32(out))
}

/// Gauss–Jordan inverse with partial pivoting.
pub fn matrix_inverse(x: &Tensor) -> Result<Tensor> {
    let (n, n2) = dims2(x, "MatrixInverse")?;
    if n != n2 {
        return Err(Status::invalid_argument("MatrixInverse: matrix must be square"));
    }
    let v = x.as_f32()?;
    let mut a: Vec<f64> = v.iter().map(|&x| x as f64).collect();
    let mut inv: Vec<f64> = vec![0.0; n * n];
    for i in 0..n {
        inv[i * n + i] = 1.0;
    }
    for col in 0..n {
        // pivot
        let mut piv = col;
        for row in col + 1..n {
            if a[row * n + col].abs() > a[piv * n + col].abs() {
                piv = row;
            }
        }
        if a[piv * n + col].abs() < 1e-12 {
            return Err(Status::invalid_argument("MatrixInverse: singular matrix"));
        }
        if piv != col {
            for j in 0..n {
                a.swap(col * n + j, piv * n + j);
                inv.swap(col * n + j, piv * n + j);
            }
        }
        let d = a[col * n + col];
        for j in 0..n {
            a[col * n + j] /= d;
            inv[col * n + j] /= d;
        }
        for row in 0..n {
            if row == col {
                continue;
            }
            let f = a[row * n + col];
            if f == 0.0 {
                continue;
            }
            for j in 0..n {
                a[row * n + j] -= f * a[col * n + j];
                inv[row * n + j] -= f * inv[col * n + j];
            }
        }
    }
    Tensor::new(Shape(vec![n, n]), TensorData::F32(inv.into_iter().map(|x| x as f32).collect()))
}

/// Determinant via LU with partial pivoting.
pub fn matrix_determinant(x: &Tensor) -> Result<Tensor> {
    let (n, n2) = dims2(x, "MatrixDeterminant")?;
    if n != n2 {
        return Err(Status::invalid_argument("MatrixDeterminant: matrix must be square"));
    }
    let v = x.as_f32()?;
    let mut a: Vec<f64> = v.iter().map(|&x| x as f64).collect();
    let mut det = 1.0f64;
    for col in 0..n {
        let mut piv = col;
        for row in col + 1..n {
            if a[row * n + col].abs() > a[piv * n + col].abs() {
                piv = row;
            }
        }
        if a[piv * n + col].abs() < 1e-14 {
            return Ok(Tensor::scalar_f32(0.0));
        }
        if piv != col {
            for j in 0..n {
                a.swap(col * n + j, piv * n + j);
            }
            det = -det;
        }
        det *= a[col * n + col];
        for row in col + 1..n {
            let f = a[row * n + col] / a[col * n + col];
            for j in col..n {
                a[row * n + j] -= f * a[col * n + j];
            }
        }
    }
    Ok(Tensor::scalar_f32(det as f32))
}

fn dims2(t: &Tensor, what: &str) -> Result<(usize, usize)> {
    let d = t.shape().dims();
    if d.len() != 2 {
        return Err(Status::invalid_argument(format!("{what}: expected rank 2, got {}", t.shape())));
    }
    Ok((d[0], d[1]))
}

pub(super) fn register(r: &mut KernelRegistry) {
    r.add_sync("MatMul", |ctx: &mut KernelContext| {
        let ta = ctx.node.attr_opt("transpose_a").and_then(|a| a.as_bool().ok()).unwrap_or(false);
        let tb = ctx.node.attr_opt("transpose_b").and_then(|a| a.as_bool().ok()).unwrap_or(false);
        // Memory-planned: accumulate into the port's arena slot.
        let (m, k, n) = matmul_dims(ctx.input(0)?, ctx.input(1)?, ta, tb)?;
        let mut out = ctx.alloc_f32_zeroed(0, m * n);
        matmul_impl(ctx.input(0)?.as_f32()?, ctx.input(1)?.as_f32()?, m, k, n, ta, tb, &mut out);
        Ok(vec![ctx.make_output(0, Shape(vec![m, n]), TensorData::F32(out))?])
    });
    r.add_sync("BatchMatMul", |ctx| {
        Ok(vec![batch_matmul(ctx.input(0)?, ctx.input(1)?)?])
    });
    r.add_sync("MatrixInverse", |ctx| Ok(vec![matrix_inverse(ctx.input(0)?)?]));
    r.add_sync("MatrixDeterminant", |ctx| Ok(vec![matrix_determinant(ctx.input(0)?)?]));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: Vec<usize>, v: Vec<f32>) -> Tensor {
        Tensor::from_f32(shape, v).unwrap()
    }

    #[test]
    fn matmul_2x2() {
        let a = t(vec![2, 2], vec![1., 2., 3., 4.]);
        let b = t(vec![2, 2], vec![5., 6., 7., 8.]);
        let c = matmul(&a, &b, false, false).unwrap();
        assert_eq!(c.as_f32().unwrap(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn matmul_rect() {
        let a = t(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = t(vec![3, 1], vec![1., 1., 1.]);
        let c = matmul(&a, &b, false, false).unwrap();
        assert_eq!(c.shape().dims(), &[2, 1]);
        assert_eq!(c.as_f32().unwrap(), &[6., 15.]);
    }

    #[test]
    fn matmul_transposes_agree() {
        // Compare every transpose flag combo against explicit transposition.
        let a = t(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = t(vec![3, 4], (0..12).map(|i| i as f32).collect());
        let base = matmul(&a, &b, false, false).unwrap();
        let at = crate::kernels::array::transpose(&a, &[1, 0]).unwrap();
        let bt = crate::kernels::array::transpose(&b, &[1, 0]).unwrap();
        assert!(matmul(&at, &b, true, false).unwrap().allclose(&base, 1e-6, 1e-6));
        assert!(matmul(&a, &bt, false, true).unwrap().allclose(&base, 1e-6, 1e-6));
        assert!(matmul(&at, &bt, true, true).unwrap().allclose(&base, 1e-6, 1e-6));
    }

    #[test]
    fn matmul_shape_mismatch() {
        let a = t(vec![2, 3], vec![0.; 6]);
        let b = t(vec![2, 2], vec![0.; 4]);
        assert!(matmul(&a, &b, false, false).is_err());
    }

    #[test]
    fn batch_matmul_basic() {
        let a = t(vec![2, 1, 2], vec![1., 2., 3., 4.]);
        let b = t(vec![2, 2, 1], vec![1., 1., 2., 2.]);
        let c = batch_matmul(&a, &b).unwrap();
        assert_eq!(c.shape().dims(), &[2, 1, 1]);
        assert_eq!(c.as_f32().unwrap(), &[3., 14.]);
    }

    #[test]
    fn inverse_roundtrip() {
        let a = t(vec![2, 2], vec![4., 7., 2., 6.]);
        let inv = matrix_inverse(&a).unwrap();
        let prod = matmul(&a, &inv, false, false).unwrap();
        let eye = t(vec![2, 2], vec![1., 0., 0., 1.]);
        assert!(prod.allclose(&eye, 1e-4, 1e-4));
    }

    #[test]
    fn inverse_singular_rejected() {
        let a = t(vec![2, 2], vec![1., 2., 2., 4.]);
        assert!(matrix_inverse(&a).is_err());
    }

    #[test]
    fn determinant_values() {
        let a = t(vec![2, 2], vec![4., 7., 2., 6.]);
        let d = matrix_determinant(&a).unwrap().scalar_value_f32().unwrap();
        assert!((d - 10.0).abs() < 1e-4);
        let sing = t(vec![2, 2], vec![1., 2., 2., 4.]);
        assert_eq!(matrix_determinant(&sing).unwrap().scalar_value_f32().unwrap(), 0.0);
        // 3x3 with known det = -306
        let m = t(vec![3, 3], vec![6., 1., 1., 4., -2., 5., 2., 8., 7.]);
        let d3 = matrix_determinant(&m).unwrap().scalar_value_f32().unwrap();
        assert!((d3 + 306.0).abs() < 1e-2, "{d3}");
    }

    #[test]
    fn matmul_identity() {
        let a = t(vec![3, 3], (0..9).map(|i| i as f32).collect());
        let eye = t(vec![3, 3], vec![1., 0., 0., 0., 1., 0., 0., 0., 1.]);
        let c = matmul(&a, &eye, false, false).unwrap();
        assert_eq!(c.as_f32().unwrap(), a.as_f32().unwrap());
    }
}
