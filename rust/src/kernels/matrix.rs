//! Matrix operations (Table 1 row 3): MatMul (with transpose flags),
//! BatchMatMul, MatrixInverse (Gauss–Jordan), MatrixDeterminant (LU).
//!
//! The f32 matmul is the L3 fallback path; the *fast* path for model math
//! is the `XlaCall` op running AOT-compiled XLA (§5.4 "optimized libraries
//! for kernel implementations"). This kernel is still tuned (blocked
//! k-loop, transpose-aware layouts) because baselines and small graphs use
//! it heavily.

use super::{KernelContext, KernelRegistry};
use crate::device::ComputePool;
use crate::error::{Result, Status};
use crate::tensor::{Shape, Tensor, TensorData};

/// Resolve the (m, k, n) problem dims of `a`·`b` under transposes.
fn matmul_dims(a: &Tensor, b: &Tensor, ta: bool, tb: bool) -> Result<(usize, usize, usize)> {
    let (ar, ac) = dims2(a, "MatMul lhs")?;
    let (br, bc) = dims2(b, "MatMul rhs")?;
    let (m, k) = if ta { (ac, ar) } else { (ar, ac) };
    let (k2, n) = if tb { (bc, br) } else { (br, bc) };
    if k != k2 {
        return Err(Status::invalid_argument(format!(
            "MatMul: inner dims mismatch {k} vs {k2} (a={ar}x{ac} ta={ta}, b={br}x{bc} tb={tb})"
        )));
    }
    Ok((m, k, n))
}

/// C[m,n] = A·B with optional logical transposes. Row-major. Serial
/// convenience over [`matmul_with_pool`] (baselines and tests).
pub fn matmul(a: &Tensor, b: &Tensor, ta: bool, tb: bool) -> Result<Tensor> {
    matmul_with_pool(&ComputePool::serial(), a, b, ta, tb)
}

/// [`matmul`] running its row-panel loop on `pool` (the kernel path uses
/// the device's intra-op pool; `benches/parallel.rs` drives this
/// directly). Results are bit-identical for every pool size.
pub fn matmul_with_pool(
    pool: &ComputePool,
    a: &Tensor,
    b: &Tensor,
    ta: bool,
    tb: bool,
) -> Result<Tensor> {
    let (m, k, n) = matmul_dims(a, b, ta, tb)?;
    let mut out = vec![0f32; m * n];
    matmul_impl(pool, a.as_f32()?, b.as_f32()?, m, k, n, ta, tb, &mut out);
    Tensor::new(Shape(vec![m, n]), TensorData::F32(out))
}

/// k-dimension tile: one B panel of `KC × n_tile` f32s stays hot in L2
/// while a chunk's rows stream over it.
const KC: usize = 128;
/// j-dimension tile for the (ff)/(tf) axpy forms: bounds the C/B row
/// segments the inner loop touches so they fit L1.
const NC: usize = 512;

/// The four-layout multiply into caller-provided storage
/// (`out.len() == m*n`, zeroed) — dims come pre-resolved from
/// [`matmul_dims`] so they are validated exactly once per invocation.
///
/// Cache-blocked and intra-op parallel: the outer loop over C's row
/// panels runs on `pool.parallel_for_mut` (disjoint `&mut` row views),
/// with k (and where it pays, j) tiled inside each panel. Every C[i,j]
/// accumulates its k-contributions in ascending-k order no matter how
/// rows are chunked, so results are bit-identical across thread counts.
#[allow(clippy::too_many_arguments)]
fn matmul_impl(
    pool: &ComputePool,
    av: &[f32],
    bv: &[f32],
    m: usize,
    k: usize,
    n: usize,
    ta: bool,
    tb: bool,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), m * n);
    // Matvec row case (batch-1 inference: [1,k]·[k,n]): a single output
    // row gives the row-panel loop nothing to split, so distribute the
    // output *columns* instead. With m == 1, A is k contiguous values
    // whichever way it is transposed, and B reads collapse to two
    // layouts.
    if m == 1 {
        let col_cost = 2usize.saturating_mul(k).max(1);
        if tb {
            // B is [n, k]: out[j] = dot(a, B[j, :]), both contiguous.
            pool.parallel_for_mut(n, col_cost, out, |cols, c| {
                for (j_rel, cj) in c.iter_mut().enumerate() {
                    let brow = &bv[(cols.start + j_rel) * k..(cols.start + j_rel + 1) * k];
                    let mut s = 0f32;
                    for kk in 0..k {
                        s += av[kk] * brow[kk];
                    }
                    *cj = s;
                }
            });
        } else {
            // B is [k, n]: out[j] += a[kk]·B[kk, j], k ascending per
            // column chunk — bit-identical at any chunking.
            pool.parallel_for_mut(n, col_cost, out, |cols, c| {
                for kk in 0..k {
                    let aik = av[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &bv[kk * n + cols.start..kk * n + cols.end];
                    for (cj, &bj) in c.iter_mut().zip(brow) {
                        *cj += aik * bj;
                    }
                }
            });
        }
        return;
    }
    // One output row costs ~2kn flops; this drives chunking + the
    // small-matrix inline path.
    let row_cost = 2usize.saturating_mul(k).saturating_mul(n).max(1);
    match (ta, tb) {
        (false, false) => {
            // Blocked ikj: for each k-tile, stream the panel's rows over
            // the resident B tile, vectorizing the inner j loop.
            pool.parallel_for_mut(m, row_cost, out, |rows, c| {
                let r0 = rows.start;
                for kb in (0..k).step_by(KC) {
                    let kend = (kb + KC).min(k);
                    for jb in (0..n).step_by(NC) {
                        let jend = (jb + NC).min(n);
                        for i in rows.clone() {
                            let crow = &mut c[(i - r0) * n + jb..(i - r0) * n + jend];
                            for kk in kb..kend {
                                let aik = av[i * k + kk];
                                if aik == 0.0 {
                                    continue;
                                }
                                let brow = &bv[kk * n + jb..kk * n + jend];
                                for (cj, &bj) in crow.iter_mut().zip(brow) {
                                    *cj += aik * bj;
                                }
                            }
                        }
                    }
                }
            });
        }
        (false, true) => {
            // B is [n, k] logically transposed: dot products over
            // contiguous rows — already cache-friendly, so only the row
            // panels are distributed.
            pool.parallel_for_mut(m, row_cost, out, |rows, c| {
                let r0 = rows.start;
                for i in rows.clone() {
                    let arow = &av[i * k..(i + 1) * k];
                    let crow = &mut c[(i - r0) * n..(i - r0 + 1) * n];
                    for (j, cj) in crow.iter_mut().enumerate() {
                        let brow = &bv[j * k..(j + 1) * k];
                        let mut s = 0f32;
                        for kk in 0..k {
                            s += arow[kk] * brow[kk];
                        }
                        *cj = s;
                    }
                }
            });
        }
        (true, false) => {
            // A is [k, m] logically transposed: k-tiled axpy over the
            // panel's rows (A is read a row per kk, B a row per kk).
            pool.parallel_for_mut(m, row_cost, out, |rows, c| {
                let r0 = rows.start;
                for kb in (0..k).step_by(KC) {
                    let kend = (kb + KC).min(k);
                    for jb in (0..n).step_by(NC) {
                        let jend = (jb + NC).min(n);
                        for i in rows.clone() {
                            let crow = &mut c[(i - r0) * n + jb..(i - r0) * n + jend];
                            for kk in kb..kend {
                                let aik = av[kk * m + i];
                                if aik == 0.0 {
                                    continue;
                                }
                                let brow = &bv[kk * n + jb..kk * n + jend];
                                for (cj, &bj) in crow.iter_mut().zip(brow) {
                                    *cj += aik * bj;
                                }
                            }
                        }
                    }
                }
            });
        }
        (true, true) => {
            pool.parallel_for_mut(m, row_cost, out, |rows, c| {
                let r0 = rows.start;
                for i in rows.clone() {
                    for j in 0..n {
                        let mut s = 0f32;
                        for kk in 0..k {
                            s += av[kk * m + i] * bv[j * k + kk];
                        }
                        c[(i - r0) * n + j] = s;
                    }
                }
            });
        }
    }
}

/// Batched matmul over leading dim: [b,m,k] x [b,k,n] -> [b,m,n].
/// Serial convenience over [`batch_matmul_with_pool`].
pub fn batch_matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    batch_matmul_with_pool(&ComputePool::serial(), a, b)
}

/// [`batch_matmul`] distributing the batch entries over `pool` (each
/// batch element is an independent multiply writing a disjoint `m×n`
/// slab, so chunking cannot change any result bit).
pub fn batch_matmul_with_pool(pool: &ComputePool, a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let ad = a.shape().dims();
    let bd = b.shape().dims();
    if ad.len() != 3 || bd.len() != 3 || ad[0] != bd[0] || ad[2] != bd[1] {
        return Err(Status::invalid_argument(format!(
            "BatchMatMul: incompatible shapes {} x {}",
            a.shape(),
            b.shape()
        )));
    }
    let (bs, m, k, n) = (ad[0], ad[1], ad[2], bd[2]);
    let av = a.as_f32()?;
    let bv = b.as_f32()?;
    let mut out = vec![0f32; bs * m * n];
    let batch_cost = 2usize.saturating_mul(m).saturating_mul(k).saturating_mul(n).max(1);
    pool.parallel_for_mut(bs, batch_cost, &mut out, |batches, c| {
        let b0 = batches.start;
        for bi in batches.clone() {
            let ao = bi * m * k;
            let bo = bi * k * n;
            let co = (bi - b0) * m * n;
            for i in 0..m {
                for kk in 0..k {
                    let aik = av[ao + i * k + kk];
                    if aik == 0.0 {
                        continue;
                    }
                    for j in 0..n {
                        c[co + i * n + j] += aik * bv[bo + kk * n + j];
                    }
                }
            }
        }
    });
    Tensor::new(Shape(vec![bs, m, n]), TensorData::F32(out))
}

/// Gauss–Jordan inverse with partial pivoting.
pub fn matrix_inverse(x: &Tensor) -> Result<Tensor> {
    let (n, n2) = dims2(x, "MatrixInverse")?;
    if n != n2 {
        return Err(Status::invalid_argument("MatrixInverse: matrix must be square"));
    }
    let v = x.as_f32()?;
    let mut a: Vec<f64> = v.iter().map(|&x| x as f64).collect();
    let mut inv: Vec<f64> = vec![0.0; n * n];
    for i in 0..n {
        inv[i * n + i] = 1.0;
    }
    for col in 0..n {
        // pivot
        let mut piv = col;
        for row in col + 1..n {
            if a[row * n + col].abs() > a[piv * n + col].abs() {
                piv = row;
            }
        }
        if a[piv * n + col].abs() < 1e-12 {
            return Err(Status::invalid_argument("MatrixInverse: singular matrix"));
        }
        if piv != col {
            for j in 0..n {
                a.swap(col * n + j, piv * n + j);
                inv.swap(col * n + j, piv * n + j);
            }
        }
        let d = a[col * n + col];
        for j in 0..n {
            a[col * n + j] /= d;
            inv[col * n + j] /= d;
        }
        for row in 0..n {
            if row == col {
                continue;
            }
            let f = a[row * n + col];
            if f == 0.0 {
                continue;
            }
            for j in 0..n {
                a[row * n + j] -= f * a[col * n + j];
                inv[row * n + j] -= f * inv[col * n + j];
            }
        }
    }
    Tensor::new(Shape(vec![n, n]), TensorData::F32(inv.into_iter().map(|x| x as f32).collect()))
}

/// Determinant via LU with partial pivoting.
pub fn matrix_determinant(x: &Tensor) -> Result<Tensor> {
    let (n, n2) = dims2(x, "MatrixDeterminant")?;
    if n != n2 {
        return Err(Status::invalid_argument("MatrixDeterminant: matrix must be square"));
    }
    let v = x.as_f32()?;
    let mut a: Vec<f64> = v.iter().map(|&x| x as f64).collect();
    let mut det = 1.0f64;
    for col in 0..n {
        let mut piv = col;
        for row in col + 1..n {
            if a[row * n + col].abs() > a[piv * n + col].abs() {
                piv = row;
            }
        }
        if a[piv * n + col].abs() < 1e-14 {
            return Ok(Tensor::scalar_f32(0.0));
        }
        if piv != col {
            for j in 0..n {
                a.swap(col * n + j, piv * n + j);
            }
            det = -det;
        }
        det *= a[col * n + col];
        for row in col + 1..n {
            let f = a[row * n + col] / a[col * n + col];
            for j in col..n {
                a[row * n + j] -= f * a[col * n + j];
            }
        }
    }
    Ok(Tensor::scalar_f32(det as f32))
}

fn dims2(t: &Tensor, what: &str) -> Result<(usize, usize)> {
    let d = t.shape().dims();
    if d.len() != 2 {
        return Err(Status::invalid_argument(format!("{what}: expected rank 2, got {}", t.shape())));
    }
    Ok((d[0], d[1]))
}

pub(super) fn register(r: &mut KernelRegistry) {
    r.add_sync("MatMul", |ctx: &mut KernelContext| {
        let ta = ctx.node.attr_opt("transpose_a").and_then(|a| a.as_bool().ok()).unwrap_or(false);
        let tb = ctx.node.attr_opt("transpose_b").and_then(|a| a.as_bool().ok()).unwrap_or(false);
        // Memory-planned: accumulate into the port's arena slot, row
        // panels distributed over the device's intra-op pool.
        let (m, k, n) = matmul_dims(ctx.input(0)?, ctx.input(1)?, ta, tb)?;
        let mut out = ctx.alloc_f32_zeroed(0, m * n);
        matmul_impl(
            &ctx.device.compute,
            ctx.input(0)?.as_f32()?,
            ctx.input(1)?.as_f32()?,
            m,
            k,
            n,
            ta,
            tb,
            &mut out,
        );
        Ok(vec![ctx.make_output(0, Shape(vec![m, n]), TensorData::F32(out))?])
    });
    r.add_sync("BatchMatMul", |ctx| {
        Ok(vec![batch_matmul_with_pool(&ctx.device.compute, ctx.input(0)?, ctx.input(1)?)?])
    });
    r.add_sync("MatrixInverse", |ctx| Ok(vec![matrix_inverse(ctx.input(0)?)?]));
    r.add_sync("MatrixDeterminant", |ctx| Ok(vec![matrix_determinant(ctx.input(0)?)?]));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: Vec<usize>, v: Vec<f32>) -> Tensor {
        Tensor::from_f32(shape, v).unwrap()
    }

    #[test]
    fn matmul_2x2() {
        let a = t(vec![2, 2], vec![1., 2., 3., 4.]);
        let b = t(vec![2, 2], vec![5., 6., 7., 8.]);
        let c = matmul(&a, &b, false, false).unwrap();
        assert_eq!(c.as_f32().unwrap(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn matmul_rect() {
        let a = t(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = t(vec![3, 1], vec![1., 1., 1.]);
        let c = matmul(&a, &b, false, false).unwrap();
        assert_eq!(c.shape().dims(), &[2, 1]);
        assert_eq!(c.as_f32().unwrap(), &[6., 15.]);
    }

    #[test]
    fn matmul_transposes_agree() {
        // Compare every transpose flag combo against explicit transposition.
        let a = t(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = t(vec![3, 4], (0..12).map(|i| i as f32).collect());
        let base = matmul(&a, &b, false, false).unwrap();
        let at = crate::kernels::array::transpose(&a, &[1, 0]).unwrap();
        let bt = crate::kernels::array::transpose(&b, &[1, 0]).unwrap();
        assert!(matmul(&at, &b, true, false).unwrap().allclose(&base, 1e-6, 1e-6));
        assert!(matmul(&a, &bt, false, true).unwrap().allclose(&base, 1e-6, 1e-6));
        assert!(matmul(&at, &bt, true, true).unwrap().allclose(&base, 1e-6, 1e-6));
    }

    #[test]
    fn matmul_shape_mismatch() {
        let a = t(vec![2, 3], vec![0.; 6]);
        let b = t(vec![2, 2], vec![0.; 4]);
        assert!(matmul(&a, &b, false, false).is_err());
    }

    #[test]
    fn batch_matmul_basic() {
        let a = t(vec![2, 1, 2], vec![1., 2., 3., 4.]);
        let b = t(vec![2, 2, 1], vec![1., 1., 2., 2.]);
        let c = batch_matmul(&a, &b).unwrap();
        assert_eq!(c.shape().dims(), &[2, 1, 1]);
        assert_eq!(c.as_f32().unwrap(), &[3., 14.]);
    }

    #[test]
    fn inverse_roundtrip() {
        let a = t(vec![2, 2], vec![4., 7., 2., 6.]);
        let inv = matrix_inverse(&a).unwrap();
        let prod = matmul(&a, &inv, false, false).unwrap();
        let eye = t(vec![2, 2], vec![1., 0., 0., 1.]);
        assert!(prod.allclose(&eye, 1e-4, 1e-4));
    }

    #[test]
    fn inverse_singular_rejected() {
        let a = t(vec![2, 2], vec![1., 2., 2., 4.]);
        assert!(matrix_inverse(&a).is_err());
    }

    #[test]
    fn determinant_values() {
        let a = t(vec![2, 2], vec![4., 7., 2., 6.]);
        let d = matrix_determinant(&a).unwrap().scalar_value_f32().unwrap();
        assert!((d - 10.0).abs() < 1e-4);
        let sing = t(vec![2, 2], vec![1., 2., 2., 4.]);
        assert_eq!(matrix_determinant(&sing).unwrap().scalar_value_f32().unwrap(), 0.0);
        // 3x3 with known det = -306
        let m = t(vec![3, 3], vec![6., 1., 1., 4., -2., 5., 2., 8., 7.]);
        let d3 = matrix_determinant(&m).unwrap().scalar_value_f32().unwrap();
        assert!((d3 + 306.0).abs() < 1e-2, "{d3}");
    }

    #[test]
    fn matmul_bit_identical_across_pool_sizes() {
        // Odd, non-tile-multiple dims; every transpose combo; pools of
        // 1/2/4/8 must agree bit for bit (the determinism contract).
        let fill = |r: usize, c: usize, seed: u32| -> Tensor {
            let v: Vec<f32> = (0..r * c)
                .map(|i| ((i as u32).wrapping_mul(2654435761).wrapping_add(seed) % 1000) as f32 * 0.013 - 6.5)
                .collect();
            t(vec![r, c], v)
        };
        // (m=1, …) exercises the matvec column-split path.
        for (m, k, n) in [(67, 131, 45), (1, 131, 4096)] {
            for (ta, tb) in [(false, false), (false, true), (true, false), (true, true)] {
                let a = if ta { fill(k, m, 1) } else { fill(m, k, 1) };
                let b = if tb { fill(n, k, 2) } else { fill(k, n, 2) };
                let base = matmul_with_pool(&ComputePool::serial(), &a, &b, ta, tb).unwrap();
                for threads in [2, 4, 8] {
                    let pool = ComputePool::new(threads, "test-mm");
                    let got = matmul_with_pool(&pool, &a, &b, ta, tb).unwrap();
                    assert_eq!(
                        got.as_f32().unwrap(),
                        base.as_f32().unwrap(),
                        "m={m} ta={ta} tb={tb} threads={threads}"
                    );
                }
            }
        }
    }

    #[test]
    fn batch_matmul_bit_identical_across_pool_sizes() {
        let a = t(vec![5, 17, 23], (0..5 * 17 * 23).map(|i| (i % 97) as f32 * 0.07 - 3.0).collect());
        let b = t(vec![5, 23, 11], (0..5 * 23 * 11).map(|i| (i % 89) as f32 * 0.05 - 2.0).collect());
        let base = batch_matmul(&a, &b).unwrap();
        let pool = ComputePool::new(4, "test-bmm");
        let got = batch_matmul_with_pool(&pool, &a, &b).unwrap();
        assert_eq!(got.as_f32().unwrap(), base.as_f32().unwrap());
    }

    #[test]
    fn matmul_identity() {
        let a = t(vec![3, 3], (0..9).map(|i| i as f32).collect());
        let eye = t(vec![3, 3], vec![1., 0., 0., 0., 1., 0., 0., 0., 1.]);
        let c = matmul(&a, &eye, false, false).unwrap();
        assert_eq!(c.as_f32().unwrap(), a.as_f32().unwrap());
    }
}
