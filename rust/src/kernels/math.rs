//! Element-wise mathematical operations (Table 1 row 1) and reductions:
//! binary ops with full numpy-style broadcasting, unary ops, comparisons,
//! Select, AddN, Cast, CheckNumerics.

use super::{Kernel, KernelContext, KernelRegistry};
use crate::device::ComputePool;
use crate::error::{Result, Status};
use crate::tensor::{DType, Shape, Tensor, TensorData};
use std::collections::HashMap;
use std::sync::{Arc, LazyLock as Lazy, Mutex};

// ---------------------------------------------------------------------------
// broadcasting machinery
// ---------------------------------------------------------------------------

/// A materialized broadcast: the output shape plus, per output element,
/// the element indices to read from each operand.
pub(crate) struct BroadcastMap {
    pub out: Shape,
    pub map: Vec<(usize, usize)>,
}

/// Process-wide pool of broadcast index maps keyed by the operand shape
/// pair. A cached step re-runs the same shapes every step, so the map —
/// formerly the biggest per-step allocation left on the general-broadcast
/// path — is built once and shared read-only.
static BROADCAST_MAPS: Lazy<Mutex<HashMap<(Shape, Shape), Arc<BroadcastMap>>>> =
    Lazy::new(|| Mutex::new(HashMap::new()));

/// Entry cap; eviction is a wholesale clear (a cold map is just a
/// rebuild, never a correctness issue).
const MAX_CACHED_MAPS: usize = 64;

/// Maps bigger than this many output elements are never cached — they
/// would pin large allocations for shapes that may never recur.
const MAX_CACHED_MAP_ELEMS: usize = 1 << 20;

/// Byte-ish budget across the whole cache (total cached index pairs, at
/// 16 B each ⇒ ≤ 64 MiB resident) — the entry cap alone would let 64
/// maximal maps pin ~1 GiB for the process lifetime.
const MAX_CACHED_MAP_TOTAL_ELEMS: usize = 1 << 22;

/// The pooled lookup of [`broadcast_index_map`].
fn cached_broadcast_map(a: &Shape, b: &Shape) -> Result<Arc<BroadcastMap>> {
    let key = (a.clone(), b.clone());
    if let Some(m) = BROADCAST_MAPS.lock().unwrap().get(&key) {
        return Ok(Arc::clone(m));
    }
    let (out, map) = broadcast_index_map(a, b)?;
    let entry = Arc::new(BroadcastMap { out, map });
    if entry.map.len() <= MAX_CACHED_MAP_ELEMS {
        let mut cache = BROADCAST_MAPS.lock().unwrap();
        let mut total: usize = cache.values().map(|m| m.map.len()).sum();
        // Evict largest-first until both caps hold — never wholesale, so
        // a working set over budget sheds its biggest maps while hot
        // small shapes stay cached.
        while cache.len() >= MAX_CACHED_MAPS
            || total.saturating_add(entry.map.len()) > MAX_CACHED_MAP_TOTAL_ELEMS
        {
            let victim = cache
                .iter()
                .max_by_key(|(_, m)| m.map.len())
                .map(|(k2, _)| k2.clone());
            match victim {
                Some(v) => {
                    if let Some(e) = cache.remove(&v) {
                        total -= e.map.len();
                    }
                }
                None => break,
            }
        }
        cache.insert(key, Arc::clone(&entry));
    }
    Ok(entry)
}

/// Iterate the broadcast of two shapes, calling `f(ai, bi)` with element
/// indices into `a` and `b` for every output element, in row-major order.
/// Fast paths: same-shape, scalar lhs/rhs.
fn broadcast_index_map(a: &Shape, b: &Shape) -> Result<(Shape, Vec<(usize, usize)>)> {
    let out = a.broadcast(b)?;
    let n = out.num_elements();
    let rank = out.rank();
    let a_strides = padded_strides(a, rank);
    let b_strides = padded_strides(b, rank);
    let out_dims = out.dims();
    let mut map = Vec::with_capacity(n);
    let mut idx = vec![0usize; rank];
    for _ in 0..n {
        let mut ai = 0;
        let mut bi = 0;
        for d in 0..rank {
            ai += idx[d] * a_strides[d];
            bi += idx[d] * b_strides[d];
        }
        map.push((ai, bi));
        // increment multi-index
        for d in (0..rank).rev() {
            idx[d] += 1;
            if idx[d] < out_dims[d] {
                break;
            }
            idx[d] = 0;
        }
    }
    Ok((out, map))
}

/// Strides of `s` when right-aligned into `rank` dims, with stride 0 for
/// broadcast (size-1 or missing) dimensions.
fn padded_strides(s: &Shape, rank: usize) -> Vec<usize> {
    let strides = s.strides();
    let offset = rank - s.rank();
    let mut out = vec![0usize; rank];
    for d in 0..s.rank() {
        out[offset + d] = if s.dims()[d] == 1 { 0 } else { strides[d] };
    }
    out
}

macro_rules! apply_binary {
    ($a:expr, $b:expr, $out_shape:expr, $map:expr, $f:expr) => {{
        let mut out = Vec::with_capacity($map.len());
        for &(ai, bi) in $map.iter() {
            out.push($f($a[ai], $b[bi]));
        }
        Tensor::new($out_shape, out.into())
    }};
}

impl From<Vec<f32>> for TensorData {
    fn from(v: Vec<f32>) -> Self {
        TensorData::F32(v)
    }
}
impl From<Vec<f64>> for TensorData {
    fn from(v: Vec<f64>) -> Self {
        TensorData::F64(v)
    }
}
impl From<Vec<i32>> for TensorData {
    fn from(v: Vec<i32>) -> Self {
        TensorData::I32(v)
    }
}
impl From<Vec<i64>> for TensorData {
    fn from(v: Vec<i64>) -> Self {
        TensorData::I64(v)
    }
}
impl From<Vec<bool>> for TensorData {
    fn from(v: Vec<bool>) -> Self {
        TensorData::Bool(v)
    }
}

/// Arithmetic binary op with broadcasting, dispatched on dtype.
/// Exposed publicly: AssignAdd/AssignSub and optimizer kernels reuse it.
pub fn binary_elementwise(a: &Tensor, b: &Tensor, op: &str) -> Result<Tensor> {
    if a.dtype() != b.dtype() {
        return Err(Status::invalid_argument(format!(
            "{op}: dtype mismatch {} vs {}",
            a.dtype(),
            b.dtype()
        )));
    }
    // Fast path: identical shapes, no index map needed.
    if a.shape() == b.shape() {
        return match (a.data(), b.data()) {
            (TensorData::F32(x), TensorData::F32(y)) => {
                let f = f32_binop(op)?;
                Tensor::new(a.shape().clone(), x.iter().zip(y).map(|(&p, &q)| f(p, q)).collect::<Vec<f32>>().into())
            }
            (TensorData::F64(x), TensorData::F64(y)) => {
                let f = f64_binop(op)?;
                Tensor::new(a.shape().clone(), x.iter().zip(y).map(|(&p, &q)| f(p, q)).collect::<Vec<f64>>().into())
            }
            (TensorData::I32(x), TensorData::I32(y)) => {
                let f = i64_binop(op)?;
                Tensor::new(a.shape().clone(), x.iter().zip(y).map(|(&p, &q)| f(p as i64, q as i64) as i32).collect::<Vec<i32>>().into())
            }
            (TensorData::I64(x), TensorData::I64(y)) => {
                let f = i64_binop(op)?;
                Tensor::new(a.shape().clone(), x.iter().zip(y).map(|(&p, &q)| f(p, q)).collect::<Vec<i64>>().into())
            }
            _ => Err(Status::unimplemented(format!("{op} for dtype {}", a.dtype()))),
        };
    }
    let bm = cached_broadcast_map(a.shape(), b.shape())?;
    let (out_shape, map) = (bm.out.clone(), &bm.map);
    match (a.data(), b.data()) {
        (TensorData::F32(x), TensorData::F32(y)) => {
            let f = f32_binop(op)?;
            apply_binary!(x, y, out_shape, map, f)
        }
        (TensorData::F64(x), TensorData::F64(y)) => {
            let f = f64_binop(op)?;
            apply_binary!(x, y, out_shape, map, f)
        }
        (TensorData::I32(x), TensorData::I32(y)) => {
            let f = i64_binop(op)?;
            let g = |p: i32, q: i32| f(p as i64, q as i64) as i32;
            apply_binary!(x, y, out_shape, map, g)
        }
        (TensorData::I64(x), TensorData::I64(y)) => {
            let f = i64_binop(op)?;
            apply_binary!(x, y, out_shape, map, f)
        }
        _ => Err(Status::unimplemented(format!("{op} for dtype {}", a.dtype()))),
    }
}

/// Scalar f32 binary function for `op` (shared with `kernels::fused`,
/// which interprets recorded op sequences element-by-element).
pub(crate) fn f32_binop(op: &str) -> Result<fn(f32, f32) -> f32> {
    Ok(match op {
        "Add" => |a, b| a + b,
        "Sub" => |a, b| a - b,
        "Mul" => |a, b| a * b,
        "Div" => |a, b| a / b,
        "Maximum" => f32::max,
        "Minimum" => f32::min,
        "Pow" => f32::powf,
        _ => return Err(Status::unimplemented(format!("f32 binop {op}"))),
    })
}

fn f64_binop(op: &str) -> Result<fn(f64, f64) -> f64> {
    Ok(match op {
        "Add" => |a, b| a + b,
        "Sub" => |a, b| a - b,
        "Mul" => |a, b| a * b,
        "Div" => |a, b| a / b,
        "Maximum" => f64::max,
        "Minimum" => f64::min,
        "Pow" => f64::powf,
        _ => return Err(Status::unimplemented(format!("f64 binop {op}"))),
    })
}

fn i64_binop(op: &str) -> Result<fn(i64, i64) -> i64> {
    Ok(match op {
        "Add" => |a, b| a.wrapping_add(b),
        "Sub" => |a, b| a.wrapping_sub(b),
        "Mul" => |a, b| a.wrapping_mul(b),
        "Div" => |a, b| if b == 0 { 0 } else { a / b },
        "Maximum" => |a, b| a.max(b),
        "Minimum" => |a, b| a.min(b),
        _ => return Err(Status::unimplemented(format!("i64 binop {op}"))),
    })
}

/// Operand geometry the planned binary fast path handles without a
/// broadcast index map.
enum BinKind {
    /// Identical shapes: lock-step iteration, either side forwardable.
    Same,
    /// Rhs is a single element (and does not raise the output's rank):
    /// output is lhs-shaped, lhs forwardable.
    ScalarRhs,
    /// Mirror image of `ScalarRhs`.
    ScalarLhs,
}

/// Approximate per-element cost of an f32 binary op, in scalar-op units
/// (drives the intra-op inline threshold and chunk grain).
pub(crate) fn f32_binop_cost(op: &str) -> usize {
    match op {
        "Div" => 4,
        "Pow" => 16,
        _ => 1,
    }
}

/// Approximate per-element cost of an f32 unary op.
pub(crate) fn f32_unary_cost(op: &str) -> usize {
    match op {
        "Neg" | "Abs" | "Sign" | "Square" => 1,
        // Exp, Log, Sqrt, Rsqrt, Tanh, Reciprocal: transcendental/divide.
        _ => 8,
    }
}

/// Fill the planned f32 output for `port` with `g(i)` over `0..n`.
/// When the pool would run inline anyway, push-fill into an
/// `alloc_f32` buffer (one write per element, no zeroing pass); when it
/// will actually fan out, zero-fill (`alloc_f32_zeroed`) and overwrite
/// through disjoint chunk views. Chunking never changes `g`'s
/// per-element evaluation, so both strategies produce identical bytes.
pub(crate) fn planned_fill(
    ctx: &KernelContext,
    port: usize,
    n: usize,
    cost: usize,
    g: impl Fn(usize) -> f32 + Sync,
) -> Vec<f32> {
    let pool = &ctx.device.compute;
    if !pool.would_parallelize(n, cost) {
        let mut out = ctx.alloc_f32(port, n);
        for i in 0..n {
            out.push(g(i));
        }
        return out;
    }
    let mut out = ctx.alloc_f32_zeroed(port, n);
    pool.parallel_for_mut(n, cost, &mut out, |r, os| {
        for (j, o) in os.iter_mut().enumerate() {
            *o = g(r.start + j);
        }
    });
    out
}

/// The memory-planned kernel body for binary elementwise ops: on the
/// same-shape and scalar-operand f32 paths, write the result in place
/// over whichever operand the plan lets this node forward
/// (`KernelContext::take_forward_f32`), else into the port's arena slot
/// (`alloc_f32_zeroed`); element chunks run on the device's intra-op
/// pool. General f32 broadcasting goes through the pooled index map into
/// the arena ([`binary_broadcast_planned`]); other dtypes fall through to
/// [`binary_elementwise`] (heap).
pub fn binary_elementwise_planned(ctx: &mut KernelContext, op: &str) -> Result<Tensor> {
    let kind = {
        let a = ctx.input(0)?;
        let b = ctx.input(1)?;
        if a.dtype() != DType::F32 || b.dtype() != DType::F32 {
            None
        } else if a.shape() == b.shape() {
            Some(BinKind::Same)
        } else if b.num_elements() == 1 && b.shape().rank() <= a.shape().rank() {
            // The rank bound keeps a [1] rhs from silently flattening a
            // rank-0 lhs's broadcast to shape [1] (cf. kernels::fused).
            Some(BinKind::ScalarRhs)
        } else if a.num_elements() == 1 && a.shape().rank() <= b.shape().rank() {
            Some(BinKind::ScalarLhs)
        } else {
            None
        }
    };
    let Some(kind) = kind else {
        return binary_broadcast_planned(ctx, op);
    };
    let f = f32_binop(op)?;
    let cost = f32_binop_cost(op);
    match kind {
        BinKind::Same => {
            // In-place over the lhs (acc = f(acc, b))…
            if let Some(mut fw) = ctx.take_forward_f32(0) {
                let b = ctx.input(1)?.as_f32()?;
                ctx.device.compute.parallel_for_mut(fw.vec.len(), cost, &mut fw.vec, |r, xs| {
                    for (x, &y) in xs.iter_mut().zip(&b[r.start..r.end]) {
                        *x = f(*x, y);
                    }
                });
                return fw.into_tensor();
            }
            // …or over the rhs (acc = f(a, acc)).
            if let Some(mut fw) = ctx.take_forward_f32(1) {
                let a = ctx.input(0)?.as_f32()?;
                ctx.device.compute.parallel_for_mut(fw.vec.len(), cost, &mut fw.vec, |r, ys| {
                    for (&x, y) in a[r.start..r.end].iter().zip(ys.iter_mut()) {
                        *y = f(x, *y);
                    }
                });
                return fw.into_tensor();
            }
            let shape = ctx.input(0)?.shape().clone();
            let out = {
                let x = ctx.input(0)?.as_f32()?;
                let y = ctx.input(1)?.as_f32()?;
                planned_fill(ctx, 0, shape.num_elements(), cost, |i| f(x[i], y[i]))
            };
            ctx.make_output(0, shape, TensorData::F32(out))
        }
        BinKind::ScalarRhs => {
            let y = ctx.input(1)?.as_f32()?[0];
            if let Some(mut fw) = ctx.take_forward_f32(0) {
                ctx.device.compute.parallel_for_mut(fw.vec.len(), cost, &mut fw.vec, |_r, xs| {
                    for x in xs.iter_mut() {
                        *x = f(*x, y);
                    }
                });
                return fw.into_tensor();
            }
            let shape = ctx.input(0)?.shape().clone();
            let out = {
                let x = ctx.input(0)?.as_f32()?;
                planned_fill(ctx, 0, shape.num_elements(), cost, |i| f(x[i], y))
            };
            ctx.make_output(0, shape, TensorData::F32(out))
        }
        BinKind::ScalarLhs => {
            let x = ctx.input(0)?.as_f32()?[0];
            if let Some(mut fw) = ctx.take_forward_f32(1) {
                ctx.device.compute.parallel_for_mut(fw.vec.len(), cost, &mut fw.vec, |_r, ys| {
                    for y in ys.iter_mut() {
                        *y = f(x, *y);
                    }
                });
                return fw.into_tensor();
            }
            let shape = ctx.input(1)?.shape().clone();
            let out = {
                let y = ctx.input(1)?.as_f32()?;
                planned_fill(ctx, 0, shape.num_elements(), cost, |i| f(x, y[i]))
            };
            ctx.make_output(0, shape, TensorData::F32(out))
        }
    }
}

/// The general-broadcast arm of [`binary_elementwise_planned`]: for f32
/// operands the pooled index map (`cached_broadcast_map`) drives chunked
/// parallel gather-compute into the node's arena slot — no per-step map
/// rebuild, no heap output. Non-f32 keeps the classic heap path.
fn binary_broadcast_planned(ctx: &mut KernelContext, op: &str) -> Result<Tensor> {
    let (shape_a, shape_b) = {
        let a = ctx.input(0)?;
        let b = ctx.input(1)?;
        if a.dtype() != DType::F32 || b.dtype() != DType::F32 {
            return binary_elementwise(a, b, op);
        }
        (a.shape().clone(), b.shape().clone())
    };
    let f = f32_binop(op)?;
    let bm = cached_broadcast_map(&shape_a, &shape_b)?;
    let out = {
        let x = ctx.input(0)?.as_f32()?;
        let y = ctx.input(1)?.as_f32()?;
        let map = &bm.map;
        let cost = f32_binop_cost(op) + 1;
        planned_fill(ctx, 0, bm.out.num_elements(), cost, |i| {
            let (ai, bi) = map[i];
            f(x[ai], y[bi])
        })
    };
    ctx.make_output(0, bm.out.clone(), TensorData::F32(out))
}

/// Memory-planned map of a scalar f32 function over input 0: in place
/// over a dying input when the plan and refcount allow, else into the
/// port's arena slot; element chunks run on the device's intra-op pool
/// (`cost` in scalar-op units drives its inline threshold). Shared by
/// the unary math kernels and `kernels::nn`'s ReLU/Sigmoid, so the
/// forwarding/alloc/parallelism contract lives in one place.
pub(crate) fn planned_unary_map(
    ctx: &mut KernelContext,
    f: fn(f32) -> f32,
    cost: usize,
) -> Result<Tensor> {
    if let Some(mut fw) = ctx.take_forward_f32(0) {
        ctx.device.compute.parallel_for_mut(fw.vec.len(), cost, &mut fw.vec, |_r, xs| {
            for x in xs.iter_mut() {
                *x = f(*x);
            }
        });
        return fw.into_tensor();
    }
    let shape = ctx.input(0)?.shape().clone();
    let out = {
        let x = ctx.input(0)?.as_f32()?;
        planned_fill(ctx, 0, shape.num_elements(), cost, |i| f(x[i]))
    };
    ctx.make_output(0, shape, TensorData::F32(out))
}

/// Memory-planned unary elementwise: in place over a dying f32 input, or
/// into the arena slot; non-f32 falls through to [`unary_elementwise`].
pub fn unary_elementwise_planned(ctx: &mut KernelContext, op: &str) -> Result<Tensor> {
    if ctx.input(0)?.dtype() != DType::F32 {
        return unary_elementwise(ctx.input(0)?, op);
    }
    planned_unary_map(ctx, f32_unary(op)?, f32_unary_cost(op))
}

/// How a comparison pairs its operand elements. The same-shape and
/// single-element fast paths avoid touching the broadcast-map cache —
/// an Equal over two big same-shape tensors needs no index map at all.
#[derive(Clone, Copy)]
enum PairIx<'m> {
    Same,
    ScalarRhs,
    ScalarLhs,
    Map(&'m [(usize, usize)]),
}

impl PairIx<'_> {
    fn at(self, i: usize) -> (usize, usize) {
        match self {
            PairIx::Same => (i, i),
            PairIx::ScalarRhs => (i, 0),
            PairIx::ScalarLhs => (0, i),
            PairIx::Map(m) => m[i],
        }
    }
}

/// Comparison / logical binary op → Bool tensor, with broadcasting.
pub fn compare_elementwise(a: &Tensor, b: &Tensor, op: &str) -> Result<Tensor> {
    if a.dtype() != b.dtype() {
        return Err(Status::invalid_argument(format!(
            "{op}: dtype mismatch {} vs {}",
            a.dtype(),
            b.dtype()
        )));
    }
    // Fast pairings first (the rank bounds mirror BinKind: a [1] operand
    // against a lower-rank one grows the output, which only the general
    // map represents); the pooled map is the general fallback.
    let bm;
    let (out_shape, ix) = if a.shape() == b.shape() {
        (a.shape().clone(), PairIx::Same)
    } else if b.num_elements() == 1 && b.shape().rank() <= a.shape().rank() {
        (a.shape().clone(), PairIx::ScalarRhs)
    } else if a.num_elements() == 1 && a.shape().rank() <= b.shape().rank() {
        (b.shape().clone(), PairIx::ScalarLhs)
    } else {
        bm = cached_broadcast_map(a.shape(), b.shape())?;
        (bm.out.clone(), PairIx::Map(&bm.map))
    };
    let n = out_shape.num_elements();
    fn cmp<T: PartialOrd + PartialEq + Copy>(
        x: &[T],
        y: &[T],
        n: usize,
        ix: PairIx<'_>,
        op: &str,
    ) -> Result<Vec<bool>> {
        let f: fn(T, T) -> bool = match op {
            "Greater" => |a, b| a > b,
            "Less" => |a, b| a < b,
            "Equal" => |a, b| a == b,
            "NotEqual" => |a, b| a != b,
            "GreaterEqual" => |a, b| a >= b,
            "LessEqual" => |a, b| a <= b,
            _ => return Err(Status::unimplemented(format!("comparison {op}"))),
        };
        Ok((0..n)
            .map(|i| {
                let (ai, bi) = ix.at(i);
                f(x[ai], y[bi])
            })
            .collect())
    }
    let out = match (a.data(), b.data()) {
        (TensorData::F32(x), TensorData::F32(y)) => cmp(x, y, n, ix, op)?,
        (TensorData::F64(x), TensorData::F64(y)) => cmp(x, y, n, ix, op)?,
        (TensorData::I32(x), TensorData::I32(y)) => cmp(x, y, n, ix, op)?,
        (TensorData::I64(x), TensorData::I64(y)) => cmp(x, y, n, ix, op)?,
        (TensorData::Bool(x), TensorData::Bool(y)) => {
            let f: fn(bool, bool) -> bool = match op {
                "Equal" => |a, b| a == b,
                "NotEqual" => |a, b| a != b,
                "LogicalAnd" => |a, b| a && b,
                "LogicalOr" => |a, b| a || b,
                _ => return Err(Status::unimplemented(format!("bool comparison {op}"))),
            };
            (0..n)
                .map(|i| {
                    let (ai, bi) = ix.at(i);
                    f(x[ai], y[bi])
                })
                .collect()
        }
        _ => return Err(Status::unimplemented(format!("{op} for dtype {}", a.dtype()))),
    };
    Tensor::new(out_shape, TensorData::Bool(out))
}

/// Scalar f32 unary function for `op` (shared with `kernels::fused`).
pub(crate) fn f32_unary(op: &str) -> Result<fn(f32) -> f32> {
    Ok(match op {
        "Neg" => |v| -v,
        "Exp" => f32::exp,
        "Log" => f32::ln,
        "Sqrt" => f32::sqrt,
        "Rsqrt" => |v| 1.0 / v.sqrt(),
        "Abs" => f32::abs,
        "Sign" => f32::signum,
        "Square" => |v| v * v,
        "Tanh" => f32::tanh,
        "Reciprocal" => |v| 1.0 / v,
        _ => return Err(Status::unimplemented(format!("f32 unary {op}"))),
    })
}

/// Unary elementwise op.
pub fn unary_elementwise(a: &Tensor, op: &str) -> Result<Tensor> {
    match a.data() {
        TensorData::F32(x) => {
            let f = f32_unary(op)?;
            Tensor::new(a.shape().clone(), TensorData::F32(x.iter().map(|&v| f(v)).collect()))
        }
        TensorData::F64(x) => {
            let f: fn(f64) -> f64 = match op {
                "Neg" => |v| -v,
                "Exp" => f64::exp,
                "Log" => f64::ln,
                "Sqrt" => f64::sqrt,
                "Rsqrt" => |v| 1.0 / v.sqrt(),
                "Abs" => f64::abs,
                "Sign" => f64::signum,
                "Square" => |v| v * v,
                "Tanh" => f64::tanh,
                "Reciprocal" => |v| 1.0 / v,
                _ => return Err(Status::unimplemented(format!("f64 unary {op}"))),
            };
            Tensor::new(a.shape().clone(), TensorData::F64(x.iter().map(|&v| f(v)).collect()))
        }
        TensorData::I32(x) => {
            let f: fn(i32) -> i32 = match op {
                "Neg" => |v| -v,
                "Abs" => i32::abs,
                "Sign" => i32::signum,
                "Square" => |v| v * v,
                _ => return Err(Status::unimplemented(format!("i32 unary {op}"))),
            };
            Tensor::new(a.shape().clone(), TensorData::I32(x.iter().map(|&v| f(v)).collect()))
        }
        TensorData::Bool(x) if op == "LogicalNot" => {
            Tensor::new(a.shape().clone(), TensorData::Bool(x.iter().map(|&v| !v).collect()))
        }
        _ => Err(Status::unimplemented(format!("{op} for dtype {}", a.dtype()))),
    }
}

// ---------------------------------------------------------------------------
// reductions
// ---------------------------------------------------------------------------

/// The accumulation kind of a reduction op.
#[derive(Clone, Copy, PartialEq)]
enum RedKind {
    Sum,
    Mean,
    Prod,
    Max,
    Min,
}

impl RedKind {
    fn parse(op: &str) -> Result<RedKind> {
        Ok(match op {
            "Sum" => RedKind::Sum,
            "Mean" => RedKind::Mean,
            "Prod" => RedKind::Prod,
            "Max" => RedKind::Max,
            "Min" => RedKind::Min,
            _ => return Err(Status::unimplemented(format!("reduction {op}"))),
        })
    }

    fn init(self) -> f64 {
        match self {
            RedKind::Sum | RedKind::Mean => 0.0,
            RedKind::Prod => 1.0,
            RedKind::Max => f64::NEG_INFINITY,
            RedKind::Min => f64::INFINITY,
        }
    }
}

/// Validated reduction geometry shared by the serial and planned paths.
struct ReducePlan {
    kind: RedKind,
    out_shape: Shape,
    /// Input dims/strides of the kept axes, in kept (= output) order.
    kept_dims: Vec<usize>,
    kept_strides: Vec<usize>,
    /// Input dims/strides of the reduced axes, in axis order.
    red_dims: Vec<usize>,
    red_strides: Vec<usize>,
    reduce_n: usize,
}

fn reduce_plan(shape: &Shape, op: &str, axes: Option<&[i64]>) -> Result<ReducePlan> {
    let kind = RedKind::parse(op)?;
    let rank = shape.rank();
    let axes: Vec<usize> = match axes {
        None => (0..rank).collect(),
        Some(ax) if ax.is_empty() => (0..rank).collect(),
        Some(ax) => {
            let mut v = Vec::with_capacity(ax.len());
            for &x in ax {
                let x = if x < 0 { x + rank as i64 } else { x };
                if x < 0 || x as usize >= rank {
                    return Err(Status::invalid_argument(format!(
                        "{op}: axis {x} out of range for rank {rank}"
                    )));
                }
                v.push(x as usize);
            }
            v.sort();
            v.dedup();
            v
        }
    };
    let in_dims = shape.dims();
    let in_strides = shape.strides();
    let kept: Vec<usize> = (0..rank).filter(|d| !axes.contains(d)).collect();
    Ok(ReducePlan {
        kind,
        out_shape: Shape(kept.iter().map(|&d| in_dims[d]).collect()),
        kept_dims: kept.iter().map(|&d| in_dims[d]).collect(),
        kept_strides: kept.iter().map(|&d| in_strides[d]).collect(),
        red_dims: axes.iter().map(|&d| in_dims[d]).collect(),
        red_strides: axes.iter().map(|&d| in_strides[d]).collect(),
        // True product: 0 when a reduced dim is empty (outputs then keep
        // their init value, matching a serial sweep of zero elements).
        reduce_n: axes.iter().map(|&d| in_dims[d]).product::<usize>(),
    })
}

/// The reduction body: each output element gathers its reduce-space
/// contributions in row-major order (exactly the sub-order a serial
/// row-major sweep of the input delivers to that slot), accumulating in
/// f64 — so every output is bit-identical to serial execution no matter
/// how `pool` chunks the output range.
fn reduce_into(pool: &ComputePool, x: &[f32], plan: &ReducePlan, out: &mut [f32]) {
    let kind = plan.kind;
    let init = kind.init();
    let cost = plan.reduce_n.saturating_mul(2).max(1);
    pool.parallel_for_mut(out.len(), cost, out, |r, os| {
        // Mixed-radix counter over the reduce space; a full sweep wraps
        // both the digits and the offset back to zero, so one counter
        // serves every output element in the chunk.
        let mut ridx = vec![0usize; plan.red_dims.len()];
        let mut off = 0usize;
        for (oi_rel, o) in os.iter_mut().enumerate() {
            let oi = r.start + oi_rel;
            // Unravel oi over the kept dims → base input offset.
            let mut rem = oi;
            let mut base = 0usize;
            for d in (0..plan.kept_dims.len()).rev() {
                base += (rem % plan.kept_dims[d]) * plan.kept_strides[d];
                rem /= plan.kept_dims[d];
            }
            let mut acc = init;
            for _ in 0..plan.reduce_n {
                let v = x[base + off] as f64;
                acc = match kind {
                    RedKind::Sum | RedKind::Mean => acc + v,
                    RedKind::Prod => acc * v,
                    RedKind::Max => acc.max(v),
                    RedKind::Min => acc.min(v),
                };
                for d in (0..ridx.len()).rev() {
                    ridx[d] += 1;
                    off += plan.red_strides[d];
                    if ridx[d] < plan.red_dims[d] {
                        break;
                    }
                    off -= plan.red_strides[d] * plan.red_dims[d];
                    ridx[d] = 0;
                }
            }
            if kind == RedKind::Mean {
                acc /= plan.reduce_n.max(1) as f64;
            }
            *o = acc as f32;
        }
    });
}

/// Reduce over `axes` (empty/None ⇒ all axes), keep_dims=false. Serial
/// heap-allocating convenience; the kernel path is [`reduce_planned`].
pub fn reduce(a: &Tensor, op: &str, axes: Option<&[i64]>) -> Result<Tensor> {
    let plan = reduce_plan(a.shape(), op, axes)?;
    let x = a.as_f32()?; // reductions implemented for f32 (the training dtype)
    let mut out = vec![0f32; plan.out_shape.num_elements()];
    reduce_into(&ComputePool::serial(), x, &plan, &mut out);
    Tensor::new(plan.out_shape.clone(), TensorData::F32(out))
}

/// Memory-planned [`reduce`]: the output lands in the node's arena slot
/// and output chunks run on the device's intra-op pool.
pub(crate) fn reduce_planned(ctx: &KernelContext, op: &str, axes: Option<&[i64]>) -> Result<Tensor> {
    let plan = reduce_plan(ctx.input(0)?.shape(), op, axes)?;
    let mut out = ctx.alloc_f32_zeroed(0, plan.out_shape.num_elements());
    {
        let x = ctx.input(0)?.as_f32()?;
        reduce_into(&ctx.device.compute, x, &plan, &mut out);
    }
    ctx.make_output(0, plan.out_shape.clone(), TensorData::F32(out))
}

/// ArgMax along `axis` → I64 tensor.
pub fn argmax(a: &Tensor, axis: i64) -> Result<Tensor> {
    let rank = a.shape().rank() as i64;
    let axis = if axis < 0 { axis + rank } else { axis };
    if axis < 0 || axis >= rank {
        return Err(Status::invalid_argument(format!("ArgMax: axis {axis} out of range")));
    }
    let axis = axis as usize;
    let x = a.as_f32()?;
    let dims = a.shape().dims();
    let out_dims: Vec<usize> =
        dims.iter().enumerate().filter(|&(d, _)| d != axis).map(|(_, &s)| s).collect();
    let out_shape = Shape(out_dims);
    let mut out = Vec::with_capacity(out_shape.num_elements());
    let outer: usize = dims[..axis].iter().product();
    let inner: usize = dims[axis + 1..].iter().product();
    let outer = outer.max(1);
    let inner = inner.max(1);
    for o in 0..outer {
        for i in 0..inner {
            let mut best = f32::NEG_INFINITY;
            let mut best_k = 0i64;
            for k in 0..dims[axis] {
                let v = x[o * dims[axis] * inner + k * inner + i];
                if v > best {
                    best = v;
                    best_k = k as i64;
                }
            }
            out.push(best_k);
        }
    }
    Tensor::new(out_shape, TensorData::I64(out))
}

/// Select(cond, a, b): elementwise cond ? a : b (shapes must match; cond
/// may broadcast).
pub fn select(cond: &Tensor, a: &Tensor, b: &Tensor) -> Result<Tensor> {
    if a.shape() != b.shape() || a.dtype() != b.dtype() {
        return Err(Status::invalid_argument("Select: a and b must match in shape and dtype"));
    }
    let c = cond.as_bool()?;
    let n = a.num_elements();
    let pick = |i: usize| -> bool {
        if c.len() == 1 {
            c[0]
        } else {
            c[i % c.len()]
        }
    };
    if c.len() != 1 && c.len() != n {
        return Err(Status::invalid_argument(format!(
            "Select: cond has {} elements, operands have {n}",
            c.len()
        )));
    }
    match (a.data(), b.data()) {
        (TensorData::F32(x), TensorData::F32(y)) => Tensor::new(
            a.shape().clone(),
            TensorData::F32((0..n).map(|i| if pick(i) { x[i] } else { y[i] }).collect()),
        ),
        (TensorData::I64(x), TensorData::I64(y)) => Tensor::new(
            a.shape().clone(),
            TensorData::I64((0..n).map(|i| if pick(i) { x[i] } else { y[i] }).collect()),
        ),
        _ => Err(Status::unimplemented(format!("Select for dtype {}", a.dtype()))),
    }
}

// ---------------------------------------------------------------------------
// registration
// ---------------------------------------------------------------------------

pub(super) fn register(r: &mut KernelRegistry) {
    for op in ["Add", "Sub", "Mul", "Div", "Maximum", "Minimum", "Pow"] {
        let name = op.to_string();
        r.add(op, move |_| {
            let name = name.clone();
            Ok(Kernel::Sync(Box::new(move |ctx: &mut KernelContext| {
                Ok(vec![binary_elementwise_planned(ctx, &name)?])
            })))
        });
    }
    for op in [
        "Neg", "Exp", "Log", "Sqrt", "Rsqrt", "Abs", "Sign", "Square", "Tanh", "Reciprocal",
    ] {
        let name = op.to_string();
        r.add(op, move |_| {
            let name = name.clone();
            Ok(Kernel::Sync(Box::new(move |ctx: &mut KernelContext| {
                Ok(vec![unary_elementwise_planned(ctx, &name)?])
            })))
        });
    }
    r.add_sync("LogicalNot", |ctx| Ok(vec![unary_elementwise(ctx.input(0)?, "LogicalNot")?]));
    for op in
        ["Greater", "Less", "Equal", "NotEqual", "GreaterEqual", "LessEqual", "LogicalAnd", "LogicalOr"]
    {
        let name = op.to_string();
        r.add(op, move |_| {
            let name = name.clone();
            Ok(Kernel::Sync(Box::new(move |ctx: &mut KernelContext| {
                Ok(vec![compare_elementwise(ctx.input(0)?, ctx.input(1)?, &name)?])
            })))
        });
    }
    for op in ["Sum", "Mean", "Max", "Min", "Prod"] {
        let name = op.to_string();
        r.add(op, move |_| {
            let name = name.clone();
            Ok(Kernel::Sync(Box::new(move |ctx: &mut KernelContext| {
                let axes = match ctx.node.attr_opt("axes") {
                    Some(a) => Some(a.as_list_i64()?.to_vec()),
                    None => None,
                };
                Ok(vec![reduce_planned(ctx, &name, axes.as_deref())?])
            })))
        });
    }
    r.add_sync("ArgMax", |ctx| {
        let axis = ctx.node.attr_opt("axis").map(|a| a.as_i64()).transpose()?.unwrap_or(-1);
        Ok(vec![argmax(ctx.input(0)?, axis)?])
    });
    r.add_sync("Select", |ctx| {
        Ok(vec![select(ctx.input(0)?, ctx.input(1)?, ctx.input(2)?)?])
    });
    r.add_sync("AddN", |ctx| {
        let mut acc = ctx.input(0)?.clone();
        for i in 1..ctx.inputs.len() {
            acc = binary_elementwise(&acc, ctx.input(i)?, "Add")?;
        }
        Ok(vec![acc])
    });
    r.add_sync("Cast", |ctx| {
        let to = ctx.node.attr("DstT")?.as_type()?;
        Ok(vec![ctx.input(0)?.cast(to)?])
    });
    r.add_sync("CheckNumerics", |ctx| {
        let t = ctx.input(0)?;
        if t.has_non_finite() {
            let msg = ctx
                .node
                .attr_opt("message")
                .and_then(|a| a.as_str().ok().map(String::from))
                .unwrap_or_default();
            return Err(Status::invalid_argument(format!(
                "CheckNumerics({}): tensor contains Inf or NaN. {msg}",
                ctx.node.name
            )));
        }
        Ok(vec![t.clone()])
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: Vec<usize>, v: Vec<f32>) -> Tensor {
        Tensor::from_f32(shape, v).unwrap()
    }

    #[test]
    fn add_same_shape() {
        let r = binary_elementwise(&t(vec![2], vec![1., 2.]), &t(vec![2], vec![3., 4.]), "Add")
            .unwrap();
        assert_eq!(r.as_f32().unwrap(), &[4., 6.]);
    }

    #[test]
    fn broadcast_scalar() {
        let r =
            binary_elementwise(&t(vec![2, 2], vec![1., 2., 3., 4.]), &Tensor::scalar_f32(10.0), "Mul")
                .unwrap();
        assert_eq!(r.as_f32().unwrap(), &[10., 20., 30., 40.]);
        assert_eq!(r.shape().dims(), &[2, 2]);
    }

    #[test]
    fn broadcast_row_and_col() {
        // [2,1] + [3] -> [2,3]
        let a = t(vec![2, 1], vec![10., 20.]);
        let b = t(vec![3], vec![1., 2., 3.]);
        let r = binary_elementwise(&a, &b, "Add").unwrap();
        assert_eq!(r.shape().dims(), &[2, 3]);
        assert_eq!(r.as_f32().unwrap(), &[11., 12., 13., 21., 22., 23.]);
    }

    #[test]
    fn broadcast_bias_add_pattern() {
        // [2,3] + [3]: the Wx+b pattern of Fig 1.
        let a = t(vec![2, 3], vec![0., 0., 0., 1., 1., 1.]);
        let b = t(vec![3], vec![5., 6., 7.]);
        let r = binary_elementwise(&a, &b, "Add").unwrap();
        assert_eq!(r.as_f32().unwrap(), &[5., 6., 7., 6., 7., 8.]);
    }

    #[test]
    fn incompatible_broadcast_rejected() {
        let a = t(vec![2, 3], vec![0.; 6]);
        let b = t(vec![4], vec![0.; 4]);
        assert!(binary_elementwise(&a, &b, "Add").is_err());
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let a = t(vec![1], vec![1.0]);
        let b = Tensor::from_i32(vec![1], vec![1]).unwrap();
        assert!(binary_elementwise(&a, &b, "Add").is_err());
    }

    #[test]
    fn integer_arithmetic() {
        let a = Tensor::from_i64(vec![3], vec![10, 20, 30]).unwrap();
        let b = Tensor::from_i64(vec![3], vec![3, 5, 0]).unwrap();
        let r = binary_elementwise(&a, &b, "Div").unwrap();
        assert_eq!(r.as_i64().unwrap(), &[3, 4, 0]); // div-by-zero -> 0
    }

    #[test]
    fn unary_ops() {
        let a = t(vec![3], vec![1., 4., 9.]);
        assert_eq!(unary_elementwise(&a, "Sqrt").unwrap().as_f32().unwrap(), &[1., 2., 3.]);
        assert_eq!(unary_elementwise(&a, "Neg").unwrap().as_f32().unwrap(), &[-1., -4., -9.]);
        assert_eq!(unary_elementwise(&a, "Square").unwrap().as_f32().unwrap(), &[1., 16., 81.]);
        let e = unary_elementwise(&t(vec![1], vec![0.0]), "Exp").unwrap();
        assert!((e.as_f32().unwrap()[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn comparisons() {
        let a = t(vec![3], vec![1., 2., 3.]);
        let b = t(vec![3], vec![2., 2., 2.]);
        assert_eq!(
            compare_elementwise(&a, &b, "Greater").unwrap().as_bool().unwrap(),
            &[false, false, true]
        );
        assert_eq!(
            compare_elementwise(&a, &b, "Equal").unwrap().as_bool().unwrap(),
            &[false, true, false]
        );
        assert_eq!(
            compare_elementwise(&a, &b, "LessEqual").unwrap().as_bool().unwrap(),
            &[true, true, false]
        );
    }

    #[test]
    fn reduce_all() {
        let a = t(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(reduce(&a, "Sum", None).unwrap().scalar_value_f32().unwrap(), 21.0);
        assert_eq!(reduce(&a, "Mean", None).unwrap().scalar_value_f32().unwrap(), 3.5);
        assert_eq!(reduce(&a, "Max", None).unwrap().scalar_value_f32().unwrap(), 6.0);
        assert_eq!(reduce(&a, "Min", None).unwrap().scalar_value_f32().unwrap(), 1.0);
    }

    #[test]
    fn reduce_axis() {
        let a = t(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let rows = reduce(&a, "Sum", Some(&[1])).unwrap();
        assert_eq!(rows.shape().dims(), &[2]);
        assert_eq!(rows.as_f32().unwrap(), &[6., 15.]);
        let cols = reduce(&a, "Sum", Some(&[0])).unwrap();
        assert_eq!(cols.as_f32().unwrap(), &[5., 7., 9.]);
        // negative axis
        let rows2 = reduce(&a, "Sum", Some(&[-1])).unwrap();
        assert_eq!(rows2.as_f32().unwrap(), &[6., 15.]);
    }

    #[test]
    fn reduce_rank3_middle_axis_and_ops() {
        // Non-trailing axes exercise the strided gather of the rewritten
        // reduction (the parallel per-output form).
        let v: Vec<f32> = (0..24).map(|i| (i as f32) * 0.5 - 3.0).collect();
        let a = t(vec![2, 3, 4], v.clone());
        let s = reduce(&a, "Sum", Some(&[1])).unwrap();
        assert_eq!(s.shape().dims(), &[2, 4]);
        // Manual check of one slot: out[0,1] = a[0,0,1]+a[0,1,1]+a[0,2,1].
        assert_eq!(s.as_f32().unwrap()[1], v[1] + v[5] + v[9]);
        let m = reduce(&a, "Max", Some(&[0, 2])).unwrap();
        assert_eq!(m.shape().dims(), &[3]);
        assert_eq!(m.as_f32().unwrap()[0], v[15]); // max over a[:,0,:]
        let p = reduce(&t(vec![2, 2], vec![2., 3., 4., 5.]), "Prod", Some(&[0])).unwrap();
        assert_eq!(p.as_f32().unwrap(), &[8., 15.]);
    }

    #[test]
    fn broadcast_map_is_pooled() {
        let a = Shape(vec![3, 1]);
        let b = Shape(vec![4]);
        let m1 = cached_broadcast_map(&a, &b).unwrap();
        let m2 = cached_broadcast_map(&a, &b).unwrap();
        assert!(Arc::ptr_eq(&m1, &m2));
        assert_eq!(m1.out.dims(), &[3, 4]);
    }

    #[test]
    fn reduce_mean_axis() {
        let a = t(vec![2, 2], vec![1., 3., 5., 7.]);
        let m = reduce(&a, "Mean", Some(&[0])).unwrap();
        assert_eq!(m.as_f32().unwrap(), &[3., 5.]);
    }

    #[test]
    fn argmax_rows() {
        let a = t(vec![2, 3], vec![1., 9., 3., 7., 5., 6.]);
        let am = argmax(&a, 1).unwrap();
        assert_eq!(am.as_i64().unwrap(), &[1, 0]);
        assert_eq!(am.shape().dims(), &[2]);
        let am0 = argmax(&a, 0).unwrap();
        assert_eq!(am0.as_i64().unwrap(), &[1, 0, 1]);
    }

    #[test]
    fn select_elementwise() {
        let c = Tensor::from_bool(vec![3], vec![true, false, true]).unwrap();
        let a = t(vec![3], vec![1., 2., 3.]);
        let b = t(vec![3], vec![10., 20., 30.]);
        let r = select(&c, &a, &b).unwrap();
        assert_eq!(r.as_f32().unwrap(), &[1., 20., 3.]);
        // scalar cond
        let c1 = Tensor::scalar_bool(false);
        let r1 = select(&c1, &a, &b).unwrap();
        assert_eq!(r1.as_f32().unwrap(), &[10., 20., 30.]);
    }
}
