//! Kernels for the sparse-embedding toolkit (§3's embedding examples,
//! §4.2's sparse gradients): segment reductions, functional scatters, the
//! DynamicPartition/DynamicStitch pair behind sharded lookups, the lazy
//! `SparseToDense` densify handle, and the sampled-softmax pair.
//!
//! Index handling is uniform across every kernel here (and the fixed
//! `Gather`): indices may be int32 or int64; negative, out-of-range, or
//! wrong-dtype indices are `InvalidArgument` — never a panic, never
//! `OutOfRange` (which the parameter server reserves for its push
//! validation).

use super::{KernelContext, KernelRegistry};
use crate::error::{Result, Status};
use crate::tensor::{Shape, Tensor, TensorData};
use crate::util::rng::Pcg32;

// ---------------------------------------------------------------------------
// shared index helpers
// ---------------------------------------------------------------------------

/// Copy an index tensor out as i64, accepting int32 or int64.
fn indices_i64(t: &Tensor, op: &str) -> Result<Vec<i64>> {
    match t.data() {
        TensorData::I64(v) => Ok(v.clone()),
        TensorData::I32(v) => Ok(v.iter().map(|&i| i as i64).collect()),
        d => Err(Status::invalid_argument(format!(
            "{op}: indices must be int32 or int64, got {}",
            d.dtype()
        ))),
    }
}

/// Range-check one index against `[0, rows)`.
fn check_row(i: i64, rows: usize, op: &str) -> Result<usize> {
    if i < 0 || i as u64 >= rows as u64 {
        return Err(Status::invalid_argument(format!(
            "{op}: index {i} out of range [0, {rows})"
        )));
    }
    Ok(i as usize)
}

/// (rows, row length) of a rank ≥ 1 tensor.
fn rows_and_row(t: &Tensor, op: &str) -> Result<(usize, usize)> {
    let dims = t.shape().dims();
    if dims.is_empty() {
        return Err(Status::invalid_argument(format!("{op}: operand must have rank >= 1")));
    }
    Ok((dims[0], dims[1..].iter().product::<usize>().max(1)))
}

// ---------------------------------------------------------------------------
// segment sum / scatter
// ---------------------------------------------------------------------------

fn unsorted_segment_sum(ctx: &mut KernelContext) -> Result<Vec<Tensor>> {
    let data = ctx.input(0)?;
    let ids = indices_i64(ctx.input(1)?, "UnsortedSegmentSum")?;
    let num = ctx.node.attr("num_segments")?.as_i64()?;
    if num < 0 {
        return Err(Status::invalid_argument(format!(
            "UnsortedSegmentSum: num_segments {num} must be >= 0"
        )));
    }
    let num = num as usize;
    let (rows, row) = rows_and_row(data, "UnsortedSegmentSum")?;
    if ids.len() != rows {
        return Err(Status::invalid_argument(format!(
            "UnsortedSegmentSum: {} segment ids for {rows} data rows",
            ids.len()
        )));
    }
    let v = data.as_f32()?;
    let mut out = ctx.alloc_f32_zeroed(0, num * row);
    for (k, &s) in ids.iter().enumerate() {
        let s = check_row(s, num, "UnsortedSegmentSum")?;
        for j in 0..row {
            out[s * row + j] += v[k * row + j];
        }
    }
    let mut out_dims = vec![num];
    out_dims.extend_from_slice(&data.shape().dims()[1..]);
    Ok(vec![ctx.make_output(0, Shape(out_dims), TensorData::F32(out))?])
}

/// Shared body of ScatterAdd/ScatterSub: a *functional* scatter — a copy
/// of `x` with `updates` rows combined in (the in-place variable flavour
/// lives on the parameter server as scatter-SGD).
fn scatter_combine(ctx: &mut KernelContext, sign: f32, op: &'static str) -> Result<Vec<Tensor>> {
    let x = ctx.input(0)?;
    let idx = indices_i64(ctx.input(1)?, op)?;
    let updates = ctx.input(2)?;
    let (rows, row) = rows_and_row(x, op)?;
    let u = updates.as_f32()?;
    if u.len() != idx.len() * row {
        return Err(Status::invalid_argument(format!(
            "{op}: updates have {} elements, want {} indices x row length {row}",
            u.len(),
            idx.len()
        )));
    }
    let xv = x.as_f32()?;
    let mut out = ctx.alloc_f32(0, xv.len());
    out.extend_from_slice(xv);
    for (k, &i) in idx.iter().enumerate() {
        let r = check_row(i, rows, op)?;
        for j in 0..row {
            out[r * row + j] += sign * u[k * row + j];
        }
    }
    Ok(vec![ctx.make_output(0, x.shape().clone(), TensorData::F32(out))?])
}

// ---------------------------------------------------------------------------
// partition / stitch
// ---------------------------------------------------------------------------

fn dynamic_partition(ctx: &mut KernelContext) -> Result<Vec<Tensor>> {
    let data = ctx.input(0)?;
    let parts = indices_i64(ctx.input(1)?, "DynamicPartition")?;
    let num = ctx.node.attr("num_partitions")?.as_i64()?;
    if num <= 0 {
        return Err(Status::invalid_argument(format!(
            "DynamicPartition: num_partitions {num} must be >= 1"
        )));
    }
    let num = num as usize;
    let (rows, row) = rows_and_row(data, "DynamicPartition")?;
    if parts.len() != rows {
        return Err(Status::invalid_argument(format!(
            "DynamicPartition: {} partition ids for {rows} data rows",
            parts.len()
        )));
    }
    let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); num];
    for (i, &p) in parts.iter().enumerate() {
        buckets[check_row(p, num, "DynamicPartition")?].push(i);
    }
    let trailing = &data.shape().dims()[1..];
    // The gradient path partitions i64 row ids alongside f32 data, so both
    // dtypes are first-class here.
    match data.data() {
        TensorData::F32(v) => buckets
            .iter()
            .map(|rs| {
                let mut out = Vec::with_capacity(rs.len() * row);
                for &i in rs {
                    out.extend_from_slice(&v[i * row..(i + 1) * row]);
                }
                let mut dims = vec![rs.len()];
                dims.extend_from_slice(trailing);
                Tensor::new(Shape(dims), TensorData::F32(out))
            })
            .collect(),
        TensorData::I64(v) => buckets
            .iter()
            .map(|rs| {
                let mut out = Vec::with_capacity(rs.len() * row);
                for &i in rs {
                    out.extend_from_slice(&v[i * row..(i + 1) * row]);
                }
                let mut dims = vec![rs.len()];
                dims.extend_from_slice(trailing);
                Tensor::new(Shape(dims), TensorData::I64(out))
            })
            .collect(),
        d => Err(Status::invalid_argument(format!(
            "DynamicPartition: data must be float32 or int64, got {}",
            d.dtype()
        ))),
    }
}

fn dynamic_stitch(ctx: &mut KernelContext) -> Result<Vec<Tensor>> {
    let n = ctx.node.attr("N")?.as_i64()?;
    if n <= 0 || ctx.inputs.len() != 2 * n as usize {
        return Err(Status::invalid_argument(format!(
            "DynamicStitch: N={n} needs 2N inputs, got {}",
            ctx.inputs.len()
        )));
    }
    let n = n as usize;
    let mut pairs = Vec::with_capacity(n);
    let mut total_max: i64 = -1;
    let mut row: Option<(usize, Vec<usize>)> = None;
    for k in 0..n {
        let idx = indices_i64(ctx.input(k)?, "DynamicStitch")?;
        let data = ctx.input(n + k)?;
        let (rows, rlen) = rows_and_row(data, "DynamicStitch")?;
        if idx.len() != rows {
            return Err(Status::invalid_argument(format!(
                "DynamicStitch: part {k} has {} indices for {rows} data rows",
                idx.len()
            )));
        }
        match &row {
            None => row = Some((rlen, data.shape().dims()[1..].to_vec())),
            Some((r, dims)) => {
                if *r != rlen || dims[..] != data.shape().dims()[1..] {
                    return Err(Status::invalid_argument(
                        "DynamicStitch: parts disagree on row shape",
                    ));
                }
            }
        }
        for &i in &idx {
            if i < 0 {
                return Err(Status::invalid_argument(format!(
                    "DynamicStitch: negative index {i}"
                )));
            }
            total_max = total_max.max(i);
        }
        pairs.push((idx, data.as_f32()?.to_vec()));
    }
    let (rlen, trailing) = row.unwrap();
    let out_rows = (total_max + 1) as usize;
    let mut out = ctx.alloc_f32_zeroed(0, out_rows * rlen);
    for (idx, data) in &pairs {
        for (pos, &i) in idx.iter().enumerate() {
            let i = i as usize;
            out[i * rlen..(i + 1) * rlen].copy_from_slice(&data[pos * rlen..(pos + 1) * rlen]);
        }
    }
    let mut dims = vec![out_rows];
    dims.extend_from_slice(&trailing);
    Ok(vec![ctx.make_output(0, Shape(dims), TensorData::F32(out))?])
}

fn row_ids(ctx: &mut KernelContext) -> Result<Vec<Tensor>> {
    let (rows, _) = rows_and_row(ctx.input(0)?, "RowIds")?;
    let mut out = ctx.alloc_i64(0, rows);
    out.extend(0..rows as i64);
    Ok(vec![ctx.make_output(0, vec![rows], TensorData::I64(out))?])
}

/// ids -> (shard = id % shards, local = id / shards): the mod-shard map of
/// `sparse::ShardedTable`. Negative ids are rejected here (before they can
/// reach a per-shard Gather with a wrapped local row).
fn mod_shard(ctx: &mut KernelContext) -> Result<Vec<Tensor>> {
    let ids = indices_i64(ctx.input(0)?, "ModShard")?;
    let shards = ctx.node.attr("shards")?.as_i64()?;
    if shards < 1 {
        return Err(Status::invalid_argument(format!(
            "ModShard: shards {shards} must be >= 1"
        )));
    }
    let n = ids.len();
    let mut parts = ctx.alloc_i64(0, n);
    let mut locals = ctx.alloc_i64(1, n);
    for &i in &ids {
        if i < 0 {
            return Err(Status::invalid_argument(format!("ModShard: negative id {i}")));
        }
        parts.push(i % shards);
        locals.push(i / shards);
    }
    let shape = ctx.input(0)?.shape().clone();
    Ok(vec![
        ctx.make_output(0, shape.clone(), TensorData::I64(parts))?,
        ctx.make_output(1, shape, TensorData::I64(locals))?,
    ])
}

fn sparse_to_dense(ctx: &mut KernelContext) -> Result<Vec<Tensor>> {
    let idx = indices_i64(ctx.input(0)?, "SparseToDense")?;
    let values = ctx.input(1)?;
    let like = ctx.input(2)?;
    let (rows, row) = rows_and_row(like, "SparseToDense")?;
    let v = values.as_f32()?;
    if v.len() != idx.len() * row {
        return Err(Status::invalid_argument(format!(
            "SparseToDense: values have {} elements, want {} indices x row length {row}",
            v.len(),
            idx.len()
        )));
    }
    // Accumulating (+=) in index order: duplicate indices sum, matching the
    // per-occurrence scatter-SGD semantics on the parameter server.
    let mut out = ctx.alloc_f32_zeroed(0, like.num_elements());
    for (k, &i) in idx.iter().enumerate() {
        let r = check_row(i, rows, "SparseToDense")?;
        for j in 0..row {
            out[r * row + j] += v[k * row + j];
        }
    }
    Ok(vec![ctx.make_output(0, like.shape().clone(), TensorData::F32(out))?])
}

// ---------------------------------------------------------------------------
// sampled softmax
// ---------------------------------------------------------------------------

/// The negative ids for one step: forward and gradient kernels both call
/// this with the same (seed, step_id), so they agree within a step and the
/// draw still varies across steps.
pub fn sampled_ids(vocab: usize, num_sampled: usize, seed: u64, step_id: u64) -> Vec<i64> {
    let mut rng = Pcg32::new(seed ^ step_id);
    (0..num_sampled).map(|_| rng.index(vocab) as i64).collect()
}

/// Validated common geometry of both sampled-softmax kernels:
/// (batch, dim, vocab, labels, num_sampled, seed).
#[allow(clippy::type_complexity)]
fn sampled_softmax_geometry(
    ctx: &KernelContext,
) -> Result<(usize, usize, usize, Vec<i64>, usize, u64)> {
    let emb = ctx.input(0)?;
    let weights = ctx.input(1)?;
    let labels = indices_i64(ctx.input(2)?, "SampledSoftmax")?;
    if emb.shape().rank() != 2 || weights.shape().rank() != 2 {
        return Err(Status::invalid_argument(
            "SampledSoftmax: emb and weights must be rank 2",
        ));
    }
    let (batch, dim) = (emb.shape().dim(0), emb.shape().dim(1));
    let (vocab, wdim) = (weights.shape().dim(0), weights.shape().dim(1));
    if wdim != dim {
        return Err(Status::invalid_argument(format!(
            "SampledSoftmax: emb dim {dim} != weights dim {wdim}"
        )));
    }
    if labels.len() != batch {
        return Err(Status::invalid_argument(format!(
            "SampledSoftmax: {} labels for batch {batch}",
            labels.len()
        )));
    }
    let num_sampled = ctx.node.attr("num_sampled")?.as_i64()?;
    if num_sampled < 1 || num_sampled as usize >= vocab.max(2) {
        return Err(Status::invalid_argument(format!(
            "SampledSoftmax: num_sampled {num_sampled} must be in [1, vocab)"
        )));
    }
    let seed = ctx.node.attr_opt("seed").and_then(|a| a.as_i64().ok()).unwrap_or(0) as u64;
    Ok((batch, dim, vocab, labels, num_sampled as usize, seed))
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

/// Softmax over one logits row, max-subtracted (the idiom shared with
/// `kernels::nn::softmax_rows`).
fn softmax_row(z: &[f32]) -> Vec<f32> {
    let zmax = z.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = z.iter().map(|&x| (x - zmax).exp()).collect();
    let sum: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / sum).collect()
}

fn sampled_softmax(ctx: &mut KernelContext) -> Result<Vec<Tensor>> {
    let (batch, dim, vocab, labels, num_sampled, seed) = sampled_softmax_geometry(ctx)?;
    let sampled = sampled_ids(vocab, num_sampled, seed, ctx.step.step_id);
    let e = ctx.input(0)?.as_f32()?;
    let w = ctx.input(1)?.as_f32()?;
    let mut loss = ctx.alloc_f32(0, batch);
    for b in 0..batch {
        let lbl = check_row(labels[b], vocab, "SampledSoftmax")?;
        let eb = &e[b * dim..(b + 1) * dim];
        let mut z = Vec::with_capacity(1 + num_sampled);
        z.push(dot(eb, &w[lbl * dim..(lbl + 1) * dim]));
        for &s in &sampled {
            let s = s as usize;
            z.push(dot(eb, &w[s * dim..(s + 1) * dim]));
        }
        // -log softmax(z)[0], max-subtracted for stability.
        let zmax = z.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let sum: f32 = z.iter().map(|&x| (x - zmax).exp()).sum();
        loss.push(sum.ln() - (z[0] - zmax));
    }
    Ok(vec![ctx.make_output(0, vec![batch], TensorData::F32(loss))?])
}

/// Fused gradient: recomputes the step's logits (same negatives via
/// [`sampled_ids`]) and emits (demb dense, dW as indices+values rows) —
/// the weights gradient never materializes [vocab, dim].
fn sampled_softmax_grad(ctx: &mut KernelContext) -> Result<Vec<Tensor>> {
    let (batch, dim, vocab, labels, num_sampled, seed) = sampled_softmax_geometry(ctx)?;
    let sampled = sampled_ids(vocab, num_sampled, seed, ctx.step.step_id);
    let e = ctx.input(0)?.as_f32()?;
    let w = ctx.input(1)?.as_f32()?;
    let g = ctx.input(3)?.as_f32()?;
    if g.len() != batch {
        return Err(Status::invalid_argument(format!(
            "SampledSoftmaxGrad: loss grad has {} elements for batch {batch}",
            g.len()
        )));
    }
    let k = batch + num_sampled;
    let mut demb = vec![0.0f32; batch * dim];
    let mut dw_vals = vec![0.0f32; k * dim];
    let mut dw_idx = Vec::with_capacity(k);
    dw_idx.extend_from_slice(&labels);
    dw_idx.extend_from_slice(&sampled);
    for b in 0..batch {
        let lbl = check_row(labels[b], vocab, "SampledSoftmaxGrad")?;
        let eb = &e[b * dim..(b + 1) * dim];
        let mut z = Vec::with_capacity(1 + num_sampled);
        z.push(dot(eb, &w[lbl * dim..(lbl + 1) * dim]));
        for &s in &sampled {
            let s = s as usize;
            z.push(dot(eb, &w[s * dim..(s + 1) * dim]));
        }
        let p = softmax_row(&z);
        // d loss/d z_0 = p_0 - 1 (the true-label column), d z_j = p_j.
        let dz0 = (p[0] - 1.0) * g[b];
        for j in 0..dim {
            demb[b * dim + j] += dz0 * w[lbl * dim + j];
            dw_vals[b * dim + j] = dz0 * eb[j];
        }
        for (si, &s) in sampled.iter().enumerate() {
            let s = s as usize;
            let dz = p[1 + si] * g[b];
            for j in 0..dim {
                demb[b * dim + j] += dz * w[s * dim + j];
                dw_vals[(batch + si) * dim + j] += dz * eb[j];
            }
        }
    }
    Ok(vec![
        ctx.make_output(0, vec![batch, dim], TensorData::F32(demb))?,
        ctx.make_output(1, vec![k], TensorData::I64(dw_idx))?,
        ctx.make_output(2, vec![k, dim], TensorData::F32(dw_vals))?,
    ])
}

pub(super) fn register(r: &mut KernelRegistry) {
    r.add_sync("UnsortedSegmentSum", unsorted_segment_sum);
    r.add_sync("ScatterAdd", |ctx| scatter_combine(ctx, 1.0, "ScatterAdd"));
    r.add_sync("ScatterSub", |ctx| scatter_combine(ctx, -1.0, "ScatterSub"));
    r.add_sync("DynamicPartition", dynamic_partition);
    r.add_sync("DynamicStitch", dynamic_stitch);
    r.add_sync("RowIds", row_ids);
    r.add_sync("ModShard", mod_shard);
    r.add_sync("SparseToDense", sparse_to_dense);
    r.add_sync("SampledSoftmax", sampled_softmax);
    r.add_sync("SampledSoftmaxGrad", sampled_softmax_grad);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Code;
    use crate::ops::builder::GraphBuilder;
    use crate::session::{Session, SessionOptions};
    use crate::tensor::DType;

    /// Run one op over constant feeds through a real session (exercises
    /// registration, arity, and the kernel together).
    fn run_op(
        op: &str,
        inputs: Vec<Tensor>,
        attrs: Vec<(&str, crate::graph::AttrValue)>,
        fetch_ports: usize,
    ) -> Result<Vec<Tensor>> {
        let mut b = GraphBuilder::new();
        let ins = inputs.into_iter().map(|t| b.constant(t)).collect();
        let id = b.op(op, "probe", ins, attrs)?;
        let name = b.graph.node(id).name.clone();
        let fetches: Vec<String> = (0..fetch_ports).map(|p| format!("{name}:{p}")).collect();
        let refs: Vec<&str> = fetches.iter().map(|s| s.as_str()).collect();
        let sess = Session::new(b.into_graph(), SessionOptions::default());
        sess.run(&[], &refs, &[])
    }

    #[test]
    fn unsorted_segment_sum_accumulates() {
        let data = Tensor::from_f32(vec![4, 2], vec![1., 2., 3., 4., 5., 6., 7., 8.]).unwrap();
        let ids = Tensor::from_i64(vec![4], vec![0, 2, 0, 2]).unwrap();
        let out = run_op("UnsortedSegmentSum", vec![data, ids], vec![("num_segments", 3.into())], 1)
            .unwrap();
        assert_eq!(out[0].shape().dims(), &[3, 2]);
        assert_eq!(out[0].as_f32().unwrap(), &[4., 6., 0., 0., 8., 10.]);
    }

    #[test]
    fn scatter_add_and_sub_are_functional() {
        let x = Tensor::from_f32(vec![3, 2], vec![1., 1., 1., 1., 1., 1.]).unwrap();
        let idx = Tensor::from_i32(vec![2], vec![2, 0]).unwrap();
        let upd = Tensor::from_f32(vec![2, 2], vec![10., 20., 30., 40.]).unwrap();
        let add =
            run_op("ScatterAdd", vec![x.clone(), idx.clone(), upd.clone()], vec![], 1).unwrap();
        assert_eq!(add[0].as_f32().unwrap(), &[31., 41., 1., 1., 11., 21.]);
        let sub = run_op("ScatterSub", vec![x, idx, upd], vec![], 1).unwrap();
        assert_eq!(sub[0].as_f32().unwrap(), &[-29., -39., 1., 1., -9., -19.]);
    }

    #[test]
    fn partition_then_stitch_roundtrips() {
        let data =
            Tensor::from_f32(vec![4, 2], vec![0., 0., 1., 1., 2., 2., 3., 3.]).unwrap();
        let parts = Tensor::from_i64(vec![4], vec![1, 0, 1, 0]).unwrap();
        let pieces = run_op(
            "DynamicPartition",
            vec![data.clone(), parts.clone()],
            vec![("num_partitions", 2.into())],
            2,
        )
        .unwrap();
        assert_eq!(pieces[0].as_f32().unwrap(), &[1., 1., 3., 3.]);
        assert_eq!(pieces[1].as_f32().unwrap(), &[0., 0., 2., 2.]);
        // Partition the row ids the same way, then stitch back.
        let ids = Tensor::from_i64(vec![4], vec![0, 1, 2, 3]).unwrap();
        let id_pieces = run_op(
            "DynamicPartition",
            vec![ids, parts],
            vec![("num_partitions", 2.into())],
            2,
        )
        .unwrap();
        let stitched = run_op(
            "DynamicStitch",
            vec![id_pieces[0].clone(), id_pieces[1].clone(), pieces[0].clone(), pieces[1].clone()],
            vec![("N", 2.into())],
            1,
        )
        .unwrap();
        assert_eq!(stitched[0].shape().dims(), data.shape().dims());
        assert_eq!(stitched[0].as_f32().unwrap(), data.as_f32().unwrap());
    }

    #[test]
    fn row_ids_counts_rows() {
        let x = Tensor::from_f32(vec![3, 2], vec![0.0; 6]).unwrap();
        let out = run_op("RowIds", vec![x], vec![], 1).unwrap();
        assert_eq!(out[0].dtype(), DType::I64);
        assert_eq!(out[0].as_i64().unwrap(), &[0, 1, 2]);
    }

    #[test]
    fn mod_shard_splits_ids() {
        let ids = Tensor::from_i64(vec![4], vec![0, 5, 7, 2]).unwrap();
        let out = run_op("ModShard", vec![ids], vec![("shards", 3.into())], 2).unwrap();
        assert_eq!(out[0].as_i64().unwrap(), &[0, 2, 1, 2]); // id % 3
        assert_eq!(out[1].as_i64().unwrap(), &[0, 1, 2, 0]); // id / 3
        let neg = Tensor::from_i64(vec![1], vec![-4]).unwrap();
        let err = run_op("ModShard", vec![neg], vec![("shards", 3.into())], 2).unwrap_err();
        assert_eq!(err.code, Code::InvalidArgument, "{err:?}");
    }

    #[test]
    fn sparse_to_dense_accumulates_duplicates() {
        let idx = Tensor::from_i64(vec![3], vec![1, 1, 0]).unwrap();
        let vals = Tensor::from_f32(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let like = Tensor::zeros(DType::F32, vec![3, 2]).unwrap();
        let out = run_op("SparseToDense", vec![idx, vals, like], vec![], 1).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[5., 6., 4., 6., 0., 0.]);
    }

    #[test]
    fn hostile_indices_error_not_panic() {
        let data = Tensor::from_f32(vec![2, 2], vec![1.; 4]).unwrap();
        for bad in [
            Tensor::from_i64(vec![2], vec![-1, 0]).unwrap(),
            Tensor::from_i64(vec![2], vec![0, 5]).unwrap(),
            Tensor::from_i64(vec![2], vec![i64::MIN, i64::MAX]).unwrap(),
            Tensor::from_f32(vec![2], vec![0.0, 1.0]).unwrap(),
        ] {
            let err = run_op(
                "UnsortedSegmentSum",
                vec![data.clone(), bad.clone()],
                vec![("num_segments", 2.into())],
                1,
            )
            .unwrap_err();
            assert_eq!(err.code, Code::InvalidArgument, "{err:?}");
            let err = run_op(
                "DynamicPartition",
                vec![data.clone(), bad.clone()],
                vec![("num_partitions", 2.into())],
                2,
            )
            .unwrap_err();
            assert_eq!(err.code, Code::InvalidArgument, "{err:?}");
            let upd = Tensor::from_f32(vec![2, 2], vec![1.; 4]).unwrap();
            let err = run_op("ScatterAdd", vec![data.clone(), bad, upd], vec![], 1).unwrap_err();
            assert_eq!(err.code, Code::InvalidArgument, "{err:?}");
        }
        // Wrong-length segment ids / ragged stitch parts.
        let short = Tensor::from_i64(vec![1], vec![0]).unwrap();
        assert!(run_op(
            "UnsortedSegmentSum",
            vec![data.clone(), short],
            vec![("num_segments", 2.into())],
            1
        )
        .is_err());
        let neg = Tensor::from_i64(vec![2], vec![-3, 0]).unwrap();
        let part = Tensor::from_f32(vec![2, 2], vec![1.; 4]).unwrap();
        let err =
            run_op("DynamicStitch", vec![neg, part], vec![("N", 1.into())], 1).unwrap_err();
        assert_eq!(err.code, Code::InvalidArgument, "{err:?}");
    }

    #[test]
    fn sampled_ids_deterministic_per_step() {
        let a = sampled_ids(1000, 8, 42, 7);
        let b = sampled_ids(1000, 8, 42, 7);
        assert_eq!(a, b);
        assert_ne!(a, sampled_ids(1000, 8, 42, 8), "different steps draw different ids");
        assert!(a.iter().all(|&i| (0..1000).contains(&i)));
    }

    #[test]
    fn sampled_softmax_loss_matches_manual() {
        // 1 example, known weights: check against a hand softmax over
        // [label logit, sampled logits].
        let emb = Tensor::from_f32(vec![1, 2], vec![1.0, 2.0]).unwrap();
        let w =
            Tensor::from_f32(vec![4, 2], vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8]).unwrap();
        let labels = Tensor::from_i64(vec![1], vec![3]).unwrap();
        let out = run_op(
            "SampledSoftmax",
            vec![emb, w.clone(), labels],
            vec![("num_sampled", 2.into()), ("seed", 5.into())],
            1,
        )
        .unwrap();
        // The session assigns some step id; recompute with every possible
        // draw being deterministic is overkill — instead assert shape and
        // that the loss is a positive finite scalar-per-row.
        assert_eq!(out[0].shape().dims(), &[1]);
        let l = out[0].as_f32().unwrap()[0];
        assert!(l.is_finite() && l > 0.0, "loss {l}");
    }
}
