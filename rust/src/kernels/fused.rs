//! The `FusedElementwise` kernel: executes a recorded sequence of unary and
//! binary elementwise operations in a single pass over the data.
//!
//! Nodes of this op are produced at build time by the §5 optimizer's
//! elementwise-chain fusion pass (`passes::fuse`), never by clients. The
//! node's input 0 is the chain's primary operand; inputs 1.. are the
//! external ("extra") operands of the binary steps; the `ops` attr records
//! one step per original node:
//!
//! * `"Tanh"` — unary step: `acc = Tanh(acc)`.
//! * `"Mul,r,2"` — binary step, extra on the *right*: `acc = acc * inputs[2]`.
//! * `"Sub,l,3"` — binary step, extra on the *left*: `acc = inputs[3] - acc`.
//!
//! Fast path (the point of fusion): when the primary operand is `f32` and
//! every extra is `f32` and either scalar, exactly primary-shaped, or
//! row-major-broadcastable *up to* the primary's shape (every
//! right-aligned dim 1 or equal — the bias-row / column-vector patterns),
//! the whole program runs element-at-a-time into one output buffer — zero
//! intermediate tensor allocations, using the *same* scalar functions as
//! the standalone kernels so fused and unfused graphs agree exactly.
//! Broadcast extras read through right-aligned zero strides, so the
//! zero-intermediate property survives broadcasting. Otherwise (other
//! dtypes, rank-raising or output-shape-changing extras) the kernel falls
//! back to applying the steps sequentially through `unary_elementwise` /
//! `binary_elementwise`, which is always correct but allocates one
//! intermediate per step.
//!
//! The fast path is also memory-planned: the output is written in place
//! over the primary when the step plan forwards it
//! (`KernelContext::take_forward_f32`), else into the node's arena slot —
//! fused chains stay zero-intermediate *and* allocation-free.

use super::{Kernel, KernelContext, KernelRegistry};
use crate::error::{Result, Status};
use crate::graph::AttrValue;
use crate::kernels::math;
use crate::kernels::nn;
use crate::tensor::{DType, Shape, Tensor, TensorData};

/// One step of a fused program, parsed from the `ops` attr.
#[derive(Debug, Clone, PartialEq)]
pub struct Step {
    pub op: String,
    /// Binary steps: true = accumulator is the left operand.
    pub acc_left: bool,
    /// Binary steps: index into the node's inputs for the extra operand.
    pub arg: Option<usize>,
}

/// Parse the `ops` attr (`list(string)`) into steps. See module docs for
/// the entry grammar.
pub fn parse_steps(attr: &AttrValue) -> Result<Vec<Step>> {
    let entries = attr.as_list_str()?;
    if entries.is_empty() {
        return Err(Status::invalid_argument("FusedElementwise: empty ops attr"));
    }
    entries
        .iter()
        .map(|entry| {
            let mut parts = entry.split(',');
            let op = parts.next().unwrap_or("").to_string();
            match (parts.next(), parts.next(), parts.next()) {
                (None, ..) => Ok(Step { op, acc_left: true, arg: None }),
                (Some(side), Some(idx), None) => {
                    let acc_left = match side {
                        "r" => true,
                        "l" => false,
                        other => {
                            return Err(Status::invalid_argument(format!(
                                "FusedElementwise: bad side {other:?} in step {entry:?}"
                            )))
                        }
                    };
                    let arg: usize = idx.parse().map_err(|_| {
                        Status::invalid_argument(format!(
                            "FusedElementwise: bad arg index in step {entry:?}"
                        ))
                    })?;
                    if arg == 0 {
                        return Err(Status::invalid_argument(
                            "FusedElementwise: extra operand cannot be input 0 (the primary)",
                        ));
                    }
                    Ok(Step { op, acc_left, arg: Some(arg) })
                }
                _ => Err(Status::invalid_argument(format!(
                    "FusedElementwise: malformed step {entry:?}"
                ))),
            }
        })
        .collect()
}

/// Render steps back into the attr form (used by the fusion pass).
pub fn steps_to_attr(steps: &[Step]) -> AttrValue {
    AttrValue::ListStr(
        steps
            .iter()
            .map(|s| match s.arg {
                None => s.op.clone(),
                Some(k) => format!("{},{},{k}", s.op, if s.acc_left { "r" } else { "l" }),
            })
            .collect(),
    )
}

/// Scalar f32 function for a unary step. ReLU/Sigmoid are the very
/// functions `kernels::nn` maps over tensors; everything else comes from
/// `kernels::math` — shared either way, so fused and unfused agree by
/// construction.
fn scalar_unary(op: &str) -> Result<fn(f32) -> f32> {
    Ok(match op {
        "ReLU" => nn::f32_relu,
        "Sigmoid" => nn::f32_sigmoid,
        _ => math::f32_unary(op)?,
    })
}

/// Apply one unary step to a whole tensor (fallback path).
fn apply_unary(t: &Tensor, op: &str) -> Result<Tensor> {
    match op {
        "ReLU" => nn::relu(t),
        "Sigmoid" => nn::sigmoid(t),
        _ => math::unary_elementwise(t, op),
    }
}

/// How the fast path reads one extra operand.
enum ExtraKind {
    Scalar,
    /// Exactly primary-shaped: index with the output index.
    Same,
    /// A rank-1 extra spanning the primary's last axis (the bias-row
    /// pattern `kernels::nn::bias_add` lowers to): index `ys[i % last]`
    /// directly — no multi-index bookkeeping.
    LastDim(usize),
    /// Right-aligned broadcast up to the primary shape: index through
    /// zero strides on the broadcast dims.
    Strided(Vec<usize>),
}

/// A step with its functions resolved, ready to interpret.
enum Compiled<'a> {
    Unary(fn(f32) -> f32),
    Binary(fn(f32, f32) -> f32, bool, &'a [f32], ExtraKind),
}

/// Does `extra` broadcast *up to exactly* the primary shape under
/// right-aligned row-major rules? (Rank ≤ primary's and every aligned dim
/// 1 or equal — so the output stays primary-shaped, which is what keeps
/// the fast path sound.)
fn broadcastable_to_primary(primary: &Shape, extra: &Shape) -> bool {
    if extra.rank() > primary.rank() {
        return false;
    }
    let offset = primary.rank() - extra.rank();
    extra
        .dims()
        .iter()
        .enumerate()
        .all(|(d, &e)| e == 1 || e == primary.dims()[offset + d])
}

/// Right-aligned strides of `extra` into the primary's index space, with
/// stride 0 on broadcast (size-1 or missing) dims.
fn primary_space_strides(primary: &Shape, extra: &Shape) -> Vec<usize> {
    let strides = extra.strides();
    let offset = primary.rank() - extra.rank();
    let mut out = vec![0usize; primary.rank()];
    for d in 0..extra.rank() {
        out[offset + d] = if extra.dims()[d] == 1 { 0 } else { strides[d] };
    }
    out
}

fn compute(steps: &[Step], ctx: &mut KernelContext) -> Result<Tensor> {
    // Fast path: f32 primary, every extra f32 and either single-element
    // with rank ≤ primary's, primary-shaped, or right-aligned
    // broadcastable up to the primary. The rank bound matters: a [1]
    // extra against a rank-0 primary broadcasts the *output* up to [1]
    // under the standalone kernels, which the primary-shaped fast-path
    // output would silently miss.
    let (fast, primary_shape) = {
        let primary = ctx.input(0)?;
        let shape = primary.shape().clone();
        let fast = primary.dtype() == DType::F32
            && steps.iter().all(|s| match s.arg {
                None => true,
                Some(k) => ctx.inputs.get(k).is_some_and(|t| {
                    t.dtype() == DType::F32
                        && ((t.num_elements() == 1 && t.shape().rank() <= shape.rank())
                            || broadcastable_to_primary(&shape, t.shape()))
                }),
            });
        (fast, shape)
    };
    if fast {
        let n = primary_shape.num_elements();
        // In-place forwarding: the output aliases the primary's storage
        // when the plan marks it dying here and we hold the only ref.
        // (Extras are distinct tensors — a shared endpoint would have
        // refcount ≥ 2 and refuse the steal — so reading them while
        // mutating the primary is sound.)
        let forwarded = ctx.take_forward_f32(0);
        let mut prog: Vec<Compiled> = Vec::with_capacity(steps.len());
        let mut any_strided = false;
        for s in steps {
            match s.arg {
                None => prog.push(Compiled::Unary(scalar_unary(&s.op)?)),
                Some(k) => {
                    let extra = ctx.input(k)?;
                    let kind = if extra.num_elements() == 1 {
                        ExtraKind::Scalar
                    } else if extra.shape() == &primary_shape {
                        ExtraKind::Same
                    } else if extra.shape().rank() == 1
                        && primary_shape.dims().last() == Some(&extra.shape().dims()[0])
                    {
                        // Bias-row pattern: a plain modulo beats the
                        // general strided walk, and — unlike Strided —
                        // needs no per-element multi-index upkeep.
                        ExtraKind::LastDim(extra.shape().dims()[0])
                    } else {
                        any_strided = true;
                        ExtraKind::Strided(primary_space_strides(&primary_shape, extra.shape()))
                    };
                    prog.push(Compiled::Binary(
                        math::f32_binop(&s.op)?,
                        s.acc_left,
                        extra.as_f32()?,
                        kind,
                    ));
                }
            }
        }
        // Multi-index over the primary dims, maintained only when some
        // extra actually needs strided reads.
        let dims = primary_shape.dims().to_vec();
        let run_prog = |i: usize, idx: &[usize], mut acc: f32| -> f32 {
            for step in &prog {
                acc = match step {
                    Compiled::Unary(f) => f(acc),
                    Compiled::Binary(f, acc_left, ys, kind) => {
                        let y = match kind {
                            ExtraKind::Scalar => ys[0],
                            ExtraKind::Same => ys[i],
                            ExtraKind::LastDim(last) => ys[i % last],
                            ExtraKind::Strided(strides) => {
                                let mut off = 0usize;
                                for (d, &s) in strides.iter().enumerate() {
                                    off += idx[d] * s;
                                }
                                ys[off]
                            }
                        };
                        if *acc_left {
                            f(acc, y)
                        } else {
                            f(y, acc)
                        }
                    }
                };
            }
            acc
        };
        let bump = |idx: &mut [usize]| {
            for d in (0..idx.len()).rev() {
                idx[d] += 1;
                if idx[d] < dims[d] {
                    break;
                }
                idx[d] = 0;
            }
        };
        // Row-major multi-index of linear element `i` (each parallel
        // chunk seeds its own counter at its start element, then bumps —
        // so chunked and serial interpretation read identical extras).
        let unravel = |mut i: usize| -> Vec<usize> {
            let mut idx = vec![0usize; dims.len()];
            for d in (0..dims.len()).rev() {
                if dims[d] > 0 {
                    idx[d] = i % dims[d];
                    i /= dims[d];
                }
            }
            idx
        };
        // Whole-program cost per element: the steps plus the strided
        // index bookkeeping when present.
        let cost = prog.len().saturating_mul(2).max(1)
            + if any_strided { dims.len() } else { 0 };
        match forwarded {
            Some(mut fw) => {
                ctx.device.compute.parallel_for_mut(n, cost, &mut fw.vec, |r, xs| {
                    let mut idx = if any_strided { unravel(r.start) } else { Vec::new() };
                    for (j, x) in xs.iter_mut().enumerate() {
                        *x = run_prog(r.start + j, &idx, *x);
                        if any_strided {
                            bump(&mut idx);
                        }
                    }
                });
                drop(prog); // release the borrows of ctx.inputs
                return fw.into_tensor();
            }
            None => {
                let out = {
                    let x = ctx.input(0)?.as_f32()?;
                    if !ctx.device.compute.would_parallelize(n, cost) {
                        // Inline: push-fill, no zeroing pass.
                        let mut out = ctx.alloc_f32(0, n);
                        let mut idx = vec![0usize; dims.len()];
                        for (i, &v) in x.iter().enumerate() {
                            out.push(run_prog(i, &idx, v));
                            if any_strided {
                                bump(&mut idx);
                            }
                        }
                        out
                    } else {
                        let mut out = ctx.alloc_f32_zeroed(0, n);
                        ctx.device.compute.parallel_for_mut(n, cost, &mut out, |r, os| {
                            let mut idx =
                                if any_strided { unravel(r.start) } else { Vec::new() };
                            for (j, o) in os.iter_mut().enumerate() {
                                let i = r.start + j;
                                *o = run_prog(i, &idx, x[i]);
                                if any_strided {
                                    bump(&mut idx);
                                }
                            }
                        });
                        out
                    }
                };
                drop(prog);
                return ctx.make_output(0, primary_shape, TensorData::F32(out));
            }
        }
    }

    // Fallback: sequential application — correct for every dtype/shape the
    // standalone kernels support, at the cost of per-step intermediates.
    let mut acc = ctx.input(0)?.clone();
    for s in steps {
        acc = match s.arg {
            None => apply_unary(&acc, &s.op)?,
            Some(k) => {
                let extra = ctx.input(k)?;
                if s.acc_left {
                    math::binary_elementwise(&acc, extra, &s.op)?
                } else {
                    math::binary_elementwise(extra, &acc, &s.op)?
                }
            }
        };
    }
    Ok(acc)
}

pub(super) fn register(r: &mut KernelRegistry) {
    r.add("FusedElementwise", |node| {
        let steps = parse_steps(node.attr("ops")?)?;
        // Fail at compile time (not step time) on unknown ops.
        for s in &steps {
            match s.arg {
                None => {
                    scalar_unary(&s.op)?;
                }
                Some(_) => {
                    math::f32_binop(&s.op)?;
                }
            }
        }
        Ok(Kernel::Sync(Box::new(move |ctx: &mut KernelContext| {
            Ok(vec![compute(&steps, ctx)?])
        })))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, DeviceSpec};
    use crate::kernels::NodeInfo;
    use crate::rendezvous::{LocalRendezvous, Rendezvous};
    use crate::resources::ResourceMgr;
    use std::collections::BTreeMap;
    use std::sync::Arc;

    fn ctx_with(inputs: Vec<Tensor>) -> KernelContext {
        KernelContext {
            inputs,
            mem: None,
            node: Arc::new(NodeInfo {
                name: "fused".into(),
                op: "FusedElementwise".into(),
                attrs: BTreeMap::new(),
                ref_resource: None,
                container: String::new(),
                device_name: "d".into(),
            }),
            device: Arc::new(Device::new(DeviceSpec::local_cpu(0), 1)),
            resources: ResourceMgr::new(),
            rendezvous: LocalRendezvous::new() as Arc<dyn Rendezvous>,
            step: crate::kernels::StepState::new(0),
        }
    }

    fn t(shape: Vec<usize>, v: Vec<f32>) -> Tensor {
        Tensor::from_f32(shape, v).unwrap()
    }

    #[test]
    fn parse_roundtrip() {
        let attr = AttrValue::ListStr(vec!["Neg".into(), "Mul,r,1".into(), "Sub,l,2".into()]);
        let steps = parse_steps(&attr).unwrap();
        assert_eq!(steps.len(), 3);
        assert_eq!(steps[0], Step { op: "Neg".into(), acc_left: true, arg: None });
        assert_eq!(steps[1], Step { op: "Mul".into(), acc_left: true, arg: Some(1) });
        assert_eq!(steps[2], Step { op: "Sub".into(), acc_left: false, arg: Some(2) });
        assert_eq!(steps_to_attr(&steps), attr);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_steps(&AttrValue::ListStr(vec![])).is_err());
        assert!(parse_steps(&AttrValue::ListStr(vec!["Mul,x,1".into()])).is_err());
        assert!(parse_steps(&AttrValue::ListStr(vec!["Mul,r,zero".into()])).is_err());
        assert!(parse_steps(&AttrValue::ListStr(vec!["Mul,r,0".into()])).is_err());
        assert!(parse_steps(&AttrValue::ListStr(vec!["Mul,r,1,2".into()])).is_err());
    }

    #[test]
    fn fast_path_matches_sequential() {
        // acc = relu((x * 2 - y)) elementwise over [4].
        let steps = vec![
            Step { op: "Mul".into(), acc_left: true, arg: Some(1) },
            Step { op: "Sub".into(), acc_left: true, arg: Some(2) },
            Step { op: "ReLU".into(), acc_left: true, arg: None },
        ];
        let x = t(vec![4], vec![-1.0, 0.5, 2.0, 3.0]);
        let two = Tensor::scalar_f32(2.0);
        let y = t(vec![4], vec![0.0, 2.0, 1.0, -1.0]);
        let mut ctx = ctx_with(vec![x.clone(), two, y.clone()]);
        let out = compute(&steps, &mut ctx).unwrap();
        let xv = x.as_f32().unwrap();
        let yv = y.as_f32().unwrap();
        for i in 0..4 {
            assert_eq!(out.as_f32().unwrap()[i], (xv[i] * 2.0 - yv[i]).max(0.0));
        }
    }

    #[test]
    fn acc_side_respected() {
        // acc = 10 - x (extra on the left).
        let steps = vec![Step { op: "Sub".into(), acc_left: false, arg: Some(1) }];
        let mut ctx = ctx_with(vec![t(vec![2], vec![1.0, 4.0]), Tensor::scalar_f32(10.0)]);
        let out = compute(&steps, &mut ctx).unwrap();
        assert_eq!(out.as_f32().unwrap(), &[9.0, 6.0]);
    }

    #[test]
    fn broadcast_extra_falls_back_correctly() {
        // Extra [2,1] against primary [2]: not primary-shaped → fallback,
        // which must agree with the standalone broadcasting kernel.
        let steps = vec![Step { op: "Add".into(), acc_left: true, arg: Some(1) }];
        let x = t(vec![2], vec![1.0, 2.0]);
        let col = t(vec![2, 1], vec![10.0, 20.0]);
        let mut ctx = ctx_with(vec![x.clone(), col.clone()]);
        let out = compute(&steps, &mut ctx).unwrap();
        let expect = math::binary_elementwise(&x, &col, "Add").unwrap();
        assert_eq!(out.shape(), expect.shape());
        assert_eq!(out.as_f32().unwrap(), expect.as_f32().unwrap());
    }

    #[test]
    fn row_broadcast_extra_takes_fast_path_and_matches() {
        // Extra [3] against primary [2,3] (the bias-add pattern): handled
        // by the strided fast path; must match the standalone kernels.
        let steps = vec![
            Step { op: "Add".into(), acc_left: true, arg: Some(1) },
            Step { op: "Tanh".into(), acc_left: true, arg: None },
        ];
        let x = t(vec![2, 3], vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6]);
        let row = t(vec![3], vec![10.0, 20.0, 30.0]);
        let mut ctx = ctx_with(vec![x.clone(), row.clone()]);
        let out = compute(&steps, &mut ctx).unwrap();
        let expect =
            math::unary_elementwise(&math::binary_elementwise(&x, &row, "Add").unwrap(), "Tanh")
                .unwrap();
        assert_eq!(out.shape(), expect.shape());
        assert_eq!(out.as_f32().unwrap(), expect.as_f32().unwrap());
    }

    #[test]
    fn last_dim_extra_takes_modulo_path_and_matches() {
        // Extra [2] against primary [2,3,2]: the LastDim specialization
        // (plain `i % last` reads, no multi-index upkeep); must match
        // the standalone broadcasting kernel exactly.
        let steps = vec![Step { op: "Add".into(), acc_left: true, arg: Some(1) }];
        let x = t(vec![2, 3, 2], (0..12).map(|i| i as f32 * 0.5).collect());
        let row = t(vec![2], vec![100.0, -100.0]);
        let mut ctx = ctx_with(vec![x.clone(), row.clone()]);
        let out = compute(&steps, &mut ctx).unwrap();
        let expect = math::binary_elementwise(&x, &row, "Add").unwrap();
        assert_eq!(out.shape(), expect.shape());
        assert_eq!(out.as_f32().unwrap(), expect.as_f32().unwrap());
    }

    #[test]
    fn column_broadcast_extra_takes_fast_path_and_matches() {
        // Extra [2,1] against primary [2,3]: same rank, dim-1 broadcast.
        let steps = vec![Step { op: "Mul".into(), acc_left: false, arg: Some(1) }];
        let x = t(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let col = t(vec![2, 1], vec![10.0, 100.0]);
        let mut ctx = ctx_with(vec![x.clone(), col.clone()]);
        let out = compute(&steps, &mut ctx).unwrap();
        let expect = math::binary_elementwise(&col, &x, "Mul").unwrap();
        assert_eq!(out.shape(), expect.shape());
        assert_eq!(out.as_f32().unwrap(), expect.as_f32().unwrap());
    }

    #[test]
    fn output_growing_extra_still_falls_back() {
        // Extra [2,3] against primary [3]: the output outgrows the
        // primary, which the fast path cannot represent — fallback, and
        // the result must match full broadcasting.
        let steps = vec![Step { op: "Add".into(), acc_left: true, arg: Some(1) }];
        let x = t(vec![3], vec![1.0, 2.0, 3.0]);
        let big = t(vec![2, 3], vec![10.0, 20.0, 30.0, 40.0, 50.0, 60.0]);
        let mut ctx = ctx_with(vec![x.clone(), big.clone()]);
        let out = compute(&steps, &mut ctx).unwrap();
        let expect = math::binary_elementwise(&x, &big, "Add").unwrap();
        assert_eq!(out.shape(), expect.shape());
        assert_eq!(out.as_f32().unwrap(), expect.as_f32().unwrap());
    }

    #[test]
    fn rank_raising_scalar_extra_falls_back() {
        // Extra [1] against a rank-0 primary: unfused broadcasting yields
        // shape [1], so the primary-shaped fast path must not engage.
        let steps = vec![Step { op: "Add".into(), acc_left: true, arg: Some(1) }];
        let x = Tensor::scalar_f32(2.0);
        let e = t(vec![1], vec![3.0]);
        let mut ctx = ctx_with(vec![x.clone(), e.clone()]);
        let out = compute(&steps, &mut ctx).unwrap();
        let expect = math::binary_elementwise(&x, &e, "Add").unwrap();
        assert_eq!(out.shape(), expect.shape());
        assert_eq!(out.as_f32().unwrap(), expect.as_f32().unwrap());
    }

    #[test]
    fn non_f32_falls_back() {
        let steps = vec![
            Step { op: "Neg".into(), acc_left: true, arg: None },
            Step { op: "Abs".into(), acc_left: true, arg: None },
        ];
        let x = Tensor::from_i32(vec![3], vec![-1, 2, -3]).unwrap();
        let mut ctx = ctx_with(vec![x]);
        let out = compute(&steps, &mut ctx).unwrap();
        assert_eq!(out.as_i32().unwrap(), &[1, 2, 3]);
    }

    #[test]
    fn unknown_op_rejected_at_kernel_build() {
        let node = NodeInfo {
            name: "fused".into(),
            op: "FusedElementwise".into(),
            attrs: {
                let mut a = BTreeMap::new();
                a.insert("ops".to_string(), AttrValue::ListStr(vec!["NotAnOp".into()]));
                a
            },
            ref_resource: None,
            container: String::new(),
            device_name: "d".into(),
        };
        assert!(crate::kernels::create_kernel(&node, "cpu").is_err());
    }
}
