//! Array operations (Table 1 row 2): Const, Identity, Concat, Slice,
//! Split, Rank, Shape, Size, Reshape, Shuffle, Fill, Gather, Transpose,
//! Pack/Unpack, Tile, ExpandDims, Squeeze, random init ops, Print.

use super::{Kernel, KernelRegistry};
use crate::error::{Result, Status};
use crate::tensor::{DType, Shape, Tensor, TensorData};
use crate::util::rng::Pcg32;
use std::sync::Mutex;

// ---------------------------------------------------------------------------
// pure helpers (reused by gradients and other kernels)
// ---------------------------------------------------------------------------

/// Validate a Concat call; returns (output shape, normalized axis).
/// Split out of [`concat`] so the kernel can size an arena buffer
/// before filling it.
fn concat_shape(xs: &[&Tensor], axis: i64) -> Result<(Shape, usize)> {
    if xs.is_empty() {
        return Err(Status::invalid_argument("Concat of zero tensors"));
    }
    let rank = xs[0].shape().rank();
    let axis = normalize_axis(axis, rank)?;
    let mut out_dims = xs[0].shape().dims().to_vec();
    let mut axis_total = 0;
    for x in xs {
        if x.shape().rank() != rank {
            return Err(Status::invalid_argument("Concat: rank mismatch"));
        }
        for d in 0..rank {
            if d != axis && x.shape().dims()[d] != out_dims[d] {
                return Err(Status::invalid_argument(format!(
                    "Concat: dim {d} mismatch: {} vs {}",
                    x.shape().dims()[d],
                    out_dims[d]
                )));
            }
        }
        axis_total += x.shape().dims()[axis];
    }
    out_dims[axis] = axis_total;
    Ok((Shape(out_dims), axis))
}

/// Push the concatenated f32 data into `out` (empty, capacity
/// pre-sized — possibly an arena checkout).
fn concat_fill_f32(
    out: &mut Vec<f32>,
    xs: &[&Tensor],
    axis: usize,
    out_dims: &[usize],
) -> Result<()> {
    let outer: usize = out_dims[..axis].iter().product::<usize>().max(1);
    let inner: usize = out_dims[axis + 1..].iter().product::<usize>().max(1);
    for o in 0..outer {
        for x in xs {
            let v = x.as_f32()?;
            let ax = x.shape().dims()[axis];
            out.extend_from_slice(&v[o * ax * inner..(o + 1) * ax * inner]);
        }
    }
    Ok(())
}

/// i64 twin of [`concat_fill_f32`], for index tensors (sparse-gradient
/// accumulation concats IndexedSlices index vectors).
fn concat_fill_i64(
    out: &mut Vec<i64>,
    xs: &[&Tensor],
    axis: usize,
    out_dims: &[usize],
) -> Result<()> {
    let outer: usize = out_dims[..axis].iter().product::<usize>().max(1);
    let inner: usize = out_dims[axis + 1..].iter().product::<usize>().max(1);
    for o in 0..outer {
        for x in xs {
            let v = x.as_i64()?;
            let ax = x.shape().dims()[axis];
            out.extend_from_slice(&v[o * ax * inner..(o + 1) * ax * inner]);
        }
    }
    Ok(())
}

/// Concatenate along `axis`. All inputs must agree on other dims.
pub fn concat(xs: &[&Tensor], axis: i64) -> Result<Tensor> {
    let (shape, axis) = concat_shape(xs, axis)?;
    let mut out: Vec<f32> = Vec::with_capacity(shape.num_elements());
    concat_fill_f32(&mut out, xs, axis, shape.dims())?;
    Tensor::new(shape, TensorData::F32(out))
}

/// Validate a Slice call; returns the output shape (with `-1` sizes
/// resolved to "to end").
fn slice_shape(x: &Tensor, begin: &[i64], size: &[i64]) -> Result<Shape> {
    let rank = x.shape().rank();
    if begin.len() != rank || size.len() != rank {
        return Err(Status::invalid_argument("Slice: begin/size must have input rank"));
    }
    let dims = x.shape().dims();
    let mut out_dims = Vec::with_capacity(rank);
    for d in 0..rank {
        let b = begin[d] as usize;
        let s = if size[d] < 0 { dims[d] - b } else { size[d] as usize };
        if b + s > dims[d] {
            return Err(Status::invalid_argument(format!(
                "Slice: begin {b} + size {s} > dim {} at axis {d}",
                dims[d]
            )));
        }
        out_dims.push(s);
    }
    Ok(Shape(out_dims))
}

/// Push the sliced f32 data into `out` (empty, capacity pre-sized).
fn slice_fill_f32(out: &mut Vec<f32>, x: &Tensor, begin: &[i64], out_dims: &[usize]) -> Result<()> {
    let rank = x.shape().rank();
    let v = x.as_f32()?;
    let strides = x.shape().strides();
    let n: usize = out_dims.iter().product();
    let mut idx = vec![0usize; rank];
    for _ in 0..n {
        let mut off = 0;
        for d in 0..rank {
            off += (begin[d] as usize + idx[d]) * strides[d];
        }
        out.push(v[off]);
        for d in (0..rank).rev() {
            idx[d] += 1;
            if idx[d] < out_dims[d] {
                break;
            }
            idx[d] = 0;
        }
    }
    Ok(())
}

/// Slice: out[i] = in[begin + i], sizes from `size` (-1 ⇒ to end).
pub fn slice(x: &Tensor, begin: &[i64], size: &[i64]) -> Result<Tensor> {
    let out_shape = slice_shape(x, begin, size)?;
    let mut out = Vec::with_capacity(out_shape.num_elements());
    slice_fill_f32(&mut out, x, begin, out_shape.dims())?;
    Tensor::new(out_shape, TensorData::F32(out))
}

/// Split into `num` equal parts along `axis`.
pub fn split(x: &Tensor, axis: i64, num: usize) -> Result<Vec<Tensor>> {
    let rank = x.shape().rank();
    let axis_u = normalize_axis(axis, rank)?;
    let dims = x.shape().dims();
    if dims[axis_u] % num != 0 {
        return Err(Status::invalid_argument(format!(
            "Split: dim {} not divisible by {num}",
            dims[axis_u]
        )));
    }
    let part = dims[axis_u] / num;
    let mut outs = Vec::with_capacity(num);
    for i in 0..num {
        let mut begin = vec![0i64; rank];
        let mut size: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        begin[axis_u] = (i * part) as i64;
        size[axis_u] = part as i64;
        outs.push(slice(x, &begin, &size)?);
    }
    Ok(outs)
}

/// Validate a Transpose call; returns (output shape, normalized perm —
/// empty input perm ⇒ reversed dims).
fn transpose_shape(x: &Tensor, perm: &[i64]) -> Result<(Shape, Vec<usize>)> {
    let rank = x.shape().rank();
    let perm: Vec<usize> = if perm.is_empty() {
        (0..rank).rev().collect()
    } else {
        if perm.len() != rank {
            return Err(Status::invalid_argument("Transpose: perm length != rank"));
        }
        perm.iter().map(|&p| p as usize).collect()
    };
    let dims = x.shape().dims();
    let out_dims: Vec<usize> = perm.iter().map(|&p| dims[p]).collect();
    Ok((Shape(out_dims), perm))
}

/// Push the transposed f32 data into `out` (empty, capacity pre-sized).
fn transpose_fill_f32(
    out: &mut Vec<f32>,
    x: &Tensor,
    perm: &[usize],
    out_dims: &[usize],
) -> Result<()> {
    let rank = x.shape().rank();
    let in_strides = x.shape().strides();
    let v = x.as_f32()?;
    let mut idx = vec![0usize; rank];
    for _ in 0..v.len() {
        let mut off = 0;
        for d in 0..rank {
            off += idx[d] * in_strides[perm[d]];
        }
        out.push(v[off]);
        for d in (0..rank).rev() {
            idx[d] += 1;
            if idx[d] < out_dims[d] {
                break;
            }
            idx[d] = 0;
        }
    }
    Ok(())
}

/// Transpose by permutation (empty perm ⇒ reverse dims).
pub fn transpose(x: &Tensor, perm: &[i64]) -> Result<Tensor> {
    let (out_shape, perm) = transpose_shape(x, perm)?;
    let mut out = Vec::with_capacity(out_shape.num_elements());
    transpose_fill_f32(&mut out, x, &perm, out_shape.dims())?;
    Tensor::new(out_shape, TensorData::F32(out))
}

/// Validate a Gather call; returns (output shape, row length). Indices
/// may be int32 or int64; any other dtype is `InvalidArgument`.
fn gather_shape(params: &Tensor, indices: &Tensor) -> Result<(Shape, usize)> {
    let dims = params.shape().dims();
    if dims.is_empty() {
        return Err(Status::invalid_argument("Gather: params must have rank >= 1"));
    }
    if !matches!(indices.dtype(), DType::I32 | DType::I64) {
        return Err(Status::invalid_argument(format!(
            "Gather: indices must be int32 or int64, got {}",
            indices.dtype()
        )));
    }
    let row: usize = dims[1..].iter().product::<usize>().max(1);
    let mut out_dims = indices.shape().dims().to_vec();
    out_dims.extend_from_slice(&dims[1..]);
    Ok((Shape(out_dims), row))
}

/// Push the gathered f32 rows into `out` (empty, capacity pre-sized).
/// Negative and too-large indices are both `InvalidArgument`.
fn gather_fill_f32(
    out: &mut Vec<f32>,
    params: &Tensor,
    indices: &Tensor,
    row: usize,
) -> Result<()> {
    let v = params.as_f32()?;
    let rows = params.shape().dims()[0];
    let mut push = |i: i64| -> Result<()> {
        if i < 0 || i as usize >= rows {
            return Err(Status::invalid_argument(format!(
                "Gather: index {i} out of range [0, {rows})"
            )));
        }
        let i = i as usize;
        out.extend_from_slice(&v[i * row..(i + 1) * row]);
        Ok(())
    };
    match indices.data() {
        TensorData::I64(idx) => idx.iter().try_for_each(|&i| push(i)),
        TensorData::I32(idx) => idx.iter().try_for_each(|&i| push(i as i64)),
        d => Err(Status::invalid_argument(format!(
            "Gather: indices must be int32 or int64, got {}",
            d.dtype()
        ))),
    }
}

/// Gather rows: out[i, …] = params[indices[i], …]. Indices may be int32
/// or int64.
pub fn gather(params: &Tensor, indices: &Tensor) -> Result<Tensor> {
    let (shape, row) = gather_shape(params, indices)?;
    let mut out = Vec::with_capacity(shape.num_elements());
    gather_fill_f32(&mut out, params, indices, row)?;
    Tensor::new(shape, TensorData::F32(out))
}

/// Tile by per-axis multiples.
pub fn tile(x: &Tensor, multiples: &[i64]) -> Result<Tensor> {
    let rank = x.shape().rank();
    if multiples.len() != rank {
        return Err(Status::invalid_argument("Tile: multiples length != rank"));
    }
    let dims = x.shape().dims();
    let out_dims: Vec<usize> =
        dims.iter().zip(multiples).map(|(&d, &m)| d * m as usize).collect();
    let out_shape = Shape(out_dims.clone());
    let v = x.as_f32()?;
    let strides = x.shape().strides();
    let mut out = Vec::with_capacity(out_shape.num_elements());
    let mut idx = vec![0usize; rank];
    for _ in 0..out_shape.num_elements() {
        let mut off = 0;
        for d in 0..rank {
            off += (idx[d] % dims[d]) * strides[d];
        }
        out.push(v[off]);
        for d in (0..rank).rev() {
            idx[d] += 1;
            if idx[d] < out_dims[d] {
                break;
            }
            idx[d] = 0;
        }
    }
    Tensor::new(out_shape, TensorData::F32(out))
}

/// Stack along a new axis.
pub fn pack(xs: &[&Tensor], axis: i64) -> Result<Tensor> {
    if xs.is_empty() {
        return Err(Status::invalid_argument("Pack of zero tensors"));
    }
    let base = xs[0].shape().clone();
    for x in xs {
        if x.shape() != &base {
            return Err(Status::invalid_argument("Pack: shape mismatch"));
        }
    }
    let rank = base.rank() + 1;
    let axis = normalize_axis(axis, rank)?;
    // Reshape each to have a 1-dim at `axis`, then concat.
    let mut with_axis = base.dims().to_vec();
    with_axis.insert(axis, 1);
    let reshaped: Vec<Tensor> =
        xs.iter().map(|x| x.reshape(with_axis.clone())).collect::<Result<_>>()?;
    let refs: Vec<&Tensor> = reshaped.iter().collect();
    concat(&refs, axis as i64)
}

fn normalize_axis(axis: i64, rank: usize) -> Result<usize> {
    let a = if axis < 0 { axis + rank as i64 } else { axis };
    if a < 0 || a as usize >= rank.max(1) {
        return Err(Status::invalid_argument(format!("axis {axis} out of range for rank {rank}")));
    }
    Ok(a as usize)
}

/// Broadcast `x` up to `shape`.
pub fn broadcast_to(x: &Tensor, shape: &Shape) -> Result<Tensor> {
    let ones = Tensor::fill_f32(shape.clone(), 0.0);
    crate::kernels::math::binary_elementwise(x, &ones, "Add")
}

/// Sum `grad` down to `target` shape (inverse of broadcasting): sum over
/// leading extra dims and over dims where target has size 1.
pub fn sum_to_shape(grad: &Tensor, target: &Shape) -> Result<Tensor> {
    if grad.shape() == target {
        return Ok(grad.clone());
    }
    let grank = grad.shape().rank();
    let trank = target.rank();
    if trank > grank {
        return Err(Status::invalid_argument(format!(
            "SumToShape: target {target} has higher rank than grad {}",
            grad.shape()
        )));
    }
    // Axes to reduce: leading extra dims + dims where target is 1.
    let mut axes: Vec<i64> = (0..grank - trank).map(|d| d as i64).collect();
    for d in 0..trank {
        if target.dims()[d] == 1 && grad.shape().dims()[grank - trank + d] != 1 {
            axes.push((grank - trank + d) as i64);
        }
    }
    let reduced = crate::kernels::math::reduce(grad, "Sum", Some(&axes))?;
    reduced.reshape(target.clone())
}

// ---------------------------------------------------------------------------
// registration
// ---------------------------------------------------------------------------

pub(super) fn register(r: &mut KernelRegistry) {
    // Const precomputes its value at kernel-build time.
    r.add("Const", |node| {
        let value = node.attr("value")?.as_tensor()?.clone();
        Ok(Kernel::Sync(Box::new(move |_ctx| Ok(vec![value.clone()]))))
    });
    r.add_sync("Identity", |ctx| Ok(vec![ctx.input(0)?.clone()]));
    r.add_sync("StopGradient", |ctx| Ok(vec![ctx.input(0)?.clone()]));
    // Placeholder must always be fed; reaching its kernel means it wasn't.
    r.add("Placeholder", |node| {
        let name = node.name.clone();
        Ok(Kernel::Sync(Box::new(move |_ctx| {
            Err(Status::invalid_argument(format!(
                "placeholder {name:?} was not fed (pass it in Run's inputs)"
            )))
        })))
    });
    r.add_sync("Rank", |ctx| {
        Ok(vec![Tensor::scalar_i32(ctx.input(0)?.shape().rank() as i32)])
    });
    r.add_sync("Shape", |ctx| {
        let dims: Vec<i64> = ctx.input(0)?.shape().dims().iter().map(|&d| d as i64).collect();
        Ok(vec![Tensor::from_i64(vec![dims.len()], dims)?])
    });
    r.add_sync("Size", |ctx| {
        Ok(vec![Tensor::scalar_i64(ctx.input(0)?.num_elements() as i64)])
    });
    r.add_sync("Reshape", |ctx| {
        let shape_t = ctx.input(1)?;
        let dims_i = shape_t.as_i64()?;
        let in_n = ctx.input(0)?.num_elements();
        // One dim may be -1 (inferred).
        let known: i64 = dims_i.iter().filter(|&&d| d >= 0).product();
        let dims: Vec<usize> = dims_i
            .iter()
            .map(|&d| if d < 0 { in_n / known.max(1) as usize } else { d as usize })
            .collect();
        Ok(vec![ctx.input(0)?.reshape(dims)?])
    });
    // Concat/Slice/Transpose route their outputs through the step arena
    // (`alloc_f32`/`make_output`): validate + size first, check the
    // output storage out of the node's planned slot (fresh Vec when the
    // plan gave it none), fill, and wrap with the slot's recycler so the
    // buffer returns to its pool on last drop.
    r.add_sync("Concat", |ctx| {
        let axis = ctx.node.attr("axis")?.as_i64()?;
        let refs: Vec<&Tensor> = ctx.inputs.iter().collect();
        let (shape, axis) = concat_shape(&refs, axis)?;
        // Dtype dispatch on the first input: f32 data or i64 indices
        // (sparse-gradient accumulation concats index vectors).
        if refs[0].dtype() == DType::I64 {
            let mut out = ctx.alloc_i64(0, shape.num_elements());
            concat_fill_i64(&mut out, &refs, axis, shape.dims())?;
            return Ok(vec![ctx.make_output(0, shape, TensorData::I64(out))?]);
        }
        let mut out = ctx.alloc_f32(0, shape.num_elements());
        concat_fill_f32(&mut out, &refs, axis, shape.dims())?;
        Ok(vec![ctx.make_output(0, shape, TensorData::F32(out))?])
    });
    r.add_sync("Slice", |ctx| {
        let begin = ctx.node.attr("begin")?.as_list_i64()?.to_vec();
        let size = ctx.node.attr("size")?.as_list_i64()?.to_vec();
        let x = ctx.input(0)?;
        let shape = slice_shape(x, &begin, &size)?;
        let mut out = ctx.alloc_f32(0, shape.num_elements());
        slice_fill_f32(&mut out, x, &begin, shape.dims())?;
        Ok(vec![ctx.make_output(0, shape, TensorData::F32(out))?])
    });
    r.add_sync("Split", |ctx| {
        let axis = ctx.node.attr("axis")?.as_i64()?;
        let num = ctx.node.attr("num_split")?.as_i64()? as usize;
        split(ctx.input(0)?, axis, num)
    });
    r.add_sync("Transpose", |ctx| {
        let perm = ctx
            .node
            .attr_opt("perm")
            .map(|a| a.as_list_i64().map(|s| s.to_vec()))
            .transpose()?
            .unwrap_or_default();
        let x = ctx.input(0)?;
        let (shape, perm) = transpose_shape(x, &perm)?;
        let mut out = ctx.alloc_f32(0, shape.num_elements());
        transpose_fill_f32(&mut out, x, &perm, shape.dims())?;
        Ok(vec![ctx.make_output(0, shape, TensorData::F32(out))?])
    });
    // Gather routes through the step arena like Concat: validate + size,
    // check out the planned slot, fill, wrap with the slot's recycler.
    r.add_sync("Gather", |ctx| {
        let params = ctx.input(0)?;
        let indices = ctx.input(1)?;
        let (shape, row) = gather_shape(params, indices)?;
        let mut out = ctx.alloc_f32(0, shape.num_elements());
        gather_fill_f32(&mut out, params, indices, row)?;
        Ok(vec![ctx.make_output(0, shape, TensorData::F32(out))?])
    });
    r.add_sync("Tile", |ctx| {
        let m = ctx.node.attr("multiples")?.as_list_i64()?.to_vec();
        Ok(vec![tile(ctx.input(0)?, &m)?])
    });
    r.add_sync("Pack", |ctx| {
        let axis = ctx.node.attr_opt("axis").map(|a| a.as_i64()).transpose()?.unwrap_or(0);
        let refs: Vec<&Tensor> = ctx.inputs.iter().collect();
        Ok(vec![pack(&refs, axis)?])
    });
    r.add_sync("Unpack", |ctx| {
        let n = ctx.node.attr("N")?.as_i64()? as usize;
        let parts = split(ctx.input(0)?, 0, n)?;
        // Drop the leading 1-dim of each part.
        parts
            .into_iter()
            .map(|p| {
                let dims = p.shape().dims()[1..].to_vec();
                p.reshape(dims)
            })
            .collect()
    });
    r.add_sync("ExpandDims", |ctx| {
        let axis = ctx.node.attr("axis")?.as_i64()?;
        let x = ctx.input(0)?;
        let mut dims = x.shape().dims().to_vec();
        let a = if axis < 0 { (axis + 1 + dims.len() as i64) as usize } else { axis as usize };
        dims.insert(a.min(dims.len()), 1);
        Ok(vec![x.reshape(dims)?])
    });
    r.add_sync("Squeeze", |ctx| {
        let x = ctx.input(0)?;
        let dims: Vec<usize> = x.shape().dims().iter().copied().filter(|&d| d != 1).collect();
        Ok(vec![x.reshape(dims)?])
    });
    r.add_sync("ZerosLike", |ctx| {
        let x = ctx.input(0)?;
        Ok(vec![Tensor::zeros(x.dtype(), x.shape().clone())?])
    });
    r.add_sync("OnesLike", |ctx| {
        let x = ctx.input(0)?;
        let n = x.num_elements();
        Ok(vec![match x.dtype() {
            DType::F32 => Tensor::from_f32(x.shape().clone(), vec![1.0; n])?,
            DType::F64 => Tensor::from_f64(x.shape().clone(), vec![1.0; n])?,
            DType::I32 => Tensor::from_i32(x.shape().clone(), vec![1; n])?,
            DType::I64 => Tensor::from_i64(x.shape().clone(), vec![1; n])?,
            d => return Err(Status::unimplemented(format!("OnesLike for {d}"))),
        }])
    });
    r.add_sync("Fill", |ctx| {
        let dims: Vec<usize> = ctx.input(0)?.as_i64()?.iter().map(|&d| d as usize).collect();
        let v = ctx.input(1)?.scalar_value_f32()?;
        Ok(vec![Tensor::fill_f32(dims, v)])
    });
    // Gradient helpers (§4.1): shapes are runtime values here.
    r.add_sync("SumToShape", |ctx| {
        // Reduce `grad` (input 0) down to the shape of `like` (input 1) by
        // summing over broadcast dimensions — the reverse of numpy
        // broadcasting.
        let grad = ctx.input(0)?;
        let like = ctx.input(1)?;
        Ok(vec![sum_to_shape(grad, like.shape())?])
    });
    r.add_sync("BroadcastLike", |ctx| {
        let x = ctx.input(0)?;
        let like = ctx.input(1)?;
        Ok(vec![broadcast_to(x, like.shape())?])
    });
    r.add_sync("ReshapeLike", |ctx| {
        let x = ctx.input(0)?;
        let like = ctx.input(1)?;
        Ok(vec![x.reshape(like.shape().clone())?])
    });
    r.add_sync("BroadcastTo", |ctx| {
        let shape = ctx.node.attr("shape")?.as_shape()?.clone();
        Ok(vec![broadcast_to(ctx.input(0)?, &shape)?])
    });
    // Shuffle: random permutation of rows (axis 0), seeded per node.
    r.add("Shuffle", |node| {
        let seed = node.attr_opt("seed").and_then(|a| a.as_i64().ok()).unwrap_or(0) as u64;
        // Perturb the seed so a Shuffle with seed=0 is uncorrelated with a
        // RandomUniform with seed=0.
        let rng = Mutex::new(Pcg32::new(seed ^ 0x9E37_79B9));
        Ok(Kernel::Sync(Box::new(move |ctx| {
            let x = ctx.input(0)?;
            let dims = x.shape().dims();
            if dims.is_empty() {
                return Ok(vec![x.clone()]);
            }
            let rows = dims[0];
            let row: usize = dims[1..].iter().product::<usize>().max(1);
            let v = x.as_f32()?;
            let mut order: Vec<usize> = (0..rows).collect();
            rng.lock().unwrap().shuffle(&mut order);
            let mut out = Vec::with_capacity(v.len());
            for r_i in order {
                out.extend_from_slice(&v[r_i * row..(r_i + 1) * row]);
            }
            Ok(vec![Tensor::new(x.shape().clone(), TensorData::F32(out))?])
        })))
    });
    r.add("RandomUniform", |node| {
        let shape = node.attr("shape")?.as_shape()?.clone();
        let lo = node.attr_opt("lo").and_then(|a| a.as_f32().ok()).unwrap_or(0.0);
        let hi = node.attr_opt("hi").and_then(|a| a.as_f32().ok()).unwrap_or(1.0);
        let seed = node.attr_opt("seed").and_then(|a| a.as_i64().ok()).unwrap_or(0) as u64;
        let rng = Mutex::new(Pcg32::new(seed));
        Ok(Kernel::Sync(Box::new(move |_ctx| {
            let mut rng = rng.lock().unwrap();
            let v: Vec<f32> = (0..shape.num_elements()).map(|_| rng.uniform(lo, hi)).collect();
            Ok(vec![Tensor::from_f32(shape.clone(), v)?])
        })))
    });
    r.add("RandomStandardNormal", |node| {
        let shape = node.attr("shape")?.as_shape()?.clone();
        let seed = node.attr_opt("seed").and_then(|a| a.as_i64().ok()).unwrap_or(0) as u64;
        let rng = Mutex::new(Pcg32::new(seed));
        Ok(Kernel::Sync(Box::new(move |_ctx| {
            let mut rng = rng.lock().unwrap();
            let v: Vec<f32> = (0..shape.num_elements()).map(|_| rng.normal()).collect();
            Ok(vec![Tensor::from_f32(shape.clone(), v)?])
        })))
    });
    r.add_sync("Print", |ctx| {
        let t = ctx.input(0)?;
        let preview: String = match t.as_f32() {
            Ok(v) => format!("{:?}", &v[..v.len().min(8)]),
            Err(_) => format!("{t}"),
        };
        eprintln!("[rustflow Print {}] {t} {preview}", ctx.node.name);
        Ok(vec![t.clone()])
    });
    // LoopCond is a plain identity over a bool (§4.4 marker op).
    r.add_sync("LoopCond", |ctx| Ok(vec![ctx.input(0)?.clone()]));
    r.add_sync("NoOp", |_ctx| Ok(vec![]));
    r.add_sync("ControlTrigger", |_ctx| Ok(vec![]));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: Vec<usize>, v: Vec<f32>) -> Tensor {
        Tensor::from_f32(shape, v).unwrap()
    }

    #[test]
    fn concat_axis0_and_1() {
        let a = t(vec![1, 2], vec![1., 2.]);
        let b = t(vec![1, 2], vec![3., 4.]);
        let c0 = concat(&[&a, &b], 0).unwrap();
        assert_eq!(c0.shape().dims(), &[2, 2]);
        assert_eq!(c0.as_f32().unwrap(), &[1., 2., 3., 4.]);
        let c1 = concat(&[&a, &b], 1).unwrap();
        assert_eq!(c1.shape().dims(), &[1, 4]);
        assert_eq!(c1.as_f32().unwrap(), &[1., 2., 3., 4.]);
    }

    #[test]
    fn concat_mismatch_rejected() {
        let a = t(vec![1, 2], vec![1., 2.]);
        let b = t(vec![1, 3], vec![3., 4., 5.]);
        assert!(concat(&[&a, &b], 0).is_err());
    }

    #[test]
    fn slice_basic() {
        let x = t(vec![3, 3], (0..9).map(|i| i as f32).collect());
        let s = slice(&x, &[1, 0], &[2, 2]).unwrap();
        assert_eq!(s.shape().dims(), &[2, 2]);
        assert_eq!(s.as_f32().unwrap(), &[3., 4., 6., 7.]);
        // -1 size = to end
        let s2 = slice(&x, &[0, 1], &[-1, -1]).unwrap();
        assert_eq!(s2.shape().dims(), &[3, 2]);
    }

    #[test]
    fn slice_out_of_bounds() {
        let x = t(vec![2, 2], vec![0.; 4]);
        assert!(slice(&x, &[1, 0], &[2, 2]).is_err());
    }

    #[test]
    fn split_even() {
        let x = t(vec![4, 2], (0..8).map(|i| i as f32).collect());
        let parts = split(&x, 0, 2).unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].as_f32().unwrap(), &[0., 1., 2., 3.]);
        assert_eq!(parts[1].as_f32().unwrap(), &[4., 5., 6., 7.]);
        assert!(split(&x, 0, 3).is_err());
    }

    #[test]
    fn split_then_concat_roundtrip() {
        let x = t(vec![2, 6], (0..12).map(|i| i as f32).collect());
        let parts = split(&x, 1, 3).unwrap();
        let refs: Vec<&Tensor> = parts.iter().collect();
        let back = concat(&refs, 1).unwrap();
        assert_eq!(back.as_f32().unwrap(), x.as_f32().unwrap());
    }

    #[test]
    fn transpose_2d() {
        let x = t(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let y = transpose(&x, &[1, 0]).unwrap();
        assert_eq!(y.shape().dims(), &[3, 2]);
        assert_eq!(y.as_f32().unwrap(), &[1., 4., 2., 5., 3., 6.]);
        // default perm = reverse
        let z = transpose(&x, &[]).unwrap();
        assert_eq!(z.as_f32().unwrap(), y.as_f32().unwrap());
    }

    #[test]
    fn transpose_3d() {
        let x = t(vec![2, 1, 3], vec![1., 2., 3., 4., 5., 6.]);
        let y = transpose(&x, &[2, 1, 0]).unwrap();
        assert_eq!(y.shape().dims(), &[3, 1, 2]);
        assert_eq!(y.as_f32().unwrap(), &[1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn gather_rows() {
        let p = t(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let i = Tensor::from_i64(vec![2], vec![2, 0]).unwrap();
        let g = gather(&p, &i).unwrap();
        assert_eq!(g.shape().dims(), &[2, 2]);
        assert_eq!(g.as_f32().unwrap(), &[5., 6., 1., 2.]);
        let bad = Tensor::from_i64(vec![1], vec![9]).unwrap();
        assert!(gather(&p, &bad).is_err());
    }

    #[test]
    fn gather_accepts_i32_indices() {
        let p = t(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let i32s = Tensor::from_i32(vec![2], vec![2, 0]).unwrap();
        let i64s = Tensor::from_i64(vec![2], vec![2, 0]).unwrap();
        let a = gather(&p, &i32s).unwrap();
        let b = gather(&p, &i64s).unwrap();
        assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
    }

    #[test]
    fn gather_hostile_indices_error_not_panic() {
        use crate::error::Code;
        let p = t(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]);
        // Negative, out-of-bounds (both dtypes), i64::MIN (usize-cast trap),
        // and wrong-dtype indices must all fail with InvalidArgument.
        let hostile = [
            Tensor::from_i64(vec![1], vec![-1]).unwrap(),
            Tensor::from_i64(vec![1], vec![3]).unwrap(),
            Tensor::from_i64(vec![1], vec![i64::MIN]).unwrap(),
            Tensor::from_i64(vec![1], vec![i64::MAX]).unwrap(),
            Tensor::from_i32(vec![1], vec![-7]).unwrap(),
            Tensor::from_i32(vec![2], vec![0, 100]).unwrap(),
        ];
        for bad in &hostile {
            let err = gather(&p, bad).unwrap_err();
            assert_eq!(err.code, Code::InvalidArgument, "{err:?}");
        }
        let fp = Tensor::from_f32(vec![1], vec![0.0]).unwrap();
        assert_eq!(gather(&p, &fp).unwrap_err().code, Code::InvalidArgument);
        // Scalar params have no rows to gather.
        assert!(gather(&Tensor::scalar_f32(1.0), &hostile[1]).is_err());
    }

    #[test]
    fn tile_2d() {
        let x = t(vec![1, 2], vec![1., 2.]);
        let y = tile(&x, &[2, 2]).unwrap();
        assert_eq!(y.shape().dims(), &[2, 4]);
        assert_eq!(y.as_f32().unwrap(), &[1., 2., 1., 2., 1., 2., 1., 2.]);
    }

    #[test]
    fn pack_stacks() {
        let a = t(vec![2], vec![1., 2.]);
        let b = t(vec![2], vec![3., 4.]);
        let p = pack(&[&a, &b], 0).unwrap();
        assert_eq!(p.shape().dims(), &[2, 2]);
        assert_eq!(p.as_f32().unwrap(), &[1., 2., 3., 4.]);
        let p1 = pack(&[&a, &b], 1).unwrap();
        assert_eq!(p1.shape().dims(), &[2, 2]);
        assert_eq!(p1.as_f32().unwrap(), &[1., 3., 2., 4.]);
    }

    #[test]
    fn broadcast_to_shape() {
        let x = t(vec![1, 3], vec![1., 2., 3.]);
        let y = broadcast_to(&x, &Shape(vec![2, 3])).unwrap();
        assert_eq!(y.as_f32().unwrap(), &[1., 2., 3., 1., 2., 3.]);
    }
}
