//! Queue op kernels (§4.6, Table 1 row 7). The queue *resource* lives in
//! `crate::queue`; these kernels wire it into the graph. Enqueue and
//! Dequeue are asynchronous kernels (§5.3): when the queue is full/empty
//! they park a continuation instead of blocking an executor thread.

use super::{DoneFn, Kernel, KernelContext, KernelRegistry};
use crate::error::{Result, Status};
use crate::queue::QueueImpl;
use crate::tensor::Tensor;

/// Build the (capacity, component count, seed…) from queue-node attrs and
/// get-or-create the resource. Queue resource key = queue node name.
fn queue_from_node(ctx: &KernelContext, queue_node: &str) -> Result<crate::queue::QueueRef> {
    ctx.container().lookup_queue(queue_node)
}

pub(super) fn register(r: &mut KernelRegistry) {
    // The queue ops output a string handle naming the resource; running
    // them creates the queue in the node's container.
    r.add("FIFOQueue", |node| {
        let name = node.name.clone();
        let capacity =
            node.attr_opt("capacity").and_then(|a| a.as_i64().ok()).unwrap_or(32) as usize;
        let components = node
            .attr_opt("component_types")
            .and_then(|a| a.as_list_type().ok().map(|l| l.len()))
            .unwrap_or(1);
        Ok(Kernel::Sync(Box::new(move |ctx: &mut KernelContext| {
            let name = name.clone();
            ctx.container().queue_or_create(&name, || QueueImpl::fifo(capacity, components));
            Ok(vec![Tensor::scalar_str(name)])
        })))
    });

    r.add("RandomShuffleQueue", |node| {
        let name = node.name.clone();
        let capacity =
            node.attr_opt("capacity").and_then(|a| a.as_i64().ok()).unwrap_or(1024) as usize;
        let components = node
            .attr_opt("component_types")
            .and_then(|a| a.as_list_type().ok().map(|l| l.len()))
            .unwrap_or(1);
        let min_after =
            node.attr_opt("min_after_dequeue").and_then(|a| a.as_i64().ok()).unwrap_or(0) as usize;
        let seed = node.attr_opt("seed").and_then(|a| a.as_i64().ok()).unwrap_or(0) as u64;
        Ok(Kernel::Sync(Box::new(move |ctx: &mut KernelContext| {
            let name = name.clone();
            ctx.container()
                .queue_or_create(&name, || QueueImpl::shuffle(capacity, components, min_after, seed));
            Ok(vec![Tensor::scalar_str(name)])
        })))
    });

    // Enqueue(queue_ref, components...) — async.
    r.add("Enqueue", |node| {
        let queue_node = node.ref_resource()?.to_string();
        Ok(Kernel::Async(Box::new(move |ctx: KernelContext, done: DoneFn| {
            let q = match queue_from_node(&ctx, &queue_node) {
                Ok(q) => q,
                Err(e) => return done(Err(e)),
            };
            let element: Vec<Tensor> = ctx.inputs[1..].to_vec();
            q.enqueue_async(element, Box::new(move |res| done(res.map(|_| vec![]))));
        })))
    });

    // Dequeue(queue_ref) -> components — async.
    r.add("Dequeue", |node| {
        let queue_node = node.ref_resource()?.to_string();
        Ok(Kernel::Async(Box::new(move |ctx: KernelContext, done: DoneFn| {
            let q = match queue_from_node(&ctx, &queue_node) {
                Ok(q) => q,
                Err(e) => return done(Err(e)),
            };
            q.dequeue_async(Box::new(move |res| done(res)));
        })))
    });

    r.add("QueueClose", |node| {
        let queue_node = node.ref_resource()?.to_string();
        let cancel = node
            .attr_opt("cancel_pending_enqueues")
            .and_then(|a| a.as_bool().ok())
            .unwrap_or(false);
        Ok(Kernel::Sync(Box::new(move |ctx: &mut KernelContext| {
            queue_from_node(ctx, &queue_node)?.close(cancel);
            Ok(vec![])
        })))
    });

    r.add("QueueSize", |node| {
        let queue_node = node.ref_resource()?.to_string();
        Ok(Kernel::Sync(Box::new(move |ctx: &mut KernelContext| {
            let q = queue_from_node(ctx, &queue_node)?;
            Ok(vec![Tensor::scalar_i32(q.size() as i32)])
        })))
    });
}

#[allow(dead_code)]
fn _state_check(_: &Status) {}
