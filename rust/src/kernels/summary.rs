//! Summary op kernels (§9.1): Scalar/Histogram summaries encode a small
//! JSON record into a string tensor; `MergeSummary` concatenates records.
//! The client writes fetched summary tensors to an event log that the
//! TensorBoard-analog (`crate::summary`) renders.

use super::{KernelContext, KernelRegistry};
use crate::tensor::{Shape, Tensor, TensorData};
use crate::util::json::Json;

pub(super) fn register(r: &mut KernelRegistry) {
    r.add("ScalarSummary", |node| {
        let tag = node
            .attr_opt("tag")
            .and_then(|a| a.as_str().ok().map(String::from))
            .unwrap_or_else(|| node.name.clone());
        Ok(super::Kernel::Sync(Box::new(move |ctx: &mut KernelContext| {
            let v = ctx.input(0)?.cast(crate::tensor::DType::F32)?.scalar_value_f32()?;
            let j = Json::obj().set("type", "scalar").set("tag", tag.clone()).set("value", v);
            Ok(vec![Tensor::scalar_str(j.render())])
        })))
    });

    r.add("HistogramSummary", |node| {
        let tag = node
            .attr_opt("tag")
            .and_then(|a| a.as_str().ok().map(String::from))
            .unwrap_or_else(|| node.name.clone());
        Ok(super::Kernel::Sync(Box::new(move |ctx: &mut KernelContext| {
            let v = ctx.input(0)?.as_f32()?;
            let (min, max, sum, sum_sq) = v.iter().fold(
                (f64::INFINITY, f64::NEG_INFINITY, 0f64, 0f64),
                |(mn, mx, s, s2), &x| {
                    let x = x as f64;
                    (mn.min(x), mx.max(x), s + x, s2 + x * x)
                },
            );
            // 20 equal-width buckets.
            let nb = 20usize;
            let width = if max > min { (max - min) / nb as f64 } else { 1.0 };
            let mut buckets = vec![0u64; nb];
            for &x in v {
                let b = (((x as f64 - min) / width) as usize).min(nb - 1);
                buckets[b] += 1;
            }
            let mut bucket_json = Json::arr();
            for b in buckets {
                bucket_json.push(b as i64);
            }
            let j = Json::obj()
                .set("type", "histogram")
                .set("tag", tag.clone())
                .set("min", min)
                .set("max", max)
                .set("sum", sum)
                .set("sum_sq", sum_sq)
                .set("count", v.len())
                .set("buckets", bucket_json);
            Ok(vec![Tensor::scalar_str(j.render())])
        })))
    });

    r.add_sync("MergeSummary", |ctx| {
        let mut records = Vec::new();
        for t in &ctx.inputs {
            records.extend(t.as_str_slice()?.iter().cloned());
        }
        Ok(vec![Tensor::new(Shape::vector(records.len()), TensorData::Str(records))?])
    });
}
