//! Checkpointing (Table 1 row 6, §3.3 "Fault tolerance"): "each Variable
//! node is connected to a Save node … executed periodically … the contents
//! of the variables are written to persistent storage"; "each Variable is
//! connected to a Restore node that is only enabled in the first iteration
//! after a restart".
//!
//! File format ("tensor bundle"): magic, count, then per entry a
//! length-prefixed name + `tensor::codec` payload. Writes go through
//! `util::fsutil::atomic_write` (unique temp file + rename) so a crash
//! mid-save never corrupts the latest checkpoint.

use super::kernels::{Kernel, KernelContext, KernelRegistry};
use crate::error::{Result, Status};
use crate::tensor::{codec, Tensor};
use crate::util::byteorder::LittleEndian;
use std::collections::HashMap;
use std::io::Read;
use std::path::Path;

const MAGIC: &[u8; 8] = b"RFLOWCKP";

/// Write a named-tensor bundle atomically.
pub fn save_bundle(path: &Path, tensors: &[(String, Tensor)]) -> Result<()> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    let mut cnt = [0u8; 4];
    LittleEndian::write_u32(&mut cnt, tensors.len() as u32);
    buf.extend_from_slice(&cnt);
    for (name, t) in tensors {
        let nb = name.as_bytes();
        let mut len = [0u8; 4];
        LittleEndian::write_u32(&mut len, nb.len() as u32);
        buf.extend_from_slice(&len);
        buf.extend_from_slice(nb);
        let payload = codec::encode(t);
        let mut plen = [0u8; 8];
        LittleEndian::write_u64(&mut plen, payload.len() as u64);
        buf.extend_from_slice(&plen);
        buf.extend_from_slice(&payload);
    }
    crate::util::fsutil::atomic_write(path, &buf)
}

/// Read a bundle back.
pub fn load_bundle(path: &Path) -> Result<HashMap<String, Tensor>> {
    let mut buf = Vec::new();
    std::fs::File::open(path)
        .map_err(|e| Status::not_found(format!("checkpoint {path:?}: {e}")))?
        .read_to_end(&mut buf)?;
    if buf.len() < 12 || &buf[..8] != MAGIC {
        return Err(Status::invalid_argument(format!("{path:?} is not a rustflow checkpoint")));
    }
    let count = LittleEndian::read_u32(&buf[8..12]) as usize;
    let mut pos = 12;
    let mut out = HashMap::with_capacity(count);
    for _ in 0..count {
        if buf.len() < pos + 4 {
            return Err(Status::invalid_argument("truncated checkpoint (name len)"));
        }
        let nlen = LittleEndian::read_u32(&buf[pos..pos + 4]) as usize;
        pos += 4;
        if buf.len() < pos + nlen + 8 {
            return Err(Status::invalid_argument("truncated checkpoint (name)"));
        }
        let name = std::str::from_utf8(&buf[pos..pos + nlen])
            .map_err(|_| Status::invalid_argument("bad name encoding"))?
            .to_string();
        pos += nlen;
        let plen = LittleEndian::read_u64(&buf[pos..pos + 8]) as usize;
        pos += 8;
        if buf.len() < pos + plen {
            return Err(Status::invalid_argument("truncated checkpoint (payload)"));
        }
        let (t, used) = codec::decode(&buf[pos..pos + plen])?;
        if used != plen {
            return Err(Status::invalid_argument("checkpoint payload length mismatch"));
        }
        pos += plen;
        out.insert(name, t);
    }
    Ok(out)
}

/// Register the Save/Restore kernels.
///
/// Save: inputs = tensors to save; attrs `tensor_names` (list), `path`.
/// Restore: no inputs; attrs `tensor_names`, `out_types`, `path`. Outputs
/// the restored tensors in `tensor_names` order, which the graph Assigns
/// into the Variables.
pub(crate) fn register_kernels(r: &mut KernelRegistry) {
    r.add("Save", |node| {
        let names: Vec<String> = node.attr("tensor_names")?.as_list_str()?.to_vec();
        let path = node.attr("path")?.as_str()?.to_string();
        Ok(Kernel::Sync(Box::new(move |ctx: &mut KernelContext| {
            if ctx.inputs.len() != names.len() {
                return Err(Status::invalid_argument(format!(
                    "Save: {} inputs but {} tensor_names",
                    ctx.inputs.len(),
                    names.len()
                )));
            }
            let pairs: Vec<(String, Tensor)> =
                names.iter().cloned().zip(ctx.inputs.iter().cloned()).collect();
            save_bundle(Path::new(&path), &pairs)?;
            Ok(vec![])
        })))
    });

    r.add("Restore", |node| {
        let names: Vec<String> = node.attr("tensor_names")?.as_list_str()?.to_vec();
        let path = node.attr("path")?.as_str()?.to_string();
        Ok(Kernel::Sync(Box::new(move |_ctx: &mut KernelContext| {
            let bundle = load_bundle(Path::new(&path))?;
            names
                .iter()
                .map(|n| {
                    bundle.get(n).cloned().ok_or_else(|| {
                        Status::not_found(format!("tensor {n:?} not in checkpoint {path:?}"))
                    })
                })
                .collect()
        })))
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("rustflow-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::create_dir_all(&d);
        d
    }

    #[test]
    fn bundle_roundtrip() {
        let path = tmpdir("rt").join("model.ckpt");
        let tensors = vec![
            ("w".to_string(), Tensor::from_f32(vec![2, 2], vec![1., 2., 3., 4.]).unwrap()),
            ("b".to_string(), Tensor::from_f32(vec![2], vec![0.5, -0.5]).unwrap()),
            ("step".to_string(), Tensor::scalar_i64(42)),
        ];
        save_bundle(&path, &tensors).unwrap();
        let loaded = load_bundle(&path).unwrap();
        assert_eq!(loaded.len(), 3);
        assert_eq!(&loaded["w"], &tensors[0].1);
        assert_eq!(&loaded["b"], &tensors[1].1);
        assert_eq!(loaded["step"].scalar_value_i64().unwrap(), 42);
    }

    #[test]
    fn missing_file_is_not_found() {
        let e = load_bundle(Path::new("/nonexistent/nope.ckpt")).unwrap_err();
        assert_eq!(e.code, crate::error::Code::NotFound);
    }

    #[test]
    fn corrupt_file_rejected() {
        let path = tmpdir("bad").join("garbage.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load_bundle(&path).is_err());
    }

    #[test]
    fn overwrite_is_atomic_replacement() {
        let path = tmpdir("ow").join("model.ckpt");
        save_bundle(&path, &[("x".into(), Tensor::scalar_f32(1.0))]).unwrap();
        save_bundle(&path, &[("x".into(), Tensor::scalar_f32(2.0))]).unwrap();
        let loaded = load_bundle(&path).unwrap();
        assert_eq!(loaded["x"].scalar_value_f32().unwrap(), 2.0);
        // No stray tmp file.
        assert!(!path.with_extension("tmp").exists());
    }

    #[test]
    fn empty_bundle_ok() {
        let path = tmpdir("empty").join("e.ckpt");
        save_bundle(&path, &[]).unwrap();
        assert!(load_bundle(&path).unwrap().is_empty());
    }
}
