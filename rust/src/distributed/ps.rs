//! Parameter-server shards for data-parallel training (§4.4, Fig 7): a
//! TCP server holding a shard of the model's variables plus the optimizer
//! slot state for them, applying pushed gradients with
//! [`Optimizer::apply_dense`] — the same arithmetic, expression for
//! expression, as the in-graph Apply* kernels, which is what makes the
//! synchronous mode bit-identical to single-process training.
//!
//! Two modes, selected by [`PsOptions::sync_replicas`]:
//!
//! - **Synchronous** (`Some(n)`): each step's pushes from all `n` replicas
//!   meet at a barrier built on the existing [`LocalRendezvous`] (one key
//!   per `(step, replica)`); an applier thread receives them **in replica
//!   order**, merges per variable with the same pairwise-add chain the
//!   in-graph `AddN` uses, scales by `1/n`, applies once, and bumps the
//!   parameter version. A push blocks until its step is applied, so
//!   replicas proceed in lockstep — "exactly as if we were running the
//!   sequential SGD algorithm with a batch size of" n×b.
//! - **Asynchronous** (`None`): Downpour-style; every push applies
//!   immediately under the shard lock at full scale, and replicas pull
//!   whenever they like. Staleness is tolerated by construction.
//!
//! Staleness contract (enforced in sync mode): a push carries the version
//! it pulled. `step < version` → `FailedPrecondition` (stale replica: its
//! gradient is refused, server state untouched — re-pull and retry).
//! `step > version` → `InvalidArgument` (a replica from the future is a
//! protocol bug). Async mode accepts any step: that is its semantics.
//!
//! Compression (§5.5) is negotiated per channel in the HELLO exchange
//! (see [`proto::CHANNEL_BF16`]): pull replies and pushed gradients
//! travel as bf16 truncations when granted, and tensors self-describe
//! their dtype, so compressed and uncompressed peers interoperate on the
//! same server. Embedding-shaped gradients may travel row-sparse
//! ([`GradEntry::Sparse`]); the server scatters them (SGD only — slot
//! optimizers would need dense slot reads and are rejected as
//! `Unimplemented`).
//!
//! Observability (§9.2): every server owns a [`MetricsRegistry`] — wire
//! frame/byte counters per message type plus push/pull totals — dumped
//! whole by `MSG_PS_STATS`. With [`PsOptions::trace`] the server also
//! records recv → barrier-wait → apply spans (tagged with the push's
//! step) into a [`TraceCollector`] that clients drain over
//! `MSG_TRACE_PULL`; the HELLO exchange carries both sides' trace clocks
//! so the client can estimate the server's clock offset and merge the
//! fragment onto its own timeline.

use super::proto::{
    self, GradEntry, GradPush, PsHello, PsHelloReply, PsInitReply, PsPullReply, PsPushReply,
    TraceReply, CHANNEL_BF16,
};
use crate::compress;
use crate::error::{Code, Result, Status};
use crate::kernels::math::binary_elementwise;
use crate::obs::httpz::{DebugServer, Response, Routes};
use crate::obs::profiler::{straggler_report, Profiler};
use crate::obs::{Counter, MetricsRegistry};
use crate::optim::{Optimizer, SlotMap};
use crate::rendezvous::{recv_blocking_timeout, LocalRendezvous, Rendezvous};
use crate::tensor::{DType, Tensor, TensorData};
use crate::tracing_tools::{process_now_us, TraceCollector, TraceFragment};
use crate::wire::{self, WireMetrics};
use std::collections::{BTreeMap, BTreeSet, HashSet};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Server-side configuration for one parameter-server shard.
#[derive(Clone)]
pub struct PsOptions {
    /// The update rule applied server-side. Must match what the reference
    /// single-process run would use for trajectory equivalence.
    pub opt: Optimizer,
    /// `Some(n)`: synchronous SGD over exactly `n` replicas per step.
    /// `None`: asynchronous (Downpour) updates.
    pub sync_replicas: Option<usize>,
    /// Grant [`CHANNEL_BF16`] to clients that request it.
    pub allow_compression: bool,
    /// Synchronous mode only: how long the applier waits for a step's
    /// missing replicas before declaring the group failed (a replica died
    /// mid-step; every blocked push then errors out instead of hanging).
    pub sync_timeout: Duration,
    /// Record recv/barrier-wait/apply spans, served over `MSG_TRACE_PULL`.
    pub trace: bool,
}

impl Default for PsOptions {
    fn default() -> Self {
        PsOptions {
            opt: Optimizer::sgd(0.01),
            sync_replicas: None,
            allow_compression: true,
            sync_timeout: Duration::from_secs(120),
            trace: false,
        }
    }
}

/// Everything guarded by the shard lock. `params` is a BTreeMap so pulls
/// and sync applies walk variables in one deterministic (sorted) order.
struct ShardState {
    params: BTreeMap<String, Tensor>,
    slots: SlotMap,
    /// Bumped once per applied step (sync) or per applied push (async).
    version: u64,
    initialized: bool,
    /// Sync mode: a step group failed (timeout / bad blob); every waiter
    /// and future push observes this instead of hanging.
    failed: Option<Status>,
}

/// One parameter-server shard. Construct with [`ParamServer::new`], then
/// [`ParamServer::serve`]; talk to it with [`PsClient`].
pub struct ParamServer {
    options: PsOptions,
    state: Mutex<ShardState>,
    /// Signalled after every version bump (and on failure/shutdown).
    applied: Condvar,
    /// Sync-mode barrier: encoded pushes parked under
    /// `psgrad;step:<s>;replica:<r>` until the applier collects them.
    barrier: Arc<LocalRendezvous>,
    addr: Mutex<Option<SocketAddr>>,
    /// Per-server metrics (not process-global: two shards in one test
    /// process must not share counters). Wire frame/byte counters live
    /// here too, via `wire_metrics`.
    registry: Arc<MetricsRegistry>,
    wire_metrics: Arc<WireMetrics>,
    pushes: Arc<Counter>,
    pulls: Arc<Counter>,
    /// Present when [`PsOptions::trace`]: spans drained by `MSG_TRACE_PULL`.
    trace: Option<Arc<TraceCollector>>,
    /// Phase rollups (recv / barrier-wait / apply) for `/statusz` —
    /// always on; feeding it is one histogram record per phase.
    profiler: Arc<Profiler>,
    /// Sync mode: `(step, first-arrival time)` of the in-flight step, so
    /// each replica's barrier *arrival lag* (its arrival minus the
    /// step's earliest) can be attributed. One slot suffices — the
    /// staleness contract admits exactly one step at a time.
    sync_first_arrival: Mutex<Option<(u64, Instant)>>,
    shutdown: AtomicBool,
}

fn barrier_key(step: u64, replica: u32) -> String {
    format!("psgrad;step:{step};replica:{replica}")
}

impl ParamServer {
    pub fn new(options: PsOptions) -> Arc<ParamServer> {
        let registry = MetricsRegistry::new();
        let wire_metrics = WireMetrics::new(&registry, "wire", proto::msg_name);
        let pushes = registry.counter("ps/pushes");
        let pulls = registry.counter("ps/pulls");
        let trace = options.trace.then(|| TraceCollector::for_step("ps", 0));
        Arc::new(ParamServer {
            options,
            state: Mutex::new(ShardState {
                params: BTreeMap::new(),
                slots: SlotMap::new(),
                version: 0,
                initialized: false,
                failed: None,
            }),
            applied: Condvar::new(),
            barrier: LocalRendezvous::new(),
            addr: Mutex::new(None),
            registry,
            wire_metrics,
            pushes,
            pulls,
            trace,
            profiler: Profiler::new(16),
            sync_first_arrival: Mutex::new(None),
            shutdown: AtomicBool::new(false),
        })
    }

    /// Bind `addr` (`"127.0.0.1:0"` for ephemeral) and serve on background
    /// threads; in synchronous mode this also starts the applier thread.
    pub fn serve(self: &Arc<Self>, addr: &str) -> Result<SocketAddr> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Status::unavailable(format!("bind {addr}: {e}")))?;
        let local = listener.local_addr()?;
        *self.addr.lock().unwrap() = Some(local);
        let server = Arc::clone(self);
        std::thread::Builder::new()
            .name("ps-accept".to_string())
            .spawn(move || {
                for conn in listener.incoming() {
                    if server.shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let s = Arc::clone(&server);
                            std::thread::spawn(move || s.handle_connection(stream));
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn ps accept thread");
        if let Some(n) = self.options.sync_replicas {
            let server = Arc::clone(self);
            std::thread::Builder::new()
                .name("ps-applier".to_string())
                .spawn(move || server.run_sync_applier(n))
                .expect("spawn ps applier thread");
        }
        Ok(local)
    }

    /// Stop serving: wakes the applier (via barrier abort), every blocked
    /// push (via the condvar), and the accept loop (via a loopback poke).
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.barrier.abort(Status::aborted("parameter server shut down"));
        self.applied.notify_all();
        if let Some(addr) = *self.addr.lock().unwrap() {
            let _ = TcpStream::connect(addr);
        }
    }

    /// Total bytes read + written across all connections (frame headers
    /// included) — the bench's bytes-on-wire measure.
    pub fn wire_bytes(&self) -> u64 {
        self.wire_metrics.total_bytes()
    }

    /// The server's metrics registry — what `MSG_PS_STATS` dumps under
    /// `"metrics"`.
    pub fn metrics(&self) -> &Arc<MetricsRegistry> {
        &self.registry
    }

    /// Phase profiler (recv / barrier-wait / apply rollups) — what
    /// `/statusz` renders.
    pub fn profiler(&self) -> &Arc<Profiler> {
        &self.profiler
    }

    /// Mount the debug surface for this shard:
    ///
    /// | path       | serves                                             |
    /// |------------|----------------------------------------------------|
    /// | `/healthz` | `ok` (200) or `shutting down` (503)                |
    /// | `/varz`    | the registry in Prometheus text exposition         |
    /// | `/statusz` | phase rollups + barrier-arrival straggler report   |
    /// | `/tracez`  | chrome trace JSON of collected spans (404 if off)  |
    pub fn serve_httpz(self: &Arc<Self>, addr: &str) -> Result<DebugServer> {
        let (h, v, s, t) =
            (Arc::clone(self), Arc::clone(self), Arc::clone(self), Arc::clone(self));
        let routes = Routes::new()
            .add("/healthz", move || {
                if h.shutdown.load(Ordering::SeqCst) {
                    Response::text(503, "shutting down\n")
                } else {
                    Response::text(200, "ok\n")
                }
            })
            .add("/varz", move || Response::text(200, v.registry.export_text()))
            .add("/statusz", move || {
                let mut body = format!(
                    "== parameter server v{} (sync_replicas={}) ==\n",
                    s.version(),
                    s.options.sync_replicas.unwrap_or(0)
                );
                body.push_str(&s.profiler.report_text(10));
                match straggler_report(&s.registry) {
                    Some(r) => body.push_str(&r.render_text()),
                    None => body.push_str("no sync pushes yet\n"),
                }
                Response::text(200, body)
            })
            .add("/tracez", move || match &t.trace {
                Some(tc) => Response::json(200, tc.to_chrome_trace()),
                None => Response::text(404, "tracing disabled\n"),
            });
        DebugServer::serve(routes, addr)
    }

    /// Current parameter version (test support).
    pub fn version(&self) -> u64 {
        self.state.lock().unwrap().version
    }

    /// Snapshot of a parameter (test support).
    pub fn param(&self, name: &str) -> Option<Tensor> {
        self.state.lock().unwrap().params.get(name).cloned()
    }

    fn handle_connection(self: Arc<Self>, mut stream: TcpStream) {
        stream.set_nodelay(true).ok();
        // Per-channel capabilities, set by HELLO; zero until negotiated.
        let mut negotiated = 0u32;
        loop {
            let (msg_type, payload) = match self.wire_metrics.read_frame(&mut stream) {
                Ok(f) => f,
                Err(_) => return, // client hung up (or sent garbage framing)
            };
            let (reply_type, reply) = match msg_type {
                proto::MSG_PS_HELLO => {
                    let granted = match PsHello::decode(&payload) {
                        Ok(h) if self.options.allow_compression => h.flags & CHANNEL_BF16,
                        Ok(_) => 0,
                        Err(e) => {
                            let r = PsHelloReply {
                                status: Err(e),
                                flags: 0,
                                time_us: process_now_us(),
                            };
                            let _ = self.reply(&mut stream, proto::MSG_PS_HELLO_REPLY, &r.encode());
                            continue;
                        }
                    };
                    negotiated = granted;
                    // `time_us` is our trace clock at (roughly) the moment
                    // the client's HELLO arrived — its half-RTT anchor.
                    let r = PsHelloReply {
                        status: Ok(()),
                        flags: granted,
                        time_us: process_now_us(),
                    };
                    (proto::MSG_PS_HELLO_REPLY, r.encode())
                }
                proto::MSG_PS_INIT => {
                    let r = match wire::decode_tensor_map(&payload, &mut 0) {
                        Ok(params) => self.handle_init(params),
                        Err(e) => PsInitReply { status: Err(e), seeded: false },
                    };
                    (proto::MSG_PS_INIT_REPLY, r.encode())
                }
                proto::MSG_PS_PULL => {
                    self.pulls.inc();
                    (proto::MSG_PS_PULL_REPLY, self.handle_pull(negotiated).encode())
                }
                proto::MSG_PS_PUSH => {
                    self.pushes.inc();
                    let r = match GradPush::decode(&payload) {
                        Ok(push) => self.handle_push(push),
                        Err(e) => PsPushReply { status: Err(e), version: 0 },
                    };
                    (proto::MSG_PS_PUSH_REPLY, r.encode())
                }
                proto::MSG_PS_STATS => (proto::MSG_PS_STATS_REPLY, self.stats_json().into_bytes()),
                proto::MSG_TRACE_PULL => {
                    let fragment = match &self.trace {
                        Some(t) => t.take_fragment(),
                        None => TraceFragment {
                            process: "ps".to_string(),
                            events: Vec::new(),
                            dropped: 0,
                        },
                    };
                    let r = TraceReply { status: Ok(()), fragment };
                    (proto::MSG_TRACE_REPLY, r.encode())
                }
                _ => return, // unknown type on a persistent channel: drop it
            };
            if self.reply(&mut stream, reply_type, &reply).is_err() {
                return;
            }
        }
    }

    fn reply(&self, stream: &mut TcpStream, msg_type: u8, payload: &[u8]) -> Result<()> {
        self.wire_metrics.write_frame(stream, msg_type, payload)
    }

    /// The legacy top-level keys (kept for callers that scrape them) plus
    /// the full registry dump under `"metrics"` — one uniform surface for
    /// shard state, push/pull totals, and per-message wire counters.
    fn stats_json(&self) -> String {
        let st = self.state.lock().unwrap();
        crate::util::json::Json::obj()
            .set("version", st.version as f64)
            .set("num_params", st.params.len() as f64)
            .set("initialized", st.initialized)
            .set("sync_replicas", self.options.sync_replicas.unwrap_or(0) as f64)
            .set("pushes", self.pushes.get() as f64)
            .set("pulls", self.pulls.get() as f64)
            .set("bytes_in", self.wire_metrics.bytes_in() as f64)
            .set("bytes_out", self.wire_metrics.bytes_out() as f64)
            .set("metrics", self.registry.to_json())
            .render()
    }

    /// First-wins initialization: the winning replica's values seed the
    /// shard; everyone else gets `seeded: false` and pulls. An empty map
    /// is legal (a shard that holds no variables still versions in
    /// lockstep with the others).
    fn handle_init(&self, params: Vec<(String, Tensor)>) -> PsInitReply {
        for (name, t) in &params {
            if t.dtype() != DType::F32 {
                return PsInitReply {
                    status: Err(Status::invalid_argument(format!(
                        "parameter {name:?} has dtype {}, parameter servers hold f32",
                        t.dtype()
                    ))),
                    seeded: false,
                };
            }
        }
        let mut st = self.state.lock().unwrap();
        if st.initialized {
            return PsInitReply { status: Ok(()), seeded: false };
        }
        st.params = params.into_iter().collect();
        st.initialized = true;
        PsInitReply { status: Ok(()), seeded: true }
    }

    fn handle_pull(&self, negotiated: u32) -> PsPullReply {
        let st = self.state.lock().unwrap();
        if let Some(f) = &st.failed {
            return PsPullReply { status: Err(f.clone()), version: st.version, params: vec![] };
        }
        if !st.initialized {
            return PsPullReply {
                status: Err(Status::failed_precondition("parameter server not initialized")),
                version: 0,
                params: vec![],
            };
        }
        let mut params = Vec::with_capacity(st.params.len());
        for (name, t) in &st.params {
            let out = if negotiated & CHANNEL_BF16 != 0 {
                match compress::f32_to_bf16(t) {
                    Ok(c) => c,
                    Err(e) => {
                        return PsPullReply { status: Err(e), version: st.version, params: vec![] }
                    }
                }
            } else {
                t.clone()
            };
            params.push((name.clone(), out));
        }
        PsPullReply { status: Ok(()), version: st.version, params }
    }

    fn handle_push(&self, mut push: GradPush) -> PsPushReply {
        // The "recv" phase of the EEG trace: widening the wire payload
        // back to f32 before any state is touched.
        let recv =
            self.trace.as_ref().map(|t| t.begin_step("ps/recv", "PsRecv", "ps", push.step));
        let recv_start = Instant::now();
        // Decompress by dtype before validation: the codec self-describes,
        // so compressed entries from any client are transparently widened.
        let mut decompress = Ok(());
        for (_, entry) in push.grads.iter_mut() {
            decompress = decompress_entry(entry);
            if decompress.is_err() {
                break;
            }
        }
        self.profiler.observe_span("ps/recv", "PsRecv", recv_start.elapsed());
        if let Some(s) = recv {
            s.end();
        }
        if let Err(e) = decompress {
            return PsPushReply { status: Err(e), version: 0 };
        }
        match self.options.sync_replicas {
            None => self.push_async(push),
            Some(n) => self.push_sync(push, n),
        }
    }

    /// Async (Downpour): validate + apply immediately at full scale.
    fn push_async(&self, push: GradPush) -> PsPushReply {
        let mut st = self.state.lock().unwrap();
        if !st.initialized {
            return PsPushReply {
                status: Err(Status::failed_precondition("parameter server not initialized")),
                version: st.version,
            };
        }
        if let Err(e) = validate_push(&st, &self.options.opt, &push) {
            return PsPushReply { status: Err(e), version: st.version };
        }
        let span =
            self.trace.as_ref().map(|t| t.begin_step("ps/apply", "PsApply", "ps", push.step));
        let apply_start = Instant::now();
        let applied = apply_entries(&mut st, &self.options.opt, &push.grads, 1.0);
        self.profiler.observe_span("ps/apply", "PsApply", apply_start.elapsed());
        if let Some(s) = span {
            s.end();
        }
        if let Err(e) = applied {
            return PsPushReply { status: Err(e), version: st.version };
        }
        st.version += 1;
        let version = st.version;
        drop(st);
        self.applied.notify_all();
        PsPushReply { status: Ok(()), version }
    }

    /// Sync: validate against the *current* version, park the encoded push
    /// at the barrier, block until the applier has applied this step.
    fn push_sync(&self, push: GradPush, n: usize) -> PsPushReply {
        let step = push.step;
        {
            let st = self.state.lock().unwrap();
            if let Some(f) = &st.failed {
                return PsPushReply { status: Err(f.clone()), version: st.version };
            }
            if !st.initialized {
                return PsPushReply {
                    status: Err(Status::failed_precondition("parameter server not initialized")),
                    version: st.version,
                };
            }
            if (push.replica as usize) >= n {
                return PsPushReply {
                    status: Err(Status::invalid_argument(format!(
                        "replica {} out of range for {n} sync replicas",
                        push.replica
                    ))),
                    version: st.version,
                };
            }
            // The staleness contract. A stale push never touches state.
            if step < st.version {
                return PsPushReply {
                    status: Err(Status::failed_precondition(format!(
                        "stale push for step {step}, server is at version {}; pull and retry",
                        st.version
                    ))),
                    version: st.version,
                };
            }
            if step > st.version {
                return PsPushReply {
                    status: Err(Status::invalid_argument(format!(
                        "push for future step {step}, server is at version {}",
                        st.version
                    ))),
                    version: st.version,
                };
            }
            if let Err(e) = validate_push(&st, &self.options.opt, &push) {
                return PsPushReply { status: Err(e), version: st.version };
            }
        }
        // Park the (validated, decompressed) push for the applier. A
        // duplicate (step, replica) key is a client bug surfaced by the
        // rendezvous' duplicate-send check.
        let blob = push.encode();
        let parked = Tensor::new(vec![blob.len()], TensorData::U8(blob));
        let parked = match parked {
            Ok(t) => t,
            Err(e) => return PsPushReply { status: Err(e), version: 0 },
        };
        // Attribute this replica's barrier *arrival lag* — how far behind
        // the step's earliest arrival it showed up — before parking, so
        // the straggler surface is fed even if the group later fails.
        self.record_arrival_lag(step, push.replica);
        if let Err(e) = self.barrier.send(&barrier_key(step, push.replica), parked) {
            let status = if e.code == Code::Internal {
                Status::failed_precondition(format!(
                    "replica {} already pushed for step {step}",
                    push.replica
                ))
            } else {
                e
            };
            let st = self.state.lock().unwrap();
            return PsPushReply { status: Err(status), version: st.version };
        }
        // Block until the applier finishes this step (or the group fails).
        // The wait is the interesting span: how long this replica sat at
        // the barrier for its peers is exactly what the EEG shows.
        let wait = self
            .trace
            .as_ref()
            .map(|t| t.begin_step("ps/barrier_wait", "PsBarrierWait", "ps", step));
        let wait_start = Instant::now();
        let reply = self.wait_for_applied(step);
        self.profiler.observe_span("ps/barrier_wait", "PsBarrierWait", wait_start.elapsed());
        if let Some(s) = wait {
            s.end();
        }
        reply
    }

    /// Record the replica's arrival lag for `step` into the
    /// `ps/replica<i>/barrier_wait_us` histogram. The first replica to
    /// arrive defines the step's epoch (lag 0); everyone after records
    /// their distance from it. One slot is enough: the staleness checks
    /// above guarantee only one step's pushes are in flight at a time.
    fn record_arrival_lag(&self, step: u64, replica: u32) {
        let now = Instant::now();
        let first = {
            let mut slot = self.sync_first_arrival.lock().unwrap();
            match *slot {
                Some((s, t)) if s == step => t,
                _ => {
                    *slot = Some((step, now));
                    now
                }
            }
        };
        let lag = now.duration_since(first);
        self.registry.histogram(&format!("ps/replica{replica}/barrier_wait_us")).record(lag);
    }

    /// Park until `step` has been applied, the group failed, or shutdown.
    fn wait_for_applied(&self, step: u64) -> PsPushReply {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(f) = &st.failed {
                return PsPushReply { status: Err(f.clone()), version: st.version };
            }
            if st.version > step {
                return PsPushReply { status: Ok(()), version: st.version };
            }
            if self.shutdown.load(Ordering::SeqCst) {
                return PsPushReply {
                    status: Err(Status::aborted("parameter server shut down")),
                    version: st.version,
                };
            }
            let (guard, _) =
                self.applied.wait_timeout(st, Duration::from_millis(50)).unwrap();
            st = guard;
        }
    }

    /// The sync applier: one iteration per step — receive all `n` pushes
    /// for the current version **in replica order**, merge + apply, bump.
    fn run_sync_applier(self: Arc<Self>, n: usize) {
        loop {
            if self.shutdown.load(Ordering::SeqCst) {
                return;
            }
            let step = self.state.lock().unwrap().version;
            let mut pushes: Vec<GradPush> = Vec::with_capacity(n);
            for r in 0..n as u32 {
                let blob = match recv_blocking_timeout(
                    &*self.barrier,
                    &barrier_key(step, r),
                    self.options.sync_timeout,
                ) {
                    Ok(b) => b,
                    Err(e) => {
                        if self.shutdown.load(Ordering::SeqCst) || e.code == Code::Aborted {
                            return;
                        }
                        self.fail_group(Status::new(
                            Code::Aborted,
                            format!(
                                "sync step {step}: replica {r} missing after {:?} ({})",
                                self.options.sync_timeout, e.message
                            ),
                        ));
                        return;
                    }
                };
                let decoded = blob.as_u8().and_then(GradPush::decode);
                match decoded {
                    Ok(p) => pushes.push(p),
                    Err(e) => {
                        self.fail_group(Status::internal(format!(
                            "sync step {step}: bad parked push from replica {r}: {e}"
                        )));
                        return;
                    }
                }
            }
            let span =
                self.trace.as_ref().map(|t| t.begin_step("ps/apply", "PsApply", "ps", step));
            let apply_start = Instant::now();
            let mut st = self.state.lock().unwrap();
            let scale = 1.0 / n as f32;
            let applied = apply_sync_step(&mut st, &self.options.opt, &pushes, scale);
            self.profiler.observe_span("ps/apply", "PsApply", apply_start.elapsed());
            if applied.is_ok() {
                // Bump under the same lock as the apply: a pull must never
                // observe new parameters at the old version.
                st.version = step + 1;
            }
            drop(st);
            if let Some(s) = span {
                s.end();
            }
            if let Err(e) = applied {
                self.fail_group(Status::internal(format!("sync step {step} apply failed: {e}")));
                return;
            }
            self.applied.notify_all();
        }
    }

    /// Mark the shard failed: every blocked and future operation observes
    /// the status instead of hanging — §3.3's "abort the entire graph
    /// execution" failure path, transplanted to the training service.
    fn fail_group(&self, status: Status) {
        self.barrier.abort(status.clone());
        let mut st = self.state.lock().unwrap();
        st.failed = Some(status);
        drop(st);
        self.applied.notify_all();
    }
}

/// Widen bf16 wire tensors back to f32 (dtype-driven, so uncompressed
/// entries pass through untouched).
fn decompress_entry(entry: &mut GradEntry) -> Result<()> {
    match entry {
        GradEntry::Dense(t) => {
            if t.dtype() == DType::BF16 {
                *t = compress::bf16_to_f32(t)?;
            }
        }
        GradEntry::Sparse { values, .. } => {
            if values.dtype() == DType::BF16 {
                *values = compress::bf16_to_f32(values)?;
            }
        }
    }
    Ok(())
}

/// Validate a (decompressed) push against the shard's parameters without
/// touching any state: unknown names, dtype/shape mismatches, duplicate
/// entries, malformed or out-of-bounds sparse indices are all rejected
/// here, *before* a push can reach the barrier or the apply path — a
/// hostile or buggy replica must never corrupt server state.
fn validate_push(st: &ShardState, opt: &Optimizer, push: &GradPush) -> Result<()> {
    let mut seen: HashSet<&str> = HashSet::with_capacity(push.grads.len());
    for (name, entry) in &push.grads {
        if !seen.insert(name.as_str()) {
            return Err(Status::invalid_argument(format!("duplicate gradient for {name:?}")));
        }
        let var = st
            .params
            .get(name)
            .ok_or_else(|| Status::not_found(format!("no parameter {name:?} on this shard")))?;
        match entry {
            GradEntry::Dense(g) => {
                if g.dtype() != DType::F32 {
                    return Err(Status::invalid_argument(format!(
                        "gradient for {name:?} has dtype {}",
                        g.dtype()
                    )));
                }
                if g.shape().dims() != var.shape().dims() {
                    return Err(Status::invalid_argument(format!(
                        "gradient for {name:?} has shape {:?}, variable is {:?}",
                        g.shape().dims(),
                        var.shape().dims()
                    )));
                }
            }
            GradEntry::Sparse { indices, values } => {
                if !matches!(opt, Optimizer::Sgd { .. }) {
                    return Err(Status::unimplemented(
                        "sparse pushes require plain SGD (slot optimizers need dense state)",
                    ));
                }
                if indices.dtype() != DType::I64 || indices.shape().rank() != 1 {
                    return Err(Status::invalid_argument(format!(
                        "sparse indices for {name:?} must be i64 of rank 1"
                    )));
                }
                if values.dtype() != DType::F32 {
                    return Err(Status::invalid_argument(format!(
                        "sparse values for {name:?} have dtype {}",
                        values.dtype()
                    )));
                }
                if var.shape().rank() < 1 || var.num_elements() == 0 {
                    return Err(Status::invalid_argument(format!(
                        "variable {name:?} is not sparse-updatable"
                    )));
                }
                let rows = var.shape().dims()[0];
                let row_len = var.num_elements() / rows;
                let k = indices.num_elements();
                if values.num_elements() != k * row_len {
                    return Err(Status::invalid_argument(format!(
                        "sparse values for {name:?}: {} elements for {k} rows of {row_len}",
                        values.num_elements()
                    )));
                }
                for &i in indices.as_i64()? {
                    if i < 0 || (i as usize) >= rows {
                        return Err(Status::out_of_range(format!(
                            "sparse index {i} out of range for {name:?} with {rows} rows"
                        )));
                    }
                }
            }
        }
    }
    Ok(())
}

/// Apply one push's entries, each scaled by `scale` (async: 1.0).
fn apply_entries(
    st: &mut ShardState,
    opt: &Optimizer,
    grads: &[(String, GradEntry)],
    scale: f32,
) -> Result<()> {
    for (name, entry) in grads {
        let var = st
            .params
            .get(name)
            .cloned()
            .ok_or_else(|| Status::not_found(format!("no parameter {name:?}")))?;
        let new = match entry {
            GradEntry::Dense(g) => {
                let scaled = binary_elementwise(g, &Tensor::scalar_f32(scale), "Mul")?;
                opt.apply_dense(name, &var, &scaled, &mut st.slots)?
            }
            GradEntry::Sparse { indices, values } => {
                let lr = match *opt {
                    Optimizer::Sgd { lr } => lr,
                    _ => return Err(Status::unimplemented("sparse push requires SGD")),
                };
                apply_sparse_sgd(&var, indices, values, lr, scale)?
            }
        };
        st.params.insert(name.clone(), new);
    }
    Ok(())
}

/// Merge + apply one synchronous step. For a variable where every replica
/// pushed dense, this mirrors the in-graph chain node for node: pairwise
/// adds in replica order (the `AddN` kernel's accumulation), a scalar
/// multiply by `1/n` (the `Mul` kernel), then one `apply_dense` (the
/// `Apply*` kernel) — hence bit-identical trajectories. Variables with
/// any sparse contribution are applied per replica at scale `1/n` (SGD
/// linearity makes that equivalent).
fn apply_sync_step(
    st: &mut ShardState,
    opt: &Optimizer,
    pushes: &[GradPush],
    scale: f32,
) -> Result<()> {
    let mut names: BTreeSet<&str> = BTreeSet::new();
    for p in pushes {
        for (name, _) in &p.grads {
            names.insert(name);
        }
    }
    let scale_t = Tensor::scalar_f32(scale);
    for name in names {
        // Contributions in replica order (pushes arrive ordered 0..n).
        let contributions: Vec<&GradEntry> = pushes
            .iter()
            .flat_map(|p| p.grads.iter().filter(|(n, _)| n == name).map(|(_, e)| e))
            .collect();
        let all_dense = contributions.iter().all(|e| matches!(e, GradEntry::Dense(_)));
        if all_dense {
            let mut iter = contributions.iter().map(|e| match e {
                GradEntry::Dense(t) => t,
                GradEntry::Sparse { .. } => unreachable!(),
            });
            let first = iter.next().ok_or_else(|| Status::internal("empty contribution"))?;
            let mut acc = first.clone();
            for g in iter {
                acc = binary_elementwise(&acc, g, "Add")?;
            }
            let mean = binary_elementwise(&acc, &scale_t, "Mul")?;
            let var = st
                .params
                .get(name)
                .cloned()
                .ok_or_else(|| Status::not_found(format!("no parameter {name:?}")))?;
            let new = opt.apply_dense(name, &var, &mean, &mut st.slots)?;
            st.params.insert(name.to_string(), new);
        } else {
            let lr = match *opt {
                Optimizer::Sgd { lr } => lr,
                _ => return Err(Status::unimplemented("sparse push requires SGD")),
            };
            for entry in contributions {
                let var = st
                    .params
                    .get(name)
                    .cloned()
                    .ok_or_else(|| Status::not_found(format!("no parameter {name:?}")))?;
                let new = match entry {
                    GradEntry::Sparse { indices, values } => {
                        apply_sparse_sgd(&var, indices, values, lr, scale)?
                    }
                    GradEntry::Dense(g) => {
                        let scaled = binary_elementwise(g, &scale_t, "Mul")?;
                        opt.apply_dense(name, &var, &scaled, &mut st.slots)?
                    }
                };
                st.params.insert(name.to_string(), new);
            }
        }
    }
    Ok(())
}

/// Row-sparse SGD scatter. Per touched element this computes the same
/// expression the dense path would (`m = v*scale; out = out*1.0 +
/// m*(-lr)`), so a sparse push of the nonzero rows matches a dense push
/// of the same gradient bit for bit (single replica).
fn apply_sparse_sgd(
    var: &Tensor,
    indices: &Tensor,
    values: &Tensor,
    lr: f32,
    scale: f32,
) -> Result<Tensor> {
    let mut out = var.as_f32()?.to_vec();
    let rows = var.shape().dims()[0];
    let row_len = out.len() / rows;
    let idx = indices.as_i64()?;
    let vals = values.as_f32()?;
    for (k, &r) in idx.iter().enumerate() {
        let r = r as usize; // bounds were validated before apply
        for j in 0..row_len {
            let m = vals[k * row_len + j] * scale;
            let o = r * row_len + j;
            out[o] = out[o] * 1.0 + m * (-lr);
        }
    }
    Tensor::new(var.shape().clone(), TensorData::F32(out))
}

// ---- client ----------------------------------------------------------------

/// A replica's persistent channel to one parameter-server shard.
pub struct PsClient {
    stream: Mutex<TcpStream>,
    negotiated: u32,
    /// Estimated `server_trace_clock − our_trace_clock` in µs (positive:
    /// the server's clock reads ahead), from the HELLO exchange.
    clock_offset_us: i64,
}

impl PsClient {
    /// Connect and negotiate capabilities. `want_compression` requests
    /// [`CHANNEL_BF16`]; the server grants or refuses, and only granted
    /// capabilities are used afterwards. The exchange doubles as an
    /// NTP-style clock probe: we stamp the HELLO with our trace clock,
    /// the server stamps the reply with its own, and assuming the
    /// symmetric half of the measured RTT puts the server's stamp at
    /// `t_send + rtt/2` on our clock.
    pub fn connect(addr: &str, want_compression: bool) -> Result<PsClient> {
        let mut stream = TcpStream::connect(addr)
            .map_err(|e| Status::unavailable(format!("connect {addr}: {e}")))?;
        stream.set_nodelay(true).ok();
        let t_send = process_now_us();
        let hello = PsHello {
            flags: if want_compression { CHANNEL_BF16 } else { 0 },
            time_us: t_send,
        };
        wire::write_frame(&mut stream, proto::MSG_PS_HELLO, &hello.encode())?;
        let (t, payload) = wire::read_frame(&mut stream)?;
        let t_recv = process_now_us();
        if t != proto::MSG_PS_HELLO_REPLY {
            return Err(Status::internal(format!("unexpected reply type {t} to HELLO")));
        }
        let reply = PsHelloReply::decode(&payload)?;
        reply.status?;
        let rtt = t_recv.saturating_sub(t_send);
        let clock_offset_us = reply.time_us as i64 - (t_send + rtt / 2) as i64;
        Ok(PsClient { stream: Mutex::new(stream), negotiated: reply.flags, clock_offset_us })
    }

    /// Whether this channel negotiated bf16 compression.
    pub fn compressed(&self) -> bool {
        self.negotiated & CHANNEL_BF16 != 0
    }

    /// The server's estimated clock offset relative to ours, in µs — the
    /// value to pair with this server's fragments in
    /// [`crate::tracing_tools::merge_fragments`].
    pub fn clock_offset_us(&self) -> i64 {
        self.clock_offset_us
    }

    fn call(&self, msg_type: u8, payload: &[u8], want_reply: u8) -> Result<Vec<u8>> {
        let mut stream = self.stream.lock().unwrap();
        wire::write_frame(&mut *stream, msg_type, payload)?;
        let (t, reply) = wire::read_frame(&mut *stream)?;
        if t != want_reply {
            return Err(Status::internal(format!(
                "unexpected reply type {t} to message {msg_type}"
            )));
        }
        Ok(reply)
    }

    /// Offer initial values; returns whether this client won the
    /// first-wins seeding race.
    pub fn init(&self, params: &[(String, Tensor)]) -> Result<bool> {
        let mut payload = Vec::new();
        wire::encode_tensor_map(&mut payload, params);
        let reply = self.call(proto::MSG_PS_INIT, &payload, proto::MSG_PS_INIT_REPLY)?;
        let r = PsInitReply::decode(&reply)?;
        r.status?;
        Ok(r.seeded)
    }

    /// Fetch the shard's parameters and version. Compressed replies are
    /// widened back to f32 here (dtype-driven).
    pub fn pull(&self) -> Result<(u64, Vec<(String, Tensor)>)> {
        let reply = self.call(proto::MSG_PS_PULL, b"", proto::MSG_PS_PULL_REPLY)?;
        let r = PsPullReply::decode(&reply)?;
        r.status?;
        let mut params = Vec::with_capacity(r.params.len());
        for (name, t) in r.params {
            let t = if t.dtype() == DType::BF16 { compress::bf16_to_f32(&t)? } else { t };
            params.push((name, t));
        }
        Ok((r.version, params))
    }

    /// Push gradients computed against version `step`; compresses f32
    /// payloads when the channel negotiated it. Returns the server
    /// version after the push took effect.
    pub fn push(
        &self,
        step: u64,
        replica: u32,
        grads: Vec<(String, GradEntry)>,
    ) -> Result<u64> {
        let grads = if self.compressed() {
            grads
                .into_iter()
                .map(|(name, entry)| {
                    let entry = match entry {
                        GradEntry::Dense(t) if t.dtype() == DType::F32 => {
                            GradEntry::Dense(compress::f32_to_bf16(&t)?)
                        }
                        GradEntry::Sparse { indices, values }
                            if values.dtype() == DType::F32 =>
                        {
                            GradEntry::Sparse { indices, values: compress::f32_to_bf16(&values)? }
                        }
                        e => e,
                    };
                    Ok((name, entry))
                })
                .collect::<Result<Vec<_>>>()?
        } else {
            grads
        };
        let push = GradPush { step, replica, grads };
        let reply = self.call(proto::MSG_PS_PUSH, &push.encode(), proto::MSG_PS_PUSH_REPLY)?;
        let r = PsPushReply::decode(&reply)?;
        r.status?;
        Ok(r.version)
    }

    /// Server-side counters as a JSON string.
    pub fn stats(&self) -> Result<String> {
        let reply = self.call(proto::MSG_PS_STATS, b"", proto::MSG_PS_STATS_REPLY)?;
        Ok(String::from_utf8_lossy(&reply).to_string())
    }

    /// Drain the server's trace collector. Each event ships exactly once;
    /// a server that isn't tracing returns an empty fragment.
    pub fn trace_pull(&self) -> Result<TraceFragment> {
        let reply = self.call(proto::MSG_TRACE_PULL, b"", proto::MSG_TRACE_REPLY)?;
        let r = TraceReply::decode(&reply)?;
        r.status?;
        Ok(r.fragment)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn serve_async(opt: Optimizer) -> (Arc<ParamServer>, String) {
        let ps = ParamServer::new(PsOptions { opt, ..Default::default() });
        let addr = ps.serve("127.0.0.1:0").unwrap().to_string();
        (ps, addr)
    }

    #[test]
    fn init_pull_push_pull() {
        let (ps, addr) = serve_async(Optimizer::sgd(0.5));
        let c = PsClient::connect(&addr, false).unwrap();
        assert!(!c.compressed());
        let w0 = Tensor::from_f32(vec![2], vec![1.0, 2.0]).unwrap();
        assert!(c.init(&[("w".into(), w0)]).unwrap());
        let (v, params) = c.pull().unwrap();
        assert_eq!(v, 0);
        assert_eq!(params[0].1.as_f32().unwrap(), &[1.0, 2.0]);
        let g = Tensor::from_f32(vec![2], vec![1.0, -1.0]).unwrap();
        let v = c.push(0, 0, vec![("w".into(), GradEntry::Dense(g))]).unwrap();
        assert_eq!(v, 1);
        let (_, params) = c.pull().unwrap();
        // w -= 0.5 * g
        assert_eq!(params[0].1.as_f32().unwrap(), &[0.5, 2.5]);
        ps.shutdown();
    }

    #[test]
    fn second_init_loses_race() {
        let (ps, addr) = serve_async(Optimizer::sgd(0.1));
        let a = PsClient::connect(&addr, false).unwrap();
        let b = PsClient::connect(&addr, false).unwrap();
        assert!(a.init(&[("w".into(), Tensor::scalar_f32(1.0))]).unwrap());
        assert!(!b.init(&[("w".into(), Tensor::scalar_f32(9.0))]).unwrap());
        let (_, params) = b.pull().unwrap();
        assert_eq!(params[0].1.scalar_value_f32().unwrap(), 1.0);
        ps.shutdown();
    }

    #[test]
    fn pull_before_init_fails() {
        let (ps, addr) = serve_async(Optimizer::sgd(0.1));
        let c = PsClient::connect(&addr, false).unwrap();
        let e = c.pull().unwrap_err();
        assert_eq!(e.code, Code::FailedPrecondition);
        ps.shutdown();
    }

    #[test]
    fn hostile_pushes_rejected_state_untouched() {
        let (ps, addr) = serve_async(Optimizer::sgd(0.1));
        let c = PsClient::connect(&addr, false).unwrap();
        let w0 = Tensor::from_f32(vec![2, 2], vec![1., 2., 3., 4.]).unwrap();
        c.init(&[("w".into(), w0.clone())]).unwrap();

        // Unknown variable.
        let g = Tensor::from_f32(vec![2], vec![1., 1.]).unwrap();
        let e = c.push(0, 0, vec![("nope".into(), GradEntry::Dense(g))]).unwrap_err();
        assert_eq!(e.code, Code::NotFound);
        // Shape mismatch.
        let g = Tensor::from_f32(vec![3], vec![1., 1., 1.]).unwrap();
        let e = c.push(0, 0, vec![("w".into(), GradEntry::Dense(g))]).unwrap_err();
        assert_eq!(e.code, Code::InvalidArgument);
        // Out-of-bounds sparse row.
        let e = c
            .push(
                0,
                0,
                vec![(
                    "w".into(),
                    GradEntry::Sparse {
                        indices: Tensor::from_i64(vec![1], vec![5]).unwrap(),
                        values: Tensor::from_f32(vec![1, 2], vec![1., 1.]).unwrap(),
                    },
                )],
            )
            .unwrap_err();
        assert_eq!(e.code, Code::OutOfRange);
        // Negative sparse row.
        let e = c
            .push(
                0,
                0,
                vec![(
                    "w".into(),
                    GradEntry::Sparse {
                        indices: Tensor::from_i64(vec![1], vec![-1]).unwrap(),
                        values: Tensor::from_f32(vec![1, 2], vec![1., 1.]).unwrap(),
                    },
                )],
            )
            .unwrap_err();
        assert_eq!(e.code, Code::OutOfRange);

        // After all of that, state is bitwise untouched and version 0.
        assert_eq!(ps.version(), 0);
        let (v, params) = c.pull().unwrap();
        assert_eq!(v, 0);
        let got: Vec<u32> = params[0].1.as_f32().unwrap().iter().map(|x| x.to_bits()).collect();
        let want: Vec<u32> = w0.as_f32().unwrap().iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, want);
        ps.shutdown();
    }

    #[test]
    fn sparse_push_requires_sgd() {
        let (ps, addr) = serve_async(Optimizer::adam(0.01));
        let c = PsClient::connect(&addr, false).unwrap();
        c.init(&[("w".into(), Tensor::from_f32(vec![2, 2], vec![0.; 4]).unwrap())]).unwrap();
        let e = c
            .push(
                0,
                0,
                vec![(
                    "w".into(),
                    GradEntry::Sparse {
                        indices: Tensor::from_i64(vec![1], vec![0]).unwrap(),
                        values: Tensor::from_f32(vec![1, 2], vec![1., 1.]).unwrap(),
                    },
                )],
            )
            .unwrap_err();
        assert_eq!(e.code, Code::Unimplemented);
        ps.shutdown();
    }

    #[test]
    fn sparse_matches_dense_bitwise_single_replica() {
        // One server per mode, same initial values, same gradient content:
        // a sparse push of the nonzero rows must land on exactly the same
        // bits as a dense push with explicit zero rows.
        let init = Tensor::from_f32(vec![4, 2], vec![1., -2., 3., 0.5, -0.25, 8., 0.125, 7.])
            .unwrap();
        let dense_grad =
            Tensor::from_f32(vec![4, 2], vec![0., 0., 2.5, -1.5, 0., 0., 0.75, 0.25]).unwrap();

        let (ps_d, addr_d) = serve_async(Optimizer::sgd(0.3));
        let cd = PsClient::connect(&addr_d, false).unwrap();
        cd.init(&[("w".into(), init.clone())]).unwrap();
        cd.push(0, 0, vec![("w".into(), GradEntry::Dense(dense_grad))]).unwrap();

        let (ps_s, addr_s) = serve_async(Optimizer::sgd(0.3));
        let cs = PsClient::connect(&addr_s, false).unwrap();
        cs.init(&[("w".into(), init)]).unwrap();
        cs.push(
            0,
            0,
            vec![(
                "w".into(),
                GradEntry::Sparse {
                    indices: Tensor::from_i64(vec![2], vec![1, 3]).unwrap(),
                    values: Tensor::from_f32(vec![2, 2], vec![2.5, -1.5, 0.75, 0.25]).unwrap(),
                },
            )],
        )
        .unwrap();

        let d: Vec<u32> =
            ps_d.param("w").unwrap().as_f32().unwrap().iter().map(|x| x.to_bits()).collect();
        let s: Vec<u32> =
            ps_s.param("w").unwrap().as_f32().unwrap().iter().map(|x| x.to_bits()).collect();
        assert_eq!(d, s);
        ps_d.shutdown();
        ps_s.shutdown();
    }

    #[test]
    fn compression_negotiated_and_interoperates() {
        // lr and all values exactly representable so the expected result
        // is exact in f32 arithmetic.
        let (ps, addr) = serve_async(Optimizer::sgd(0.25));
        let plain = PsClient::connect(&addr, false).unwrap();
        let zipped = PsClient::connect(&addr, true).unwrap();
        assert!(!plain.compressed());
        assert!(zipped.compressed());
        // Values chosen exactly representable in bf16 so both channels
        // see identical numbers.
        plain.init(&[("w".into(), Tensor::from_f32(vec![2], vec![1.5, -0.25]).unwrap())]).unwrap();
        let (_, p1) = plain.pull().unwrap();
        let (_, p2) = zipped.pull().unwrap();
        assert_eq!(p1[0].1.as_f32().unwrap(), p2[0].1.as_f32().unwrap());
        // Compressed push from one client is visible to the plain one.
        let g = Tensor::from_f32(vec![2], vec![1.0, 2.0]).unwrap();
        zipped.push(0, 1, vec![("w".into(), GradEntry::Dense(g))]).unwrap();
        let (_, p3) = plain.pull().unwrap();
        assert_eq!(p3[0].1.as_f32().unwrap(), &[1.25, -0.75]);
        ps.shutdown();
    }

    #[test]
    fn tracing_and_unified_stats() {
        use crate::util::json::Json;
        let ps = ParamServer::new(PsOptions {
            opt: Optimizer::sgd(0.5),
            trace: true,
            ..Default::default()
        });
        let addr = ps.serve("127.0.0.1:0").unwrap().to_string();
        let c = PsClient::connect(&addr, false).unwrap();
        // Loopback offset must be tiny (both clocks are the same epoch).
        assert!(c.clock_offset_us().abs() < 1_000_000, "offset {}", c.clock_offset_us());
        c.init(&[("w".into(), Tensor::from_f32(vec![2], vec![1.0, 2.0]).unwrap())]).unwrap();
        let g = Tensor::from_f32(vec![2], vec![1.0, -1.0]).unwrap();
        c.push(0, 0, vec![("w".into(), GradEntry::Dense(g))]).unwrap();
        let _ = c.pull().unwrap();

        // MSG_PS_STATS serves the legacy keys AND the registry dump, with
        // per-message wire counters in it.
        let stats = c.stats().unwrap();
        let j = Json::parse(&stats).unwrap();
        assert_eq!(j.get("pushes").and_then(Json::as_f64), Some(1.0));
        let m = j.get("metrics").expect("metrics dump present");
        assert_eq!(m.get("ps/pushes").and_then(Json::as_i64), Some(1));
        assert_eq!(m.get("wire/PS_PUSH/frames_in").and_then(Json::as_i64), Some(1));
        assert!(m.get("wire/bytes_in_total").and_then(Json::as_i64).unwrap() > 0);
        assert_eq!(ps.metrics().counter_value("ps/pulls"), Some(1));
        assert!(ps.wire_bytes() > 0);

        // The trace pull drains recv + apply spans stamped with step 0.
        let frag = c.trace_pull().unwrap();
        assert_eq!(frag.process, "ps");
        assert!(frag.events.iter().any(|e| e.name == "ps/recv"));
        assert!(frag.events.iter().any(|e| e.name == "ps/apply"));
        assert!(frag.events.iter().all(|e| e.step == 0));
        // Drain semantics: a second pull is empty.
        assert!(c.trace_pull().unwrap().events.is_empty());
        ps.shutdown();
    }

    #[test]
    fn refuses_compression_when_disallowed() {
        let ps = ParamServer::new(PsOptions {
            opt: Optimizer::sgd(0.1),
            allow_compression: false,
            ..Default::default()
        });
        let addr = ps.serve("127.0.0.1:0").unwrap().to_string();
        let c = PsClient::connect(&addr, true).unwrap();
        assert!(!c.compressed(), "server must negotiate compression away");
        ps.shutdown();
    }

    #[test]
    fn sync_arrival_lag_names_the_straggler() {
        let ps = ParamServer::new(PsOptions {
            opt: Optimizer::sgd(0.1),
            sync_replicas: Some(2),
            ..Default::default()
        });
        let addr = ps.serve("127.0.0.1:0").unwrap().to_string();
        let c0 = PsClient::connect(&addr, false).unwrap();
        assert!(c0.init(&[("w".into(), Tensor::scalar_f32(1.0))]).unwrap());
        // Replica 1 sleeps before each push: the injected straggler.
        for step in 0..3u64 {
            let slow_addr = addr.clone();
            let slow = std::thread::spawn(move || {
                let c1 = PsClient::connect(&slow_addr, false).unwrap();
                std::thread::sleep(Duration::from_millis(25));
                let g = Tensor::scalar_f32(0.5);
                c1.push(step, 1, vec![("w".into(), GradEntry::Dense(g))]).unwrap()
            });
            let g = Tensor::scalar_f32(0.5);
            assert_eq!(
                c0.push(step, 0, vec![("w".into(), GradEntry::Dense(g))]).unwrap(),
                step + 1
            );
            assert_eq!(slow.join().unwrap(), step + 1);
        }
        // The straggler must be identifiable from the arrival-lag
        // histograms alone — no trace, no clocks shared with the client.
        let report = straggler_report(ps.metrics()).expect("lag histograms after sync pushes");
        assert_eq!(report.replicas.len(), 2);
        assert_eq!(report.slowest, 1);
        let slow = report.slowest_wait().unwrap();
        assert_eq!(slow.count, 3);
        assert!(
            slow.p95_us >= 20_000,
            "injected 25ms sleep must dominate the lag: {} us",
            slow.p95_us
        );
        let fast = report.replicas.iter().find(|r| r.replica == 0).unwrap();
        assert!(
            fast.p95_us < slow.p95_us / 2,
            "fast replica p95 {} us should be far below slow {} us",
            fast.p95_us,
            slow.p95_us
        );
        ps.shutdown();
    }

    #[test]
    fn httpz_surface_serves_health_varz_statusz() {
        let ps = ParamServer::new(PsOptions {
            opt: Optimizer::sgd(0.5),
            trace: true,
            ..Default::default()
        });
        let addr = ps.serve("127.0.0.1:0").unwrap().to_string();
        let dbg = ps.serve_httpz("127.0.0.1:0").unwrap();
        let dbg_addr = dbg.addr();

        let c = PsClient::connect(&addr, false).unwrap();
        c.init(&[("w".into(), Tensor::scalar_f32(1.0))]).unwrap();
        let g = Tensor::scalar_f32(1.0);
        c.push(0, 0, vec![("w".into(), GradEntry::Dense(g))]).unwrap();

        let (code, body) = crate::obs::httpz::get(dbg_addr, "/healthz").unwrap();
        assert_eq!((code, body.as_str()), (200, "ok\n"));
        let (code, body) = crate::obs::httpz::get(dbg_addr, "/varz").unwrap();
        assert_eq!(code, 200);
        assert!(body.contains("ps_push_wire_bytes") || body.contains("# TYPE"));
        let (code, body) = crate::obs::httpz::get(dbg_addr, "/statusz").unwrap();
        assert_eq!(code, 200);
        assert!(body.contains("parameter server v1"));
        assert!(body.contains("ps/recv"), "statusz must name the recv phase: {body}");
        assert!(body.contains("ps/apply"), "statusz must name the apply phase: {body}");
        let (code, body) = crate::obs::httpz::get(dbg_addr, "/tracez").unwrap();
        assert_eq!(code, 200);
        assert!(body.trim_start().starts_with('['), "chrome trace is array-form: {body}");
        assert!(body.contains("ps/recv"), "trace must hold the recv span: {body}");

        ps.shutdown();
        let (code, _) = crate::obs::httpz::get(dbg_addr, "/healthz").unwrap();
        assert_eq!(code, 503, "healthz flips once the server is shutting down");
        dbg.shutdown();
    }
}
