//! `DistTrainer`: the replica-side driver for data-parallel training
//! against parameter-server shards (§4.4, Fig 7). Each replica owns a
//! local [`Session`] holding the model graph with **gradient-only** train
//! outputs ([`crate::replicate::tower_gradients`] — no Apply ops; the
//! update lives on the servers), plus one [`PsClient`] channel per shard.
//!
//! A step is pull → assign → compute → push:
//!
//! 1. pull every shard's parameters (tracking each shard's version),
//! 2. write them into the local variables through a grouped Assign subgraph
//!    fed by `ps_in/<var>` placeholders,
//! 3. run the graph once, fetching the loss and every gradient,
//! 4. push the gradients back, tagged with the pulled version — the
//!    staleness token the synchronous server checks.
//!
//! Variables are sharded over the servers by a stable name hash, so every
//! replica agrees on the layout without coordination. Every shard gets a
//! push every step (possibly with no entries): that keeps shard versions
//! in lockstep, which is what lets one `step()` call block on all shards'
//! sync barriers simultaneously.
//!
//! Gradient compression is per-channel (negotiated at connect, see
//! [`super::proto::CHANNEL_BF16`]); embedding-shaped gradients whose
//! touched-row fraction is below
//! [`DistTrainerOptions::sparse_row_threshold`] travel row-sparse when
//! `sparse_push` is on.
//!
//! Gradients that autodiff already produced as `IndexedSlices`
//! ([`GraphBuilder::sparse_grads`] — the `Gather`/sampled-softmax path)
//! skip the densify node *and* the sniffer entirely: the trainer fetches
//! the (indices, values) twins and ships [`GradEntry::Sparse`] natively,
//! so the dense `[vocab, dim]` gradient never exists anywhere — not in
//! the executor, not on the wire.

use super::proto::GradEntry;
use super::ps::PsClient;
use crate::error::{Result, Status};
use crate::graph::Endpoint;
use crate::ops::builder::GraphBuilder;
use crate::replicate::tower_gradients;
use crate::session::{Session, SessionOptions};
use crate::tensor::{DType, Tensor};
use crate::tracing_tools::{merge_fragments, TraceCollector, TraceFragment};
use std::sync::Arc;
use std::time::Instant;

/// Replica-side knobs.
#[derive(Debug, Clone)]
pub struct DistTrainerOptions {
    /// Request bf16 channel compression from every shard (§5.5). The
    /// server may still negotiate it away; training works either way.
    pub compress: bool,
    /// Detect row-sparse gradients (embedding updates) and push only the
    /// touched rows.
    pub sparse_push: bool,
    /// Push sparse only when `touched_rows / rows` is at or below this
    /// fraction (above it, dense is smaller or comparable on the wire).
    pub sparse_row_threshold: f64,
    /// Ship `IndexedSlices` gradients natively (fetch the twins, never
    /// densify). Off forces the dense handle path — A/B support for
    /// measuring what the sparse wire format saves.
    pub native_sparse: bool,
}

impl Default for DistTrainerOptions {
    fn default() -> Self {
        DistTrainerOptions {
            compress: true,
            sparse_push: false,
            sparse_row_threshold: 0.5,
            native_sparse: true,
        }
    }
}

/// Stable shard assignment: FNV-1a over the variable name. Every replica
/// computes the same layout with no coordination.
fn shard_of(name: &str, shards: usize) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    (h % shards as u64) as usize
}

pub struct DistTrainer {
    sess: Session,
    replica: u32,
    clients: Vec<PsClient>,
    var_names: Vec<String>,
    /// Shard index per variable, aligned with `var_names`.
    var_shard: Vec<usize>,
    loss_fetch: String,
    /// Flat fetch list: one name per dense gradient, two consecutive
    /// names (indices, values) per natively-sparse gradient.
    grad_fetches: Vec<String>,
    /// Per variable: does its gradient ride the native IndexedSlices
    /// path (two fetches) instead of a dense handle (one fetch)?
    grad_sparse: Vec<bool>,
    /// `ps_in/<var>` placeholder names, aligned with `var_names`.
    assign_feeds: Vec<String>,
    pull_assign: String,
    init_ops: Vec<String>,
    /// Last pulled version per shard — the staleness token for pushes.
    shard_version: Vec<u64>,
    options: DistTrainerOptions,
    steps: u64,
    /// Present when the session traces: accumulates pull/compute/push
    /// phase spans plus the session's per-kernel spans, re-tagged with
    /// the distributed step number.
    trace: Option<Arc<TraceCollector>>,
}

impl DistTrainer {
    /// Take ownership of a built model (`loss` + its `vars`), extend it
    /// with gradient fetches and the parameter-injection subgraph, and
    /// connect to the shard servers. The graph must not already contain
    /// Apply ops for these variables — the servers own the update.
    pub fn new(
        mut b: GraphBuilder,
        loss: Endpoint,
        vars: &[Endpoint],
        replica: u32,
        ps_addrs: &[String],
        options: DistTrainerOptions,
        session_options: SessionOptions,
    ) -> Result<DistTrainer> {
        if ps_addrs.is_empty() {
            return Err(Status::invalid_argument("no parameter-server shards"));
        }
        if vars.is_empty() {
            return Err(Status::invalid_argument("no variables to train"));
        }
        let var_names: Vec<String> =
            vars.iter().map(|v| b.graph.node(v.node).name.clone()).collect();
        let var_shard: Vec<usize> =
            var_names.iter().map(|n| shard_of(n, ps_addrs.len())).collect();

        let grads = tower_gradients(&mut b, loss, vars)?;
        let fetch_name = |b: &GraphBuilder, e: Endpoint| {
            format!("{}:{}", b.graph.node(e.node).name, e.port)
        };
        // Natively-sparse gradients fetch their (indices, values) twins;
        // the lazy SparseToDense handle is left unfetched and therefore
        // never executes.
        let mut grad_fetches: Vec<String> = Vec::with_capacity(grads.len());
        let mut grad_sparse: Vec<bool> = Vec::with_capacity(grads.len());
        for g in &grads {
            match crate::sparse::as_sparse(&b, *g).filter(|_| options.native_sparse) {
                Some(s) => {
                    grad_sparse.push(true);
                    grad_fetches.push(fetch_name(&b, s.indices));
                    grad_fetches.push(fetch_name(&b, s.values));
                }
                None => {
                    grad_sparse.push(false);
                    grad_fetches.push(fetch_name(&b, *g));
                }
            }
        }
        let loss_fetch = format!("{}:{}", b.graph.node(loss.node).name, loss.port);

        // The injection subgraph: one placeholder + Assign per variable,
        // grouped so a single target runs them all.
        let mut assign_feeds = Vec::with_capacity(vars.len());
        let mut assigns = Vec::with_capacity(vars.len());
        for (var, name) in vars.iter().zip(&var_names) {
            let ph_name = format!("ps_in/{name}");
            let ph = b.placeholder(&ph_name, DType::F32)?;
            assigns.push(b.assign(*var, ph)?);
            assign_feeds.push(ph_name);
        }
        let pull_assign_node = b.group("ps/pull_assign", assigns);
        let pull_assign = b.graph.node(pull_assign_node).name.clone();
        let init_ops: Vec<String> =
            b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();

        // The session's trace flag drives replica tracing too: one knob
        // turns on the whole distributed EEG for this replica.
        let trace = session_options
            .trace
            .then(|| TraceCollector::for_step(&format!("replica:{replica}"), 0));
        let sess = Session::new(b.into_graph(), session_options);
        let clients = ps_addrs
            .iter()
            .map(|a| PsClient::connect(a, options.compress))
            .collect::<Result<Vec<_>>>()?;
        let shard_version = vec![0; clients.len()];
        Ok(DistTrainer {
            sess,
            replica,
            clients,
            var_names,
            var_shard,
            loss_fetch,
            grad_fetches,
            grad_sparse,
            assign_feeds,
            pull_assign,
            init_ops,
            shard_version,
            options,
            steps: 0,
            trace,
        })
    }

    /// Run the local initializers and offer the values to every shard
    /// (first replica wins; later ones pull the winner's values on their
    /// next step). Returns whether this replica seeded any shard.
    pub fn init_params(&self) -> Result<bool> {
        let init_refs: Vec<&str> = self.init_ops.iter().map(String::as_str).collect();
        self.sess.run_targets(&init_refs)?;
        let name_refs: Vec<&str> = self.var_names.iter().map(String::as_str).collect();
        let vals = self.sess.run(&[], &name_refs, &[])?;
        let mut per_shard: Vec<Vec<(String, Tensor)>> = vec![Vec::new(); self.clients.len()];
        for ((name, shard), val) in self.var_names.iter().zip(&self.var_shard).zip(vals) {
            per_shard[*shard].push((name.clone(), val));
        }
        let mut seeded = false;
        for (client, params) in self.clients.iter().zip(&per_shard) {
            seeded |= client.init(params)?;
        }
        Ok(seeded)
    }

    /// Pull every shard and assign into the local variables.
    pub fn pull(&mut self) -> Result<()> {
        let mut feeds: Vec<(String, Tensor)> = Vec::with_capacity(self.var_names.len());
        for (s, client) in self.clients.iter().enumerate() {
            let (version, params) = client.pull()?;
            self.shard_version[s] = version;
            for (name, t) in params {
                feeds.push((format!("ps_in/{name}"), t));
            }
        }
        let refs: Vec<(&str, Tensor)> =
            feeds.iter().map(|(k, t)| (k.as_str(), t.clone())).collect();
        self.sess.run(&refs, &[], &[self.pull_assign.as_str()])?;
        Ok(())
    }

    /// One training step: pull → compute → push. Returns the step's loss
    /// (computed against the parameters just pulled). In synchronous mode
    /// this blocks until every replica's push for the step is applied.
    pub fn step(&mut self, feeds: &[(&str, Tensor)]) -> Result<f32> {
        let step_no = self.steps;
        let me = format!("replica:{}", self.replica);
        let span =
            self.trace.as_ref().map(|t| t.begin_step("replica/pull", "DistPull", &me, step_no));
        let phase_start = Instant::now();
        let pulled = self.pull();
        self.observe_phase("replica/pull", "DistPull", phase_start);
        if let Some(s) = span {
            s.end();
        }
        pulled?;
        let mut fetches: Vec<&str> = Vec::with_capacity(1 + self.grad_fetches.len());
        fetches.push(self.loss_fetch.as_str());
        fetches.extend(self.grad_fetches.iter().map(String::as_str));
        let span = self
            .trace
            .as_ref()
            .map(|t| t.begin_step("replica/compute", "DistCompute", &me, step_no));
        let phase_start = Instant::now();
        let out = self.sess.run(feeds, &fetches, &[]);
        self.observe_phase("replica/compute", "DistCompute", phase_start);
        if let Some(s) = span {
            s.end();
        }
        let out = out?;
        // Pick up the session's per-kernel spans for the compute run,
        // re-tagged with the distributed step number (the session counts
        // its own runs — pull-assign runs included — separately).
        if let Some(acc) = &self.trace {
            if let Some(st) = self.sess.last_trace() {
                let mut evs = st.drain();
                for e in &mut evs {
                    e.step = step_no;
                }
                acc.absorb(evs);
            }
        }
        let loss = out[0].scalar_value_f32()?;

        let mut per_shard: Vec<Vec<(String, GradEntry)>> =
            vec![Vec::new(); self.clients.len()];
        let mut it = out.into_iter().skip(1);
        let mut next = || {
            it.next().ok_or_else(|| Status::internal("fewer fetch results than gradients"))
        };
        for ((name, shard), native_sparse) in
            self.var_names.iter().zip(&self.var_shard).zip(&self.grad_sparse)
        {
            let entry = if *native_sparse {
                // IndexedSlices straight off the graph — no densify, no
                // sniffing, the wire form is the gradient's own form.
                let indices = next()?;
                let values = next()?;
                GradEntry::Sparse { indices, values }
            } else {
                let grad = next()?;
                if self.options.sparse_push {
                    match sparsify(&grad, self.options.sparse_row_threshold) {
                        Some((indices, values)) => GradEntry::Sparse { indices, values },
                        None => GradEntry::Dense(grad),
                    }
                } else {
                    GradEntry::Dense(grad)
                }
            };
            per_shard[*shard].push((name.clone(), entry));
        }
        // Every shard gets a push — empty ones included — so shard
        // versions advance in lockstep.
        let span =
            self.trace.as_ref().map(|t| t.begin_step("replica/push", "DistPush", &me, step_no));
        let phase_start = Instant::now();
        let mut pushed = Ok(());
        for (s, grads) in per_shard.into_iter().enumerate() {
            pushed = self.clients[s].push(self.shard_version[s], self.replica, grads).map(|_| ());
            if pushed.is_err() {
                break;
            }
        }
        self.observe_phase("replica/push", "DistPush", phase_start);
        if let Some(s) = span {
            s.end();
        }
        pushed?;
        self.steps += 1;
        Ok(loss)
    }

    /// Feed a pull/compute/push phase duration into the session's
    /// profiler, so the replica's `/statusz` shows where the step goes —
    /// a no-op when profiling is off (`profile_window: 0`).
    fn observe_phase(&self, name: &str, op: &str, start: Instant) {
        if let Some(p) = self.sess.profiler() {
            p.observe_span(name, op, start.elapsed());
        }
    }

    /// Steps completed by this replica.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// The local session (test support: inspect variables between steps).
    pub fn session(&self) -> &Session {
        &self.sess
    }

    /// Whether shard 0's channel negotiated compression.
    pub fn compressed(&self) -> bool {
        self.clients.first().map(PsClient::compressed).unwrap_or(false)
    }

    /// The `ps_in/<var>` placeholder names, aligned with the variables
    /// (test support).
    pub fn assign_feeds(&self) -> &[String] {
        &self.assign_feeds
    }

    /// Per-variable flags: true where the gradient rides the native
    /// IndexedSlices wire path (test support).
    pub fn native_sparse(&self) -> &[bool] {
        &self.grad_sparse
    }

    /// Per-shard stats JSON from every server.
    pub fn shard_stats(&self) -> Result<Vec<String>> {
        self.clients.iter().map(PsClient::stats).collect()
    }

    /// Drain this replica's accumulated spans as a fragment (`None` when
    /// the session was built without `trace`).
    pub fn take_trace(&self) -> Option<TraceFragment> {
        self.trace.as_ref().map(|t| t.take_fragment())
    }

    /// Drain every shard's server-side spans, each paired with that
    /// channel's estimated clock offset — ready for
    /// [`crate::tracing_tools::merge_fragments`].
    pub fn pull_shard_traces(&self) -> Result<Vec<(TraceFragment, i64)>> {
        self.clients
            .iter()
            .map(|c| Ok((c.trace_pull()?, c.clock_offset_us())))
            .collect()
    }

    /// One chrome://tracing JSON reconstructing the distributed step(s)
    /// end to end: this replica's spans, every parameter-server shard's
    /// (clock-aligned via the HELLO offsets), and any `extra` fragments
    /// from peer replicas (offset 0 — in-process peers share our trace
    /// epoch). Drains every collector involved.
    pub fn merged_trace(&self, extra: Vec<TraceFragment>) -> Result<String> {
        let mut parts: Vec<(TraceFragment, i64)> = Vec::new();
        if let Some(own) = self.take_trace() {
            parts.push((own, 0));
        }
        for frag in extra {
            parts.push((frag, 0));
        }
        parts.extend(self.pull_shard_traces()?);
        Ok(merge_fragments(parts).to_chrome_trace())
    }
}

/// Row-sparse detection: the touched rows of `g` (first-dimension slices
/// with any nonzero), as (indices `[k]` i64, values `[k, rest…]`), when
/// they are few enough to be worth shipping sparse.
fn sparsify(g: &Tensor, threshold: f64) -> Option<(Tensor, Tensor)> {
    if g.shape().rank() < 1 {
        return None;
    }
    let rows = g.shape().dims()[0];
    if rows == 0 {
        return None;
    }
    let v = g.as_f32().ok()?;
    let row_len = v.len() / rows;
    let mut idx: Vec<i64> = Vec::new();
    for r in 0..rows {
        if v[r * row_len..(r + 1) * row_len].iter().any(|&x| x != 0.0) {
            idx.push(r as i64);
        }
    }
    if idx.len() == rows || (idx.len() as f64) > threshold * rows as f64 {
        return None;
    }
    let mut vals = Vec::with_capacity(idx.len() * row_len);
    for &r in &idx {
        let r = r as usize;
        vals.extend_from_slice(&v[r * row_len..(r + 1) * row_len]);
    }
    let mut vshape = g.shape().dims().to_vec();
    vshape[0] = idx.len();
    let indices = Tensor::from_i64(vec![idx.len()], idx).ok()?;
    let values = Tensor::from_f32(vshape, vals).ok()?;
    Some((indices, values))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_assignment_is_stable_and_total() {
        for shards in 1..5 {
            for name in ["w0", "w1", "bias", "emb/table"] {
                let s = shard_of(name, shards);
                assert!(s < shards);
                assert_eq!(s, shard_of(name, shards), "stable");
            }
        }
    }

    #[test]
    fn sparsify_picks_touched_rows() {
        let g =
            Tensor::from_f32(vec![4, 2], vec![0., 0., 1., 2., 0., 0., 0., 0.]).unwrap();
        let (idx, vals) = sparsify(&g, 0.5).unwrap();
        assert_eq!(idx.as_i64().unwrap(), &[1]);
        assert_eq!(vals.shape().dims(), &[1, 2]);
        assert_eq!(vals.as_f32().unwrap(), &[1., 2.]);
    }

    #[test]
    fn sparsify_declines_dense_gradients() {
        let g = Tensor::from_f32(vec![2, 2], vec![1., 1., 1., 1.]).unwrap();
        assert!(sparsify(&g, 0.5).is_none());
        // Scalars can't be row-sparse.
        assert!(sparsify(&Tensor::scalar_f32(1.0), 0.5).is_none());
    }

    #[test]
    fn sparsify_respects_threshold() {
        // 2 of 4 rows touched: allowed at 0.5, refused below it.
        let g =
            Tensor::from_f32(vec![4, 1], vec![1., 0., 2., 0.]).unwrap();
        assert!(sparsify(&g, 0.5).is_some());
        assert!(sparsify(&g, 0.25).is_none());
    }
}
