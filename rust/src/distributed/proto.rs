//! Wire protocol between master ⇄ worker and worker ⇄ worker (§3.3).
//! Length-prefixed frames over TCP; payloads reuse the graph/tensor
//! codecs. ("Send/Receive node pairs that communicate across worker
//! processes use remote communication mechanisms such as TCP or RDMA.")

use crate::error::{Code, Result, Status};
use crate::graph::Graph;
use crate::tensor::{codec, Tensor};
use crate::util::byteorder::LittleEndian;
use std::io::{Read, Write};
use std::net::TcpStream;

pub const MSG_REGISTER_GRAPH: u8 = 1;
pub const MSG_REGISTER_REPLY: u8 = 2;
pub const MSG_RUN_PARTITION: u8 = 3;
pub const MSG_RUN_REPLY: u8 = 4;
pub const MSG_RECV_TENSOR: u8 = 5;
pub const MSG_TENSOR_REPLY: u8 = 6;
pub const MSG_HEALTH: u8 = 7;
pub const MSG_HEALTH_OK: u8 = 8;
pub const MSG_SHUTDOWN: u8 = 9;
pub const MSG_RESET: u8 = 10;

/// Write one frame: u32 length, u8 type, payload.
pub fn write_frame(stream: &mut TcpStream, msg_type: u8, payload: &[u8]) -> Result<()> {
    let mut header = [0u8; 5];
    LittleEndian::write_u32(&mut header, payload.len() as u32 + 1);
    header[4] = msg_type;
    stream.write_all(&header)?;
    stream.write_all(payload)?;
    stream.flush()?;
    Ok(())
}

/// Read one frame.
pub fn read_frame(stream: &mut TcpStream) -> Result<(u8, Vec<u8>)> {
    let mut header = [0u8; 5];
    stream.read_exact(&mut header)?;
    let len = LittleEndian::read_u32(&header) as usize;
    if len == 0 {
        return Err(Status::unavailable("empty frame"));
    }
    let msg_type = header[4];
    let mut payload = vec![0u8; len - 1];
    stream.read_exact(&mut payload)?;
    Ok((msg_type, payload))
}

// ---- message payloads -------------------------------------------------------

pub struct RegisterGraph {
    pub graph: Graph,
}

impl RegisterGraph {
    pub fn encode(&self) -> Vec<u8> {
        crate::graph::serde::encode_graph(&self.graph)
    }

    pub fn decode(buf: &[u8]) -> Result<RegisterGraph> {
        Ok(RegisterGraph { graph: crate::graph::serde::decode_graph(buf)? })
    }
}

pub struct RunPartition {
    pub handle: u64,
    pub step_id: u64,
    pub feeds: Vec<(String, Tensor)>,
}

impl RunPartition {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        let mut b = [0u8; 8];
        LittleEndian::write_u64(&mut b, self.handle);
        out.extend_from_slice(&b);
        LittleEndian::write_u64(&mut b, self.step_id);
        out.extend_from_slice(&b);
        encode_tensor_map(&mut out, &self.feeds);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<RunPartition> {
        if buf.len() < 16 {
            return Err(Status::invalid_argument("short RunPartition"));
        }
        let handle = LittleEndian::read_u64(&buf[0..8]);
        let step_id = LittleEndian::read_u64(&buf[8..16]);
        let mut pos = 16;
        let feeds = decode_tensor_map(buf, &mut pos)?;
        Ok(RunPartition { handle, step_id, feeds })
    }
}

pub struct RunReply {
    pub status: Result<()>,
    pub fetches: Vec<(String, Tensor)>,
}

impl RunReply {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        encode_status(&mut out, &self.status);
        encode_tensor_map(&mut out, &self.fetches);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<RunReply> {
        let mut pos = 0;
        let status = decode_status(buf, &mut pos)?;
        let fetches = decode_tensor_map(buf, &mut pos)?;
        Ok(RunReply { status, fetches })
    }
}

pub struct TensorReply {
    pub status: Result<Tensor>,
}

impl TensorReply {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match &self.status {
            Ok(t) => {
                encode_status(&mut out, &Ok(()));
                out.extend(codec::encode(t));
            }
            Err(e) => encode_status(&mut out, &Err(e.clone())),
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<TensorReply> {
        let mut pos = 0;
        let status = decode_status(buf, &mut pos)?;
        match status {
            Ok(()) => {
                let (t, _) = codec::decode(&buf[pos..])?;
                Ok(TensorReply { status: Ok(t) })
            }
            Err(e) => Ok(TensorReply { status: Err(e) }),
        }
    }
}

fn encode_status(out: &mut Vec<u8>, s: &Result<()>) {
    match s {
        Ok(()) => {
            out.push(255);
        }
        Err(e) => {
            out.push(e.code.as_u8());
            let msg = e.message.as_bytes();
            let mut b = [0u8; 4];
            LittleEndian::write_u32(&mut b, msg.len() as u32);
            out.extend_from_slice(&b);
            out.extend_from_slice(msg);
        }
    }
}

fn decode_status(buf: &[u8], pos: &mut usize) -> Result<Result<()>> {
    if buf.len() <= *pos {
        return Err(Status::invalid_argument("short status"));
    }
    let code = buf[*pos];
    *pos += 1;
    if code == 255 {
        return Ok(Ok(()));
    }
    if buf.len() < *pos + 4 {
        return Err(Status::invalid_argument("short status message"));
    }
    let len = LittleEndian::read_u32(&buf[*pos..]) as usize;
    *pos += 4;
    if buf.len() < *pos + len {
        return Err(Status::invalid_argument("short status message body"));
    }
    let msg = String::from_utf8_lossy(&buf[*pos..*pos + len]).to_string();
    *pos += len;
    Ok(Err(Status::new(Code::from_u8(code), msg)))
}

fn encode_tensor_map(out: &mut Vec<u8>, m: &[(String, Tensor)]) {
    let mut b = [0u8; 4];
    LittleEndian::write_u32(&mut b, m.len() as u32);
    out.extend_from_slice(&b);
    for (k, t) in m {
        LittleEndian::write_u32(&mut b, k.len() as u32);
        out.extend_from_slice(&b);
        out.extend_from_slice(k.as_bytes());
        let payload = codec::encode(t);
        let mut l = [0u8; 8];
        LittleEndian::write_u64(&mut l, payload.len() as u64);
        out.extend_from_slice(&l);
        out.extend_from_slice(&payload);
    }
}

fn decode_tensor_map(buf: &[u8], pos: &mut usize) -> Result<Vec<(String, Tensor)>> {
    if buf.len() < *pos + 4 {
        return Err(Status::invalid_argument("short tensor map"));
    }
    let n = LittleEndian::read_u32(&buf[*pos..]) as usize;
    *pos += 4;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        if buf.len() < *pos + 4 {
            return Err(Status::invalid_argument("short tensor map key"));
        }
        let klen = LittleEndian::read_u32(&buf[*pos..]) as usize;
        *pos += 4;
        let key = String::from_utf8_lossy(&buf[*pos..*pos + klen]).to_string();
        *pos += klen;
        let plen = LittleEndian::read_u64(&buf[*pos..]) as usize;
        *pos += 8;
        let (t, used) = codec::decode(&buf[*pos..*pos + plen])?;
        if used != plen {
            return Err(Status::invalid_argument("tensor map payload mismatch"));
        }
        *pos += plen;
        out.push((key, t));
    }
    Ok(out)
}

/// One-shot RPC helper: connect, send, await reply.
pub fn rpc(addr: &str, msg_type: u8, payload: &[u8]) -> Result<(u8, Vec<u8>)> {
    let mut stream = TcpStream::connect(addr)
        .map_err(|e| Status::unavailable(format!("connect {addr}: {e}")))?;
    stream.set_nodelay(true).ok();
    write_frame(&mut stream, msg_type, payload)?;
    read_frame(&mut stream)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_partition_roundtrip() {
        let msg = RunPartition {
            handle: 7,
            step_id: 42,
            feeds: vec![
                ("feed;x:0".into(), Tensor::scalar_f32(1.5)),
                ("feed;y:0".into(), Tensor::from_i64(vec![2], vec![1, 2]).unwrap()),
            ],
        };
        let dec = RunPartition::decode(&msg.encode()).unwrap();
        assert_eq!(dec.handle, 7);
        assert_eq!(dec.step_id, 42);
        assert_eq!(dec.feeds.len(), 2);
        assert_eq!(dec.feeds[0].0, "feed;x:0");
        assert_eq!(dec.feeds[1].1.as_i64().unwrap(), &[1, 2]);
    }

    #[test]
    fn run_reply_roundtrip_ok_and_err() {
        let ok = RunReply {
            status: Ok(()),
            fetches: vec![("loss:0".into(), Tensor::scalar_f32(0.5))],
        };
        let dec = RunReply::decode(&ok.encode()).unwrap();
        assert!(dec.status.is_ok());
        assert_eq!(dec.fetches[0].1.scalar_value_f32().unwrap(), 0.5);

        let err = RunReply {
            status: Err(Status::aborted("worker lost")),
            fetches: vec![],
        };
        let dec = RunReply::decode(&err.encode()).unwrap();
        let e = dec.status.unwrap_err();
        assert_eq!(e.code, Code::Aborted);
        assert_eq!(e.message, "worker lost");
    }

    #[test]
    fn tensor_reply_roundtrip() {
        let r = TensorReply { status: Ok(Tensor::from_f32(vec![3], vec![1., 2., 3.]).unwrap()) };
        let dec = TensorReply::decode(&r.encode()).unwrap();
        assert_eq!(dec.status.unwrap().as_f32().unwrap(), &[1., 2., 3.]);
        let e = TensorReply { status: Err(Status::not_found("no key")) };
        let dec = TensorReply::decode(&e.encode()).unwrap();
        assert_eq!(dec.status.unwrap_err().code, Code::NotFound);
    }

    #[test]
    fn frames_over_localhost() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let (t, p) = read_frame(&mut s).unwrap();
            assert_eq!(t, MSG_HEALTH);
            assert_eq!(p, b"ping");
            write_frame(&mut s, MSG_HEALTH_OK, b"pong").unwrap();
        });
        let (t, p) = rpc(&addr.to_string(), MSG_HEALTH, b"ping").unwrap();
        assert_eq!(t, MSG_HEALTH_OK);
        assert_eq!(p, b"pong");
        server.join().unwrap();
    }
}
