//! Wire protocol between master ⇄ worker and worker ⇄ worker (§3.3).
//! Length-prefixed frames over TCP; payloads reuse the graph/tensor
//! codecs. ("Send/Receive node pairs that communicate across worker
//! processes use remote communication mechanisms such as TCP or RDMA.")
//!
//! The transport itself — framing, status, tensor maps — lives in
//! [`crate::wire`], shared with the serving front end
//! (`crate::serving::net`); this module keeps the distributed message
//! types and their payload layouts.

use crate::error::{Result, Status};
use crate::graph::Graph;
use crate::tensor::Tensor;
use crate::wire::{
    decode_status, decode_tensor_map, encode_status, encode_tensor_map, get_u64, put_u64,
};

pub use crate::wire::{read_frame, rpc, write_frame};

pub const MSG_REGISTER_GRAPH: u8 = 1;
pub const MSG_REGISTER_REPLY: u8 = 2;
pub const MSG_RUN_PARTITION: u8 = 3;
pub const MSG_RUN_REPLY: u8 = 4;
pub const MSG_RECV_TENSOR: u8 = 5;
pub const MSG_TENSOR_REPLY: u8 = 6;
pub const MSG_HEALTH: u8 = 7;
pub const MSG_HEALTH_OK: u8 = 8;
pub const MSG_SHUTDOWN: u8 = 9;
pub const MSG_RESET: u8 = 10;

// ---- message payloads -------------------------------------------------------

pub struct RegisterGraph {
    pub graph: Graph,
}

impl RegisterGraph {
    pub fn encode(&self) -> Vec<u8> {
        crate::graph::serde::encode_graph(&self.graph)
    }

    pub fn decode(buf: &[u8]) -> Result<RegisterGraph> {
        Ok(RegisterGraph { graph: crate::graph::serde::decode_graph(buf)? })
    }
}

pub struct RunPartition {
    pub handle: u64,
    pub step_id: u64,
    pub feeds: Vec<(String, Tensor)>,
}

impl RunPartition {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        put_u64(&mut out, self.handle);
        put_u64(&mut out, self.step_id);
        encode_tensor_map(&mut out, &self.feeds);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<RunPartition> {
        let mut pos = 0;
        let handle = get_u64(buf, &mut pos)
            .map_err(|_| Status::invalid_argument("short RunPartition"))?;
        let step_id = get_u64(buf, &mut pos)
            .map_err(|_| Status::invalid_argument("short RunPartition"))?;
        let feeds = decode_tensor_map(buf, &mut pos)?;
        Ok(RunPartition { handle, step_id, feeds })
    }
}

pub struct RunReply {
    pub status: Result<()>,
    pub fetches: Vec<(String, Tensor)>,
}

impl RunReply {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        encode_status(&mut out, &self.status);
        encode_tensor_map(&mut out, &self.fetches);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<RunReply> {
        let mut pos = 0;
        let status = decode_status(buf, &mut pos)?;
        let fetches = decode_tensor_map(buf, &mut pos)?;
        Ok(RunReply { status, fetches })
    }
}

pub struct TensorReply {
    pub status: Result<Tensor>,
}

impl TensorReply {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match &self.status {
            Ok(t) => {
                encode_status(&mut out, &Ok(()));
                out.extend(crate::tensor::codec::encode(t));
            }
            Err(e) => encode_status(&mut out, &Err(e.clone())),
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<TensorReply> {
        let mut pos = 0;
        let status = decode_status(buf, &mut pos)?;
        match status {
            Ok(()) => {
                let (t, _) = crate::tensor::codec::decode(&buf[pos..])?;
                Ok(TensorReply { status: Ok(t) })
            }
            Err(e) => Ok(TensorReply { status: Err(e) }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Code;

    #[test]
    fn run_partition_roundtrip() {
        let msg = RunPartition {
            handle: 7,
            step_id: 42,
            feeds: vec![
                ("feed;x:0".into(), Tensor::scalar_f32(1.5)),
                ("feed;y:0".into(), Tensor::from_i64(vec![2], vec![1, 2]).unwrap()),
            ],
        };
        let dec = RunPartition::decode(&msg.encode()).unwrap();
        assert_eq!(dec.handle, 7);
        assert_eq!(dec.step_id, 42);
        assert_eq!(dec.feeds.len(), 2);
        assert_eq!(dec.feeds[0].0, "feed;x:0");
        assert_eq!(dec.feeds[1].1.as_i64().unwrap(), &[1, 2]);
    }

    #[test]
    fn run_reply_roundtrip_ok_and_err() {
        let ok = RunReply {
            status: Ok(()),
            fetches: vec![("loss:0".into(), Tensor::scalar_f32(0.5))],
        };
        let dec = RunReply::decode(&ok.encode()).unwrap();
        assert!(dec.status.is_ok());
        assert_eq!(dec.fetches[0].1.scalar_value_f32().unwrap(), 0.5);

        let err = RunReply {
            status: Err(Status::aborted("worker lost")),
            fetches: vec![],
        };
        let dec = RunReply::decode(&err.encode()).unwrap();
        let e = dec.status.unwrap_err();
        assert_eq!(e.code, Code::Aborted);
        assert_eq!(e.message, "worker lost");
    }

    #[test]
    fn tensor_reply_roundtrip() {
        let r = TensorReply { status: Ok(Tensor::from_f32(vec![3], vec![1., 2., 3.]).unwrap()) };
        let dec = TensorReply::decode(&r.encode()).unwrap();
        assert_eq!(dec.status.unwrap().as_f32().unwrap(), &[1., 2., 3.]);
        let e = TensorReply { status: Err(Status::not_found("no key")) };
        let dec = TensorReply::decode(&e.encode()).unwrap();
        assert_eq!(dec.status.unwrap_err().code, Code::NotFound);
    }

    #[test]
    fn frames_over_localhost() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let (t, p) = read_frame(&mut s).unwrap();
            assert_eq!(t, MSG_HEALTH);
            assert_eq!(p, b"ping");
            write_frame(&mut s, MSG_HEALTH_OK, b"pong").unwrap();
        });
        let (t, p) = rpc(&addr.to_string(), MSG_HEALTH, b"ping").unwrap();
        assert_eq!(t, MSG_HEALTH_OK);
        assert_eq!(p, b"pong");
        server.join().unwrap();
    }
}
