//! Wire protocol between master ⇄ worker and worker ⇄ worker (§3.3).
//! Length-prefixed frames over TCP; payloads reuse the graph/tensor
//! codecs. ("Send/Receive node pairs that communicate across worker
//! processes use remote communication mechanisms such as TCP or RDMA.")
//!
//! The transport itself — framing, status, tensor maps — lives in
//! [`crate::wire`], shared with the serving front end
//! (`crate::serving::net`); this module keeps the distributed message
//! types and their payload layouts.

use crate::error::{Result, Status};
use crate::graph::Graph;
use crate::tensor::Tensor;
use crate::wire::{
    decode_status, decode_tensor_map, encode_status, encode_tensor_map, get_str, get_tensor,
    get_u32, get_u64, get_u8, put_str, put_tensor, put_u32, put_u64, put_u8,
};

pub use crate::wire::{read_frame, rpc, write_frame};

pub const MSG_REGISTER_GRAPH: u8 = 1;
pub const MSG_REGISTER_REPLY: u8 = 2;
pub const MSG_RUN_PARTITION: u8 = 3;
pub const MSG_RUN_REPLY: u8 = 4;
pub const MSG_RECV_TENSOR: u8 = 5;
pub const MSG_TENSOR_REPLY: u8 = 6;
pub const MSG_HEALTH: u8 = 7;
pub const MSG_HEALTH_OK: u8 = 8;
pub const MSG_SHUTDOWN: u8 = 9;
pub const MSG_RESET: u8 = 10;

// Parameter-server channel (§4.4 data-parallel training): a persistent
// connection per replica, opened with HELLO (capability negotiation),
// then any number of INIT/PULL/PUSH/STATS requests, one reply each.
pub const MSG_PS_HELLO: u8 = 11;
pub const MSG_PS_HELLO_REPLY: u8 = 12;
pub const MSG_PS_INIT: u8 = 13;
pub const MSG_PS_INIT_REPLY: u8 = 14;
pub const MSG_PS_PULL: u8 = 15;
pub const MSG_PS_PULL_REPLY: u8 = 16;
pub const MSG_PS_PUSH: u8 = 17;
pub const MSG_PS_PUSH_REPLY: u8 = 18;
pub const MSG_PS_STATS: u8 = 19;
pub const MSG_PS_STATS_REPLY: u8 = 20;

// Distributed EEG (§9.2): pull-and-drain one process's trace fragment.
// Served by both the parameter server and the worker protocol; the
// master merges fragments (clock-aligned via the HELLO handshake's
// timestamp exchange) into one cross-process chrome://tracing timeline.
pub const MSG_TRACE_PULL: u8 = 21;
pub const MSG_TRACE_REPLY: u8 = 22;

/// Human-readable message name for wire metrics
/// (`wire/PS_PUSH/bytes_in` beats `wire/MSG_17/bytes_in` in a dump).
pub fn msg_name(t: u8) -> String {
    match t {
        MSG_REGISTER_GRAPH => "REGISTER_GRAPH".into(),
        MSG_REGISTER_REPLY => "REGISTER_REPLY".into(),
        MSG_RUN_PARTITION => "RUN_PARTITION".into(),
        MSG_RUN_REPLY => "RUN_REPLY".into(),
        MSG_RECV_TENSOR => "RECV_TENSOR".into(),
        MSG_TENSOR_REPLY => "TENSOR_REPLY".into(),
        MSG_HEALTH => "HEALTH".into(),
        MSG_HEALTH_OK => "HEALTH_OK".into(),
        MSG_SHUTDOWN => "SHUTDOWN".into(),
        MSG_RESET => "RESET".into(),
        MSG_PS_HELLO => "PS_HELLO".into(),
        MSG_PS_HELLO_REPLY => "PS_HELLO_REPLY".into(),
        MSG_PS_INIT => "PS_INIT".into(),
        MSG_PS_INIT_REPLY => "PS_INIT_REPLY".into(),
        MSG_PS_PULL => "PS_PULL".into(),
        MSG_PS_PULL_REPLY => "PS_PULL_REPLY".into(),
        MSG_PS_PUSH => "PS_PUSH".into(),
        MSG_PS_PUSH_REPLY => "PS_PUSH_REPLY".into(),
        MSG_PS_STATS => "PS_STATS".into(),
        MSG_PS_STATS_REPLY => "PS_STATS_REPLY".into(),
        MSG_TRACE_PULL => "TRACE_PULL".into(),
        MSG_TRACE_REPLY => "TRACE_REPLY".into(),
        other => crate::wire::raw_msg_name(other),
    }
}

/// Channel capability flag: §5.5 lossy f32→bf16 truncation on this
/// channel's tensor payloads. A client *requests* it in HELLO; the server
/// *grants* the intersection in the reply, and only granted capabilities
/// may be used — so an uncompressed peer talking to a compressing server
/// (or vice versa) negotiates down to plain f32 and interoperates.
/// Tensor payloads are self-describing (the codec carries the dtype), so
/// a receiver decompresses by dtype, never by assumption.
pub const CHANNEL_BF16: u32 = 1;

// ---- message payloads -------------------------------------------------------

pub struct RegisterGraph {
    pub graph: Graph,
}

impl RegisterGraph {
    pub fn encode(&self) -> Vec<u8> {
        crate::graph::serde::encode_graph(&self.graph)
    }

    pub fn decode(buf: &[u8]) -> Result<RegisterGraph> {
        Ok(RegisterGraph { graph: crate::graph::serde::decode_graph(buf)? })
    }
}

pub struct RunPartition {
    pub handle: u64,
    pub step_id: u64,
    pub feeds: Vec<(String, Tensor)>,
}

impl RunPartition {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        put_u64(&mut out, self.handle);
        put_u64(&mut out, self.step_id);
        encode_tensor_map(&mut out, &self.feeds);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<RunPartition> {
        let mut pos = 0;
        let handle = get_u64(buf, &mut pos)
            .map_err(|_| Status::invalid_argument("short RunPartition"))?;
        let step_id = get_u64(buf, &mut pos)
            .map_err(|_| Status::invalid_argument("short RunPartition"))?;
        let feeds = decode_tensor_map(buf, &mut pos)?;
        Ok(RunPartition { handle, step_id, feeds })
    }
}

pub struct RunReply {
    pub status: Result<()>,
    pub fetches: Vec<(String, Tensor)>,
}

impl RunReply {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        encode_status(&mut out, &self.status);
        encode_tensor_map(&mut out, &self.fetches);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<RunReply> {
        let mut pos = 0;
        let status = decode_status(buf, &mut pos)?;
        let fetches = decode_tensor_map(buf, &mut pos)?;
        Ok(RunReply { status, fetches })
    }
}

pub struct TensorReply {
    pub status: Result<Tensor>,
}

impl TensorReply {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match &self.status {
            Ok(t) => {
                encode_status(&mut out, &Ok(()));
                out.extend(crate::tensor::codec::encode(t));
            }
            Err(e) => encode_status(&mut out, &Err(e.clone())),
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<TensorReply> {
        let mut pos = 0;
        let status = decode_status(buf, &mut pos)?;
        match status {
            Ok(()) => {
                let (t, _) = crate::tensor::codec::decode(&buf[pos..])?;
                Ok(TensorReply { status: Ok(t) })
            }
            Err(e) => Ok(TensorReply { status: Err(e) }),
        }
    }
}

// ---- parameter-server payloads ---------------------------------------------

/// HELLO: the capability flags a replica requests for this channel, plus
/// the client's trace-clock reading (µs since its process epoch,
/// [`crate::tracing_tools::process_now_us`]) taken just before send —
/// one half of the NTP-style clock-offset exchange that lets the master
/// align trace fragments from different processes.
pub struct PsHello {
    pub flags: u32,
    pub time_us: u64,
}

impl PsHello {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        put_u32(&mut out, self.flags);
        put_u64(&mut out, self.time_us);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<PsHello> {
        let mut pos = 0;
        let flags = get_u32(buf, &mut pos)?;
        let time_us = get_u64(buf, &mut pos)?;
        Ok(PsHello { flags, time_us })
    }
}

/// HELLO reply: the granted subset of the requested flags, plus the
/// server's trace-clock reading taken while answering. The client
/// estimates the server-clock offset as
/// `time_us - (t_send + rtt/2)` — standard one-shot NTP; accuracy is
/// bounded by rtt asymmetry, which on the LAN links this targets is tens
/// of µs.
pub struct PsHelloReply {
    pub status: Result<()>,
    pub flags: u32,
    pub time_us: u64,
}

impl PsHelloReply {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        encode_status(&mut out, &self.status);
        put_u32(&mut out, self.flags);
        put_u64(&mut out, self.time_us);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<PsHelloReply> {
        let mut pos = 0;
        let status = decode_status(buf, &mut pos)?;
        let flags = get_u32(buf, &mut pos)?;
        let time_us = get_u64(buf, &mut pos)?;
        Ok(PsHelloReply { status, flags, time_us })
    }
}

/// One variable's gradient contribution inside a push.
pub enum GradEntry {
    /// The full gradient tensor.
    Dense(Tensor),
    /// Row-sparse gradient for embedding-shaped variables: `indices` is
    /// i64 `[k]` (row numbers into the variable's first dimension),
    /// `values` is `[k, rest…]` — only the touched rows travel.
    Sparse { indices: Tensor, values: Tensor },
}

const GRAD_KIND_DENSE: u8 = 0;
const GRAD_KIND_SPARSE: u8 = 1;

/// A gradient push: which step's parameters the gradients were computed
/// against (the staleness token), who pushed, and one entry per variable.
pub struct GradPush {
    pub step: u64,
    pub replica: u32,
    pub grads: Vec<(String, GradEntry)>,
}

impl GradPush {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        put_u64(&mut out, self.step);
        put_u32(&mut out, self.replica);
        put_u32(&mut out, self.grads.len() as u32);
        for (name, entry) in &self.grads {
            put_str(&mut out, name);
            match entry {
                GradEntry::Dense(t) => {
                    put_u8(&mut out, GRAD_KIND_DENSE);
                    put_tensor(&mut out, t);
                }
                GradEntry::Sparse { indices, values } => {
                    put_u8(&mut out, GRAD_KIND_SPARSE);
                    put_tensor(&mut out, indices);
                    put_tensor(&mut out, values);
                }
            }
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<GradPush> {
        let mut pos = 0;
        let step = get_u64(buf, &mut pos)?;
        let replica = get_u32(buf, &mut pos)?;
        let n = get_u32(buf, &mut pos)? as usize;
        let mut grads = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            let name = get_str(buf, &mut pos)?;
            let entry = match get_u8(buf, &mut pos)? {
                GRAD_KIND_DENSE => GradEntry::Dense(get_tensor(buf, &mut pos)?),
                GRAD_KIND_SPARSE => GradEntry::Sparse {
                    indices: get_tensor(buf, &mut pos)?,
                    values: get_tensor(buf, &mut pos)?,
                },
                other => {
                    return Err(Status::invalid_argument(format!(
                        "unknown gradient entry kind {other}"
                    )))
                }
            };
            grads.push((name, entry));
        }
        Ok(GradPush { step, replica, grads })
    }
}

/// Push reply: the server's parameter version after this push was
/// incorporated (sync: after the whole step's barrier applied).
pub struct PsPushReply {
    pub status: Result<()>,
    pub version: u64,
}

impl PsPushReply {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        encode_status(&mut out, &self.status);
        put_u64(&mut out, self.version);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<PsPushReply> {
        let mut pos = 0;
        let status = decode_status(buf, &mut pos)?;
        let version = get_u64(buf, &mut pos)?;
        Ok(PsPushReply { status, version })
    }
}

/// Pull reply: the shard's current version plus every parameter it holds
/// (bf16-compressed when the channel negotiated `CHANNEL_BF16`).
pub struct PsPullReply {
    pub status: Result<()>,
    pub version: u64,
    pub params: Vec<(String, Tensor)>,
}

impl PsPullReply {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        encode_status(&mut out, &self.status);
        put_u64(&mut out, self.version);
        encode_tensor_map(&mut out, &self.params);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<PsPullReply> {
        let mut pos = 0;
        let status = decode_status(buf, &mut pos)?;
        let version = get_u64(buf, &mut pos)?;
        let params = decode_tensor_map(buf, &mut pos)?;
        Ok(PsPullReply { status, version, params })
    }
}

// ---- trace fragments (§9.2 distributed EEG) --------------------------------

/// `MSG_TRACE_REPLY`: a drained [`TraceFragment`] from the serving
/// process. The request (`MSG_TRACE_PULL`) carries an empty payload.
/// Layout: status, process name, dropped count, u32 event count, then
/// per event name/op/device strings +
/// thread/start_us/dur_us/step/out_bytes u64s.
pub struct TraceReply {
    pub status: Result<()>,
    pub fragment: crate::tracing_tools::TraceFragment,
}

impl TraceReply {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        encode_status(&mut out, &self.status);
        put_str(&mut out, &self.fragment.process);
        put_u64(&mut out, self.fragment.dropped);
        put_u32(&mut out, self.fragment.events.len() as u32);
        for ev in &self.fragment.events {
            put_str(&mut out, &ev.name);
            put_str(&mut out, &ev.op);
            put_str(&mut out, &ev.device);
            put_u64(&mut out, ev.thread);
            put_u64(&mut out, ev.start_us);
            put_u64(&mut out, ev.dur_us);
            put_u64(&mut out, ev.step);
            put_u64(&mut out, ev.out_bytes);
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<TraceReply> {
        let mut pos = 0;
        let status = decode_status(buf, &mut pos)?;
        let process = get_str(buf, &mut pos)?;
        let dropped = get_u64(buf, &mut pos)?;
        let n = get_u32(buf, &mut pos)? as usize;
        let mut events = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            let name = get_str(buf, &mut pos)?;
            let op = get_str(buf, &mut pos)?;
            let device = get_str(buf, &mut pos)?;
            let thread = get_u64(buf, &mut pos)?;
            let start_us = get_u64(buf, &mut pos)?;
            let dur_us = get_u64(buf, &mut pos)?;
            let step = get_u64(buf, &mut pos)?;
            let out_bytes = get_u64(buf, &mut pos)?;
            events.push(crate::tracing_tools::Event {
                name,
                op,
                device,
                thread,
                start_us,
                dur_us,
                step,
                out_bytes,
            });
        }
        Ok(TraceReply {
            status,
            fragment: crate::tracing_tools::TraceFragment { process, events, dropped },
        })
    }
}

/// Init reply: `seeded` is true for the replica whose initial values won
/// the first-wins race; later initializers get `false` and must pull.
pub struct PsInitReply {
    pub status: Result<()>,
    pub seeded: bool,
}

impl PsInitReply {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        encode_status(&mut out, &self.status);
        put_u8(&mut out, self.seeded as u8);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<PsInitReply> {
        let mut pos = 0;
        let status = decode_status(buf, &mut pos)?;
        let seeded = get_u8(buf, &mut pos)? != 0;
        Ok(PsInitReply { status, seeded })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Code;

    #[test]
    fn run_partition_roundtrip() {
        let msg = RunPartition {
            handle: 7,
            step_id: 42,
            feeds: vec![
                ("feed;x:0".into(), Tensor::scalar_f32(1.5)),
                ("feed;y:0".into(), Tensor::from_i64(vec![2], vec![1, 2]).unwrap()),
            ],
        };
        let dec = RunPartition::decode(&msg.encode()).unwrap();
        assert_eq!(dec.handle, 7);
        assert_eq!(dec.step_id, 42);
        assert_eq!(dec.feeds.len(), 2);
        assert_eq!(dec.feeds[0].0, "feed;x:0");
        assert_eq!(dec.feeds[1].1.as_i64().unwrap(), &[1, 2]);
    }

    #[test]
    fn run_reply_roundtrip_ok_and_err() {
        let ok = RunReply {
            status: Ok(()),
            fetches: vec![("loss:0".into(), Tensor::scalar_f32(0.5))],
        };
        let dec = RunReply::decode(&ok.encode()).unwrap();
        assert!(dec.status.is_ok());
        assert_eq!(dec.fetches[0].1.scalar_value_f32().unwrap(), 0.5);

        let err = RunReply {
            status: Err(Status::aborted("worker lost")),
            fetches: vec![],
        };
        let dec = RunReply::decode(&err.encode()).unwrap();
        let e = dec.status.unwrap_err();
        assert_eq!(e.code, Code::Aborted);
        assert_eq!(e.message, "worker lost");
    }

    #[test]
    fn tensor_reply_roundtrip() {
        let r = TensorReply { status: Ok(Tensor::from_f32(vec![3], vec![1., 2., 3.]).unwrap()) };
        let dec = TensorReply::decode(&r.encode()).unwrap();
        assert_eq!(dec.status.unwrap().as_f32().unwrap(), &[1., 2., 3.]);
        let e = TensorReply { status: Err(Status::not_found("no key")) };
        let dec = TensorReply::decode(&e.encode()).unwrap();
        assert_eq!(dec.status.unwrap_err().code, Code::NotFound);
    }

    #[test]
    fn grad_push_roundtrip_dense_and_sparse() {
        let msg = GradPush {
            step: 41,
            replica: 3,
            grads: vec![
                (
                    "w0".into(),
                    GradEntry::Dense(Tensor::from_f32(vec![2, 2], vec![1., 2., 3., 4.]).unwrap()),
                ),
                (
                    "emb".into(),
                    GradEntry::Sparse {
                        indices: Tensor::from_i64(vec![2], vec![0, 7]).unwrap(),
                        values: Tensor::from_f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap(),
                    },
                ),
            ],
        };
        let dec = GradPush::decode(&msg.encode()).unwrap();
        assert_eq!(dec.step, 41);
        assert_eq!(dec.replica, 3);
        assert_eq!(dec.grads.len(), 2);
        match &dec.grads[0].1 {
            GradEntry::Dense(t) => assert_eq!(t.as_f32().unwrap(), &[1., 2., 3., 4.]),
            _ => panic!("expected dense"),
        }
        match &dec.grads[1].1 {
            GradEntry::Sparse { indices, values } => {
                assert_eq!(indices.as_i64().unwrap(), &[0, 7]);
                assert_eq!(values.shape().dims(), &[2, 3]);
            }
            _ => panic!("expected sparse"),
        }
    }

    #[test]
    fn ps_replies_roundtrip() {
        let h = PsHelloReply { status: Ok(()), flags: CHANNEL_BF16, time_us: 123_456 };
        let dec = PsHelloReply::decode(&h.encode()).unwrap();
        assert!(dec.status.is_ok());
        assert_eq!(dec.flags, CHANNEL_BF16);
        assert_eq!(dec.time_us, 123_456);

        let hello = PsHello { flags: CHANNEL_BF16, time_us: 77 };
        let dec = PsHello::decode(&hello.encode()).unwrap();
        assert_eq!((dec.flags, dec.time_us), (CHANNEL_BF16, 77));

        let p = PsPushReply { status: Err(Status::failed_precondition("stale push")), version: 9 };
        let dec = PsPushReply::decode(&p.encode()).unwrap();
        assert_eq!(dec.status.unwrap_err().code, Code::FailedPrecondition);
        assert_eq!(dec.version, 9);

        let pl = PsPullReply {
            status: Ok(()),
            version: 4,
            params: vec![("w".into(), Tensor::scalar_f32(2.5))],
        };
        let dec = PsPullReply::decode(&pl.encode()).unwrap();
        assert_eq!(dec.version, 4);
        assert_eq!(dec.params[0].1.scalar_value_f32().unwrap(), 2.5);

        let i = PsInitReply { status: Ok(()), seeded: true };
        assert!(PsInitReply::decode(&i.encode()).unwrap().seeded);
    }

    /// PR-5-style hostile-frame fuzz: every truncation of a valid
    /// gradient-push payload must decode to an error, never panic or
    /// over-read.
    #[test]
    fn grad_push_truncation_fuzz() {
        let msg = GradPush {
            step: 7,
            replica: 1,
            grads: vec![
                ("a".into(), GradEntry::Dense(Tensor::from_f32(vec![3], vec![1., 2., 3.]).unwrap())),
                (
                    "b".into(),
                    GradEntry::Sparse {
                        indices: Tensor::from_i64(vec![1], vec![2]).unwrap(),
                        values: Tensor::from_f32(vec![1, 2], vec![5., 6.]).unwrap(),
                    },
                ),
            ],
        };
        let full = msg.encode();
        for cut in 0..full.len() {
            assert!(GradPush::decode(&full[..cut]).is_err(), "cut at {cut} decoded");
        }
        // And the replies, same treatment.
        let pull = PsPullReply {
            status: Ok(()),
            version: 3,
            params: vec![("w".into(), Tensor::from_f32(vec![2], vec![1., 2.]).unwrap())],
        }
        .encode();
        for cut in 0..pull.len() {
            assert!(PsPullReply::decode(&pull[..cut]).is_err(), "pull cut at {cut} decoded");
        }
    }

    /// Oversize / corrupt length fields must be rejected by bounds checks,
    /// not fed to an allocator or a wrapping add.
    #[test]
    fn grad_push_hostile_lengths() {
        // Entry count far beyond the payload.
        let mut buf = Vec::new();
        crate::wire::put_u64(&mut buf, 1); // step
        crate::wire::put_u32(&mut buf, 0); // replica
        crate::wire::put_u32(&mut buf, u32::MAX); // grads "count"
        assert!(GradPush::decode(&buf).is_err());

        // Tensor length near u64::MAX inside an entry.
        let mut buf = Vec::new();
        crate::wire::put_u64(&mut buf, 1);
        crate::wire::put_u32(&mut buf, 0);
        crate::wire::put_u32(&mut buf, 1);
        crate::wire::put_str(&mut buf, "w");
        crate::wire::put_u8(&mut buf, 0); // dense
        crate::wire::put_u64(&mut buf, u64::MAX - 3);
        buf.extend_from_slice(&[0u8; 32]);
        assert!(GradPush::decode(&buf).is_err());

        // Unknown entry kind byte.
        let mut buf = Vec::new();
        crate::wire::put_u64(&mut buf, 1);
        crate::wire::put_u32(&mut buf, 0);
        crate::wire::put_u32(&mut buf, 1);
        crate::wire::put_str(&mut buf, "w");
        crate::wire::put_u8(&mut buf, 9); // bogus kind
        assert!(GradPush::decode(&buf).is_err());
    }

    /// Random byte soup at every length: decoders must return, not panic.
    #[test]
    fn grad_push_random_fuzz() {
        let mut rng = crate::util::rng::Pcg32::new(0x9517);
        for len in 0..256usize {
            let buf: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
            let _ = GradPush::decode(&buf);
            let _ = PsPullReply::decode(&buf);
            let _ = PsPushReply::decode(&buf);
            let _ = PsHelloReply::decode(&buf);
            let _ = PsInitReply::decode(&buf);
            let _ = PsHello::decode(&buf);
            let _ = TraceReply::decode(&buf);
        }
    }

    #[test]
    fn trace_reply_roundtrip() {
        let ev = |name: &str, start: u64| crate::tracing_tools::Event {
            name: name.to_string(),
            op: "PsApply".to_string(),
            device: "/ps".to_string(),
            thread: 2,
            start_us: start,
            dur_us: 15,
            step: 6,
            out_bytes: 4096,
        };
        let msg = TraceReply {
            status: Ok(()),
            fragment: crate::tracing_tools::TraceFragment {
                process: "ps".to_string(),
                events: vec![ev("recv;r0", 100), ev("apply", 250)],
                dropped: 3,
            },
        };
        let dec = TraceReply::decode(&msg.encode()).unwrap();
        assert!(dec.status.is_ok());
        assert_eq!(dec.fragment, msg.fragment);
    }

    /// Hostile/truncated `MSG_TRACE` payloads error instead of panic:
    /// every truncation of a valid reply, an absurd event count, and a
    /// huge declared string length.
    #[test]
    fn trace_reply_hostile_frames() {
        let msg = TraceReply {
            status: Ok(()),
            fragment: crate::tracing_tools::TraceFragment {
                process: "worker:0".to_string(),
                events: vec![crate::tracing_tools::Event {
                    name: "MatMul_1".to_string(),
                    op: "MatMul".to_string(),
                    device: "/device:cpu:0".to_string(),
                    thread: 1,
                    start_us: 10,
                    dur_us: 20,
                    step: 1,
                    out_bytes: 0,
                }],
                dropped: 0,
            },
        };
        let full = msg.encode();
        for cut in 0..full.len() {
            assert!(TraceReply::decode(&full[..cut]).is_err(), "cut at {cut} decoded");
        }
        // Event count claims 4 billion events on a tiny payload.
        let mut buf = Vec::new();
        crate::wire::encode_status(&mut buf, &Ok(()));
        crate::wire::put_str(&mut buf, "ps");
        crate::wire::put_u64(&mut buf, 0);
        crate::wire::put_u32(&mut buf, u32::MAX);
        assert!(TraceReply::decode(&buf).is_err());
        // String length near u32::MAX inside an event.
        let mut buf = Vec::new();
        crate::wire::encode_status(&mut buf, &Ok(()));
        crate::wire::put_str(&mut buf, "ps");
        crate::wire::put_u64(&mut buf, 0);
        crate::wire::put_u32(&mut buf, 1);
        crate::wire::put_u32(&mut buf, u32::MAX - 1); // event name "length"
        buf.extend_from_slice(&[0u8; 64]);
        assert!(TraceReply::decode(&buf).is_err());
    }

    #[test]
    fn frames_over_localhost() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let (t, p) = read_frame(&mut s).unwrap();
            assert_eq!(t, MSG_HEALTH);
            assert_eq!(p, b"ping");
            write_frame(&mut s, MSG_HEALTH_OK, b"pong").unwrap();
        });
        let (t, p) = rpc(&addr.to_string(), MSG_HEALTH, b"ping").unwrap();
        assert_eq!(t, MSG_HEALTH_OK);
        assert_eq!(p, b"pong");
        server.join().unwrap();
    }
}
