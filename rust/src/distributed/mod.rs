//! Distributed execution (§3.3): a master process drives worker processes
//! over TCP. "The distributed implementation shares most of the code with
//! the local implementation, but extends it with support for an
//! environment where the client, the master, and the workers can all be in
//! different processes on different machines."
//!
//! - the master places the client graph over every worker's devices,
//!   partitions it, registers each per-device partition with its worker,
//!   and per step "issue[s] a single Run request … to each worker that has
//!   any nodes for the graph";
//! - workers execute partitions with their local executors; cross-worker
//!   Send/Recv pairs pull tensors directly worker↔worker through
//!   [`RemoteRendezvous`] (the master is NOT on the data path);
//! - fault tolerance: "(a) an error in a communication between a Send and
//!   Receive node pair, and (b) periodic health-checks from the master
//!   process to every worker process" — both are surfaced as `Unavailable`
//!   / `Aborted` run errors, and training loops recover by restoring
//!   variables from the latest checkpoint (see `examples/distributed.rs`
//!   and experiment E17).
//!
//! Data-parallel training (§4.4, Fig 7) layers on top: [`ParamServer`]
//! shards own the parameters and apply updates (synchronously — averaged
//! once per step across replicas — or asynchronously), [`DistTrainer`]
//! drives the replica side (pull → compute → push), and gradients travel
//! bf16-compressed (§5.5) when both ends negotiate it.

pub mod master;
pub mod proto;
pub mod ps;
pub mod rendezvous;
pub mod train;
pub mod worker;

pub use master::{DistMaster, DistMasterOptions};
pub use ps::{ParamServer, PsClient, PsOptions};
pub use rendezvous::RemoteRendezvous;
pub use train::{DistTrainer, DistTrainerOptions};
pub use worker::{Worker, WorkerOptions};

/// Addresses of every worker task; task index = position.
/// Device names are `/job:worker/task:<i>/device:cpu:<j>`.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub workers: Vec<String>,
    pub devices_per_worker: usize,
}

impl ClusterSpec {
    pub fn new(workers: Vec<String>, devices_per_worker: usize) -> ClusterSpec {
        ClusterSpec { workers, devices_per_worker: devices_per_worker.max(1) }
    }

    pub fn num_tasks(&self) -> usize {
        self.workers.len()
    }

    pub fn addr_of(&self, task: usize) -> &str {
        &self.workers[task]
    }

    /// Parse the task index out of a device name.
    pub fn task_of_device(device: &str) -> crate::error::Result<usize> {
        let spec = crate::device::DeviceSpec::parse(device)?;
        Ok(spec.task)
    }
}
