//! The worker process (§3): "one or more worker processes, with each
//! worker process responsible for arbitrating access to one or more
//! computational devices … and for executing graph nodes on those devices
//! as instructed by the master."
//!
//! Thread-per-connection TCP server handling RegisterGraph / RunPartition
//! / RecvTensor (worker↔worker pulls) / Health / Reset / Shutdown.

use super::proto::{self, RegisterGraph, RunPartition, RunReply, TensorReply, TraceReply};
use super::rendezvous::{RemoteRendezvous, StepRendezvous};
use super::ClusterSpec;
use crate::device::DeviceSet;
use crate::error::{Result, Status};
use crate::executor::{CompiledGraph, Executor, RunContext};
use crate::kernels::StepState;
use crate::rendezvous::{recv_blocking, Rendezvous};
use crate::obs::httpz::{DebugServer, Response, Routes};
use crate::obs::profiler::Profiler;
use crate::resources::ResourceMgr;
use crate::tracing_tools::{StepStats, TraceCollector, TraceFragment};
use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Per-worker runtime knobs, the worker-process mirror of the
/// thread-related `SessionOptions` fields: remote partitions run on this
/// worker's devices, so both pool sizes plumb through here.
#[derive(Debug, Clone)]
pub struct WorkerOptions {
    /// Inter-op threads per device (mirror of
    /// `SessionOptions::threads_per_device`).
    pub threads_per_device: usize,
    /// Intra-op compute-pool lanes per device (mirror of
    /// `SessionOptions::intra_op_threads`): how many lanes a single large
    /// kernel's `parallel_for` fans out over. Results are bit-identical
    /// at every setting (the pool's determinism contract), and workers
    /// spawn lazily, so raising this only costs threads once a large
    /// kernel actually runs on a large remote partition.
    pub intra_op_threads: usize,
    /// Plan step memory for registered partitions (mirror of
    /// `SessionOptions::enable_memory_planning`): each `RegisterGraph`
    /// compiles with a liveness-based buffer plan and its own `ArenaPool`,
    /// keyed by the graph handle the master runs against — the PR-3
    /// planner, now on by default for remote partitions too. Results are
    /// identical either way; only allocation traffic changes.
    pub enable_memory_planning: bool,
    /// Record per-kernel spans for every partition run (tagged with the
    /// master's step id), served over `MSG_TRACE_PULL`.
    pub trace: bool,
}

impl Default for WorkerOptions {
    fn default() -> Self {
        WorkerOptions {
            threads_per_device: 2,
            intra_op_threads: 2,
            enable_memory_planning: true,
            trace: false,
        }
    }
}

pub struct Worker {
    pub task: usize,
    cluster: ClusterSpec,
    devices: DeviceSet,
    resources: Arc<ResourceMgr>,
    rendezvous: Arc<RemoteRendezvous>,
    graphs: Mutex<HashMap<u64, Arc<CompiledGraph>>>,
    next_handle: AtomicU64,
    shutdown: AtomicBool,
    options: WorkerOptions,
    /// Present when [`WorkerOptions::trace`]: accumulates every run's
    /// per-kernel spans until a `MSG_TRACE_PULL` drains them.
    trace: Option<Arc<TraceCollector>>,
    /// Always-on partition-run rollups for `/statusz`; per-kernel node
    /// rollups additionally flow in when tracing is enabled.
    profiler: Arc<Profiler>,
}

impl Worker {
    /// A worker with serial kernels (intra-op parallelism of 1); the
    /// historical constructor. Use [`Worker::with_options`] to size the
    /// intra-op pools.
    pub fn new(task: usize, cluster: ClusterSpec, threads_per_device: usize) -> Arc<Worker> {
        Worker::with_options(
            task,
            cluster,
            WorkerOptions { threads_per_device, intra_op_threads: 1, ..Default::default() },
        )
    }

    pub fn with_options(task: usize, cluster: ClusterSpec, options: WorkerOptions) -> Arc<Worker> {
        let devices = DeviceSet::new(
            (0..cluster.devices_per_worker)
                .map(|i| {
                    Arc::new(crate::device::Device::with_intra_op(
                        crate::device::DeviceSpec::worker_cpu(task, i),
                        options.threads_per_device,
                        options.intra_op_threads.max(1),
                    ))
                })
                .collect(),
        );
        let rendezvous = RemoteRendezvous::new(cluster.clone(), task);
        let trace = options.trace.then(|| TraceCollector::for_step(&format!("worker:{task}"), 0));
        Arc::new(Worker {
            task,
            cluster,
            devices,
            resources: ResourceMgr::new(),
            rendezvous,
            graphs: Mutex::new(HashMap::new()),
            next_handle: AtomicU64::new(1),
            shutdown: AtomicBool::new(false),
            options,
            trace,
            profiler: Profiler::new(16),
        })
    }

    /// The worker's span accumulator (when [`WorkerOptions::trace`]).
    pub fn trace(&self) -> Option<&Arc<TraceCollector>> {
        self.trace.as_ref()
    }

    /// Partition-run rollups — what this worker's `/statusz` renders.
    pub fn profiler(&self) -> &Arc<Profiler> {
        &self.profiler
    }

    /// Mount the worker's debug surface: `/healthz` (flips to 503 once
    /// `Shutdown` arrives), `/varz` (the process-global registry),
    /// `/statusz` (partition-run + per-node rollups), `/tracez` (chrome
    /// trace of accumulated spans; 404 when tracing is off).
    pub fn serve_httpz(self: &Arc<Self>, addr: &str) -> Result<DebugServer> {
        let (h, s, t) = (Arc::clone(self), Arc::clone(self), Arc::clone(self));
        let routes = Routes::new()
            .add("/healthz", move || {
                if h.shutdown.load(Ordering::SeqCst) {
                    Response::text(503, "shutting down\n")
                } else {
                    Response::text(200, "ok\n")
                }
            })
            .add("/varz", move || Response::text(200, crate::obs::global().export_text()))
            .add("/statusz", move || {
                let mut body = format!("== worker {} ==\n", s.task);
                body.push_str(&s.profiler.report_text(10));
                Response::text(200, body)
            })
            .add("/tracez", move || match &t.trace {
                Some(tc) => Response::json(200, tc.to_chrome_trace()),
                None => Response::text(404, "tracing disabled\n"),
            });
        DebugServer::serve(routes, addr)
    }

    pub fn resources(&self) -> &Arc<ResourceMgr> {
        &self.resources
    }

    /// This worker's devices (test support; also where the intra-op pool
    /// sizing is observable).
    pub fn devices(&self) -> &DeviceSet {
        &self.devices
    }

    /// Serve on `addr` (must match the cluster spec's entry for this
    /// task). Returns once the listener is bound; serving continues on
    /// background threads until `Shutdown` arrives.
    pub fn serve(self: &Arc<Self>, addr: &str) -> Result<std::net::SocketAddr> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Status::unavailable(format!("bind {addr}: {e}")))?;
        let local = listener.local_addr()?;
        let worker = Arc::clone(self);
        std::thread::Builder::new()
            .name(format!("worker-{}-accept", self.task))
            .spawn(move || {
                for conn in listener.incoming() {
                    if worker.shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let w = Arc::clone(&worker);
                            std::thread::spawn(move || {
                                let _ = w.handle_connection(stream);
                            });
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn worker accept thread");
        Ok(local)
    }

    fn handle_connection(self: &Arc<Self>, mut stream: TcpStream) -> Result<()> {
        stream.set_nodelay(true).ok();
        let (msg_type, payload) = proto::read_frame(&mut stream)?;
        match msg_type {
            proto::MSG_REGISTER_GRAPH => {
                let reply = match self.register(&payload) {
                    Ok(handle) => {
                        let mut out = vec![255u8];
                        out.extend_from_slice(&handle.to_le_bytes());
                        out
                    }
                    Err(e) => {
                        let mut out = vec![e.code.as_u8()];
                        out.extend_from_slice(e.message.as_bytes());
                        out
                    }
                };
                proto::write_frame(&mut stream, proto::MSG_REGISTER_REPLY, &reply)
            }
            proto::MSG_RUN_PARTITION => {
                let reply = self.run_partition(&payload);
                proto::write_frame(&mut stream, proto::MSG_RUN_REPLY, &reply.encode())
            }
            proto::MSG_RECV_TENSOR => {
                let key = String::from_utf8_lossy(&payload).to_string();
                // Blocks this handler thread until the producer's Send
                // deposits the tensor (§3.2.2 synchronization).
                let status = recv_blocking(&*self.rendezvous, &key);
                proto::write_frame(
                    &mut stream,
                    proto::MSG_TENSOR_REPLY,
                    &TensorReply { status }.encode(),
                )
            }
            proto::MSG_HEALTH => proto::write_frame(&mut stream, proto::MSG_HEALTH_OK, b""),
            proto::MSG_RESET => {
                let name = String::from_utf8_lossy(&payload).to_string();
                self.resources.reset_container(&name);
                proto::write_frame(&mut stream, proto::MSG_HEALTH_OK, b"")
            }
            proto::MSG_SHUTDOWN => {
                self.shutdown.store(true, Ordering::SeqCst);
                proto::write_frame(&mut stream, proto::MSG_HEALTH_OK, b"")
            }
            proto::MSG_TRACE_PULL => {
                let fragment = match &self.trace {
                    Some(t) => t.take_fragment(),
                    None => TraceFragment {
                        process: format!("worker:{}", self.task),
                        events: Vec::new(),
                        dropped: 0,
                    },
                };
                let r = TraceReply { status: Ok(()), fragment };
                proto::write_frame(&mut stream, proto::MSG_TRACE_REPLY, &r.encode())
            }
            other => Err(Status::invalid_argument(format!("unknown message type {other}"))),
        }
    }

    fn register(&self, payload: &[u8]) -> Result<u64> {
        let msg = RegisterGraph::decode(payload)?;
        // Every node of a partition is placed on one of this worker's
        // devices; find it.
        let device_name = msg
            .graph
            .nodes
            .first()
            .and_then(|n| n.assigned_device.clone())
            .ok_or_else(|| Status::invalid_argument("empty or unplaced partition"))?;
        let device = self.devices.find_by_name(&device_name)?;
        // Each registered partition gets its own plan + ArenaPool, keyed
        // by the handle the master's Run requests will name.
        let compiled =
            CompiledGraph::compile_planned(&msg.graph, device, self.options.enable_memory_planning)?;
        let handle = self.next_handle.fetch_add(1, Ordering::SeqCst);
        self.graphs.lock().unwrap().insert(handle, compiled);
        Ok(handle)
    }

    fn run_partition(self: &Arc<Self>, payload: &[u8]) -> RunReply {
        let run = match RunPartition::decode(payload) {
            Ok(r) => r,
            Err(e) => return RunReply { status: Err(e), fetches: vec![] },
        };
        let compiled = match self.graphs.lock().unwrap().get(&run.handle) {
            Some(c) => Arc::clone(c),
            None => {
                return RunReply {
                    status: Err(Status::not_found(format!("graph handle {}", run.handle))),
                    fetches: vec![],
                }
            }
        };
        let step = StepState::new(run.step_id);
        let rendezvous = StepRendezvous::new(self.rendezvous.clone() as Arc<dyn Rendezvous>);
        for (key, tensor) in run.feeds {
            if let Err(e) = rendezvous.send(&key, tensor) {
                return RunReply { status: Err(e), fetches: vec![] };
            }
        }
        // When tracing, each run records into a child collector tagged
        // with the master's step id, absorbed into the worker's
        // accumulator afterwards (the executor API takes one collector
        // per run; the accumulator spans many).
        let run_trace = self
            .trace
            .as_ref()
            .map(|_| TraceCollector::for_step(&format!("worker:{}", self.task), run.step_id));
        let ctx = RunContext {
            resources: Arc::clone(&self.resources),
            rendezvous: rendezvous as Arc<dyn Rendezvous>,
            step: Arc::clone(&step),
            trace: run_trace.clone(),
        };
        let run_start = Instant::now();
        let status = Executor::new(compiled).run(ctx);
        self.profiler.observe_span("worker/run_partition", "RunPartition", run_start.elapsed());
        if let (Some(acc), Some(child)) = (&self.trace, run_trace) {
            let evs = child.drain();
            // Per-kernel rollups for /statusz ride the same spans the
            // trace accumulator gets.
            self.profiler.observe(Arc::new(StepStats::from_events(run.step_id, &evs, Vec::new())));
            acc.absorb(evs);
        }
        let fetches = step.take_fetches().into_iter().collect();
        RunReply { status, fetches }
    }

    /// Cluster spec this worker serves in (test support).
    pub fn cluster(&self) -> &ClusterSpec {
        &self.cluster
    }
}
