//! Worker-side rendezvous for the distributed runtime.
//!
//! Send deposits locally (the producer side owns the tensor, §3.2.2);
//! Recv inspects the key's source device: local keys resolve in-process,
//! remote keys issue a `RecvTensor` RPC to the producing worker — data
//! flows worker↔worker, never through the master.
//!
//! `StepRendezvous` overlays a per-step table (feeds) on the long-lived
//! worker rendezvous, so `feed;…` keys never collide across steps.

use super::proto;
use super::ClusterSpec;
use crate::error::{Result, Status};
use crate::rendezvous::{LocalRendezvous, RecvDone, Rendezvous};
use crate::tensor::Tensor;
use std::sync::Arc;

pub struct RemoteRendezvous {
    local: Arc<LocalRendezvous>,
    cluster: ClusterSpec,
    my_task: usize,
}

impl RemoteRendezvous {
    pub fn new(cluster: ClusterSpec, my_task: usize) -> Arc<RemoteRendezvous> {
        Arc::new(RemoteRendezvous { local: LocalRendezvous::new(), cluster, my_task })
    }

    pub fn local(&self) -> &Arc<LocalRendezvous> {
        &self.local
    }

    /// Keys are `stepPrefix…;src_device;dst_device;tensor;frame`. The
    /// source device is the component before the dst device.
    fn src_task(&self, key: &str) -> Result<usize> {
        let parts: Vec<&str> = key.split(';').collect();
        // Find the first component that parses as a device name.
        for p in &parts {
            if p.starts_with("/job:") {
                return ClusterSpec::task_of_device(p);
            }
        }
        Err(Status::invalid_argument(format!("rendezvous key {key:?} has no source device")))
    }
}

impl Rendezvous for RemoteRendezvous {
    fn send(&self, key: &str, value: Tensor) -> Result<()> {
        self.local.send(key, value)
    }

    fn recv_async(&self, key: &str, done: RecvDone) {
        match self.src_task(key) {
            Ok(task) if task == self.my_task => self.local.recv_async(key, done),
            Ok(task) => {
                // Pull from the remote worker on a waiter thread (the RPC
                // blocks server-side until the producer's Send runs).
                let addr = self.cluster.addr_of(task).to_string();
                let key = key.to_string();
                std::thread::spawn(move || {
                    let result = (|| -> Result<Tensor> {
                        let (t, payload) =
                            proto::rpc(&addr, proto::MSG_RECV_TENSOR, key.as_bytes())?;
                        if t != proto::MSG_TENSOR_REPLY {
                            return Err(Status::internal(format!("unexpected reply type {t}")));
                        }
                        proto::TensorReply::decode(&payload)?.status
                    })();
                    done(result);
                });
            }
            Err(e) => done(Err(e)),
        }
    }

    fn abort(&self, status: Status) {
        self.local.abort(status);
    }

    fn try_recv(&self, key: &str) -> Option<Tensor> {
        self.local.try_recv(key)
    }
}

/// Per-step overlay: feeds resolve in the step table, everything else in
/// the worker-global rendezvous.
pub struct StepRendezvous {
    pub step: Arc<LocalRendezvous>,
    pub global: Arc<dyn Rendezvous>,
}

impl StepRendezvous {
    pub fn new(global: Arc<dyn Rendezvous>) -> Arc<StepRendezvous> {
        Arc::new(StepRendezvous { step: LocalRendezvous::new(), global })
    }

    fn is_step_key(key: &str) -> bool {
        key.starts_with("feed;")
    }
}

impl Rendezvous for StepRendezvous {
    fn send(&self, key: &str, value: Tensor) -> Result<()> {
        if Self::is_step_key(key) {
            self.step.send(key, value)
        } else {
            self.global.send(key, value)
        }
    }

    fn recv_async(&self, key: &str, done: RecvDone) {
        if Self::is_step_key(key) {
            self.step.recv_async(key, done)
        } else {
            self.global.recv_async(key, done)
        }
    }

    fn abort(&self, status: Status) {
        self.step.abort(status.clone());
        // Do NOT abort the global rendezvous here: other steps/partitions
        // may be healthy. Step-level cancellation handles the rest.
    }

    fn try_recv(&self, key: &str) -> Option<Tensor> {
        if Self::is_step_key(key) {
            self.step.try_recv(key)
        } else {
            self.global.try_recv(key)
        }
    }
}
