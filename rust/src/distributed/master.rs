//! The master (§3): receives the client's graph through the Session-like
//! interface, places it over every worker's devices, ships partitions, and
//! per step "issue[s] a single Run request per graph execution to each
//! worker that has any nodes for the graph". Also runs the §3.3 health
//! checks.

use super::proto::{self, RegisterGraph, RunPartition, RunReply};
use super::ClusterSpec;
use crate::device::{Device, DeviceSet, DeviceSpec};
use crate::error::{Result, Status};
use crate::graph::Graph;
use crate::partition::{partition, PartitionOptions};
use crate::passes;
use crate::placement::{place, CostModel};
use crate::session::prune_for_run;
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

#[derive(Clone)]
pub struct DistMasterOptions {
    /// §5 build-time constant folding on pruned graphs.
    pub enable_constant_folding: bool,
    /// §5 arithmetic-identity simplification on pruned graphs.
    pub enable_arithmetic_simplification: bool,
    /// §5.1 CSE pass on pruned graphs.
    pub enable_cse: bool,
    /// §5 elementwise-chain fusion — workers deserialize and execute
    /// `FusedElementwise` nodes like any other op, so the master runs the
    /// same full pipeline as a local `Session`.
    pub enable_elementwise_fusion: bool,
    pub enable_recv_scheduling: bool,
    pub partition: PartitionOptions,
    pub cost_model: CostModel,
}

impl Default for DistMasterOptions {
    fn default() -> Self {
        DistMasterOptions {
            enable_constant_folding: true,
            enable_arithmetic_simplification: true,
            enable_cse: true,
            enable_elementwise_fusion: true,
            enable_recv_scheduling: true,
            partition: PartitionOptions::default(),
            cost_model: CostModel::new(),
        }
    }
}

struct CachedStep {
    /// (task, handle) per registered partition.
    partitions: Vec<(usize, u64)>,
    feed_keys: Vec<String>,
    fetch_keys: Vec<String>,
}

/// Client-facing distributed session.
pub struct DistMaster {
    cluster: ClusterSpec,
    graph: Mutex<Graph>,
    options: DistMasterOptions,
    /// Placement metadata mirror of the remote devices (no kernels run on
    /// these Device objects).
    device_mirror: DeviceSet,
    next_step: AtomicU64,
    cache: Mutex<HashMap<String, Arc<CachedStep>>>,
}

impl DistMaster {
    pub fn new(cluster: ClusterSpec, graph: Graph, options: DistMasterOptions) -> DistMaster {
        let mut devices = Vec::new();
        for t in 0..cluster.num_tasks() {
            for d in 0..cluster.devices_per_worker {
                devices.push(Arc::new(Device::new(DeviceSpec::worker_cpu(t, d), 1)));
            }
        }
        DistMaster {
            cluster,
            graph: Mutex::new(graph),
            options,
            device_mirror: DeviceSet::new(devices),
            next_step: AtomicU64::new(1),
            cache: Mutex::new(HashMap::new()),
        }
    }

    /// §3.3 health checks: ping every worker.
    pub fn health_check(&self) -> Result<()> {
        for (t, addr) in self.cluster.workers.iter().enumerate() {
            let (msg, _) = proto::rpc(addr, proto::MSG_HEALTH, b"")
                .map_err(|e| Status::unavailable(format!("worker task {t} unreachable: {}", e.message)))?;
            if msg != proto::MSG_HEALTH_OK {
                return Err(Status::unavailable(format!("worker task {t} unhealthy")));
            }
        }
        Ok(())
    }

    /// Drop cached registrations (after a worker restart the handles are
    /// gone; the next run re-places and re-registers).
    pub fn invalidate(&self) {
        self.cache.lock().unwrap().clear();
    }

    pub fn cluster(&self) -> &ClusterSpec {
        &self.cluster
    }

    pub fn run_targets(&self, targets: &[&str]) -> Result<()> {
        self.run(&[], &[], targets)?;
        Ok(())
    }

    pub fn run(
        &self,
        feeds: &[(&str, Tensor)],
        fetches: &[&str],
        targets: &[&str],
    ) -> Result<Vec<Tensor>> {
        let signature = {
            let mut s = String::new();
            for (k, _) in feeds {
                s.push_str(k);
                s.push(';');
            }
            s.push('|');
            for f in fetches {
                s.push_str(f);
                s.push(';');
            }
            s.push('|');
            for t in targets {
                s.push_str(t);
                s.push(';');
            }
            s
        };
        let cached = {
            let c = self.cache.lock().unwrap();
            c.get(&signature).cloned()
        };
        let cached = match cached {
            Some(c) => c,
            None => {
                let built = Arc::new(self.build_step(feeds, fetches, targets)?);
                self.cache.lock().unwrap().insert(signature, Arc::clone(&built));
                built
            }
        };

        let step_id = self.next_step.fetch_add(1, Ordering::SeqCst);
        let feed_map: Vec<(String, Tensor)> = feeds
            .iter()
            .zip(&cached.feed_keys)
            .map(|((_, t), k)| (k.clone(), t.clone()))
            .collect();

        // One Run request per partition, concurrently.
        let replies: Vec<Result<RunReply>> = std::thread::scope(|scope| {
            let handles: Vec<_> = cached
                .partitions
                .iter()
                .map(|&(task, handle)| {
                    let addr = self.cluster.addr_of(task).to_string();
                    let feeds = feed_map.clone();
                    scope.spawn(move || -> Result<RunReply> {
                        let msg = RunPartition { handle, step_id, feeds };
                        let (t, payload) =
                            proto::rpc(&addr, proto::MSG_RUN_PARTITION, &msg.encode())?;
                        if t != proto::MSG_RUN_REPLY {
                            return Err(Status::internal(format!("unexpected reply {t}")));
                        }
                        RunReply::decode(&payload)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("rpc thread panicked")).collect()
        });

        let mut fetched: HashMap<String, Tensor> = HashMap::new();
        let mut first_error: Option<Status> = None;
        for reply in replies {
            match reply {
                Ok(r) => {
                    if let Err(e) = r.status {
                        first_error.get_or_insert(e);
                    }
                    fetched.extend(r.fetches);
                }
                Err(e) => {
                    first_error.get_or_insert(e);
                }
            }
        }
        if let Some(e) = first_error {
            return Err(e);
        }
        cached
            .fetch_keys
            .iter()
            .map(|k| {
                fetched
                    .remove(k)
                    .ok_or_else(|| Status::internal(format!("fetch {k:?} missing from replies")))
            })
            .collect()
    }

    fn build_step(
        &self,
        feeds: &[(&str, Tensor)],
        fetches: &[&str],
        targets: &[&str],
    ) -> Result<CachedStep> {
        let full = self.graph.lock().unwrap().clone();
        let (pruned, feed_keys, fetch_keys) = prune_for_run(
            &full,
            &feeds.iter().map(|(k, _)| *k).collect::<Vec<_>>(),
            fetches,
            targets,
        )?;
        // The full §5 pipeline (fold → simplify → cse → fuse), same flags
        // and order as `Session::build_step` — the pruned graph the
        // workers execute is the optimized one.
        let pipeline = passes::PassManager::standard(
            self.options.enable_constant_folding,
            self.options.enable_arithmetic_simplification,
            self.options.enable_cse,
            self.options.enable_elementwise_fusion,
        );
        let (pruned, _pipeline_stats) = pipeline.run(&pruned)?;
        let mut placed = pruned;
        place(&mut placed, &self.device_mirror, &self.options.cost_model)?;
        // Rendezvous keys carry %STEP%, substituted per step by the
        // Send/Recv kernels — one registration serves every step.
        let (mut parts, _stats) = partition(&placed, &self.options.partition, "%STEP%;")?;
        if self.options.enable_recv_scheduling {
            passes::schedule_recvs_global(&mut parts, &self.options.cost_model)?;
        }
        let mut partitions = Vec::with_capacity(parts.len());
        for p in &parts {
            let task = ClusterSpec::task_of_device(&p.device)?;
            let msg = RegisterGraph { graph: p.graph.clone() };
            let (t, payload) =
                proto::rpc(self.cluster.addr_of(task), proto::MSG_REGISTER_GRAPH, &msg.encode())?;
            if t != proto::MSG_REGISTER_REPLY {
                return Err(Status::internal(format!("unexpected register reply {t}")));
            }
            if payload.first() != Some(&255) || payload.len() < 9 {
                return Err(Status::internal(format!(
                    "register failed on task {task}: {}",
                    String::from_utf8_lossy(&payload[1..])
                )));
            }
            let handle = u64::from_le_bytes(payload[1..9].try_into().unwrap());
            partitions.push((task, handle));
        }
        Ok(CachedStep { partitions, feed_keys, fetch_keys })
    }
}
