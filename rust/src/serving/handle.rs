//! Per-request completion: a tiny blocking future shared between the
//! client thread and the lane's scheduler thread.

use crate::error::{Result, Status};
use crate::tensor::Tensor;
use std::sync::{Arc, Condvar, Mutex};

/// Shared completion slot: `None` until the scheduler fulfills it.
pub(crate) struct ResponseSlot {
    state: Mutex<Option<Result<Vec<Tensor>>>>,
    cond: Condvar,
}

impl ResponseSlot {
    pub(crate) fn new() -> Arc<ResponseSlot> {
        Arc::new(ResponseSlot { state: Mutex::new(None), cond: Condvar::new() })
    }

    /// First fulfillment wins; later calls are ignored (a request is
    /// fulfilled exactly once on the happy path, and a second time only
    /// by the drop-cancellation guard).
    pub(crate) fn fulfill(&self, result: Result<Vec<Tensor>>) {
        let mut s = self.state.lock().unwrap();
        if s.is_none() {
            *s = Some(result);
            self.cond.notify_all();
        }
    }

    fn take_blocking(&self) -> Result<Vec<Tensor>> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(r) = s.take() {
                return r;
            }
            s = self.cond.wait(s).unwrap();
        }
    }

    fn is_ready(&self) -> bool {
        self.state.lock().unwrap().is_some()
    }
}

/// The client's handle to one in-flight request: returned by
/// [`crate::serving::ModelServer::submit`], redeemed with [`ResponseHandle::wait`].
pub struct ResponseHandle {
    slot: Arc<ResponseSlot>,
}

impl ResponseHandle {
    pub(crate) fn new(slot: Arc<ResponseSlot>) -> ResponseHandle {
        ResponseHandle { slot }
    }

    /// Block until the request completes; returns the fetched tensors in
    /// the order the fetches were submitted.
    pub fn wait(self) -> Result<Vec<Tensor>> {
        self.slot.take_blocking()
    }

    /// Has the scheduler fulfilled this request yet? (Non-blocking poll.)
    pub fn is_ready(&self) -> bool {
        self.slot.is_ready()
    }
}

/// One admitted request, queued in a lane until the scheduler batches it.
pub(crate) struct PendingRequest {
    /// Feed tensors, in the lane's feed-name order. Every tensor carries
    /// the request's row count on axis 0.
    pub(crate) feeds: Vec<Tensor>,
    /// Rows this request contributes to a batch (axis-0 extent).
    pub(crate) rows: usize,
    pub(crate) slot: Arc<ResponseSlot>,
}

impl Drop for PendingRequest {
    /// A request dropped unfulfilled (server shut down with work still
    /// queued, scheduler panicked) must not strand its client: deliver
    /// `Cancelled` instead of hanging `wait()` forever. `fulfill` is
    /// first-write-wins, so this is a no-op after normal completion.
    fn drop(&mut self) {
        self.slot.fulfill(Err(Status::cancelled("request dropped before execution")));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fulfill_then_wait() {
        let slot = ResponseSlot::new();
        let h = ResponseHandle::new(Arc::clone(&slot));
        assert!(!h.is_ready());
        slot.fulfill(Ok(vec![Tensor::scalar_f32(1.0)]));
        assert!(h.is_ready());
        let out = h.wait().unwrap();
        assert_eq!(out[0].scalar_value_f32().unwrap(), 1.0);
    }

    #[test]
    fn wait_blocks_until_fulfilled() {
        let slot = ResponseSlot::new();
        let h = ResponseHandle::new(Arc::clone(&slot));
        let t = std::thread::spawn(move || h.wait().unwrap());
        std::thread::sleep(std::time::Duration::from_millis(10));
        slot.fulfill(Ok(vec![Tensor::scalar_f32(2.0)]));
        assert_eq!(t.join().unwrap()[0].scalar_value_f32().unwrap(), 2.0);
    }

    #[test]
    fn first_fulfill_wins() {
        let slot = ResponseSlot::new();
        let h = ResponseHandle::new(Arc::clone(&slot));
        slot.fulfill(Err(Status::internal("first")));
        slot.fulfill(Ok(vec![]));
        assert_eq!(h.wait().unwrap_err().message, "first");
    }

    #[test]
    fn dropped_request_cancels_client() {
        let slot = ResponseSlot::new();
        let h = ResponseHandle::new(Arc::clone(&slot));
        let req = PendingRequest { feeds: vec![], rows: 1, slot };
        drop(req);
        let e = h.wait().unwrap_err();
        assert_eq!(e.code, crate::error::Code::Cancelled);
    }
}
