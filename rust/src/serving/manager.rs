//! The model lifecycle manager: versioned model loading and atomic
//! hot-swap serving (the production half of the OSDI'16 serving story —
//! "deploying machine learning systems into production" needs models to
//! be *updated* without dropping traffic, not just batched).
//!
//! A [`ModelManager`] owns any number of named models, each with
//! numbered versions. A version is a full serving stack — a
//! [`crate::Session`] built from a serialized GraphDef
//! ([`crate::graph::serde::read_graphdef`]) with its variables restored
//! from a checkpoint bundle ([`crate::checkpoint::load_bundle`]), fronted
//! by its own [`ModelServer`] (dynamic batching lanes). Versions move
//! through a fixed state machine:
//!
//! ```text
//! loading → warming → live → draining → retired
//! ```
//!
//! * **loading** — artifacts are being read and the Session built; the
//!   version is not yet visible to requests (it only appears in the
//!   version table once its server exists, already `warming`).
//! * **warming** — optional [`WarmupRequest`]s run through the version's
//!   own server: they compile the cached step, spin up the batching
//!   lane, and touch the arena pools, so the first real request never
//!   pays build cost. A failed warmup retires the version without it
//!   ever going live — the previous live version keeps serving.
//! * **live** — the version receives "latest" traffic. Exactly one
//!   version of a model is live at a time; `live` points at the most
//!   recent successful deploy (re-deploying an older number is how you
//!   roll back).
//! * **draining** — a newer version went live. The old version accepts
//!   no new requests, but every request admitted before the swap is
//!   still executed: its `ModelServer` lanes stay alive until their
//!   queues empty (`ModelServer::shutdown` closes the queues and joins
//!   the schedulers, which drain everything already admitted).
//! * **retired** — drained and shut down. Version-pinned requests to a
//!   retired version fail fast with `NotFound`; they never hang.
//!
//! **The zero-loss hot-swap contract.** `submit` resolves the target
//! version and admits into its server *while holding the model's state
//! read-lock*; the swap flips `live` under the write-lock and only then
//! drains the old version. So every request that observed a version as
//! `live` is admitted to its queues before draining can begin, and the
//! drain executes everything admitted — a hot-swap under concurrent
//! load completes every in-flight request, and every request admitted
//! after the swap returns is answered by the new version.

use super::{BatchConfig, ModelServer, ResponseHandle, ServingStats};
use crate::checkpoint;
use crate::error::{Result, Status};
use crate::graph::Endpoint;
use crate::obs::{Counter, Gauge, MetricsRegistry};
use crate::session::{Session, SessionOptions};
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::stats::{LatencyHistogram, LatencySummary};
use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// Where a version lives in its lifecycle. See the module docs for the
/// full state machine; transitions only move rightward.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VersionState {
    Loading,
    Warming,
    Live,
    Draining,
    Retired,
}

impl std::fmt::Display for VersionState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            VersionState::Loading => "loading",
            VersionState::Warming => "warming",
            VersionState::Live => "live",
            VersionState::Draining => "draining",
            VersionState::Retired => "retired",
        };
        f.write_str(s)
    }
}

/// One request run against a freshly loaded version before it goes live:
/// compiles the cached step for this `(feeds, fetches)` signature and
/// exercises the whole lane, so live traffic never pays first-request
/// build cost. Shapes follow the serving contract (batch axis 0 on every
/// feed).
#[derive(Clone)]
pub struct WarmupRequest {
    pub feeds: Vec<(String, Tensor)>,
    pub fetches: Vec<String>,
}

/// On-disk description of one model version.
#[derive(Clone, Default)]
pub struct ModelSpec {
    /// Serialized graph ([`crate::graph::serde::write_graphdef`]).
    pub graph_path: PathBuf,
    /// Checkpoint bundle restored into the graph's Variables
    /// ([`crate::checkpoint::load_bundle`] + [`restore_variables`]).
    pub checkpoint_path: Option<PathBuf>,
    /// Target nodes run once after load, before the checkpoint restore —
    /// e.g. the graph's variable initializers when a version ships
    /// without (or with a partial) checkpoint.
    pub init_targets: Vec<String>,
    /// Requests run while `warming`; any failure aborts the deploy.
    pub warmup: Vec<WarmupRequest>,
}

/// Manager-wide configuration: the template every version's Session and
/// batching server is built from.
#[derive(Clone, Default)]
pub struct ManagerOptions {
    pub session: SessionOptions,
    pub batch: BatchConfig,
}

/// Per-version counters, shared between the manager and every
/// outstanding [`ManagedHandle`]. The handles live in the manager's
/// [`MetricsRegistry`] under `serving/<model>/v<version>/…`, so the same
/// numbers surface in both [`VersionStats`] and the registry dump —
/// one source of truth (a rollback re-deploy reuses the names and keeps
/// accumulating).
struct VersionCounters {
    submitted: Arc<Counter>,
    ok: Arc<Counter>,
    errors: Arc<Counter>,
    inflight: Arc<Gauge>,
    latency: Arc<LatencyHistogram>,
}

impl VersionCounters {
    fn registered(reg: &Arc<MetricsRegistry>, model: &str, version: u64) -> VersionCounters {
        let p = format!("serving/{model}/v{version}");
        VersionCounters {
            submitted: reg.counter(&format!("{p}/requests")),
            ok: reg.counter(&format!("{p}/ok")),
            errors: reg.counter(&format!("{p}/errors")),
            inflight: reg.gauge(&format!("{p}/inflight")),
            latency: reg.histogram(&format!("{p}/latency")),
        }
    }
}

/// One deployed version: its serving stack plus lifecycle state.
struct VersionEntry {
    version: u64,
    state: Mutex<VersionState>,
    server: ModelServer,
    counters: Arc<VersionCounters>,
}

impl VersionEntry {
    fn state(&self) -> VersionState {
        *self.state.lock().unwrap()
    }

    fn set_state(&self, s: VersionState) {
        *self.state.lock().unwrap() = s;
    }
}

/// Version table of one named model. Lock order (everywhere): the
/// manager's model map, then a model's `state`, then an entry's `state`.
struct Model {
    name: String,
    state: RwLock<ModelState>,
}

struct ModelState {
    versions: BTreeMap<u64, Arc<VersionEntry>>,
    /// The version "latest" routes to: the most recent successful deploy.
    live: Option<u64>,
}

/// Snapshot of one version's counters and lifecycle state.
#[derive(Debug, Clone)]
pub struct VersionStats {
    pub model: String,
    pub version: u64,
    pub state: VersionState,
    /// Is this the version "latest" currently routes to?
    pub live: bool,
    /// Requests admitted through the manager.
    pub requests: u64,
    pub ok: u64,
    pub errors: u64,
    /// Admitted but not yet redeemed by the client.
    pub inflight: u64,
    /// The underlying batch scheduler's counters.
    pub batch: ServingStats,
    /// Submit→completion latency (p50/p95/p99) of redeemed requests.
    pub latency: LatencySummary,
}

/// The client's handle to one in-flight managed request. Redeeming it
/// with [`ManagedHandle::wait`] records the request's latency and
/// outcome into the serving version's stats.
pub struct ManagedHandle {
    inner: ResponseHandle,
    start: Instant,
    counters: Arc<VersionCounters>,
    _inflight: InflightGuard,
}

/// Decrements the version's in-flight gauge exactly once — when the
/// handle is redeemed or dropped, whichever comes first.
struct InflightGuard(Arc<VersionCounters>);

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.0.inflight.sub(1);
    }
}

impl ManagedHandle {
    /// Block until the request completes; records latency and outcome.
    pub fn wait(self) -> Result<Vec<Tensor>> {
        let ManagedHandle { inner, start, counters, _inflight } = self;
        let result = inner.wait();
        counters.latency.record(start.elapsed());
        match &result {
            Ok(_) => counters.ok.inc(),
            Err(_) => counters.errors.inc(),
        };
        result
    }

    pub fn is_ready(&self) -> bool {
        self.inner.is_ready()
    }
}

/// A multi-model, multi-version serving hub. See the module docs for
/// the lifecycle and hot-swap contract; see [`crate::serving::net`] for
/// the TCP front end that exposes it as a standalone process.
pub struct ModelManager {
    options: ManagerOptions,
    models: RwLock<HashMap<String, Arc<Model>>>,
    /// Per-manager (not process-global): two managers in one test process
    /// must not share `serving/…` counters.
    registry: Arc<MetricsRegistry>,
    shutting_down: AtomicBool,
}

impl ModelManager {
    pub fn new(options: ManagerOptions) -> ModelManager {
        ModelManager {
            options,
            models: RwLock::new(HashMap::new()),
            registry: MetricsRegistry::new(),
            shutting_down: AtomicBool::new(false),
        }
    }

    pub fn options(&self) -> &ManagerOptions {
        &self.options
    }

    /// The manager's metrics registry (what `stats_json` dumps under
    /// `"metrics"`; the TCP front end registers its wire counters here).
    pub fn metrics(&self) -> &Arc<MetricsRegistry> {
        &self.registry
    }

    /// Deploy a version from on-disk artifacts: read the GraphDef, build
    /// a Session from the manager's session template, run
    /// `init_targets`, restore the checkpoint, then hand off to
    /// [`ModelManager::deploy_session`] for warmup + swap. Blocks until
    /// the swap is complete and any previous live version has fully
    /// drained; "latest" traffic keeps flowing to the old version for
    /// the whole load + warmup.
    pub fn deploy(&self, model: &str, version: u64, spec: &ModelSpec) -> Result<()> {
        let annotate = |e: Status, what: &str| {
            Status::new(e.code, format!("model {model:?} v{version} {what}: {}", e.message))
        };
        let graph = crate::graph::serde::read_graphdef(&spec.graph_path)
            .map_err(|e| annotate(e, "graphdef load failed"))?;
        let session = Arc::new(Session::new(graph, self.options.session.clone()));
        if !spec.init_targets.is_empty() {
            let targets: Vec<&str> = spec.init_targets.iter().map(String::as_str).collect();
            session.run_targets(&targets).map_err(|e| annotate(e, "init failed"))?;
        }
        if let Some(ckpt) = &spec.checkpoint_path {
            let bundle =
                checkpoint::load_bundle(ckpt).map_err(|e| annotate(e, "checkpoint load failed"))?;
            restore_variables(&session, &bundle)
                .map_err(|e| annotate(e, "checkpoint restore failed"))?;
        }
        self.deploy_session(model, version, session, &spec.warmup)
    }

    /// Deploy a version around an already-built Session (in-process
    /// serving without artifact files; also the substrate `deploy` ends
    /// in). Runs `warmup`, then atomically swaps "latest" to this
    /// version and drains the previous live version to `retired` before
    /// returning. Fails with `AlreadyExists` if the version number is
    /// already deployed and not retired.
    pub fn deploy_session(
        &self,
        model: &str,
        version: u64,
        session: Arc<Session>,
        warmup: &[WarmupRequest],
    ) -> Result<()> {
        if self.shutting_down.load(Ordering::SeqCst) {
            return Err(Status::unavailable("model manager is shutting down"));
        }
        if version == 0 {
            return Err(Status::invalid_argument(
                "model version numbers start at 1 (0 means \"latest\" on the wire)",
            ));
        }
        let model_arc = {
            let mut models = self.models.write().unwrap();
            Arc::clone(models.entry(model.to_string()).or_insert_with(|| {
                Arc::new(Model {
                    name: model.to_string(),
                    state: RwLock::new(ModelState { versions: BTreeMap::new(), live: None }),
                })
            }))
        };
        let entry = Arc::new(VersionEntry {
            version,
            state: Mutex::new(VersionState::Warming),
            server: ModelServer::with_session(session, self.options.batch.clone()),
            counters: Arc::new(VersionCounters::registered(&self.registry, model, version)),
        });
        {
            let mut st = model_arc.state.write().unwrap();
            if let Some(existing) = st.versions.get(&version) {
                if existing.state() != VersionState::Retired {
                    return Err(Status::already_exists(format!(
                        "model {model:?} version {version} is already deployed ({})",
                        existing.state()
                    )));
                }
            }
            // Visible (to stats and pinned requests) as `warming`; a
            // pinned request to a warming version is told to retry, not
            // routed.
            st.versions.insert(version, Arc::clone(&entry));
        }

        // Warmup runs outside any model lock: "latest" traffic keeps
        // flowing to the current live version while this one warms.
        for (i, w) in warmup.iter().enumerate() {
            let feeds: Vec<(&str, Tensor)> =
                w.feeds.iter().map(|(n, t)| (n.as_str(), t.clone())).collect();
            let fetches: Vec<&str> = w.fetches.iter().map(String::as_str).collect();
            if let Err(e) = entry.server.run(&feeds, &fetches) {
                entry.set_state(VersionState::Retired);
                entry.server.shutdown();
                return Err(Status::new(
                    e.code,
                    format!("model {model:?} v{version} warmup request {i} failed: {}", e.message),
                ));
            }
        }

        // The atomic swap: once the write-lock releases, "latest"
        // resolves to the new version and the old one admits nothing.
        let old = {
            let mut st = model_arc.state.write().unwrap();
            // Re-check under the write-lock: an undeploy()/shutdown()
            // during the unlocked warmup window may have retired this
            // entry already — going live would resurrect a shut-down
            // server as the routing target.
            if self.shutting_down.load(Ordering::SeqCst)
                || entry.state() != VersionState::Warming
            {
                drop(st);
                entry.set_state(VersionState::Retired);
                entry.server.shutdown();
                return Err(Status::unavailable(format!(
                    "model {model:?} v{version} was retired before going live \
                     (undeployed or manager shut down during warmup)"
                )));
            }
            entry.set_state(VersionState::Live);
            let old = st.live.replace(version).filter(|&v| v != version);
            let old = old.and_then(|v| st.versions.get(&v).cloned());
            if let Some(o) = &old {
                o.set_state(VersionState::Draining);
            }
            old
        };
        // Graceful drain, after the swap: every request admitted while
        // the old version was live is still executed; only then do its
        // lanes shut down.
        if let Some(o) = old {
            o.server.shutdown();
            o.set_state(VersionState::Retired);
        }
        Ok(())
    }

    /// Retire every version of `model` (draining each live lane) and
    /// stop routing to it. The version table is kept so pinned requests
    /// keep failing with `NotFound` rather than "unknown model".
    pub fn undeploy(&self, model: &str) -> Result<()> {
        let model_arc = self
            .models
            .read()
            .unwrap()
            .get(model)
            .cloned()
            .ok_or_else(|| Status::not_found(format!("model {model:?} is not deployed")))?;
        let draining = {
            let mut st = model_arc.state.write().unwrap();
            st.live = None;
            let mut draining = Vec::new();
            for entry in st.versions.values() {
                if entry.state() != VersionState::Retired {
                    entry.set_state(VersionState::Draining);
                    draining.push(Arc::clone(entry));
                }
            }
            draining
        };
        for entry in draining {
            entry.server.shutdown();
            entry.set_state(VersionState::Retired);
        }
        Ok(())
    }

    /// Drain and retire everything. Idempotent; new deploys and submits
    /// fail with `Unavailable` afterwards.
    pub fn shutdown(&self) {
        self.shutting_down.store(true, Ordering::SeqCst);
        let names: Vec<String> = self.models.read().unwrap().keys().cloned().collect();
        for name in names {
            let _ = self.undeploy(&name);
        }
    }

    /// Submit a request to `model`: `version: None` routes to the live
    /// version ("latest"), `Some(v)` pins version `v` and fails with
    /// `NotFound` if `v` was never deployed or is already
    /// draining/retired. Feed/fetch semantics are
    /// [`ModelServer::submit`]'s (batch axis 0 on every feed).
    pub fn submit(
        &self,
        model: &str,
        version: Option<u64>,
        feeds: &[(&str, Tensor)],
        fetches: &[&str],
    ) -> Result<ManagedHandle> {
        if self.shutting_down.load(Ordering::SeqCst) {
            return Err(Status::unavailable("model manager is shutting down"));
        }
        let model_arc = self
            .models
            .read()
            .unwrap()
            .get(model)
            .cloned()
            .ok_or_else(|| Status::not_found(format!("model {model:?} is not deployed")))?;
        // Hold the model read-lock across resolve + admit: the hot-swap
        // takes the write-lock, so a request that resolved a live
        // version is admitted to its queues before draining can start.
        let st = model_arc.state.read().unwrap();
        let entry = match version {
            Some(v) => Arc::clone(st.versions.get(&v).ok_or_else(|| {
                Status::not_found(format!("model {model:?} has no version {v}"))
            })?),
            None => {
                let v = st.live.ok_or_else(|| {
                    Status::unavailable(format!("model {model:?} has no live version"))
                })?;
                Arc::clone(st.versions.get(&v).expect("live version must be in the table"))
            }
        };
        match entry.state() {
            VersionState::Live => {}
            VersionState::Loading | VersionState::Warming => {
                return Err(Status::unavailable(format!(
                    "model {model:?} v{} is still warming",
                    entry.version
                )));
            }
            VersionState::Draining | VersionState::Retired => {
                return Err(Status::not_found(format!(
                    "model {model:?} v{} is retired (hot-swapped out)",
                    entry.version
                )));
            }
        }
        let start = Instant::now();
        let inner = entry.server.submit(feeds, fetches)?;
        entry.counters.submitted.inc();
        entry.counters.inflight.add(1);
        Ok(ManagedHandle {
            inner,
            start,
            counters: Arc::clone(&entry.counters),
            _inflight: InflightGuard(Arc::clone(&entry.counters)),
        })
    }

    /// Blocking convenience: submit and wait.
    pub fn run(
        &self,
        model: &str,
        version: Option<u64>,
        feeds: &[(&str, Tensor)],
        fetches: &[&str],
    ) -> Result<Vec<Tensor>> {
        self.submit(model, version, feeds, fetches)?.wait()
    }

    /// True once [`ModelManager::shutdown`] has begun — the `/healthz`
    /// liveness signal for the debug surface.
    pub fn is_shutting_down(&self) -> bool {
        self.shutting_down.load(Ordering::SeqCst)
    }

    /// Every live version's Session as `(model, version, session)`,
    /// ordered by model name — the debug surface reads their profilers
    /// for `/statusz` and their traces for `/tracez`.
    pub fn live_sessions(&self) -> Vec<(String, u64, Arc<Session>)> {
        let models: Vec<Arc<Model>> = {
            let map = self.models.read().unwrap();
            let mut ms: Vec<Arc<Model>> = map.values().cloned().collect();
            ms.sort_by(|a, b| a.name.cmp(&b.name));
            ms
        };
        let mut out = Vec::new();
        for model in models {
            let st = model.state.read().unwrap();
            if let Some(v) = st.live {
                if let Some(entry) = st.versions.get(&v) {
                    out.push((model.name.clone(), v, Arc::clone(entry.server.session())));
                }
            }
        }
        out
    }

    /// The version "latest" currently routes to, if any.
    pub fn live_version(&self, model: &str) -> Option<u64> {
        let model_arc = self.models.read().unwrap().get(model).cloned()?;
        let st = model_arc.state.read().unwrap();
        st.live
    }

    /// Deployed model names, sorted.
    pub fn models(&self) -> Vec<String> {
        let mut names: Vec<String> = self.models.read().unwrap().keys().cloned().collect();
        names.sort();
        names
    }

    /// Stats for every version of every model, ordered by model name
    /// then version number.
    pub fn stats(&self) -> Vec<VersionStats> {
        let models: Vec<Arc<Model>> = {
            let map = self.models.read().unwrap();
            let mut ms: Vec<Arc<Model>> = map.values().cloned().collect();
            ms.sort_by(|a, b| a.name.cmp(&b.name));
            ms
        };
        let mut out = Vec::new();
        for model in models {
            let st = model.state.read().unwrap();
            for entry in st.versions.values() {
                out.push(VersionStats {
                    model: model.name.clone(),
                    version: entry.version,
                    state: entry.state(),
                    live: st.live == Some(entry.version),
                    requests: entry.counters.submitted.get(),
                    ok: entry.counters.ok.get(),
                    errors: entry.counters.errors.get(),
                    inflight: entry.counters.inflight.get().max(0) as u64,
                    batch: entry.server.stats(),
                    latency: entry.counters.latency.summary(),
                });
            }
        }
        out
    }

    /// [`ModelManager::stats`] for one model.
    pub fn model_stats(&self, model: &str) -> Vec<VersionStats> {
        self.stats().into_iter().filter(|s| s.model == model).collect()
    }

    /// Stats rendered as JSON (the TCP front end's stats reply).
    pub fn stats_json(&self) -> String {
        let ms = |d: std::time::Duration| d.as_secs_f64() * 1e3;
        let mut versions = Json::arr();
        for s in self.stats() {
            versions.push(
                Json::obj()
                    .set("model", s.model)
                    .set("version", s.version)
                    .set("state", s.state.to_string())
                    .set("live", s.live)
                    .set("requests", s.requests)
                    .set("ok", s.ok)
                    .set("errors", s.errors)
                    .set("inflight", s.inflight)
                    .set("batches", s.batch.batches)
                    .set("mean_batch_rows", s.batch.mean_batch_rows())
                    .set("latency_ms_p50", ms(s.latency.p50))
                    .set("latency_ms_p95", ms(s.latency.p95))
                    .set("latency_ms_p99", ms(s.latency.p99)),
            );
        }
        Json::obj()
            .set("versions", versions)
            .set("shutting_down", self.shutting_down.load(Ordering::SeqCst))
            .set("metrics", self.registry.to_json())
            .render()
    }
}

impl Drop for ModelManager {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Restore a checkpoint bundle into a Session's Variables: extend the
/// graph with one `Placeholder → Assign` pair per bundled tensor and run
/// them as a single step, feeding the values. Feeding (rather than
/// baking `Const` weights into restore nodes) keeps the stored graph
/// free of a second copy of every weight. Fails with `NotFound` if a
/// bundled name has no matching `Variable` node.
pub fn restore_variables(session: &Session, bundle: &HashMap<String, Tensor>) -> Result<()> {
    if bundle.is_empty() {
        return Ok(());
    }
    let mut names: Vec<&String> = bundle.keys().collect();
    names.sort();
    // Validate every name against a snapshot before touching the graph,
    // so a bad bundle rejects the whole restore without leaving partial
    // `_restore` plumbing behind.
    {
        let snapshot = session.graph_snapshot();
        for name in &names {
            let var = snapshot.find(name.as_str()).ok_or_else(|| {
                Status::not_found(format!(
                    "checkpoint tensor {name:?} has no matching node in the graph"
                ))
            })?;
            if snapshot.node(var).op != "Variable" {
                return Err(Status::invalid_argument(format!(
                    "checkpoint tensor {name:?} maps to op {:?}, expected Variable",
                    snapshot.node(var).op
                )));
            }
        }
    }
    let mut feed_pairs: Vec<(String, Tensor)> = Vec::with_capacity(names.len());
    let mut target = String::new();
    session.extend(|b| {
        let mut assigns = Vec::with_capacity(names.len());
        for name in &names {
            let var = b.graph.must_find(name.as_str())?;
            let t = &bundle[name.as_str()];
            let ph = b.placeholder(&format!("_restore/{name}/value"), t.dtype())?;
            feed_pairs.push((b.graph.node(ph.node).name.clone(), t.clone()));
            assigns.push(b.assign(Endpoint::new(var, 0), ph)?);
        }
        let group = b.group("_restore/all", assigns);
        target = b.graph.node(group).name.clone();
        Ok(())
    })?;
    let feeds: Vec<(&str, Tensor)> =
        feed_pairs.iter().map(|(n, t)| (n.as_str(), t.clone())).collect();
    session.run(&feeds, &[], &[&target])?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::builder::GraphBuilder;
    use crate::tensor::DType;

    /// y = x * k as a Session (one column feed, one fetch named "Mul:0").
    fn scale_session(k: f32) -> (Arc<Session>, String) {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let c = b.scalar(k);
        let y = b.mul(x, c);
        let fetch = format!("{}:0", b.graph.node(y.node).name);
        (Arc::new(Session::new(b.into_graph(), SessionOptions::default())), fetch)
    }

    fn col(vals: &[f32]) -> Tensor {
        Tensor::from_f32(vec![vals.len(), 1], vals.to_vec()).unwrap()
    }

    #[test]
    fn deploy_and_route_latest() {
        let mgr = ModelManager::new(ManagerOptions::default());
        let (s1, fetch) = scale_session(2.0);
        mgr.deploy_session("m", 1, s1, &[]).unwrap();
        assert_eq!(mgr.live_version("m"), Some(1));
        let out = mgr.run("m", None, &[("x", col(&[3.0]))], &[&fetch]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[6.0]);
        // Pinned to the same version works too.
        let out = mgr.run("m", Some(1), &[("x", col(&[4.0]))], &[&fetch]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[8.0]);
        let stats = mgr.model_stats("m");
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].requests, 2);
        assert_eq!(stats[0].ok, 2);
        assert_eq!(stats[0].latency.count, 2);
        assert!(stats[0].live);
    }

    #[test]
    fn unknown_model_and_version_are_not_found() {
        let mgr = ModelManager::new(ManagerOptions::default());
        let e = mgr.run("ghost", None, &[("x", col(&[1.0]))], &["y:0"]).unwrap_err();
        assert_eq!(e.code, crate::error::Code::NotFound);
        let (s1, fetch) = scale_session(1.0);
        mgr.deploy_session("m", 1, s1, &[]).unwrap();
        let e = mgr.run("m", Some(9), &[("x", col(&[1.0]))], &[&fetch]).unwrap_err();
        assert_eq!(e.code, crate::error::Code::NotFound);
    }

    #[test]
    fn swap_retires_old_and_redirects_latest() {
        let mgr = ModelManager::new(ManagerOptions::default());
        let (s1, fetch) = scale_session(1.0);
        let (s2, fetch2) = scale_session(10.0);
        assert_eq!(fetch, fetch2);
        mgr.deploy_session("m", 1, s1, &[]).unwrap();
        mgr.deploy_session("m", 2, s2, &[]).unwrap();
        assert_eq!(mgr.live_version("m"), Some(2));
        let out = mgr.run("m", None, &[("x", col(&[3.0]))], &[&fetch]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[30.0]);
        // Pinned to the retired version: NotFound, not a hang.
        let e = mgr.run("m", Some(1), &[("x", col(&[3.0]))], &[&fetch]).unwrap_err();
        assert_eq!(e.code, crate::error::Code::NotFound);
        assert!(e.message.contains("retired"), "{}", e.message);
        let stats = mgr.model_stats("m");
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].state, VersionState::Retired);
        assert_eq!(stats[1].state, VersionState::Live);
    }

    #[test]
    fn duplicate_version_rejected_rollback_allowed() {
        let mgr = ModelManager::new(ManagerOptions::default());
        let (s1, _) = scale_session(1.0);
        let (s1b, _) = scale_session(1.5);
        let (s2, fetch) = scale_session(2.0);
        let (s1c, _) = scale_session(3.0);
        mgr.deploy_session("m", 1, s1, &[]).unwrap();
        let e = mgr.deploy_session("m", 1, s1b, &[]).unwrap_err();
        assert_eq!(e.code, crate::error::Code::AlreadyExists);
        mgr.deploy_session("m", 2, s2, &[]).unwrap();
        // v1 is retired now; re-deploying its number is the rollback path.
        mgr.deploy_session("m", 1, s1c, &[]).unwrap();
        assert_eq!(mgr.live_version("m"), Some(1));
        let out = mgr.run("m", None, &[("x", col(&[2.0]))], &[&fetch]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[6.0]);
    }

    #[test]
    fn version_zero_rejected() {
        let mgr = ModelManager::new(ManagerOptions::default());
        let (s1, _) = scale_session(1.0);
        let e = mgr.deploy_session("m", 0, s1, &[]).unwrap_err();
        assert_eq!(e.code, crate::error::Code::InvalidArgument);
    }

    #[test]
    fn failed_warmup_keeps_previous_version_live() {
        let mgr = ModelManager::new(ManagerOptions::default());
        let (s1, fetch) = scale_session(5.0);
        mgr.deploy_session("m", 1, s1, &[]).unwrap();
        let (s2, _) = scale_session(7.0);
        // Warmup fetches a node that does not exist → deploy fails.
        let bad = WarmupRequest {
            feeds: vec![("x".into(), col(&[1.0]))],
            fetches: vec!["nope:0".into()],
        };
        let e = mgr.deploy_session("m", 2, s2, &[bad]).unwrap_err();
        assert!(e.message.contains("warmup"), "{}", e.message);
        assert_eq!(mgr.live_version("m"), Some(1));
        let out = mgr.run("m", None, &[("x", col(&[2.0]))], &[&fetch]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[10.0]);
        // The failed version shows as retired in stats.
        let stats = mgr.model_stats("m");
        assert_eq!(stats.iter().find(|s| s.version == 2).unwrap().state, VersionState::Retired);
    }

    #[test]
    fn undeploy_then_shutdown() {
        let mgr = ModelManager::new(ManagerOptions::default());
        let (s1, fetch) = scale_session(1.0);
        mgr.deploy_session("m", 1, s1, &[]).unwrap();
        mgr.undeploy("m").unwrap();
        let e = mgr.run("m", None, &[("x", col(&[1.0]))], &[&fetch]).unwrap_err();
        assert_eq!(e.code, crate::error::Code::Unavailable);
        let e = mgr.run("m", Some(1), &[("x", col(&[1.0]))], &[&fetch]).unwrap_err();
        assert_eq!(e.code, crate::error::Code::NotFound);
        mgr.shutdown();
        let (s2, _) = scale_session(2.0);
        let e = mgr.deploy_session("m", 2, s2, &[]).unwrap_err();
        assert_eq!(e.code, crate::error::Code::Unavailable);
        mgr.shutdown(); // idempotent
    }

    #[test]
    fn undeploy_during_warmup_never_resurrects_the_candidate() {
        // Race an undeploy against a slow-warmup deploy. Whichever side
        // wins, the invariant is: a version reported live actually
        // serves; a deploy that lost returns an error and leaves
        // everything retired — never a live pointer at a shut-down
        // server.
        let mgr = Arc::new(ModelManager::new(ManagerOptions::default()));
        let (s1, fetch) = scale_session(1.0);
        mgr.deploy_session("m", 1, s1, &[]).unwrap();
        let (s2, _) = scale_session(2.0);
        let warmup: Vec<WarmupRequest> = (0..32)
            .map(|i| WarmupRequest {
                feeds: vec![("x".to_string(), col(&[i as f32]))],
                fetches: vec![fetch.clone()],
            })
            .collect();
        let deployer = {
            let mgr = Arc::clone(&mgr);
            std::thread::spawn(move || mgr.deploy_session("m", 2, s2, &warmup))
        };
        std::thread::sleep(std::time::Duration::from_millis(20));
        mgr.undeploy("m").unwrap();
        let deploy_result = deployer.join().unwrap();
        match mgr.live_version("m") {
            // Deploy won the race: v2 swapped in after the undeploy and
            // must genuinely serve.
            Some(v) => {
                assert_eq!(v, 2);
                assert!(deploy_result.is_ok());
                let out = mgr.run("m", None, &[("x", col(&[3.0]))], &[&fetch]).unwrap();
                assert_eq!(out[0].as_f32().unwrap(), &[6.0]);
            }
            // Undeploy won — either mid-warmup (deploy errored) or after
            // the swap (deploy succeeded, then v2 was retired). Either
            // way nothing may route and every version must be retired.
            None => {
                for s in mgr.model_stats("m") {
                    assert_eq!(s.state, VersionState::Retired, "v{} not retired", s.version);
                }
                let e = mgr.run("m", None, &[("x", col(&[1.0]))], &[&fetch]).unwrap_err();
                assert_eq!(e.code, crate::error::Code::Unavailable);
            }
        }
    }

    #[test]
    fn stats_json_renders() {
        let mgr = ModelManager::new(ManagerOptions::default());
        let (s1, fetch) = scale_session(1.0);
        mgr.deploy_session("m", 1, s1, &[]).unwrap();
        mgr.run("m", None, &[("x", col(&[1.0]))], &[&fetch]).unwrap();
        let j = mgr.stats_json();
        assert!(j.contains("\"model\":\"m\""), "{j}");
        assert!(j.contains("\"state\":\"live\""), "{j}");
        // The same counters surface in the unified registry dump.
        let parsed = Json::parse(&j).unwrap();
        let metrics = parsed.get("metrics").unwrap();
        assert_eq!(metrics.get("serving/m/v1/requests").and_then(Json::as_i64), Some(1));
        assert_eq!(metrics.get("serving/m/v1/ok").and_then(Json::as_i64), Some(1));
        assert_eq!(parsed.get("shutting_down").and_then(Json::as_bool), Some(false));
        assert_eq!(mgr.metrics().counter_value("serving/m/v1/errors"), Some(0));
    }

    #[test]
    fn restore_variables_roundtrip() {
        let mut b = GraphBuilder::new();
        let v = b.variable("w", Tensor::zeros(DType::F32, vec![2, 2]).unwrap()).unwrap();
        let x = b.placeholder("x", DType::F32).unwrap();
        let y = b.matmul(x, v);
        let fetch = format!("{}:0", b.graph.node(y.node).name);
        let sess = Session::new(b.into_graph(), SessionOptions::default());
        let mut bundle = HashMap::new();
        bundle.insert("w".to_string(), Tensor::from_f32(vec![2, 2], vec![1., 2., 3., 4.]).unwrap());
        restore_variables(&sess, &bundle).unwrap();
        let out = sess
            .run(&[("x", Tensor::from_f32(vec![1, 2], vec![1.0, 1.0]).unwrap())], &[&fetch], &[])
            .unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[4.0, 6.0]);
        // A second restore (new values) also works — extend is repeatable.
        bundle.insert("w".to_string(), Tensor::from_f32(vec![2, 2], vec![0., 0., 0., 1.]).unwrap());
        restore_variables(&sess, &bundle).unwrap();
        let out = sess
            .run(&[("x", Tensor::from_f32(vec![1, 2], vec![1.0, 1.0]).unwrap())], &[&fetch], &[])
            .unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[0.0, 1.0]);
        // Unknown names fail loudly.
        let mut bad = HashMap::new();
        bad.insert("ghost".to_string(), Tensor::scalar_f32(1.0));
        assert_eq!(
            restore_variables(&sess, &bad).unwrap_err().code,
            crate::error::Code::NotFound
        );
    }
}
