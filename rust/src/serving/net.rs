//! The TCP predict front end: exposes a [`ModelManager`] as a standalone
//! serving process, so clients in other processes (or on other hosts)
//! reach models over the same length-prefixed frame transport the
//! distributed runtime uses ([`crate::wire`] — one frame layout, two
//! protocols).
//!
//! A connection is persistent: the client writes any number of request
//! frames and reads one reply frame per request, in order. The server
//! runs one handler thread per connection (the same thread-per-connection
//! model as `distributed::worker`); a handler blocks inside
//! [`ModelManager::run`], which is exactly the dynamic-batching admission
//! path — so concurrent connections coalesce into shared batches on the
//! serving lanes, and per-connection threads are the knob that bounds
//! concurrent in-flight requests.
//!
//! Message types (this protocol's own space, unrelated to
//! `distributed::proto`'s):
//!
//! | type | payload |
//! |------|---------|
//! | [`MSG_PREDICT`] | model, version (0 = latest), fetches, feeds |
//! | [`MSG_PREDICT_REPLY`] | status, fetched tensors in fetch order |
//! | [`MSG_STATS`] | empty → [`MSG_STATS_REPLY`]: manager stats as JSON |
//! | [`MSG_PING`] | empty → [`MSG_PONG`]: liveness probe |

use super::manager::ModelManager;
use crate::error::{Result, Status};
use crate::obs::httpz::{DebugServer, Response, Routes};
use crate::tensor::Tensor;
use crate::wire::{self, WireMetrics};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

pub const MSG_PREDICT: u8 = 1;
pub const MSG_PREDICT_REPLY: u8 = 2;
pub const MSG_STATS: u8 = 3;
pub const MSG_STATS_REPLY: u8 = 4;
pub const MSG_PING: u8 = 5;
pub const MSG_PONG: u8 = 6;

/// Human name of a serving message type, for the per-type wire counters
/// (`wire/PREDICT/frames_in` etc. in the manager's registry).
pub fn msg_name(t: u8) -> String {
    match t {
        MSG_PREDICT => "PREDICT".to_string(),
        MSG_PREDICT_REPLY => "PREDICT_REPLY".to_string(),
        MSG_STATS => "STATS".to_string(),
        MSG_STATS_REPLY => "STATS_REPLY".to_string(),
        MSG_PING => "PING".to_string(),
        MSG_PONG => "PONG".to_string(),
        other => wire::raw_msg_name(other),
    }
}

/// One inference request on the wire.
pub struct PredictRequest {
    pub model: String,
    /// `None` = route to the live version ("latest"); encoded as 0.
    pub version: Option<u64>,
    pub feeds: Vec<(String, Tensor)>,
    pub fetches: Vec<String>,
}

impl PredictRequest {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        wire::put_str(&mut out, &self.model);
        wire::put_u64(&mut out, self.version.unwrap_or(0));
        wire::encode_str_list(&mut out, &self.fetches);
        wire::encode_tensor_map(&mut out, &self.feeds);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<PredictRequest> {
        let mut pos = 0;
        let model = wire::get_str(buf, &mut pos)?;
        let version = match wire::get_u64(buf, &mut pos)? {
            0 => None,
            v => Some(v),
        };
        let fetches = wire::decode_str_list(buf, &mut pos)?;
        let feeds = wire::decode_tensor_map(buf, &mut pos)?;
        Ok(PredictRequest { model, version, feeds, fetches })
    }
}

/// The reply: a status plus, on success, one tensor per fetch in request
/// order (keyed by fetch name).
pub struct PredictReply {
    pub status: Result<()>,
    pub outputs: Vec<(String, Tensor)>,
}

impl PredictReply {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        wire::encode_status(&mut out, &self.status);
        wire::encode_tensor_map(&mut out, &self.outputs);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<PredictReply> {
        let mut pos = 0;
        let status = wire::decode_status(buf, &mut pos)?;
        let outputs = wire::decode_tensor_map(buf, &mut pos)?;
        Ok(PredictReply { status, outputs })
    }
}

/// A running TCP front end. Dropping it (or calling
/// [`NetServer::shutdown`]) stops accepting; established connections
/// finish their in-flight request and close on their next read.
pub struct NetServer {
    addr: SocketAddr,
    shutting_down: Arc<AtomicBool>,
    accept_thread: Mutex<Option<JoinHandle<()>>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and start
    /// the accept loop. Returns once the listener is bound; serving runs
    /// on background threads.
    pub fn serve(manager: Arc<ModelManager>, addr: &str) -> Result<NetServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Status::unavailable(format!("bind {addr}: {e}")))?;
        let local = listener.local_addr()?;
        let shutting_down = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&shutting_down);
        // Frame/byte accounting lands in the manager's registry, so
        // MSG_STATS replies include the front end's own wire traffic.
        let wire_metrics = WireMetrics::new(manager.metrics(), "wire", msg_name);
        let accept = std::thread::Builder::new()
            .name("modelhub-accept".to_string())
            .spawn(move || {
                for conn in listener.incoming() {
                    if flag.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let manager = Arc::clone(&manager);
                            let flag = Arc::clone(&flag);
                            let wm = Arc::clone(&wire_metrics);
                            let spawned = std::thread::Builder::new()
                                .name("modelhub-conn".to_string())
                                .spawn(move || handle_connection(&manager, &flag, &wm, stream));
                            if spawned.is_err() {
                                // Out of threads: shed the connection (it
                                // closes, the client sees Unavailable)
                                // rather than dying.
                                continue;
                            }
                        }
                        // Transient accept failures (ECONNABORTED, fd
                        // pressure) must not kill the front end; back off
                        // briefly and keep accepting. Only the shutdown
                        // flag ends the loop.
                        Err(_) => {
                            std::thread::sleep(std::time::Duration::from_millis(10));
                        }
                    }
                }
            })
            .expect("spawn modelhub accept thread");
        Ok(NetServer { addr: local, shutting_down, accept_thread: Mutex::new(Some(accept)) })
    }

    /// The bound address (resolves the ephemeral port of `":0"` binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Mount the standard debug/status surface for a serving hub on its
    /// own listener (`debug_addr`, e.g. `"127.0.0.1:0"`): `/healthz`,
    /// `/varz`, `/statusz`, `/tracez` — see [`debug_routes`]. Serve it
    /// beside the frame protocol; shut it down independently.
    pub fn serve_debug(manager: &Arc<ModelManager>, debug_addr: &str) -> Result<DebugServer> {
        DebugServer::serve(debug_routes(manager), debug_addr)
    }

    /// Stop accepting connections and join the accept loop. Idempotent.
    pub fn shutdown(&self) {
        if self.shutting_down.swap(true, Ordering::SeqCst) {
            return;
        }
        // Unblock the accept loop with a throwaway connection to our own
        // port; it observes the flag and exits. A wildcard bind address
        // (0.0.0.0 / ::) is not connectable, so target loopback on the
        // same port instead.
        let mut wake_addr = self.addr;
        if wake_addr.ip().is_unspecified() {
            wake_addr.set_ip(match wake_addr {
                SocketAddr::V4(_) => std::net::Ipv4Addr::LOCALHOST.into(),
                SocketAddr::V6(_) => std::net::Ipv6Addr::LOCALHOST.into(),
            });
        }
        let woke = TcpStream::connect(wake_addr).is_ok();
        if let Some(h) = self.accept_thread.lock().unwrap().take() {
            if woke {
                let _ = h.join();
            }
            // If the wake connection failed (firewalled loopback, etc.)
            // the accept thread stays parked until the next incoming
            // connection, at which point it observes the flag and exits;
            // joining here would block the caller indefinitely.
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The serving hub's debug-route table:
///
/// | path | body |
/// |------|------|
/// | `/healthz` | `ok` (200), or `shutting down` (503) once the manager drains |
/// | `/varz` | the manager registry in Prometheus exposition format |
/// | `/statusz` | per-live-version profiler report (top-k nodes/ops/bytes, step latency, memory watermarks) |
/// | `/tracez` | the newest live version's last traced step as chrome://tracing JSON |
pub fn debug_routes(manager: &Arc<ModelManager>) -> Routes {
    let m_health = Arc::clone(manager);
    let m_varz = Arc::clone(manager);
    let m_statusz = Arc::clone(manager);
    let m_tracez = Arc::clone(manager);
    Routes::new()
        .add("/healthz", move || {
            if m_health.is_shutting_down() {
                Response::text(503, "shutting down\n")
            } else {
                Response::text(200, "ok\n")
            }
        })
        .add("/varz", move || Response::text(200, m_varz.metrics().export_text()))
        .add("/statusz", move || {
            let mut body = String::new();
            for (model, version, session) in m_statusz.live_sessions() {
                body.push_str(&format!("== model {model:?} v{version} ==\n"));
                match session.profiler() {
                    Some(p) => body.push_str(&p.report_text(10)),
                    None => body.push_str("(profiling disabled: profile_window = 0)\n"),
                }
                body.push('\n');
            }
            if body.is_empty() {
                body.push_str("no live model versions\n");
            }
            Response::text(200, body)
        })
        .add("/tracez", move || {
            for (_, _, session) in m_tracez.live_sessions() {
                if let Some(t) = session.last_trace() {
                    return Response::json(200, t.to_chrome_trace());
                }
            }
            Response::text(404, "no traced step yet\n")
        })
}

/// One connection's request loop: read a frame, serve it, reply, repeat
/// until EOF / transport error / server shutdown.
fn handle_connection(
    manager: &ModelManager,
    shutting_down: &AtomicBool,
    wm: &WireMetrics,
    mut stream: TcpStream,
) {
    stream.set_nodelay(true).ok();
    loop {
        let (msg_type, payload) = match wm.read_frame(&mut stream) {
            Ok(f) => f,
            Err(_) => return, // client hung up (or sent garbage framing)
        };
        if shutting_down.load(Ordering::SeqCst) {
            // Answer with the reply type the request expects (a ping must
            // not see a predict frame), then close the connection. Stats
            // requests get the real dump — it carries the
            // `"shutting_down":true` marker, which is exactly what a
            // prober draining the hub wants to see.
            let _ = match msg_type {
                MSG_PING => wm.write_frame(&mut stream, MSG_PONG, b""),
                MSG_STATS => {
                    wm.write_frame(&mut stream, MSG_STATS_REPLY, manager.stats_json().as_bytes())
                }
                _ => {
                    let reply = PredictReply {
                        status: Err(Status::unavailable("model hub is shutting down")),
                        outputs: vec![],
                    };
                    wm.write_frame(&mut stream, MSG_PREDICT_REPLY, &reply.encode())
                }
            };
            return;
        }
        let written = match msg_type {
            MSG_PREDICT => {
                let reply = serve_predict(manager, &payload);
                wm.write_frame(&mut stream, MSG_PREDICT_REPLY, &reply.encode())
            }
            MSG_STATS => {
                wm.write_frame(&mut stream, MSG_STATS_REPLY, manager.stats_json().as_bytes())
            }
            MSG_PING => wm.write_frame(&mut stream, MSG_PONG, b""),
            other => {
                let reply = PredictReply {
                    status: Err(Status::invalid_argument(format!(
                        "unknown serving message type {other}"
                    ))),
                    outputs: vec![],
                };
                wm.write_frame(&mut stream, MSG_PREDICT_REPLY, &reply.encode())
            }
        };
        if written.is_err() {
            return;
        }
    }
}

fn serve_predict(manager: &ModelManager, payload: &[u8]) -> PredictReply {
    let req = match PredictRequest::decode(payload) {
        Ok(r) => r,
        Err(e) => return PredictReply { status: Err(e), outputs: vec![] },
    };
    let feeds: Vec<(&str, Tensor)> =
        req.feeds.iter().map(|(n, t)| (n.as_str(), t.clone())).collect();
    let fetches: Vec<&str> = req.fetches.iter().map(String::as_str).collect();
    match manager.run(&req.model, req.version, &feeds, &fetches) {
        Ok(outs) => PredictReply {
            status: Ok(()),
            outputs: req.fetches.iter().cloned().zip(outs).collect(),
        },
        Err(e) => PredictReply { status: Err(e), outputs: vec![] },
    }
}

/// A blocking client for one connection to a [`NetServer`]. Not
/// `Sync`-shareable by design: one request is in flight per connection
/// at a time; use one client per thread (they batch together on the
/// server's lanes anyway).
pub struct NetClient {
    stream: TcpStream,
}

impl NetClient {
    pub fn connect(addr: &str) -> Result<NetClient> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Status::unavailable(format!("connect {addr}: {e}")))?;
        stream.set_nodelay(true).ok();
        Ok(NetClient { stream })
    }

    /// One predict round trip; returns the fetched tensors in `fetches`
    /// order. Server-side failures come back with their original status
    /// code (`NotFound` for unknown model/version, etc.); transport
    /// failures surface as `Unavailable`.
    pub fn predict(
        &mut self,
        model: &str,
        version: Option<u64>,
        feeds: &[(&str, Tensor)],
        fetches: &[&str],
    ) -> Result<Vec<Tensor>> {
        let req = PredictRequest {
            model: model.to_string(),
            version,
            feeds: feeds.iter().map(|(n, t)| (n.to_string(), t.clone())).collect(),
            fetches: fetches.iter().map(|s| s.to_string()).collect(),
        };
        wire::write_frame(&mut self.stream, MSG_PREDICT, &req.encode())?;
        let (msg_type, payload) = wire::read_frame(&mut self.stream)?;
        if msg_type != MSG_PREDICT_REPLY {
            return Err(Status::internal(format!("unexpected reply type {msg_type}")));
        }
        let reply = PredictReply::decode(&payload)?;
        reply.status?;
        if reply.outputs.len() != fetches.len() {
            return Err(Status::internal(format!(
                "predict reply has {} outputs for {} fetches",
                reply.outputs.len(),
                fetches.len()
            )));
        }
        Ok(reply.outputs.into_iter().map(|(_, t)| t).collect())
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<()> {
        wire::write_frame(&mut self.stream, MSG_PING, b"")?;
        let (msg_type, _) = wire::read_frame(&mut self.stream)?;
        if msg_type != MSG_PONG {
            return Err(Status::internal(format!("unexpected ping reply type {msg_type}")));
        }
        Ok(())
    }

    /// The manager's stats, rendered as JSON by the server.
    pub fn stats_json(&mut self) -> Result<String> {
        wire::write_frame(&mut self.stream, MSG_STATS, b"")?;
        let (msg_type, payload) = wire::read_frame(&mut self.stream)?;
        if msg_type != MSG_STATS_REPLY {
            return Err(Status::internal(format!("unexpected stats reply type {msg_type}")));
        }
        Ok(String::from_utf8_lossy(&payload).to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Code;

    #[test]
    fn predict_request_roundtrip() {
        let req = PredictRequest {
            model: "mnist".into(),
            version: Some(3),
            feeds: vec![("x".into(), Tensor::from_f32(vec![1, 2], vec![1., 2.]).unwrap())],
            fetches: vec!["logits:0".into()],
        };
        let dec = PredictRequest::decode(&req.encode()).unwrap();
        assert_eq!(dec.model, "mnist");
        assert_eq!(dec.version, Some(3));
        assert_eq!(dec.fetches, vec!["logits:0".to_string()]);
        assert_eq!(dec.feeds[0].1.as_f32().unwrap(), &[1., 2.]);

        let latest = PredictRequest { version: None, ..req };
        let dec = PredictRequest::decode(&latest.encode()).unwrap();
        assert_eq!(dec.version, None);
    }

    #[test]
    fn predict_reply_roundtrip() {
        let ok = PredictReply {
            status: Ok(()),
            outputs: vec![("y:0".into(), Tensor::scalar_f32(4.0))],
        };
        let dec = PredictReply::decode(&ok.encode()).unwrap();
        assert!(dec.status.is_ok());
        assert_eq!(dec.outputs[0].1.scalar_value_f32().unwrap(), 4.0);

        let err = PredictReply {
            status: Err(Status::not_found("model \"ghost\" is not deployed")),
            outputs: vec![],
        };
        let dec = PredictReply::decode(&err.encode()).unwrap();
        assert_eq!(dec.status.unwrap_err().code, Code::NotFound);
    }

    #[test]
    fn truncated_predict_request_rejected() {
        let req = PredictRequest {
            model: "m".into(),
            version: None,
            feeds: vec![("x".into(), Tensor::scalar_f32(1.0))],
            fetches: vec!["y:0".into()],
        };
        let enc = req.encode();
        for cut in 0..enc.len() {
            assert!(PredictRequest::decode(&enc[..cut]).is_err(), "cut at {cut}");
        }
    }
}
