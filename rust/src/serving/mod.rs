//! Inference serving with dynamic request batching.
//!
//! The paper's §3 partial-execution model — feed/fetch subgraphs pruned,
//! compiled, and *cached per run signature* — is exactly the substrate an
//! inference service needs: a server sets up a [`crate::Session`] once and
//! then executes the same small subgraph millions of times. The OSDI
//! follow-up (TensorFlow: A system for large-scale machine learning,
//! §Serving) adds the observation that makes it fast in production:
//! many concurrent *small* client requests should be coalesced into one
//! *large* device step, because a step's fixed overhead (dispatch,
//! executor wakeup, kernel launch) is amortized over every row in the
//! batch.
//!
//! This module provides that layer on top of `Session`:
//!
//! * [`ModelServer`] — owns a `Session`, admits requests from any number
//!   of client threads through a bounded queue
//!   ([`crate::util::bounded::Bounded`], giving backpressure when the
//!   service is saturated), and groups requests by their
//!   `(feeds, fetches)` signature into per-signature *lanes*.
//! * The **batch scheduler** — one scheduler thread per lane pops the
//!   first pending request, greedily drains every request already
//!   queued (up to [`BatchConfig::max_batch_size`] rows), and lets a
//!   *lone* request linger up to [`BatchConfig::max_batch_delay`] for a
//!   batch-mate. Feed tensors are packed along axis 0 with
//!   [`crate::Tensor::concat_rows`], the batch runs as a single
//!   `Session::run`, and each fetch is unpacked back per request with
//!   [`crate::Tensor::split_rows`].
//! * [`ResponseHandle`] — a per-request future: `submit` returns
//!   immediately and the client blocks (or polls) on the handle.
//!
//! Requirements on the served graph: every feed and every fetch must
//! carry the batch dimension on axis 0 (the usual convention for
//! inference graphs — `[batch, features…]` in, `[batch, logits…]` out).
//! A fetch that reduces away the batch axis (e.g. a scalar mean) is
//! reported as an error to every request in the batch rather than
//! silently mis-split.
//!
//! Because every batch a lane forms replays one cached `Session` step,
//! lanes also inherit the step memory planner (`crate::memory`,
//! `SessionOptions::enable_memory_planning`): the cached step's arena
//! pool is reused across batched steps of the same signature, so after
//! warmup a lane's intermediates come out of pooled slots (dynamic
//! slots grow to the high-water batch size) instead of the allocator.
//! [`ModelServer::memory_stats`] exposes the per-lane reuse counters.
//!
//! Batching also composes with *intra-op* parallelism
//! (`SessionOptions::intra_op_threads`, `crate::device::ComputePool`):
//! coalescing requests is exactly what turns many tiny kernels — each
//! below the `parallel_for` inline threshold — into one large batched
//! MatMul/activation whose row panels fan out across the device's
//! compute pool. Size `intra_op_threads` to the cores you want a single
//! batch to use; results are bit-identical at every setting, so the
//! knob is pure throughput tuning.
//!
//! ```no_run
//! use rustflow::serving::{BatchConfig, ModelServer};
//! use rustflow::{GraphBuilder, Session, SessionOptions, Tensor, DType};
//!
//! let mut b = GraphBuilder::new();
//! let x = b.placeholder("x", DType::F32).unwrap();
//! let w = b.constant(Tensor::fill_f32(vec![4, 2], 0.5));
//! let y = b.matmul(x, w);
//! let fetch = format!("{}:0", b.graph.node(y.node).name);
//! let server = ModelServer::new(
//!     Session::new(b.into_graph(), SessionOptions::default()),
//!     BatchConfig::default(),
//! );
//! // Any number of client threads:
//! let handle = server
//!     .submit(&[("x", Tensor::fill_f32(vec![1, 4], 1.0))], &[&fetch])
//!     .unwrap();
//! let outputs = handle.wait().unwrap();
//! assert_eq!(outputs[0].shape().dims(), &[1, 2]);
//! ```

//! On top of the single-model `ModelServer`, the **model lifecycle
//! layer** makes the stack production-shaped (see [`manager`] and
//! [`net`]):
//!
//! * [`ModelManager`] — multiple named models, numbered versions loaded
//!   from GraphDef + checkpoint artifacts, atomic hot-swap with graceful
//!   draining (`loading → warming → live → draining → retired`), and
//!   per-version [`VersionStats`] with latency percentiles.
//! * [`net`] — a TCP predict front end over the shared [`crate::wire`]
//!   framing ([`NetServer`] accept loop + blocking [`NetClient`]), so
//!   the hub runs as a standalone process.

mod handle;
pub mod manager;
pub mod net;
mod server;

pub use handle::ResponseHandle;
pub use manager::{
    ManagedHandle, ManagerOptions, ModelManager, ModelSpec, VersionState, VersionStats,
    WarmupRequest,
};
pub use net::{NetClient, NetServer};
pub use server::ModelServer;

use std::time::Duration;

/// Dynamic-batching policy for one [`ModelServer`].
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Close a batch once this many rows have accumulated. `1` disables
    /// batching: every request runs as its own step (the baseline the
    /// serving bench compares against).
    pub max_batch_size: usize,
    /// Maximum extra latency the scheduler may add waiting for a
    /// batch-mate when a batch holds a single request and the queue is
    /// empty. Batches that already coalesced ≥ 2 requests run as soon as
    /// the queue drains — waiting out the delay there would stall
    /// closed-loop clients that can never fill `max_batch_size`.
    pub max_batch_delay: Duration,
    /// Admission-queue capacity per lane, in requests. `submit` blocks
    /// (backpressure) and `try_submit` fails with `ResourceExhausted`
    /// once a lane is this far behind.
    pub queue_capacity: usize,
    /// Maximum number of lanes (distinct `(feeds, fetches)` signatures).
    /// Each lane owns a scheduler thread and a queue, so signature churn
    /// must not grow them without bound: requests for a new signature
    /// beyond this cap fail with `ResourceExhausted`.
    pub max_lanes: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            max_batch_size: 32,
            max_batch_delay: Duration::from_millis(2),
            queue_capacity: 1024,
            max_lanes: 64,
        }
    }
}

impl BatchConfig {
    /// Batching disabled: every request is its own step.
    pub fn unbatched() -> Self {
        BatchConfig { max_batch_size: 1, ..Default::default() }
    }
}

/// Snapshot of a server's counters (monotonic since construction).
#[derive(Debug, Clone, Default)]
pub struct ServingStats {
    /// Requests admitted (successfully submitted).
    pub requests: u64,
    /// Session steps executed on behalf of those requests.
    pub batches: u64,
    /// Total rows across all executed batches.
    pub rows: u64,
}

impl ServingStats {
    /// Mean rows per device step — the batching win. 1.0 means no
    /// coalescing happened.
    pub fn mean_batch_rows(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.rows as f64 / self.batches as f64
        }
    }
}
