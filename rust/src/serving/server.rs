//! The model server and its per-signature batch scheduler threads.

use super::handle::{PendingRequest, ResponseHandle, ResponseSlot};
use super::{BatchConfig, ServingStats};
use crate::error::{Result, Status};
use crate::session::Session;
use crate::tensor::Tensor;
use crate::util::bounded::{Bounded, Pop};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// One (feeds, fetches) signature's admission queue. The paper caches one
/// compiled step per signature; a lane is the serving-side mirror of that
/// cache entry, so every batch the lane forms hits the same cached
/// executable.
struct Lane {
    feed_names: Vec<String>,
    fetch_names: Vec<String>,
    queue: Bounded<PendingRequest>,
}

#[derive(Default)]
struct Counters {
    requests: AtomicU64,
    batches: AtomicU64,
    rows: AtomicU64,
}

/// A multi-threaded inference front end over one [`Session`].
///
/// Clients call [`ModelServer::submit`] (async, returns a
/// [`ResponseHandle`]) or [`ModelServer::run`] (blocking) from any number
/// of threads. Requests with the same `(feeds, fetches)` signature share a
/// lane whose scheduler thread coalesces them into batched steps according
/// to the [`BatchConfig`].
pub struct ModelServer {
    session: Arc<Session>,
    config: BatchConfig,
    lanes: Mutex<HashMap<String, Arc<Lane>>>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    counters: Arc<Counters>,
    shutting_down: AtomicBool,
}

impl ModelServer {
    pub fn new(session: Session, config: BatchConfig) -> ModelServer {
        ModelServer::with_session(Arc::new(session), config)
    }

    pub fn with_session(session: Arc<Session>, config: BatchConfig) -> ModelServer {
        ModelServer {
            session,
            config,
            lanes: Mutex::new(HashMap::new()),
            workers: Mutex::new(Vec::new()),
            counters: Arc::new(Counters::default()),
            shutting_down: AtomicBool::new(false),
        }
    }

    /// The underlying session (e.g. to run init ops before serving, or to
    /// compare served results against direct `run` calls).
    pub fn session(&self) -> &Arc<Session> {
        &self.session
    }

    pub fn config(&self) -> &BatchConfig {
        &self.config
    }

    /// Submit a request; blocks only if the lane's admission queue is
    /// full (backpressure). Every feed tensor must carry this request's
    /// row count on axis 0.
    pub fn submit(&self, feeds: &[(&str, Tensor)], fetches: &[&str]) -> Result<ResponseHandle> {
        self.admit(feeds, fetches, true)
    }

    /// Like [`ModelServer::submit`] but never blocks: fails with
    /// `ResourceExhausted` when the lane is saturated (load shedding).
    pub fn try_submit(&self, feeds: &[(&str, Tensor)], fetches: &[&str]) -> Result<ResponseHandle> {
        self.admit(feeds, fetches, false)
    }

    /// Blocking convenience: submit and wait for completion.
    pub fn run(&self, feeds: &[(&str, Tensor)], fetches: &[&str]) -> Result<Vec<Tensor>> {
        self.submit(feeds, fetches)?.wait()
    }

    pub fn stats(&self) -> ServingStats {
        ServingStats {
            requests: self.counters.requests.load(Ordering::Relaxed),
            batches: self.counters.batches.load(Ordering::Relaxed),
            rows: self.counters.rows.load(Ordering::Relaxed),
        }
    }

    /// Memory reports of one lane's cached step (`Session::memory_stats`
    /// for the lane's `(feeds, fetches)` signature). Every batch a lane
    /// forms runs the same cached executable, so its step arenas are
    /// reused across batched steps — after warmup, `runtime.reuse_hits`
    /// should dominate `reuse_misses` even though batch sizes vary (the
    /// planner's dynamic slots grow to the high-water batch). `None`
    /// until the lane has executed its first batch.
    pub fn memory_stats(
        &self,
        feeds: &[&str],
        fetches: &[&str],
    ) -> Option<Vec<crate::memory::MemoryReport>> {
        self.session.memory_stats(feeds, fetches, &[])
    }

    /// Stop accepting requests, drain the lanes, and join the scheduler
    /// threads. Requests already admitted are executed; requests admitted
    /// concurrently with shutdown may be cancelled. Idempotent.
    pub fn shutdown(&self) {
        self.shutting_down.store(true, Ordering::SeqCst);
        for lane in self.lanes.lock().unwrap().values() {
            lane.queue.close();
        }
        let workers = std::mem::take(&mut *self.workers.lock().unwrap());
        for w in workers {
            let _ = w.join();
        }
    }

    fn admit(
        &self,
        feeds: &[(&str, Tensor)],
        fetches: &[&str],
        block: bool,
    ) -> Result<ResponseHandle> {
        if self.shutting_down.load(Ordering::SeqCst) {
            return Err(Status::unavailable("model server is shutting down"));
        }
        if feeds.is_empty() {
            return Err(Status::invalid_argument(
                "serving request needs at least one feed (the batch axis comes from feeds)",
            ));
        }
        if fetches.is_empty() {
            return Err(Status::invalid_argument("serving request needs at least one fetch"));
        }
        let rows = feeds[0].1.shape().dims().first().copied().ok_or_else(|| {
            Status::invalid_argument(format!(
                "feed {:?} is a scalar; serving feeds need a batch axis (axis 0)",
                feeds[0].0
            ))
        })?;
        for (name, t) in feeds {
            let r = t.shape().dims().first().copied().ok_or_else(|| {
                Status::invalid_argument(format!(
                    "feed {name:?} is a scalar; serving feeds need a batch axis (axis 0)"
                ))
            })?;
            if r != rows {
                return Err(Status::invalid_argument(format!(
                    "feed {name:?} has {r} rows but feed {:?} has {rows}; \
                     all feeds of one request must agree on axis 0",
                    feeds[0].0
                )));
            }
        }
        if rows == 0 {
            return Err(Status::invalid_argument("serving request with zero rows"));
        }

        let lane = self.lane_for(feeds, fetches)?;
        let slot = ResponseSlot::new();
        let request = PendingRequest {
            feeds: feeds.iter().map(|(_, t)| t.clone()).collect(),
            rows,
            slot: Arc::clone(&slot),
        };
        if block {
            lane.queue.push(request)?;
        } else {
            lane.queue.try_push(request)?;
        }
        self.counters.requests.fetch_add(1, Ordering::Relaxed);
        Ok(ResponseHandle::new(slot))
    }

    /// Get or lazily create the lane (and its scheduler thread) for a
    /// request signature.
    fn lane_for(&self, feeds: &[(&str, Tensor)], fetches: &[&str]) -> Result<Arc<Lane>> {
        // Same key the session cache uses (with no targets), so one lane
        // maps to exactly one cached compiled step.
        let feed_names: Vec<&str> = feeds.iter().map(|(n, _)| *n).collect();
        let key = crate::session::run_signature(&feed_names, fetches, &[]);

        let mut lanes = self.lanes.lock().unwrap();
        // Re-check the flag under the lanes lock: shutdown() sets it and
        // then closes/joins everything it finds in `lanes`, so a lane
        // created after that sweep would live (and accept work) forever.
        if self.shutting_down.load(Ordering::SeqCst) {
            return Err(Status::unavailable("model server is shutting down"));
        }
        if let Some(lane) = lanes.get(&key) {
            return Ok(Arc::clone(lane));
        }
        if lanes.len() >= self.config.max_lanes {
            return Err(Status::resource_exhausted(format!(
                "lane limit reached ({} signatures); refusing a new (feeds, fetches) \
                 signature — each lane owns a scheduler thread",
                self.config.max_lanes
            )));
        }
        let lane = Arc::new(Lane {
            feed_names: feeds.iter().map(|(n, _)| n.to_string()).collect(),
            fetch_names: fetches.iter().map(|f| f.to_string()).collect(),
            queue: Bounded::new(self.config.queue_capacity),
        });
        lanes.insert(key, Arc::clone(&lane));

        let session = Arc::clone(&self.session);
        let counters = Arc::clone(&self.counters);
        let config = self.config.clone();
        let worker_lane = Arc::clone(&lane);
        let handle = std::thread::Builder::new()
            .name(format!("serving-lane-{}", lanes.len()))
            .spawn(move || scheduler_loop(session, worker_lane, counters, config))
            .expect("failed to spawn serving scheduler thread");
        self.workers.lock().unwrap().push(handle);
        Ok(lane)
    }
}

impl Drop for ModelServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Closes and drains the lane's queue when the scheduler exits — on
/// panic unwind too. Without this, a scheduler that dies mid-flight
/// (poisoned mutex, kernel bug) would strand queued clients in `wait()`
/// forever; with it they get `Cancelled` (via `PendingRequest::drop`)
/// and new submits fail with `Unavailable`.
struct LaneGuard(Arc<Lane>);

impl Drop for LaneGuard {
    fn drop(&mut self) {
        self.0.queue.close();
        while let Pop::Item(r) = self.0.queue.try_pop() {
            drop(r);
        }
    }
}

/// One lane's scheduler: form batches (first request opens the batch;
/// the greedy drain, the row budget, or a lone request's linger deadline
/// closes it), execute, fulfill.
fn scheduler_loop(
    session: Arc<Session>,
    lane: Arc<Lane>,
    counters: Arc<Counters>,
    config: BatchConfig,
) {
    let _guard = LaneGuard(Arc::clone(&lane));
    // A request that would overflow the current batch is carried into the
    // next one rather than split or dropped.
    let mut carry: Option<PendingRequest> = None;
    loop {
        let first = match carry.take().or_else(|| lane.queue.pop()) {
            Some(r) => r,
            None => break, // queue closed and drained
        };
        let mut rows = first.rows;
        let mut batch = vec![first];
        if config.max_batch_size > 1 && rows < config.max_batch_size {
            let deadline = Instant::now() + config.max_batch_delay;
            'fill: loop {
                // Greedily drain everything already queued: concurrent
                // clients coalesce without paying any added latency.
                loop {
                    if rows >= config.max_batch_size {
                        break 'fill;
                    }
                    match lane.queue.try_pop() {
                        Pop::Item(r) => {
                            if rows + r.rows > config.max_batch_size
                                || !compatible(&batch[0], &r)
                            {
                                carry = Some(r);
                                break 'fill;
                            }
                            rows += r.rows;
                            batch.push(r);
                        }
                        Pop::TimedOut => break, // empty right now
                        Pop::Closed => break 'fill,
                    }
                }
                // Queue is empty. A batch that already has company runs
                // immediately — waiting out the full delay would stall
                // closed-loop clients that can never fill max_batch_size.
                // Only a lone request lingers for a batch-mate.
                if batch.len() > 1 {
                    break;
                }
                match lane.queue.pop_deadline(deadline) {
                    Pop::Item(r) => {
                        if rows + r.rows > config.max_batch_size || !compatible(&batch[0], &r) {
                            carry = Some(r);
                            break;
                        }
                        rows += r.rows;
                        batch.push(r);
                        // Loop back to drain whatever arrived with it.
                    }
                    Pop::TimedOut | Pop::Closed => break,
                }
            }
        }
        // Count the step before fulfilling its requests: a client that
        // returns from wait() and immediately reads stats() must see the
        // step that produced its answer.
        counters.batches.fetch_add(1, Ordering::Relaxed);
        counters.rows.fetch_add(rows as u64, Ordering::Relaxed);
        execute_batch(&session, &lane, batch, rows);
    }
}

/// Run one batch as a single session step and fulfill every member.
fn execute_batch(session: &Session, lane: &Lane, batch: Vec<PendingRequest>, total_rows: usize) {
    match run_batch(session, lane, &batch, total_rows) {
        Ok(per_request) => {
            for (req, outs) in batch.iter().zip(per_request) {
                req.slot.fulfill(Ok(outs));
            }
        }
        Err(e) => {
            for req in &batch {
                req.slot.fulfill(Err(e.clone()));
            }
        }
    }
}

/// Can two requests share a batch? Only if every feed agrees on dtype and
/// trailing dims — `concat_rows` would fail otherwise, failing innocent
/// batch-mates along with the malformed request. Incompatible requests
/// are carried into their own batch instead, so a bad shape always fails
/// alone against the graph.
fn compatible(a: &PendingRequest, b: &PendingRequest) -> bool {
    a.feeds.len() == b.feeds.len()
        && a.feeds.iter().zip(&b.feeds).all(|(x, y)| {
            x.dtype() == y.dtype() && x.shape().dims()[1..] == y.shape().dims()[1..]
        })
}

/// Pack feeds along axis 0, run, split fetches back per request.
fn run_batch(
    session: &Session,
    lane: &Lane,
    batch: &[PendingRequest],
    total_rows: usize,
) -> Result<Vec<Vec<Tensor>>> {
    let fetch_strs: Vec<&str> = lane.fetch_names.iter().map(String::as_str).collect();

    // §3 partial execution does the heavy lifting: the same cached
    // compiled step serves every batch size, because feed shapes are not
    // part of the run signature.
    let packed: Vec<Tensor> = if batch.len() == 1 {
        batch[0].feeds.clone()
    } else {
        let mut packed = Vec::with_capacity(lane.feed_names.len());
        for i in 0..lane.feed_names.len() {
            let parts: Vec<Tensor> = batch.iter().map(|r| r.feeds[i].clone()).collect();
            packed.push(Tensor::concat_rows(&parts)?);
        }
        packed
    };
    let feeds: Vec<(&str, Tensor)> =
        lane.feed_names.iter().map(String::as_str).zip(packed).collect();
    let outs = session.run(&feeds, &fetch_strs, &[])?;

    // Enforce the batch-axis contract on every fetch, even for
    // single-request steps, so a graph that reduces away axis 0 fails the
    // same way at every batch size.
    for (name, out) in lane.fetch_names.iter().zip(&outs) {
        let ok = out.shape().dims().first() == Some(&total_rows);
        if !ok {
            return Err(Status::internal(format!(
                "fetch {name:?} does not preserve the batch axis: batch has {total_rows} rows \
                 but the fetched tensor has shape {}",
                out.shape()
            )));
        }
    }

    if batch.len() == 1 {
        return Ok(vec![outs]);
    }
    let row_counts: Vec<usize> = batch.iter().map(|r| r.rows).collect();
    let mut per_request: Vec<Vec<Tensor>> = (0..batch.len()).map(|_| Vec::new()).collect();
    for out in &outs {
        for (ri, part) in out.split_rows(&row_counts)?.into_iter().enumerate() {
            per_request[ri].push(part);
        }
    }
    Ok(per_request)
}

/// The whole serving stack must be shareable across client threads.
#[allow(dead_code)]
fn assert_send_sync() {
    fn check<T: Send + Sync>() {}
    check::<Session>();
    check::<ModelServer>();
    check::<ResponseHandle>();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::builder::GraphBuilder;
    use crate::session::SessionOptions;
    use crate::tensor::DType;
    use std::time::Duration;

    /// y = x * z elementwise, both fed with shape [rows, 1].
    fn product_server(config: BatchConfig) -> (ModelServer, String) {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let z = b.placeholder("z", DType::F32).unwrap();
        let y = b.mul(x, z);
        let fetch = format!("{}:0", b.graph.node(y.node).name);
        let server = ModelServer::new(Session::new(b.into_graph(), SessionOptions::default()), config);
        (server, fetch)
    }

    fn col(vals: &[f32]) -> Tensor {
        Tensor::from_f32(vec![vals.len(), 1], vals.to_vec()).unwrap()
    }

    #[test]
    fn single_request_roundtrip() {
        let (server, fetch) = product_server(BatchConfig::default());
        let out = server
            .run(&[("x", col(&[2.0, 3.0])), ("z", col(&[10.0, 10.0]))], &[&fetch])
            .unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[20.0, 30.0]);
        let s = server.stats();
        assert_eq!((s.requests, s.batches, s.rows), (1, 1, 2));
    }

    #[test]
    fn submitted_requests_coalesce_into_one_step() {
        let (server, fetch) = product_server(BatchConfig {
            max_batch_size: 16,
            max_batch_delay: Duration::from_millis(200),
            queue_capacity: 64,
            ..BatchConfig::default()
        });
        // Submit 8 one-row requests up front, then redeem the handles:
        // they all land inside the first batch's 200ms window.
        let handles: Vec<(f32, ResponseHandle)> = (0..8)
            .map(|i| {
                let v = i as f32 + 1.0;
                let h = server
                    .submit(&[("x", col(&[v])), ("z", col(&[100.0]))], &[&fetch])
                    .unwrap();
                (v, h)
            })
            .collect();
        for (v, h) in handles {
            let out = h.wait().unwrap();
            assert_eq!(out[0].as_f32().unwrap(), &[v * 100.0], "cross-talk for request {v}");
        }
        let s = server.stats();
        assert_eq!(s.requests, 8);
        assert_eq!(s.rows, 8);
        assert!(s.batches <= 4, "expected coalescing, got {} batches for 8 requests", s.batches);
        assert!(s.mean_batch_rows() >= 2.0);
    }

    #[test]
    fn oversize_request_is_carried_not_split() {
        let (server, fetch) = product_server(BatchConfig {
            max_batch_size: 4,
            max_batch_delay: Duration::from_millis(50),
            queue_capacity: 64,
            ..BatchConfig::default()
        });
        // 3 + 3 rows cannot share a 4-row batch; both must still complete.
        let h1 = server
            .submit(&[("x", col(&[1.0, 2.0, 3.0])), ("z", col(&[2.0, 2.0, 2.0]))], &[&fetch])
            .unwrap();
        let h2 = server
            .submit(&[("x", col(&[4.0, 5.0, 6.0])), ("z", col(&[3.0, 3.0, 3.0]))], &[&fetch])
            .unwrap();
        assert_eq!(h1.wait().unwrap()[0].as_f32().unwrap(), &[2.0, 4.0, 6.0]);
        assert_eq!(h2.wait().unwrap()[0].as_f32().unwrap(), &[12.0, 15.0, 18.0]);
        assert_eq!(server.stats().batches, 2);
    }

    #[test]
    fn mismatched_feed_rows_rejected() {
        let (server, fetch) = product_server(BatchConfig::default());
        let e = server
            .submit(&[("x", col(&[1.0, 2.0])), ("z", col(&[1.0]))], &[&fetch])
            .unwrap_err();
        assert_eq!(e.code, crate::error::Code::InvalidArgument);
        // Scalar feeds carry no batch axis.
        let e = server
            .submit(&[("x", Tensor::scalar_f32(1.0)), ("z", Tensor::scalar_f32(1.0))], &[&fetch])
            .unwrap_err();
        assert_eq!(e.code, crate::error::Code::InvalidArgument);
    }

    #[test]
    fn incompatible_shapes_never_share_a_batch() {
        // y = x · W with W [4,2]: a [1,5] request is malformed for the
        // graph. It must fail alone — requests whose feeds disagree on
        // trailing dims or dtype are placed in separate batches, so the
        // malformed one cannot poison its well-formed neighbours.
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let w = b.constant(Tensor::from_f32(vec![4, 2], vec![1.0; 8]).unwrap());
        let y = b.matmul(x, w);
        let fetch = format!("{}:0", b.graph.node(y.node).name);
        let server = ModelServer::new(
            Session::new(b.into_graph(), SessionOptions::default()),
            BatchConfig {
                max_batch_size: 8,
                max_batch_delay: Duration::from_millis(100),
                queue_capacity: 64,
                ..BatchConfig::default()
            },
        );
        let good1 = server
            .submit(&[("x", Tensor::from_f32(vec![1, 4], vec![1.0; 4]).unwrap())], &[&fetch])
            .unwrap();
        let bad = server
            .submit(&[("x", Tensor::from_f32(vec![1, 5], vec![0.0; 5]).unwrap())], &[&fetch])
            .unwrap();
        let good2 = server
            .submit(&[("x", Tensor::from_f32(vec![1, 4], vec![2.0; 4]).unwrap())], &[&fetch])
            .unwrap();
        assert_eq!(good1.wait().unwrap()[0].as_f32().unwrap(), &[4.0, 4.0]);
        assert!(bad.wait().is_err(), "malformed shape must fail");
        assert_eq!(good2.wait().unwrap()[0].as_f32().unwrap(), &[8.0, 8.0]);
    }

    #[test]
    fn fetch_that_loses_batch_axis_errors() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let m = b.reduce_mean(x, None);
        let fetch = format!("{}:0", b.graph.node(m.node).name);
        let server = ModelServer::new(
            Session::new(b.into_graph(), SessionOptions::default()),
            BatchConfig::default(),
        );
        let e = server.run(&[("x", col(&[1.0, 2.0]))], &[&fetch]).unwrap_err();
        assert_eq!(e.code, crate::error::Code::Internal);
        assert!(e.message.contains("batch axis"), "unexpected message: {}", e.message);
    }

    #[test]
    fn kernel_error_propagates_to_every_request() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let checked = b.op1("CheckNumerics", "check", vec![x], vec![]).unwrap();
        let fetch = format!("{}:0", b.graph.node(checked.node).name);
        let server = ModelServer::new(
            Session::new(b.into_graph(), SessionOptions::default()),
            BatchConfig {
                max_batch_size: 8,
                max_batch_delay: Duration::from_millis(100),
                queue_capacity: 64,
                ..BatchConfig::default()
            },
        );
        let h1 = server.submit(&[("x", col(&[1.0]))], &[&fetch]).unwrap();
        let h2 = server.submit(&[("x", col(&[f32::NAN]))], &[&fetch]).unwrap();
        // The NaN poisons whichever batch it lands in; both requests get
        // a definite answer (no hangs), and the NaN one is an error.
        let r1 = h1.wait();
        let r2 = h2.wait();
        assert!(r2.is_err());
        match r1 {
            Ok(out) => assert_eq!(out[0].as_f32().unwrap(), &[1.0]),
            Err(e) => assert_eq!(e.code, crate::error::Code::InvalidArgument),
        }
    }

    #[test]
    fn shutdown_rejects_new_requests() {
        let (server, fetch) = product_server(BatchConfig::default());
        server.run(&[("x", col(&[1.0])), ("z", col(&[1.0]))], &[&fetch]).unwrap();
        server.shutdown();
        let e = server
            .submit(&[("x", col(&[1.0])), ("z", col(&[1.0]))], &[&fetch])
            .unwrap_err();
        assert_eq!(e.code, crate::error::Code::Unavailable);
        // Idempotent.
        server.shutdown();
    }

    #[test]
    fn bad_first_request_does_not_brick_the_lane() {
        // y = x · W with W fixed [4,2]: the graph itself constrains the
        // trailing feed dims, unlike the elementwise product graph.
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let w = b.constant(Tensor::from_f32(vec![4, 2], vec![1.0; 8]).unwrap());
        let y = b.matmul(x, w);
        let fetch = format!("{}:0", b.graph.node(y.node).name);
        let server = ModelServer::new(
            Session::new(b.into_graph(), SessionOptions::default()),
            BatchConfig::default(),
        );
        // The first request has bogus trailing dims [5] and fails in the
        // matmul kernel…
        let bad = Tensor::from_f32(vec![1, 5], vec![0.0; 5]).unwrap();
        assert!(server.run(&[("x", bad)], &[&fetch]).is_err());
        // …and leaves no per-lane shape state behind, so later valid
        // clients are unaffected.
        let good = Tensor::from_f32(vec![1, 4], vec![1.0; 4]).unwrap();
        let out = server.run(&[("x", good)], &[&fetch]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[4.0, 4.0]);
    }

    #[test]
    fn lane_limit_sheds_new_signatures() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let two = b.scalar(2.0);
        let three = b.scalar(3.0);
        let y2 = b.mul(x, two);
        let y3 = b.mul(x, three);
        let f2 = format!("{}:0", b.graph.node(y2.node).name);
        let f3 = format!("{}:0", b.graph.node(y3.node).name);
        let server = ModelServer::new(
            Session::new(b.into_graph(), SessionOptions::default()),
            BatchConfig { max_lanes: 1, ..BatchConfig::default() },
        );
        // First signature claims the only lane; it keeps working.
        server.run(&[("x", col(&[1.0]))], &[&f2]).unwrap();
        // A second signature is shed instead of spawning another thread.
        let e = server.run(&[("x", col(&[1.0]))], &[&f3]).unwrap_err();
        assert_eq!(e.code, crate::error::Code::ResourceExhausted);
        server.run(&[("x", col(&[5.0]))], &[&f2]).unwrap();
    }

    #[test]
    fn distinct_signatures_get_distinct_lanes() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let two = b.scalar(2.0);
        let three = b.scalar(3.0);
        let y2 = b.mul(x, two);
        let y3 = b.mul(x, three);
        let f2 = format!("{}:0", b.graph.node(y2.node).name);
        let f3 = format!("{}:0", b.graph.node(y3.node).name);
        let server = ModelServer::new(
            Session::new(b.into_graph(), SessionOptions::default()),
            BatchConfig::default(),
        );
        let out2 = server.run(&[("x", col(&[5.0]))], &[&f2]).unwrap();
        let out3 = server.run(&[("x", col(&[5.0]))], &[&f3]).unwrap();
        assert_eq!(out2[0].as_f32().unwrap(), &[10.0]);
        assert_eq!(out3[0].as_f32().unwrap(), &[15.0]);
        assert_eq!(server.stats().requests, 2);
    }
}
