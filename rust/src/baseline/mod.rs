//! DistBelief-like baseline (§11: "DistBelief, Project Adam and the
//! Parameter Server systems all have whole separate parameter server
//! subsystems devoted to communicating and updating parameter values").
//!
//! Used by experiment E10 (the §6 claim: the TensorFlow port of Inception
//! trained 6× faster than the DistBelief implementation). The baseline
//! reproduces the architectural costs TensorFlow removed:
//!
//! * parameters live in a *separate parameter-server* component; every
//!   step PULLS full parameter copies and PUSHES full gradients (per
//!   variable, through a serialize/deserialize boundary — DistBelief's
//!   process boundary), instead of flowing through the dataflow graph;
//! * the model is evaluated by a fixed layer-by-layer interpreter with no
//!   graph-level optimization: no CSE, no fused scheduling, no
//!   cross-kernel parallelism within a step;
//! * no canonicalized transfers: each layer's pull is per-consumer.
//!
//! The compute kernels are the very same `kernels::` implementations, so
//! the comparison isolates the *system* design, not the math library.

use crate::data::Example;
use crate::error::Result;
use crate::kernels::{math, matrix, nn};
use crate::tensor::{codec, Tensor};
use std::collections::HashMap;
use std::sync::Mutex;

/// The separate parameter-server subsystem. Every access crosses a
/// serialization boundary, mimicking DistBelief's parameter-server RPCs.
pub struct ParameterServer {
    store: Mutex<HashMap<String, Vec<u8>>>,
    pub bytes_pulled: Mutex<u64>,
    pub bytes_pushed: Mutex<u64>,
    lr: f32,
}

impl ParameterServer {
    pub fn new(lr: f32) -> ParameterServer {
        ParameterServer {
            store: Mutex::new(HashMap::new()),
            bytes_pulled: Mutex::new(0),
            bytes_pushed: Mutex::new(0),
            lr,
        }
    }

    pub fn init(&self, name: &str, value: &Tensor) {
        self.store.lock().unwrap().insert(name.to_string(), codec::encode(value));
    }

    /// Pull a full parameter copy (deserializing, as across a process
    /// boundary).
    pub fn pull(&self, name: &str) -> Result<Tensor> {
        let bytes = self.store.lock().unwrap().get(name).cloned().ok_or_else(|| {
            crate::error::Status::not_found(format!("parameter {name:?}"))
        })?;
        *self.bytes_pulled.lock().unwrap() += bytes.len() as u64;
        Ok(codec::decode(&bytes)?.0)
    }

    /// Push a gradient; the server applies SGD centrally.
    pub fn push_gradient(&self, name: &str, grad: &Tensor) -> Result<()> {
        let enc = codec::encode(grad);
        *self.bytes_pushed.lock().unwrap() += enc.len() as u64;
        let (grad, _) = codec::decode(&enc)?; // deserialize server-side
        let mut store = self.store.lock().unwrap();
        let cur = codec::decode(store.get(name).unwrap())?.0;
        let gv = grad.as_f32()?;
        let cv = cur.as_f32()?;
        let new: Vec<f32> = cv.iter().zip(gv).map(|(&p, &g)| p - self.lr * g).collect();
        store.insert(name.to_string(), codec::encode(&Tensor::from_f32(cur.shape().clone(), new)?));
        Ok(())
    }
}

/// Layer-by-layer MLP worker: pulls, computes forward + backward with the
/// shared kernels, pushes gradients.
pub struct BaselineTrainer {
    ps: ParameterServer,
    dims: Vec<usize>,
}

impl BaselineTrainer {
    pub fn new(dims: &[usize], lr: f32, seed: u64) -> Result<BaselineTrainer> {
        let ps = ParameterServer::new(lr);
        let mut rng = crate::util::rng::Pcg32::new(seed);
        for (i, pair) in dims.windows(2).enumerate() {
            let std = (2.0 / pair[0] as f32).sqrt();
            let w: Vec<f32> =
                (0..pair[0] * pair[1]).map(|_| rng.normal() * std).collect();
            ps.init(&format!("w{i}"), &Tensor::from_f32(vec![pair[0], pair[1]], w)?);
            ps.init(&format!("b{i}"), &Tensor::zeros(crate::tensor::DType::F32, vec![pair[1]])?);
        }
        Ok(BaselineTrainer { ps, dims: dims.to_vec() })
    }

    /// One synchronous step over a batch; returns the loss.
    pub fn step(&self, batch: &[Example], classes: usize) -> Result<f32> {
        let (x, labels_i) = crate::data::batch_tensors(batch)?;
        let labels = crate::data::one_hot(labels_i.as_i32()?, classes);
        let n_layers = self.dims.len() - 1;
        // PULL phase: fetch every parameter (full copies, per layer).
        let mut ws = Vec::new();
        let mut bs = Vec::new();
        for i in 0..n_layers {
            ws.push(self.ps.pull(&format!("w{i}"))?);
            bs.push(self.ps.pull(&format!("b{i}"))?);
        }
        // FORWARD, strictly serial layer-by-layer.
        let mut acts = vec![x.clone()];
        let mut pres = Vec::new();
        for i in 0..n_layers {
            let mm = matrix::matmul(acts.last().unwrap(), &ws[i], false, false)?;
            let pre = nn::bias_add(&mm, &bs[i])?;
            pres.push(pre.clone());
            let a = if i + 1 < n_layers { nn::relu(&pre)? } else { pre };
            acts.push(a);
        }
        let (loss_vec, backprop) = nn::softmax_xent(acts.last().unwrap(), &labels)?;
        let loss = math::reduce(&loss_vec, "Mean", None)?.scalar_value_f32()?;
        // BACKWARD.
        let batch_n = batch.len() as f32;
        let scale = Tensor::scalar_f32(1.0 / batch_n);
        let mut delta = math::binary_elementwise(&backprop, &scale, "Mul")?;
        for i in (0..n_layers).rev() {
            let dw = matrix::matmul(&acts[i], &delta, true, false)?;
            let db = nn::bias_add_grad(&delta)?;
            // PUSH phase: full gradients to the parameter server.
            self.ps.push_gradient(&format!("w{i}"), &dw)?;
            self.ps.push_gradient(&format!("b{i}"), &db)?;
            if i > 0 {
                let da = matrix::matmul(&delta, &ws[i], false, true)?;
                delta = nn::relu_grad(&da, &pres[i - 1])?;
            }
        }
        Ok(loss)
    }

    pub fn wire_bytes(&self) -> (u64, u64) {
        (*self.ps.bytes_pulled.lock().unwrap(), *self.ps.bytes_pushed.lock().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_converges() {
        let examples = crate::data::synthetic_classification(64, 16, 4, 0.2, 3);
        let t = BaselineTrainer::new(&[16, 32, 4], 0.5, 1).unwrap();
        let first = t.step(&examples, 4).unwrap();
        let mut last = first;
        for _ in 0..60 {
            last = t.step(&examples, 4).unwrap();
        }
        assert!(last < first * 0.5, "baseline failed to learn: {first} -> {last}");
    }

    #[test]
    fn parameter_traffic_accounted() {
        let examples = crate::data::synthetic_classification(16, 8, 2, 0.2, 3);
        let t = BaselineTrainer::new(&[8, 16, 2], 0.1, 1).unwrap();
        t.step(&examples, 2).unwrap();
        let (pulled, pushed) = t.wire_bytes();
        // Every parameter is pulled and every gradient pushed each step.
        let param_bytes: u64 = (8 * 16 + 16 + 16 * 2 + 2) * 4;
        assert!(pulled >= param_bytes);
        assert!(pushed >= param_bytes);
    }
}
