//! §7 "Common Programming Idioms": data-parallel training (synchronous and
//! asynchronous, Fig 7), model-parallel placement helpers (Fig 8), and
//! concurrent-steps support (Fig 9 — a runtime pattern: N client threads
//! driving the same training subgraph).

use crate::autodiff::gradients;
use crate::error::{Result, Status};
use crate::graph::{Endpoint, NodeId};
use crate::optim::Optimizer;
use crate::ops::builder::GraphBuilder;

/// Synchronous data parallelism (Fig 7 top): towers each compute the
/// gradient for their shard; gradients are averaged and applied once — "in
/// order to behave exactly as if we were running the sequential SGD
/// algorithm with a batch size of" n×b.
pub fn sync_data_parallel(
    b: &mut GraphBuilder,
    vars: &[Endpoint],
    tower_losses: &[Endpoint],
    opt: &Optimizer,
) -> Result<NodeId> {
    if tower_losses.is_empty() {
        return Err(Status::invalid_argument("no towers"));
    }
    let n = tower_losses.len();
    let mut per_var: Vec<Vec<Endpoint>> = vec![Vec::with_capacity(n); vars.len()];
    for &loss in tower_losses {
        let gs = gradients(b, loss, vars)?;
        for (i, g) in gs.into_iter().enumerate() {
            per_var[i].push(g.ok_or_else(|| {
                Status::invalid_argument(format!(
                    "tower loss does not depend on variable {:?}",
                    b.graph.node(vars[i].node).name
                ))
            })?);
        }
    }
    let scale = b.scalar(1.0 / n as f32);
    let mut updates = Vec::with_capacity(vars.len());
    for (var, grads) in vars.iter().zip(per_var) {
        let summed = if grads.len() == 1 { grads[0] } else { b.add_n(grads) };
        let mean = b.mul(summed, scale);
        updates.push(opt.apply(b, *var, mean)?);
    }
    Ok(b.group("sync_train", updates))
}

/// Gradient-only tower (§4.4 parameter-server training): the gradients of
/// `loss` w.r.t. `vars`, with **no** Apply ops — a replica fetches these
/// and pushes them to parameter-server shards, where the update happens
/// (`distributed::train::DistTrainer` drives this). Errors if the loss is
/// independent of any requested variable, like `Optimizer::minimize`.
pub fn tower_gradients(
    b: &mut GraphBuilder,
    loss: Endpoint,
    vars: &[Endpoint],
) -> Result<Vec<Endpoint>> {
    let gs = gradients(b, loss, vars)?;
    gs.into_iter()
        .zip(vars)
        .map(|(g, var)| {
            g.ok_or_else(|| {
                Status::invalid_argument(format!(
                    "loss does not depend on variable {:?}",
                    b.graph.node(var.node).name
                ))
            })
        })
        .collect()
}

/// Asynchronous data parallelism (Fig 7 bottom): "each one of these
/// replicas also applies the parameter updates … asynchronously. In this
/// configuration, there is one client thread for each of the graph
/// replicas." Returns one train op per tower; drive each from its own
/// thread.
pub fn async_data_parallel(
    b: &mut GraphBuilder,
    vars: &[Endpoint],
    tower_losses: &[Endpoint],
    opt: &Optimizer,
) -> Result<Vec<NodeId>> {
    let mut train_ops = Vec::with_capacity(tower_losses.len());
    for (t, &loss) in tower_losses.iter().enumerate() {
        let gs = gradients(b, loss, vars)?;
        let mut updates = Vec::with_capacity(vars.len());
        for (var, g) in vars.iter().zip(gs) {
            let g = g.ok_or_else(|| Status::invalid_argument("tower loss independent of var"))?;
            updates.push(opt.apply(b, *var, g)?);
        }
        train_ops.push(b.group(&format!("async_train_{t}"), updates));
    }
    Ok(train_ops)
}

/// Build `n` towers, each under a device scope produced by `device_of`
/// (model replication across devices; the variables stay wherever the
/// caller created them).
pub fn build_towers<T>(
    b: &mut GraphBuilder,
    n: usize,
    device_of: impl Fn(usize) -> String,
    mut tower_fn: impl FnMut(&mut GraphBuilder, usize) -> Result<T>,
) -> Result<Vec<T>> {
    (0..n)
        .map(|i| {
            let dev = device_of(i);
            b.with_device(&dev, |b| b.with_scope(&format!("tower_{i}"), |b| tower_fn(b, i)))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::{Session, SessionOptions};
    use crate::tensor::Tensor;

    /// Shared quadratic losses: each tower sees a different target; the
    /// sync optimum is the mean of targets.
    fn quadratic_towers(n: usize) -> (GraphBuilder, Endpoint, Vec<Endpoint>, Vec<String>) {
        let mut b = GraphBuilder::new();
        let w = b.variable("w", Tensor::scalar_f32(0.0)).unwrap();
        let losses = (0..n)
            .map(|i| {
                let target = b.scalar(i as f32);
                let d = b.sub(w, target);
                b.square(d)
            })
            .collect();
        let inits = b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
        (b, w, losses, inits)
    }

    #[test]
    fn sync_converges_to_mean_target() {
        let (mut b, w, losses, inits) = quadratic_towers(4); // targets 0..3, mean 1.5
        let train = sync_data_parallel(&mut b, &[w], &losses, &Optimizer::sgd(0.1)).unwrap();
        let tname = b.graph.node(train).name.clone();
        let sess = Session::new(b.into_graph(), SessionOptions::default());
        sess.run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();
        for _ in 0..100 {
            sess.run_targets(&[&tname]).unwrap();
        }
        let wv = sess.run(&[], &["w"], &[]).unwrap()[0].scalar_value_f32().unwrap();
        assert!((wv - 1.5).abs() < 1e-2, "sync data-parallel converged to {wv}, want 1.5");
    }

    #[test]
    fn async_converges_with_concurrent_clients() {
        let (mut b, w, losses, inits) = quadratic_towers(4);
        let trains = async_data_parallel(&mut b, &[w], &losses, &Optimizer::sgd(0.02)).unwrap();
        let tnames: Vec<String> = trains.iter().map(|&t| b.graph.node(t).name.clone()).collect();
        let sess = std::sync::Arc::new(Session::new(
            b.into_graph(),
            SessionOptions { devices: 2, ..Default::default() },
        ));
        sess.run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();
        // One client thread per replica (Fig 7 bottom).
        std::thread::scope(|scope| {
            for name in &tnames {
                let sess = std::sync::Arc::clone(&sess);
                scope.spawn(move || {
                    for _ in 0..100 {
                        sess.run_targets(&[name]).unwrap();
                    }
                });
            }
        });
        let wv = sess.run(&[], &["w"], &[]).unwrap()[0].scalar_value_f32().unwrap();
        // Async converges near the mean, tolerating staleness noise.
        assert!((wv - 1.5).abs() < 0.5, "async data-parallel ended at {wv}, want ≈1.5");
    }

    #[test]
    fn towers_get_device_scopes() {
        let mut b = GraphBuilder::new();
        let outs = build_towers(&mut b, 3, |i| format!("/device:cpu:{i}"), |b, i| {
            Ok(b.scalar(i as f32))
        })
        .unwrap();
        for (i, e) in outs.iter().enumerate() {
            assert_eq!(b.graph.node(e.node).requested_device, format!("/device:cpu:{i}"));
            assert!(b.graph.node(e.node).name.starts_with(&format!("tower_{i}/")));
        }
    }
}
