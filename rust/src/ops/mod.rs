//! The operation registry (§2 "Operations and Kernels"): an *operation* is
//! an abstract computation with a name, attrs, and a signature; a *kernel*
//! (see `crate::kernels`) is a device-specific implementation. "A
//! TensorFlow binary defines the sets of operations and kernels available
//! via a registration mechanism, and this set can be extended" — here the
//! registries are process-global `LazyLock` maps with `register_op` /
//! `register_kernel` entry points, and the built-in set is installed on
//! first use.

pub mod builder;

use crate::error::{Result, Status};
use crate::graph::Node;
use std::sync::LazyLock as Lazy;
use std::collections::HashMap;
use std::sync::RwLock;

/// Table-1 operation categories. Used by the op-coverage test (E2) and the
/// cost model's static heuristics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    ElementWise,
    Array,
    Matrix,
    Stateful,
    NeuralNet,
    Checkpointing,
    QueueSync,
    ControlFlow,
    Internal,
}

/// Number of data inputs an op accepts.
#[derive(Debug, Clone, Copy)]
pub enum Arity {
    Exact(usize),
    AtLeast(usize),
    Range(usize, usize),
}

impl Arity {
    pub fn check(&self, n: usize) -> bool {
        match *self {
            Arity::Exact(k) => n == k,
            Arity::AtLeast(k) => n >= k,
            Arity::Range(a, b) => n >= a && n <= b,
        }
    }
}

/// Definition of an operation.
#[derive(Clone)]
pub struct OpDef {
    pub name: &'static str,
    pub category: Category,
    pub arity: Arity,
    /// Output count, possibly attr-dependent (e.g. Split's `num_split`).
    pub num_outputs: fn(&Node) -> Result<usize>,
    /// Stateful ops are never deduplicated by CSE (§5.1) and pin their
    /// placement (variables stay put across steps).
    pub stateful: bool,
    /// Ops whose kernel completes via continuation (§5.3): Recv, queue ops.
    pub is_async: bool,
}

fn fixed<const N: usize>(_: &Node) -> Result<usize> {
    Ok(N)
}

fn outputs_from_attr_num_split(n: &Node) -> Result<usize> {
    Ok(n.attr("num_split")?.as_i64()? as usize)
}

fn outputs_from_component_types(n: &Node) -> Result<usize> {
    Ok(n.attr("component_types")?.as_list_type()?.len())
}

fn outputs_from_out_types(n: &Node) -> Result<usize> {
    Ok(n.attr("out_types")?.as_list_type()?.len())
}

fn outputs_from_attr_n(n: &Node) -> Result<usize> {
    Ok(n.attr("N")?.as_i64()? as usize)
}

fn outputs_from_attr_num_partitions(n: &Node) -> Result<usize> {
    Ok(n.attr("num_partitions")?.as_i64()? as usize)
}

struct Registry {
    ops: HashMap<&'static str, OpDef>,
}

static REGISTRY: Lazy<RwLock<Registry>> = Lazy::new(|| {
    let mut r = Registry { ops: HashMap::new() };
    install_builtin(&mut r);
    RwLock::new(r)
});

/// Register an additional op (the paper's "extended by linking in
/// additional operation definitions/registrations").
pub fn register_op(def: OpDef) -> Result<()> {
    let mut r = REGISTRY.write().unwrap();
    if r.ops.contains_key(def.name) {
        return Err(Status::already_exists(format!("op {} already registered", def.name)));
    }
    r.ops.insert(def.name, def);
    Ok(())
}

/// Look up an op definition.
pub fn lookup(name: &str) -> Result<OpDef> {
    let r = REGISTRY.read().unwrap();
    r.ops
        .get(name)
        .cloned()
        .ok_or_else(|| Status::not_found(format!("op {name:?} is not registered")))
}

pub fn is_registered(name: &str) -> bool {
    REGISTRY.read().unwrap().ops.contains_key(name)
}

/// All registered op names (op-coverage test support).
pub fn all_ops() -> Vec<(String, Category)> {
    let r = REGISTRY.read().unwrap();
    r.ops.values().map(|d| (d.name.to_string(), d.category)).collect()
}

/// Validate a node against its op definition: op exists, arity matches,
/// attr-dependent output count computable.
pub fn validate_node(node: &Node) -> Result<()> {
    let def = lookup(&node.op)?;
    if !def.arity.check(node.inputs.len()) {
        return Err(Status::invalid_argument(format!(
            "node {:?}: op {} got {} inputs, arity {:?}",
            node.name,
            node.op,
            node.inputs.len(),
            def.arity
        )));
    }
    (def.num_outputs)(node)?;
    Ok(())
}

/// Output count for a node.
pub fn num_outputs(node: &Node) -> Result<usize> {
    (lookup(&node.op)?.num_outputs)(node)
}

macro_rules! op {
    ($r:expr, $name:literal, $cat:ident, $arity:expr, $outs:expr) => {
        op!($r, $name, $cat, $arity, $outs, stateful = false, is_async = false)
    };
    ($r:expr, $name:literal, $cat:ident, $arity:expr, $outs:expr, stateful = $st:literal) => {
        op!($r, $name, $cat, $arity, $outs, stateful = $st, is_async = false)
    };
    ($r:expr, $name:literal, $cat:ident, $arity:expr, $outs:expr, stateful = $st:literal, is_async = $as:literal) => {
        $r.ops.insert(
            $name,
            OpDef {
                name: $name,
                category: Category::$cat,
                arity: $arity,
                num_outputs: $outs,
                stateful: $st,
                is_async: $as,
            },
        );
    };
}

fn install_builtin(r: &mut Registry) {
    use Arity::*;

    // --- Element-wise mathematical operations (Table 1 row 1) ---
    for name in ["Add", "Sub", "Mul", "Div", "Maximum", "Minimum", "Pow"] {
        r.ops.insert(
            name,
            OpDef {
                name: Box::leak(name.to_string().into_boxed_str()),
                category: Category::ElementWise,
                arity: Exact(2),
                num_outputs: fixed::<1>,
                stateful: false,
                is_async: false,
            },
        );
    }
    for name in ["Neg", "Exp", "Log", "Sqrt", "Rsqrt", "Abs", "Sign", "Square", "Tanh", "Reciprocal"] {
        r.ops.insert(
            name,
            OpDef {
                name: Box::leak(name.to_string().into_boxed_str()),
                category: Category::ElementWise,
                arity: Exact(1),
                num_outputs: fixed::<1>,
                stateful: false,
                is_async: false,
            },
        );
    }
    for name in ["Greater", "Less", "Equal", "GreaterEqual", "LessEqual", "NotEqual", "LogicalAnd", "LogicalOr"] {
        r.ops.insert(
            name,
            OpDef {
                name: Box::leak(name.to_string().into_boxed_str()),
                category: Category::ElementWise,
                arity: Exact(2),
                num_outputs: fixed::<1>,
                stateful: false,
                is_async: false,
            },
        );
    }
    op!(r, "LogicalNot", ElementWise, Exact(1), fixed::<1>);
    op!(r, "Select", ElementWise, Exact(3), fixed::<1>);
    op!(r, "AddN", ElementWise, AtLeast(1), fixed::<1>);
    op!(r, "Cast", ElementWise, Exact(1), fixed::<1>);
    op!(r, "CheckNumerics", ElementWise, Exact(1), fixed::<1>);
    // Produced by the §5 optimizer's fusion pass (`passes::fuse`), never
    // by clients: input 0 is the chain's primary operand, inputs 1.. the
    // binary steps' extra operands, attr `ops` the recorded op sequence.
    op!(r, "FusedElementwise", ElementWise, AtLeast(1), fixed::<1>);

    // --- Array operations (Table 1 row 2) ---
    op!(r, "Const", Array, Exact(0), fixed::<1>);
    op!(r, "Identity", Array, Exact(1), fixed::<1>);
    op!(r, "Placeholder", Array, Exact(0), fixed::<1>);
    op!(r, "Concat", Array, AtLeast(2), fixed::<1>); // inputs: tensors...; attr axis
    op!(r, "Slice", Array, Exact(1), fixed::<1>); // attrs begin, size
    op!(r, "Split", Array, Exact(1), outputs_from_attr_num_split);
    op!(r, "Rank", Array, Exact(1), fixed::<1>);
    op!(r, "Shape", Array, Exact(1), fixed::<1>);
    op!(r, "Size", Array, Exact(1), fixed::<1>);
    op!(r, "Reshape", Array, Exact(2), fixed::<1>);
    op!(r, "Shuffle", Array, Exact(1), fixed::<1>); // random permutation along axis 0
    op!(r, "ZerosLike", Array, Exact(1), fixed::<1>);
    op!(r, "OnesLike", Array, Exact(1), fixed::<1>);
    op!(r, "Fill", Array, Exact(2), fixed::<1>);
    op!(r, "Gather", Array, Exact(2), fixed::<1>);
    // --- Sparse-embedding toolkit (§3 embedding examples, §4.2 sparse
    // gradients): segment reductions, functional scatters, and the
    // partition/stitch pair used by sharded lookups. ---
    op!(r, "UnsortedSegmentSum", Array, Exact(2), fixed::<1>); // (data, segment_ids); attr num_segments
    op!(r, "ScatterAdd", Array, Exact(3), fixed::<1>); // (x, indices, updates) -> copy with rows +=
    op!(r, "ScatterSub", Array, Exact(3), fixed::<1>); // (x, indices, updates) -> copy with rows -=
    op!(r, "DynamicPartition", Array, Exact(2), outputs_from_attr_num_partitions); // (data, partitions)
    op!(r, "DynamicStitch", Array, AtLeast(2), fixed::<1>); // N index tensors then N data tensors; attr N
    op!(r, "RowIds", Array, Exact(1), fixed::<1>); // i64 [rows(x)] = 0..rows
    op!(r, "ModShard", Array, Exact(1), fixed::<2>); // ids -> (ids % shards, ids / shards); attr shards
    // Lazy densify handle for IndexedSlices gradients (§4.1): only runs
    // when a dense consumer actually fetches it.
    op!(r, "SparseToDense", Array, Exact(3), fixed::<1>); // (indices, values, like)
    op!(r, "Transpose", Array, Exact(1), fixed::<1>); // attr perm
    op!(r, "Pack", Array, AtLeast(1), fixed::<1>);
    op!(r, "Unpack", Array, Exact(1), outputs_from_attr_n);
    op!(r, "Tile", Array, Exact(1), fixed::<1>); // attr multiples
    // Gradient helpers (§4.1): runtime-shaped broadcast/reduction, since
    // shapes are not known at graph-construction time.
    op!(r, "SumToShape", Array, Exact(2), fixed::<1>); // (grad, like)
    op!(r, "BroadcastLike", Array, Exact(2), fixed::<1>); // (x, like)
    op!(r, "ReshapeLike", Array, Exact(2), fixed::<1>); // (x, like)
    op!(r, "ExpandDims", Array, Exact(1), fixed::<1>); // attr axis
    op!(r, "Squeeze", Array, Exact(1), fixed::<1>);
    op!(r, "StopGradient", Array, Exact(1), fixed::<1>);
    op!(r, "BroadcastTo", Array, Exact(1), fixed::<1>); // attr shape
    for name in ["RandomUniform", "RandomStandardNormal"] {
        r.ops.insert(
            name,
            OpDef {
                name: Box::leak(name.to_string().into_boxed_str()),
                category: Category::Array,
                arity: Exact(0),
                num_outputs: fixed::<1>,
                stateful: true, // random state
                is_async: false,
            },
        );
    }

    // --- Reductions (element-wise family in Table 1's "...") ---
    for name in ["Sum", "Mean", "Max", "Min", "Prod", "ArgMax"] {
        r.ops.insert(
            name,
            OpDef {
                name: Box::leak(name.to_string().into_boxed_str()),
                category: Category::ElementWise,
                arity: Exact(1),
                num_outputs: fixed::<1>,
                stateful: false,
                is_async: false,
            },
        );
    }

    // --- Matrix operations (Table 1 row 3) ---
    op!(r, "MatMul", Matrix, Exact(2), fixed::<1>); // attrs transpose_a/b
    op!(r, "MatrixInverse", Matrix, Exact(1), fixed::<1>);
    op!(r, "MatrixDeterminant", Matrix, Exact(1), fixed::<1>);
    op!(r, "BatchMatMul", Matrix, Exact(2), fixed::<1>);

    // --- Stateful operations (Table 1 row 4) ---
    op!(r, "Variable", Stateful, Exact(0), fixed::<1>, stateful = true);
    op!(r, "Assign", Stateful, Exact(2), fixed::<1>, stateful = true);
    op!(r, "AssignAdd", Stateful, Exact(2), fixed::<1>, stateful = true);
    op!(r, "AssignSub", Stateful, Exact(2), fixed::<1>, stateful = true);
    op!(r, "CountUpTo", Stateful, Exact(1), fixed::<1>, stateful = true);
    // Optimizer apply ops (§4.1 / §7 idioms). Input 0 is the variable ref.
    op!(r, "ApplyGradientDescent", Stateful, Exact(3), fixed::<1>, stateful = true);
    op!(r, "ApplyMomentum", Stateful, Exact(4), fixed::<1>, stateful = true);
    op!(r, "ApplyAdagrad", Stateful, Exact(3), fixed::<1>, stateful = true);
    op!(r, "ApplyAdam", Stateful, Exact(5), fixed::<1>, stateful = true);

    // --- Neural-net building blocks (Table 1 row 5) ---
    op!(r, "ReLU", NeuralNet, Exact(1), fixed::<1>);
    op!(r, "ReluGrad", NeuralNet, Exact(2), fixed::<1>);
    op!(r, "Sigmoid", NeuralNet, Exact(1), fixed::<1>);
    op!(r, "SoftMax", NeuralNet, Exact(1), fixed::<1>);
    op!(r, "LogSoftmax", NeuralNet, Exact(1), fixed::<1>);
    op!(r, "BiasAdd", NeuralNet, Exact(2), fixed::<1>);
    op!(r, "BiasAddGrad", NeuralNet, Exact(1), fixed::<1>);
    op!(r, "Convolution2D", NeuralNet, Exact(2), fixed::<1>); // NHWC; attrs strides, padding
    op!(r, "Conv2DBackpropInput", NeuralNet, Exact(3), fixed::<1>); // (dy, filter, x-for-shape)
    op!(r, "Conv2DBackpropFilter", NeuralNet, Exact(3), fixed::<1>); // (x, dy, filter-for-shape)
    op!(r, "MaxPool", NeuralNet, Exact(1), fixed::<2>); // (output, argmax)
    op!(r, "MaxPoolGrad", NeuralNet, Exact(3), fixed::<1>);
    op!(r, "SoftmaxCrossEntropyWithLogits", NeuralNet, Exact(2), fixed::<2>); // (loss, backprop)
    op!(r, "L2Loss", NeuralNet, Exact(1), fixed::<1>);
    // Sampled softmax (§3 large-vocabulary example): (emb, weights, labels)
    // with attrs num_sampled + seed; the grad kernel re-draws the same
    // negatives from Pcg32::new(seed ^ step_id).
    op!(r, "SampledSoftmax", NeuralNet, Exact(3), fixed::<1>); // loss [batch]
    op!(r, "SampledSoftmaxGrad", NeuralNet, Exact(4), fixed::<3>); // (demb, dw_indices, dw_values)

    // --- Checkpointing operations (Table 1 row 6) ---
    op!(r, "Save", Checkpointing, AtLeast(1), fixed::<0>, stateful = true);
    op!(r, "Restore", Checkpointing, Exact(0), outputs_from_out_types, stateful = true);

    // --- Queue and synchronization operations (Table 1 row 7) ---
    op!(r, "FIFOQueue", QueueSync, Exact(0), fixed::<1>, stateful = true);
    op!(r, "RandomShuffleQueue", QueueSync, Exact(0), fixed::<1>, stateful = true);
    op!(r, "Enqueue", QueueSync, AtLeast(2), fixed::<0>, stateful = true, is_async = true);
    op!(r, "Dequeue", QueueSync, Exact(1), outputs_from_component_types, stateful = true, is_async = true);
    op!(r, "QueueClose", QueueSync, Exact(1), fixed::<0>, stateful = true);
    op!(r, "QueueSize", QueueSync, Exact(1), fixed::<1>, stateful = true);
    op!(r, "MutexAcquire", QueueSync, Exact(0), fixed::<0>, stateful = true, is_async = true);
    op!(r, "MutexRelease", QueueSync, Exact(0), fixed::<0>, stateful = true);

    // --- Control flow operations (Table 1 row 8, §4.4) ---
    op!(r, "Merge", ControlFlow, AtLeast(1), fixed::<2>); // (value, value_index)
    op!(r, "Switch", ControlFlow, Exact(2), fixed::<2>); // (output_false, output_true)
    op!(r, "Enter", ControlFlow, Exact(1), fixed::<1>); // attr frame_name
    op!(r, "Exit", ControlFlow, Exact(1), fixed::<1>);
    op!(r, "NextIteration", ControlFlow, Exact(1), fixed::<1>);
    op!(r, "LoopCond", ControlFlow, Exact(1), fixed::<1>);
    op!(r, "NoOp", ControlFlow, Exact(0), fixed::<0>);
    op!(r, "ControlTrigger", ControlFlow, Exact(0), fixed::<0>);

    // --- Input (§4.5) and summaries (§9.1) ---
    op!(r, "RecordInput", Array, Exact(0), fixed::<2>, stateful = true); // (features, labels)
    op!(r, "ScalarSummary", Array, Exact(1), fixed::<1>);
    op!(r, "HistogramSummary", Array, Exact(1), fixed::<1>);
    op!(r, "MergeSummary", Array, AtLeast(1), fixed::<1>);
    op!(r, "Print", Array, AtLeast(1), fixed::<1>, stateful = true);

    // --- Internal: communication (§3.2.2), feeds/fetches (§4.2), XLA (§5.4) ---
    op!(r, "_Send", Internal, Exact(1), fixed::<0>, stateful = true);
    op!(r, "_Recv", Internal, Exact(0), fixed::<1>, stateful = true, is_async = true);
    op!(r, "_Feed", Internal, Exact(0), fixed::<1>, stateful = true);
    op!(r, "_Fetch", Internal, Exact(1), fixed::<0>, stateful = true);
    op!(r, "XlaCall", Internal, AtLeast(0), outputs_from_out_types);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{AttrValue, Node};
    use std::collections::BTreeMap;

    fn node(op: &str, n_inputs: usize) -> Node {
        Node {
            name: "n".into(),
            op: op.into(),
            inputs: vec![crate::graph::Endpoint::new(crate::graph::NodeId(0), 0); n_inputs],
            control_inputs: vec![],
            attrs: BTreeMap::new(),
            requested_device: String::new(),
            assigned_device: None,
        }
    }

    #[test]
    fn lookup_builtin() {
        assert!(lookup("MatMul").is_ok());
        assert!(lookup("Nonexistent").is_err());
        assert!(is_registered("Add"));
    }

    #[test]
    fn arity_validation() {
        assert!(validate_node(&node("Add", 2)).is_ok());
        assert!(validate_node(&node("Add", 1)).is_err());
        assert!(validate_node(&node("AddN", 3)).is_ok());
        assert!(validate_node(&node("AddN", 0)).is_err());
    }

    #[test]
    fn attr_dependent_outputs() {
        let mut n = node("Split", 1);
        n.attrs.insert("num_split".into(), AttrValue::I64(4));
        assert_eq!(num_outputs(&n).unwrap(), 4);
        let bad = node("Split", 1);
        assert!(num_outputs(&bad).is_err());
    }

    #[test]
    fn stateful_flags() {
        assert!(lookup("Variable").unwrap().stateful);
        assert!(lookup("Assign").unwrap().stateful);
        assert!(!lookup("Add").unwrap().stateful);
    }

    #[test]
    fn async_flags() {
        assert!(lookup("_Recv").unwrap().is_async);
        assert!(lookup("Dequeue").unwrap().is_async);
        assert!(!lookup("MatMul").unwrap().is_async);
    }

    #[test]
    fn user_registration() {
        let def = OpDef {
            name: "MyCustomOp",
            category: Category::ElementWise,
            arity: Arity::Exact(1),
            num_outputs: fixed::<1>,
            stateful: false,
            is_async: false,
        };
        register_op(def.clone()).unwrap();
        assert!(is_registered("MyCustomOp"));
        assert!(register_op(def).is_err()); // duplicate
    }
}
