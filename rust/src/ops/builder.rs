//! Graph-construction front end — the analog of the paper's Python client
//! (Fig 1). Builds `Graph`s node by node with validation against the op
//! registry, name/device scoping, and convenience methods for the common
//! ops. The `Session` consumes the finished graph (§2 "Sessions").

use super::validate_node;
use crate::error::Result;
use crate::graph::{AttrValue, Endpoint, Graph, Node, NodeId};
use crate::tensor::{DType, Shape, Tensor};
use std::collections::HashMap;

/// Fluent graph builder.
#[derive(Default)]
pub struct GraphBuilder {
    pub graph: Graph,
    /// Name-scope stack, joined with '/'.
    scope: Vec<String>,
    /// Device-scope stack; innermost wins.
    device_stack: Vec<String>,
    /// Initialization ops (Assign of initial values into Variables);
    /// run once via `Session::run(targets=init_ops)`.
    pub init_ops: Vec<NodeId>,
    /// Sparse-gradient side table (§4.2 embedding gradients): a gradient
    /// endpoint that is really an [`IndexedSlices`](crate::sparse::IndexedSlices)
    /// maps its lazy dense handle (a `SparseToDense` output) to its
    /// (indices, values) endpoints. Sparse-aware consumers (the
    /// distributed trainer, `sparse::densify`) fetch those twins and never
    /// execute the densify node; dense consumers just use the handle.
    pub sparse_grads: HashMap<Endpoint, crate::sparse::IndexedSlices>,
}

impl GraphBuilder {
    pub fn new() -> GraphBuilder {
        GraphBuilder::default()
    }

    pub fn into_graph(self) -> Graph {
        self.graph
    }

    // ---- scoping --------------------------------------------------------

    /// Run `f` inside a name scope (`scope/op_name`).
    pub fn with_scope<T>(&mut self, scope: &str, f: impl FnOnce(&mut Self) -> T) -> T {
        self.scope.push(scope.to_string());
        let out = f(self);
        self.scope.pop();
        out
    }

    /// Run `f` with a device constraint applied to created nodes (§4.3
    /// "only place this node on …").
    pub fn with_device<T>(&mut self, device: &str, f: impl FnOnce(&mut Self) -> T) -> T {
        self.device_stack.push(device.to_string());
        let out = f(self);
        self.device_stack.pop();
        out
    }

    fn scoped_name(&self, hint: &str) -> String {
        let base = if self.scope.is_empty() {
            hint.to_string()
        } else {
            format!("{}/{hint}", self.scope.join("/"))
        };
        self.graph.unique_name(&base)
    }

    // ---- core op insertion ----------------------------------------------

    /// Add a node running `op` over `inputs` with `attrs`; name is
    /// `hint` made unique under the current scope.
    pub fn op(
        &mut self,
        op: &str,
        hint: &str,
        inputs: Vec<Endpoint>,
        attrs: Vec<(&str, AttrValue)>,
    ) -> Result<NodeId> {
        let node = Node {
            name: self.scoped_name(hint),
            op: op.to_string(),
            inputs,
            control_inputs: vec![],
            attrs: attrs.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
            requested_device: self.device_stack.last().cloned().unwrap_or_default(),
            assigned_device: None,
        };
        validate_node(&node)?;
        self.graph.add(node)
    }

    /// Same as `op` but returns output 0 as an endpoint.
    pub fn op1(
        &mut self,
        op: &str,
        hint: &str,
        inputs: Vec<Endpoint>,
        attrs: Vec<(&str, AttrValue)>,
    ) -> Result<Endpoint> {
        Ok(self.op(op, hint, inputs, attrs)?.into())
    }

    /// Add a control dependency edge (§2 "control dependencies ... enforce
    /// happens before relationships").
    pub fn add_control_input(&mut self, node: NodeId, dep: NodeId) {
        let n = self.graph.node_mut(node);
        if !n.control_inputs.contains(&dep) {
            n.control_inputs.push(dep);
        }
    }

    /// Colocation constraint (§4.3 "Colocate this node with the node named
    /// variable13"): stored as attr `_class = ["loc:@target"]`, TF-style.
    pub fn colocate(&mut self, node: NodeId, with: NodeId) {
        let target = self.graph.node(with).name.clone();
        let n = self.graph.node_mut(node);
        n.attrs
            .insert("_class".to_string(), AttrValue::ListStr(vec![format!("loc:@{target}")]));
    }

    // ---- sources ----------------------------------------------------------

    pub fn constant(&mut self, t: Tensor) -> Endpoint {
        let dt = t.dtype();
        self.op1("Const", "Const", vec![], vec![("value", t.into()), ("T", dt.into())])
            .expect("Const is always valid")
    }

    pub fn constant_f32(&mut self, shape: impl Into<Shape>, v: Vec<f32>) -> Result<Endpoint> {
        Ok(self.constant(Tensor::from_f32(shape, v)?))
    }

    pub fn scalar(&mut self, v: f32) -> Endpoint {
        self.constant(Tensor::scalar_f32(v))
    }

    pub fn placeholder(&mut self, name: &str, dtype: DType) -> Result<Endpoint> {
        self.op1("Placeholder", name, vec![], vec![("T", dtype.into())])
    }

    /// A variable with an initial-value tensor: creates the Variable node,
    /// plus `Const(init) -> Assign` recorded in `init_ops` (the client runs
    /// those once, as in TF's `initialize_all_variables`).
    pub fn variable(&mut self, name: &str, init: Tensor) -> Result<Endpoint> {
        let dt = init.dtype();
        let shape = init.shape().clone();
        let var = self.op(
            "Variable",
            name,
            vec![],
            vec![("T", dt.into()), ("shape", shape.into())],
        )?;
        let init_const = self.constant(init);
        let assign = self.op(
            "Assign",
            &format!("{name}/init"),
            vec![var.into(), init_const],
            vec![("T", dt.into())],
        )?;
        // Initializer must live with the variable.
        self.colocate(assign, var);
        if let Some(cid) = Some(init_const.node) {
            self.colocate(cid, var);
        }
        self.init_ops.push(assign);
        Ok(var.into())
    }

    /// Variable initialized from a random-normal draw scaled by `stddev`.
    pub fn variable_normal(
        &mut self,
        name: &str,
        shape: impl Into<Shape>,
        stddev: f32,
        seed: u64,
    ) -> Result<Endpoint> {
        let shape = shape.into();
        let mut rng = crate::util::rng::Pcg32::new(seed);
        let v: Vec<f32> = (0..shape.num_elements()).map(|_| rng.normal() * stddev).collect();
        self.variable(name, Tensor::from_f32(shape, v)?)
    }

    /// Variable initialized uniformly in [lo, hi) (Fig 1's
    /// `tf.random_uniform([784,100],-1,1)`).
    pub fn variable_uniform(
        &mut self,
        name: &str,
        shape: impl Into<Shape>,
        lo: f32,
        hi: f32,
        seed: u64,
    ) -> Result<Endpoint> {
        let shape = shape.into();
        let mut rng = crate::util::rng::Pcg32::new(seed);
        let v: Vec<f32> = (0..shape.num_elements()).map(|_| rng.uniform(lo, hi)).collect();
        self.variable(name, Tensor::from_f32(shape, v)?)
    }

    // ---- elementwise ------------------------------------------------------

    pub fn add(&mut self, a: Endpoint, b: Endpoint) -> Endpoint {
        self.op1("Add", "Add", vec![a, b], vec![]).unwrap()
    }

    pub fn sub(&mut self, a: Endpoint, b: Endpoint) -> Endpoint {
        self.op1("Sub", "Sub", vec![a, b], vec![]).unwrap()
    }

    pub fn mul(&mut self, a: Endpoint, b: Endpoint) -> Endpoint {
        self.op1("Mul", "Mul", vec![a, b], vec![]).unwrap()
    }

    pub fn div(&mut self, a: Endpoint, b: Endpoint) -> Endpoint {
        self.op1("Div", "Div", vec![a, b], vec![]).unwrap()
    }

    pub fn neg(&mut self, a: Endpoint) -> Endpoint {
        self.op1("Neg", "Neg", vec![a], vec![]).unwrap()
    }

    pub fn exp(&mut self, a: Endpoint) -> Endpoint {
        self.op1("Exp", "Exp", vec![a], vec![]).unwrap()
    }

    pub fn log(&mut self, a: Endpoint) -> Endpoint {
        self.op1("Log", "Log", vec![a], vec![]).unwrap()
    }

    pub fn square(&mut self, a: Endpoint) -> Endpoint {
        self.op1("Square", "Square", vec![a], vec![]).unwrap()
    }

    pub fn sqrt(&mut self, a: Endpoint) -> Endpoint {
        self.op1("Sqrt", "Sqrt", vec![a], vec![]).unwrap()
    }

    pub fn tanh(&mut self, a: Endpoint) -> Endpoint {
        self.op1("Tanh", "Tanh", vec![a], vec![]).unwrap()
    }

    pub fn add_n(&mut self, xs: Vec<Endpoint>) -> Endpoint {
        self.op1("AddN", "AddN", xs, vec![]).unwrap()
    }

    pub fn greater(&mut self, a: Endpoint, b: Endpoint) -> Endpoint {
        self.op1("Greater", "Greater", vec![a, b], vec![]).unwrap()
    }

    pub fn less(&mut self, a: Endpoint, b: Endpoint) -> Endpoint {
        self.op1("Less", "Less", vec![a, b], vec![]).unwrap()
    }

    pub fn equal(&mut self, a: Endpoint, b: Endpoint) -> Endpoint {
        self.op1("Equal", "Equal", vec![a, b], vec![]).unwrap()
    }

    pub fn select(&mut self, cond: Endpoint, a: Endpoint, b: Endpoint) -> Endpoint {
        self.op1("Select", "Select", vec![cond, a, b], vec![]).unwrap()
    }

    pub fn cast(&mut self, a: Endpoint, to: DType) -> Endpoint {
        self.op1("Cast", "Cast", vec![a], vec![("DstT", to.into())]).unwrap()
    }

    // ---- reductions ---------------------------------------------------------

    /// Sum over all axes (axes attr absent) or given axes.
    pub fn reduce_sum(&mut self, a: Endpoint, axes: Option<Vec<i64>>) -> Endpoint {
        let attrs = match axes {
            Some(ax) => vec![("axes", AttrValue::ListI64(ax))],
            None => vec![],
        };
        self.op1("Sum", "Sum", vec![a], attrs).unwrap()
    }

    pub fn reduce_mean(&mut self, a: Endpoint, axes: Option<Vec<i64>>) -> Endpoint {
        let attrs = match axes {
            Some(ax) => vec![("axes", AttrValue::ListI64(ax))],
            None => vec![],
        };
        self.op1("Mean", "Mean", vec![a], attrs).unwrap()
    }

    pub fn argmax(&mut self, a: Endpoint, axis: i64) -> Endpoint {
        self.op1("ArgMax", "ArgMax", vec![a], vec![("axis", axis.into())]).unwrap()
    }

    // ---- array ---------------------------------------------------------------

    pub fn identity(&mut self, a: Endpoint) -> Endpoint {
        self.op1("Identity", "Identity", vec![a], vec![]).unwrap()
    }

    pub fn reshape_to(&mut self, a: Endpoint, shape: Vec<i64>) -> Endpoint {
        let shape_t = self.constant(Tensor::from_i64(vec![shape.len()], shape).unwrap());
        self.op1("Reshape", "Reshape", vec![a, shape_t], vec![]).unwrap()
    }

    pub fn concat(&mut self, xs: Vec<Endpoint>, axis: i64) -> Endpoint {
        self.op1("Concat", "Concat", xs, vec![("axis", axis.into())]).unwrap()
    }

    pub fn slice(&mut self, a: Endpoint, begin: Vec<i64>, size: Vec<i64>) -> Endpoint {
        self.op1(
            "Slice",
            "Slice",
            vec![a],
            vec![("begin", AttrValue::ListI64(begin)), ("size", AttrValue::ListI64(size))],
        )
        .unwrap()
    }

    pub fn split(&mut self, a: Endpoint, axis: i64, num_split: i64) -> Result<Vec<Endpoint>> {
        let id = self.op(
            "Split",
            "Split",
            vec![a],
            vec![("axis", axis.into()), ("num_split", num_split.into())],
        )?;
        Ok((0..num_split as usize).map(|p| Endpoint::new(id, p)).collect())
    }

    pub fn transpose(&mut self, a: Endpoint, perm: Vec<i64>) -> Endpoint {
        self.op1("Transpose", "Transpose", vec![a], vec![("perm", AttrValue::ListI64(perm))])
            .unwrap()
    }

    pub fn zeros_like(&mut self, a: Endpoint) -> Endpoint {
        self.op1("ZerosLike", "ZerosLike", vec![a], vec![]).unwrap()
    }

    pub fn ones_like(&mut self, a: Endpoint) -> Endpoint {
        self.op1("OnesLike", "OnesLike", vec![a], vec![]).unwrap()
    }

    pub fn stop_gradient(&mut self, a: Endpoint) -> Endpoint {
        self.op1("StopGradient", "StopGradient", vec![a], vec![]).unwrap()
    }

    pub fn pack(&mut self, xs: Vec<Endpoint>, axis: i64) -> Endpoint {
        self.op1("Pack", "Pack", xs, vec![("axis", axis.into())]).unwrap()
    }

    // ---- matrix / nn -----------------------------------------------------------

    pub fn matmul(&mut self, a: Endpoint, b: Endpoint) -> Endpoint {
        self.op1("MatMul", "MatMul", vec![a, b], vec![]).unwrap()
    }

    pub fn matmul_t(
        &mut self,
        a: Endpoint,
        b: Endpoint,
        transpose_a: bool,
        transpose_b: bool,
    ) -> Endpoint {
        self.op1(
            "MatMul",
            "MatMul",
            vec![a, b],
            vec![("transpose_a", transpose_a.into()), ("transpose_b", transpose_b.into())],
        )
        .unwrap()
    }

    pub fn relu(&mut self, a: Endpoint) -> Endpoint {
        self.op1("ReLU", "ReLU", vec![a], vec![]).unwrap()
    }

    pub fn sigmoid(&mut self, a: Endpoint) -> Endpoint {
        self.op1("Sigmoid", "Sigmoid", vec![a], vec![]).unwrap()
    }

    pub fn softmax(&mut self, a: Endpoint) -> Endpoint {
        self.op1("SoftMax", "SoftMax", vec![a], vec![]).unwrap()
    }

    pub fn bias_add(&mut self, a: Endpoint, b: Endpoint) -> Endpoint {
        self.op1("BiasAdd", "BiasAdd", vec![a, b], vec![]).unwrap()
    }

    /// (loss[batch], backprop[batch, classes])
    pub fn softmax_xent(&mut self, logits: Endpoint, labels: Endpoint) -> Result<(Endpoint, Endpoint)> {
        let id = self.op("SoftmaxCrossEntropyWithLogits", "xent", vec![logits, labels], vec![])?;
        Ok((Endpoint::new(id, 0), Endpoint::new(id, 1)))
    }

    // ---- state --------------------------------------------------------------

    pub fn assign(&mut self, var: Endpoint, value: Endpoint) -> Result<NodeId> {
        self.op("Assign", "Assign", vec![var, value], vec![])
    }

    pub fn assign_add(&mut self, var: Endpoint, value: Endpoint) -> Result<NodeId> {
        self.op("AssignAdd", "AssignAdd", vec![var, value], vec![])
    }

    // ---- control flow (§4.4) --------------------------------------------------

    pub fn switch(&mut self, data: Endpoint, pred: Endpoint) -> Result<(Endpoint, Endpoint)> {
        let id = self.op("Switch", "Switch", vec![data, pred], vec![])?;
        Ok((Endpoint::new(id, 0), Endpoint::new(id, 1))) // (false, true)
    }

    pub fn merge(&mut self, xs: Vec<Endpoint>) -> Result<(Endpoint, Endpoint)> {
        let id = self.op("Merge", "Merge", xs, vec![])?;
        Ok((Endpoint::new(id, 0), Endpoint::new(id, 1))) // (value, index)
    }

    pub fn enter(&mut self, data: Endpoint, frame: &str) -> Result<Endpoint> {
        self.op1("Enter", "Enter", vec![data], vec![("frame_name", frame.into())])
    }

    pub fn exit(&mut self, data: Endpoint) -> Result<Endpoint> {
        self.op1("Exit", "Exit", vec![data], vec![])
    }

    pub fn next_iteration(&mut self, data: Endpoint) -> Result<Endpoint> {
        self.op1("NextIteration", "NextIteration", vec![data], vec![])
    }

    pub fn loop_cond(&mut self, pred: Endpoint) -> Result<Endpoint> {
        self.op1("LoopCond", "LoopCond", vec![pred], vec![])
    }

    pub fn no_op(&mut self, hint: &str) -> NodeId {
        self.op("NoOp", hint, vec![], vec![]).unwrap()
    }

    /// Group: a NoOp with control deps on all of `deps` (like tf.group).
    pub fn group(&mut self, hint: &str, deps: Vec<NodeId>) -> NodeId {
        let id = self.no_op(hint);
        for d in deps {
            self.add_control_input(id, d);
        }
        id
    }

    /// Build a while-loop: `body` maps loop vars to next values while
    /// `cond` is true (§4.4's Enter/Merge/Switch/NextIteration/Exit
    /// pattern, compiled exactly as the paper describes).
    pub fn while_loop(
        &mut self,
        frame: &str,
        init: Vec<Endpoint>,
        cond: impl FnOnce(&mut Self, &[Endpoint]) -> Result<Endpoint>,
        body: impl FnOnce(&mut Self, &[Endpoint]) -> Result<Vec<Endpoint>>,
    ) -> Result<Vec<Endpoint>> {
        // Enter each loop variable into the frame.
        let enters: Vec<Endpoint> =
            init.iter().map(|&e| self.enter(e, frame)).collect::<Result<_>>()?;
        // Merge(Enter, NextIteration) — NextIteration edge patched below.
        let merges: Vec<NodeId> = enters
            .iter()
            .map(|&e| self.op("Merge", "Merge", vec![e], vec![]))
            .collect::<Result<_>>()?;
        let merge_vals: Vec<Endpoint> = merges.iter().map(|&m| Endpoint::new(m, 0)).collect();
        // Loop condition on merged values.
        let pred = cond(self, &merge_vals)?;
        let pred = self.loop_cond(pred)?;
        // Switch each var on the condition: true side continues, false exits.
        let mut next_inputs = Vec::new();
        let mut exits = Vec::new();
        for &mv in &merge_vals {
            let (f, t) = self.switch(mv, pred)?;
            exits.push(self.exit(f)?);
            next_inputs.push(t);
        }
        // Body on the true side.
        let next_vals = body(self, &next_inputs)?;
        crate::rf_ensure!(
            next_vals.len() == init.len(),
            InvalidArgument,
            "while_loop body returned {} values, expected {}",
            next_vals.len(),
            init.len()
        );
        // NextIteration feeds back into each Merge.
        for (&m, &nv) in merges.iter().zip(&next_vals) {
            let ni = self.next_iteration(nv)?;
            self.graph.node_mut(m).inputs.push(ni);
        }
        Ok(exits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure1_graph_builds() {
        // The paper's Fig 1: relu(W x + b) over [784,100].
        let mut b = GraphBuilder::new();
        let w = b.variable_uniform("W", vec![100, 784], -1.0, 1.0, 1).unwrap();
        let bias = b.variable("b", Tensor::zeros(DType::F32, vec![100, 1]).unwrap()).unwrap();
        let x = b.placeholder("x", DType::F32).unwrap();
        let wx = b.matmul(w, x);
        let pre = b.add(wx, bias);
        let _relu = b.relu(pre);
        assert!(b.graph.find("W").is_some());
        assert!(b.graph.find("x").is_some());
        assert_eq!(b.init_ops.len(), 2);
        // MatMul consumes W and x.
        let mm = b.graph.find("MatMul").unwrap();
        assert_eq!(b.graph.node(mm).op, "MatMul");
    }

    #[test]
    fn scoping_prefixes_names() {
        let mut b = GraphBuilder::new();
        let c = b.with_scope("layer1", |b| b.scalar(1.0));
        assert!(b.graph.node(c.node).name.starts_with("layer1/"));
    }

    #[test]
    fn device_scope_sets_constraint() {
        let mut b = GraphBuilder::new();
        let c = b.with_device("/device:cpu:1", |b| b.scalar(1.0));
        assert_eq!(b.graph.node(c.node).requested_device, "/device:cpu:1");
        let d = b.scalar(2.0);
        assert_eq!(b.graph.node(d.node).requested_device, "");
    }

    #[test]
    fn unique_naming() {
        let mut b = GraphBuilder::new();
        let a = b.scalar(1.0);
        let c = b.scalar(2.0);
        assert_ne!(b.graph.node(a.node).name, b.graph.node(c.node).name);
    }

    #[test]
    fn colocation_attr() {
        let mut b = GraphBuilder::new();
        let v = b.variable("v", Tensor::scalar_f32(0.0)).unwrap();
        let c = b.scalar(1.0);
        b.colocate(c.node, v.node);
        let cls = b.graph.node(c.node).attr("_class").unwrap().as_list_str().unwrap().to_vec();
        assert_eq!(cls, vec!["loc:@v".to_string()]);
    }

    #[test]
    fn while_loop_shape() {
        // while (i < 10) i += 1
        let mut b = GraphBuilder::new();
        let zero = b.scalar(0.0);
        let exits = b
            .while_loop(
                "loop",
                vec![zero],
                |b, vars| {
                    let ten = b.scalar(10.0);
                    Ok(b.less(vars[0], ten))
                },
                |b, vars| {
                    let one = b.scalar(1.0);
                    Ok(vec![b.add(vars[0], one)])
                },
            )
            .unwrap();
        assert_eq!(exits.len(), 1);
        // Graph must be topo-sortable (back edge via NextIteration allowed).
        assert!(b.graph.topo_order().is_ok());
        // And contain the five §4.4 primitives.
        for op in ["Enter", "Merge", "Switch", "Exit", "NextIteration", "LoopCond"] {
            assert!(
                b.graph.nodes.iter().any(|n| n.op == op),
                "missing control-flow op {op}"
            );
        }
    }

    #[test]
    fn group_builds_control_deps() {
        let mut b = GraphBuilder::new();
        let x = b.scalar(1.0);
        let y = b.scalar(2.0);
        let g = b.group("init", vec![x.node, y.node]);
        assert_eq!(b.graph.node(g).control_inputs.len(), 2);
    }
}
