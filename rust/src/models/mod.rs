//! Model builders shared by examples, experiments, and benches: MLP
//! classifiers (the §6 "start small" workflow), an LSTM (Fig 8's
//! model-parallel workload), and a deep tower for the §6 Inception-port
//! analog.

use crate::error::Result;
use crate::graph::Endpoint;
use crate::ops::builder::GraphBuilder;
use crate::tensor::{DType, Tensor};

/// An MLP classifier head: returns (logits, variables).
pub fn mlp(
    b: &mut GraphBuilder,
    x: Endpoint,
    dims: &[usize], // e.g. [input, hidden…, classes]
    seed: u64,
) -> Result<(Endpoint, Vec<Endpoint>)> {
    let mut vars = Vec::new();
    let mut h = x;
    for (i, pair) in dims.windows(2).enumerate() {
        let (fan_in, fan_out) = (pair[0], pair[1]);
        let std = (2.0 / fan_in as f32).sqrt();
        let w = b.variable_normal(&format!("w{i}"), vec![fan_in, fan_out], std, seed + i as u64)?;
        let bias = b.variable(&format!("b{i}"), Tensor::zeros(DType::F32, vec![fan_out])?)?;
        vars.push(w);
        vars.push(bias);
        let mm = b.matmul(h, w);
        let pre = b.bias_add(mm, bias);
        h = if i + 2 < dims.len() { b.relu(pre) } else { pre };
    }
    Ok((h, vars))
}

/// Mean softmax cross-entropy loss over one-hot labels.
pub fn xent_loss(b: &mut GraphBuilder, logits: Endpoint, labels: Endpoint) -> Result<Endpoint> {
    let (loss_vec, _) = b.softmax_xent(logits, labels)?;
    Ok(b.reduce_mean(loss_vec, None))
}

/// One LSTM cell step: (h, c) = lstm(x, h, c) with fused 4-gate weights.
/// x: [batch, in], h/c: [batch, hidden], w: [in+hidden, 4*hidden],
/// bias: [4*hidden].
pub fn lstm_cell(
    b: &mut GraphBuilder,
    x: Endpoint,
    h: Endpoint,
    c: Endpoint,
    w: Endpoint,
    bias: Endpoint,
) -> Result<(Endpoint, Endpoint)> {
    let xh = b.concat(vec![x, h], 1);
    let gates0 = b.matmul(xh, w);
    let gates = b.bias_add(gates0, bias);
    let parts = b.split(gates, 1, 4)?;
    let i = b.sigmoid(parts[0]);
    let f = b.sigmoid(parts[1]);
    let o = b.sigmoid(parts[2]);
    let g = b.tanh(parts[3]);
    let fc = b.mul(f, c);
    let ig = b.mul(i, g);
    let c_new = b.add(fc, ig);
    let c_act = b.tanh(c_new);
    let h_new = b.mul(o, c_act);
    Ok((h_new, c_new))
}

/// LSTM layer variables: (w, bias).
pub fn lstm_vars(
    b: &mut GraphBuilder,
    name: &str,
    input: usize,
    hidden: usize,
    seed: u64,
) -> Result<(Endpoint, Endpoint)> {
    let std = (1.0 / (input + hidden) as f32).sqrt();
    let w = b.variable_normal(&format!("{name}/w"), vec![input + hidden, 4 * hidden], std, seed)?;
    let bias = b.variable(&format!("{name}/b"), Tensor::zeros(DType::F32, vec![4 * hidden])?)?;
    Ok((w, bias))
}

/// A deep stacked-LSTM unrolled over `seq_len` steps, each layer optionally
/// pinned to a device (the Fig 8 model-parallel pattern: "different
/// portions of the model computation are done on different computational
/// devices simultaneously"). Returns (final top-layer h, variables).
pub fn stacked_lstm(
    b: &mut GraphBuilder,
    inputs: &[Endpoint], // seq of [batch, in]
    batch: usize,
    input_dim: usize,
    hidden: usize,
    layers: usize,
    device_of_layer: Option<&dyn Fn(usize) -> String>,
    seed: u64,
) -> Result<(Endpoint, Vec<Endpoint>)> {
    let mut vars = Vec::new();
    let mut layer_params = Vec::new();
    for l in 0..layers {
        let in_dim = if l == 0 { input_dim } else { hidden };
        let (w, bias) = match device_of_layer {
            Some(f) => b.with_device(&f(l), |b| lstm_vars(b, &format!("lstm{l}"), in_dim, hidden, seed + l as u64))?,
            None => lstm_vars(b, &format!("lstm{l}"), in_dim, hidden, seed + l as u64)?,
        };
        vars.push(w);
        vars.push(bias);
        layer_params.push((w, bias));
    }
    let zeros = Tensor::zeros(DType::F32, vec![batch, hidden])?;
    let mut h: Vec<Endpoint> = (0..layers).map(|_| b.constant(zeros.clone())).collect();
    let mut c: Vec<Endpoint> = (0..layers).map(|_| b.constant(zeros.clone())).collect();
    let mut top = h[0];
    for &x_t in inputs {
        let mut layer_in = x_t;
        for l in 0..layers {
            let (w, bias) = layer_params[l];
            let step = |b: &mut GraphBuilder| lstm_cell(b, layer_in, h[l], c[l], w, bias);
            let (h_new, c_new) = match device_of_layer {
                Some(f) => b.with_device(&f(l), step)?,
                None => step(b)?,
            };
            h[l] = h_new;
            c[l] = c_new;
            layer_in = h_new;
        }
        top = layer_in;
    }
    Ok((top, vars))
}

/// The §6 Inception-port analog: a deep MLP tower (many layers of matmul +
/// bias + relu) — enough depth and parameter volume to make engine
/// overheads and transfer costs visible, runnable on CPU.
pub fn deep_tower(
    b: &mut GraphBuilder,
    x: Endpoint,
    input: usize,
    width: usize,
    depth: usize,
    classes: usize,
    seed: u64,
) -> Result<(Endpoint, Vec<Endpoint>)> {
    let mut dims = vec![input];
    dims.extend(std::iter::repeat(width).take(depth));
    dims.push(classes);
    mlp(b, x, &dims, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::{Session, SessionOptions};

    #[test]
    fn mlp_shapes() {
        let mut b = GraphBuilder::new();
        let x = b.constant(Tensor::zeros(DType::F32, vec![8, 16]).unwrap());
        let (logits, vars) = mlp(&mut b, x, &[16, 32, 10], 1).unwrap();
        assert_eq!(vars.len(), 4);
        let name = format!("{}:0", b.graph.node(logits.node).name);
        let inits: Vec<String> = b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
        let sess = Session::new(b.into_graph(), SessionOptions::default());
        sess.run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();
        let out = sess.run(&[], &[&name], &[]).unwrap();
        assert_eq!(out[0].shape().dims(), &[8, 10]);
    }

    #[test]
    fn lstm_step_runs_and_is_bounded() {
        let mut b = GraphBuilder::new();
        let x = b.constant(Tensor::fill_f32(vec![2, 4], 0.5));
        let h0 = b.constant(Tensor::zeros(DType::F32, vec![2, 8]).unwrap());
        let c0 = b.constant(Tensor::zeros(DType::F32, vec![2, 8]).unwrap());
        let (w, bias) = lstm_vars(&mut b, "cell", 4, 8, 3).unwrap();
        let (h1, c1) = lstm_cell(&mut b, x, h0, c0, w, bias).unwrap();
        let hname = format!("{}:0", b.graph.node(h1.node).name);
        let cname = format!("{}:0", b.graph.node(c1.node).name);
        let inits: Vec<String> = b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
        let sess = Session::new(b.into_graph(), SessionOptions::default());
        sess.run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();
        let out = sess.run(&[], &[&hname, &cname], &[]).unwrap();
        assert_eq!(out[0].shape().dims(), &[2, 8]);
        // h = o * tanh(c) is bounded in (-1, 1).
        assert!(out[0].as_f32().unwrap().iter().all(|v| v.abs() < 1.0));
    }

    #[test]
    fn stacked_lstm_unrolls() {
        let mut b = GraphBuilder::new();
        let xs: Vec<Endpoint> =
            (0..3).map(|_| b.constant(Tensor::fill_f32(vec![2, 4], 0.1))).collect();
        let (top, vars) = stacked_lstm(&mut b, &xs, 2, 4, 8, 2, None, 5).unwrap();
        assert_eq!(vars.len(), 4);
        let name = format!("{}:0", b.graph.node(top.node).name);
        let inits: Vec<String> = b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
        let sess = Session::new(b.into_graph(), SessionOptions::default());
        sess.run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();
        let out = sess.run(&[], &[&name], &[]).unwrap();
        assert_eq!(out[0].shape().dims(), &[2, 8]);
    }
}
