//! TensorBoard analog (§9.1): an event-file writer for Summary-op output
//! plus a renderer of time-series statistics. Summary ops (kernels in
//! `kernels::summary`) emit JSON records as string tensors; the client
//! fetches them periodically and appends them here, tagged with wall time
//! and step ("the client driver program writes the summary data to a log
//! file associated with the model training").

use crate::error::Result;
use crate::tensor::Tensor;
use crate::util::json::Json;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::{SystemTime, UNIX_EPOCH};

/// Appends summary records to an events file (one JSON object per line —
/// readable by anything, renderable by `summarize`).
pub struct SummaryWriter {
    path: PathBuf,
    file: std::fs::File,
}

impl SummaryWriter {
    pub fn create(path: &Path) -> Result<SummaryWriter> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file = std::fs::File::create(path)?;
        Ok(SummaryWriter { path: path.to_path_buf(), file })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Write every record of a fetched summary tensor under `step`.
    pub fn add_summary(&mut self, step: u64, summary: &Tensor) -> Result<()> {
        let wall = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default().as_secs_f64();
        for record in summary.as_str_slice()? {
            // Wrap the kernel-emitted record with step/time envelope.
            let line = Json::obj()
                .set("step", step)
                .set("wall_time", wall)
                .set("summary", Json::Str(record.clone()));
            writeln!(self.file, "{}", line.render())?;
        }
        Ok(())
    }

    /// Convenience: log a bare scalar without a Summary op.
    pub fn add_scalar(&mut self, step: u64, tag: &str, value: f64) -> Result<()> {
        let wall = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default().as_secs_f64();
        let inner = Json::obj().set("type", "scalar").set("tag", tag).set("value", value);
        let line = Json::obj()
            .set("step", step)
            .set("wall_time", wall)
            .set("summary", Json::Str(inner.render()));
        writeln!(self.file, "{}", line.render())?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.file.flush()?;
        Ok(())
    }
}

/// Rough text rendering of an events file: per-tag series (step, value) —
/// the §9.1 "display this summary information and how it changes over
/// time", minus the pixels.
pub fn summarize(path: &Path) -> Result<String> {
    let text = std::fs::read_to_string(path)?;
    let mut out = String::new();
    let mut count = 0;
    for line in text.lines() {
        // Cheap field scrape (records are our own writer's output).
        let step = scrape(line, "\"step\":").unwrap_or_default();
        if let Some(tag_pos) = line.find("\\\"tag\\\":\\\"") {
            let rest = &line[tag_pos + 10..];
            let tag = &rest[..rest.find('\\').unwrap_or(0)];
            let value = scrape(line, "\\\"value\\\":").unwrap_or_default();
            out.push_str(&format!("step {step:>8}  {tag:<24} {value}\n"));
            count += 1;
        }
    }
    out.push_str(&format!("{count} scalar records\n"));
    Ok(out)
}

fn scrape(line: &str, key: &str) -> Option<String> {
    let pos = line.find(key)? + key.len();
    let rest = &line[pos..];
    let end = rest.find([',', '}', '\\']).unwrap_or(rest.len());
    Some(rest[..end].trim().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Shape, TensorData};

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("rustflow-events-{tag}-{}.log", std::process::id()))
    }

    #[test]
    fn writes_and_summarizes() {
        let path = tmp("basic");
        let mut w = SummaryWriter::create(&path).unwrap();
        for step in 0..5 {
            w.add_scalar(step, "loss", 1.0 / (step + 1) as f64).unwrap();
        }
        w.flush().unwrap();
        let text = summarize(&path).unwrap();
        assert!(text.contains("loss"));
        assert!(text.contains("5 scalar records"));
    }

    #[test]
    fn accepts_summary_tensors() {
        let path = tmp("tensor");
        let mut w = SummaryWriter::create(&path).unwrap();
        let t = Tensor::new(
            Shape::vector(2),
            TensorData::Str(vec![
                r#"{"type":"scalar","tag":"acc","value":0.9}"#.into(),
                r#"{"type":"histogram","tag":"w","min":0,"max":1}"#.into(),
            ]),
        )
        .unwrap();
        w.add_summary(3, &t).unwrap();
        w.flush().unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert_eq!(content.lines().count(), 2);
        assert!(content.contains("\"step\":3"));
    }
}
