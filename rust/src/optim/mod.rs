//! Optimizers as graph builders (§4.1 + §7): `minimize(loss, vars)`
//! extends the graph with `gradients()` and one Apply* op per variable,
//! grouped under a single train NoOp — the "Update" nodes of Fig 7.

use crate::autodiff::gradients;
use crate::error::{Result, Status};
use crate::graph::{Endpoint, NodeId};
use crate::kernels::math::binary_elementwise;
use crate::ops::builder::GraphBuilder;
use crate::tensor::{Tensor, TensorData};
use std::collections::HashMap;

/// Optimizer slot state for [`Optimizer::apply_dense`], keyed exactly like
/// the kernel container's slot variables (`"<var>/Momentum"`,
/// `"<var>/Adam/m"`, …) so a parameter server's state is inspectable with
/// the same names the in-graph kernels would use.
pub type SlotMap = HashMap<String, Tensor>;

/// elementwise a*s + b*t for f32 — the same arithmetic (same expression,
/// same iteration order) as the `axpby` helper inside `kernels::state`,
/// so host-side applies are bit-identical to the Apply* kernels.
fn axpby(a: &Tensor, s: f32, b: &Tensor, t: f32) -> Result<Tensor> {
    let av = a.as_f32()?;
    let bv = b.as_f32()?;
    if av.len() != bv.len() {
        return Err(Status::invalid_argument("axpby: length mismatch"));
    }
    Tensor::new(
        a.shape().clone(),
        TensorData::F32(av.iter().zip(bv).map(|(&x, &y)| x * s + y * t).collect()),
    )
}

/// Optimizer algorithm + hyperparameters.
#[derive(Debug, Clone)]
pub enum Optimizer {
    Sgd { lr: f32 },
    Momentum { lr: f32, momentum: f32 },
    Adagrad { lr: f32 },
    Adam { lr: f32, beta1: f32, beta2: f32 },
}

impl Optimizer {
    pub fn sgd(lr: f32) -> Optimizer {
        Optimizer::Sgd { lr }
    }

    pub fn momentum(lr: f32, momentum: f32) -> Optimizer {
        Optimizer::Momentum { lr, momentum }
    }

    pub fn adagrad(lr: f32) -> Optimizer {
        Optimizer::Adagrad { lr }
    }

    pub fn adam(lr: f32) -> Optimizer {
        Optimizer::Adam { lr, beta1: 0.9, beta2: 0.999 }
    }

    /// Add one Apply node updating `var` with `grad`.
    pub fn apply(&self, b: &mut GraphBuilder, var: Endpoint, grad: Endpoint) -> Result<NodeId> {
        match *self {
            Optimizer::Sgd { lr } => {
                let lr = b.scalar(lr);
                b.op("ApplyGradientDescent", "sgd_update", vec![var, lr, grad], vec![])
            }
            Optimizer::Momentum { lr, momentum } => {
                let lr = b.scalar(lr);
                let mom = b.scalar(momentum);
                b.op("ApplyMomentum", "momentum_update", vec![var, lr, grad, mom], vec![])
            }
            Optimizer::Adagrad { lr } => {
                let lr = b.scalar(lr);
                b.op("ApplyAdagrad", "adagrad_update", vec![var, lr, grad], vec![])
            }
            Optimizer::Adam { lr, beta1, beta2 } => {
                let lr = b.scalar(lr);
                let b1 = b.scalar(beta1);
                let b2 = b.scalar(beta2);
                b.op("ApplyAdam", "adam_update", vec![var, lr, grad, b1, b2], vec![])
            }
        }
    }

    /// Apply one update to a plain tensor, outside any graph: the
    /// parameter-server path (§4.4) where the variable lives in a
    /// server-side map instead of a resource container. Mirrors the
    /// corresponding Apply* kernel in `kernels::state` expression-for-
    /// expression, so a trajectory driven through `apply_dense` is
    /// bit-identical to one driven through the in-graph update ops.
    /// `name` keys the optimizer slots in `slots` with the kernels' slot
    /// naming; slots are zero-initialized on first use, as the kernels do.
    pub fn apply_dense(
        &self,
        name: &str,
        var: &Tensor,
        grad: &Tensor,
        slots: &mut SlotMap,
    ) -> Result<Tensor> {
        if var.num_elements() != grad.num_elements() {
            return Err(Status::invalid_argument(format!(
                "apply_dense {name:?}: var has {} elements, grad {}",
                var.num_elements(),
                grad.num_elements()
            )));
        }
        match *self {
            Optimizer::Sgd { lr } => axpby(var, 1.0, grad, -lr),
            Optimizer::Momentum { lr, momentum } => {
                let key = format!("{name}/Momentum");
                let acc = match slots.get(&key) {
                    Some(a) => axpby(a, momentum, grad, 1.0)?,
                    None => {
                        let z = Tensor::zeros(grad.dtype(), grad.shape().clone())?;
                        axpby(&z, momentum, grad, 1.0)?
                    }
                };
                let out = axpby(var, 1.0, &acc, -lr)?;
                slots.insert(key, acc);
                Ok(out)
            }
            Optimizer::Adagrad { lr } => {
                let g2 = binary_elementwise(grad, grad, "Mul")?;
                let key = format!("{name}/Adagrad");
                let acc = match slots.get(&key) {
                    Some(a) => binary_elementwise(a, &g2, "Add")?,
                    None => {
                        let z = Tensor::zeros(grad.dtype(), grad.shape().clone())?;
                        binary_elementwise(&z, &g2, "Add")?
                    }
                };
                let cv = var.as_f32()?;
                let gv = grad.as_f32()?;
                let av = acc.as_f32()?;
                let out: Vec<f32> = cv
                    .iter()
                    .zip(gv.iter().zip(av))
                    .map(|(&c, (&g, &a))| c - lr * g / (a + 1e-8).sqrt())
                    .collect();
                let out = Tensor::new(var.shape().clone(), TensorData::F32(out))?;
                slots.insert(key, acc);
                Ok(out)
            }
            Optimizer::Adam { lr, beta1, beta2 } => {
                let eps = 1e-8f32;
                let t_key = format!("{name}/Adam/t");
                let t = match slots.get(&t_key) {
                    Some(t) => t.scalar_value_f32()? + 1.0,
                    None => 1.0,
                };
                slots.insert(t_key, Tensor::scalar_f32(t));
                let m_key = format!("{name}/Adam/m");
                let m = match slots.get(&m_key) {
                    Some(m) => axpby(m, beta1, grad, 1.0 - beta1)?,
                    None => {
                        let z = Tensor::zeros(grad.dtype(), grad.shape().clone())?;
                        axpby(&z, beta1, grad, 1.0 - beta1)?
                    }
                };
                let g2 = binary_elementwise(grad, grad, "Mul")?;
                let v_key = format!("{name}/Adam/v");
                let v = match slots.get(&v_key) {
                    Some(v) => axpby(v, beta2, &g2, 1.0 - beta2)?,
                    None => {
                        let z = Tensor::zeros(grad.dtype(), grad.shape().clone())?;
                        axpby(&z, beta2, &g2, 1.0 - beta2)?
                    }
                };
                let bc1 = 1.0 - beta1.powf(t);
                let bc2 = 1.0 - beta2.powf(t);
                let cv = var.as_f32()?;
                let mv = m.as_f32()?;
                let vv = v.as_f32()?;
                let out: Vec<f32> = cv
                    .iter()
                    .zip(mv.iter().zip(vv))
                    .map(|(&c, (&mi, &vi))| {
                        let mhat = mi / bc1;
                        let vhat = vi / bc2;
                        c - lr * mhat / (vhat.sqrt() + eps)
                    })
                    .collect();
                let out = Tensor::new(var.shape().clone(), TensorData::F32(out))?;
                slots.insert(m_key, m);
                slots.insert(v_key, v);
                Ok(out)
            }
        }
    }

    /// Apply an IndexedSlices gradient to a plain tensor: rows `indices`
    /// of `var` receive the matching rows of `values` scaled by `scale`,
    /// per occurrence (duplicates apply repeatedly, in index order).
    /// Mirrors the parameter server's scatter-SGD expression for
    /// expression (`out = out * 1.0 + v * scale * (-lr)`), so a replica
    /// shipping `GradEntry::Sparse` and a host applying `apply_sparse`
    /// walk bit-identical trajectories. SGD only — slot optimizers would
    /// need dense slot reads, same as the server-side restriction.
    pub fn apply_sparse(
        &self,
        name: &str,
        var: &Tensor,
        indices: &Tensor,
        values: &Tensor,
        scale: f32,
        _slots: &mut SlotMap,
    ) -> Result<Tensor> {
        let lr = match *self {
            Optimizer::Sgd { lr } => lr,
            _ => {
                return Err(Status::unimplemented(format!(
                    "apply_sparse {name:?}: sparse gradients require SGD"
                )))
            }
        };
        let mut out = var.as_f32()?.to_vec();
        let dims = var.shape().dims();
        if dims.is_empty() || dims[0] == 0 {
            return Err(Status::invalid_argument(format!(
                "apply_sparse {name:?}: var must have rank >= 1 with rows"
            )));
        }
        let rows = dims[0];
        let row_len = out.len() / rows;
        let idx = indices.as_i64()?;
        let vals = values.as_f32()?;
        if vals.len() != idx.len() * row_len {
            return Err(Status::invalid_argument(format!(
                "apply_sparse {name:?}: {} values for {} indices x row {row_len}",
                vals.len(),
                idx.len()
            )));
        }
        for (k, &r) in idx.iter().enumerate() {
            if r < 0 || r as u64 >= rows as u64 {
                return Err(Status::invalid_argument(format!(
                    "apply_sparse {name:?}: index {r} out of range [0, {rows})"
                )));
            }
            let r = r as usize;
            for j in 0..row_len {
                let m = vals[k * row_len + j] * scale;
                let o = r * row_len + j;
                out[o] = out[o] * 1.0 + m * (-lr);
            }
        }
        Tensor::new(var.shape().clone(), TensorData::F32(out))
    }

    /// `minimize`: gradients of `loss` w.r.t. `vars`, one apply per var,
    /// all grouped under a returned train op.
    pub fn minimize(
        &self,
        b: &mut GraphBuilder,
        loss: Endpoint,
        vars: &[Endpoint],
    ) -> Result<NodeId> {
        let grads = gradients(b, loss, vars)?;
        let mut updates = Vec::with_capacity(vars.len());
        for (var, grad) in vars.iter().zip(grads) {
            let grad = grad.ok_or_else(|| {
                Status::invalid_argument(format!(
                    "loss does not depend on variable {:?}",
                    b.graph.node(var.node).name
                ))
            })?;
            let upd = self.apply(b, *var, grad)?;
            // Keep the update on the variable's device (§4.3 colocation of
            // parameter state) — already enforced by ref-edge colocation.
            updates.push(upd);
        }
        Ok(b.group("train", updates))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::{Session, SessionOptions};
    use crate::tensor::Tensor;

    /// Minimize (w - 3)^2 and check convergence to w = 3.
    fn converges(opt: Optimizer, steps: usize, tol: f32) {
        let mut b = GraphBuilder::new();
        let w = b.variable("w", Tensor::scalar_f32(0.0)).unwrap();
        let three = b.scalar(3.0);
        let diff = b.sub(w, three);
        let loss = b.square(diff);
        let train = opt.minimize(&mut b, loss, &[w]).unwrap();
        let train_name = b.graph.node(train).name.clone();
        let init: Vec<String> = b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
        let sess = Session::new(b.into_graph(), SessionOptions::default());
        sess.run_targets(&init.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();
        for _ in 0..steps {
            sess.run_targets(&[&train_name]).unwrap();
        }
        let out = sess.run(&[], &["w"], &[]).unwrap();
        let w_final = out[0].scalar_value_f32().unwrap();
        assert!((w_final - 3.0).abs() < tol, "{opt:?} converged to {w_final}, want 3.0");
    }

    #[test]
    fn sgd_converges() {
        converges(Optimizer::sgd(0.1), 100, 1e-3);
    }

    #[test]
    fn momentum_converges() {
        converges(Optimizer::momentum(0.05, 0.9), 200, 1e-2);
    }

    #[test]
    fn adagrad_converges() {
        converges(Optimizer::adagrad(0.9), 400, 5e-2);
    }

    #[test]
    fn adam_converges() {
        converges(Optimizer::adam(0.1), 400, 1e-2);
    }

    /// apply_dense must walk the exact trajectory of the in-graph Apply*
    /// kernel: same bits, not merely close.
    fn apply_dense_matches_kernel(opt: Optimizer, steps: usize) {
        // In-graph side: w updated by the Apply kernel with a fixed
        // per-step gradient fed through a placeholder.
        let mut b = GraphBuilder::new();
        let init_val = Tensor::from_f32(vec![3], vec![0.5, -1.25, 2.0]).unwrap();
        let w = b.variable("w", init_val.clone()).unwrap();
        let g = b.placeholder("g", crate::tensor::DType::F32).unwrap();
        let upd = opt.apply(&mut b, w, g).unwrap();
        let upd_name = b.graph.node(upd).name.clone();
        let init: Vec<String> = b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
        let sess = Session::new(b.into_graph(), SessionOptions::default());
        sess.run_targets(&init.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();

        // Host side: the same trajectory through apply_dense.
        let mut cur = init_val;
        let mut slots = SlotMap::new();
        let mut rng = crate::util::rng::Pcg32::new(7);
        for _ in 0..steps {
            let gv: Vec<f32> = (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let grad = Tensor::from_f32(vec![3], gv).unwrap();
            sess.run(&[("g", grad.clone())], &[], &[&upd_name]).unwrap();
            cur = opt.apply_dense("w", &cur, &grad, &mut slots).unwrap();
            let kernel_w = sess.run(&[], &["w"], &[]).unwrap();
            let kbits: Vec<u32> =
                kernel_w[0].as_f32().unwrap().iter().map(|x| x.to_bits()).collect();
            let hbits: Vec<u32> = cur.as_f32().unwrap().iter().map(|x| x.to_bits()).collect();
            assert_eq!(kbits, hbits, "{opt:?} diverged from the kernel trajectory");
        }
    }

    #[test]
    fn apply_dense_bitwise_matches_sgd() {
        apply_dense_matches_kernel(Optimizer::sgd(0.1), 20);
    }

    #[test]
    fn apply_dense_bitwise_matches_momentum() {
        apply_dense_matches_kernel(Optimizer::momentum(0.05, 0.9), 20);
    }

    #[test]
    fn apply_dense_bitwise_matches_adagrad() {
        apply_dense_matches_kernel(Optimizer::adagrad(0.5), 20);
    }

    #[test]
    fn apply_dense_bitwise_matches_adam() {
        apply_dense_matches_kernel(Optimizer::adam(0.05), 20);
    }

    /// With unique indices, scatter-apply must equal densify-then-apply
    /// bit for bit (the IndexedSlices parity contract).
    #[test]
    fn apply_sparse_bitwise_matches_densified_on_unique_rows() {
        let opt = Optimizer::sgd(0.1);
        let var =
            Tensor::from_f32(vec![4, 2], vec![0.5, -1.0, 2.0, 0.25, -3.5, 1.0, 0.0, 7.0]).unwrap();
        let idx = Tensor::from_i64(vec![2], vec![3, 1]).unwrap();
        let vals = Tensor::from_f32(vec![2, 2], vec![0.7, -0.2, 1.1, 0.3]).unwrap();
        // Densify by hand: rows 3 and 1 receive the value rows.
        let mut dense = vec![0.0f32; 8];
        dense[3 * 2..4 * 2].copy_from_slice(&[0.7, -0.2]);
        dense[1 * 2..2 * 2].copy_from_slice(&[1.1, 0.3]);
        let dense = Tensor::from_f32(vec![4, 2], dense).unwrap();
        let mut slots = SlotMap::new();
        let want = opt.apply_dense("w", &var, &dense, &mut slots).unwrap();
        let got = opt.apply_sparse("w", &var, &idx, &vals, 1.0, &mut slots).unwrap();
        let wb: Vec<u32> = want.as_f32().unwrap().iter().map(|x| x.to_bits()).collect();
        let gb: Vec<u32> = got.as_f32().unwrap().iter().map(|x| x.to_bits()).collect();
        assert_eq!(wb, gb);
    }

    #[test]
    fn apply_sparse_rejects_non_sgd_and_bad_indices() {
        let var = Tensor::from_f32(vec![2, 2], vec![1.0; 4]).unwrap();
        let idx = Tensor::from_i64(vec![1], vec![0]).unwrap();
        let vals = Tensor::from_f32(vec![1, 2], vec![1.0, 1.0]).unwrap();
        let mut slots = SlotMap::new();
        let err = Optimizer::adam(0.1)
            .apply_sparse("w", &var, &idx, &vals, 1.0, &mut slots)
            .unwrap_err();
        assert_eq!(err.code, crate::error::Code::Unimplemented);
        for bad in [vec![-1i64], vec![2], vec![i64::MIN]] {
            let idx = Tensor::from_i64(vec![1], bad).unwrap();
            let err = Optimizer::sgd(0.1)
                .apply_sparse("w", &var, &idx, &vals, 1.0, &mut slots)
                .unwrap_err();
            assert_eq!(err.code, crate::error::Code::InvalidArgument);
        }
    }

    #[test]
    fn apply_dense_rejects_shape_mismatch() {
        let var = Tensor::from_f32(vec![2], vec![1., 2.]).unwrap();
        let grad = Tensor::from_f32(vec![3], vec![1., 2., 3.]).unwrap();
        let mut slots = SlotMap::new();
        assert!(Optimizer::sgd(0.1).apply_dense("w", &var, &grad, &mut slots).is_err());
    }

    #[test]
    fn minimize_rejects_unrelated_variable() {
        let mut b = GraphBuilder::new();
        let w = b.variable("w", Tensor::scalar_f32(0.0)).unwrap();
        let loss = b.scalar(1.0);
        assert!(Optimizer::sgd(0.1).minimize(&mut b, loss, &[w]).is_err());
    }

    #[test]
    fn multi_variable_linear_regression() {
        // y = a*x + c fit to y = 2x + 1 over fixed points.
        let mut b = GraphBuilder::new();
        let a = b.variable("a", Tensor::scalar_f32(0.0)).unwrap();
        let c = b.variable("c", Tensor::scalar_f32(0.0)).unwrap();
        let xs = b.constant(Tensor::from_f32(vec![4], vec![0., 1., 2., 3.]).unwrap());
        let ys = b.constant(Tensor::from_f32(vec![4], vec![1., 3., 5., 7.]).unwrap());
        let ax = b.mul(a, xs);
        let pred = b.add(ax, c);
        let err = b.sub(pred, ys);
        let sq = b.square(err);
        let loss = b.reduce_mean(sq, None);
        let train = Optimizer::sgd(0.05).minimize(&mut b, loss, &[a, c]).unwrap();
        let train_name = b.graph.node(train).name.clone();
        let init: Vec<String> = b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
        let sess = Session::new(b.into_graph(), SessionOptions::default());
        sess.run_targets(&init.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();
        for _ in 0..500 {
            sess.run_targets(&[&train_name]).unwrap();
        }
        let out = sess.run(&[], &["a", "c"], &[]).unwrap();
        assert!((out[0].scalar_value_f32().unwrap() - 2.0).abs() < 0.05);
        assert!((out[1].scalar_value_f32().unwrap() - 1.0).abs() < 0.1);
    }
}
