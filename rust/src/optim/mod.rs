//! Optimizers as graph builders (§4.1 + §7): `minimize(loss, vars)`
//! extends the graph with `gradients()` and one Apply* op per variable,
//! grouped under a single train NoOp — the "Update" nodes of Fig 7.

use crate::autodiff::gradients;
use crate::error::{Result, Status};
use crate::graph::{Endpoint, NodeId};
use crate::ops::builder::GraphBuilder;

/// Optimizer algorithm + hyperparameters.
#[derive(Debug, Clone)]
pub enum Optimizer {
    Sgd { lr: f32 },
    Momentum { lr: f32, momentum: f32 },
    Adagrad { lr: f32 },
    Adam { lr: f32, beta1: f32, beta2: f32 },
}

impl Optimizer {
    pub fn sgd(lr: f32) -> Optimizer {
        Optimizer::Sgd { lr }
    }

    pub fn momentum(lr: f32, momentum: f32) -> Optimizer {
        Optimizer::Momentum { lr, momentum }
    }

    pub fn adagrad(lr: f32) -> Optimizer {
        Optimizer::Adagrad { lr }
    }

    pub fn adam(lr: f32) -> Optimizer {
        Optimizer::Adam { lr, beta1: 0.9, beta2: 0.999 }
    }

    /// Add one Apply node updating `var` with `grad`.
    pub fn apply(&self, b: &mut GraphBuilder, var: Endpoint, grad: Endpoint) -> Result<NodeId> {
        match *self {
            Optimizer::Sgd { lr } => {
                let lr = b.scalar(lr);
                b.op("ApplyGradientDescent", "sgd_update", vec![var, lr, grad], vec![])
            }
            Optimizer::Momentum { lr, momentum } => {
                let lr = b.scalar(lr);
                let mom = b.scalar(momentum);
                b.op("ApplyMomentum", "momentum_update", vec![var, lr, grad, mom], vec![])
            }
            Optimizer::Adagrad { lr } => {
                let lr = b.scalar(lr);
                b.op("ApplyAdagrad", "adagrad_update", vec![var, lr, grad], vec![])
            }
            Optimizer::Adam { lr, beta1, beta2 } => {
                let lr = b.scalar(lr);
                let b1 = b.scalar(beta1);
                let b2 = b.scalar(beta2);
                b.op("ApplyAdam", "adam_update", vec![var, lr, grad, b1, b2], vec![])
            }
        }
    }

    /// `minimize`: gradients of `loss` w.r.t. `vars`, one apply per var,
    /// all grouped under a returned train op.
    pub fn minimize(
        &self,
        b: &mut GraphBuilder,
        loss: Endpoint,
        vars: &[Endpoint],
    ) -> Result<NodeId> {
        let grads = gradients(b, loss, vars)?;
        let mut updates = Vec::with_capacity(vars.len());
        for (var, grad) in vars.iter().zip(grads) {
            let grad = grad.ok_or_else(|| {
                Status::invalid_argument(format!(
                    "loss does not depend on variable {:?}",
                    b.graph.node(var.node).name
                ))
            })?;
            let upd = self.apply(b, *var, grad)?;
            // Keep the update on the variable's device (§4.3 colocation of
            // parameter state) — already enforced by ref-edge colocation.
            updates.push(upd);
        }
        Ok(b.group("train", updates))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::{Session, SessionOptions};
    use crate::tensor::Tensor;

    /// Minimize (w - 3)^2 and check convergence to w = 3.
    fn converges(opt: Optimizer, steps: usize, tol: f32) {
        let mut b = GraphBuilder::new();
        let w = b.variable("w", Tensor::scalar_f32(0.0)).unwrap();
        let three = b.scalar(3.0);
        let diff = b.sub(w, three);
        let loss = b.square(diff);
        let train = opt.minimize(&mut b, loss, &[w]).unwrap();
        let train_name = b.graph.node(train).name.clone();
        let init: Vec<String> = b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
        let sess = Session::new(b.into_graph(), SessionOptions::default());
        sess.run_targets(&init.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();
        for _ in 0..steps {
            sess.run_targets(&[&train_name]).unwrap();
        }
        let out = sess.run(&[], &["w"], &[]).unwrap();
        let w_final = out[0].scalar_value_f32().unwrap();
        assert!((w_final - 3.0).abs() < tol, "{opt:?} converged to {w_final}, want 3.0");
    }

    #[test]
    fn sgd_converges() {
        converges(Optimizer::sgd(0.1), 100, 1e-3);
    }

    #[test]
    fn momentum_converges() {
        converges(Optimizer::momentum(0.05, 0.9), 200, 1e-2);
    }

    #[test]
    fn adagrad_converges() {
        converges(Optimizer::adagrad(0.9), 400, 5e-2);
    }

    #[test]
    fn adam_converges() {
        converges(Optimizer::adam(0.1), 400, 1e-2);
    }

    #[test]
    fn minimize_rejects_unrelated_variable() {
        let mut b = GraphBuilder::new();
        let w = b.variable("w", Tensor::scalar_f32(0.0)).unwrap();
        let loss = b.scalar(1.0);
        assert!(Optimizer::sgd(0.1).minimize(&mut b, loss, &[w]).is_err());
    }

    #[test]
    fn multi_variable_linear_regression() {
        // y = a*x + c fit to y = 2x + 1 over fixed points.
        let mut b = GraphBuilder::new();
        let a = b.variable("a", Tensor::scalar_f32(0.0)).unwrap();
        let c = b.variable("c", Tensor::scalar_f32(0.0)).unwrap();
        let xs = b.constant(Tensor::from_f32(vec![4], vec![0., 1., 2., 3.]).unwrap());
        let ys = b.constant(Tensor::from_f32(vec![4], vec![1., 3., 5., 7.]).unwrap());
        let ax = b.mul(a, xs);
        let pred = b.add(ax, c);
        let err = b.sub(pred, ys);
        let sq = b.square(err);
        let loss = b.reduce_mean(sq, None);
        let train = Optimizer::sgd(0.05).minimize(&mut b, loss, &[a, c]).unwrap();
        let train_name = b.graph.node(train).name.clone();
        let init: Vec<String> = b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
        let sess = Session::new(b.into_graph(), SessionOptions::default());
        sess.run_targets(&init.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();
        for _ in 0..500 {
            sess.run_targets(&[&train_name]).unwrap();
        }
        let out = sess.run(&[], &["a", "c"], &[]).unwrap();
        assert!((out[0].scalar_value_f32().unwrap() - 2.0).abs() < 0.05);
        assert!((out[1].scalar_value_f32().unwrap() - 1.0).abs() < 0.1);
    }
}
