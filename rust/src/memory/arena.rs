//! The step arena: pooled, slot-structured tensor storage executed against
//! a [`MemoryPlan`](crate::memory::MemoryPlan).
//!
//! A [`StepArena`] holds one storage pool per plan *slot*. Kernels check
//! storage out of their assigned slot ([`StepArena::checkout_f32`]),
//! write their result into it, and wrap it in a `Tensor` whose
//! [`TensorBuffer`](crate::tensor::TensorBuffer) carries the slot's
//! recycler — when the last reference to that tensor drops, the storage
//! lands back in the slot, ready for the next tenant (a later node of this
//! step, or the same node next step).
//!
//! Reuse is therefore *refcount-driven*: the plan only decides which
//! endpoints share a slot. If a slot's storage is still referenced when
//! the next tenant arrives (out-of-order dataflow execution, an escaped
//! fetch), checkout simply falls back to a fresh allocation — a miss, not
//! a bug. Nothing ever aliases: the `Mutex<Option<…>>` hand-off gives each
//! tenant unique ownership of the `Vec`.
//!
//! Arenas are pooled per compiled step by [`ArenaPool`]; each `Run`
//! checks out a whole arena for the duration of the step, so concurrent
//! steps of one cached signature never share one (asserted at checkout).

use crate::tensor::{BufRecycler, TensorData};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};

/// Monotonic counters shared by every arena of one [`ArenaPool`] — the
/// runtime half of the step's memory report (the static half is
/// `MemoryPlanStats`).
#[derive(Debug, Default)]
pub struct MemCounters {
    arenas_created: AtomicU64,
    checkouts: AtomicU64,
    reuse_hits: AtomicU64,
    reuse_misses: AtomicU64,
    bytes_reused: AtomicU64,
    bytes_fresh: AtomicU64,
    forwards_taken: AtomicU64,
    bytes_forwarded: AtomicU64,
    scratch_checkouts: AtomicU64,
    scratch_bytes_fresh: AtomicU64,
    // High-watermarks: the largest single-step byte totals any arena of
    // this pool has seen (folded in at `end_step`), split by source.
    hw_planned_bytes: AtomicU64,
    hw_dynamic_bytes: AtomicU64,
    hw_scratch_bytes: AtomicU64,
}

/// The largest single-step byte totals any arena of a pool has served,
/// split by where the storage came from: pooled plan slots (`planned`),
/// fresh heap fallbacks (`dynamic` — empty slot, wrong dtype, or storage
/// still referenced), and kernel scratch (`scratch`). The memory half of
/// the §9.2 EEG story: "what does one step of this signature cost at
/// peak", per device.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ArenaHighWater {
    pub planned_bytes: u64,
    pub dynamic_bytes: u64,
    pub scratch_bytes: u64,
}

impl ArenaHighWater {
    /// Sum of all three watermarks — a step's peak arena-served bytes.
    pub fn total_bytes(&self) -> u64 {
        self.planned_bytes + self.dynamic_bytes + self.scratch_bytes
    }
}

/// Point-in-time copy of [`MemCounters`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MemSnapshot {
    /// Distinct arenas ever built for this pool (≥ the max number of
    /// concurrent steps observed).
    pub arenas_created: u64,
    /// Steps that checked an arena out.
    pub checkouts: u64,
    /// Slot checkouts served from pooled storage (no heap allocation).
    pub reuse_hits: u64,
    /// Slot checkouts that had to allocate fresh storage.
    pub reuse_misses: u64,
    pub bytes_reused: u64,
    pub bytes_fresh: u64,
    /// In-place kernel forwards taken (output aliased its dying input).
    pub forwards_taken: u64,
    pub bytes_forwarded: u64,
    /// Kernel scratch checkouts (GEMM packing panels, im2col patches).
    pub scratch_checkouts: u64,
    /// Scratch checkouts that had to allocate (the rest reused a pooled
    /// buffer already big enough).
    pub scratch_bytes_fresh: u64,
}

impl MemSnapshot {
    /// Counter deltas since `earlier` (saturating: the counters are
    /// monotonic, but a snapshot pair taken across pool replacement may
    /// not be). Used for per-step arena stats in `StepStats`.
    pub fn delta_since(&self, earlier: &MemSnapshot) -> MemSnapshot {
        MemSnapshot {
            arenas_created: self.arenas_created.saturating_sub(earlier.arenas_created),
            checkouts: self.checkouts.saturating_sub(earlier.checkouts),
            reuse_hits: self.reuse_hits.saturating_sub(earlier.reuse_hits),
            reuse_misses: self.reuse_misses.saturating_sub(earlier.reuse_misses),
            bytes_reused: self.bytes_reused.saturating_sub(earlier.bytes_reused),
            bytes_fresh: self.bytes_fresh.saturating_sub(earlier.bytes_fresh),
            forwards_taken: self.forwards_taken.saturating_sub(earlier.forwards_taken),
            bytes_forwarded: self.bytes_forwarded.saturating_sub(earlier.bytes_forwarded),
            scratch_checkouts: self.scratch_checkouts.saturating_sub(earlier.scratch_checkouts),
            scratch_bytes_fresh: self
                .scratch_bytes_fresh
                .saturating_sub(earlier.scratch_bytes_fresh),
        }
    }
}

impl MemCounters {
    pub fn snapshot(&self) -> MemSnapshot {
        MemSnapshot {
            arenas_created: self.arenas_created.load(Ordering::Relaxed),
            checkouts: self.checkouts.load(Ordering::Relaxed),
            reuse_hits: self.reuse_hits.load(Ordering::Relaxed),
            reuse_misses: self.reuse_misses.load(Ordering::Relaxed),
            bytes_reused: self.bytes_reused.load(Ordering::Relaxed),
            bytes_fresh: self.bytes_fresh.load(Ordering::Relaxed),
            forwards_taken: self.forwards_taken.load(Ordering::Relaxed),
            bytes_forwarded: self.bytes_forwarded.load(Ordering::Relaxed),
            scratch_checkouts: self.scratch_checkouts.load(Ordering::Relaxed),
            scratch_bytes_fresh: self.scratch_bytes_fresh.load(Ordering::Relaxed),
        }
    }

    pub(crate) fn note_forward(&self, bytes: usize) {
        self.forwards_taken.fetch_add(1, Ordering::Relaxed);
        self.bytes_forwarded.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// The pool's per-step high-watermark so far.
    pub fn high_water(&self) -> ArenaHighWater {
        ArenaHighWater {
            planned_bytes: self.hw_planned_bytes.load(Ordering::Relaxed),
            dynamic_bytes: self.hw_dynamic_bytes.load(Ordering::Relaxed),
            scratch_bytes: self.hw_scratch_bytes.load(Ordering::Relaxed),
        }
    }
}

/// One slot's pooled storage plus its shared recycler handle.
struct Slot {
    pooled: Mutex<Option<TensorData>>,
    recycler: Arc<SlotRecycler>,
}

/// Returns storage to `slot` of `arena` when a tensor's last reference
/// drops. Holds the arena weakly so an abandoned arena (pool dropped,
/// escaped fetch outliving the session) frees instead of leaking a cycle.
struct SlotRecycler {
    arena: Weak<StepArena>,
    slot: usize,
}

impl BufRecycler for SlotRecycler {
    fn recycle(&self, data: TensorData) {
        if let Some(arena) = self.arena.upgrade() {
            let mut pooled = arena.slots[self.slot].pooled.lock().unwrap();
            if pooled.is_none() {
                *pooled = Some(data);
            }
        }
    }
}

/// Scratch buffers retained per arena (GEMM packing panels, im2col
/// patches); beyond this, returned scratch is freed.
const MAX_SCRATCH_PER_ARENA: usize = 4;

/// Slot-structured storage for one executing step.
pub struct StepArena {
    slots: Vec<Slot>,
    /// Side pool for kernel-internal scratch that is not a planned
    /// endpoint (packing panels, im2col patches). Arenas are pooled per
    /// compiled step, so steady-state steps reuse the same scratch
    /// allocations the way slots reuse endpoint storage.
    scratch: Mutex<Vec<Vec<f32>>>,
    counters: Arc<MemCounters>,
    /// Guard: a pooled arena must never serve two steps at once.
    in_use: AtomicBool,
    // This step's running byte totals, reset at `begin_step` and folded
    // into the pool-wide high-watermark at `end_step`.
    step_planned: AtomicU64,
    step_dynamic: AtomicU64,
    step_scratch: AtomicU64,
}

impl StepArena {
    pub fn new(num_slots: usize, counters: Arc<MemCounters>) -> Arc<StepArena> {
        counters.arenas_created.fetch_add(1, Ordering::Relaxed);
        Arc::new_cyclic(|weak: &Weak<StepArena>| StepArena {
            slots: (0..num_slots)
                .map(|slot| Slot {
                    pooled: Mutex::new(None),
                    recycler: Arc::new(SlotRecycler { arena: weak.clone(), slot }),
                })
                .collect(),
            scratch: Mutex::new(Vec::new()),
            counters,
            in_use: AtomicBool::new(false),
            step_planned: AtomicU64::new(0),
            step_dynamic: AtomicU64::new(0),
            step_scratch: AtomicU64::new(0),
        })
    }

    pub fn num_slots(&self) -> usize {
        self.slots.len()
    }

    pub fn counters(&self) -> &Arc<MemCounters> {
        &self.counters
    }

    /// Check out `slot`'s storage for an f32 result of `n` elements.
    /// Returns an *empty* Vec with capacity ≥ `n` (callers push exactly
    /// `n` elements) — pooled when the slot holds suitable storage, fresh
    /// otherwise.
    pub fn checkout_f32(&self, slot: usize, n: usize) -> Vec<f32> {
        let taken = self.slots[slot].pooled.lock().unwrap().take();
        match taken {
            Some(TensorData::F32(mut v)) if v.capacity() >= n => {
                self.counters.reuse_hits.fetch_add(1, Ordering::Relaxed);
                self.counters.bytes_reused.fetch_add((n * 4) as u64, Ordering::Relaxed);
                self.step_planned.fetch_add((n * 4) as u64, Ordering::Relaxed);
                v.clear();
                v
            }
            _ => {
                // Empty slot, wrong dtype, or too small: allocate. (A
                // mismatched pooled Vec is dropped; the slot re-learns its
                // size from what comes back.)
                self.counters.reuse_misses.fetch_add(1, Ordering::Relaxed);
                self.counters.bytes_fresh.fetch_add((n * 4) as u64, Ordering::Relaxed);
                self.step_dynamic.fetch_add((n * 4) as u64, Ordering::Relaxed);
                Vec::with_capacity(n)
            }
        }
    }

    /// Like [`StepArena::checkout_f32`] but returned with `len == n`, all
    /// zeros (for index-written kernels like MatMul).
    pub fn checkout_f32_zeroed(&self, slot: usize, n: usize) -> Vec<f32> {
        let mut v = self.checkout_f32(slot, n);
        v.resize(n, 0.0);
        v
    }

    /// [`StepArena::checkout_f32`] for i32 storage.
    pub fn checkout_i32(&self, slot: usize, n: usize) -> Vec<i32> {
        let taken = self.slots[slot].pooled.lock().unwrap().take();
        match taken {
            Some(TensorData::I32(mut v)) if v.capacity() >= n => {
                self.counters.reuse_hits.fetch_add(1, Ordering::Relaxed);
                self.counters.bytes_reused.fetch_add((n * 4) as u64, Ordering::Relaxed);
                self.step_planned.fetch_add((n * 4) as u64, Ordering::Relaxed);
                v.clear();
                v
            }
            _ => {
                self.counters.reuse_misses.fetch_add(1, Ordering::Relaxed);
                self.counters.bytes_fresh.fetch_add((n * 4) as u64, Ordering::Relaxed);
                self.step_dynamic.fetch_add((n * 4) as u64, Ordering::Relaxed);
                Vec::with_capacity(n)
            }
        }
    }

    /// [`StepArena::checkout_f32`] for i64 storage.
    pub fn checkout_i64(&self, slot: usize, n: usize) -> Vec<i64> {
        let taken = self.slots[slot].pooled.lock().unwrap().take();
        match taken {
            Some(TensorData::I64(mut v)) if v.capacity() >= n => {
                self.counters.reuse_hits.fetch_add(1, Ordering::Relaxed);
                self.counters.bytes_reused.fetch_add((n * 8) as u64, Ordering::Relaxed);
                self.step_planned.fetch_add((n * 8) as u64, Ordering::Relaxed);
                v.clear();
                v
            }
            _ => {
                self.counters.reuse_misses.fetch_add(1, Ordering::Relaxed);
                self.counters.bytes_fresh.fetch_add((n * 8) as u64, Ordering::Relaxed);
                self.step_dynamic.fetch_add((n * 8) as u64, Ordering::Relaxed);
                Vec::with_capacity(n)
            }
        }
    }

    /// [`StepArena::checkout_f32`] for f64 storage.
    pub fn checkout_f64(&self, slot: usize, n: usize) -> Vec<f64> {
        let taken = self.slots[slot].pooled.lock().unwrap().take();
        match taken {
            Some(TensorData::F64(mut v)) if v.capacity() >= n => {
                self.counters.reuse_hits.fetch_add(1, Ordering::Relaxed);
                self.counters.bytes_reused.fetch_add((n * 8) as u64, Ordering::Relaxed);
                self.step_planned.fetch_add((n * 8) as u64, Ordering::Relaxed);
                v.clear();
                v
            }
            _ => {
                self.counters.reuse_misses.fetch_add(1, Ordering::Relaxed);
                self.counters.bytes_fresh.fetch_add((n * 8) as u64, Ordering::Relaxed);
                self.step_dynamic.fetch_add((n * 8) as u64, Ordering::Relaxed);
                Vec::with_capacity(n)
            }
        }
    }

    /// [`StepArena::checkout_f64`] returned zero-filled to `len == n`.
    pub fn checkout_f64_zeroed(&self, slot: usize, n: usize) -> Vec<f64> {
        let mut v = self.checkout_f64(slot, n);
        v.resize(n, 0.0);
        v
    }

    /// Check out a scratch `Vec<f32>` with capacity ≥ `n` (length 0) for
    /// kernel-internal buffers that are not planned endpoints — GEMM
    /// packing panels, im2col patches. Return it with
    /// [`StepArena::give_scratch_f32`] so the next node (or next step on
    /// this pooled arena) reuses the allocation.
    pub fn take_scratch_f32(&self, n: usize) -> Vec<f32> {
        self.counters.scratch_checkouts.fetch_add(1, Ordering::Relaxed);
        // The watermark tracks scratch *usage*, pooled or fresh.
        self.step_scratch.fetch_add((n * 4) as u64, Ordering::Relaxed);
        let mut pool = self.scratch.lock().unwrap();
        if let Some(pos) = pool.iter().position(|v| v.capacity() >= n) {
            let mut v = pool.swap_remove(pos);
            v.clear();
            return v;
        }
        drop(pool);
        self.counters.scratch_bytes_fresh.fetch_add((n * 4) as u64, Ordering::Relaxed);
        Vec::with_capacity(n)
    }

    /// Return a vector checked out with [`StepArena::take_scratch_f32`].
    pub fn give_scratch_f32(&self, v: Vec<f32>) {
        if v.capacity() == 0 {
            return;
        }
        let mut pool = self.scratch.lock().unwrap();
        if pool.len() < MAX_SCRATCH_PER_ARENA {
            pool.push(v);
        }
    }

    /// The recycler to attach to tensors built over `slot`'s storage.
    pub fn recycler(&self, slot: usize) -> Arc<dyn BufRecycler> {
        Arc::clone(&self.slots[slot].recycler) as Arc<dyn BufRecycler>
    }

    fn begin_step(&self) {
        assert!(
            !self.in_use.swap(true, Ordering::SeqCst),
            "StepArena checked out by two concurrent steps"
        );
        self.step_planned.store(0, Ordering::Relaxed);
        self.step_dynamic.store(0, Ordering::Relaxed);
        self.step_scratch.store(0, Ordering::Relaxed);
    }

    fn end_step(&self) {
        let c = &self.counters;
        c.hw_planned_bytes.fetch_max(self.step_planned.load(Ordering::Relaxed), Ordering::Relaxed);
        c.hw_dynamic_bytes.fetch_max(self.step_dynamic.load(Ordering::Relaxed), Ordering::Relaxed);
        c.hw_scratch_bytes.fetch_max(self.step_scratch.load(Ordering::Relaxed), Ordering::Relaxed);
        self.in_use.store(false, Ordering::SeqCst);
    }
}

impl std::fmt::Debug for StepArena {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "StepArena({} slots)", self.slots.len())
    }
}

/// How many idle arenas a pool keeps; beyond this, returned arenas are
/// dropped (their pooled storage with them). Bounds memory held by a
/// signature that once saw a concurrency burst.
const MAX_POOLED_ARENAS: usize = 8;

/// Per-compiled-step pool of [`StepArena`]s. One arena serves exactly one
/// in-flight step; concurrent steps get distinct arenas.
#[derive(Debug)]
pub struct ArenaPool {
    num_slots: usize,
    free: Mutex<Vec<Arc<StepArena>>>,
    counters: Arc<MemCounters>,
}

impl ArenaPool {
    pub fn new(num_slots: usize) -> Arc<ArenaPool> {
        Arc::new(ArenaPool {
            num_slots,
            free: Mutex::new(Vec::new()),
            counters: Arc::new(MemCounters::default()),
        })
    }

    pub fn counters(&self) -> &Arc<MemCounters> {
        &self.counters
    }

    /// An arena for one step. Exclusive until [`ArenaPool::checkin`].
    pub fn checkout(&self) -> Arc<StepArena> {
        self.counters.checkouts.fetch_add(1, Ordering::Relaxed);
        let pooled = self.free.lock().unwrap().pop();
        let arena =
            pooled.unwrap_or_else(|| StepArena::new(self.num_slots, Arc::clone(&self.counters)));
        arena.begin_step();
        arena
    }

    /// Return a step's arena. Storage the step's tensors have already
    /// released is retained in the slots; late drops (escaped fetches)
    /// refill slots whenever they happen.
    pub fn checkin(&self, arena: Arc<StepArena>) {
        arena.end_step();
        let mut free = self.free.lock().unwrap();
        if free.len() < MAX_POOLED_ARENAS {
            free.push(arena);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Tensor, TensorBuffer};

    #[test]
    fn checkout_reuses_recycled_storage() {
        let pool = ArenaPool::new(2);
        let arena = pool.checkout();
        let v = arena.checkout_f32(0, 8);
        assert_eq!(v.len(), 0);
        assert!(v.capacity() >= 8);
        // First checkout is a miss.
        assert_eq!(pool.counters().snapshot().reuse_misses, 1);
        // Wrap as a tensor, drop it: storage returns to slot 0.
        let mut v = v;
        v.resize(8, 1.5);
        let t = Tensor::with_buffer(
            vec![8],
            TensorBuffer::recycled(TensorData::F32(v), arena.recycler(0)),
        )
        .unwrap();
        drop(t);
        let v2 = arena.checkout_f32(0, 8);
        assert!(v2.capacity() >= 8);
        let snap = pool.counters().snapshot();
        assert_eq!(snap.reuse_hits, 1);
        assert_eq!(snap.bytes_reused, 32);
    }

    #[test]
    fn live_reference_forces_fresh_allocation() {
        let pool = ArenaPool::new(1);
        let arena = pool.checkout();
        let mut v = arena.checkout_f32(0, 4);
        v.resize(4, 0.0);
        let t = Tensor::with_buffer(
            vec![4],
            TensorBuffer::recycled(TensorData::F32(v), arena.recycler(0)),
        )
        .unwrap();
        let held = t.clone();
        drop(t); // one reference still live: no recycle yet
        let _fresh = arena.checkout_f32(0, 4);
        assert_eq!(pool.counters().snapshot().reuse_hits, 0);
        drop(held); // now it lands back in the slot
        let _reused = arena.checkout_f32(0, 4);
        assert_eq!(pool.counters().snapshot().reuse_hits, 1);
    }

    #[test]
    fn concurrent_checkouts_get_distinct_arenas() {
        let pool = ArenaPool::new(1);
        let a = pool.checkout();
        let b = pool.checkout();
        assert!(!Arc::ptr_eq(&a, &b), "two in-flight steps must not share an arena");
        pool.checkin(a);
        pool.checkin(b);
        // After checkin, pooled arenas are recycled.
        let c = pool.checkout();
        pool.checkin(c);
        assert_eq!(pool.counters().snapshot().arenas_created, 2);
    }

    #[test]
    fn dtype_mismatch_falls_back_to_fresh() {
        let pool = ArenaPool::new(1);
        let arena = pool.checkout();
        // Hand back i32 storage into the slot.
        arena.recycler(0).recycle(TensorData::I32(vec![1, 2, 3]));
        let v = arena.checkout_f32(0, 2);
        assert!(v.capacity() >= 2);
        assert_eq!(pool.counters().snapshot().reuse_hits, 0);
    }

    #[test]
    fn scratch_checkout_reuses_capacity() {
        let pool = ArenaPool::new(1);
        let arena = pool.checkout();
        let mut v = arena.take_scratch_f32(64);
        assert!(v.is_empty());
        assert!(v.capacity() >= 64);
        v.resize(64, 1.0);
        let ptr = v.as_ptr();
        arena.give_scratch_f32(v);
        let v2 = arena.take_scratch_f32(32);
        assert!(v2.is_empty());
        assert_eq!(v2.as_ptr(), ptr, "smaller request reuses the pooled scratch");
        let snap = pool.counters().snapshot();
        assert_eq!(snap.scratch_checkouts, 2);
        assert_eq!(snap.scratch_bytes_fresh, 64 * 4);
    }

    #[test]
    fn high_water_tracks_peak_step_not_sum() {
        let pool = ArenaPool::new(2);
        // Step 1: one fresh 8-element f32 checkout (32 dynamic bytes) and
        // 64 scratch bytes.
        let a = pool.checkout();
        let _v = a.checkout_f32(0, 8);
        a.give_scratch_f32(a.take_scratch_f32(16));
        pool.checkin(a);
        let hw = pool.counters().high_water();
        assert_eq!(hw.dynamic_bytes, 32);
        assert_eq!(hw.scratch_bytes, 64);
        assert_eq!(hw.planned_bytes, 0);
        // Step 2 is smaller: the watermark must not move (max, not sum).
        let a = pool.checkout();
        let _v = a.checkout_f32(0, 2);
        pool.checkin(a);
        let hw2 = pool.counters().high_water();
        assert_eq!(hw2.dynamic_bytes, 32);
        assert_eq!(hw2.scratch_bytes, 64);
        assert_eq!(hw2.total_bytes(), 96);
        // Step 3 with a pooled hit: recycled storage counts as planned.
        let a = pool.checkout();
        let mut v = a.checkout_f32(1, 4);
        v.resize(4, 0.0);
        let t = Tensor::with_buffer(
            vec![4],
            TensorBuffer::recycled(TensorData::F32(v), a.recycler(1)),
        )
        .unwrap();
        drop(t);
        let _reused = a.checkout_f32(1, 4);
        pool.checkin(a);
        assert_eq!(pool.counters().high_water().planned_bytes, 16);
    }

    #[test]
    fn abandoned_arena_recycler_is_harmless() {
        let pool = ArenaPool::new(1);
        let arena = pool.checkout();
        let recycler = arena.recycler(0);
        pool.checkin(arena);
        drop(pool);
        // Arena is gone (weak upgrade fails): recycle is a no-op drop.
        recycler.recycle(TensorData::F32(vec![0.0; 4]));
    }
}
