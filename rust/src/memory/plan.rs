//! The static memory plan (tentpole layer 2): assign plannable endpoints
//! to arena *slots* by first-fit-by-offset over their liveness intervals,
//! and mark the inputs eligible for in-place kernel forwarding (layer 3).
//!
//! Endpoints with statically known f32 shapes get byte-exact *static*
//! slots: the classic first-fit-by-offset packing — walk existing slots in
//! arena-offset order, take the first one that is free over the endpoint's
//! interval and large enough, else append a new slot at the arena's
//! current end. `arena_bytes` (the packed footprint) vs `naive_bytes`
//! (one allocation per endpoint, what the unplanned executor does) is the
//! headline stat. Endpoints that are plannable but dynamically shaped
//! (anything downstream of a feed) get *dynamic* slots: the same interval
//! packing with sizes unknown — their pooled buffers grow to the
//! high-water mark at run time. Everything else stays on the heap.

use crate::error::Result;
use crate::executor::compile::CompiledNode;
use crate::graph::Graph;
use crate::kernels::is_forwarding_safe;
use crate::memory::liveness;

/// `MemoryPlanStats`: the build-time report surfaced beside
/// `Session::optimizer_stats` (runtime counters live in
/// [`MemSnapshot`](crate::memory::MemSnapshot)).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MemoryPlanStats {
    /// Endpoints assigned byte-exact static slots.
    pub planned_static: usize,
    /// Endpoints assigned capacity-pooled dynamic slots.
    pub planned_dynamic: usize,
    /// Endpoints pinned to the heap (feeds, fetches, control flow,
    /// stateful, non-f32).
    pub unplanned: usize,
    pub num_slots: usize,
    /// Packed footprint of the static slots.
    pub arena_bytes: usize,
    /// Sum of static endpoint sizes (the naive one-buffer-per-endpoint
    /// cost the packing is measured against).
    pub naive_bytes: usize,
    /// Input slots marked eligible for in-place forwarding.
    pub forward_candidates: usize,
}

/// The per-partition plan, computed once per cached step and shared by
/// every run of it.
#[derive(Debug, Default)]
pub struct MemoryPlan {
    /// `[node][port]` → arena slot, `None` = heap.
    out_slots: Vec<Vec<Option<u32>>>,
    /// `[node][input slot]` → may alias the input's storage in place.
    forward_inputs: Vec<Vec<bool>>,
    pub stats: MemoryPlanStats,
}

impl MemoryPlan {
    /// Total arena slots (static + dynamic) the plan assigns.
    pub fn num_slots(&self) -> usize {
        self.stats.num_slots
    }

    pub fn out_slot(&self, node: usize, port: usize) -> Option<u32> {
        self.out_slots.get(node).and_then(|p| p.get(port)).copied().flatten()
    }

    pub fn input_forwardable(&self, node: usize, slot: usize) -> bool {
        self.forward_inputs.get(node).and_then(|f| f.get(slot)).copied().unwrap_or(false)
    }
}

/// A slot under assignment: free again once `free_after` has executed.
/// Slots live in creation order, which *is* offset order (each new slot
/// starts at the running `arena_end`), so index order == offset order.
struct SlotState {
    size: usize,
    free_after: usize,
}

/// Compute the plan for one compiled partition. `nodes` must index the
/// same graph (as produced inside `CompiledGraph::compile`).
pub fn plan_partition(graph: &Graph, nodes: &[CompiledNode]) -> Result<MemoryPlan> {
    let lv = liveness::analyze(graph, nodes)?;
    let mut stats = MemoryPlanStats::default();
    let mut out_slots: Vec<Vec<Option<u32>>> =
        nodes.iter().map(|cn| vec![None; cn.out_edges.len()]).collect();

    // Endpoints in def order — the first-fit scan must see tenants in the
    // order the schedule estimate creates them.
    let mut endpoints: Vec<(usize, usize)> = Vec::new();
    for (i, cn) in nodes.iter().enumerate() {
        for port in 0..cn.out_edges.len() {
            if lv.plannable[i][port] {
                endpoints.push((i, port));
            } else {
                stats.unplanned += 1;
            }
        }
    }
    endpoints.sort_by_key(|&(i, _)| lv.pos[i]);

    let mut static_slots: Vec<SlotState> = Vec::new();
    let mut dynamic_slots: Vec<SlotState> = Vec::new();
    let mut arena_end = 0usize;
    for &(i, port) in &endpoints {
        let def = lv.pos[i];
        let last = lv.last_use[i][port];
        match lv.static_bytes(i, port) {
            Some(bytes) => {
                stats.planned_static += 1;
                stats.naive_bytes += bytes;
                // First fit by offset: slots are appended in offset order,
                // so a linear scan visits lowest offsets first.
                let k = match static_slots
                    .iter()
                    .position(|s| s.free_after < def && s.size >= bytes)
                {
                    Some(k) => {
                        static_slots[k].free_after = last;
                        k
                    }
                    None => {
                        static_slots.push(SlotState { size: bytes, free_after: last });
                        arena_end += bytes;
                        static_slots.len() - 1
                    }
                };
                out_slots[i][port] = Some(k as u32);
            }
            None => {
                stats.planned_dynamic += 1;
                let k = match dynamic_slots.iter().position(|s| s.free_after < def) {
                    Some(k) => {
                        dynamic_slots[k].free_after = last;
                        k
                    }
                    None => {
                        dynamic_slots.push(SlotState { size: 0, free_after: last });
                        dynamic_slots.len() - 1
                    }
                };
                // Dynamic slots are numbered after every static slot (the
                // static count is final only once all endpoints are seen,
                // so park them high and renumber below).
                out_slots[i][port] = Some(u32::MAX - k as u32);
            }
        }
    }
    // Renumber dynamic slots into [num_static, num_static + num_dynamic).
    let num_static = static_slots.len();
    for row in &mut out_slots {
        for s in row.iter_mut() {
            if let Some(v) = *s {
                if v > u32::MAX / 2 {
                    *s = Some(num_static as u32 + (u32::MAX - v));
                }
            }
        }
    }
    stats.num_slots = num_static + dynamic_slots.len();
    stats.arena_bytes = arena_end;

    // ---- in-place forwarding marks (layer 3) ----------------------------
    // An input may be written in place when: its endpoint is planned, this
    // node is its interval's end, it is the *only* read of the endpoint
    // (two reads by one node mean two live aliases), and the kernel is
    // registered forwarding-safe. The executor still requires refcount 1
    // at run time, so these marks are candidates, never promises.
    let mut forward_inputs: Vec<Vec<bool>> =
        nodes.iter().map(|cn| vec![false; cn.inputs.len()]).collect();
    for (i, cn) in nodes.iter().enumerate() {
        if !is_forwarding_safe(&cn.info.op) {
            continue;
        }
        for (slot, e) in cn.inputs.iter().enumerate() {
            let planned = out_slots
                .get(e.node.0)
                .and_then(|p| p.get(e.port))
                .copied()
                .flatten()
                .is_some();
            if planned
                && lv.last_use[e.node.0][e.port] == lv.pos[i]
                && lv.consumers[e.node.0][e.port] == 1
            {
                forward_inputs[i][slot] = true;
                stats.forward_candidates += 1;
            }
        }
    }

    Ok(MemoryPlan { out_slots, forward_inputs, stats })
}
