//! Liveness analysis over a compiled per-device partition (tentpole layer
//! 1): first-def/last-use intervals per tensor endpoint, plus the pinning
//! rules that decide which endpoints the planner may manage at all.
//!
//! Intervals are positions in a memory-aware serial order of the
//! partition graph (`passes::schedule::lifetime_shrinking_order`). The
//! real executor is dataflow-concurrent, so this order is a *schedule
//! estimate*, not a contract — the arena degrades interval violations to
//! allocation misses (see `memory::arena`), never to aliasing.
//!
//! Pinned (unplannable) endpoints, per the §5 rules:
//! * control flow — Switch/Merge/Enter/Exit/NextIteration producers,
//!   anything outside the root frame, loop-invariant captures, and
//!   producers feeding any of those: their tokens cross iteration state
//!   the serial order cannot see;
//! * stateful/variable-backed tensors — `Variable`, `Assign*`/`Apply*`,
//!   queue ops, `_Send`/`_Recv`, `_Feed` (feeds), plus endpoints
//!   *consumed* by a stateful op — `_Fetch` (fetches) and `_Send` make a
//!   tensor escape the step, `Assign` makes it the variable's backing
//!   store;
//! * `Const` — its storage is shared with the node's attr across steps.

use crate::error::Result;
use crate::executor::compile::{CompiledNode, NodeKind};
use crate::graph::Graph;
use crate::ops;
use crate::tensor::{DType, Shape};

/// Per-endpoint facts, indexed `[node][port]`.
pub struct Liveness {
    /// Serial schedule estimate used for the intervals.
    pub pos: Vec<usize>,
    /// May the planner manage this endpoint's storage?
    pub plannable: Vec<Vec<bool>>,
    /// Position of the endpoint's last consumer (== producer position for
    /// unconsumed outputs).
    pub last_use: Vec<Vec<usize>>,
    /// Total (consumer, slot) pairs reading the endpoint.
    pub consumers: Vec<Vec<usize>>,
    /// Statically inferred (shape, dtype), where derivable from Const
    /// roots; `None` = dynamic (known only at run time).
    pub static_info: Vec<Vec<Option<(Shape, DType)>>>,
}

impl Liveness {
    /// Statically known byte size of an endpoint, for the dtypes the
    /// arena can pool (f32/f64/i32/i64), if any.
    pub fn static_bytes(&self, node: usize, port: usize) -> Option<usize> {
        match &self.static_info[node][port] {
            Some((shape, DType::F32)) | Some((shape, DType::I32)) => {
                Some(shape.num_elements() * 4)
            }
            Some((shape, DType::F64)) | Some((shape, DType::I64)) => {
                Some(shape.num_elements() * 8)
            }
            _ => None,
        }
    }
}

/// Is `op`'s output storage pinned by the stateful rule? (Unregistered ops
/// are conservatively pinned.)
fn stateful_op(op: &str) -> bool {
    ops::lookup(op).map(|d| d.stateful).unwrap_or(true)
}

/// Run the analysis. `nodes` must be the compiled view of `graph` (same
/// indexing), so frames and node kinds are already resolved.
pub fn analyze(graph: &Graph, nodes: &[CompiledNode]) -> Result<Liveness> {
    let order = crate::passes::schedule::lifetime_shrinking_order(graph)?;
    let mut pos = vec![0usize; nodes.len()];
    for (i, &id) in order.iter().enumerate() {
        pos[id.0] = i;
    }

    let static_info = infer_static_info(graph, nodes, &order);

    let mut plannable: Vec<Vec<bool>> = Vec::with_capacity(nodes.len());
    let mut last_use: Vec<Vec<usize>> = Vec::with_capacity(nodes.len());
    let mut consumers: Vec<Vec<usize>> = Vec::with_capacity(nodes.len());
    for (i, cn) in nodes.iter().enumerate() {
        let op = cn.info.op.as_str();
        let producer_ok = matches!(cn.kind, NodeKind::Normal)
            && cn.frame == 0
            && !cn.has_invariant_consumers
            && !stateful_op(op)
            && op != "Const";
        // Endpoints *known* to be dtypes the arena cannot pool
        // (Bool/U8/Str/BF16) stay on the heap — a slot there would sit
        // permanently dead. f32/f64/i32/i64 all have checkout paths now;
        // unknown dtypes may still turn out poolable and get dynamic slots.
        let known_unpoolable = |port: usize| {
            matches!(
                static_info[i].get(port),
                Some(Some((_, d)))
                    if !matches!(d, DType::F32 | DType::F64 | DType::I32 | DType::I64)
            )
        };
        let mut node_plan = Vec::with_capacity(cn.out_edges.len());
        let mut node_last = Vec::with_capacity(cn.out_edges.len());
        let mut node_cons = Vec::with_capacity(cn.out_edges.len());
        for (port, edges) in cn.out_edges.iter().enumerate() {
            let mut ok = producer_ok && !known_unpoolable(port);
            let mut last = pos[i];
            for &(consumer, _slot) in edges {
                let c = &nodes[consumer.0];
                // Consumers that retag, capture, escape, or persist the
                // tensor pin it (control flow, other frames, _Fetch/_Send,
                // Assign-family, queue ops).
                if !matches!(c.kind, NodeKind::Normal)
                    || c.frame != 0
                    || stateful_op(&c.info.op)
                {
                    ok = false;
                }
                last = last.max(pos[consumer.0]);
            }
            node_plan.push(ok);
            node_last.push(last);
            node_cons.push(edges.len());
        }
        plannable.push(node_plan);
        last_use.push(node_last);
        consumers.push(node_cons);
    }

    Ok(Liveness { pos, plannable, last_use, consumers, static_info })
}

/// Forward static shape/dtype inference from Const roots through the ops
/// whose output geometry is a pure function of input geometry. Fed or
/// otherwise-dynamic endpoints stay `None` — the plan gives them
/// capacity-pooled *dynamic* slots instead of byte-exact offsets.
fn infer_static_info(
    graph: &Graph,
    nodes: &[CompiledNode],
    order: &[crate::graph::NodeId],
) -> Vec<Vec<Option<(Shape, DType)>>> {
    let mut info: Vec<Vec<Option<(Shape, DType)>>> =
        nodes.iter().map(|cn| vec![None; cn.out_edges.len().max(1)]).collect();
    fn input_info(
        nodes: &[CompiledNode],
        info: &[Vec<Option<(Shape, DType)>>],
        i: usize,
        slot: usize,
    ) -> Option<(Shape, DType)> {
        nodes[i]
            .inputs
            .get(slot)
            .and_then(|e| info[e.node.0].get(e.port).cloned().flatten())
    }
    for &id in order {
        let i = id.0;
        let n = graph.node(id);
        let out: Option<(Shape, DType)> = match n.op.as_str() {
            "Const" => n
                .attr_opt("value")
                .and_then(|a| a.as_tensor().ok())
                .map(|t| (t.shape().clone(), t.dtype())),
            // Shape-preserving unary ops.
            "Neg" | "Exp" | "Log" | "Sqrt" | "Rsqrt" | "Abs" | "Sign" | "Square" | "Tanh"
            | "Reciprocal" | "ReLU" | "Sigmoid" | "Identity" | "StopGradient"
            | "CheckNumerics" => input_info(nodes, &info, i, 0),
            "Cast" => match (input_info(nodes, &info, i, 0), n.attr_opt("DstT")) {
                (Some((shape, _)), Some(a)) => a.as_type().ok().map(|d| (shape, d)),
                _ => None,
            },
            "Add" | "Sub" | "Mul" | "Div" | "Maximum" | "Minimum" | "Pow" => {
                match (input_info(nodes, &info, i, 0), input_info(nodes, &info, i, 1)) {
                    (Some((a, d)), Some((b, _))) => a.broadcast(&b).ok().map(|s| (s, d)),
                    _ => None,
                }
            }
            // AddN broadcasts across all inputs (its kernel folds through
            // binary Add), so the output is the broadcast of every input.
            "AddN" => {
                let mut acc = input_info(nodes, &info, i, 0);
                for slot in 1..nodes[i].inputs.len() {
                    acc = match (acc, input_info(nodes, &info, i, slot)) {
                        (Some((a, d)), Some((b, _))) => {
                            a.broadcast(&b).ok().map(|s| (s, d))
                        }
                        _ => None,
                    };
                }
                acc
            }
            "Select" => input_info(nodes, &info, i, 1),
            // Comparisons/logical ops produce Bool — inferred so the
            // planner can *pin* them (non-f32 endpoints stay on the heap).
            "Greater" | "Less" | "Equal" | "NotEqual" | "GreaterEqual" | "LessEqual"
            | "LogicalAnd" | "LogicalOr" => {
                match (input_info(nodes, &info, i, 0), input_info(nodes, &info, i, 1)) {
                    (Some((a, _)), Some((b, _))) => {
                        a.broadcast(&b).ok().map(|s| (s, DType::Bool))
                    }
                    _ => None,
                }
            }
            "LogicalNot" => {
                input_info(nodes, &info, i, 0).map(|(s, _)| (s, DType::Bool))
            }
            "FusedElementwise" => {
                // Output is primary-shaped when every extra broadcasts up
                // to (a prefix-compatible subset of) the primary.
                input_info(nodes, &info, i, 0).filter(|(primary, _)| {
                    (1..nodes[i].inputs.len()).all(|slot| {
                        input_info(nodes, &info, i, slot).is_some_and(|(extra, d)| {
                            d == DType::F32
                                && primary.broadcast(&extra).map(|s| &s == primary).unwrap_or(false)
                        })
                    })
                })
            }
            "MatMul" => {
                let ta = n.attr_opt("transpose_a").and_then(|a| a.as_bool().ok()).unwrap_or(false);
                let tb = n.attr_opt("transpose_b").and_then(|a| a.as_bool().ok()).unwrap_or(false);
                match (input_info(nodes, &info, i, 0), input_info(nodes, &info, i, 1)) {
                    (Some((a, d)), Some((b, _))) if a.rank() == 2 && b.rank() == 2 => {
                        let m = if ta { a.dim(1) } else { a.dim(0) };
                        let n_ = if tb { b.dim(0) } else { b.dim(1) };
                        Some((Shape(vec![m, n_]), d))
                    }
                    _ => None,
                }
            }
            _ => None,
        };
        if let Some(v) = out {
            if !info[i].is_empty() {
                info[i][0] = Some(v);
            }
        }
    }
    info
}
