//! The step memory planner (whitepaper §5, §9): turn a compiled step into
//! a static memory plan and execute against it, so a cached serving step
//! stops paying the allocator for every intermediate of every node of
//! every run.
//!
//! The whitepaper credits much of TensorFlow's single-step speed to
//! memory-aware execution — §5.2 schedules Receive nodes to shrink tensor
//! residency, and the §9.2 EEG traces were used to find allocation hot
//! spots; the OSDI'16 follow-up describes the production runtime's planned
//! buffer reuse and in-place kernels. This module is that subsystem for
//! our runtime, in three layers:
//!
//! 1. **[`liveness`]** — first-def/last-use intervals per tensor endpoint
//!    over the post-optimizer, post-placement partition graph, with feeds,
//!    fetches, control flow, and stateful/variable-backed tensors pinned
//!    as unplannable.
//! 2. **[`plan`] + [`arena`]** — a first-fit-by-offset assignment of
//!    planned endpoints into one per-device step arena ([`MemoryPlan`]),
//!    executed by pooled slot storage ([`StepArena`] / [`ArenaPool`])
//!    handed to kernels through `KernelContext`. Tensors over arena
//!    storage are ordinary `Tensor`s whose
//!    [`TensorBuffer`](crate::tensor::TensorBuffer) returns the storage to
//!    its slot on last drop.
//! 3. **in-place forwarding** — when a planned input's interval ends at a
//!    node, the plan read exactly one use, and the kernel is registered
//!    forwarding-safe (`kernels::is_forwarding_safe` — elementwise math
//!    and `FusedElementwise`; Identity-likes already pass through
//!    zero-copy), the kernel writes
//!    its result over the input's storage instead of taking a new buffer
//!    (`KernelContext::take_forward_f32`), guarded by refcount 1 at run
//!    time.
//!
//! The plan is computed once in `Session::build_step` (gated by
//! `SessionOptions::enable_memory_planning`, default on), cached with the
//! step, and reported as [`MemoryPlanStats`] + [`MemSnapshot`] via
//! `Session::memory_stats` beside `optimizer_stats`. Correctness never
//! depends on the plan: slot checkout falls back to a fresh heap
//! allocation whenever pooled storage is still referenced, and forwarding
//! requires unique ownership — a wrong interval costs a miss, not a value.

pub mod arena;
pub mod liveness;
pub mod plan;

pub use arena::{ArenaHighWater, ArenaPool, MemCounters, MemSnapshot, StepArena};
pub use plan::{plan_partition, MemoryPlan, MemoryPlanStats};

/// One executor's memory report: the build-time plan stats plus the
/// runtime arena counters accumulated across every run of the cached
/// step, and the pool's per-step byte high-watermark. Returned by
/// `Session::memory_stats` / `Session::memory_profile`.
#[derive(Debug, Clone, Default)]
pub struct MemoryReport {
    /// Device the partition runs on.
    pub device: String,
    pub plan: MemoryPlanStats,
    pub runtime: MemSnapshot,
    /// Peak single-step bytes served by this executor's arena pool,
    /// split planned / dynamic / scratch.
    pub high_water: ArenaHighWater,
}
