//! Node attributes (§2 "Operations and Kernels": "an operation can have
//! attributes, and all attributes must be provided or inferred at
//! graph-construction time"). The common use is type polymorphism (`T`),
//! plus shapes, artifact paths (`XlaCall`), queue capacities, etc.

use crate::error::{Result, Status};
use crate::tensor::{DType, Shape, Tensor};

#[derive(Debug, Clone, PartialEq)]
pub enum AttrValue {
    I64(i64),
    F32(f32),
    Bool(bool),
    Str(String),
    Type(DType),
    Shape(Shape),
    Tensor(Tensor),
    ListI64(Vec<i64>),
    ListStr(Vec<String>),
    ListType(Vec<DType>),
    ListShape(Vec<Shape>),
}

impl AttrValue {
    pub fn kind(&self) -> &'static str {
        match self {
            AttrValue::I64(_) => "int",
            AttrValue::F32(_) => "float",
            AttrValue::Bool(_) => "bool",
            AttrValue::Str(_) => "string",
            AttrValue::Type(_) => "type",
            AttrValue::Shape(_) => "shape",
            AttrValue::Tensor(_) => "tensor",
            AttrValue::ListI64(_) => "list(int)",
            AttrValue::ListStr(_) => "list(string)",
            AttrValue::ListType(_) => "list(type)",
            AttrValue::ListShape(_) => "list(shape)",
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            AttrValue::I64(v) => Ok(*v),
            other => Err(Status::invalid_argument(format!("attr is {}, want int", other.kind()))),
        }
    }

    pub fn as_f32(&self) -> Result<f32> {
        match self {
            AttrValue::F32(v) => Ok(*v),
            other => Err(Status::invalid_argument(format!("attr is {}, want float", other.kind()))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            AttrValue::Bool(v) => Ok(*v),
            other => Err(Status::invalid_argument(format!("attr is {}, want bool", other.kind()))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            AttrValue::Str(v) => Ok(v),
            other => {
                Err(Status::invalid_argument(format!("attr is {}, want string", other.kind())))
            }
        }
    }

    pub fn as_type(&self) -> Result<DType> {
        match self {
            AttrValue::Type(v) => Ok(*v),
            other => Err(Status::invalid_argument(format!("attr is {}, want type", other.kind()))),
        }
    }

    pub fn as_shape(&self) -> Result<&Shape> {
        match self {
            AttrValue::Shape(v) => Ok(v),
            other => Err(Status::invalid_argument(format!("attr is {}, want shape", other.kind()))),
        }
    }

    pub fn as_tensor(&self) -> Result<&Tensor> {
        match self {
            AttrValue::Tensor(v) => Ok(v),
            other => {
                Err(Status::invalid_argument(format!("attr is {}, want tensor", other.kind())))
            }
        }
    }

    pub fn as_list_i64(&self) -> Result<&[i64]> {
        match self {
            AttrValue::ListI64(v) => Ok(v),
            other => {
                Err(Status::invalid_argument(format!("attr is {}, want list(int)", other.kind())))
            }
        }
    }

    pub fn as_list_str(&self) -> Result<&[String]> {
        match self {
            AttrValue::ListStr(v) => Ok(v),
            other => Err(Status::invalid_argument(format!(
                "attr is {}, want list(string)",
                other.kind()
            ))),
        }
    }

    pub fn as_list_type(&self) -> Result<&[DType]> {
        match self {
            AttrValue::ListType(v) => Ok(v),
            other => Err(Status::invalid_argument(format!(
                "attr is {}, want list(type)",
                other.kind()
            ))),
        }
    }

    pub fn as_list_shape(&self) -> Result<&[Shape]> {
        match self {
            AttrValue::ListShape(v) => Ok(v),
            other => Err(Status::invalid_argument(format!(
                "attr is {}, want list(shape)",
                other.kind()
            ))),
        }
    }
}

impl From<i64> for AttrValue {
    fn from(v: i64) -> Self {
        AttrValue::I64(v)
    }
}
impl From<f32> for AttrValue {
    fn from(v: f32) -> Self {
        AttrValue::F32(v)
    }
}
impl From<bool> for AttrValue {
    fn from(v: bool) -> Self {
        AttrValue::Bool(v)
    }
}
impl From<&str> for AttrValue {
    fn from(v: &str) -> Self {
        AttrValue::Str(v.to_string())
    }
}
impl From<String> for AttrValue {
    fn from(v: String) -> Self {
        AttrValue::Str(v)
    }
}
impl From<DType> for AttrValue {
    fn from(v: DType) -> Self {
        AttrValue::Type(v)
    }
}
impl From<Shape> for AttrValue {
    fn from(v: Shape) -> Self {
        AttrValue::Shape(v)
    }
}
impl From<Tensor> for AttrValue {
    fn from(v: Tensor) -> Self {
        AttrValue::Tensor(v)
    }
}
impl From<Vec<i64>> for AttrValue {
    fn from(v: Vec<i64>) -> Self {
        AttrValue::ListI64(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn typed_accessors() {
        assert_eq!(AttrValue::from(3i64).as_i64().unwrap(), 3);
        assert_eq!(AttrValue::from(2.5f32).as_f32().unwrap(), 2.5);
        assert_eq!(AttrValue::from(true).as_bool().unwrap(), true);
        assert_eq!(AttrValue::from("x").as_str().unwrap(), "x");
        assert_eq!(AttrValue::from(DType::F32).as_type().unwrap(), DType::F32);
        assert!(AttrValue::from(3i64).as_str().is_err());
        assert!(AttrValue::from("x").as_i64().is_err());
    }

    #[test]
    fn tensor_attr() {
        let t = Tensor::scalar_f32(1.0);
        let a = AttrValue::from(t.clone());
        assert_eq!(a.as_tensor().unwrap(), &t);
    }
}
